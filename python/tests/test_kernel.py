"""L1 correctness: Pallas kernels vs pure-jnp oracles (assert_allclose),
with hypothesis sweeping shapes, block sizes and dtypes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    nbody_accel,
    nbody_accel_ref,
    stencil3d,
    stencil3d_ref,
)

RTOL = 2e-4
ATOL = 1e-5


def _particles(rng, nt, ns):
    pt = rng.uniform(-2, 2, size=(nt, 3)).astype(np.float32)
    ps = rng.uniform(-2, 2, size=(ns, 3)).astype(np.float32)
    ms = rng.uniform(0.1, 1.0, size=(ns,)).astype(np.float32)
    return pt, ps, ms


# ---------------------------------------------------------------------------
# N-body kernel
# ---------------------------------------------------------------------------

class TestNbodyKernel:
    def test_matches_ref_basic(self):
        pt, ps, ms = _particles(np.random.RandomState(0), 64, 64)
        got = nbody_accel(pt, ps, ms, block_t=32, block_s=16)
        want = nbody_accel_ref(jnp.asarray(pt), jnp.asarray(ps), jnp.asarray(ms))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)

    @settings(max_examples=40, deadline=None)
    @given(
        nt=st.integers(1, 97),
        ns=st.integers(1, 97),
        bt=st.sampled_from([4, 16, 32, 128]),
        bs=st.sampled_from([4, 16, 32, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shape_sweep(self, nt, ns, bt, bs, seed):
        pt, ps, ms = _particles(np.random.RandomState(seed), nt, ns)
        got = nbody_accel(pt, ps, ms, block_t=bt, block_s=bs)
        want = nbody_accel_ref(jnp.asarray(pt), jnp.asarray(ps), jnp.asarray(ms))
        assert got.shape == (nt, 3)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(eps=st.floats(0.01, 1.0), seed=st.integers(0, 1000))
    def test_eps_is_respected(self, eps, seed):
        pt, ps, ms = _particles(np.random.RandomState(seed), 16, 16)
        got = nbody_accel(pt, ps, ms, eps=eps, block_t=8, block_s=8)
        want = nbody_accel_ref(
            jnp.asarray(pt), jnp.asarray(ps), jnp.asarray(ms), eps=eps
        )
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)

    def test_zero_mass_sources_contribute_nothing(self):
        pt, ps, ms = _particles(np.random.RandomState(1), 8, 8)
        a0 = nbody_accel(pt, ps, np.zeros_like(ms), block_t=8, block_s=8)
        assert_allclose(np.asarray(a0), 0.0, atol=1e-7)

    def test_self_forces_sum_to_zero(self):
        # Newton's third law: with targets == sources, total momentum
        # change sum_i m_i a_i vanishes.
        pt, _, ms = _particles(np.random.RandomState(2), 48, 48)
        a = np.asarray(nbody_accel(pt, pt, ms, block_t=16, block_s=16))
        total = (ms[:, None] * a).sum(axis=0)
        assert_allclose(total, 0.0, atol=5e-4)

    def test_single_particle_pair(self):
        # Two unit masses 1 apart on x: analytic softened force.
        pt = np.array([[0.0, 0, 0], [1.0, 0, 0]], dtype=np.float32)
        ms = np.array([1.0, 1.0], dtype=np.float32)
        eps = 0.05
        a = np.asarray(nbody_accel(pt, pt, ms, eps=eps, block_t=2, block_s=2))
        expected = 1.0 / (1.0 + eps * eps) ** 1.5
        assert_allclose(a[0], [expected, 0, 0], rtol=1e-5, atol=1e-6)
        assert_allclose(a[1], [-expected, 0, 0], rtol=1e-5, atol=1e-6)

    def test_block_size_larger_than_n(self):
        pt, ps, ms = _particles(np.random.RandomState(3), 5, 7)
        got = nbody_accel(pt, ps, ms, block_t=128, block_s=128)
        want = nbody_accel_ref(jnp.asarray(pt), jnp.asarray(ps), jnp.asarray(ms))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)

    def test_accepts_float64_input(self):
        pt, ps, ms = _particles(np.random.RandomState(4), 9, 9)
        got = nbody_accel(pt.astype(np.float64), ps.astype(np.float64), ms.astype(np.float64))
        assert got.dtype == jnp.float32
        want = nbody_accel_ref(jnp.asarray(pt), jnp.asarray(ps), jnp.asarray(ms))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# 3-D stencil kernel
# ---------------------------------------------------------------------------

class TestStencilKernel:
    def test_matches_ref_basic(self):
        u = np.random.RandomState(0).randn(16, 16, 16).astype(np.float32)
        got = stencil3d(u, block_z=4)
        want = stencil3d_ref(jnp.asarray(u))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)

    @settings(max_examples=30, deadline=None)
    @given(
        x=st.integers(3, 20),
        y=st.integers(3, 20),
        z=st.integers(3, 24),
        bz=st.sampled_from([1, 2, 4, 8, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shape_sweep(self, x, y, z, bz, seed):
        u = np.random.RandomState(seed).randn(x, y, z).astype(np.float32)
        got = stencil3d(u, block_z=bz)
        want = stencil3d_ref(jnp.asarray(u))
        assert got.shape == (x, y, z)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(omega=st.floats(0.1, 1.0), seed=st.integers(0, 1000))
    def test_omega_is_respected(self, omega, seed):
        u = np.random.RandomState(seed).randn(8, 8, 8).astype(np.float32)
        got = stencil3d(u, omega=omega, block_z=4)
        want = stencil3d_ref(jnp.asarray(u), omega=omega)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=1e-4)

    def test_constant_field_is_fixed_point(self):
        u = np.full((10, 10, 10), 3.25, dtype=np.float32)
        got = np.asarray(stencil3d(u, block_z=5))
        assert_allclose(got, 3.25, rtol=0, atol=1e-6)

    def test_boundary_cells_never_change(self):
        u = np.random.RandomState(5).randn(12, 11, 10).astype(np.float32)
        got = np.asarray(stencil3d(u, block_z=4))
        for sl in [
            (0, slice(None), slice(None)),
            (-1, slice(None), slice(None)),
            (slice(None), 0, slice(None)),
            (slice(None), -1, slice(None)),
            (slice(None), slice(None), 0),
            (slice(None), slice(None), -1),
        ]:
            assert_allclose(got[sl], u[sl], atol=1e-7)

    def test_max_principle(self):
        # Relaxation with omega<=1 cannot create new extrema.
        u = np.random.RandomState(6).randn(9, 9, 9).astype(np.float32)
        got = np.asarray(stencil3d(u, block_z=3))
        assert got.max() <= u.max() + 1e-5
        assert got.min() >= u.min() - 1e-5

    def test_repeated_relaxation_converges_toward_harmonic(self):
        # With fixed boundaries, repeated sweeps must monotonically reduce
        # the residual of the discrete Laplace equation.
        rng = np.random.RandomState(7)
        u = rng.randn(8, 8, 8).astype(np.float32)
        def residual(v):
            c = v[1:-1, 1:-1, 1:-1]
            nbr = (
                v[:-2, 1:-1, 1:-1] + v[2:, 1:-1, 1:-1] + v[1:-1, :-2, 1:-1]
                + v[1:-1, 2:, 1:-1] + v[1:-1, 1:-1, :-2] + v[1:-1, 1:-1, 2:]
            )
            return float(np.abs(nbr / 6.0 - c).max())
        r0 = residual(u)
        v = u
        for _ in range(50):
            v = np.asarray(stencil3d(v, block_z=4))
        assert residual(v) < 0.5 * r0

    def test_min_size_grid(self):
        u = np.random.RandomState(8).randn(3, 3, 3).astype(np.float32)
        got = stencil3d(u, block_z=1)
        want = stencil3d_ref(jnp.asarray(u))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
