"""AOT compile path: artifacts lower, parse, and the manifest's validation
vectors match a re-execution of the jitted models."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out))
    with open(out / "manifest.json") as f:
        manifest = json.load(f)
    return out, manifest


def test_all_artifacts_emitted(built):
    out, manifest = built
    expected = {
        "nbody_accel",
        "nbody_kick_drift",
        "nbody_kinetic",
        "flow1d_step",
        "flow3d_step",
    }
    assert set(manifest["artifacts"]) == expected
    for name, meta in manifest["artifacts"].items():
        path = out / meta["file"]
        assert path.exists(), name
        text = path.read_text()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_manifest_shapes_consistent(built):
    _, manifest = built
    for name, meta in manifest["artifacts"].items():
        v = meta["validation"]
        for spec, flat in zip(meta["inputs"], v["inputs"]):
            assert int(np.prod(spec["shape"])) == len(flat), name
        for spec, flat in zip(meta["outputs"], v["outputs"]):
            assert int(np.prod(spec["shape"])) == len(flat), name


def test_validation_vectors_reproduce(built):
    _, manifest = built
    fns = {
        "nbody_accel": model.nbody_accel_model,
        "nbody_kick_drift": model.nbody_kick_drift,
        "nbody_kinetic": model.nbody_kinetic,
        "flow1d_step": model.flow1d_step,
        "flow3d_step": model.flow3d_step,
    }
    for name, meta in manifest["artifacts"].items():
        v = meta["validation"]
        inputs = [
            np.asarray(flat, dtype=np.float32).reshape(spec["shape"])
            for spec, flat in zip(meta["inputs"], v["inputs"])
        ]
        outputs = fns[name](*inputs)
        for spec, flat, got in zip(meta["outputs"], v["outputs"], outputs):
            want = np.asarray(flat, dtype=np.float32).reshape(spec["shape"])
            assert_allclose(np.asarray(got), want, rtol=v["rtol"], atol=v["atol"])


def test_config_recorded(built):
    _, manifest = built
    cfg = manifest["config"]
    assert cfg["nbody_n"] == model.NBODY_N
    assert cfg["flow1d_m"] == model.FLOW1D_M
    assert cfg["flow3d_d"] == model.FLOW3D_D


def test_hlo_text_has_no_64bit_id_problem(built):
    # The interchange gotcha: text parses on the runtime side because ids
    # are reassigned. Here we sanity-check the emitted text is plain ASCII
    # HLO and does not embed a serialized proto.
    out, manifest = built
    for meta in manifest["artifacts"].values():
        head = (out / meta["file"]).read_text()[:200]
        assert head.isascii()


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
