"""L2 model invariants: the physics the Rust coordinator relies on."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model


def _system(rng, n):
    pos = rng.uniform(-1, 1, size=(n, 3)).astype(np.float32)
    vel = rng.uniform(-0.1, 0.1, size=(n, 3)).astype(np.float32)
    mass = rng.uniform(0.5, 1.0, size=(n,)).astype(np.float32)
    return pos, vel, mass


class TestNbodyModel:
    def test_accel_model_shape(self):
        pos, _, mass = _system(np.random.RandomState(0), 32)
        (acc,) = model.nbody_accel_model(pos, pos, mass)
        assert acc.shape == (32, 3)

    def test_kick_drift_math(self):
        pos = np.zeros((4, 3), dtype=np.float32)
        vel = np.ones((4, 3), dtype=np.float32)
        acc = np.full((4, 3), 2.0, dtype=np.float32)
        dt = np.array([0.5], dtype=np.float32)
        p, v = model.nbody_kick_drift(pos, vel, acc, dt)
        assert_allclose(np.asarray(v), 2.0)  # 1 + 2*0.5
        assert_allclose(np.asarray(p), 1.0)  # 0 + 2*0.5

    def test_momentum_conserved_over_steps(self):
        rng = np.random.RandomState(1)
        pos, vel, mass = _system(rng, 64)
        dt = np.array([0.01], dtype=np.float32)
        p0 = (mass[:, None] * vel).sum(0)
        for _ in range(20):
            (acc,) = model.nbody_accel_model(pos, pos, mass)
            pos, vel = model.nbody_kick_drift(pos, vel, np.asarray(acc), dt)
            pos, vel = np.asarray(pos), np.asarray(vel)
        p1 = (mass[:, None] * vel).sum(0)
        assert_allclose(p1, p0, atol=2e-4)

    def test_cross_site_forces_superpose(self):
        # acc(all) == acc(site A) + acc(site B): the property the
        # distributed CosmoGrid exchange relies on.
        rng = np.random.RandomState(2)
        pos, _, mass = _system(rng, 48)
        pa, pb = pos[:24], pos[24:]
        ma, mb = mass[:24], mass[24:]
        (acc_all,) = model.nbody_accel_model(pa, pos, mass)
        (acc_a,) = model.nbody_accel_model(pa, pa, ma)
        (acc_b,) = model.nbody_accel_model(pa, pb, mb)
        assert_allclose(
            np.asarray(acc_all), np.asarray(acc_a) + np.asarray(acc_b),
            rtol=1e-4, atol=1e-5,
        )

    def test_kinetic_energy(self):
        _, vel, mass = _system(np.random.RandomState(3), 16)
        (ke,) = model.nbody_kinetic(vel, mass)
        want = 0.5 * (mass[:, None] * vel * vel).sum()
        assert_allclose(np.asarray(ke)[0], want, rtol=1e-5)

    def test_total_energy_conserved(self):
        # KE + PE drift of the kick-drift integrator over 100 small steps
        # must stay well below 1% (measured ~0.12% at this configuration).
        def pe(pos, mass, eps=0.05):
            d = pos[None, :, :] - pos[:, None, :]
            r2 = (d * d).sum(-1) + eps * eps
            inv = 1.0 / np.sqrt(r2)
            np.fill_diagonal(inv, 0.0)
            return -0.5 * (mass[:, None] * mass[None, :] * inv).sum()

        rng = np.random.RandomState(4)
        n = 32
        pos = rng.uniform(-1, 1, (n, 3)).astype(np.float32)
        mass = rng.uniform(0.5, 1.0, n).astype(np.float32)
        v_scale = np.sqrt(abs(pe(pos, mass)) / mass.sum())
        vel = (rng.randn(n, 3) * 0.5 * v_scale).astype(np.float32)
        dt = np.array([0.001], dtype=np.float32)

        def ke(v):
            return 0.5 * (mass[:, None] * v * v).sum()

        e0 = ke(vel) + pe(pos, mass)
        for _ in range(100):
            (acc,) = model.nbody_accel_model(pos, pos, mass)
            pos, vel = model.nbody_kick_drift(pos, vel, np.asarray(acc), dt)
            pos, vel = np.asarray(pos), np.asarray(vel)
        e1 = ke(vel) + pe(pos, mass)
        assert np.isfinite(e1)
        assert abs(e1 - e0) / abs(e0) < 0.01


class TestFlow1d:
    def test_shapes_and_bc(self):
        m = model.FLOW1D_M
        p = np.zeros(m, dtype=np.float32)
        q = np.zeros(m, dtype=np.float32)
        bc = np.array([2.0, 0.5], dtype=np.float32)
        p2, q2, iface = model.flow1d_step(p, q, bc)
        assert p2.shape == (m,) and q2.shape == (m,) and iface.shape == (2,)
        assert_allclose(float(p2[0]), 2.0)
        assert_allclose(float(p2[-1]), 0.5)

    def test_stable_over_many_steps(self):
        m = model.FLOW1D_M
        rng = np.random.RandomState(5)
        p = rng.randn(m).astype(np.float32) * 0.1
        q = np.zeros(m, dtype=np.float32)
        for i in range(300):
            bc = np.array([np.sin(0.1 * i), 0.0], dtype=np.float32)
            p, q, _ = model.flow1d_step(p, q, bc)
            p, q = np.asarray(p), np.asarray(q)
        assert np.isfinite(p).all() and np.isfinite(q).all()
        assert np.abs(p).max() < 50 and np.abs(q).max() < 50

    def test_pulse_propagates_downstream(self):
        m = model.FLOW1D_M
        p = np.zeros(m, dtype=np.float32)
        q = np.zeros(m, dtype=np.float32)
        # constant inlet pressure drives flow into the vessel
        for _ in range(40):
            p, q, iface = model.flow1d_step(p, q, np.array([1.0, 0.0], dtype=np.float32))
            p, q = np.asarray(p), np.asarray(q)
        assert np.abs(np.asarray(p)[1 : m // 2]).max() > 1e-3


class TestFlow3d:
    def test_shapes_and_outlet(self):
        d = model.FLOW3D_D
        u = np.zeros((d, d, d), dtype=np.float32)
        bc = np.full((d, d), 1.0, dtype=np.float32)
        u2, outlet = model.flow3d_step(u, bc)
        assert u2.shape == (d, d, d)
        assert outlet.shape == (1,)

    def test_bc_plane_injected(self):
        d = model.FLOW3D_D
        u = np.zeros((d, d, d), dtype=np.float32)
        bc = np.full((d, d), 2.0, dtype=np.float32)
        u2, _ = model.flow3d_step(u, bc)
        # x=0 plane carries the injected boundary (held by Dirichlet mask)
        assert_allclose(np.asarray(u2)[0], 2.0, atol=1e-6)

    def test_relaxes_toward_uniform_bc(self):
        d = model.FLOW3D_D
        u = np.zeros((d, d, d), dtype=np.float32)
        bc = np.full((d, d), 1.0, dtype=np.float32)
        outs = []
        for _ in range(60):
            u, outlet = model.flow3d_step(np.asarray(u), bc)
            outs.append(float(np.asarray(outlet)[0]))
        # signal must have diffused into the volume
        assert np.asarray(u)[d // 2].mean() > 1e-4
        assert np.isfinite(outs).all()


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
