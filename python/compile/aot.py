"""AOT compile path: lower the L2 models to HLO **text** + a manifest.

HLO text — NOT ``lowered.compile()`` or proto ``.serialize()`` — is the
interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is lowered with ``return_tuple=True`` so the Rust side
uniformly unwraps a tuple. The manifest records input/output shapes plus
a full validation vector (seeded inputs and the jax-computed outputs) so
``rust/tests/runtime_artifacts.rs`` can verify the PJRT round-trip
numerically without invoking Python.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, specs):
    """Lower a jitted function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _example_inputs(specs, seed):
    rng = np.random.RandomState(seed)
    out = []
    for s in specs:
        arr = rng.uniform(-1.0, 1.0, size=s.shape).astype(np.float32)
        out.append(arr)
    return out


# Artifact registry: name -> (fn, specs, validation seed, input tweak)
def _registry():
    def positive_mass(inputs):
        # masses must be positive for a physical validation case
        tweaked = list(inputs)
        tweaked[-1] = np.abs(tweaked[-1]) + 0.1
        return tweaked

    def positive_dt(inputs):
        tweaked = list(inputs)
        tweaked[3] = np.array([0.01], dtype=np.float32)
        return tweaked

    return {
        "nbody_accel": (model.nbody_accel_model, model.nbody_accel_specs(), 101, positive_mass),
        "nbody_kick_drift": (model.nbody_kick_drift, model.nbody_kick_drift_specs(), 102, positive_dt),
        "nbody_kinetic": (model.nbody_kinetic, model.nbody_kinetic_specs(), 103, positive_mass),
        "flow1d_step": (model.flow1d_step, model.flow1d_specs(), 104, None),
        "flow3d_step": (model.flow3d_step, model.flow3d_specs(), 105, None),
    }


def build(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "config": {
            "nbody_n": model.NBODY_N,
            "flow1d_m": model.FLOW1D_M,
            "flow3d_d": model.FLOW3D_D,
            "flow1d_dt": model.FLOW1D_DT,
            "stencil_omega": model.STENCIL_OMEGA,
        },
        "artifacts": {},
    }
    for name, (fn, specs, seed, tweak) in _registry().items():
        hlo = to_hlo_text(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)

        inputs = _example_inputs(specs, seed)
        if tweak is not None:
            inputs = tweak(inputs)
        outputs = jax.jit(fn)(*[np.asarray(a) for a in inputs])
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [{"shape": list(s.shape), "dtype": "f32"} for s in specs],
            "outputs": [
                {"shape": list(np.asarray(o).shape), "dtype": "f32"} for o in outputs
            ],
            "validation": {
                "inputs": [np.asarray(a).ravel().tolist() for a in inputs],
                "outputs": [np.asarray(o).ravel().astype(float).tolist() for o in outputs],
                "rtol": 2e-3,
                "atol": 1e-4,
            },
        }
        print(f"wrote {fname} ({len(hlo)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
