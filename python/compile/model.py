"""L2 JAX models: the compute graphs of the two distributed applications
the paper couples with MPWide (DESIGN.md §1, §3).

* CosmoGrid analog — softened all-pairs N-body with a kick-drift
  integrator. The force evaluation calls the L1 Pallas kernel
  (:mod:`.kernels.nbody`); ``nbody_accel_model`` is exported separately so
  the Rust coordinator can evaluate *cross-site* forces on boundary
  particles received over MPWide.
* Bloodflow analog — a 1-D arterial-network solver (pyNS analog, pure
  jnp: the 1-D model is tiny by design) and a 3-D relaxation solver
  (HemeLB analog) whose sweep is the L1 Pallas stencil kernel.

Everything here is build-time only: :mod:`.aot` lowers these functions to
HLO text once, and the Rust runtime executes the artifacts. Python never
runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import nbody_accel, DEFAULT_EPS
from .kernels.stencil3d import stencil3d

# ---------------------------------------------------------------------------
# Export configuration: the fixed shapes baked into the AOT artifacts.
# ---------------------------------------------------------------------------

NBODY_N = 1024          # particles per site (CosmoGrid example/benches)
FLOW1D_M = 64           # 1-D arterial segments
FLOW3D_D = 24           # 3-D grid extent (cube)

# 1-D solver constants (phenomenological; chosen CFL-stable: c·dt/dx = 0.4)
FLOW1D_DT = 0.2
FLOW1D_DX = 1.0
FLOW1D_C2 = 4.0         # wave speed squared
FLOW1D_R = 0.1          # resistance (damping)

STENCIL_OMEGA = 0.8


# ---------------------------------------------------------------------------
# CosmoGrid analog (N-body)
# ---------------------------------------------------------------------------

def nbody_accel_model(pos_t, pos_s, mass_s):
    """Acceleration of targets due to sources (L1 Pallas kernel).

    Used for both the site-local force evaluation (targets == sources)
    and cross-site contributions from boundary particles received over
    MPWide.
    """
    return (nbody_accel(pos_t, pos_s, mass_s, eps=DEFAULT_EPS),)


def nbody_kick_drift(pos, vel, acc, dt):
    """Kick-drift update: v += a·dt, then x += v·dt.

    ``dt`` is a (1,)-shaped array so the artifact can be driven with a
    runtime-chosen step size (XLA scalars round-trip awkwardly through
    the text interchange; a 1-vector is unambiguous).
    """
    v_new = vel + acc * dt[0]
    p_new = pos + v_new * dt[0]
    return (p_new, v_new)


def nbody_kinetic(vel, mass):
    """Kinetic energy (diagnostics for the experiment logs)."""
    ke = 0.5 * jnp.sum(mass * jnp.sum(vel * vel, axis=-1))
    return (jnp.reshape(ke, (1,)),)


# ---------------------------------------------------------------------------
# Bloodflow analog — 1-D arterial network (pyNS analog)
# ---------------------------------------------------------------------------

def flow1d_step(p, q, bc):
    """One explicit step of a linearized 1-D pressure/flow system.

    dp/dt = -c² ∂q/∂x,  dq/dt = -∂p/∂x - R·q

    Args:
        p: (M,) pressure.
        q: (M,) flow rate.
        bc: (2,) boundary values — bc[0] is the inlet pressure (heart
            model), bc[1] the outlet pressure received from the 3-D code
            over MPWide (the multiscale coupling of §1.2.2).

    Returns:
        (p', q', iface) where iface = (2,) holds the values this model
        sends back to the 3-D code: pressure and flow at the coupling
        interface (the distal end).
    """
    p, q, bc = jnp.asarray(p), jnp.asarray(q), jnp.asarray(bc)
    pb = p.at[0].set(bc[0]).at[-1].set(bc[1])
    # Lax–Friedrichs: central differences with neighbour averaging, stable
    # for c·dt/dx < 1 (here 0.4). Edge replication pads the stencil.
    pe = jnp.pad(pb, 1, mode="edge")
    qe = jnp.pad(q, 1, mode="edge")
    dq = (qe[2:] - qe[:-2]) / (2.0 * FLOW1D_DX)
    dp = (pe[2:] - pe[:-2]) / (2.0 * FLOW1D_DX)
    p_avg = 0.5 * (pe[2:] + pe[:-2])
    q_avg = 0.5 * (qe[2:] + qe[:-2])
    p_new = p_avg - FLOW1D_DT * FLOW1D_C2 * dq
    q_new = q_avg - FLOW1D_DT * (dp + FLOW1D_R * q)
    p_new = p_new.at[0].set(bc[0]).at[-1].set(bc[1])
    iface = jnp.stack([p_new[-2], q_new[-1]])
    return (p_new, q_new, iface)


# ---------------------------------------------------------------------------
# Bloodflow analog — 3-D relaxation solver (HemeLB analog)
# ---------------------------------------------------------------------------

def flow3d_step(u, bc_plane):
    """One relaxation sweep with inlet boundary injection.

    Args:
        u: (D, D, D) field (e.g. pressure).
        bc_plane: (D, D) inlet values applied at the x=0 plane — in the
            coupled run this is derived from the 1-D model's interface
            pressure received over MPWide.

    Returns:
        (u', outlet) where outlet is a (1,) array holding the mean of the
        x=D-1 plane, sent back to the 1-D model as its outlet pressure.
    """
    u, bc_plane = jnp.asarray(u), jnp.asarray(bc_plane)
    u = u.at[0, :, :].set(bc_plane)
    u_new = stencil3d(u, omega=STENCIL_OMEGA)
    outlet = jnp.reshape(jnp.mean(u_new[-1, :, :]), (1,))
    return (u_new, outlet)


# ---------------------------------------------------------------------------
# Example-argument builders (shared by aot.py and the tests)
# ---------------------------------------------------------------------------

def nbody_accel_specs(n=NBODY_N):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, 3), f32),
        jax.ShapeDtypeStruct((n, 3), f32),
        jax.ShapeDtypeStruct((n,), f32),
    )


def nbody_kick_drift_specs(n=NBODY_N):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, 3), f32),
        jax.ShapeDtypeStruct((n, 3), f32),
        jax.ShapeDtypeStruct((n, 3), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )


def nbody_kinetic_specs(n=NBODY_N):
    f32 = jnp.float32
    return (jax.ShapeDtypeStruct((n, 3), f32), jax.ShapeDtypeStruct((n,), f32))


def flow1d_specs(m=FLOW1D_M):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((2,), f32),
    )


def flow3d_specs(d=FLOW3D_D):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((d, d, d), f32),
        jax.ShapeDtypeStruct((d, d), f32),
    )
