"""Pallas kernel: tiled all-pairs softened gravity (the GreeM-analog
compute hot-spot of the CosmoGrid application, DESIGN.md §3).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is 2-D over
(target blocks, source blocks). Each program holds one (BT, 3) target
block and one (BS, 3) source block in VMEM — `2*(BT+BS)*3*4` bytes plus
the (BT, BS) distance tile, far under the ~16 MiB VMEM budget for the
default BT=BS=128 (tile ≈ 64 KiB f32). The (BT, BS) pairwise reduction is
the MXU-shaped inner product; accumulation over source blocks happens in
the output ref across grid dimension 1 (revisiting semantics), which is
the standard Pallas reduction idiom. Lowered with ``interpret=True`` —
the CPU PJRT client cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DEFAULT_EPS


def _accel_kernel(pt_ref, ps_ref, ms_ref, acc_ref, *, eps2):
    """One (target-block, source-block) tile of the all-pairs sum."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pt = pt_ref[...]  # (BT, 3)
    ps = ps_ref[...]  # (BS, 3)
    ms = ms_ref[...]  # (BS,)
    d = ps[None, :, :] - pt[:, None, :]  # (BT, BS, 3)
    r2 = jnp.sum(d * d, axis=-1) + eps2  # (BT, BS)
    inv_r = jax.lax.rsqrt(r2)
    inv_r3 = inv_r * inv_r * inv_r
    w = ms[None, :] * inv_r3  # (BT, BS)
    acc_ref[...] += jnp.sum(d * w[..., None], axis=1)


def _pad_to(x, n, axis=0):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_t", "block_s", "eps"))
def nbody_accel(pos_t, pos_s, mass_s, *, eps=DEFAULT_EPS, block_t=128, block_s=128):
    """Tiled Pallas version of :func:`..kernels.ref.nbody_accel_ref`.

    Arbitrary Nt/Ns are supported by zero-padding: padded *sources* carry
    zero mass (contribute nothing), padded *targets* are sliced off.

    Args:
        pos_t: (Nt, 3) target positions.
        pos_s: (Ns, 3) source positions.
        mass_s: (Ns,) source masses.
        eps: softening length (baked into the kernel).
        block_t / block_s: VMEM tile sizes.

    Returns:
        (Nt, 3) accelerations, matching the reference to f32 tolerance.
    """
    nt, ns = pos_t.shape[0], pos_s.shape[0]
    bt = min(block_t, max(nt, 1))
    bs = min(block_s, max(ns, 1))
    nt_pad = -(-nt // bt) * bt
    ns_pad = -(-ns // bs) * bs
    pt = _pad_to(pos_t.astype(jnp.float32), nt_pad)
    ps = _pad_to(pos_s.astype(jnp.float32), ns_pad)
    ms = _pad_to(mass_s.astype(jnp.float32), ns_pad)

    grid = (nt_pad // bt, ns_pad // bs)
    acc = pl.pallas_call(
        functools.partial(_accel_kernel, eps2=float(eps) * float(eps)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((bs, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((bs,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt_pad, 3), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(pt, ps, ms)
    return acc[:nt]
