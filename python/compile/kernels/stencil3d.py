"""Pallas kernel: damped-Jacobi 7-point relaxation (the HemeLB-analog 3-D
bloodflow solver's inner sweep, DESIGN.md §3).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is 1-D over
z-slabs; each program produces one (X, Y, BZ) output slab. The input is
presented as a full-array block and the program slices its
(X+2, Y+2, BZ+2) halo'd working set with ``lax.dynamic_slice`` — on a
real TPU this becomes the HBM→VMEM halo DMA; with the default slab size
the working set is a few hundred KiB, comfortably inside VMEM. Dirichlet
boundaries are enforced by masking with the global cell coordinates.
Lowered with ``interpret=True`` (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relax_kernel(u_ref, o_ref, *, omega, bz, dims):
    x, y, z = dims
    k = pl.program_id(0)
    u = u_ref[...]  # full (X, Y, Z) — sliced below; see module docstring
    up = jnp.pad(u, 1, mode="edge")  # (X+2, Y+2, Z+2)
    z0 = k * bz
    blk = jax.lax.dynamic_slice(up, (0, 0, z0), (x + 2, y + 2, bz + 2))
    c = blk[1:-1, 1:-1, 1:-1]  # (X, Y, BZ) — the slab itself
    nbr = (
        blk[:-2, 1:-1, 1:-1]
        + blk[2:, 1:-1, 1:-1]
        + blk[1:-1, :-2, 1:-1]
        + blk[1:-1, 2:, 1:-1]
        + blk[1:-1, 1:-1, :-2]
        + blk[1:-1, 1:-1, 2:]
    )
    cand = (1.0 - omega) * c + (omega / 6.0) * nbr
    # Dirichlet mask in *global* coordinates.
    gx = jax.lax.broadcasted_iota(jnp.int32, (x, y, bz), 0)
    gy = jax.lax.broadcasted_iota(jnp.int32, (x, y, bz), 1)
    gz = jax.lax.broadcasted_iota(jnp.int32, (x, y, bz), 2) + z0
    interior = (
        (gx > 0) & (gx < x - 1) & (gy > 0) & (gy < y - 1) & (gz > 0) & (gz < z - 1)
    )
    o_ref[...] = jnp.where(interior, cand, c)


@functools.partial(jax.jit, static_argnames=("omega", "block_z"))
def stencil3d(u, *, omega=0.8, block_z=8):
    """Tiled Pallas version of :func:`..kernels.ref.stencil3d_ref`.

    Arbitrary Z is supported by choosing the largest slab size that
    divides the (possibly padded) extent; padding replicates the far
    boundary plane and is sliced off, which cannot affect interior cells
    because the pad plane only neighbours boundary cells (held fixed).

    Args:
        u: (X, Y, Z) field, any float dtype (computed in f32).
        omega: relaxation factor.
        block_z: requested z-slab thickness.

    Returns:
        (X, Y, Z) relaxed field (f32).
    """
    x, y, z = u.shape
    bz = min(block_z, z)
    z_pad = -(-z // bz) * bz
    uu = u.astype(jnp.float32)
    if z_pad != z:
        uu = jnp.concatenate([uu, jnp.repeat(uu[:, :, -1:], z_pad - z, axis=2)], axis=2)
    out = pl.pallas_call(
        functools.partial(_relax_kernel, omega=float(omega), bz=bz, dims=(x, y, z)),
        grid=(z_pad // bz,),
        in_specs=[pl.BlockSpec((x, y, z_pad), lambda k: (0, 0, 0))],
        out_specs=pl.BlockSpec((x, y, bz), lambda k: (0, 0, k)),
        out_shape=jax.ShapeDtypeStruct((x, y, z_pad), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(uu)
    return out[:, :, :z]
