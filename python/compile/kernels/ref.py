"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

These are the ground truth the Pallas kernels are validated against in
``python/tests/`` (pytest + hypothesis) — the CORE correctness signal of
the compile path. They are deliberately written in the most obvious way
possible; no tiling, no tricks.
"""

import jax.numpy as jnp

DEFAULT_EPS = 0.05  # Plummer softening length (code units)


def nbody_accel_ref(pos_t, pos_s, mass_s, eps=DEFAULT_EPS):
    """Softened gravitational acceleration on targets from sources.

    a_i = sum_j m_j (x_j - x_i) / (|x_j - x_i|^2 + eps^2)^{3/2}

    Self-interaction (pos_t is pos_s) contributes zero because the
    displacement is zero while the softened denominator is finite.

    Args:
        pos_t: (Nt, 3) target positions.
        pos_s: (Ns, 3) source positions.
        mass_s: (Ns,) source masses.
        eps: softening length.

    Returns:
        (Nt, 3) accelerations.
    """
    d = pos_s[None, :, :] - pos_t[:, None, :]  # (Nt, Ns, 3)
    r2 = jnp.sum(d * d, axis=-1) + eps * eps
    inv_r3 = r2 ** -1.5
    return jnp.sum(d * (mass_s[None, :] * inv_r3)[..., None], axis=1)


def stencil3d_ref(u, omega=0.8):
    """Damped-Jacobi 7-point relaxation sweep with Dirichlet boundaries.

    Interior cells move toward the average of their 6 neighbours with
    relaxation factor ``omega``; boundary cells are held fixed.

    Args:
        u: (X, Y, Z) field.
        omega: relaxation factor in (0, 1].

    Returns:
        (X, Y, Z) relaxed field.
    """
    c = u[1:-1, 1:-1, 1:-1]
    nbr = (
        u[:-2, 1:-1, 1:-1]
        + u[2:, 1:-1, 1:-1]
        + u[1:-1, :-2, 1:-1]
        + u[1:-1, 2:, 1:-1]
        + u[1:-1, 1:-1, :-2]
        + u[1:-1, 1:-1, 2:]
    )
    updated = (1.0 - omega) * c + (omega / 6.0) * nbr
    return u.at[1:-1, 1:-1, 1:-1].set(updated)
