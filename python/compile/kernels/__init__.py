"""L1 Pallas kernels and their pure-jnp oracles."""

from .nbody import nbody_accel
from .ref import nbody_accel_ref, stencil3d_ref, DEFAULT_EPS
from .stencil3d import stencil3d

__all__ = [
    "nbody_accel",
    "nbody_accel_ref",
    "stencil3d",
    "stencil3d_ref",
    "DEFAULT_EPS",
]
