//! Integration: mpw-cp and DataGather over real sockets — end-to-end
//! integrity (CRC32), multi-stream transfers, sync semantics, and the
//! MPWTest suite over loopback TCP.

use mpwide::mpwide::{Path, PathConfig, PathListener};
use mpwide::tools::{datagather, mpwcp, mpwtest};
use mpwide::util::Rng;

fn cfg(n: usize) -> PathConfig {
    let mut c = PathConfig::with_streams(n);
    c.autotune = false;
    c
}

fn tcp_pair(n: usize) -> (Path, Path) {
    let mut listener = PathListener::bind(0, cfg(n)).unwrap();
    let port = listener.port();
    let c = cfg(n);
    let t = std::thread::spawn(move || Path::connect("127.0.0.1", port, c).unwrap());
    let server = listener.accept_path().unwrap();
    (t.join().unwrap(), server)
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tools-int-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn mpwcp_over_tcp_with_16_streams() {
    let dir = tmpdir("cp16");
    let src = dir.join("big.bin");
    let mut data = vec![0u8; 10 << 20];
    Rng::new(21).fill_bytes(&mut data);
    std::fs::write(&src, &data).unwrap();

    let (client, server) = tcp_pair(16);
    let dest = dir.join("out");
    std::fs::create_dir_all(&dest).unwrap();
    let dest2 = dest.clone();
    let t = std::thread::spawn(move || mpwcp::recv_file(&server, &dest2).unwrap());
    let stats = mpwcp::send_file(&client, &src, "big.bin").unwrap();
    let (stored, size, crc) = t.join().unwrap();
    assert_eq!(size, 10 << 20);
    assert_eq!(crc, stats.crc);
    assert_eq!(std::fs::read(stored).unwrap(), data);
    assert!(stats.seconds > 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mpwcp_tuned_chunk_size_still_correct() {
    let dir = tmpdir("cpchunk");
    let src = dir.join("f.bin");
    let mut data = vec![0u8; 3_333_333];
    Rng::new(22).fill_bytes(&mut data);
    std::fs::write(&src, &data).unwrap();

    let (client, server) = tcp_pair(3);
    client.set_chunk_size(7_777).unwrap();
    server.set_chunk_size(7_777).unwrap();
    let dest = dir.clone();
    let t = std::thread::spawn(move || mpwcp::recv_file(&server, &dest).unwrap());
    mpwcp::send_file(&client, &src, "g.bin").unwrap();
    let (stored, _, _) = t.join().unwrap();
    assert_eq!(std::fs::read(stored).unwrap(), data);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn datagather_over_tcp_incremental_rounds() {
    let dir = tmpdir("dg");
    let src = dir.join("src");
    let dst = dir.join("dst");
    std::fs::create_dir_all(src.join("deep/nest")).unwrap();
    std::fs::write(src.join("deep/nest/a.dat"), vec![1u8; 123_456]).unwrap();
    std::fs::write(src.join("b.dat"), vec![2u8; 777]).unwrap();

    let (client, server) = tcp_pair(2);
    // round 1: ship all
    let dst2 = dst.clone();
    let t = std::thread::spawn(move || {
        let n1 = datagather::serve_once(&server, &dst2).unwrap();
        let n2 = datagather::serve_once(&server, &dst2).unwrap();
        let n3 = datagather::serve_once(&server, &dst2).unwrap();
        (n1, n2, n3)
    });
    let s1 = datagather::sync_once(&client, &src).unwrap();
    // round 2: no change
    let s2 = datagather::sync_once(&client, &src).unwrap();
    // round 3: file modified in place
    std::fs::write(src.join("b.dat"), vec![9u8; 777]).unwrap();
    let s3 = datagather::sync_once(&client, &src).unwrap();
    let (n1, n2, n3) = t.join().unwrap();
    assert_eq!((n1, s1.shipped), (2, 2));
    assert_eq!((n2, s2.shipped), (0, 0));
    assert_eq!((n3, s3.shipped), (1, 1));
    assert_eq!(std::fs::read(dst.join("deep__nest__a.dat")).unwrap(), vec![1u8; 123_456]);
    assert_eq!(std::fs::read(dst.join("b.dat")).unwrap(), vec![9u8; 777]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mpwtest_suite_over_tcp() {
    let (client, server) = tcp_pair(4);
    let t = std::thread::spawn(move || mpwtest::run_slave(&server).unwrap());
    let rows = mpwtest::run_master(&client, &[4096, 262_144, 1 << 20], |_| 4).unwrap();
    t.join().unwrap();
    assert_eq!(rows.len(), 3);
    // loopback should beat 50 MB/s easily at 1 MB messages
    let last = rows.last().unwrap();
    assert!(
        last.rate > 50.0 * 1024.0 * 1024.0,
        "loopback rate only {:.1} MB/s",
        last.rate / (1024.0 * 1024.0)
    );
}

#[test]
fn cli_binary_selftest_and_dns() {
    // exercise the shipped binary end-to-end (MPWUnitTests analog)
    let bin = env!("CARGO_BIN_EXE_mpwide");
    let out = std::process::Command::new(bin).arg("selftest").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("selftest OK"));

    let out = std::process::Command::new(bin).args(["dns", "localhost"]).output().unwrap();
    assert!(out.status.success());
    let ip = String::from_utf8_lossy(&out.stdout);
    assert!(ip.contains("127.0.0.1") || ip.contains("::1"), "{ip}");

    let out = std::process::Command::new(bin).arg("help").output().unwrap();
    assert!(String::from_utf8_lossy(&out.stdout).contains("mpw-cp"));
}

#[test]
fn cli_cp_roundtrip_via_processes() {
    let dir = tmpdir("clicp");
    let src = dir.join("payload.bin");
    let mut data = vec![0u8; 1 << 20];
    Rng::new(23).fill_bytes(&mut data);
    std::fs::write(&src, &data).unwrap();
    let dest = dir.join("recv");
    std::fs::create_dir_all(&dest).unwrap();

    let bin = env!("CARGO_BIN_EXE_mpwide");
    let port = "16131";
    let mut server = std::process::Command::new(bin)
        .args([
            "cp-serve", "--port", port, "--dir", dest.to_str().unwrap(), "--streams", "4",
            "--no-autotune",
        ])
        .spawn()
        .unwrap();
    // client retries until the server listens (connect_retry handles it)
    let out = std::process::Command::new(bin)
        .args([
            "cp",
            src.to_str().unwrap(),
            "127.0.0.1",
            "copied.bin",
            "--port",
            port,
            "--streams",
            "4",
            "--no-autotune",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let _ = server.wait();
    assert_eq!(std::fs::read(dest.join("copied.bin")).unwrap(), data);
    let _ = std::fs::remove_dir_all(&dir);
}
