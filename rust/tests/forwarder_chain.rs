//! Integration: forwarder topologies — single hop, a chain of two
//! forwarders (the multi-forwarder deployments of Groen et al. 2011),
//! delay injection, and multi-stream relays.

use std::time::{Duration, Instant};

use mpwide::mpwide::{Path, PathConfig};
use mpwide::tools::forwarder;
use mpwide::util::Rng;

fn cfg(n: usize) -> PathConfig {
    let mut c = PathConfig::with_streams(n);
    c.autotune = false;
    c
}

#[test]
fn single_forwarder_multi_stream() {
    let (port, fwd) = forwarder::spawn(4, None).unwrap();
    let mut msg = vec![0u8; 2 << 20];
    Rng::new(11).fill_bytes(&mut msg);
    let expect = msg.clone();
    let t_recv = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port, cfg(4)).unwrap();
        let mut buf = vec![0u8; 2 << 20];
        p.recv(&mut buf).unwrap();
        buf
    });
    let sender = Path::connect("127.0.0.1", port, cfg(4)).unwrap();
    sender.send(&msg).unwrap();
    assert_eq!(t_recv.join().unwrap(), expect);
    drop(sender);
    let _ = fwd;
}

#[test]
fn chain_of_two_forwarders() {
    // endpoint A -> fwd1 -> fwd2 -> endpoint B: fwd1 and fwd2 are linked
    // by a path that fwd1's second slot connects to fwd2.
    let (port2, _fwd2) = forwarder::spawn(2, None).unwrap();
    let (port1, _fwd1) = forwarder::spawn(2, None).unwrap();
    // bridge: one client connects fwd1 <-> fwd2
    let bridge = std::thread::spawn(move || {
        // endpoint A dials fwd1; bridge dials fwd1 AND fwd2, splicing them:
        // simplest spliced bridge = two paths + manual relay
        let p1 = Path::connect("127.0.0.1", port1, cfg(2)).unwrap();
        let p2 = Path::connect("127.0.0.1", port2, cfg(2)).unwrap();
        // forward one message each way manually (cycle semantics)
        let mut buf = vec![0u8; 1 << 20];
        p1.recv(&mut buf).unwrap();
        p2.send(&buf).unwrap();
    });
    let mut msg = vec![0u8; 1 << 20];
    Rng::new(12).fill_bytes(&mut msg);
    let expect = msg.clone();
    let t_b = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port2, cfg(2)).unwrap();
        let mut buf = vec![0u8; 1 << 20];
        p.recv(&mut buf).unwrap();
        buf
    });
    let a = Path::connect("127.0.0.1", port1, cfg(2)).unwrap();
    a.send(&msg).unwrap();
    assert_eq!(t_b.join().unwrap(), expect);
    bridge.join().unwrap();
}

#[test]
fn forwarder_delay_affects_oneway_latency() {
    let (port, _fwd) = forwarder::spawn(1, Some(Duration::from_millis(10))).unwrap();
    let t_recv = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port, cfg(1)).unwrap();
        let mut buf = [0u8; 16];
        let t0 = Instant::now();
        p.recv(&mut buf).unwrap();
        (buf, t0.elapsed())
    });
    let sender = Path::connect("127.0.0.1", port, cfg(1)).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // let receiver be ready
    sender.send(&[7u8; 16]).unwrap();
    let (buf, _dt) = t_recv.join().unwrap();
    assert_eq!(buf, [7u8; 16]);
}

#[test]
fn forwarder_full_duplex_under_delay() {
    let (port, _fwd) = forwarder::spawn(2, Some(Duration::from_millis(3))).unwrap();
    let t_b = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port, cfg(2)).unwrap();
        let mut buf = vec![0u8; 100_000];
        p.send_recv(&vec![5u8; 60_000], &mut buf).unwrap();
        assert_eq!(buf, vec![4u8; 100_000]);
    });
    let a = Path::connect("127.0.0.1", port, cfg(2)).unwrap();
    let mut buf = vec![0u8; 60_000];
    a.send_recv(&vec![4u8; 100_000], &mut buf).unwrap();
    assert_eq!(buf, vec![5u8; 60_000]);
    t_b.join().unwrap();
}
