//! Integration: forwarder topologies — single hop, a chain of two
//! forwarders (the multi-forwarder deployments of Groen et al. 2011),
//! delay injection, multi-stream relays, and relay behaviour when one
//! leg's path dies mid-pump.

use std::time::{Duration, Instant};

use mpwide::mpwide::relay::relay;
use mpwide::mpwide::transport::{mem_path_pairs, mem_path_pairs_killable};
use mpwide::mpwide::{MpwError, Path, PathConfig};
use mpwide::tools::forwarder;
use mpwide::util::Rng;

fn cfg(n: usize) -> PathConfig {
    let mut c = PathConfig::with_streams(n);
    c.autotune = false;
    c
}

#[test]
fn single_forwarder_multi_stream() {
    let (port, fwd) = forwarder::spawn(4, None).unwrap();
    let mut msg = vec![0u8; 2 << 20];
    Rng::new(11).fill_bytes(&mut msg);
    let expect = msg.clone();
    let t_recv = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port, cfg(4)).unwrap();
        let mut buf = vec![0u8; 2 << 20];
        p.recv(&mut buf).unwrap();
        buf
    });
    let sender = Path::connect("127.0.0.1", port, cfg(4)).unwrap();
    sender.send(&msg).unwrap();
    assert_eq!(t_recv.join().unwrap(), expect);
    drop(sender);
    let _ = fwd;
}

#[test]
fn chain_of_two_forwarders() {
    // endpoint A -> fwd1 -> fwd2 -> endpoint B: fwd1 and fwd2 are linked
    // by a path that fwd1's second slot connects to fwd2.
    let (port2, _fwd2) = forwarder::spawn(2, None).unwrap();
    let (port1, _fwd1) = forwarder::spawn(2, None).unwrap();
    // bridge: one client connects fwd1 <-> fwd2
    let bridge = std::thread::spawn(move || {
        // endpoint A dials fwd1; bridge dials fwd1 AND fwd2, splicing them:
        // simplest spliced bridge = two paths + manual relay
        let p1 = Path::connect("127.0.0.1", port1, cfg(2)).unwrap();
        let p2 = Path::connect("127.0.0.1", port2, cfg(2)).unwrap();
        // forward one message each way manually (cycle semantics)
        let mut buf = vec![0u8; 1 << 20];
        p1.recv(&mut buf).unwrap();
        p2.send(&buf).unwrap();
    });
    let mut msg = vec![0u8; 1 << 20];
    Rng::new(12).fill_bytes(&mut msg);
    let expect = msg.clone();
    let t_b = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port2, cfg(2)).unwrap();
        let mut buf = vec![0u8; 1 << 20];
        p.recv(&mut buf).unwrap();
        buf
    });
    let a = Path::connect("127.0.0.1", port1, cfg(2)).unwrap();
    a.send(&msg).unwrap();
    assert_eq!(t_b.join().unwrap(), expect);
    bridge.join().unwrap();
}

#[test]
fn forwarder_delay_affects_oneway_latency() {
    let (port, _fwd) = forwarder::spawn(1, Some(Duration::from_millis(10))).unwrap();
    let t_recv = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port, cfg(1)).unwrap();
        let mut buf = [0u8; 16];
        let t0 = Instant::now();
        p.recv(&mut buf).unwrap();
        (buf, t0.elapsed())
    });
    let sender = Path::connect("127.0.0.1", port, cfg(1)).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // let receiver be ready
    sender.send(&[7u8; 16]).unwrap();
    let (buf, _dt) = t_recv.join().unwrap();
    assert_eq!(buf, [7u8; 16]);
}

#[test]
fn relay_returns_partial_stats_when_one_leg_dies_mid_pump() {
    // Regression: a hard stream error on one leg used to leave the other
    // pumps parked in reads forever — relay() hung instead of reporting.
    let (l, fl, kills) = mem_path_pairs_killable(3);
    let (fr, r) = mem_path_pairs(3);
    let left = Path::from_pairs(l, cfg(3)).unwrap();
    let fwd_l = Path::from_pairs(fl, cfg(3)).unwrap();
    let fwd_r = Path::from_pairs(fr, cfg(3)).unwrap();
    let right = Path::from_pairs(r, cfg(3)).unwrap();

    let t_relay = std::thread::spawn(move || relay(&fwd_l, &fwd_r));
    let t_right = std::thread::spawn(move || {
        let mut buf = vec![0u8; 30_000];
        right.recv(&mut buf).unwrap();
        buf
    });
    let mut msg = vec![0u8; 30_000];
    Rng::new(23).fill_bytes(&mut msg);
    left.send(&msg).unwrap();
    assert_eq!(t_right.join().unwrap(), msg, "healthy relay must still forward");

    // sever one stream of the left leg while the relay idles on it; the
    // relay must notice, tear down and return — within a bounded time
    let t0 = Instant::now();
    kills[2].fire();
    match t_relay.join().unwrap() {
        Err(MpwError::RelayBroken { a_to_b, b_to_a, .. }) => {
            let hdr = mpwide::mpwide::path::ACTIVE_HEADER_LEN as u64;
            assert_eq!(a_to_b, 30_000 + hdr, "partial stats must be preserved");
            assert_eq!(b_to_a, 0);
        }
        other => panic!("expected RelayBroken, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(10), "relay hung on the dead leg");
}

#[test]
fn forwarder_full_duplex_under_delay() {
    let (port, _fwd) = forwarder::spawn(2, Some(Duration::from_millis(3))).unwrap();
    let t_b = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port, cfg(2)).unwrap();
        let mut buf = vec![0u8; 100_000];
        p.send_recv(&[5u8; 60_000], &mut buf).unwrap();
        assert_eq!(buf, vec![4u8; 100_000]);
    });
    let a = Path::connect("127.0.0.1", port, cfg(2)).unwrap();
    let mut buf = vec![0u8; 60_000];
    a.send_recv(&[4u8; 100_000], &mut buf).unwrap();
    assert_eq!(buf, vec![5u8; 60_000]);
    t_b.join().unwrap();
}
