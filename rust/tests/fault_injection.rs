//! Integration: netsim fault injection — stream blackout mid-send,
//! full-path flap, flap with no recovery, flappy reconnect, and the
//! adaptive controller's live-count ceiling. Mirrors the scenarios the
//! `resilience_wan` bench measures, with hard assertions suitable for
//! `cargo test`.

use mpwide::mpwide::adapt::TuneMode;
use mpwide::mpwide::{MpwError, PathConfig};
use mpwide::netsim::{profiles, AdaptiveSimPath, DriftingLink, FaultSchedule, LinkProfile};

const MB: u64 = 1024 * 1024;
const MBF: f64 = 1024.0 * 1024.0;

/// Amsterdam–Tokyo geometry with the stochastic terms zeroed so the
/// stream-count arithmetic is exact (same construction as the
/// `resilience_wan` bench).
fn clean_link() -> LinkProfile {
    let mut link = profiles::amsterdam_tokyo();
    link.loss_ab = 0.0;
    link.loss_ba = 0.0;
    link.bg_ab = 0.0;
    link.bg_ba = 0.0;
    link.jitter = 0.0;
    link.duplex_penalty = 0.0;
    link
}

fn sim(nstreams: usize, faults: FaultSchedule) -> AdaptiveSimPath {
    let mut cfg = PathConfig::with_streams(nstreams);
    cfg.tcp_window = Some(8 << 20);
    cfg.pacing_rate = Some(2.0 * MBF); // deterministic per-stream rate
    cfg.resilience.enabled = true;
    cfg.resilience.reconnect.enabled = true; // rejoin (Up events) needs it
    AdaptiveSimPath::with_faults(DriftingLink::steady(clean_link()), cfg, faults)
}

/// Drive `count` exchanges; returns per-exchange (start, end) times.
fn drive(p: &mut AdaptiveSimPath, count: usize, message: u64) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(count);
    let mut seed = 4_000;
    for _ in 0..count {
        let t0 = p.clock();
        p.send_recv(message, seed);
        seed += 1;
        out.push((t0, p.clock()));
    }
    out
}

#[test]
fn kill_one_of_four_mid_send_completes_at_three_quarters_goodput() {
    let message = 32 * MB;
    // Baseline: healthy 4-stream exchanges.
    let mut base = sim(4, FaultSchedule::none());
    let base_times = drive(&mut base, 8, message);
    let base_goodput = message as f64 / (base_times[5].1 - base_times[5].0);

    // Fault: stream 2 dies inside the 4th exchange and never returns.
    let t_kill = base_times[3].0 + 0.5 * (base_times[3].1 - base_times[3].0);
    let mut faulty = sim(4, FaultSchedule::blackout(2, t_kill, 1e9));
    let times = drive(&mut faulty, 8, message);

    assert!(faulty.retries() >= 1, "the kill must land mid-transfer");
    assert_eq!(faulty.live_streams(), 3);
    // every message completed (drive would have panicked otherwise); the
    // steady degraded goodput keeps >= (N-1)/N of baseline
    let degraded_goodput = message as f64 / (times[6].1 - times[6].0);
    let floor = 3.0 / 4.0;
    assert!(
        degraded_goodput >= floor * base_goodput,
        "degraded {:.2} MB/s < {floor} x baseline {:.2} MB/s",
        degraded_goodput / MBF,
        base_goodput / MBF
    );
}

#[test]
fn full_path_flap_stalls_then_recovers() {
    let message = 16 * MB;
    let mut healthy = sim(4, FaultSchedule::none());
    let per_exchange = {
        let t = drive(&mut healthy, 2, message);
        t[1].1 - t[1].0
    };
    // all four streams die inside the second exchange; rejoin 30 s later
    let flap_at = 1.5 * per_exchange;
    let back_at = flap_at + 30.0;
    let mut p = sim(4, FaultSchedule::path_flap(4, flap_at, back_at));
    let times = drive(&mut p, 3, message);
    assert!(p.retries() >= 1);
    assert_eq!(p.rejoins(), 4, "all streams rejoin at the flap end");
    assert_eq!(p.live_streams(), 4);
    // the interrupted exchange could only finish after the rejoin
    assert!(
        times[1].1 >= back_at,
        "exchange 1 finished at {:.1}s, before the {back_at:.1}s recovery",
        times[1].1
    );
    // post-recovery exchanges run at full speed again
    let post = times[2].1 - times[2].0;
    assert!(post <= 1.2 * per_exchange, "post-flap exchange too slow: {post:.2}s");
}

#[test]
fn flap_without_recovery_errors_all_streams_dead() {
    let message = 16 * MB;
    let faults = FaultSchedule::new(vec![
        mpwide::netsim::FaultEvent::Down { t: 0.5, stream: 0 },
        mpwide::netsim::FaultEvent::Down { t: 0.5, stream: 1 },
    ]);
    let mut p = sim(2, faults);
    let mut seed = 1;
    let mut saw_error = false;
    for _ in 0..4 {
        match p.try_send_recv(message, seed) {
            Ok(_) => seed += 1,
            Err(MpwError::AllStreamsDead) => {
                saw_error = true;
                break;
            }
            Err(e) => panic!("unexpected error {e}"),
        }
    }
    assert!(saw_error, "a dead path with no scheduled recovery must error");
}

#[test]
fn flappy_reconnect_completes_everything_and_reabsorbs() {
    let message = 16 * MB;
    let mut p = sim(4, FaultSchedule::flappy(1, 2.0, 10.0, 3));
    let times = drive(&mut p, 12, message);
    assert_eq!(times.len(), 12, "every exchange must complete");
    assert!(p.rejoins() >= 2, "flappy stream must rejoin repeatedly: {}", p.rejoins());
    // drive past the last Up event so the stream is re-absorbed
    while p.clock() < 2.0 + 2.0 * 10.0 + 5.0 + 1.0 {
        p.send_recv(message, 99);
    }
    assert_eq!(p.live_streams(), 4, "flappy stream must end re-absorbed");
    assert_eq!(p.tuning().active_streams(), 4);
}

#[test]
fn adaptive_controller_respects_live_ceiling_and_reclimbs() {
    let message = 32 * MB;
    let mut cfg = PathConfig::with_streams(8);
    cfg.tcp_window = Some(8 << 20);
    cfg.pacing_rate = Some(2.0 * MBF);
    cfg.adapt.mode = TuneMode::Adaptive;
    cfg.adapt.cooldown = 0;
    let down_at = 30.0;
    let up_at = 200.0;
    let mut p = AdaptiveSimPath::with_faults(
        DriftingLink::steady(clean_link()),
        cfg,
        FaultSchedule::blackout(5, down_at, up_at),
    );
    let mut seed = 7;
    while p.clock() < up_at - 1.0 {
        p.send_recv(message, seed);
        seed += 1;
        if p.clock() > down_at {
            assert!(
                p.tuning().active_streams() <= 7,
                "striping over a dead stream at t={:.1}",
                p.clock()
            );
        }
    }
    // after the rejoin the ceiling lifts; the controller may climb again
    while p.clock() < up_at + 100.0 {
        p.send_recv(message, seed);
        seed += 1;
    }
    assert_eq!(p.live_streams(), 8);
    assert!(p.tuning().active_streams() >= 7, "{}", p.tuning().active_streams());
}
