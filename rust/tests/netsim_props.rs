//! Property tests on the WAN simulator: conservation, capacity bounds,
//! determinism, fair-share sanity and the monotonicities the paper's
//! claims rest on.

use mpwide::mpwide::PathConfig;
use mpwide::netsim::network::{maxmin_allocate, transfer_oneway};
use mpwide::netsim::{profiles, Direction, SimPath};
use mpwide::util::prop;

const MB: f64 = 1024.0 * 1024.0;

#[test]
fn prop_all_bytes_always_delivered() {
    prop::check("conservation", 40, |rng| {
        let profs = profiles::all();
        let link = profs[rng.urange(0, profs.len())].clone();
        let bytes = rng.urange(1, 64) as f64 * MB;
        let n = rng.urange(1, 128);
        let rwnd = rng.urange(64 * 1024, 8 << 20) as f64;
        let dir = if rng.chance(0.5) { Direction::AtoB } else { Direction::BtoA };
        let r = transfer_oneway(&link, dir, bytes, n, rwnd, None, rng.next_u64());
        if (r.bytes - bytes).abs() > 1.0 {
            return Err(format!("{} of {} bytes delivered on {}", r.bytes, bytes, link.name));
        }
        if !r.seconds.is_finite() || r.seconds <= 0.0 {
            return Err(format!("bad duration {}", r.seconds));
        }
        Ok(())
    });
}

#[test]
fn prop_throughput_bounded_by_capacity() {
    prop::check("cap-bound", 40, |rng| {
        let profs = profiles::all();
        let link = profs[rng.urange(0, profs.len())].clone();
        let bytes = rng.urange(4, 64) as f64 * MB;
        let n = rng.urange(1, 128);
        let r = transfer_oneway(&link, Direction::AtoB, bytes, n, 4.0 * MB, None, rng.next_u64());
        // ×1.05: round-granularity bookkeeping can slightly overshoot
        if r.throughput > link.capacity * 1.05 {
            return Err(format!("{} > {} on {}", r.throughput, link.capacity, link.name));
        }
        Ok(())
    });
}

#[test]
fn prop_deterministic_given_seed() {
    prop::check("determinism", 25, |rng| {
        let profs = profiles::all();
        let link = profs[rng.urange(0, profs.len())].clone();
        let seed = rng.next_u64();
        let bytes = rng.urange(1, 32) as f64 * MB;
        let n = rng.urange(1, 64);
        let a = transfer_oneway(&link, Direction::AtoB, bytes, n, 2.0 * MB, None, seed);
        let b = transfer_oneway(&link, Direction::AtoB, bytes, n, 2.0 * MB, None, seed);
        if a.seconds != b.seconds || a.losses != b.losses {
            return Err("same seed, different outcome".into());
        }
        Ok(())
    });
}

#[test]
fn prop_maxmin_allocation_is_feasible_and_fair() {
    prop::check("maxmin", 300, |rng| {
        let n = rng.urange(1, 40);
        let offers: Vec<f64> = (0..n).map(|_| rng.urange(0, 1 << 22) as f64).collect();
        let cap = rng.urange(1, 1 << 24) as f64;
        let bg = rng.f64() * 8.0;
        let alloc = maxmin_allocate(&offers, cap, bg);
        let total: f64 = alloc.iter().sum();
        if total > cap * (1.0 + 1e-9) + 1.0 {
            return Err(format!("allocated {total} > cap {cap}"));
        }
        for (i, (&a, &o)) in alloc.iter().zip(&offers).enumerate() {
            if a > o + 1e-9 {
                return Err(format!("flow {i} allocated {a} > offer {o}"));
            }
            if a < 0.0 {
                return Err("negative allocation".into());
            }
        }
        // fairness: two flows with equal demand get equal allocation
        if n >= 2 {
            let mut offers2 = offers.clone();
            offers2[0] = 1000.0;
            offers2[1] = 1000.0;
            let alloc2 = maxmin_allocate(&offers2, cap, bg);
            if (alloc2[0] - alloc2[1]).abs() > 1e-6 {
                return Err("equal demands, unequal shares".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_more_streams_never_much_worse() {
    // Monotonicity (statistical): aggregate throughput with 4× the
    // streams should never be dramatically worse on any WAN profile.
    prop::check("streams-monotone", 12, |rng| {
        let wan = [
            profiles::london_poznan(),
            profiles::poznan_gdansk(),
            profiles::poznan_amsterdam(),
            profiles::ucl_yale(),
        ];
        let link = wan[rng.urange(0, wan.len())].clone();
        let seed = rng.next_u64();
        let few = SimPath::new(link.clone(), PathConfig::with_streams(2))
            .send(64 * 1024 * 1024, Direction::AtoB, seed);
        let many = SimPath::new(link, PathConfig::with_streams(8))
            .send(64 * 1024 * 1024, Direction::AtoB, seed);
        if many.throughput_ab() < 0.6 * few.throughput_ab() {
            return Err(format!(
                "8 streams {:.1} MB/s much worse than 2 streams {:.1} MB/s",
                many.throughput_ab() / MB,
                few.throughput_ab() / MB
            ));
        }
        Ok(())
    });
}

#[test]
fn wan_recommendation_holds_32_streams_beat_1() {
    // The paper's §1.3.1 guidance, asserted across every WAN profile.
    for link in [
        profiles::london_poznan(),
        profiles::poznan_gdansk(),
        profiles::poznan_amsterdam(),
        profiles::ucl_yale(),
        profiles::amsterdam_tokyo(),
    ] {
        let one = SimPath::new(link.clone(), PathConfig::with_streams(1))
            .send(64 * 1024 * 1024, Direction::AtoB, 42);
        let many = SimPath::new(link.clone(), PathConfig::with_streams(32))
            .send(64 * 1024 * 1024, Direction::AtoB, 42);
        assert!(
            many.throughput_ab() > one.throughput_ab(),
            "{}: 32 streams {:.1} <= 1 stream {:.1} MB/s",
            link.name,
            many.throughput_ab() / MB,
            one.throughput_ab() / MB
        );
    }
}

#[test]
fn local_single_stream_recommendation_holds() {
    // §1.3.1: "a single stream for connections between local programs".
    let link = profiles::local_lan();
    let one = SimPath::new(link.clone(), PathConfig::with_streams(1))
        .send(64 * 1024 * 1024, Direction::AtoB, 7);
    let many = SimPath::new(link, PathConfig::with_streams(64))
        .send(64 * 1024 * 1024, Direction::AtoB, 7);
    // locally, more streams buy nothing (within noise)
    assert!(
        many.throughput_ab() < 1.3 * one.throughput_ab(),
        "64 streams {:.0} vs 1 stream {:.0} MB/s locally",
        many.throughput_ab() / MB,
        one.throughput_ab() / MB
    );
}
