//! Integration: the online adaptive tuner (live restriping).
//!
//! Netsim side — a mid-run WAN disturbance (congestion ramp / loss
//! burst) must trigger restriping over more of the established streams
//! and recover most of the lost goodput, while a frozen creation-time
//! configuration stays degraded. Socket side — a path with adaptation
//! enabled keeps moving bytes correctly while the controller works.

use mpwide::mpwide::adapt::TuneMode;
use mpwide::mpwide::{Path, PathConfig, PathListener};
use mpwide::netsim::{profiles, AdaptiveSimPath, DriftingLink};
use mpwide::util::Rng;

const MB: u64 = 1024 * 1024;

/// A 32-stream path whose creation-time tuning settled on a few active
/// streams (plenty on a clean lightpath, given generous 8 MB windows —
/// the site maximum), over the given schedule.
fn tuned_path(schedule: DriftingLink, mode: TuneMode, active: usize) -> AdaptiveSimPath {
    let mut cfg = PathConfig::with_streams(32);
    cfg.tcp_window = Some(8 << 20);
    cfg.adapt.mode = mode;
    let p = AdaptiveSimPath::new(schedule, cfg);
    p.tuning().set_active(active);
    p
}

/// Drive `p` with 64 MB duplex exchanges until its clock passes
/// `until`; returns the goodput (A→B) of each exchange.
fn drive_until(p: &mut AdaptiveSimPath, until: f64, seed0: &mut u64) -> Vec<f64> {
    let mut rates = Vec::new();
    while p.clock() < until {
        let r = p.send_recv(64 * MB, *seed0);
        *seed0 += 1;
        rates.push(r.throughput_ab());
    }
    rates
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

#[test]
fn congestion_ramp_triggers_restriping_and_recovers_goodput() {
    let onset = 5.0;
    let horizon = 40.0;
    let schedule = || DriftingLink::congestion_ramp(profiles::cosmogrid_lightpath(), onset, 12.0);

    let mut adaptive = tuned_path(schedule(), TuneMode::Adaptive, 4);
    let mut frozen = tuned_path(schedule(), TuneMode::Static, 4);

    let mut seed = 1000;
    drive_until(&mut adaptive, onset, &mut seed);
    let adaptive_post = drive_until(&mut adaptive, horizon, &mut seed);

    let mut seed = 1000;
    drive_until(&mut frozen, onset, &mut seed);
    let frozen_post = drive_until(&mut frozen, horizon, &mut seed);

    // the bandwidth drop made the controller stripe over (many) more of
    // the established streams — no reconnect happened, the path still
    // has 32 streams and simply uses more of them
    let active = adaptive.tuning().active_streams();
    assert!(active >= 16, "controller only reached {active} active streams");
    assert_eq!(frozen.tuning().active_streams(), 4, "frozen config must not move");

    // steady state after convergence: compare the last half of the
    // disturbance window
    let a = mean(&adaptive_post[adaptive_post.len() / 2..]);
    let f = mean(&frozen_post[frozen_post.len() / 2..]);
    assert!(
        a > 1.5 * f,
        "adaptive {:.1} MB/s not >= 1.5x frozen {:.1} MB/s",
        a / MB as f64,
        f / MB as f64
    );
}

#[test]
fn loss_burst_restripes_and_recovery_is_stable() {
    let schedule =
        DriftingLink::loss_burst(profiles::cosmogrid_lightpath(), 3.0, 30.0, 5.0e-5);
    let mut p = tuned_path(schedule, TuneMode::Adaptive, 4);
    let mut seed = 4242;
    drive_until(&mut p, 3.0, &mut seed);
    let during = drive_until(&mut p, 30.0, &mut seed);
    assert!(
        p.tuning().active_streams() > 8,
        "loss burst did not trigger restriping: {} active",
        p.tuning().active_streams()
    );
    // after the burst clears, the path must keep working and not thrash
    let after = drive_until(&mut p, 40.0, &mut seed);
    assert!(!during.is_empty() && !after.is_empty());
    assert!(mean(&after) >= mean(&during), "post-burst goodput regressed");
}

#[test]
fn adaptive_socket_path_stays_correct_under_controller_activity() {
    // Loopback TCP with adaptation on: the controller adjusts active
    // streams / chunk / pacing between messages while data integrity
    // must hold bit-exact. (Throughput is not asserted — CI machines.)
    let mut cfg = PathConfig::with_streams(8);
    cfg.autotune = false;
    cfg.adapt.mode = TuneMode::Adaptive;
    let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
    let port = listener.port();
    let t = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port, cfg).unwrap();
        let mut msg = vec![0u8; 1 << 20];
        for i in 0..12u64 {
            Rng::new(i).fill_bytes(&mut msg);
            p.send(&msg).unwrap();
        }
        p.barrier().unwrap();
    });
    let server = listener.accept_path().unwrap();
    let mut buf = vec![0u8; 1 << 20];
    let mut want = vec![0u8; 1 << 20];
    for i in 0..12u64 {
        server.recv(&mut buf).unwrap();
        Rng::new(i).fill_bytes(&mut want);
        assert_eq!(buf, want, "payload corrupted at message {i}");
    }
    server.barrier().unwrap();
    t.join().unwrap();
    let snap = server.tune_snapshot();
    assert!((1..=8).contains(&snap.active_streams), "{snap:?}");
}
