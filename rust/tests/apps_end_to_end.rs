//! End-to-end application tests: the distributed CosmoGrid run (threads +
//! PJRT + real loopback MPWide ring) matches the single-site reference,
//! and the coupled bloodflow run completes with latency hiding beating
//! blocking exchanges. Requires `make artifacts`.

use mpwide::bloodflow::{run_coupled, CouplingConfig};
use mpwide::cosmogrid::{self, sim, SimConfig};
use mpwide::runtime::Runtime;

fn artifacts_or_skip() -> Option<std::path::PathBuf> {
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn distributed_matches_single_site() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg = SimConfig {
        sites: 2,
        steps: 3,
        artifacts_dir: dir,
        nstreams: 2,
        seed: 7,
        ..Default::default()
    };
    let (_, ref_sites) = cosmogrid::run_single_site(&cfg).unwrap();
    let dist = cosmogrid::run_distributed(&cfg).unwrap();
    assert_eq!(dist.sites.len(), ref_sites.len());
    // same ICs, same tile decomposition; only the f32 summation order of
    // cross-site contributions differs → tight but not bitwise tolerance
    for (d, r) in dist.sites.iter().zip(&ref_sites) {
        assert_eq!(d.n_local, r.n_local);
        let max_err = d
            .pos
            .iter()
            .zip(&r.pos)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-4, "positions diverged by {max_err}");
    }
    assert!(dist.bytes_exchanged > 0);
}

#[test]
fn distributed_momentum_is_conserved() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg = SimConfig {
        sites: 2,
        steps: 5,
        artifacts_dir: dir,
        nstreams: 2,
        seed: 11,
        ..Default::default()
    };
    let dist = cosmogrid::run_distributed(&cfg).unwrap();
    // total momentum across sites ≈ initial total momentum (generation
    // has small random net momentum; conservation is about drift)
    let total: [f32; 3] = dist.sites.iter().fold([0.0; 3], |mut acc, s| {
        let m = s.momentum();
        for d in 0..3 {
            acc[d] += m[d];
        }
        acc
    });
    // against the initial state: re-generate and sum
    let rt = Runtime::open(&cfg.artifacts_dir).unwrap();
    let n_pad = rt.manifest().config_usize("nbody_n").unwrap();
    let (_, vel, mass) = cosmogrid::generate_ics(n_pad * 2, 11);
    let mut initial = [0.0f32; 3];
    for i in 0..mass.len() {
        for d in 0..3 {
            initial[d] += mass[i] * vel[i * 3 + d];
        }
    }
    for d in 0..3 {
        let drift = (total[d] - initial[d]).abs();
        assert!(drift < 5e-3, "momentum drift in {d}: {total:?} vs {initial:?}");
    }
}

#[test]
fn per_step_timings_are_recorded() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg =
        SimConfig { sites: 2, steps: 4, artifacts_dir: dir, nstreams: 2, ..Default::default() };
    let dist = cosmogrid::run_distributed(&cfg).unwrap();
    assert_eq!(dist.timings.len(), 4);
    for t in &dist.timings {
        assert!(t.compute > 0.0);
        assert!(t.comm >= 0.0);
    }
    let frac = sim::comm_fraction(&dist.timings);
    assert!((0.0..1.0).contains(&frac));
}

#[test]
fn snapshot_written_from_distributed_state() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg =
        SimConfig { sites: 3, steps: 1, artifacts_dir: dir, nstreams: 2, ..Default::default() };
    let dist = cosmogrid::run_distributed(&cfg).unwrap();
    let out = std::env::temp_dir().join(format!("fig2-{}.ppm", std::process::id()));
    cosmogrid::snapshot::snapshot(&dist.sites, &out, 128, 0.8).unwrap();
    let data = std::fs::read(&out).unwrap();
    assert!(data.starts_with(b"P6\n128 128\n255\n"));
    // three sites → at least two distinct colours present
    let body = &data[15..];
    let mut reds = 0usize;
    let mut greens = 0usize;
    for px in body.chunks(3) {
        if px[0] > px[1] && px[0] > px[2] {
            reds += 1;
        }
        if px[1] > px[0] && px[1] > px[2] {
            greens += 1;
        }
    }
    assert!(reds > 0 && greens > 0, "expected multi-colour snapshot");
    let _ = std::fs::remove_file(&out);
}

#[test]
fn single_site_snapshot_steps_create_io_peaks() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg = SimConfig {
        sites: 2,
        steps: 4,
        artifacts_dir: dir,
        snapshot_steps: vec![2],
        ..Default::default()
    };
    let (timings, _) = cosmogrid::run_single_site(&cfg).unwrap();
    assert!(timings[2].io > 0.0, "snapshot step has no io time");
    assert_eq!(timings[1].io, 0.0);
}

#[test]
fn bloodflow_coupled_run_completes_and_hides_latency() {
    let Some(dir) = artifacts_or_skip() else { return };
    let base = CouplingConfig {
        exchanges: 15,
        substeps: 10,
        substeps_1d: 20,
        hop_delay: Some(std::time::Duration::from_micros(5500)),
        artifacts_dir: dir.clone(),
        latency_hiding: true,
    };
    let hidden = run_coupled(&base).unwrap();
    let blocking = run_coupled(&CouplingConfig { latency_hiding: false, ..base.clone() }).unwrap();

    assert_eq!(hidden.exchanges, 15);
    assert!(hidden.final_outlet.is_finite());
    // blocking pays a large share of the 11 ms RTT per exchange (exact
    // value depends on which side arrives first); hiding must beat it
    assert!(
        blocking.overhead_per_exchange > 0.004,
        "blocking overhead {:.4}s suspiciously low",
        blocking.overhead_per_exchange
    );
    assert!(
        hidden.overhead_per_exchange < blocking.overhead_per_exchange,
        "hiding {:.4}s not better than blocking {:.4}s",
        hidden.overhead_per_exchange,
        blocking.overhead_per_exchange
    );
}

#[test]
fn bloodflow_physics_signal_propagates() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg = CouplingConfig {
        exchanges: 40,
        substeps: 15,
        substeps_1d: 30,
        hop_delay: None, // fast test
        artifacts_dir: dir,
        latency_hiding: true,
    };
    let report = run_coupled(&cfg).unwrap();
    // the heart pulse must reach the 1-D interface by then
    assert!(report.final_iface_p.abs() > 1e-5, "no signal at interface");
}
