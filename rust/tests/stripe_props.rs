//! Property tests on the striping/chunking core (the paper's `MPW_Send`
//! "splitted evenly over the channels" contract) and on the real Path
//! over in-memory transports: reassembly is exact for arbitrary sizes,
//! stream counts and chunk sizes.

use mpwide::mpwide::transport::mem_path_pairs;
use mpwide::mpwide::{stripe, Path, PathConfig};
use mpwide::util::prop;

#[test]
fn prop_segments_partition_any_message() {
    prop::check("segments-partition", 500, |rng| {
        let len = prop::message_size(rng, 4096);
        let n = rng.urange(1, 257);
        let segs = stripe::segments(len, n);
        if segs.len() != n {
            return Err(format!("want {n} segments, got {}", segs.len()));
        }
        let mut covered = 0usize;
        for (i, s) in segs.iter().enumerate() {
            if s.start != covered {
                return Err(format!("gap before segment {i}"));
            }
            covered = s.end;
        }
        if covered != len {
            return Err(format!("covered {covered} != len {len}"));
        }
        // balance: sizes differ by at most 1
        let sizes: Vec<usize> = segs.iter().map(|s| s.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        if mx - mn > 1 {
            return Err(format!("unbalanced: {mn}..{mx}"));
        }
        Ok(())
    });
}

#[test]
fn prop_chunks_partition_any_segment() {
    prop::check("chunks-partition", 500, |rng| {
        let start = rng.urange(0, 10_000);
        let len = rng.urange(0, 100_000);
        let chunk = rng.urange(1, 9999);
        let mut covered = start;
        for c in stripe::chunks(start..start + len, chunk) {
            if c.start != covered {
                return Err("gap".into());
            }
            if c.len() > chunk {
                return Err(format!("chunk {} > {chunk}", c.len()));
            }
            if c.is_empty() {
                return Err("empty chunk".into());
            }
            covered = c.end;
        }
        if covered != start + len {
            return Err("incomplete".into());
        }
        Ok(())
    });
}

#[test]
fn prop_call_count_consistent_with_chunks() {
    prop::check("call-count", 300, |rng| {
        let len = prop::message_size(rng, 1 << 16);
        let n = rng.urange(1, 64);
        let chunk = rng.urange(1, 1 << 20);
        let want: usize = stripe::segments(len, n)
            .into_iter()
            .map(|s| stripe::chunks(s, chunk).count())
            .sum();
        let got = stripe::call_count(len, n, chunk);
        if got != want {
            return Err(format!("{got} != {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_path_roundtrip_any_size_and_chunk() {
    // End-to-end over the real Path implementation (in-memory transport):
    // whatever we send arrives byte-identical, for adversarial
    // size/stream/chunk combinations.
    prop::check("path-roundtrip", 60, |rng| {
        let n = rng.urange(1, 9);
        let chunk = rng.urange(1, 3000);
        let len = prop::message_size(rng, chunk).min(200_000);
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        cfg.chunk_size = chunk;
        let a = Path::from_pairs(l, cfg.clone()).map_err(|e| e.to_string())?;
        let b = Path::from_pairs(r, cfg).map_err(|e| e.to_string())?;
        let mut msg = vec![0u8; len];
        rng.fill_bytes(&mut msg);
        let expect = msg.clone();
        let t = std::thread::spawn(move || -> Result<Vec<u8>, String> {
            let mut buf = vec![0u8; len];
            b.recv(&mut buf).map_err(|e| e.to_string())?;
            Ok(buf)
        });
        a.send(&msg).map_err(|e| e.to_string())?;
        let got = t.join().map_err(|_| "join".to_string())??;
        if got != expect {
            return Err("bytes differ after reassembly".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dynamic_roundtrip_any_size() {
    prop::check("dsend-roundtrip", 40, |rng| {
        let n = rng.urange(1, 5);
        let len = rng.urange(0, 100_000);
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        let a = Path::from_pairs(l, cfg.clone()).map_err(|e| e.to_string())?;
        let b = Path::from_pairs(r, cfg).map_err(|e| e.to_string())?;
        let mut msg = vec![0u8; len];
        rng.fill_bytes(&mut msg);
        let expect = msg.clone();
        let t = std::thread::spawn(move || b.drecv().map_err(|e| e.to_string()));
        a.dsend(&msg).map_err(|e| e.to_string())?;
        let got = t.join().map_err(|_| "join".to_string())??;
        if got != expect {
            return Err(format!("dynamic roundtrip mismatch at len {len}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sendrecv_full_duplex_never_deadlocks() {
    // Regression guard: full-duplex exchanges of mismatched sizes must
    // not deadlock (header/body interleaving on stream 0).
    prop::check("duplex-no-deadlock", 30, |rng| {
        let n = rng.urange(1, 4);
        let la = rng.urange(0, 50_000);
        let lb = rng.urange(0, 50_000);
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        let a = Path::from_pairs(l, cfg.clone()).map_err(|e| e.to_string())?;
        let b = Path::from_pairs(r, cfg).map_err(|e| e.to_string())?;
        let ma = vec![0xAAu8; la];
        let mb = vec![0xBBu8; lb];
        let (ma2, mb2) = (ma.clone(), mb.clone());
        let t = std::thread::spawn(move || -> Result<(), String> {
            let mut cache = Vec::new();
            let got = b.dsend_recv(&mb2, &mut cache).map_err(|e| e.to_string())?;
            if cache[..got] != ma2[..] {
                return Err("b side mismatch".into());
            }
            Ok(())
        });
        let mut cache = Vec::new();
        let got = a.dsend_recv(&ma, &mut cache).map_err(|e| e.to_string())?;
        if cache[..got] != mb[..] {
            return Err("a side mismatch".into());
        }
        t.join().map_err(|_| "join".to_string())??;
        Ok(())
    });
}
