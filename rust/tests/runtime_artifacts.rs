//! Integration: the PJRT runtime loads every AOT artifact and reproduces
//! the jax-computed validation outputs — the numeric contract across the
//! python→rust boundary. Requires `make artifacts` (skipped with a notice
//! otherwise).

use mpwide::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(dir).expect("runtime opens"))
}

#[test]
fn manifest_lists_all_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.manifest().names();
    for expected in
        ["flow1d_step", "flow3d_step", "nbody_accel", "nbody_kick_drift", "nbody_kinetic"]
    {
        assert!(names.contains(&expected), "missing {expected} in {names:?}");
    }
}

#[test]
fn every_artifact_validates_numerically() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in rt.manifest().names() {
        let exe = rt.load(name).unwrap_or_else(|e| panic!("load {name}: {e:#}"));
        let max_rel = exe.validate().unwrap_or_else(|e| panic!("validate {name}: {e:#}"));
        eprintln!("{name}: max rel err {max_rel:.2e}");
    }
}

#[test]
fn nbody_accel_shapes_and_physics() {
    let Some(rt) = runtime_or_skip() else { return };
    let n = rt.manifest().config_usize("nbody_n").unwrap();
    let exe = rt.load("nbody_accel").unwrap();

    // Two bodies far apart on x, everything else at the origin with zero
    // mass: acceleration must point along +x for the body at -d.
    let mut pos = vec![0.0f32; n * 3];
    let mut mass = vec![0.0f32; n];
    pos[0] = -1.0; // body 0 at (-1, 0, 0)
    pos[3] = 1.0; // body 1 at (+1, 0, 0)
    mass[0] = 1.0;
    mass[1] = 1.0;
    let out = exe.run_f32(&[&pos, &pos, &mass]).unwrap();
    assert_eq!(out.len(), 1);
    let acc = &out[0];
    assert_eq!(acc.len(), n * 3);
    assert!(acc[0] > 0.0, "body 0 pulled toward +x, got {}", acc[0]);
    assert!(acc[3] < 0.0, "body 1 pulled toward -x, got {}", acc[3]);
    assert!((acc[0] + acc[3]).abs() < 1e-5, "Newton's third law");
    // all zero-mass bodies feel the same field; y/z components vanish
    assert!(acc[1].abs() < 1e-6 && acc[2].abs() < 1e-6);
}

#[test]
fn kick_drift_is_exact_arithmetic() {
    let Some(rt) = runtime_or_skip() else { return };
    let n = rt.manifest().config_usize("nbody_n").unwrap();
    let exe = rt.load("nbody_kick_drift").unwrap();
    let pos = vec![1.0f32; n * 3];
    let vel = vec![2.0f32; n * 3];
    let acc = vec![4.0f32; n * 3];
    let dt = vec![0.5f32];
    let out = exe.run_f32(&[&pos, &vel, &acc, &dt]).unwrap();
    // v' = 2 + 4*0.5 = 4 ; p' = 1 + 4*0.5 = 3
    assert!(out[0].iter().all(|&p| (p - 3.0).abs() < 1e-6));
    assert!(out[1].iter().all(|&v| (v - 4.0).abs() < 1e-6));
}

#[test]
fn flow_models_run_and_couple() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest().config_usize("flow1d_m").unwrap();
    let d = rt.manifest().config_usize("flow3d_d").unwrap();
    let f1 = rt.load("flow1d_step").unwrap();
    let f3 = rt.load("flow3d_step").unwrap();

    let mut p = vec![0.0f32; m];
    let mut q = vec![0.0f32; m];
    let mut u = vec![0.0f32; d * d * d];
    let mut outlet = 0.0f32;
    // The 1-D wave travels ~0.4 cells/step, so the inlet signal needs
    // ~160 steps to reach the coupling interface at the distal end; run
    // 400 to let the coupled 3-D field pick it up.
    for step in 0..400 {
        let inlet = (0.2 * step as f32).sin();
        let bc = vec![inlet, outlet];
        let out1 = f1.run_f32(&[&p, &q, &bc]).unwrap();
        p = out1[0].clone();
        q = out1[1].clone();
        let iface_p = out1[2][0];
        let plane = vec![iface_p; d * d];
        let out3 = f3.run_f32(&[&u, &plane]).unwrap();
        u = out3[0].clone();
        outlet = out3[1][0];
        assert!(p.iter().all(|v| v.is_finite()));
        assert!(u.iter().all(|v| v.is_finite()));
    }
    // after the coupled run the 3-D field must have picked up signal
    assert!(u.iter().any(|&v| v.abs() > 1e-6));
}

#[test]
fn wrong_input_count_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("nbody_kinetic").unwrap();
    assert!(exe.run_f32(&[]).is_err());
}

#[test]
fn wrong_input_size_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("nbody_kinetic").unwrap();
    let vel = vec![0.0f32; 3];
    let mass = vec![0.0f32; 7];
    assert!(exe.run_f32(&[&vel, &mass]).is_err());
}

#[test]
fn unknown_artifact_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.load("does_not_exist").is_err());
}

#[test]
fn one_runtime_per_thread_pattern_works() {
    // The xla wrappers are Rc-based (not Send), so each coordinator
    // thread — like each CosmoGrid site — owns its own Runtime. This is
    // the pattern the applications use; prove it composes.
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    std::thread::scope(|s| {
        for t in 0..3usize {
            let dir = dir.clone();
            s.spawn(move || {
                let rt = Runtime::open(dir).unwrap();
                let n = rt.manifest().config_usize("nbody_n").unwrap();
                let exe = rt.load("nbody_kinetic").unwrap();
                let vel = vec![t as f32; n * 3];
                let mass = vec![1.0f32; n];
                let out = exe.run_f32(&[&vel, &mass]).unwrap();
                let want = 0.5 * (t * t * 3 * n) as f32;
                assert!((out[0][0] - want).abs() <= want.max(1.0) * 1e-4);
            });
        }
    });
}
