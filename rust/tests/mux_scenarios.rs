//! WAN scenario matrix for channel multiplexing: {2, 8, 32 channels} ×
//! {clean link, mid-run stream blackout, full path flap + rejoin}.
//!
//! Every cell asserts the mux contract end to end:
//!   * **delivery** — every message queued on every channel arrives
//!     exactly once with intact content;
//!   * **per-channel ordering** — each channel's messages arrive in
//!     send order (message payloads embed `(channel, index)`);
//!   * **no cross-channel starvation** — a bulk message queued *first*
//!     on channel 0 must finish *after* every small channel's traffic
//!     (checked via the endpoint's global delivery tickets, which a
//!     strict-FIFO mux would fail deterministically).
//!
//! The clean and blackout cells run over the in-memory transport (the
//! blackout kills one of four streams mid-run; the resilience layer
//! stripes around it underneath the channels). The path-flap cell runs
//! over real sockets with the full rejoin machinery — reconnect
//! monitor, rejoin daemon — and kills **all** streams between two
//! traffic batches.

use std::sync::Arc;
use std::time::{Duration, Instant};

use mpwide::mpwide::mux::{Channel, ChannelOptions, MuxConfig, MuxEndpoint};
use mpwide::mpwide::resilience::connect_with_rejoin;
use mpwide::mpwide::transport::mem_path_pairs_killable;
use mpwide::mpwide::{Path, PathConfig, PathListener};
use mpwide::util::Rng;

const CHANNEL_COUNTS: [usize; 3] = [2, 8, 32];
const SMALL_MSGS: u32 = 3;
const SMALL_LEN: usize = 2 * 1024;
const BULK_LEN: usize = 2 << 20;

/// Deterministic payload for message `i` of channel `ch`: 8-byte
/// `(ch, i)` prefix + seeded random body.
fn msg_for(ch: u32, i: u32, len: usize) -> Vec<u8> {
    let mut m = vec![0u8; len.max(8)];
    m[0..4].copy_from_slice(&ch.to_be_bytes());
    m[4..8].copy_from_slice(&i.to_be_bytes());
    Rng::new(((ch as u64) << 32) | i as u64).fill_bytes(&mut m[8..]);
    m
}

fn mux_cfg() -> MuxConfig {
    // small quantum so the bulk message needs many rotations — the
    // starvation property is meaningful at every channel count
    MuxConfig { chunk_budget: 32 * 1024, high_water: 64 << 20, ..MuxConfig::default() }
}

/// Per-stream pacing for every scenario path: rate-limiting the pump
/// makes the starvation assertion deterministic — the producer queues
/// all messages in microseconds while the bulk transfer needs tens of
/// milliseconds of wire time, so the small channels are always queued
/// before the pump could possibly finish the bulk message.
const PACE_PER_STREAM: f64 = 32.0 * 1024.0 * 1024.0;

/// Queue one bulk message on channel 0, then `SMALL_MSGS` small
/// messages on every other channel.
fn produce(channels: &[Channel]) {
    channels[0].send(&msg_for(0, 0, BULK_LEN)).unwrap();
    for (ci, ch) in channels.iter().enumerate().skip(1) {
        for i in 0..SMALL_MSGS {
            ch.send(&msg_for(ci as u32, i, SMALL_LEN)).unwrap();
        }
    }
}

/// Drain and verify one consumer side: content, per-channel ordering.
fn consume(channels: &[Channel]) {
    let mut handles = Vec::new();
    for (ci, ch) in channels.iter().enumerate() {
        let ch = ch.clone();
        let ci = ci as u32;
        handles.push(std::thread::spawn(move || {
            let n = if ci == 0 { 1 } else { SMALL_MSGS };
            for i in 0..n {
                let len = if ci == 0 { BULK_LEN } else { SMALL_LEN };
                let m = ch.recv().unwrap();
                assert_eq!(
                    m,
                    msg_for(ci, i, len),
                    "channel {ci}: message {i} corrupted or out of order"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

/// The starvation check: every small channel's last delivery must
/// pre-date the bulk channel's delivery in the endpoint-global ticket
/// order.
fn assert_no_starvation(consumer: &MuxEndpoint, nch: usize) {
    let stats = consumer.channel_stats();
    let bulk_ticket = stats
        .iter()
        .find(|c| c.id == 0)
        .expect("bulk channel stats missing")
        .last_delivery_ticket;
    for c in stats.iter().filter(|c| c.id != 0 && (c.id as usize) < nch) {
        assert!(
            c.last_delivery_ticket < bulk_ticket,
            "channel {} (ticket {}) starved behind the bulk transfer (ticket {bulk_ticket})",
            c.id,
            c.last_delivery_ticket
        );
    }
}

fn open_all(ep: &MuxEndpoint, nch: usize) -> Vec<Channel> {
    (0..nch as u32).map(|id| ep.open(id).unwrap()).collect()
}

// ---------------------------------------------------------------------------
// Scenario: clean link.
// ---------------------------------------------------------------------------

fn run_clean(nch: usize) {
    let (l, r, _kills) = mem_path_pairs_killable(4);
    let mut pc = PathConfig::with_streams(4);
    pc.autotune = false;
    pc.chunk_size = 64 * 1024;
    pc.pacing_rate = Some(PACE_PER_STREAM);
    let a = MuxEndpoint::start_cfg(Arc::new(Path::from_pairs(l, pc.clone()).unwrap()), mux_cfg())
        .unwrap();
    let b =
        MuxEndpoint::start_cfg(Arc::new(Path::from_pairs(r, pc).unwrap()), mux_cfg()).unwrap();
    let tx = open_all(&a, nch);
    let rx = open_all(&b, nch);
    produce(&tx);
    consume(&rx);
    assert_no_starvation(&b, nch);
}

#[test]
fn clean_link_2_channels() {
    run_clean(2);
}

#[test]
fn clean_link_8_channels() {
    run_clean(8);
}

#[test]
fn clean_link_32_channels() {
    run_clean(32);
}

// ---------------------------------------------------------------------------
// Scenario: one-of-four stream blackout mid-run (resilient path).
// ---------------------------------------------------------------------------

fn run_blackout(nch: usize) {
    let (l, r, kills) = mem_path_pairs_killable(4);
    let mut pc = PathConfig::with_streams(4);
    pc.autotune = false;
    pc.chunk_size = 32 * 1024;
    pc.pacing_rate = Some(PACE_PER_STREAM);
    pc.resilience.enabled = true;
    let pa = Arc::new(Path::from_pairs(l, pc.clone()).unwrap());
    let pb = Arc::new(Path::from_pairs(r, pc).unwrap());
    let a = MuxEndpoint::start_cfg(pa, mux_cfg()).unwrap();
    let b = MuxEndpoint::start_cfg(pb, mux_cfg()).unwrap();
    let tx = open_all(&a, nch);
    let rx = open_all(&b, nch);
    // sever a non-control stream while the bulk transfer is in flight
    let killer = {
        let k = kills[2].clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            k.fire();
        })
    };
    produce(&tx);
    consume(&rx);
    killer.join().unwrap();
    assert_no_starvation(&b, nch);
    let st = a.path().status();
    assert!(st.live >= 3, "only the killed stream may be dead: {st:?}");
}

#[test]
fn blackout_2_channels() {
    run_blackout(2);
}

#[test]
fn blackout_8_channels() {
    run_blackout(8);
}

#[test]
fn blackout_32_channels() {
    run_blackout(32);
}

// ---------------------------------------------------------------------------
// Scenario: windowed resilient pipeline under the mux (optionally with a
// mid-run stream blackout). The pump posts up to `window` delivery-ACKed
// frames into the path's send window instead of running stop-and-wait;
// the mux contract (delivery, ordering, fairness) must be unaffected.
// ---------------------------------------------------------------------------

fn run_windowed(nch: usize, kill_mid_run: bool) {
    let (l, r, kills) = mem_path_pairs_killable(4);
    let mut pc = PathConfig::with_streams(4);
    pc.autotune = false;
    pc.chunk_size = 32 * 1024;
    pc.pacing_rate = Some(PACE_PER_STREAM);
    pc.resilience.enabled = true;
    pc.resilience.window = 8;
    let pa = Arc::new(Path::from_pairs(l, pc.clone()).unwrap());
    let pb = Arc::new(Path::from_pairs(r, pc).unwrap());
    let a = MuxEndpoint::start_cfg(pa, mux_cfg()).unwrap();
    let b = MuxEndpoint::start_cfg(pb, mux_cfg()).unwrap();
    let tx = open_all(&a, nch);
    let rx = open_all(&b, nch);
    let killer = kill_mid_run.then(|| {
        let k = kills[2].clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            k.fire();
        })
    });
    produce(&tx);
    consume(&rx);
    if let Some(killer) = killer {
        killer.join().unwrap();
    }
    assert_no_starvation(&b, nch);
    // channel flush drains the path's in-flight send window too
    for ch in &tx {
        ch.flush().unwrap();
    }
    let st = a.path().status();
    assert_eq!(st.window_in_flight, 0, "flush left frames in flight: {st:?}");
    if kill_mid_run {
        assert!(st.live >= 3, "only the killed stream may be dead: {st:?}");
    } else {
        assert_eq!(st.live, 4, "{st:?}");
    }
}

#[test]
fn windowed_clean_8_channels() {
    run_windowed(8, false);
}

#[test]
fn windowed_blackout_8_channels() {
    run_windowed(8, true);
}

#[test]
fn windowed_blackout_32_channels() {
    run_windowed(32, true);
}

// ---------------------------------------------------------------------------
// Scenario: full path flap with rejoin (TCP + monitor + daemon).
// ---------------------------------------------------------------------------

fn wait_for_live(path: &Path, want: usize, timeout: Duration) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if path.status().live >= want {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

fn run_path_flap(nch: usize) {
    const NSTREAMS: usize = 4;
    let mut cfg = PathConfig::with_streams(NSTREAMS);
    cfg.autotune = false;
    cfg.chunk_size = 32 * 1024;
    cfg.pacing_rate = Some(PACE_PER_STREAM);
    cfg.resilience.enabled = true;
    cfg.resilience.reconnect.enabled = true;
    cfg.resilience.reconnect.base_delay = Duration::from_millis(10);
    cfg.resilience.reconnect.connect_timeout = Duration::from_secs(2);
    cfg.resilience.reconnect.rejoin_wait = Duration::from_secs(15);

    let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
    let port = listener.port();
    let accept = std::thread::spawn({
        let cfg = cfg.clone();
        move || connect_with_rejoin("127.0.0.1", port, cfg).unwrap()
    });
    let server_path: Arc<Path> = listener.accept_path_arc().unwrap();
    let daemon = listener.into_rejoin_daemon().unwrap();
    let (client_path, _monitor) = accept.join().unwrap();

    let a = MuxEndpoint::start_cfg(client_path, mux_cfg()).unwrap();
    let b = MuxEndpoint::start_cfg(server_path.clone(), mux_cfg()).unwrap();
    let tx = open_all(&a, nch);
    let rx = open_all(&b, nch);

    // batch 1 over a healthy path
    produce(&tx);
    consume(&rx);
    assert_no_starvation(&b, nch);

    // the flap: every stream dies server-side. The client discovers the
    // deaths through its own failing I/O and the receiver's NACK
    // dead-stream reports — which requires traffic — so batch 2 is sent
    // IMMEDIATELY: its retries drive the discovery, the monitor redials
    // each discovered stream, and the daemon slots the sockets back in.
    for i in 0..NSTREAMS {
        server_path.inject_stream_failure(i).unwrap();
    }
    produce(&tx);
    consume(&rx);

    // with traffic done, every stream was either rejoined mid-batch or
    // redialed right after discovery — the path must return to full
    // health and stay there
    assert!(
        wait_for_live(&server_path, NSTREAMS, Duration::from_secs(20)),
        "path never recovered from the flap: {:?}",
        server_path.status()
    );
    let st = server_path.status();
    assert!(st.rejoined >= NSTREAMS as u64, "expected a full rejoin: {st:?}");
    drop(daemon);
}

// ---------------------------------------------------------------------------
// Scenario: receiver-driven credit (`MuxConfig::recv_high_water`) with a
// slow, stalled, or absent reader. The contract under test is the PR's
// acceptance bound: a channel whose application stops calling `recv`
// holds at most `recv_high_water` plus one in-flight message, the
// *peer's* pump parks that channel (and only that channel — siblings
// keep flowing), and a resumed reader drains everything the producer
// queued.
// ---------------------------------------------------------------------------

const CREDIT_HW: usize = 256 * 1024;
const CREDIT_MSG: usize = 64 * 1024;
const CREDIT_N: u32 = 64; // 4 MiB queued against a 256 KiB inbound bound

fn credited_mux_cfg() -> MuxConfig {
    MuxConfig {
        chunk_budget: 32 * 1024,
        high_water: 64 << 20, // producers never block: the bound under test is inbound
        recv_high_water: Some(CREDIT_HW),
        ..MuxConfig::default()
    }
}

/// Build a credited endpoint pair over the in-memory transport and
/// guarantee the *sender* endpoint already holds the receiver's initial
/// grants: each receiver-side channel sends one warmup message, and a
/// per-channel credit advert preempts that channel's data in the pump's
/// priority order, so once the warmup arrives over the FIFO wire the
/// grant must have arrived before it.
fn credited_pair(nch: usize) -> (MuxEndpoint, MuxEndpoint, Vec<Channel>, Vec<Channel>) {
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let mut pc = PathConfig::with_streams(2);
    pc.autotune = false;
    pc.chunk_size = 64 * 1024;
    let a = MuxEndpoint::start_cfg(Arc::new(Path::from_pairs(l, pc.clone()).unwrap()), credited_mux_cfg())
        .unwrap();
    let b = MuxEndpoint::start_cfg(Arc::new(Path::from_pairs(r, pc).unwrap()), credited_mux_cfg())
        .unwrap();
    let tx = open_all(&a, nch);
    let rx = open_all(&b, nch);
    for (ci, ch) in rx.iter().enumerate() {
        ch.send(&msg_for(ci as u32, 9999, 64)).unwrap();
    }
    for (ci, ch) in tx.iter().enumerate() {
        assert_eq!(ch.recv().unwrap(), msg_for(ci as u32, 9999, 64), "warmup corrupted");
    }
    (a, b, tx, rx)
}

/// Channel 0's current `inbound_queued_bytes` on `ep`.
fn ch0_inbound(ep: &MuxEndpoint) -> usize {
    ep.channel_stats()
        .into_iter()
        .find(|c| c.id == 0)
        .expect("channel 0 stats missing")
        .inbound_queued_bytes
}

/// Run `body` while a scoped monitor thread records the peak
/// `inbound_queued_bytes` of channel 0 on `ep`; returns that peak.
fn with_peak_monitor<F: FnOnce()>(ep: &MuxEndpoint, body: F) -> usize {
    use std::sync::atomic::{AtomicBool, Ordering};
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let monitor = s.spawn(|| {
            let mut peak = 0usize;
            while !stop.load(Ordering::Relaxed) {
                peak = peak.max(ch0_inbound(ep));
                std::thread::sleep(Duration::from_millis(1));
            }
            peak.max(ch0_inbound(ep))
        });
        body();
        stop.store(true, Ordering::Relaxed);
        monitor.join().unwrap()
    })
}

#[test]
fn credited_stalled_reader_is_bounded_then_drains() {
    let (a, b, tx, rx) = credited_pair(2);
    let peak = with_peak_monitor(&b, || {
        // flood channel 0 while its reader is stalled; every send
        // returns immediately (the outbound high-water is far above
        // the total)
        for i in 0..CREDIT_N {
            tx[0].send(&msg_for(0, i, CREDIT_MSG)).unwrap();
        }

        // the sibling channel keeps flowing while channel 0 is parked —
        // the credit gate must not head-of-line block the rotation
        for i in 0..16 {
            tx[1].send(&msg_for(1, i, SMALL_LEN)).unwrap();
            assert_eq!(rx[1].recv().unwrap(), msg_for(1, i, SMALL_LEN), "sibling starved");
        }

        // let the parked state settle, then check the steady-state
        // bound directly in addition to the monitor's peak
        std::thread::sleep(Duration::from_millis(100));
        let queued = ch0_inbound(&b);
        assert!(
            queued <= CREDIT_HW + CREDIT_MSG,
            "stalled reader exceeded the credit bound: {queued} > {CREDIT_HW} + {CREDIT_MSG}"
        );

        // the reader comes back: everything the producer queued must
        // arrive intact and in order as credit replenishes
        for i in 0..CREDIT_N {
            assert_eq!(rx[0].recv().unwrap(), msg_for(0, i, CREDIT_MSG), "message {i} after resume");
        }
    });
    assert!(
        peak <= CREDIT_HW + CREDIT_MSG,
        "peak inbound {peak} exceeded recv_high_water {CREDIT_HW} + one message {CREDIT_MSG}"
    );
    // the credit machinery actually engaged: the sender saw real grants
    let grant = a
        .channel_stats()
        .into_iter()
        .find(|c| c.id == 0)
        .expect("channel 0 stats missing")
        .peer_grant;
    assert!(grant > 0, "sender never received a WINDOW_UPDATE grant");
}

#[test]
fn credited_never_reader_leaves_siblings_flowing() {
    let (_a, b, tx, rx) = credited_pair(3);
    let peak = with_peak_monitor(&b, || {
        // channel 0's reader is simply gone, forever
        for i in 0..CREDIT_N {
            tx[0].send(&msg_for(0, i, CREDIT_MSG)).unwrap();
        }

        // both sibling channels run several full batches — strictly
        // more traffic than the parked channel ever got through —
        // without stalls
        for round in 0..8u32 {
            for ci in 1..3u32 {
                tx[ci as usize].send(&msg_for(ci, round, SMALL_LEN)).unwrap();
                assert_eq!(
                    rx[ci as usize].recv().unwrap(),
                    msg_for(ci, round, SMALL_LEN),
                    "channel {ci} starved behind the never-read channel"
                );
            }
        }
    });
    assert!(peak <= CREDIT_HW + CREDIT_MSG, "never-read channel grew past the bound: {peak}");
    // teardown with a parked sender and an undrained inbound queue must
    // not deadlock: MuxEndpoint::shutdown is abrupt by contract (both
    // endpoints drop here while channel 0 still holds queued bytes)
}

// ---------------------------------------------------------------------------
// Scenario: weighted DRR scheduling (`ChannelOptions { weight }`). Three
// equally-backlogged channels with weights {1, 2, 4} share one paced
// path; while all three still hold backlog, the pump's cumulative
// per-channel sent bytes must be in weight proportion (each channel's
// share can be off by at most one rotation quantum). Also composes the
// weights with receiver credit: a stalled-reader channel forfeits its
// turns no matter how heavy its weight, so siblings keep flowing and
// the inbound bound holds.
// ---------------------------------------------------------------------------

const W_WEIGHTS: [u32; 3] = [1, 2, 4];
const W_MSG: usize = 1 << 20;
const W_BACKLOG: usize = 12 << 20; // per channel

#[test]
fn weighted_shares_follow_weights_end_to_end() {
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let mut pc = PathConfig::with_streams(2);
    pc.autotune = false;
    pc.chunk_size = 64 * 1024;
    pc.pacing_rate = Some(PACE_PER_STREAM);
    let a = MuxEndpoint::start_cfg(Arc::new(Path::from_pairs(l, pc.clone()).unwrap()), mux_cfg())
        .unwrap();
    let b =
        MuxEndpoint::start_cfg(Arc::new(Path::from_pairs(r, pc).unwrap()), mux_cfg()).unwrap();
    let tx: Vec<Channel> = W_WEIGHTS
        .iter()
        .enumerate()
        .map(|(ci, &w)| a.open_opts(ci as u32, ChannelOptions { weight: w, rate: None }).unwrap())
        .collect();
    let _rx = open_all(&b, W_WEIGHTS.len());
    for (ci, ch) in tx.iter().enumerate() {
        for i in 0..(W_BACKLOG / W_MSG) as u32 {
            ch.send(&msg_for(ci as u32, i, W_MSG)).unwrap();
        }
    }
    // sample once the heaviest channel is a third through its backlog —
    // late enough for many full rotations, early enough that every
    // channel is still backlogged (shares stay comparable)
    let deadline = Instant::now() + Duration::from_secs(30);
    let stats = loop {
        let stats = a.channel_stats();
        let heavy = stats.iter().find(|c| c.id == 2).expect("channel 2 missing").sent_bytes;
        if heavy >= (W_BACKLOG / 3) as u64 {
            break stats;
        }
        assert!(Instant::now() < deadline, "pump made no progress: {stats:?}");
        std::thread::sleep(Duration::from_millis(2));
    };
    let mut norm = Vec::new();
    for (ci, &w) in W_WEIGHTS.iter().enumerate() {
        let c = stats.iter().find(|c| c.id == ci as u32).expect("channel stats missing");
        assert_eq!(c.weight, w, "stats must report the open-time weight");
        assert!(c.queued_bytes > 0, "channel {ci} drained; shares no longer comparable");
        norm.push(c.sent_bytes as f64 / f64::from(w));
    }
    let (lo, hi) =
        norm.iter().fold((f64::INFINITY, 0.0f64), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    assert!(lo > 0.0, "a backlogged channel sent nothing: {norm:?}");
    assert!(
        hi / lo < 1.6,
        "weight-normalized shares diverged: {norm:?} (weights {W_WEIGHTS:?})"
    );
}

#[test]
fn credited_parked_heavy_channel_keeps_siblings_flowing() {
    let (_a, b, tx, rx) = credited_pair(3);
    // a live weight change: channel 0 becomes 64x heavier than its
    // siblings, then its reader stalls — credit gating must dominate
    // the weight (a creditless channel forfeits its turn without
    // burning deficit, however large its quantum)
    tx[0].set_weight(64).unwrap();
    let peak = with_peak_monitor(&b, || {
        for i in 0..CREDIT_N {
            tx[0].send(&msg_for(0, i, CREDIT_MSG)).unwrap();
        }
        for round in 0..8u32 {
            for ci in 1..3u32 {
                tx[ci as usize].send(&msg_for(ci, round, SMALL_LEN)).unwrap();
                assert_eq!(
                    rx[ci as usize].recv().unwrap(),
                    msg_for(ci, round, SMALL_LEN),
                    "channel {ci} starved behind a parked weight-64 channel"
                );
            }
        }
    });
    assert!(
        peak <= CREDIT_HW + CREDIT_MSG,
        "parked heavy channel grew past the credit bound: {peak}"
    );
}

#[test]
fn path_flap_2_channels() {
    run_path_flap(2);
}

#[test]
fn path_flap_8_channels() {
    run_path_flap(8);
}

#[test]
fn path_flap_32_channels() {
    run_path_flap(32);
}
