//! Integration: fault-tolerant paths over real sockets and the
//! in-memory transport — stream failure detection, degraded-mode
//! striping, automatic rejoin (reconnect monitor + rejoin daemon), and
//! the path-status surface.

use std::sync::Arc;
use std::time::{Duration, Instant};

use mpwide::mpwide::resilience::connect_with_rejoin;
use mpwide::mpwide::transport::mem_path_pairs_killable;
use mpwide::mpwide::{MpwError, Path, PathConfig, PathListener};
use mpwide::util::Rng;

fn resilient_cfg(n: usize) -> PathConfig {
    let mut cfg = PathConfig::with_streams(n);
    cfg.autotune = false;
    cfg.chunk_size = 64 * 1024;
    cfg.resilience.enabled = true;
    cfg
}

fn rejoin_cfg(n: usize) -> PathConfig {
    let mut cfg = resilient_cfg(n);
    cfg.resilience.reconnect.enabled = true;
    cfg.resilience.reconnect.base_delay = Duration::from_millis(10);
    cfg.resilience.reconnect.connect_timeout = Duration::from_secs(2);
    cfg.resilience.reconnect.rejoin_wait = Duration::from_secs(10);
    cfg
}

fn wait_for_live(path: &Path, want: usize, timeout: Duration) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if path.status().live >= want {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

#[test]
fn tcp_stream_death_rejoin_and_reabsorb() {
    let cfg = rejoin_cfg(4);
    let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
    let port = listener.port();

    const LEN: usize = 1 << 20;
    let client = std::thread::spawn(move || {
        let (path, _monitor) = connect_with_rejoin("127.0.0.1", port, cfg).unwrap();
        let mut msg = vec![0u8; LEN];
        for i in 0..3u64 {
            Rng::new(100 + i).fill_bytes(&mut msg);
            path.send(&msg).unwrap();
        }
        // the monitor must re-establish the injected-dead stream
        assert!(
            wait_for_live(&path, 4, Duration::from_secs(10)),
            "client never re-absorbed the stream: {:?}",
            path.status()
        );
        Rng::new(103).fill_bytes(&mut msg);
        path.send(&msg).unwrap();
        path.status()
    });

    let server: Arc<Path> = listener.accept_path_arc().unwrap();
    let daemon = listener.into_rejoin_daemon().unwrap();
    let mut buf = vec![0u8; LEN];
    let mut expect = vec![0u8; LEN];

    // message 0 over a fully healthy path
    server.recv(&mut buf).unwrap();
    Rng::new(100).fill_bytes(&mut expect);
    assert_eq!(buf, expect);

    // sever stream 1 server-side: the shutdown propagates to the client,
    // whose monitor redials; the daemon slots the socket back in
    server.inject_stream_failure(1).unwrap();
    assert_eq!(server.status().live, 3);

    for i in 1..3u64 {
        server.recv(&mut buf).unwrap();
        Rng::new(100 + i).fill_bytes(&mut expect);
        assert_eq!(buf, expect, "message {i} corrupted during degradation");
    }

    assert!(
        wait_for_live(&server, 4, Duration::from_secs(10)),
        "server never saw the rejoin: {:?}",
        server.status()
    );
    let st = server.status();
    assert_eq!(st.rejoined, 1, "{st:?}");
    assert!(st.dead.is_empty(), "{st:?}");

    // message 3 arrives over the re-absorbed full stripe set
    server.recv(&mut buf).unwrap();
    Rng::new(103).fill_bytes(&mut expect);
    assert_eq!(buf, expect, "post-rejoin message corrupted");

    let client_status = client.join().unwrap();
    assert_eq!(client_status.live, 4, "{client_status:?}");
    assert_eq!(client_status.rejoined, 1, "{client_status:?}");
    assert_eq!(
        client_status.active_streams, 4,
        "rejoined stream must be re-absorbed into striping: {client_status:?}"
    );
    drop(daemon);
}

#[test]
fn tcp_resilient_path_with_autotune() {
    // The creation-time autotuner must keep working when its probe
    // traffic runs over the resilient framing.
    let mut cfg = PathConfig::with_streams(2);
    cfg.resilience.enabled = true;
    cfg.autotune = true;
    let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
    let port = listener.port();
    let t = std::thread::spawn(move || {
        let p = Path::connect("127.0.0.1", port, cfg).unwrap();
        let msg = vec![3u8; 100_000];
        p.send(&msg).unwrap();
        p.barrier().unwrap();
    });
    let server = listener.accept_path().unwrap();
    let mut buf = vec![0u8; 100_000];
    server.recv(&mut buf).unwrap();
    assert_eq!(buf, vec![3u8; 100_000]);
    server.barrier().unwrap();
    t.join().unwrap();
}

#[test]
fn mem_degraded_send_recv_after_double_failure() {
    let (l, r, kills) = mem_path_pairs_killable(4);
    let cfg = resilient_cfg(4);
    let a = Path::from_pairs(l, cfg.clone()).unwrap();
    let b = Path::from_pairs(r, cfg).unwrap();
    kills[0].fire(); // includes the initial control stream
    kills[2].fire();
    let mut msg = vec![0u8; 500_000];
    Rng::new(9).fill_bytes(&mut msg);
    let m2 = msg.clone();
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; 500_000];
        b.recv(&mut buf).unwrap();
        (buf, b.status())
    });
    a.send(&msg).unwrap();
    let (buf, bs) = t.join().unwrap();
    assert_eq!(buf, m2);
    assert_eq!(a.status().live, 2, "{:?}", a.status());
    assert_eq!(bs.live, 2, "{bs:?}");
}

#[test]
fn mem_all_dead_with_reconnect_times_out() {
    let (l, _r, kills) = mem_path_pairs_killable(2);
    let mut cfg = rejoin_cfg(2);
    cfg.resilience.reconnect.rejoin_wait = Duration::from_millis(150);
    let a = Path::from_pairs(l, cfg).unwrap();
    for k in &kills {
        k.fire();
    }
    let t0 = Instant::now();
    match a.send(&[1, 2, 3]) {
        Err(MpwError::AllStreamsDead) => {}
        other => panic!("expected AllStreamsDead, got {other:?}"),
    }
    // no monitor is running (no remote endpoint on a mem path), so the
    // send must give up after roughly rejoin_wait, not hang
    assert!(t0.elapsed() < Duration::from_secs(5));
}

#[test]
fn close_is_sticky_and_fails_fast() {
    let (l, _r, _kills) = mem_path_pairs_killable(2);
    let mut cfg = rejoin_cfg(2);
    cfg.resilience.reconnect.rejoin_wait = Duration::from_secs(30); // must not be waited out
    let a = Path::from_pairs(l, cfg).unwrap();
    a.close();
    assert!(a.is_closed());
    let t0 = Instant::now();
    match a.send(&[1, 2, 3]) {
        Err(MpwError::AllStreamsDead) => {}
        other => panic!("expected AllStreamsDead on a closed path, got {other:?}"),
    }
    // the closed flag gates the zero-live wait: no rejoin_wait stall
    assert!(t0.elapsed() < Duration::from_secs(5), "closed path waited for rejoin");
}

#[test]
fn ack_progress_timeout_unsticks_a_stalled_sender() {
    // A sender whose receiver never posts a recv (so the rendezvous ACK
    // never arrives) stands in for the control-stream divergence window:
    // the sender is parked in a blocking read nothing will ever satisfy.
    // Without the watchdog this hangs until the transport gives up —
    // forever, on the in-memory transport. With ack_timeout set, each
    // control stream is force-closed after its budget and the send fails
    // over to the retry path, ending in a bounded error instead.
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let _keep_peer_alive = r; // a dropped peer would fail fast by EOF instead
    let mut cfg = resilient_cfg(2);
    cfg.resilience.ack_timeout = Some(Duration::from_millis(150));
    let a = Path::from_pairs(l, cfg).unwrap();
    let t0 = Instant::now();
    let res = a.send(&[7u8; 64 * 1024]);
    assert!(res.is_err(), "nobody ever acked; the send must not report success");
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "send did not fail in bounded time: {:?}",
        t0.elapsed()
    );
    let st = a.status();
    assert!(st.ack_timeouts >= 1, "watchdog never fired: {st:?}");
}

#[test]
fn ack_timeout_does_not_fire_on_healthy_traffic() {
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let mut cfg = resilient_cfg(2);
    cfg.resilience.ack_timeout = Some(Duration::from_secs(30));
    let a = Path::from_pairs(l, cfg.clone()).unwrap();
    let b = Path::from_pairs(r, cfg).unwrap();
    let mut msg = vec![0u8; 200_000];
    Rng::new(55).fill_bytes(&mut msg);
    let m2 = msg.clone();
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; 200_000];
        for _ in 0..5 {
            b.recv(&mut buf).unwrap();
        }
        buf
    });
    for _ in 0..5 {
        a.send(&msg).unwrap();
    }
    assert_eq!(t.join().unwrap(), m2);
    let st = a.status();
    assert_eq!(st.ack_timeouts, 0, "watchdog misfired on healthy traffic: {st:?}");
    assert_eq!(st.live, 2, "{st:?}");
}

fn windowed_cfg(n: usize, window: usize) -> PathConfig {
    let mut cfg = resilient_cfg(n);
    cfg.resilience.window = window;
    cfg
}

#[test]
fn windowed_pipeline_roundtrips_in_order() {
    // A window of 8 lets every send below return after *posting*; the
    // receiver must still observe the messages complete and in order,
    // and a flush must leave nothing in flight.
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let cfg = windowed_cfg(2, 8);
    let a = Path::from_pairs(l, cfg.clone()).unwrap();
    let b = Path::from_pairs(r, cfg).unwrap();
    const N: u64 = 20;
    const LEN: usize = 100_000;
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; LEN];
        let mut expect = vec![0u8; LEN];
        for i in 0..N {
            b.recv(&mut buf).unwrap();
            Rng::new(500 + i).fill_bytes(&mut expect);
            assert_eq!(buf, expect, "message {i} corrupted or reordered");
        }
    });
    let mut msg = vec![0u8; LEN];
    for i in 0..N {
        Rng::new(500 + i).fill_bytes(&mut msg);
        a.send(&msg).unwrap();
    }
    a.flush().unwrap();
    t.join().unwrap();
    let st = a.status();
    assert_eq!(st.window_in_flight, 0, "flush left messages in flight: {st:?}");
    assert_eq!(st.ack_timeouts, 0, "{st:?}");
}

#[test]
fn windowed_selective_retry_survives_mid_window_stream_kill() {
    // Kill a (non-control) stream while a window's worth of messages is
    // in flight: only the affected messages are retried, over the
    // surviving streams, and every byte still arrives intact.
    let (l, r, kills) = mem_path_pairs_killable(4);
    let cfg = windowed_cfg(4, 4);
    let a = Path::from_pairs(l, cfg.clone()).unwrap();
    let b = Path::from_pairs(r, cfg).unwrap();
    const N: u64 = 12;
    const LEN: usize = 300_000;
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; LEN];
        let mut expect = vec![0u8; LEN];
        for i in 0..N {
            b.recv(&mut buf).unwrap();
            Rng::new(700 + i).fill_bytes(&mut expect);
            assert_eq!(buf, expect, "message {i} corrupted across the kill");
        }
        b.status()
    });
    let mut msg = vec![0u8; LEN];
    for i in 0..N {
        if i == 4 {
            kills[2].fire(); // mid-window, while earlier posts are unacked
        }
        Rng::new(700 + i).fill_bytes(&mut msg);
        a.send(&msg).unwrap();
    }
    a.flush().unwrap();
    let bs = t.join().unwrap();
    let st = a.status();
    assert_eq!(st.window_in_flight, 0, "{st:?}");
    assert!(st.live >= 3, "sender lost more than the killed stream: {st:?}");
    assert!(bs.live >= 3, "receiver lost more than the killed stream: {bs:?}");
}

#[test]
fn windowed_watchdog_fires_on_oldest_unacked_stall() {
    // With a window, sends *post* and return — a stalled receiver shows
    // up at the next drain. The watchdog must track the oldest unacked
    // message and fail the pipeline in bounded time; the poisoned
    // pipeline must then fail later sends instead of hanging.
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let _keep_peer_alive = r; // a dropped peer would fail fast by EOF instead
    let mut cfg = windowed_cfg(2, 2);
    cfg.resilience.ack_timeout = Some(Duration::from_millis(150));
    let a = Path::from_pairs(l, cfg).unwrap();
    for _ in 0..2 {
        // fills the window; nobody ever acks
        let _ = a.send(&[7u8; 64 * 1024]);
    }
    let t0 = Instant::now();
    assert!(a.flush().is_err(), "nobody ever acked; the drain must not report success");
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "drain did not fail in bounded time: {:?}",
        t0.elapsed()
    );
    let st = a.status();
    assert!(st.ack_timeouts >= 1, "watchdog never fired: {st:?}");
    assert!(a.send(&[1u8; 16]).is_err(), "poisoned pipeline accepted a new send");
}

#[test]
fn window_of_one_degenerates_to_rendezvous() {
    // window = 1 must behave exactly like the historic rendezvous mode:
    // every send blocks for its ACK, so nothing is ever left in flight.
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let cfg = windowed_cfg(2, 1);
    let a = Path::from_pairs(l, cfg.clone()).unwrap();
    let b = Path::from_pairs(r, cfg).unwrap();
    let mut msg = vec![0u8; 150_000];
    Rng::new(81).fill_bytes(&mut msg);
    let m2 = msg.clone();
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; 150_000];
        for _ in 0..3 {
            b.recv(&mut buf).unwrap();
        }
        buf
    });
    for _ in 0..3 {
        a.send(&msg).unwrap();
        assert_eq!(a.status().window_in_flight, 0, "rendezvous send left data in flight");
    }
    assert_eq!(t.join().unwrap(), m2);
    a.flush().unwrap(); // no-op on an empty window
}

#[test]
fn stash_high_water_bounds_reorder_buffer_and_completes() {
    // Regression for the unbounded reorder stash: with
    // `recv_stash_high_water` set, a windowed sender racing 4 streams
    // against a deliberately slow receiver must keep the receiver's
    // out-of-order stash under the byte bound at all times (frames that
    // don't fit are NACKed and retried after backoff) — and every
    // message must still arrive intact and in order.
    const HW: usize = 64 * 1024;
    const LEN: usize = 50_000; // fits the stash alone, two never do
    const N: u64 = 24;
    let (l, r, _kills) = mem_path_pairs_killable(4);
    let mut cfg = windowed_cfg(4, 8);
    cfg.resilience.recv_stash_high_water = Some(HW);
    let a = Path::from_pairs(l, cfg.clone()).unwrap();
    let b = Path::from_pairs(r, cfg).unwrap();
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; LEN];
        let mut expect = vec![0u8; LEN];
        let mut peak = 0usize;
        for i in 0..N {
            b.recv(&mut buf).unwrap();
            Rng::new(900 + i).fill_bytes(&mut expect);
            assert_eq!(buf, expect, "message {i} corrupted under the stash bound");
            peak = peak.max(b.status().reorder_stash_bytes);
            if i % 4 == 0 {
                // a slow consumer is what builds the stash up
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        (peak, b.status())
    });
    let mut msg = vec![0u8; LEN];
    for i in 0..N {
        Rng::new(900 + i).fill_bytes(&mut msg);
        a.send(&msg).unwrap();
    }
    a.flush().unwrap();
    let (peak, bs) = t.join().unwrap();
    assert!(peak <= HW, "reorder stash exceeded its high-water: {peak} > {HW}");
    assert_eq!(bs.reorder_stash_bytes, 0, "stash not drained: {bs:?}");
    assert_eq!(a.status().window_in_flight, 0, "{:?}", a.status());
}

#[test]
fn stash_high_water_smaller_than_one_message_never_deadlocks() {
    // The empty-stash-always-fits rule: a bound smaller than a single
    // message must degrade to at-most-one-stashed-message, not wedge
    // the pipeline (the sender would otherwise never get credit for any
    // message).
    const LEN: usize = 100_000;
    const N: u64 = 8;
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let mut cfg = windowed_cfg(2, 4);
    cfg.resilience.recv_stash_high_water = Some(16 * 1024); // < one message
    let a = Path::from_pairs(l, cfg.clone()).unwrap();
    let b = Path::from_pairs(r, cfg).unwrap();
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; LEN];
        let mut expect = vec![0u8; LEN];
        for i in 0..N {
            b.recv(&mut buf).unwrap();
            Rng::new(1100 + i).fill_bytes(&mut expect);
            assert_eq!(buf, expect, "message {i} corrupted under an undersized bound");
        }
    });
    let mut msg = vec![0u8; LEN];
    for i in 0..N {
        Rng::new(1100 + i).fill_bytes(&mut msg);
        a.send(&msg).unwrap();
    }
    a.flush().unwrap();
    t.join().unwrap();
}

#[test]
fn seed_window_from_bdp_widens_window_from_pacing_rate() {
    // With no adaptive samples, the seeding falls back to the aggregate
    // pacing rate; an (absurdly) fast configured rate makes BDP/chunk
    // exceed MAX_WINDOW for any positive measured RTT, so the clamp is
    // the deterministic expectation.
    use mpwide::mpwide::resilience::MAX_WINDOW;
    let (l, r, _kills) = mem_path_pairs_killable(2);
    let mut cfg = windowed_cfg(2, 1);
    cfg.pacing_rate = Some(1e16);
    let a = Path::from_pairs(l, cfg.clone()).unwrap();
    let b = Path::from_pairs(r, cfg).unwrap();
    let t = std::thread::spawn(move || {
        b.barrier().unwrap();
        b
    });
    let w = a.seed_window_from_bdp().unwrap();
    let b = t.join().unwrap();
    assert_eq!(w, MAX_WINDOW, "BDP seeding did not widen the window");
    // the widened pipeline still carries traffic
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; 50_000];
        for _ in 0..4 {
            b.recv(&mut buf).unwrap();
        }
    });
    for _ in 0..4 {
        a.send(&[5u8; 50_000]).unwrap();
    }
    a.flush().unwrap();
    t.join().unwrap();
}

#[test]
fn seed_window_from_bdp_rejects_non_resilient_paths() {
    let (l, _r, _kills) = mem_path_pairs_killable(2);
    let mut cfg = PathConfig::with_streams(2);
    cfg.autotune = false;
    let a = Path::from_pairs(l, cfg).unwrap();
    assert!(matches!(a.seed_window_from_bdp(), Err(MpwError::Config(_))));
}

#[test]
fn status_reports_preferred_vs_effective_striping() {
    let (l, _r, kills) = mem_path_pairs_killable(3);
    let a = Path::from_pairs(l, resilient_cfg(3)).unwrap();
    let st = a.status();
    assert_eq!((st.nstreams, st.live, st.active_streams), (3, 3, 3));
    assert!(st.resilient);
    kills[1].fire();
    a.inject_stream_failure(1).unwrap();
    let st = a.status();
    assert_eq!(st.live, 2);
    assert_eq!(st.dead, vec![1]);
    assert_eq!(st.active_streams, 2, "degraded clamp missing: {st:?}");
    assert_eq!(st.preferred_active, 3, "intent lost: {st:?}");
}
