//! Property-style codec tests for the wire encoders: the resilience
//! frame header + CTRL payload and the mux channel frame header.
//!
//! Every case is driven by the crate's seeded deterministic RNG (no new
//! dependencies, exactly reproducible failures): random-value
//! round-trips, exhaustive truncation, and random byte corruption. The
//! corruption properties assert the *safety contract* of a decoder
//! facing a hostile or damaged stream: it must never panic, and
//! anything it accepts must satisfy the documented invariants.

use mpwide::mpwide::mux::{
    decode_mux_hdr, encode_mux_hdr, MuxHdr, CH_CLOSE, CH_DATA, CH_FIN, CH_OPEN,
    CH_WINDOW_UPDATE, MAX_MUX_PAYLOAD, MUX_HDR_LEN,
};
use mpwide::mpwide::resilience::{
    decode_frame_hdr, encode_credit, encode_ctrl, encode_frame_hdr, parse_credit, parse_ctrl,
    Credit, FrameHdr, FRAME_HDR_LEN, KIND_ACK, KIND_CTRL, KIND_DATA, KIND_WINDOW_UPDATE,
    MAX_FRAME_PAYLOAD, WINDOW_UPDATE_LEN,
};
use mpwide::util::Rng;

/// Iteration count for the randomized properties. `MPW_FUZZ_ITERS`
/// overrides the default — the Miri CI job runs these tests with a much
/// smaller count (interpreted execution is ~100x slower).
fn iters() -> usize {
    std::env::var("MPW_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(2_000)
}

// ---------------------------------------------------------------------------
// Resilience frame header.
// ---------------------------------------------------------------------------

#[test]
fn resilience_frame_hdr_roundtrips_random_values() {
    let mut rng = Rng::new(0xF0A1);
    for _ in 0..iters() {
        let kind = [KIND_CTRL, KIND_DATA, KIND_ACK, KIND_WINDOW_UPDATE][rng.urange(0, 4)];
        let msg_seq = rng.next_u64();
        let attempt = rng.next_u64() as u32;
        let len = rng.range(0, MAX_FRAME_PAYLOAD as u64 + 1) as u32;
        let h = encode_frame_hdr(kind, msg_seq, attempt, len);
        let d = decode_frame_hdr(&h).expect("valid header must decode");
        assert_eq!(d, FrameHdr { kind, msg_seq, attempt, len });
    }
}

#[test]
fn resilience_frame_hdr_corruption_is_rejected_or_sane() {
    let mut rng = Rng::new(0xF0A2);
    for _ in 0..iters() {
        let mut h = encode_frame_hdr(
            [KIND_CTRL, KIND_DATA, KIND_ACK, KIND_WINDOW_UPDATE][rng.urange(0, 4)],
            rng.next_u64(),
            rng.next_u64() as u32,
            rng.range(0, MAX_FRAME_PAYLOAD as u64 + 1) as u32,
        );
        let flips = rng.urange(1, 4);
        for _ in 0..flips {
            let pos = rng.urange(0, FRAME_HDR_LEN);
            h[pos] ^= rng.range(1, 256) as u8;
        }
        // must never panic; anything accepted must honour the invariants
        if let Ok(d) = decode_frame_hdr(&h) {
            assert!(
                (KIND_CTRL..=KIND_WINDOW_UPDATE).contains(&d.kind),
                "kind {} escaped",
                d.kind
            );
            assert!(d.len as usize <= MAX_FRAME_PAYLOAD, "len {} escaped the bound", d.len);
        }
    }
}

#[test]
fn resilience_frame_hdr_unknown_kinds_rejected() {
    // The kind byte (offset 1) has exactly four assigned values; every
    // other value is reserved and must be rejected, not passed through —
    // a forward-compat frame kind would otherwise be silently
    // misinterpreted by an old receiver.
    let good = encode_frame_hdr(KIND_DATA, 7, 0, 16);
    for kind in 0..=u8::MAX {
        if (KIND_CTRL..=KIND_WINDOW_UPDATE).contains(&kind) {
            continue;
        }
        let mut h = good;
        h[1] = kind;
        assert!(decode_frame_hdr(&h).is_err(), "reserved frame kind {kind:#04x} must be rejected");
    }
}

// ---------------------------------------------------------------------------
// Resilience CTRL payload.
// ---------------------------------------------------------------------------

fn random_ctrl(rng: &mut Rng) -> (u64, Vec<u16>, Vec<u16>) {
    let total = rng.next_u64() >> 8;
    let k = rng.urange(1, 65);
    let streams: Vec<u16> = (0..k).map(|_| rng.range(0, 256) as u16).collect();
    let d = rng.urange(0, 9);
    let dead: Vec<u16> = (0..d).map(|_| rng.range(0, 256) as u16).collect();
    (total, streams, dead)
}

#[test]
fn ctrl_payload_roundtrips_random_values() {
    let mut rng = Rng::new(0xC7A1);
    for _ in 0..iters() {
        let (total, streams, dead) = random_ctrl(&mut rng);
        let p = encode_ctrl(total, &streams, &dead);
        let c = parse_ctrl(&p).expect("valid ctrl must parse");
        assert_eq!(c.total, total);
        assert_eq!(c.streams, streams);
        assert_eq!(c.dead, dead);
    }
}

#[test]
fn ctrl_payload_every_truncation_is_rejected() {
    let mut rng = Rng::new(0xC7A2);
    for _ in 0..(iters() / 10).max(1) {
        let (total, streams, dead) = random_ctrl(&mut rng);
        let p = encode_ctrl(total, &streams, &dead);
        for cut in 0..p.len() {
            assert!(
                parse_ctrl(&p[..cut]).is_err(),
                "truncated ctrl ({cut}/{} bytes, k={}, d={}) must not parse",
                p.len(),
                streams.len(),
                dead.len()
            );
        }
    }
}

#[test]
fn ctrl_payload_corruption_never_panics() {
    let mut rng = Rng::new(0xC7A3);
    for _ in 0..iters() {
        let (total, streams, dead) = random_ctrl(&mut rng);
        let mut p = encode_ctrl(total, &streams, &dead);
        let flips = rng.urange(1, 5);
        for _ in 0..flips {
            let pos = rng.urange(0, p.len());
            p[pos] ^= rng.range(1, 256) as u8;
        }
        // the decoder must stay total: reject or return a structurally
        // consistent message, never panic on hostile bytes
        if let Ok(c) = parse_ctrl(&p) {
            assert!(!c.streams.is_empty(), "parser accepted an empty stream list");
            // accepted lists must be exactly what the length accounting
            // implies — no trailing garbage can have been skipped
            assert_eq!(p.len(), 12 + 2 * c.streams.len() + 2 * c.dead.len());
        }
    }
}

// ---------------------------------------------------------------------------
// Mux channel frame header.
// ---------------------------------------------------------------------------

#[test]
fn mux_hdr_roundtrips_random_values() {
    let mut rng = Rng::new(0xA0B1);
    for _ in 0..iters() {
        let kind = [CH_DATA, CH_FIN][rng.urange(0, 2)];
        let channel = rng.next_u64() as u32;
        let msg_seq = rng.next_u64();
        let len = rng.range(0, MAX_MUX_PAYLOAD as u64 + 1) as u32;
        let h = encode_mux_hdr(kind, channel, msg_seq, len);
        let d = decode_mux_hdr(&h).expect("valid header must decode");
        assert_eq!(d, MuxHdr { kind, channel, msg_seq, len });
        // control kinds round-trip too, but only with empty payloads
        let h = encode_mux_hdr(CH_OPEN, channel, 0, 0);
        assert_eq!(decode_mux_hdr(&h).unwrap().kind, CH_OPEN);
    }
}

#[test]
fn mux_hdr_control_frames_with_payload_rejected() {
    for kind in [CH_OPEN, CH_CLOSE, CH_WINDOW_UPDATE] {
        let h = encode_mux_hdr(kind, 3, 0, 1);
        assert!(decode_mux_hdr(&h).is_err(), "control frame with payload must be rejected");
    }
}

#[test]
fn mux_hdr_unknown_kinds_rejected() {
    // Same contract as the resilience header: kinds outside
    // CH_DATA..=CH_WINDOW_UPDATE are reserved and must fail to decode
    // whatever the rest of the header says.
    let good = encode_mux_hdr(CH_DATA, 9, 3, 16);
    for kind in 0..=u8::MAX {
        if (CH_DATA..=CH_WINDOW_UPDATE).contains(&kind) {
            continue;
        }
        let mut h = good;
        h[1] = kind;
        assert!(decode_mux_hdr(&h).is_err(), "reserved mux kind {kind:#04x} must be rejected");
    }
}

#[test]
fn mux_hdr_corruption_is_rejected_or_sane() {
    let mut rng = Rng::new(0xA0B2);
    for _ in 0..iters() {
        let mut h = encode_mux_hdr(
            [CH_DATA, CH_FIN, CH_OPEN, CH_CLOSE, CH_WINDOW_UPDATE][rng.urange(0, 5)],
            rng.next_u64() as u32,
            rng.next_u64(),
            0,
        );
        let flips = rng.urange(1, 4);
        for _ in 0..flips {
            let pos = rng.urange(0, MUX_HDR_LEN);
            h[pos] ^= rng.range(1, 256) as u8;
        }
        if let Ok(d) = decode_mux_hdr(&h) {
            assert!((CH_DATA..=CH_WINDOW_UPDATE).contains(&d.kind), "kind {} escaped", d.kind);
            assert!(d.len as usize <= MAX_MUX_PAYLOAD, "len {} escaped the bound", d.len);
            if d.kind == CH_OPEN || d.kind == CH_CLOSE || d.kind == CH_WINDOW_UPDATE {
                assert_eq!(d.len, 0, "control frame with payload accepted");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Resilience WINDOW_UPDATE credit block.
// ---------------------------------------------------------------------------

#[test]
fn credit_block_roundtrips_random_values() {
    let mut rng = Rng::new(0xCBA1);
    for _ in 0..iters() {
        let c = Credit {
            advert_id: rng.next_u64(),
            seq_limit: rng.next_u64(),
            byte_credit: rng.next_u64(),
            budget_msgs: rng.next_u64() as u32,
        };
        let p = encode_credit(&c);
        assert_eq!(p.len(), WINDOW_UPDATE_LEN);
        let d = parse_credit(&p).expect("valid credit block must parse");
        assert_eq!(d, c);
    }
}

#[test]
fn credit_block_every_truncation_is_rejected() {
    let c = Credit { advert_id: 7, seq_limit: 99, byte_credit: 1 << 30, budget_msgs: 16 };
    let p = encode_credit(&c);
    for cut in 0..p.len() {
        assert!(parse_credit(&p[..cut]).is_err(), "truncated credit ({cut} bytes) must not parse");
    }
    // oversized payloads are equally malformed — the block is fixed-width
    let mut long = p.to_vec();
    long.push(0);
    assert!(parse_credit(&long).is_err(), "oversized credit block must not parse");
}

#[test]
fn credit_block_corruption_never_panics() {
    // Every field is a plain big-endian integer, so any fixed-width
    // 28-byte buffer parses to *some* credit; the property here is
    // totality (no panic) and width-strictness under corruption.
    let mut rng = Rng::new(0xCBA2);
    for _ in 0..iters() {
        let c = Credit {
            advert_id: rng.next_u64(),
            seq_limit: rng.next_u64(),
            byte_credit: rng.next_u64(),
            budget_msgs: rng.next_u64() as u32,
        };
        let mut p = encode_credit(&c);
        let flips = rng.urange(1, 5);
        for _ in 0..flips {
            let pos = rng.urange(0, p.len());
            p[pos] ^= rng.range(1, 256) as u8;
        }
        let _ = parse_credit(&p);
    }
}
