//! Integration: concurrent use of the library — the paper's
//! MPWTestConcurrent analog. Multiple paths, non-blocking exchanges in
//! flight simultaneously, DataGather running while a "simulation"
//! exchanges, and the facade under concurrent access.

use std::sync::Arc;

use mpwide::mpwide::nonblocking::{NbeHandle, NbeOp};
use mpwide::mpwide::{Path, PathConfig, PathListener};
use mpwide::util::Rng;

fn cfg(n: usize) -> PathConfig {
    let mut c = PathConfig::with_streams(n);
    c.autotune = false;
    c
}

fn pair(n: usize) -> (Arc<Path>, Arc<Path>) {
    let mut listener = PathListener::bind(0, cfg(n)).unwrap();
    let port = listener.port();
    let c = cfg(n);
    let t = std::thread::spawn(move || Path::connect("127.0.0.1", port, c).unwrap());
    let server = listener.accept_path().unwrap();
    (Arc::new(t.join().unwrap()), Arc::new(server))
}

#[test]
fn several_paths_transfer_concurrently() {
    let pairs: Vec<_> = (0..4).map(|_| pair(2)).collect();
    std::thread::scope(|s| {
        for (i, (client, server)) in pairs.iter().enumerate() {
            let msg = vec![i as u8; 500_000];
            let expect = msg.clone();
            let server = server.clone();
            let client = client.clone();
            s.spawn(move || {
                let t = std::thread::spawn(move || {
                    let mut buf = vec![0u8; 500_000];
                    server.recv(&mut buf).unwrap();
                    assert_eq!(buf, expect);
                });
                client.send(&msg).unwrap();
                t.join().unwrap();
            });
        }
    });
}

#[test]
fn multiple_nonblocking_exchanges_in_flight() {
    let (client, server) = pair(2);
    // echo server: three sequential dynamic exchanges
    let echo = std::thread::spawn(move || {
        for _ in 0..3 {
            let mut cache = Vec::new();
            let n = server.drecv_into(&mut cache).unwrap();
            server.dsend(&cache[..n]).unwrap();
        }
    });
    // client posts three exchanges back-to-back; the path's send/recv
    // gates keep the wire streams intact, but which handle picks up
    // which echo is scheduling-dependent — compare as a multiset
    let payloads: Vec<Vec<u8>> = (0..3).map(|i| vec![i as u8 + 1; 10_000 * (i + 1)]).collect();
    let handles: Vec<NbeHandle> = payloads
        .iter()
        .map(|p| NbeHandle::start(client.clone(), NbeOp::DSendRecv(p.clone())))
        .collect();
    let mut got: Vec<Vec<u8>> = handles.into_iter().map(|h| h.wait().unwrap().unwrap()).collect();
    let mut want = payloads.clone();
    got.sort();
    want.sort();
    assert_eq!(got, want);
    echo.join().unwrap();
}

#[test]
fn datagather_runs_while_simulation_exchanges() {
    // the paper's DataGather use case: sync concurrently with a running
    // distributed application
    let dir = std::env::temp_dir().join(format!("concurrent-dg-{}", std::process::id()));
    let src = dir.join("src");
    let dst = dir.join("dst");
    std::fs::create_dir_all(&src).unwrap();
    std::fs::write(src.join("state.dat"), vec![3u8; 200_000]).unwrap();

    let (sim_client, sim_server) = pair(2);
    let (dg_client, dg_server) = pair(1);

    std::thread::scope(|s| {
        // the "simulation": 20 sendrecv rounds
        s.spawn(move || {
            let mut buf = vec![0u8; 50_000];
            for _ in 0..20 {
                sim_server.send_recv(&[1u8; 50_000], &mut buf).unwrap();
            }
        });
        s.spawn(move || {
            let mut buf = vec![0u8; 50_000];
            for _ in 0..20 {
                sim_client.send_recv(&[2u8; 50_000], &mut buf).unwrap();
            }
        });
        // the gather, concurrently
        let dst2 = dst.clone();
        s.spawn(move || {
            mpwide::tools::datagather::serve_once(&dg_server, &dst2).unwrap();
        });
        let src2 = src.clone();
        s.spawn(move || {
            let stats = mpwide::tools::datagather::sync_once(&dg_client, &src2).unwrap();
            assert_eq!(stats.shipped, 1);
        });
    });
    assert_eq!(std::fs::read(dst.join("state.dat")).unwrap(), vec![3u8; 200_000]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn facade_paths_used_from_worker_threads() {
    use mpwide::mpwide::api;
    api::mpw_init();
    let mut listener = PathListener::bind(0, cfg(2)).unwrap();
    let port = listener.port();
    let echo = std::thread::spawn(move || {
        let p = listener.accept_path().unwrap();
        let mut buf = vec![0u8; 10_000];
        for _ in 0..4 {
            p.recv(&mut buf).unwrap();
            p.send(&buf).unwrap();
        }
    });
    let id = api::mpw_create_path_cfg("127.0.0.1", port, cfg(2)).unwrap();
    // four threads hammer the same facade path id (serialized internally)
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(move || {
                let mut msg = vec![0u8; 10_000];
                Rng::new(5).fill_bytes(&mut msg);
                api::mpw_send(id, &msg).unwrap();
                let mut back = vec![0u8; 10_000];
                api::mpw_recv(id, &mut back).unwrap();
            });
        }
    });
    echo.join().unwrap();
    api::mpw_finalize();
}

#[test]
fn barrier_storm_no_deadlock() {
    let (client, server) = pair(1);
    let t = std::thread::spawn(move || {
        for _ in 0..200 {
            server.barrier().unwrap();
        }
    });
    for _ in 0..200 {
        client.barrier().unwrap();
    }
    t.join().unwrap();
}
