//! Integration: real TCP paths over loopback — creation, transfer,
//! tuning knobs, barriers, autotuning and teardown (the paper's
//! MPWUnitTests analog).

use std::time::Duration;

use mpwide::mpwide::{Path, PathConfig, PathListener};
use mpwide::util::Rng;

fn cfg(n: usize, autotune: bool) -> PathConfig {
    let mut c = PathConfig::with_streams(n);
    c.autotune = autotune;
    c
}

fn pair(n: usize, autotune: bool) -> (Path, Path) {
    let mut listener = PathListener::bind(0, cfg(n, autotune)).unwrap();
    let port = listener.port();
    let c = cfg(n, autotune);
    let t = std::thread::spawn(move || Path::connect("127.0.0.1", port, c).unwrap());
    let server = listener.accept_path().unwrap();
    (t.join().unwrap(), server)
}

#[test]
fn large_transfer_many_streams() {
    let (client, server) = pair(16, false);
    let mut msg = vec![0u8; 8 << 20];
    Rng::new(1).fill_bytes(&mut msg);
    let expect = msg.clone();
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; 8 << 20];
        server.recv(&mut buf).unwrap();
        buf
    });
    client.send(&msg).unwrap();
    assert_eq!(t.join().unwrap(), expect);
}

#[test]
fn bidirectional_sendrecv_loopback() {
    let (client, server) = pair(4, false);
    let a = vec![1u8; 1 << 20];
    let b = vec![2u8; 1 << 20];
    let (a2, b2) = (a.clone(), b.clone());
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; 1 << 20];
        server.send_recv(&b2, &mut buf).unwrap();
        assert_eq!(buf, a2);
    });
    let mut buf = vec![0u8; 1 << 20];
    client.send_recv(&a, &mut buf).unwrap();
    assert_eq!(buf, b);
    t.join().unwrap();
}

#[test]
fn autotuned_path_creation_converges() {
    // both ends autotune (the paper's default); path must come up and
    // agree on a probed chunk size
    let (client, server) = pair(2, true);
    let client_chunk = client.config().chunk_size;
    let server_chunk = server.config().chunk_size;
    assert_eq!(client_chunk, server_chunk);
    assert!(mpwide::mpwide::autotune::CANDIDATE_CHUNKS.contains(&client_chunk));
    // and the tuned path still moves data correctly
    let msg = vec![9u8; 100_000];
    let m2 = msg.clone();
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; 100_000];
        server.recv(&mut buf).unwrap();
        buf
    });
    client.send(&msg).unwrap();
    assert_eq!(t.join().unwrap(), m2);
}

#[test]
fn set_window_applies_on_live_path() {
    let (client, server) = pair(2, false);
    let granted = client.set_window(256 * 1024).unwrap();
    assert!(granted.unwrap() >= 256 * 1024 / 2, "kernel granted {granted:?}");
    drop(server);
}

#[test]
fn pacing_limits_loopback_throughput() {
    let (client, server) = pair(1, false);
    client.set_pacing_rate(Some(4.0 * 1024.0 * 1024.0)).unwrap(); // 4 MB/s
    client.set_chunk_size(64 * 1024).unwrap();
    let msg = vec![0u8; 2 << 20]; // 2 MB at 4 MB/s ≈ 0.5 s minus burst
    let t = std::thread::spawn(move || {
        let mut buf = vec![0u8; 2 << 20];
        server.recv(&mut buf).unwrap();
    });
    let t0 = std::time::Instant::now();
    client.send(&msg).unwrap();
    let dt = t0.elapsed();
    t.join().unwrap();
    assert!(dt >= Duration::from_millis(300), "paced send took only {dt:?}");
}

#[test]
fn rtt_measurement_sane_on_loopback() {
    let (client, server) = pair(1, false);
    let t = std::thread::spawn(move || {
        for _ in 0..5 {
            server.barrier().unwrap();
        }
    });
    let mut rtts = Vec::new();
    for _ in 0..5 {
        rtts.push(client.measure_rtt().unwrap());
    }
    t.join().unwrap();
    assert!(rtts.iter().all(|r| *r < Duration::from_millis(100)), "{rtts:?}");
}

#[test]
fn peer_disconnect_surfaces_as_error() {
    let (client, server) = pair(1, false);
    drop(server);
    let mut buf = vec![0u8; 1024];
    // allow the FIN to land
    std::thread::sleep(Duration::from_millis(50));
    assert!(client.recv(&mut buf).is_err());
}

#[test]
fn connect_to_closed_port_times_out() {
    let mut c = cfg(1, false);
    c.connect_timeout = Duration::from_millis(300);
    let t0 = std::time::Instant::now();
    let r = Path::connect("127.0.0.1", 9, c); // discard port; closed
    assert!(r.is_err());
    assert!(t0.elapsed() < Duration::from_secs(10));
}

#[test]
fn many_sequential_paths_from_one_listener() {
    let mut listener = PathListener::bind(0, cfg(1, false)).unwrap();
    let port = listener.port();
    for i in 0..5 {
        let c = cfg(1, false);
        let t = std::thread::spawn(move || {
            let p = Path::connect("127.0.0.1", port, c).unwrap();
            p.send(&[i as u8]).unwrap();
        });
        let p = listener.accept_path().unwrap();
        let mut b = [0u8; 1];
        p.recv(&mut b).unwrap();
        assert_eq!(b[0], i as u8);
        t.join().unwrap();
    }
}
