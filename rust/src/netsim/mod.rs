//! WAN substrate simulator (DESIGN.md §2).
//!
//! The paper's evaluation ran on real wide-area routes (London–Poznań,
//! Poznań–Gdańsk, Poznań–Amsterdam, UCL–Yale, and 10 Gbit/s lightpaths
//! between Espoo/Edinburgh/Amsterdam and Amsterdam–Tokyo). Those links are
//! not available here, so this module provides a **flow-level,
//! round-based discrete-event TCP model**: per-flow congestion windows
//! (slow start + AIMD), receiver-window caps, per-direction stochastic
//! loss, background load, and proportional sharing of a bottleneck.
//!
//! The point of the model is that the phenomena MPWide exploits *emerge
//! from the mechanisms* rather than being scripted:
//!
//! * a single TCP flow on a long fat network is capped by
//!   `min(rwnd/RTT, ~MSS/(RTT·√p))` (the Mathis law falls out of AIMD),
//! * N parallel flows recover from loss independently and aggregate,
//! * loss asymmetry between directions produces the asymmetric
//!   single-stream numbers in the paper's Table 1,
//! * and MPWide's own benchmark exchanges data in *both directions at
//!   once* (`MPW_SendRecv`), which is why its Table 1 rows are symmetric.
//!
//! Only the per-route parameters (RTT, capacity, loss, background load)
//! are calibrated; see [`profiles`] and EXPERIMENTS.md for the
//! paper-vs-measured comparison.

pub mod faults;
pub mod link;
pub mod network;
pub mod simpath;
pub mod tcp_model;

pub use faults::{FaultEvent, FaultSchedule, ReaderSchedule};
pub use link::{profiles, Direction, LinkProfile};
pub use network::{simulate_duplex, simulate_oneway, OneWayResult};
pub use simpath::{AdaptiveSimPath, DriftingLink, LinkPhase, SimPath, SimTransferResult};
pub use tcp_model::{TcpFlow, INIT_CWND, MSS};
