//! `SimPath`: MPWide's path semantics over the simulated WAN.
//!
//! Reuses the *production* striping logic ([`crate::mpwide::stripe`]) and
//! [`crate::mpwide::PathConfig`], and mirrors the autotuner's window rule
//! (BDP split across streams, clamped — the same arithmetic as
//! `mpwide::autotune::tune_master`), so the simulated experiments exercise
//! the same decisions as the real socket path. Only the byte movement is
//! replaced by the flow-level TCP model.

use super::link::{Direction, LinkProfile};
use super::network::{simulate_duplex, simulate_oneway, OneWayResult};
use super::tcp_model::TcpFlow;
use crate::mpwide::{stripe, PathConfig};
use crate::util::Rng;

/// Default receiver window when the user neither tunes nor autotunes:
/// modern kernels autoscale a single bulk flow up to several MB; sites
/// "not optimally configured by administrators" (the paper's premise)
/// commonly cap near 4 MB.
pub const OS_AUTOSCALE_RWND: f64 = 4.0 * 1024.0 * 1024.0;

/// Site hard cap on explicitly-requested windows (`MPW_setWin` is granted
/// only "within the constraints of the site configuration").
pub const SITE_MAX_RWND: f64 = 8.0 * 1024.0 * 1024.0;

/// Per-low-level-call CPU cost, seconds (syscall + copy dispatch). Makes
/// the chunk-size knob meaningful in simulation: tiny chunks → many calls.
pub const PER_CALL_OVERHEAD: f64 = 3.0e-6;

/// Outcome of a simulated MPWide exchange.
#[derive(Debug, Clone)]
pub struct SimTransferResult {
    /// A→B direction result.
    pub ab: OneWayResult,
    /// B→A direction result (zero-byte for one-way sends).
    pub ba: OneWayResult,
    /// Per-stream receiver window used (after autotune/setWin rules).
    pub rwnd: f64,
    /// CPU time charged for chunked low-level calls, seconds.
    pub call_overhead: f64,
}

impl SimTransferResult {
    /// Duplex throughput of the A→B direction, bytes/second, including
    /// the per-call CPU overhead.
    pub fn throughput_ab(&self) -> f64 {
        let t = self.ab.seconds + self.call_overhead;
        if t > 0.0 {
            self.ab.bytes / t
        } else {
            0.0
        }
    }

    /// Duplex throughput of the B→A direction.
    pub fn throughput_ba(&self) -> f64 {
        let t = self.ba.seconds + self.call_overhead;
        if t > 0.0 {
            self.ba.bytes / t
        } else {
            0.0
        }
    }
}

/// A simulated MPWide path over a link profile.
#[derive(Debug, Clone)]
pub struct SimPath {
    link: LinkProfile,
    cfg: PathConfig,
    rwnd: f64,
}

impl SimPath {
    /// Create a simulated path. Applies the same window policy as the
    /// real path: explicit `tcp_window` is clamped to the site maximum;
    /// autotune sets BDP/streams (clamped to [64 KB, 16 MB]); otherwise
    /// the OS autoscaling default applies.
    pub fn new(link: LinkProfile, cfg: PathConfig) -> SimPath {
        let rwnd = match (cfg.tcp_window, cfg.autotune) {
            (Some(w), _) => (w as f64).min(SITE_MAX_RWND),
            (None, true) => {
                // mirror mpwide::autotune::tune_master's BDP estimate
                (link.bdp() / cfg.nstreams as f64).clamp(64.0 * 1024.0, 16.0 * 1024.0 * 1024.0)
            }
            (None, false) => OS_AUTOSCALE_RWND,
        };
        SimPath { link, cfg, rwnd }
    }

    /// The link this path runs over.
    pub fn link(&self) -> &LinkProfile {
        &self.link
    }

    /// Effective per-stream receiver window.
    pub fn rwnd(&self) -> f64 {
        self.rwnd
    }

    fn flows(&self, bytes: u64) -> Vec<TcpFlow> {
        // exact production striping: segment lengths per stream
        stripe::segments(bytes as usize, self.cfg.nstreams)
            .into_iter()
            .map(|seg| TcpFlow::new(seg.len() as f64, self.rwnd, self.cfg.pacing_rate))
            .collect()
    }

    fn overhead(&self, bytes: u64) -> f64 {
        stripe::call_count(bytes as usize, self.cfg.nstreams, self.cfg.chunk_size) as f64
            * PER_CALL_OVERHEAD
    }

    /// Simulate `MPW_Send` of `bytes` in one direction.
    pub fn send(&self, bytes: u64, dir: Direction, seed: u64) -> SimTransferResult {
        let mut rng = Rng::new(seed);
        let mut flows = self.flows(bytes);
        let res = simulate_oneway(&mut flows, &self.link, dir, &mut rng, false);
        let empty = OneWayResult {
            seconds: 0.0,
            bytes: 0.0,
            throughput: 0.0,
            losses: 0,
            rounds: 0,
            timeline: Vec::new(),
        };
        let (ab, ba) = match dir {
            Direction::AtoB => (res, empty),
            Direction::BtoA => (empty, res),
        };
        SimTransferResult { ab, ba, rwnd: self.rwnd, call_overhead: self.overhead(bytes) }
    }

    /// Simulate `MPW_SendRecv` of `bytes` in **both directions at once** —
    /// how the paper's MPWide throughput tests ran (hence the symmetric
    /// Table 1 rows).
    pub fn send_recv(&self, bytes: u64, seed: u64) -> SimTransferResult {
        let mut rng = Rng::new(seed);
        let mut ab = self.flows(bytes);
        let mut ba = self.flows(bytes);
        let (ra, rb) = simulate_duplex(&mut ab, &mut ba, &self.link, &mut rng);
        SimTransferResult {
            ab: ra,
            ba: rb,
            rwnd: self.rwnd,
            call_overhead: self.overhead(bytes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::profiles;

    const MB: u64 = 1024 * 1024;

    fn wan_cfg(n: usize) -> PathConfig {
        PathConfig { nstreams: n, ..Default::default() }
    }

    #[test]
    fn autotune_window_mirrors_tuner_rule() {
        let link = profiles::amsterdam_tokyo(); // BDP = 337.5 MB
        let p = SimPath::new(link.clone(), wan_cfg(32));
        let expect = (link.bdp() / 32.0).clamp(64.0 * 1024.0, 16.0 * 1024.0 * 1024.0);
        assert_eq!(p.rwnd(), expect);
    }

    #[test]
    fn explicit_window_clamped_to_site_max() {
        let mut cfg = wan_cfg(4);
        cfg.tcp_window = Some(64 << 20);
        let p = SimPath::new(profiles::london_poznan(), cfg);
        assert_eq!(p.rwnd(), SITE_MAX_RWND);
    }

    #[test]
    fn no_autotune_uses_os_default() {
        let mut cfg = wan_cfg(4);
        cfg.autotune = false;
        let p = SimPath::new(profiles::london_poznan(), cfg);
        assert_eq!(p.rwnd(), OS_AUTOSCALE_RWND);
    }

    #[test]
    fn send_moves_all_bytes() {
        let p = SimPath::new(profiles::london_poznan(), wan_cfg(16));
        let r = p.send(64 * MB, Direction::AtoB, 1);
        assert!((r.ab.bytes - (64 * MB) as f64).abs() < 1.0);
        assert_eq!(r.ba.bytes, 0.0);
    }

    #[test]
    fn sendrecv_is_roughly_symmetric() {
        let p = SimPath::new(profiles::poznan_gdansk(), wan_cfg(32));
        let r = p.send_recv(64 * MB, 2);
        let ratio = r.throughput_ab() / r.throughput_ba();
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn more_streams_help_on_wan() {
        let link = profiles::london_poznan();
        let one = SimPath::new(link.clone(), wan_cfg(1)).send(64 * MB, Direction::AtoB, 3);
        let many = SimPath::new(link, wan_cfg(32)).send(64 * MB, Direction::AtoB, 3);
        assert!(
            many.throughput_ab() > 1.5 * one.throughput_ab(),
            "32 streams {:.1} vs 1 stream {:.1} MB/s",
            many.throughput_ab() / MB as f64,
            one.throughput_ab() / MB as f64
        );
    }

    #[test]
    fn tiny_chunks_cost_cpu() {
        let link = profiles::local_lan();
        let mut cfg = wan_cfg(4);
        cfg.chunk_size = 1024; // pathological
        let small = SimPath::new(link.clone(), cfg).send(64 * MB, Direction::AtoB, 4);
        let big = SimPath::new(link, wan_cfg(4)).send(64 * MB, Direction::AtoB, 4);
        assert!(small.call_overhead > 10.0 * big.call_overhead);
        assert!(small.throughput_ab() < big.throughput_ab());
    }

    #[test]
    fn pacing_caps_per_stream_rate() {
        let mut link = profiles::cosmogrid_lightpath();
        link.loss_ab = 0.0;
        link.bg_ab = 0.0;
        let mut cfg = wan_cfg(4);
        cfg.pacing_rate = Some(2.0 * MB as f64); // 2 MB/s per stream
        let p = SimPath::new(link, cfg);
        let r = p.send(32 * MB, Direction::AtoB, 5);
        // 4 streams × 2 MB/s = 8 MB/s aggregate ceiling
        assert!(r.throughput_ab() <= 8.5 * MB as f64, "{}", r.throughput_ab());
    }
}
