//! `SimPath`: MPWide's path semantics over the simulated WAN.
//!
//! Reuses the *production* striping logic ([`crate::mpwide::stripe`]) and
//! [`crate::mpwide::PathConfig`], and mirrors the autotuner's window rule
//! (BDP split across streams, clamped — the same arithmetic as
//! `mpwide::autotune::tune_master`), so the simulated experiments exercise
//! the same decisions as the real socket path. Only the byte movement is
//! replaced by the flow-level TCP model.
//!
//! For the runtime-adaptation experiments this module also provides
//! **time-varying links** ([`DriftingLink`]: piecewise link profiles —
//! congestion ramps, loss bursts) and [`AdaptiveSimPath`], a simulated
//! path that consults the *production*
//! [`TuningState`](crate::mpwide::adapt::TuningState) /
//! [`AdaptiveController`](crate::mpwide::adapt::AdaptiveController) per
//! exchange — so the controller logic tested here is byte-for-byte the
//! one the socket path runs.

use std::sync::Arc;

use super::faults::{FaultEvent, FaultSchedule};
use super::link::{Direction, LinkProfile};
use super::network::{simulate_duplex, simulate_oneway, OneWayResult};
use super::tcp_model::TcpFlow;
use crate::mpwide::adapt::{AdaptiveController, TuneMode, TuningState};
use crate::mpwide::{stripe, MpwError, PathConfig};
use crate::util::Rng;

/// Default receiver window when the user neither tunes nor autotunes:
/// modern kernels autoscale a single bulk flow up to several MB; sites
/// "not optimally configured by administrators" (the paper's premise)
/// commonly cap near 4 MB.
pub const OS_AUTOSCALE_RWND: f64 = 4.0 * 1024.0 * 1024.0;

/// Site hard cap on explicitly-requested windows (`MPW_setWin` is granted
/// only "within the constraints of the site configuration").
pub const SITE_MAX_RWND: f64 = 8.0 * 1024.0 * 1024.0;

/// Per-low-level-call CPU cost, seconds (syscall + copy dispatch). Makes
/// the chunk-size knob meaningful in simulation: tiny chunks → many calls.
pub const PER_CALL_OVERHEAD: f64 = 3.0e-6;

/// Outcome of a simulated MPWide exchange.
#[derive(Debug, Clone)]
pub struct SimTransferResult {
    /// A→B direction result.
    pub ab: OneWayResult,
    /// B→A direction result (zero-byte for one-way sends).
    pub ba: OneWayResult,
    /// Per-stream receiver window used (after autotune/setWin rules).
    pub rwnd: f64,
    /// CPU time charged for chunked low-level calls, seconds.
    pub call_overhead: f64,
}

impl SimTransferResult {
    /// Duplex throughput of the A→B direction, bytes/second, including
    /// the per-call CPU overhead.
    pub fn throughput_ab(&self) -> f64 {
        let t = self.ab.seconds + self.call_overhead;
        if t > 0.0 {
            self.ab.bytes / t
        } else {
            0.0
        }
    }

    /// Duplex throughput of the B→A direction.
    pub fn throughput_ba(&self) -> f64 {
        let t = self.ba.seconds + self.call_overhead;
        if t > 0.0 {
            self.ba.bytes / t
        } else {
            0.0
        }
    }
}

/// A simulated MPWide path over a link profile.
#[derive(Debug, Clone)]
pub struct SimPath {
    link: LinkProfile,
    cfg: PathConfig,
    rwnd: f64,
}

impl SimPath {
    /// Create a simulated path. Applies the same window policy as the
    /// real path: explicit `tcp_window` is clamped to the site maximum;
    /// autotune sets BDP/streams (clamped to [64 KB, 16 MB]); otherwise
    /// the OS autoscaling default applies.
    pub fn new(link: LinkProfile, cfg: PathConfig) -> SimPath {
        let rwnd = match (cfg.tcp_window, cfg.autotune) {
            (Some(w), _) => (w as f64).min(SITE_MAX_RWND),
            (None, true) => {
                // mirror mpwide::autotune::tune_master's BDP estimate
                (link.bdp() / cfg.nstreams as f64).clamp(64.0 * 1024.0, 16.0 * 1024.0 * 1024.0)
            }
            (None, false) => OS_AUTOSCALE_RWND,
        };
        SimPath { link, cfg, rwnd }
    }

    /// The link this path runs over.
    pub fn link(&self) -> &LinkProfile {
        &self.link
    }

    /// Effective per-stream receiver window.
    pub fn rwnd(&self) -> f64 {
        self.rwnd
    }

    fn flows(&self, bytes: u64) -> Vec<TcpFlow> {
        // exact production striping: segment lengths per stream
        stripe::segments(bytes as usize, self.cfg.nstreams)
            .into_iter()
            .map(|seg| TcpFlow::new(seg.len() as f64, self.rwnd, self.cfg.pacing_rate))
            .collect()
    }

    fn overhead(&self, bytes: u64) -> f64 {
        stripe::call_count(bytes as usize, self.cfg.nstreams, self.cfg.chunk_size) as f64
            * PER_CALL_OVERHEAD
    }

    /// Simulate `MPW_Send` of `bytes` in one direction.
    pub fn send(&self, bytes: u64, dir: Direction, seed: u64) -> SimTransferResult {
        let mut rng = Rng::new(seed);
        let mut flows = self.flows(bytes);
        let res = simulate_oneway(&mut flows, &self.link, dir, &mut rng, false);
        let empty = OneWayResult {
            seconds: 0.0,
            bytes: 0.0,
            throughput: 0.0,
            losses: 0,
            rounds: 0,
            timeline: Vec::new(),
        };
        let (ab, ba) = match dir {
            Direction::AtoB => (res, empty),
            Direction::BtoA => (empty, res),
        };
        SimTransferResult { ab, ba, rwnd: self.rwnd, call_overhead: self.overhead(bytes) }
    }

    /// Simulate `MPW_SendRecv` of `bytes` in **both directions at once** —
    /// how the paper's MPWide throughput tests ran (hence the symmetric
    /// Table 1 rows).
    pub fn send_recv(&self, bytes: u64, seed: u64) -> SimTransferResult {
        let mut rng = Rng::new(seed);
        let mut ab = self.flows(bytes);
        let mut ba = self.flows(bytes);
        let (ra, rb) = simulate_duplex(&mut ab, &mut ba, &self.link, &mut rng);
        SimTransferResult {
            ab: ra,
            ba: rb,
            rwnd: self.rwnd,
            call_overhead: self.overhead(bytes),
        }
    }
}

// ---------------------------------------------------------------------------
// Time-varying links + the adaptive simulated path.
// ---------------------------------------------------------------------------

/// One segment of a time-varying WAN: the route behaves as `link` from
/// simulated time `start` (seconds) onward, until the next phase begins.
#[derive(Debug, Clone)]
pub struct LinkPhase {
    /// Simulated time at which this phase takes effect.
    pub start: f64,
    /// The link profile in force during the phase.
    pub link: LinkProfile,
}

/// A piecewise-constant time-varying link: the deterministic stand-in
/// for WAN drift (background load rising over hours, loss bursts) that
/// the online tuner exists to survive.
#[derive(Debug, Clone)]
pub struct DriftingLink {
    phases: Vec<LinkPhase>,
}

impl DriftingLink {
    /// Build from explicit phases. The earliest phase must start at or
    /// before t = 0 so every query time is covered.
    pub fn new(mut phases: Vec<LinkPhase>) -> DriftingLink {
        assert!(!phases.is_empty(), "a drifting link needs at least one phase");
        phases.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        assert!(phases[0].start <= 0.0, "first phase must start at t <= 0");
        DriftingLink { phases }
    }

    /// A link that never changes (useful as a control).
    pub fn steady(link: LinkProfile) -> DriftingLink {
        DriftingLink::new(vec![LinkPhase { start: 0.0, link }])
    }

    /// The profile in force at simulated time `t`.
    pub fn at(&self, t: f64) -> &LinkProfile {
        self.phases
            .iter()
            .rev()
            .find(|p| p.start <= t)
            .map(|p| &p.link)
            .unwrap_or(&self.phases[0].link)
    }

    /// Canned scenario: at `onset` the route's background load jumps to
    /// `bg` competing elastic flows per direction (a congestion ramp —
    /// the share-starvation case more parallel streams recover from).
    pub fn congestion_ramp(base: LinkProfile, onset: f64, bg: f64) -> DriftingLink {
        let mut hot = base.clone();
        hot.bg_ab = bg;
        hot.bg_ba = bg;
        DriftingLink::new(vec![
            LinkPhase { start: 0.0, link: base },
            LinkPhase { start: onset, link: hot },
        ])
    }

    /// Canned scenario: residual loss jumps to `loss` per direction
    /// during `[from, until)` and recovers afterwards.
    pub fn loss_burst(base: LinkProfile, from: f64, until: f64, loss: f64) -> DriftingLink {
        assert!(from < until, "loss burst must have positive duration");
        let mut lossy = base.clone();
        lossy.loss_ab = loss;
        lossy.loss_ba = loss;
        DriftingLink::new(vec![
            LinkPhase { start: 0.0, link: base.clone() },
            LinkPhase { start: from, link: lossy },
            LinkPhase { start: until, link: base },
        ])
    }
}

/// A simulated MPWide path over a [`DriftingLink`], with the production
/// runtime-tuning stack in the loop: each `send_recv` consults the
/// shared [`TuningState`] for the active stream count / chunk / pacing,
/// advances a simulated clock by the transfer's wall time, and (in
/// adaptive mode) feeds the observed goodput to the
/// [`AdaptiveController`], applying its decisions exactly like
/// `Path::send` does on real sockets.
///
/// With a [`FaultSchedule`] attached ([`AdaptiveSimPath::with_faults`])
/// the path also mirrors the resilience layer: a `Down` event that
/// lands mid-transfer on a stream in use aborts the attempt (the time
/// already spent is charged), the stream is isolated, striping clamps
/// to the live count, and the message retries over the survivors. `Up`
/// events model completed rejoins and restore the preferred striping
/// width.
#[derive(Debug)]
pub struct AdaptiveSimPath {
    schedule: DriftingLink,
    cfg: PathConfig,
    tuning: Arc<TuningState>,
    controller: AdaptiveController,
    rwnd: f64,
    clock: f64,
    faults: FaultSchedule,
    alive: Vec<bool>,
    /// Index of the next unapplied fault event.
    applied: usize,
    retries: u64,
    rejoins: u64,
}

impl AdaptiveSimPath {
    /// Create over a schedule. The TCP window is fixed at creation from
    /// the **phase-0** link (exactly the real path's behaviour: windows
    /// are autotuned once, against the conditions seen at creation).
    pub fn new(schedule: DriftingLink, cfg: PathConfig) -> AdaptiveSimPath {
        AdaptiveSimPath::with_faults(schedule, cfg, FaultSchedule::none())
    }

    /// Create with stream-fault injection.
    pub fn with_faults(
        schedule: DriftingLink,
        cfg: PathConfig,
        faults: FaultSchedule,
    ) -> AdaptiveSimPath {
        let rwnd = SimPath::new(schedule.at(0.0).clone(), cfg.clone()).rwnd();
        let tuning = Arc::new(TuningState::from_config(&cfg));
        let controller = AdaptiveController::new(cfg.adapt.clone(), cfg.nstreams);
        let alive = vec![true; cfg.nstreams];
        AdaptiveSimPath {
            schedule,
            cfg,
            tuning,
            controller,
            rwnd,
            clock: 0.0,
            faults,
            alive,
            applied: 0,
            retries: 0,
            rejoins: 0,
        }
    }

    /// The live tuning knobs (set the initial active count here to model
    /// a creation-time-tuned path).
    pub fn tuning(&self) -> &TuningState {
        &self.tuning
    }

    /// Simulated seconds elapsed so far.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Transfers aborted by a mid-flight stream death (and retried).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Streams re-absorbed after an `Up` event.
    pub fn rejoins(&self) -> u64 {
        self.rejoins
    }

    /// Streams currently able to carry traffic.
    pub fn live_streams(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Advance the clock without traffic (compute phases between
    /// exchanges).
    pub fn advance(&mut self, seconds: f64) {
        self.clock += seconds.max(0.0);
    }

    /// Apply every fault event at or before the current clock, mirroring
    /// `Path::mark_stream_dead` / `Path::reinstall_stream`.
    fn apply_faults(&mut self) {
        while self.applied < self.faults.events().len()
            && self.faults.events()[self.applied].time() <= self.clock
        {
            let ev = self.faults.events()[self.applied];
            self.applied += 1;
            let s = ev.stream();
            if s >= self.alive.len() {
                continue;
            }
            match ev {
                FaultEvent::Down { .. } => {
                    if self.alive[s] {
                        self.alive[s] = false;
                        self.on_health_change();
                    }
                }
                FaultEvent::Up { .. } => {
                    if !self.alive[s] {
                        self.alive[s] = true;
                        self.rejoins += 1;
                        self.on_health_change();
                    }
                }
            }
        }
    }

    /// Degraded-mode striping: clamp the effective active count to the
    /// live count and cap the controller's hill climb, exactly like the
    /// socket path does.
    fn on_health_change(&mut self) {
        let live = self.live_streams().max(1);
        self.tuning.apply_live_limit(live);
        self.controller.set_ceiling(live);
    }

    /// Simulate one full-duplex `MPW_SendRecv` of `bytes` per direction
    /// under the link profile in force *now*, then let the controller
    /// react to the observed goodput. Panics if every stream is dead
    /// with no recovery scheduled; use [`AdaptiveSimPath::try_send_recv`]
    /// for fault schedules that may never recover.
    pub fn send_recv(&mut self, bytes: u64, seed: u64) -> SimTransferResult {
        self.try_send_recv(bytes, seed)
            .expect("all simulated streams dead with no recovery scheduled")
    }

    /// [`AdaptiveSimPath::send_recv`] with explicit failure: returns
    /// `AllStreamsDead` when the whole path is down and the schedule has
    /// no later `Up` event to wait for.
    pub fn try_send_recv(
        &mut self,
        bytes: u64,
        seed: u64,
    ) -> crate::mpwide::Result<SimTransferResult> {
        let mut seed = seed;
        // Simulated time lost to aborted attempts and zero-live waits;
        // charged against this exchange's goodput observation.
        let mut waste = 0.0f64;
        loop {
            self.apply_faults();
            let live: Vec<usize> =
                (0..self.cfg.nstreams).filter(|&i| self.alive[i]).collect();
            if live.is_empty() {
                match self.faults.next_up_after(self.clock) {
                    Some(up) => {
                        // a full-path flap: the resilient send blocks in
                        // wait_for_any_live until the first rejoin
                        waste += up.time() - self.clock;
                        self.clock = up.time();
                        continue;
                    }
                    None => return Err(MpwError::AllStreamsDead),
                }
            }
            let active =
                self.tuning.active_streams().clamp(1, self.cfg.nstreams).min(live.len());
            let used: Vec<usize> = live[..active].to_vec();
            let chunk = self.tuning.chunk();
            let pacing = self.tuning.pacing();
            let link = self.schedule.at(self.clock).clone();
            let rwnd = self.rwnd;
            let mk_flows = || -> Vec<TcpFlow> {
                stripe::segments(bytes as usize, active)
                    .into_iter()
                    .map(|seg| TcpFlow::new(seg.len() as f64, rwnd, pacing))
                    .collect()
            };
            let mut ab = mk_flows();
            let mut ba = mk_flows();
            let mut rng = Rng::new(seed);
            // decorrelate retry attempts without wall-clock entropy
            seed = seed.wrapping_mul(0x5851_F42D_4C95_7F2D).wrapping_add(0x9E37_79B9);
            let (ra, rb) = simulate_duplex(&mut ab, &mut ba, &link, &mut rng);
            let call_overhead =
                stripe::call_count(bytes as usize, active, chunk) as f64 * PER_CALL_OVERHEAD;
            let d = ra.seconds.max(rb.seconds) + call_overhead;
            if let Some(ev) = self.faults.first_down_in(self.clock, self.clock + d, &used) {
                // a stream in use died mid-transfer: the attempt aborts at
                // the event and the message retries over the survivors
                waste += ev.time() - self.clock;
                self.clock = ev.time();
                self.retries += 1;
                continue;
            }
            let res = SimTransferResult { ab: ra, ba: rb, rwnd: self.rwnd, call_overhead };
            self.clock += d;
            if self.tuning.mode() == TuneMode::Adaptive {
                let snapshot = self.tuning.snapshot();
                let seconds = res.ab.seconds + call_overhead + waste;
                let decision = self.controller.observe(bytes as usize, seconds, &snapshot);
                self.tuning.apply(&decision);
            }
            return Ok(res);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::profiles;

    const MB: u64 = 1024 * 1024;

    fn wan_cfg(n: usize) -> PathConfig {
        PathConfig { nstreams: n, ..Default::default() }
    }

    #[test]
    fn autotune_window_mirrors_tuner_rule() {
        let link = profiles::amsterdam_tokyo(); // BDP = 337.5 MB
        let p = SimPath::new(link.clone(), wan_cfg(32));
        let expect = (link.bdp() / 32.0).clamp(64.0 * 1024.0, 16.0 * 1024.0 * 1024.0);
        assert_eq!(p.rwnd(), expect);
    }

    #[test]
    fn explicit_window_clamped_to_site_max() {
        let mut cfg = wan_cfg(4);
        cfg.tcp_window = Some(64 << 20);
        let p = SimPath::new(profiles::london_poznan(), cfg);
        assert_eq!(p.rwnd(), SITE_MAX_RWND);
    }

    #[test]
    fn no_autotune_uses_os_default() {
        let mut cfg = wan_cfg(4);
        cfg.autotune = false;
        let p = SimPath::new(profiles::london_poznan(), cfg);
        assert_eq!(p.rwnd(), OS_AUTOSCALE_RWND);
    }

    #[test]
    fn send_moves_all_bytes() {
        let p = SimPath::new(profiles::london_poznan(), wan_cfg(16));
        let r = p.send(64 * MB, Direction::AtoB, 1);
        assert!((r.ab.bytes - (64 * MB) as f64).abs() < 1.0);
        assert_eq!(r.ba.bytes, 0.0);
    }

    #[test]
    fn sendrecv_is_roughly_symmetric() {
        let p = SimPath::new(profiles::poznan_gdansk(), wan_cfg(32));
        let r = p.send_recv(64 * MB, 2);
        let ratio = r.throughput_ab() / r.throughput_ba();
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn more_streams_help_on_wan() {
        let link = profiles::london_poznan();
        let one = SimPath::new(link.clone(), wan_cfg(1)).send(64 * MB, Direction::AtoB, 3);
        let many = SimPath::new(link, wan_cfg(32)).send(64 * MB, Direction::AtoB, 3);
        assert!(
            many.throughput_ab() > 1.5 * one.throughput_ab(),
            "32 streams {:.1} vs 1 stream {:.1} MB/s",
            many.throughput_ab() / MB as f64,
            one.throughput_ab() / MB as f64
        );
    }

    #[test]
    fn tiny_chunks_cost_cpu() {
        let link = profiles::local_lan();
        let mut cfg = wan_cfg(4);
        cfg.chunk_size = 1024; // pathological
        let small = SimPath::new(link.clone(), cfg).send(64 * MB, Direction::AtoB, 4);
        let big = SimPath::new(link, wan_cfg(4)).send(64 * MB, Direction::AtoB, 4);
        assert!(small.call_overhead > 10.0 * big.call_overhead);
        assert!(small.throughput_ab() < big.throughput_ab());
    }

    #[test]
    fn drifting_link_selects_phase_by_time() {
        let sched = DriftingLink::congestion_ramp(profiles::cosmogrid_lightpath(), 10.0, 8.0);
        assert!(sched.at(0.0).bg_ab < 1.0);
        assert!(sched.at(9.99).bg_ab < 1.0);
        assert_eq!(sched.at(10.0).bg_ab, 8.0);
        assert_eq!(sched.at(1e6).bg_ab, 8.0);
    }

    #[test]
    fn loss_burst_recovers() {
        let base = profiles::cosmogrid_lightpath();
        let sched = DriftingLink::loss_burst(base.clone(), 5.0, 15.0, 1e-3);
        assert_eq!(sched.at(0.0).loss_ab, base.loss_ab);
        assert_eq!(sched.at(7.0).loss_ab, 1e-3);
        assert_eq!(sched.at(15.0).loss_ab, base.loss_ab);
    }

    #[test]
    #[should_panic(expected = "first phase must start")]
    fn drifting_link_requires_time_zero_coverage() {
        DriftingLink::new(vec![LinkPhase { start: 5.0, link: profiles::local_lan() }]);
    }

    #[test]
    fn adaptive_sim_path_moves_bytes_and_advances_clock() {
        let sched = DriftingLink::steady(profiles::london_poznan());
        let mut p = AdaptiveSimPath::new(sched, wan_cfg(8));
        let r = p.send_recv(16 * MB, 3);
        assert!((r.ab.bytes - (16 * MB) as f64).abs() < 1.0);
        assert!(p.clock() > 0.0);
        let t1 = p.clock();
        p.advance(2.5);
        assert!((p.clock() - t1 - 2.5).abs() < 1e-9);
    }

    #[test]
    fn static_mode_never_touches_the_knobs() {
        let sched = DriftingLink::congestion_ramp(profiles::cosmogrid_lightpath(), 0.5, 16.0);
        let mut cfg = wan_cfg(16);
        cfg.autotune = false;
        let mut p = AdaptiveSimPath::new(sched, cfg);
        p.tuning().set_active(4);
        for i in 0..20 {
            p.send_recv(16 * MB, 100 + i);
        }
        assert_eq!(p.tuning().active_streams(), 4, "static path restriped itself");
    }

    #[test]
    fn pacing_caps_per_stream_rate() {
        let mut link = profiles::cosmogrid_lightpath();
        link.loss_ab = 0.0;
        link.bg_ab = 0.0;
        let mut cfg = wan_cfg(4);
        cfg.pacing_rate = Some(2.0 * MB as f64); // 2 MB/s per stream
        let p = SimPath::new(link, cfg);
        let r = p.send(32 * MB, Direction::AtoB, 5);
        // 4 streams × 2 MB/s = 8 MB/s aggregate ceiling
        assert!(r.throughput_ab() <= 8.5 * MB as f64, "{}", r.throughput_ab());
    }
}
