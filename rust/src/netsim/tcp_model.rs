//! Per-flow TCP congestion model: slow start + CUBIC-style loss recovery
//! with a receiver-window cap and an optional application-level rate cap
//! (crypto for scp, serialization for MUSCLE, `MPW_setPacingRate` for
//! MPWide).
//!
//! One "round" of the simulation is one RTT: the flow offers
//! `min(cwnd, rwnd, app_cap·dt, remaining)` bytes, the network delivers a
//! (possibly scaled) share, and the window reacts — shrinking to β·cwnd
//! on a loss round (CUBIC β = 0.7) and converging back toward the
//! pre-loss window quickly before probing onward. CUBIC (Linux's default
//! since 2006) matters here: classic Reno's one-MSS-per-RTT recovery
//! makes any stream that loses early a multi-second straggler that gates
//! the whole striped message — a pathology real 2013 endpoints did not
//! have. A loss-rate scaling law still emerges (asserted below):
//! throughput falls superlinearly in √p as loss grows.

/// Ethernet-ish maximum segment size, bytes.
pub const MSS: f64 = 1448.0;

/// Initial congestion window (RFC 6928's 10 segments).
pub const INIT_CWND: f64 = 10.0 * MSS;

/// One TCP flow moving a fixed number of bytes.
#[derive(Debug, Clone)]
pub struct TcpFlow {
    /// Congestion window, bytes.
    pub cwnd: f64,
    /// Slow-start threshold, bytes.
    pub ssthresh: f64,
    /// Receiver window cap, bytes (`MPW_setWin` / OS autotuning limit).
    pub rwnd: f64,
    /// Bytes still to deliver.
    pub remaining: f64,
    /// Application-side rate cap, bytes/second (crypto, serialization,
    /// or software pacing). `None` = unlimited.
    pub app_cap: Option<f64>,
    /// Bytes delivered so far.
    pub delivered: f64,
    /// Loss (window-reduction) events experienced.
    pub losses: u32,
    /// Window size at the last loss (CUBIC's W_max convergence target).
    pub w_max: f64,
    /// Application-level stall after each loss event, in rounds. 0 for a
    /// plain TCP flow; >0 models protocols whose application layer
    /// head-of-line blocks on retransmission (scp's ssh channel layer).
    pub stall_rounds: u32,
    /// Remaining stalled rounds (state).
    stalled: u32,
}

impl TcpFlow {
    /// New flow with `bytes` to move under a receiver window of `rwnd`.
    pub fn new(bytes: f64, rwnd: f64, app_cap: Option<f64>) -> TcpFlow {
        TcpFlow {
            cwnd: INIT_CWND.min(rwnd),
            ssthresh: rwnd,
            rwnd,
            remaining: bytes,
            app_cap,
            delivered: 0.0,
            losses: 0,
            w_max: rwnd,
            stall_rounds: 0,
            stalled: 0,
        }
    }

    /// Builder: make the flow stall for `rounds` after every loss event
    /// (application-level head-of-line blocking, e.g. scp).
    pub fn with_loss_stall(mut self, rounds: u32) -> TcpFlow {
        self.stall_rounds = rounds;
        self
    }

    /// Whether the flow has delivered everything.
    pub fn done(&self) -> bool {
        self.remaining < 0.5
    }

    /// Bytes the flow would like to move in a round of length `dt`.
    pub fn offer(&self, dt: f64) -> f64 {
        if self.done() || self.stalled > 0 {
            return 0.0;
        }
        let mut o = self.cwnd.min(self.rwnd).min(self.remaining);
        if let Some(cap) = self.app_cap {
            o = o.min(cap * dt);
        }
        o.max(0.0)
    }

    /// CUBIC multiplicative-decrease factor.
    pub const BETA: f64 = 0.7;

    /// Account one round: `delivered` bytes acked; `lost` = at least one
    /// loss event this round (triple-dup-ack → multiplicative decrease).
    pub fn on_round(&mut self, delivered: f64, lost: bool) {
        self.remaining = (self.remaining - delivered).max(0.0);
        self.delivered += delivered;
        if self.stalled > 0 {
            self.stalled -= 1;
            return;
        }
        if lost {
            self.stalled = self.stall_rounds;
            self.losses += 1;
            self.w_max = self.cwnd;
            self.cwnd = (self.cwnd * Self::BETA).max(2.0 * MSS);
            self.ssthresh = self.cwnd;
        } else if self.cwnd < self.ssthresh {
            // slow start: one extra segment per acked segment
            self.cwnd = (self.cwnd + delivered).min(self.rwnd);
        } else {
            // CUBIC-flavoured avoidance: converge quickly back toward the
            // pre-loss window, then probe beyond it.
            let frac = if self.cwnd > 0.0 { (delivered / self.cwnd).min(1.0) } else { 0.0 };
            let step = if self.cwnd < self.w_max {
                // concave convergence: close 25% of the gap per RTT
                MSS + 0.25 * (self.w_max - self.cwnd)
            } else {
                // max probing: gentle compounding growth past W_max
                MSS + 0.03 * self.cwnd
            };
            self.cwnd = (self.cwnd + step * frac).min(self.rwnd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn offer_respects_all_caps() {
        let f = TcpFlow::new(1e9, 100_000.0, Some(1e6));
        // rwnd 100 KB, cwnd starts at INIT_CWND, app cap 1 MB/s over 10 ms
        assert!((f.offer(0.01) - INIT_CWND.min(10_000.0)).abs() < 1.0);
        let f2 = TcpFlow::new(500.0, 1e9, None);
        assert_eq!(f2.offer(0.01), 500.0); // remaining is the binding cap
    }

    #[test]
    fn slow_start_doubles_then_converges_to_wmax() {
        let mut f = TcpFlow::new(1e12, 1e9, None);
        f.ssthresh = 64.0 * MSS;
        let c0 = f.cwnd;
        f.on_round(f.cwnd, false);
        assert!((f.cwnd - 2.0 * c0).abs() < 1.0, "slow start doubles");
        // past ssthresh with a gap to w_max: close 25% of the gap + 1 MSS
        f.cwnd = f.ssthresh;
        f.w_max = f.ssthresh + 400.0 * MSS;
        let c1 = f.cwnd;
        f.on_round(f.cwnd, false);
        let expect = c1 + MSS + 0.25 * (f.w_max - c1);
        assert!((f.cwnd - expect).abs() < 1.0, "cubic convergence step");
    }

    #[test]
    fn loss_shrinks_window_by_beta() {
        let mut f = TcpFlow::new(1e12, 1e9, None);
        f.cwnd = 1e6;
        f.on_round(1e6, true);
        assert!((f.cwnd - TcpFlow::BETA * 1e6).abs() < 1.0);
        assert_eq!(f.losses, 1);
        assert!((f.w_max - 1e6).abs() < 1.0, "w_max remembers the pre-loss window");
    }

    #[test]
    fn recovery_after_loss_is_fast_not_linear() {
        // The straggler pathology guard: after a loss at 4 MB, the window
        // must be back within 5% of w_max in < 25 RTTs (Reno would need
        // ~830 RTTs at 1 MSS per RTT).
        let mut f = TcpFlow::new(1e12, 1e9, None);
        f.cwnd = 4e6;
        f.ssthresh = 2.0 * MSS; // force CA
        f.on_round(4e6, true);
        let mut rounds = 0;
        while f.cwnd < 0.95 * f.w_max && rounds < 1000 {
            f.on_round(f.cwnd, false);
            rounds += 1;
        }
        assert!(rounds < 25, "recovery took {rounds} RTTs");
    }

    #[test]
    fn window_never_exceeds_rwnd() {
        let mut f = TcpFlow::new(1e12, 50_000.0, None);
        for _ in 0..100 {
            let o = f.offer(0.01);
            f.on_round(o, false);
            assert!(f.cwnd <= 50_000.0 + 1.0);
        }
    }

    #[test]
    fn completes_exact_byte_count() {
        let mut f = TcpFlow::new(1_000_000.0, 1e9, None);
        let mut moved = 0.0;
        while !f.done() {
            let o = f.offer(0.01);
            f.on_round(o, false);
            moved += o;
        }
        assert!((moved - 1_000_000.0).abs() < 1.0);
        assert!((f.delivered - 1_000_000.0).abs() < 1.0);
    }

    /// A loss-rate scaling law must *emerge*: steady-state throughput of
    /// a loss-limited flow falls steeply and monotonically as the loss
    /// probability grows (CUBIC sits between Mathis's p^-1/2 and its own
    /// p^-3/4 on these horizons). We only pin the shape, not a constant.
    #[test]
    fn loss_scaling_law_emerges() {
        let rtt = 0.05;
        let mut rates = Vec::new();
        for &p in &[1e-5f64, 1e-4, 1e-3] {
            let mut rng = Rng::new(42);
            let mut f = TcpFlow::new(f64::INFINITY, 1e12, None);
            f.ssthresh = 2.0 * MSS; // force CA from the start
            f.cwnd = 100.0 * MSS;
            f.w_max = 100.0 * MSS;
            let rounds = 30_000;
            let mut total = 0.0;
            for _ in 0..rounds {
                let o = f.offer(rtt);
                let packets = o / MSS;
                let lost = rng.chance(1.0 - (1.0 - p).powf(packets));
                f.on_round(o, lost);
                total += o;
            }
            rates.push(total / (rounds as f64 * rtt));
        }
        assert!(rates[0] > 2.0 * rates[1], "p×10 should cost >2x: {rates:?}");
        assert!(rates[1] > 2.0 * rates[2], "p×10 should cost >2x: {rates:?}");
        // and two decades of loss cost at least a decade of rate
        assert!(rates[0] > 10.0 * rates[2], "{rates:?}");
    }
}
