//! Link profiles: the calibrated per-route parameters of the simulator.
//!
//! Everything qualitative comes from the TCP model; a profile only fixes
//! what a real route fixes — propagation delay, bottleneck capacity,
//! residual loss per direction, and how busy the route is. Profiles are
//! named after the endpoint pairs in the paper's evaluation.

/// Transfer direction over a link (the paper reports each direction
/// separately — Table 1's `11/16`-style cells).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Endpoint 1 → endpoint 2 (first number in the paper's cells).
    AtoB,
    /// Endpoint 2 → endpoint 1.
    BtoA,
}

/// A wide-area route between two endpoints.
#[derive(Debug, Clone)]
pub struct LinkProfile {
    /// Human-readable route name (paper endpoint pair).
    pub name: &'static str,
    /// Base round-trip time, seconds.
    pub rtt: f64,
    /// Bottleneck capacity per direction, bytes/second.
    pub capacity: f64,
    /// Residual per-packet loss probability, A→B.
    pub loss_ab: f64,
    /// Residual per-packet loss probability, B→A.
    pub loss_ba: f64,
    /// Background traffic A→B expressed as a number of competing elastic
    /// TCP flows (fractional allowed). Fair-share competition against
    /// these is what makes N parallel streams collectively faster than
    /// one — the paper's core mechanism.
    pub bg_ab: f64,
    /// Background competing-flow weight, B→A.
    pub bg_ba: f64,
    /// Relative RTT jitter (std-dev as a fraction of the base RTT).
    pub jitter: f64,
    /// Coupling between directions under simultaneous bidirectional load
    /// (ack compression, duplex contention on campus equipment): the
    /// usable share of one direction shrinks by `duplex · utilization` of
    /// the other.
    pub duplex_penalty: f64,
}

impl LinkProfile {
    /// Loss probability for a direction.
    pub fn loss(&self, dir: Direction) -> f64 {
        match dir {
            Direction::AtoB => self.loss_ab,
            Direction::BtoA => self.loss_ba,
        }
    }

    /// Background load for a direction.
    pub fn bg(&self, dir: Direction) -> f64 {
        match dir {
            Direction::AtoB => self.bg_ab,
            Direction::BtoA => self.bg_ba,
        }
    }

    /// Bandwidth-delay product, bytes.
    pub fn bdp(&self) -> f64 {
        self.capacity * self.rtt
    }
}

/// The routes of the paper's evaluation. Parameters are calibrated so the
/// *measured tooling throughputs* land near Table 1 / §1.2.3 — see
/// EXPERIMENTS.md for the comparison and the calibration notes.
pub mod profiles {
    use super::LinkProfile;

    /// London (UK) – Poznań (PL), regular internet (Table 1 rows 1–3).
    pub fn london_poznan() -> LinkProfile {
        LinkProfile {
            name: "London-Poznan",
            rtt: 0.035,
            capacity: 135e6,
            loss_ab: 8.0e-5,
            loss_ba: 2.0e-6,
            bg_ab: 3.5,
            bg_ba: 0.15,
            jitter: 0.06,
            duplex_penalty: 0.42,
        }
    }

    /// Poznań (PL) – Gdańsk (PL), national research network.
    pub fn poznan_gdansk() -> LinkProfile {
        LinkProfile {
            name: "Poznan-Gdansk",
            rtt: 0.012,
            capacity: 140e6,
            loss_ab: 3.0e-5,
            loss_ba: 2.0e-6,
            bg_ab: 1.2,
            bg_ba: 0.10,
            jitter: 0.05,
            duplex_penalty: 0.15,
        }
    }

    /// Poznań (PL) – Amsterdam (NL), regular internet.
    pub fn poznan_amsterdam() -> LinkProfile {
        LinkProfile {
            name: "Poznan-Amsterdam",
            rtt: 0.030,
            capacity: 70e6,
            loss_ab: 8.0e-6,
            loss_ba: 3.0e-4,
            bg_ab: 1.2,
            bg_ba: 1.2,
            jitter: 0.06,
            duplex_penalty: 0.18,
        }
    }

    /// UCL (London) – Yale (US), regular internet (§1.2.3 file transfers).
    pub fn ucl_yale() -> LinkProfile {
        LinkProfile {
            name: "UCL-Yale",
            rtt: 0.075,
            capacity: 55e6,
            loss_ab: 1.0e-4,
            loss_ba: 1.0e-4,
            bg_ab: 1.2,
            bg_ba: 1.2,
            jitter: 0.08,
            duplex_penalty: 0.20,
        }
    }

    /// UCL desktop – HECToR (Edinburgh) over regular internet: the
    /// bloodflow coupling link (§1.2.2; "messages require 11 ms to
    /// traverse the network back and forth").
    pub fn ucl_hector() -> LinkProfile {
        LinkProfile {
            name: "UCL-HECToR",
            rtt: 0.011,
            capacity: 60e6,
            loss_ab: 1.0e-6,
            loss_ba: 1.0e-6,
            bg_ab: 0.5,
            bg_ba: 0.5,
            jitter: 0.10,
            duplex_penalty: 0.10,
        }
    }

    /// Dedicated 10 Gbit/s lightpath between CosmoGrid supercomputers
    /// (Espoo–Edinburgh–Amsterdam triangle, §1.2.1 / Fig 1).
    pub fn cosmogrid_lightpath() -> LinkProfile {
        LinkProfile {
            name: "CosmoGrid-lightpath",
            rtt: 0.030,
            capacity: 1.25e9,
            loss_ab: 1.0e-7,
            loss_ba: 1.0e-7,
            bg_ab: 0.05,
            bg_ba: 0.05,
            jitter: 0.03,
            duplex_penalty: 0.05,
        }
    }

    /// Amsterdam – Tokyo 10 Gbit/s lightpath (the original CosmoGrid run,
    /// §1.2.1): intercontinental RTT, clean dedicated capacity.
    pub fn amsterdam_tokyo() -> LinkProfile {
        LinkProfile {
            name: "Amsterdam-Tokyo",
            rtt: 0.27,
            capacity: 1.25e9,
            loss_ab: 2.0e-7,
            loss_ba: 2.0e-7,
            bg_ab: 0.05,
            bg_ba: 0.05,
            jitter: 0.02,
            duplex_penalty: 0.05,
        }
    }

    /// Synthetic high-bandwidth-delay-product reference: a clean 10
    /// Gbit/s route at transcontinental RTT (120 ms → BDP = 150 MB).
    /// This is the regime where one-message-at-a-time rendezvous
    /// resilience collapses to `chunk / RTT` and in-flight windowing
    /// ([`ResilienceConfig::window`]) pays off; the
    /// `resilience_window` bench pins its link to this profile.
    ///
    /// [`ResilienceConfig::window`]:
    ///     crate::mpwide::config::ResilienceConfig#structfield.window
    pub fn high_bdp() -> LinkProfile {
        LinkProfile {
            name: "high-BDP-reference",
            rtt: 0.12,
            capacity: 1.25e9,
            loss_ab: 1.0e-7,
            loss_ba: 1.0e-7,
            bg_ab: 0.05,
            bg_ba: 0.05,
            jitter: 0.02,
            duplex_penalty: 0.05,
        }
    }

    /// Same-machine / LAN reference (the paper's §1.3.6 constraint: MPWide
    /// has little to gain locally).
    pub fn local_lan() -> LinkProfile {
        LinkProfile {
            name: "local-LAN",
            rtt: 0.0002,
            capacity: 1.2e9,
            loss_ab: 0.0,
            loss_ba: 0.0,
            bg_ab: 0.0,
            bg_ba: 0.0,
            jitter: 0.05,
            duplex_penalty: 0.0,
        }
    }

    /// All profiles (for sweeps and sanity tests).
    pub fn all() -> Vec<LinkProfile> {
        vec![
            london_poznan(),
            poznan_gdansk(),
            poznan_amsterdam(),
            ucl_yale(),
            ucl_hector(),
            cosmogrid_lightpath(),
            amsterdam_tokyo(),
            high_bdp(),
            local_lan(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direction_accessors() {
        let l = profiles::london_poznan();
        assert_eq!(l.loss(Direction::AtoB), l.loss_ab);
        assert_eq!(l.bg(Direction::BtoA), l.bg_ba);
    }

    #[test]
    fn profiles_are_physical() {
        for p in profiles::all() {
            assert!(p.rtt > 0.0 && p.rtt < 1.0, "{}", p.name);
            assert!(p.capacity > 1e6, "{}", p.name);
            assert!((0.0..0.01).contains(&p.loss_ab), "{}", p.name);
            assert!((0.0..0.01).contains(&p.loss_ba), "{}", p.name);
            assert!((0.0..64.0).contains(&p.bg_ab), "{}", p.name);
            assert!(p.duplex_penalty < 1.0, "{}", p.name);
        }
    }

    #[test]
    fn lightpaths_are_10g() {
        assert!((profiles::cosmogrid_lightpath().capacity - 1.25e9).abs() < 1.0);
        assert!((profiles::amsterdam_tokyo().capacity - 1.25e9).abs() < 1.0);
    }

    #[test]
    fn bdp_math() {
        let l = profiles::amsterdam_tokyo();
        assert!((l.bdp() - 1.25e9 * 0.27).abs() < 1.0);
    }
}
