//! The round-based network engine: advances a set of flows over a link,
//! sharing the bottleneck proportionally, drawing loss and background
//! load stochastically, and (for bidirectional runs) coupling the two
//! directions through the profile's duplex penalty.

use super::link::{Direction, LinkProfile};
use super::tcp_model::{TcpFlow, MSS};
use crate::util::Rng;

/// Result of driving a set of flows to completion in one direction.
#[derive(Debug, Clone)]
pub struct OneWayResult {
    /// Wall-clock (simulated) seconds until the last flow finished.
    pub seconds: f64,
    /// Total bytes delivered.
    pub bytes: f64,
    /// Aggregate throughput, bytes/second.
    pub throughput: f64,
    /// Total loss events across flows.
    pub losses: u32,
    /// Rounds simulated.
    pub rounds: u32,
    /// Optional timeline of (t, cumulative bytes) samples.
    pub timeline: Vec<(f64, f64)>,
}

/// Hard cap on simulation rounds (guards against a mis-parameterized run
/// spinning forever; generous: 10⁶ RTTs).
const MAX_ROUNDS: u32 = 1_000_000;

fn round_dt(link: &LinkProfile, rng: &mut Rng) -> f64 {
    (link.rtt * (1.0 + link.jitter * rng.gauss())).clamp(link.rtt * 0.5, link.rtt * 2.0)
}

/// Max-min fair ("waterfilling") allocation of `capacity` bytes among
/// foreground flows demanding `offers`, with `bg_weight` additional
/// elastic (always-hungry) background flows absorbing their fair share.
/// This is the mechanism behind MPWide's stream-count advantage: on a
/// busy bottleneck, N flows collectively receive ~N/(N+bg) of capacity
/// where one flow receives ~1/(1+bg).
pub fn maxmin_allocate(offers: &[f64], capacity: f64, bg_weight: f64) -> Vec<f64> {
    let mut alloc = vec![0.0; offers.len()];
    let mut unsat: Vec<usize> = (0..offers.len()).filter(|&i| offers[i] > 0.0).collect();
    let mut cap = capacity;
    // Background flows are never satisfied, so they keep their weight in
    // every round of the waterfilling and simply absorb the remainder.
    while !unsat.is_empty() && cap > 1e-9 {
        let share = cap / (unsat.len() as f64 + bg_weight);
        let satisfied: Vec<usize> =
            unsat.iter().copied().filter(|&i| offers[i] <= share).collect();
        if satisfied.is_empty() {
            for &i in &unsat {
                alloc[i] = share;
            }
            return alloc;
        }
        for &i in &satisfied {
            alloc[i] = offers[i];
            cap -= offers[i];
        }
        unsat.retain(|i| !satisfied.contains(i));
    }
    alloc
}

/// Advance `flows` one round in one direction. `other_util` is the
/// utilization (0..1) of the opposite direction during the same round,
/// for the duplex coupling. Returns (bytes delivered, loss events,
/// utilization of this direction).
fn step_direction(
    flows: &mut [TcpFlow],
    link: &LinkProfile,
    dir: Direction,
    dt: f64,
    other_util: f64,
    rng: &mut Rng,
) -> (f64, u32, f64) {
    let offers: Vec<f64> = flows.iter().map(|f| f.offer(dt)).collect();
    let total_offer: f64 = offers.iter().sum();
    if total_offer <= 0.0 {
        // nothing to move this round, but stalled flows must still tick
        for f in flows.iter_mut() {
            if !f.done() {
                f.on_round(0.0, false);
            }
        }
        return (0.0, 0, 0.0);
    }
    // Background intensity fluctuates round to round.
    let bg = (link.bg(dir) * (1.0 + 0.3 * rng.gauss())).max(0.0);
    let duplex = 1.0 - link.duplex_penalty * other_util;
    let capacity = (link.capacity * dt * duplex).max(MSS);
    let alloc = maxmin_allocate(&offers, capacity, bg);

    // Loss: residual random loss per packet, plus queue-overflow pressure
    // when a flow's window overshoots its fair allocation. Per-flow (not
    // global) loss avoids synchronized collapse and lets each flow's
    // AIMD settle just above its share — standard flow-level modelling.
    const BETA_LOSS: f64 = 0.3;
    let p_rand = link.loss(dir);
    let mut delivered_total = 0.0;
    let mut losses = 0;
    for ((f, &offer), &a) in flows.iter_mut().zip(&offers).zip(&alloc) {
        if offer <= 0.0 {
            // still tick the flow (stall countdown) without progress
            if !f.done() {
                f.on_round(0.0, false);
            }
            continue;
        }
        let delivered = offer.min(a);
        let packets = delivered / MSS;
        let overshoot = ((offer - a) / a.max(MSS)).max(0.0);
        let p_loss = (1.0 - (1.0 - p_rand).powf(packets)) + BETA_LOSS * overshoot.min(3.0);
        let lost = rng.chance(p_loss.min(0.95));
        f.on_round(delivered, lost);
        if lost {
            losses += 1;
        }
        delivered_total += delivered;
    }
    let util = (delivered_total / (link.capacity * dt)).min(1.0);
    (delivered_total, losses, util)
}

/// Drive `flows` to completion in a single direction (scp-style
/// unidirectional transfer). `record_timeline` samples cumulative bytes
/// each round (used by the figure benches).
pub fn simulate_oneway(
    flows: &mut [TcpFlow],
    link: &LinkProfile,
    dir: Direction,
    rng: &mut Rng,
    record_timeline: bool,
) -> OneWayResult {
    let mut t = 0.0;
    let mut rounds = 0;
    let mut losses = 0;
    let mut moved = 0.0;
    let mut timeline = Vec::new();
    while flows.iter().any(|f| !f.done()) && rounds < MAX_ROUNDS {
        let dt = round_dt(link, rng);
        let (d, l, _) = step_direction(flows, link, dir, dt, 0.0, rng);
        t += dt;
        rounds += 1;
        losses += l;
        moved += d;
        if record_timeline {
            timeline.push((t, moved));
        }
    }
    OneWayResult {
        seconds: t,
        bytes: moved,
        throughput: if t > 0.0 { moved / t } else { 0.0 },
        losses,
        rounds,
        timeline,
    }
}

/// Drive two flow sets simultaneously, one per direction — the shape of
/// `MPW_SendRecv`, which is how the paper's MPWide throughput tests ran
/// (and why MPWide's Table 1 rows are symmetric). Returns per-direction
/// results; each direction's clock stops when its own flows finish.
pub fn simulate_duplex(
    flows_ab: &mut [TcpFlow],
    flows_ba: &mut [TcpFlow],
    link: &LinkProfile,
    rng: &mut Rng,
) -> (OneWayResult, OneWayResult) {
    let mut t = 0.0;
    let mut rounds = 0;
    let (mut end_ab, mut end_ba) = (0.0f64, 0.0f64);
    let (mut moved_ab, mut moved_ba) = (0.0f64, 0.0f64);
    let (mut losses_ab, mut losses_ba) = (0u32, 0u32);
    let (mut util_ab, mut util_ba) = (0.0f64, 0.0f64);
    while (flows_ab.iter().any(|f| !f.done()) || flows_ba.iter().any(|f| !f.done()))
        && rounds < MAX_ROUNDS
    {
        let dt = round_dt(link, rng);
        let (d_ab, l_ab, u_ab) =
            step_direction(flows_ab, link, Direction::AtoB, dt, util_ba, rng);
        let (d_ba, l_ba, u_ba) =
            step_direction(flows_ba, link, Direction::BtoA, dt, util_ab, rng);
        util_ab = u_ab;
        util_ba = u_ba;
        t += dt;
        rounds += 1;
        moved_ab += d_ab;
        moved_ba += d_ba;
        losses_ab += l_ab;
        losses_ba += l_ba;
        if d_ab > 0.0 {
            end_ab = t;
        }
        if d_ba > 0.0 {
            end_ba = t;
        }
    }
    let mk = |moved: f64, end: f64, losses: u32| OneWayResult {
        seconds: end,
        bytes: moved,
        throughput: if end > 0.0 { moved / end } else { 0.0 },
        losses,
        rounds,
        timeline: Vec::new(),
    };
    (mk(moved_ab, end_ab, losses_ab), mk(moved_ba, end_ba, losses_ba))
}

/// Convenience: unidirectional transfer of `bytes` over `nstreams` equal
/// flows with the given per-stream receiver window and app cap.
pub fn transfer_oneway(
    link: &LinkProfile,
    dir: Direction,
    bytes: f64,
    nstreams: usize,
    rwnd: f64,
    app_cap: Option<f64>,
    seed: u64,
) -> OneWayResult {
    let mut rng = Rng::new(seed);
    let per = bytes / nstreams as f64;
    let mut flows: Vec<TcpFlow> =
        (0..nstreams).map(|_| TcpFlow::new(per, rwnd, app_cap)).collect();
    simulate_oneway(&mut flows, link, dir, &mut rng, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::profiles;
    use crate::util::prop;

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn conservation_all_bytes_arrive() {
        let link = profiles::london_poznan();
        let r = transfer_oneway(&link, Direction::AtoB, 64.0 * MB, 8, 1e6, None, 1);
        assert!((r.bytes - 64.0 * MB).abs() < 1.0, "{}", r.bytes);
    }

    #[test]
    fn deterministic_for_seed() {
        let link = profiles::ucl_yale();
        let a = transfer_oneway(&link, Direction::AtoB, 16.0 * MB, 4, 1e6, None, 7);
        let b = transfer_oneway(&link, Direction::AtoB, 16.0 * MB, 4, 1e6, None, 7);
        assert_eq!(a.seconds, b.seconds);
        assert_eq!(a.losses, b.losses);
    }

    #[test]
    fn window_limited_single_flow_hits_rwnd_over_rtt() {
        // Clean LFN, tiny window: throughput ≈ rwnd / RTT.
        let mut link = profiles::cosmogrid_lightpath();
        link.loss_ab = 0.0;
        link.bg_ab = 0.0;
        link.jitter = 0.0;
        let rwnd = 256.0 * 1024.0;
        let r = transfer_oneway(&link, Direction::AtoB, 64.0 * MB, 1, rwnd, None, 3);
        let expect = rwnd / link.rtt;
        let ratio = r.throughput / expect;
        assert!((0.7..1.1).contains(&ratio), "thr {} vs {}", r.throughput, expect);
    }

    #[test]
    fn more_streams_beat_one_on_lossy_lfn() {
        // The paper's core claim: ≥32 streams over long-distance networks.
        let link = profiles::london_poznan();
        let one = transfer_oneway(&link, Direction::AtoB, 64.0 * MB, 1, 4e6, None, 5);
        let many = transfer_oneway(&link, Direction::AtoB, 64.0 * MB, 32, 4e6, None, 5);
        assert!(
            many.throughput > 2.0 * one.throughput,
            "32 streams {:.1} MB/s vs 1 stream {:.1} MB/s",
            many.throughput / MB,
            one.throughput / MB
        );
    }

    #[test]
    fn throughput_never_exceeds_capacity() {
        prop::check("thr<=cap", 20, |rng| {
            let mut profs = profiles::all();
            let link = profs.remove(rng.urange(0, profs.len()));
            let bytes = (rng.urange(1, 64) as f64) * MB;
            let n = rng.urange(1, 64);
            let rwnd = rng.urange(64 * 1024, 8 << 20) as f64;
            let r = transfer_oneway(&link, Direction::AtoB, bytes, n, rwnd, None, rng.next_u64());
            if r.throughput <= link.capacity * 1.01 {
                Ok(())
            } else {
                Err(format!("{} > cap {}", r.throughput, link.capacity))
            }
        });
    }

    #[test]
    fn app_cap_binds() {
        let mut link = profiles::poznan_gdansk();
        link.loss_ab = 0.0;
        link.bg_ab = 0.0;
        let cap = 5.0 * MB;
        let r = transfer_oneway(&link, Direction::AtoB, 32.0 * MB, 1, 64e6, Some(cap), 9);
        assert!(r.throughput <= cap * 1.05, "{} vs {}", r.throughput, cap);
        assert!(r.throughput >= cap * 0.6, "{} vs {}", r.throughput, cap);
    }

    #[test]
    fn loss_asymmetry_produces_rate_asymmetry() {
        // Single stream, directions differing only in loss: the cleaner
        // direction must be faster (ZeroMQ's 30/110 pattern).
        let link = profiles::london_poznan();
        let ab = transfer_oneway(&link, Direction::AtoB, 64.0 * MB, 1, 4e6, None, 11);
        let ba = transfer_oneway(&link, Direction::BtoA, 64.0 * MB, 1, 4e6, None, 11);
        assert!(
            ba.throughput > 1.5 * ab.throughput,
            "clean dir {:.1} vs lossy dir {:.1} MB/s",
            ba.throughput / MB,
            ab.throughput / MB
        );
    }

    #[test]
    fn duplex_runs_finish_both_directions() {
        let link = profiles::poznan_amsterdam();
        let mut rng = Rng::new(13);
        let per = 64.0 * MB / 16.0;
        let mut ab: Vec<TcpFlow> = (0..16).map(|_| TcpFlow::new(per, 4e6, None)).collect();
        let mut ba: Vec<TcpFlow> = (0..16).map(|_| TcpFlow::new(per, 4e6, None)).collect();
        let (ra, rb) = simulate_duplex(&mut ab, &mut ba, &link, &mut rng);
        assert!((ra.bytes - 64.0 * MB).abs() < 1.0);
        assert!((rb.bytes - 64.0 * MB).abs() < 1.0);
        // symmetric setup → roughly symmetric rates (the MPWide pattern)
        let ratio = ra.throughput / rb.throughput;
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn timeline_is_monotonic() {
        let link = profiles::ucl_yale();
        let mut rng = Rng::new(17);
        let mut flows = vec![TcpFlow::new(8.0 * MB, 2e6, None); 4];
        let r = simulate_oneway(&mut flows, &link, Direction::AtoB, &mut rng, true);
        assert!(!r.timeline.is_empty());
        for w in r.timeline.windows(2) {
            assert!(w[1].0 > w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn local_lan_is_fast_regardless_of_streams() {
        let link = profiles::local_lan();
        let one = transfer_oneway(&link, Direction::AtoB, 64.0 * MB, 1, 4e6, None, 19);
        // loopback/LAN: single stream already saturates (paper §1.3.6)
        assert!(one.throughput > 0.5 * link.capacity, "{}", one.throughput);
    }
}
