//! Fault injection for the simulated WAN: deterministic stream-level
//! failure schedules driven by simulated time.
//!
//! The real resilience layer ([`crate::mpwide::resilience`]) reacts to
//! socket errors; in the simulator the same *decisions* (isolate the
//! stream, retry the in-flight message over survivors, clamp striping
//! to the live count, re-absorb on rejoin) are driven by a
//! [`FaultSchedule`] instead — a sorted list of down/up events per
//! stream. Canned scenarios cover the cases the `resilience_wan` bench
//! and the fault-injection tests exercise: a single-stream blackout, a
//! full-path flap, and a flappy stream that keeps dying and rejoining.

/// One stream-level event at a simulated time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// Stream `stream` dies at time `t` (seconds).
    Down {
        /// Event time, simulated seconds.
        t: f64,
        /// Stream index.
        stream: usize,
    },
    /// Stream `stream` finishes rejoining at time `t`.
    Up {
        /// Event time, simulated seconds.
        t: f64,
        /// Stream index.
        stream: usize,
    },
}

impl FaultEvent {
    /// Event time, simulated seconds.
    pub fn time(&self) -> f64 {
        match self {
            FaultEvent::Down { t, .. } | FaultEvent::Up { t, .. } => *t,
        }
    }

    /// Stream the event applies to.
    pub fn stream(&self) -> usize {
        match self {
            FaultEvent::Down { stream, .. } | FaultEvent::Up { stream, .. } => *stream,
        }
    }
}

/// A deterministic, time-sorted fault schedule.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
}

impl FaultSchedule {
    /// No faults (the control case).
    pub fn none() -> FaultSchedule {
        FaultSchedule::default()
    }

    /// Build from explicit events (sorted by time internally).
    pub fn new(mut events: Vec<FaultEvent>) -> FaultSchedule {
        events.sort_by(|a, b| a.time().partial_cmp(&b.time()).unwrap());
        FaultSchedule { events }
    }

    /// Single-stream blackout: `stream` dies at `from` and rejoins at
    /// `until`.
    pub fn blackout(stream: usize, from: f64, until: f64) -> FaultSchedule {
        assert!(from < until, "blackout must have positive duration");
        FaultSchedule::new(vec![
            FaultEvent::Down { t: from, stream },
            FaultEvent::Up { t: until, stream },
        ])
    }

    /// Full-path flap: every stream of an `nstreams` path dies at `from`
    /// and rejoins at `until`.
    pub fn path_flap(nstreams: usize, from: f64, until: f64) -> FaultSchedule {
        assert!(from < until, "flap must have positive duration");
        let mut ev = Vec::with_capacity(2 * nstreams);
        for s in 0..nstreams {
            ev.push(FaultEvent::Down { t: from, stream: s });
            ev.push(FaultEvent::Up { t: until, stream: s });
        }
        FaultSchedule::new(ev)
    }

    /// Flappy reconnect: `stream` dies every `period` seconds starting
    /// at `from`, rejoining half a period later, `cycles` times.
    pub fn flappy(stream: usize, from: f64, period: f64, cycles: usize) -> FaultSchedule {
        assert!(period > 0.0, "flap period must be positive");
        let mut ev = Vec::with_capacity(2 * cycles);
        for c in 0..cycles {
            let t0 = from + c as f64 * period;
            ev.push(FaultEvent::Down { t: t0, stream });
            ev.push(FaultEvent::Up { t: t0 + period / 2.0, stream });
        }
        FaultSchedule::new(ev)
    }

    /// True when the schedule has no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The sorted events.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// The first `Down` event in the open-closed interval `(t0, t1]`
    /// whose stream is in `used` — the event that aborts a transfer
    /// occupying that window.
    pub fn first_down_in(&self, t0: f64, t1: f64, used: &[usize]) -> Option<FaultEvent> {
        self.events
            .iter()
            .find(|e| {
                matches!(e, FaultEvent::Down { .. })
                    && e.time() > t0
                    && e.time() <= t1
                    && used.contains(&e.stream())
            })
            .copied()
    }

    /// The earliest `Up` event strictly after `t` (what a zero-live-path
    /// send waits for).
    pub fn next_up_after(&self, t: f64) -> Option<FaultEvent> {
        self.events
            .iter()
            .find(|e| matches!(e, FaultEvent::Up { .. }) && e.time() > t)
            .copied()
    }
}

/// A consumer-side pacing profile: when the *application* on the
/// receiving end actually calls `recv`. Flow-control tests and the
/// `flow_control` bench drive a slow or stalled reader with this instead
/// of ad-hoc sleeps — the interesting failure mode of an unbounded
/// inbound queue is not a broken link (that is [`FaultSchedule`]'s job)
/// but a healthy link feeding a reader that has wandered off.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReaderSchedule {
    /// The reader stops consuming at this time (seconds); `f64::INFINITY`
    /// means it never stalls.
    pub stall_from: f64,
    /// The reader resumes at this time; `f64::INFINITY` means it never
    /// comes back (the never-reader case).
    pub stall_until: f64,
}

impl ReaderSchedule {
    /// A reader that keeps up: consumes whenever data is available.
    pub fn always() -> ReaderSchedule {
        ReaderSchedule { stall_from: f64::INFINITY, stall_until: f64::INFINITY }
    }

    /// A reader that stalls in `[from, until)` and then resumes; pass
    /// `f64::INFINITY` for `until` to model a reader that never returns.
    pub fn stalled(from: f64, until: f64) -> ReaderSchedule {
        assert!(from < until, "stall must have positive duration");
        ReaderSchedule { stall_from: from, stall_until: until }
    }

    /// Whether the reader consumes at time `t`.
    pub fn should_read(&self, t: f64) -> bool {
        !(self.stall_from..self.stall_until).contains(&t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reader_schedule_windows() {
        let r = ReaderSchedule::always();
        assert!(r.should_read(0.0) && r.should_read(1e9));
        let r = ReaderSchedule::stalled(2.0, 5.0);
        assert!(r.should_read(1.9));
        assert!(!r.should_read(2.0));
        assert!(!r.should_read(4.99));
        assert!(r.should_read(5.0));
        let never = ReaderSchedule::stalled(1.0, f64::INFINITY);
        assert!(never.should_read(0.5));
        assert!(!never.should_read(1e12), "a never-reader stays stalled");
    }

    #[test]
    fn blackout_orders_events() {
        let f = FaultSchedule::blackout(2, 5.0, 9.0);
        assert_eq!(f.events().len(), 2);
        assert_eq!(f.events()[0], FaultEvent::Down { t: 5.0, stream: 2 });
        assert_eq!(f.events()[1], FaultEvent::Up { t: 9.0, stream: 2 });
    }

    #[test]
    fn path_flap_covers_all_streams() {
        let f = FaultSchedule::path_flap(4, 1.0, 2.0);
        let downs = f.events().iter().filter(|e| matches!(e, FaultEvent::Down { .. })).count();
        let ups = f.events().iter().filter(|e| matches!(e, FaultEvent::Up { .. })).count();
        assert_eq!((downs, ups), (4, 4));
        assert!(f.events().windows(2).all(|w| w[0].time() <= w[1].time()));
    }

    #[test]
    fn flappy_alternates() {
        let f = FaultSchedule::flappy(1, 0.5, 2.0, 3);
        assert_eq!(f.events().len(), 6);
        assert_eq!(f.events()[0].time(), 0.5);
        assert_eq!(f.events()[1].time(), 1.5);
        assert_eq!(f.events()[2].time(), 2.5);
    }

    #[test]
    fn first_down_in_respects_window_and_streams() {
        let f = FaultSchedule::blackout(2, 5.0, 9.0);
        assert_eq!(f.first_down_in(0.0, 4.9, &[2]), None, "before the window");
        assert_eq!(f.first_down_in(0.0, 6.0, &[0, 1]), None, "stream not in use");
        let hit = f.first_down_in(0.0, 6.0, &[1, 2]).unwrap();
        assert_eq!(hit, FaultEvent::Down { t: 5.0, stream: 2 });
        assert_eq!(f.first_down_in(5.0, 9.0, &[2]), None, "t0 is exclusive");
    }

    #[test]
    fn next_up_after_finds_recovery() {
        let f = FaultSchedule::path_flap(2, 1.0, 3.0);
        assert_eq!(f.next_up_after(1.5).unwrap().time(), 3.0);
        assert!(f.next_up_after(3.0).is_none());
    }
}
