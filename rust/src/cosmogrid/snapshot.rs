//! Snapshot rendering (paper Fig 2): project the distributed simulation's
//! particles to a 2-D image, coloured by the site hosting them — green /
//! blue / red exactly as the paper colours Espoo / Edinburgh / Amsterdam.
//! Output is a binary PPM (P6), dependency-free.

use std::io::Write;
use std::path::Path as FsPath;

use anyhow::Result;

use super::domain::SiteParticles;

/// Site colour palette (paper Fig 2: green, blue, red; extras cycle).
pub const SITE_COLORS: [[u8; 3]; 6] = [
    [40, 220, 70],   // green  (Espoo)
    [70, 110, 255],  // blue   (Edinburgh)
    [240, 60, 50],   // red    (Amsterdam)
    [240, 200, 40],  // yellow
    [200, 60, 220],  // magenta
    [60, 220, 220],  // cyan
];

/// Render particle blocks to an RGB buffer of `size`×`size`, projecting
/// (x, y) over `[-extent, extent]²` with additive brightness.
pub fn render(blocks: &[SiteParticles], size: usize, extent: f32) -> Vec<u8> {
    let mut img = vec![0u8; size * size * 3];
    for (si, b) in blocks.iter().enumerate() {
        let color = SITE_COLORS[si % SITE_COLORS.len()];
        for i in 0..b.n_local {
            let x = b.pos[i * 3];
            let y = b.pos[i * 3 + 1];
            let px = ((x / extent + 1.0) * 0.5 * (size as f32 - 1.0)).round();
            let py = ((1.0 - (y / extent + 1.0) * 0.5) * (size as f32 - 1.0)).round();
            if px < 0.0 || py < 0.0 || px >= size as f32 || py >= size as f32 {
                continue;
            }
            let idx = (py as usize * size + px as usize) * 3;
            for c in 0..3 {
                img[idx + c] = img[idx + c].saturating_add(color[c] / 2);
            }
        }
    }
    img
}

/// Write an RGB buffer as binary PPM (P6).
pub fn write_ppm(path: &FsPath, img: &[u8], size: usize) -> Result<()> {
    anyhow::ensure!(img.len() == size * size * 3, "image buffer size mismatch");
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{size} {size}\n255\n")?;
    f.write_all(img)?;
    Ok(())
}

/// Convenience: render and write in one call (the Fig 2 artifact).
pub fn snapshot(blocks: &[SiteParticles], path: &FsPath, size: usize, extent: f32) -> Result<()> {
    write_ppm(path, &render(blocks, size, extent), size)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_particle_block(x: f32, y: f32) -> SiteParticles {
        let mut b = SiteParticles::empty(4);
        b.n_local = 1;
        b.pos[0] = x;
        b.pos[1] = y;
        b.mass[0] = 1.0;
        b
    }

    #[test]
    fn particle_lands_on_expected_pixel() {
        let img = render(&[one_particle_block(0.0, 0.0)], 11, 1.0);
        // center pixel (5,5) should be coloured with site 0's green
        let idx = (5 * 11 + 5) * 3;
        assert!(img[idx + 1] > 0, "green channel set");
        let lit: usize = img.iter().filter(|&&v| v > 0).count();
        assert!(lit <= 3, "only one pixel lit");
    }

    #[test]
    fn sites_use_distinct_colors() {
        let b0 = one_particle_block(-0.5, 0.0);
        let b1 = one_particle_block(0.5, 0.0);
        let img = render(&[b0, b1], 21, 1.0);
        // find the two lit pixels and compare dominant channels
        let mut colors = Vec::new();
        for p in img.chunks(3) {
            if p.iter().any(|&v| v > 0) {
                colors.push([p[0], p[1], p[2]]);
            }
        }
        assert_eq!(colors.len(), 2);
        assert_ne!(colors[0], colors[1]);
    }

    #[test]
    fn out_of_frame_particles_are_skipped() {
        let img = render(&[one_particle_block(5.0, 5.0)], 8, 1.0);
        assert!(img.iter().all(|&v| v == 0));
    }

    #[test]
    fn ppm_file_has_header_and_size() {
        let dir = std::env::temp_dir().join(format!("snap-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.ppm");
        snapshot(&[one_particle_block(0.0, 0.0)], &p, 16, 1.0).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(data.starts_with(b"P6\n16 16\n255\n"));
        assert_eq!(data.len(), 13 + 16 * 16 * 3);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
