//! Per-site state: one "supercomputer" in the CosmoGrid run — a thread
//! owning its own PJRT runtime (the xla wrappers are not `Send`), its
//! particle block, and the compiled AOT executables.

use anyhow::Result;

use super::domain::SiteParticles;
use crate::runtime::{Executable, Runtime};

/// One site of the distributed run.
pub struct Site {
    /// Site index (also its colour in the Fig 2 snapshot).
    pub rank: usize,
    /// This site's particles (padded to the artifact size).
    pub particles: SiteParticles,
    accel: Executable,
    kick_drift: Executable,
    kinetic: Executable,
}

impl Site {
    /// Open the runtime and compile the three N-body artifacts.
    pub fn new(
        rank: usize,
        artifacts_dir: &std::path::Path,
        particles: SiteParticles,
    ) -> Result<Site> {
        let rt = Runtime::open(artifacts_dir)?;
        let n = rt.manifest().config_usize("nbody_n")?;
        anyhow::ensure!(
            particles.n_pad == n,
            "particle block padded to {} but artifacts expect {n}",
            particles.n_pad
        );
        Ok(Site {
            rank,
            particles,
            accel: rt.load("nbody_accel")?,
            kick_drift: rt.load("nbody_kick_drift")?,
            kinetic: rt.load("nbody_kinetic")?,
        })
    }

    /// Acceleration of this site's particles due to the given source
    /// block (local↔local or local↔remote — the superposition property is
    /// tested in python/tests/test_model.py).
    pub fn accel_from(&self, src_pos: &[f32], src_mass: &[f32]) -> Result<Vec<f32>> {
        let out = self.accel.run_f32(&[&self.particles.pos, src_pos, src_mass])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Self-gravity of the local block.
    pub fn self_accel(&self) -> Result<Vec<f32>> {
        self.accel_from(&self.particles.pos.clone(), &self.particles.mass.clone())
    }

    /// Kick-drift update with the accumulated acceleration.
    pub fn step(&mut self, acc: &[f32], dt: f32) -> Result<()> {
        let out = self.kick_drift.run_f32(&[
            &self.particles.pos,
            &self.particles.vel,
            acc,
            &[dt],
        ])?;
        let mut it = out.into_iter();
        self.particles.pos = it.next().unwrap();
        self.particles.vel = it.next().unwrap();
        Ok(())
    }

    /// Kinetic energy of the block (diagnostics; zero-mass padding
    /// contributes nothing).
    pub fn kinetic(&self) -> Result<f32> {
        let out = self.kinetic.run_f32(&[&self.particles.vel, &self.particles.mass])?;
        Ok(out[0][0])
    }

    /// Serialize (pos, mass) for the ring exchange: the data another
    /// site needs to compute our gravity on its particles.
    pub fn exchange_block(&self) -> Vec<u8> {
        let cap = self.particles.pos.len() * 4 + self.particles.mass.len() * 4;
        let mut buf = Vec::with_capacity(cap);
        for v in &self.particles.pos {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.particles.mass {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf
    }

    /// Deserialize a peer's exchange block into (pos, mass).
    pub fn decode_block(buf: &[u8], n_pad: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            buf.len() == n_pad * 16,
            "exchange block size {} != {}",
            buf.len(),
            n_pad * 16
        );
        let read = |range: std::ops::Range<usize>| -> Vec<f32> {
            buf[range]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        Ok((read(0..n_pad * 12), read(n_pad * 12..n_pad * 16)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cosmogrid::domain::SiteParticles;

    #[test]
    fn exchange_block_roundtrip() {
        let mut sp = SiteParticles::empty(4);
        sp.pos[0] = 1.5;
        sp.pos[11] = -2.25;
        sp.mass[3] = 0.75;
        sp.n_local = 4;
        // fake a Site without PJRT: test the pure serialization directly
        let mut buf = Vec::new();
        for v in &sp.pos {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for v in &sp.mass {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let (pos, mass) = Site::decode_block(&buf, 4).unwrap();
        assert_eq!(pos, sp.pos);
        assert_eq!(mass, sp.mass);
    }

    #[test]
    fn decode_rejects_bad_size() {
        assert!(Site::decode_block(&[0u8; 10], 4).is_err());
    }
}
