//! The CosmoGrid application (paper §1.2.1, Figs 1–2): cosmological
//! N-body simulation distributed across supercomputers, coupled by
//! MPWide.
//!
//! The paper ran the GreeM TreePM code with 2048³ particles across up to
//! four supercomputers on dedicated 10 Gbit/s lightpaths; here the same
//! *system structure* runs at laptop scale (DESIGN.md §2): each "site" is
//! a coordinator thread owning its own PJRT runtime (L2/L1 AOT
//! artifacts: tiled Pallas all-pairs gravity + kick-drift integrator),
//! sites exchange particle blocks every step over **real MPWide paths**
//! in a ring, and the per-step wallclock/communication split is recorded
//! exactly as Fig 1 plots it. A single-site reference driver evaluates
//! the identical tile decomposition without the network (the teal line),
//! including the snapshot-write peaks.

pub mod domain;
pub mod sim;
pub mod site;
pub mod snapshot;

pub use domain::{generate_ics, rebalance, split_slabs, SiteParticles};
pub use sim::{
    run_distributed, run_single_site, DistributedReport, SimConfig, StepTiming,
};
