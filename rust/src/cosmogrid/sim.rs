//! CosmoGrid drivers: the distributed run (sites = threads, real MPWide
//! ring over loopback TCP, per-step compute/comm accounting — Fig 1's
//! red and black lines) and the single-site reference (same tile
//! decomposition, no network, snapshot-write peaks — the teal line).

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::domain::{generate_ics, rebalance, split_slabs, SiteParticles};
use super::site::Site;
use crate::mpwide::{Path, PathConfig, PathListener};

/// Configuration of a CosmoGrid run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Number of sites (supercomputers).
    pub sites: usize,
    /// Integration steps.
    pub steps: usize,
    /// Time step.
    pub dt: f32,
    /// Artifacts directory (contains `manifest.json`).
    pub artifacts_dir: PathBuf,
    /// TCP streams per inter-site path (paper: ≥32 over real WANs; the
    /// loopback default keeps tests fast).
    pub nstreams: usize,
    /// Steps at which the reference run writes a snapshot to disk (the
    /// two I/O peaks in Fig 1). Empty = never.
    pub snapshot_steps: Vec<usize>,
    /// Rebalance ownership every this many steps (0 = never).
    pub rebalance_every: usize,
    /// RNG seed for the initial conditions.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            sites: 3,
            steps: 20,
            dt: 1e-3,
            artifacts_dir: crate::runtime::Runtime::default_dir(),
            nstreams: 4,
            snapshot_steps: vec![],
            rebalance_every: 0,
            seed: 42,
        }
    }
}

/// Per-step timing record (the quantities Fig 1 plots).
#[derive(Debug, Clone, Copy)]
pub struct StepTiming {
    /// Step index.
    pub step: usize,
    /// Seconds in force evaluation + integration.
    pub compute: f64,
    /// Seconds in the inter-site exchange (0 for single-site).
    pub comm: f64,
    /// Seconds writing snapshots (0 unless a snapshot step).
    pub io: f64,
}

impl StepTiming {
    /// Total wallclock for the step.
    pub fn total(&self) -> f64 {
        self.compute + self.comm + self.io
    }
}

/// Result of a distributed run.
#[derive(Debug)]
pub struct DistributedReport {
    /// Per-step timings (max across sites — the step completes when the
    /// slowest site finishes, exactly how Fig 1 measures).
    pub timings: Vec<StepTiming>,
    /// Final particle state per site (for snapshots / validation).
    pub sites: Vec<SiteParticles>,
    /// Total bytes exchanged over MPWide.
    pub bytes_exchanged: u64,
}

/// Sum of per-step totals.
pub fn total_wallclock(timings: &[StepTiming]) -> f64 {
    timings.iter().map(|t| t.total()).sum()
}

/// Communication fraction of the run (§1.2.1 reports ~10%).
pub fn comm_fraction(timings: &[StepTiming]) -> f64 {
    let comm: f64 = timings.iter().map(|t| t.comm).sum();
    let total = total_wallclock(timings);
    if total > 0.0 {
        comm / total
    } else {
        0.0
    }
}

/// Single-site reference: all blocks evaluated in one process with the
/// identical tile decomposition (site-block × site-block), so the FLOP
/// count matches the distributed run exactly; `snapshot_steps` incur
/// real disk writes (the Fig 1 peaks).
pub fn run_single_site(cfg: &SimConfig) -> Result<(Vec<StepTiming>, Vec<SiteParticles>)> {
    let rt = crate::runtime::Runtime::open(&cfg.artifacts_dir)?;
    let n_pad = rt.manifest().config_usize("nbody_n")?;
    let total_particles = n_pad * cfg.sites;
    let (pos, vel, mass) = generate_ics(total_particles, cfg.seed);
    let counts = vec![n_pad; cfg.sites];
    let blocks = split_slabs(&pos, &vel, &mass, &counts, n_pad);

    let mut sites: Vec<Site> = blocks
        .into_iter()
        .enumerate()
        .map(|(i, b)| Site::new(i, &cfg.artifacts_dir, b))
        .collect::<Result<_>>()?;

    let snap_dir = std::env::temp_dir().join(format!("cosmogrid-ref-{}", std::process::id()));
    std::fs::create_dir_all(&snap_dir)?;

    let mut timings = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let t0 = Instant::now();
        // row i: acceleration of site i's block from every block j
        let mut accs: Vec<Vec<f32>> = Vec::with_capacity(cfg.sites);
        for i in 0..cfg.sites {
            let mut acc = vec![0.0f32; n_pad * 3];
            for j in 0..cfg.sites {
                let (src_pos, src_mass) =
                    (sites[j].particles.pos.clone(), sites[j].particles.mass.clone());
                let a = sites[i].accel_from(&src_pos, &src_mass)?;
                for (dst, s) in acc.iter_mut().zip(&a) {
                    *dst += s;
                }
            }
            accs.push(acc);
        }
        for (site, acc) in sites.iter_mut().zip(&accs) {
            site.step(acc, cfg.dt)?;
        }
        let compute = t0.elapsed().as_secs_f64();

        // snapshot peaks: a genuine disk write of the whole state
        let mut io = 0.0;
        if cfg.snapshot_steps.contains(&step) {
            let t_io = Instant::now();
            let mut blob = Vec::with_capacity(total_particles * 24 * 4);
            for s in &sites {
                blob.extend_from_slice(&s.exchange_block());
                // pad the write up to a meaningful size so the peak is
                // visible at laptop scale (the paper wrote 160 GB)
                blob.extend_from_slice(&vec![0u8; 4 << 20]);
            }
            std::fs::write(snap_dir.join(format!("snap{step}.dat")), &blob)?;
            io = t_io.elapsed().as_secs_f64();
        }
        timings.push(StepTiming { step, compute, comm: 0.0, io });
    }
    let _ = std::fs::remove_dir_all(&snap_dir);
    Ok((timings, sites.into_iter().map(|s| s.particles).collect()))
}

/// Distributed run: `cfg.sites` coordinator threads, each owning a PJRT
/// runtime, connected in a ring of real MPWide paths over loopback. Each
/// step does a ring all-gather of (pos, mass) blocks (`MPW_SendRecv`
/// semantics), accumulates cross-site gravity, and integrates.
pub fn run_distributed(cfg: &SimConfig) -> Result<DistributedReport> {
    let s = cfg.sites;
    anyhow::ensure!(s >= 2, "distributed run needs >= 2 sites");
    let rt = crate::runtime::Runtime::open(&cfg.artifacts_dir)?;
    let n_pad = rt.manifest().config_usize("nbody_n")?;
    drop(rt);
    let total_particles = n_pad * s;
    let (pos, vel, mass) = generate_ics(total_particles, cfg.seed);
    let counts = vec![n_pad; s];
    let blocks = split_slabs(&pos, &vel, &mass, &counts, n_pad);

    // ring wiring: site i listens; site i connects to site (i+1) % s
    let mut pcfg = PathConfig::with_streams(cfg.nstreams);
    pcfg.autotune = false; // loopback; keep path creation instant
    let mut listeners: Vec<PathListener> = (0..s)
        .map(|_| PathListener::bind(0, pcfg.clone()))
        .collect::<crate::mpwide::Result<_>>()
        .context("binding ring listeners")?;
    let ports: Vec<u16> = listeners.iter().map(|l| l.port()).collect();

    let (tx, rx) = mpsc::channel::<Result<SiteReport>>();
    std::thread::scope(|scope| {
        for (rank, (block, mut listener)) in
            blocks.into_iter().zip(listeners.drain(..)).enumerate()
        {
            let tx = tx.clone();
            let cfg = cfg.clone();
            let next_port = ports[(rank + 1) % s];
            scope.spawn(move || {
                let r = run_site(rank, block, &mut listener, next_port, &cfg, n_pad);
                let _ = tx.send(r);
            });
        }
        drop(tx);
    });

    let mut reports: Vec<SiteReport> = Vec::with_capacity(s);
    for r in rx.iter() {
        reports.push(r?);
    }
    anyhow::ensure!(reports.len() == s, "lost site reports");
    reports.sort_by_key(|r| r.rank);

    // per-step: the step finishes when the slowest site does
    let steps = reports[0].timings.len();
    let mut timings = Vec::with_capacity(steps);
    for k in 0..steps {
        let compute =
            reports.iter().map(|r| r.timings[k].compute).fold(0.0f64, f64::max);
        let comm = reports.iter().map(|r| r.timings[k].comm).fold(0.0f64, f64::max);
        timings.push(StepTiming { step: k, compute, comm, io: 0.0 });
    }
    let bytes = reports.iter().map(|r| r.bytes).sum();
    Ok(DistributedReport {
        timings,
        sites: reports.into_iter().map(|r| r.particles).collect(),
        bytes_exchanged: bytes,
    })
}

struct SiteReport {
    rank: usize,
    timings: Vec<StepTiming>,
    particles: SiteParticles,
    bytes: u64,
}

fn run_site(
    rank: usize,
    block: SiteParticles,
    listener: &mut PathListener,
    next_port: u16,
    cfg: &SimConfig,
    n_pad: usize,
) -> Result<SiteReport> {
    let s = cfg.sites;
    let mut site = Site::new(rank, &cfg.artifacts_dir, block)?;

    // connect to the next site while accepting from the previous — both
    // concurrently, or the ring deadlocks
    let mut pcfg = PathConfig::with_streams(cfg.nstreams);
    pcfg.autotune = false;
    let (path_next, path_prev) = std::thread::scope(
        |sc| -> Result<(Path, Path)> {
            let connect = sc.spawn(|| Path::connect("127.0.0.1", next_port, pcfg.clone()));
            let prev = listener.accept_path()?;
            let next = connect.join().expect("connect thread")?;
            Ok((next, prev))
        },
    )?;

    let mut timings = Vec::with_capacity(cfg.steps);
    let mut bytes = 0u64;
    let mut times_buf: Vec<f64> = vec![0.0; s];

    for step in 0..cfg.steps {
        // local gravity
        let t_c0 = Instant::now();
        let mut acc = site.self_accel()?;
        let mut compute = t_c0.elapsed().as_secs_f64();

        // ring all-gather: pass blocks around s-1 times (MPW_SendRecv)
        let mut block = site.exchange_block();
        let mut comm = 0.0;
        for _ in 1..s {
            let t_x0 = Instant::now();
            let mut incoming = vec![0u8; block.len()];
            // send to next while receiving from prev — concurrent, or the
            // ring deadlocks once blocks outgrow socket buffers
            std::thread::scope(|sc| -> Result<()> {
                let tx = sc.spawn(|| path_next.send(&block));
                path_prev.recv(&mut incoming)?;
                tx.join().expect("ring send thread")?;
                Ok(())
            })?;
            comm += t_x0.elapsed().as_secs_f64();
            bytes += block.len() as u64;

            let t_c = Instant::now();
            let (rpos, rmass) = Site::decode_block(&incoming, n_pad)?;
            let a = site.accel_from(&rpos, &rmass)?;
            for (dst, sa) in acc.iter_mut().zip(&a) {
                *dst += sa;
            }
            compute += t_c.elapsed().as_secs_f64();
            block = incoming;
        }

        let t_c1 = Instant::now();
        site.step(&acc, cfg.dt)?;
        compute += t_c1.elapsed().as_secs_f64();

        // optional load-balance bookkeeping (counts are equal in this
        // driver, but the rule is exercised and reported)
        if cfg.rebalance_every > 0 && step % cfg.rebalance_every == cfg.rebalance_every - 1 {
            times_buf[rank] = compute;
            let counts = vec![site.particles.n_local; s];
            let _proposal = rebalance(&counts, &times_buf, 1, n_pad);
        }

        timings.push(StepTiming { step, compute, comm, io: 0.0 });
    }
    Ok(SiteReport { rank, timings, particles: site.particles, bytes })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_fraction_math() {
        let t = vec![
            StepTiming { step: 0, compute: 0.9, comm: 0.1, io: 0.0 },
            StepTiming { step: 1, compute: 0.8, comm: 0.2, io: 0.0 },
        ];
        assert!((total_wallclock(&t) - 2.0).abs() < 1e-12);
        assert!((comm_fraction(&t) - 0.15).abs() < 1e-12);
        assert_eq!(comm_fraction(&[]), 0.0);
    }

    // PJRT-backed end-to-end runs live in rust/tests/apps_end_to_end.rs.
}
