//! Domain decomposition and initial conditions for the CosmoGrid run:
//! slab split by x-coordinate across sites, plus the dynamic
//! load-balancing rule (the paper's distributed run "also features
//! dynamic load balancing").

use crate::util::Rng;

/// Particle block owned by one site. Arrays are padded to the artifact
/// size `n_pad` with zero-mass particles (padded sources contribute no
/// force; padded targets are ignored on readout), so the fixed-shape AOT
/// executables accept any ownership count ≤ `n_pad`.
#[derive(Debug, Clone)]
pub struct SiteParticles {
    /// Flat (n_pad, 3) positions.
    pub pos: Vec<f32>,
    /// Flat (n_pad, 3) velocities.
    pub vel: Vec<f32>,
    /// (n_pad,) masses; zero beyond `n_local`.
    pub mass: Vec<f32>,
    /// Number of real particles in this block.
    pub n_local: usize,
    /// Padded size (the artifact's N).
    pub n_pad: usize,
}

impl SiteParticles {
    /// Empty block of padded size `n_pad`.
    pub fn empty(n_pad: usize) -> SiteParticles {
        SiteParticles {
            pos: vec![0.0; n_pad * 3],
            vel: vec![0.0; n_pad * 3],
            mass: vec![0.0; n_pad],
            n_local: 0,
            n_pad,
        }
    }

    /// Total momentum of the real particles (diagnostics).
    pub fn momentum(&self) -> [f32; 3] {
        let mut p = [0.0f32; 3];
        for i in 0..self.n_local {
            for d in 0..3 {
                p[d] += self.mass[i] * self.vel[i * 3 + d];
            }
        }
        p
    }
}

/// Generate initial conditions: `n` particles in a unit cube around the
/// origin with a cold Hubble-like perturbation (radially outward velocity
/// plus small noise) — enough structure for slabs and snapshots to be
/// visually meaningful at laptop scale.
pub fn generate_ics(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut pos = Vec::with_capacity(n * 3);
    let mut vel = Vec::with_capacity(n * 3);
    let mut mass = Vec::with_capacity(n);
    for _ in 0..n {
        let p: [f64; 3] = [rng.f64() - 0.5, rng.f64() - 0.5, rng.f64() - 0.5];
        for d in 0..3 {
            pos.push(p[d] as f32);
            // mild expansion + noise; kept small so the cube stays bound
            vel.push((0.05 * p[d] + 0.01 * rng.gauss()) as f32);
        }
        mass.push((1.0 / n as f64) as f32);
    }
    (pos, vel, mass)
}

/// Split particles into `counts.len()` slabs by x-coordinate with the
/// given per-site counts (must sum to the particle count). Returns the
/// per-site blocks padded to `n_pad`.
pub fn split_slabs(
    pos: &[f32],
    vel: &[f32],
    mass: &[f32],
    counts: &[usize],
    n_pad: usize,
) -> Vec<SiteParticles> {
    let n = mass.len();
    assert_eq!(counts.iter().sum::<usize>(), n, "counts must cover all particles");
    assert!(counts.iter().all(|&c| c <= n_pad), "count exceeds artifact size");
    // order by x so slabs are spatially contiguous (Fig 2's colour bands)
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| pos[a * 3].partial_cmp(&pos[b * 3]).unwrap());

    let mut out = Vec::with_capacity(counts.len());
    let mut cursor = 0;
    for &c in counts {
        let mut sp = SiteParticles::empty(n_pad);
        for (slot, &idx) in order[cursor..cursor + c].iter().enumerate() {
            for d in 0..3 {
                sp.pos[slot * 3 + d] = pos[idx * 3 + d];
                sp.vel[slot * 3 + d] = vel[idx * 3 + d];
            }
            sp.mass[slot] = mass[idx];
        }
        sp.n_local = c;
        out.push(sp);
        cursor += c;
    }
    out
}

/// Dynamic load balancing: given current per-site particle counts and
/// measured per-step compute times, propose new counts that equalize
/// time assuming cost ∝ count (all-pairs row cost). Deterministic, sums
/// preserved, each site keeps at least `min_count` and at most `max_count`.
pub fn rebalance(
    counts: &[usize],
    times: &[f64],
    min_count: usize,
    max_count: usize,
) -> Vec<usize> {
    assert_eq!(counts.len(), times.len());
    let total: usize = counts.iter().sum();
    // per-particle speed of each site; target counts ∝ speed
    let speeds: Vec<f64> = counts
        .iter()
        .zip(times)
        .map(|(&c, &t)| if t > 1e-12 { c as f64 / t } else { c as f64 })
        .collect();
    let speed_sum: f64 = speeds.iter().sum();
    if speed_sum <= 0.0 {
        return counts.to_vec();
    }
    let mut new: Vec<usize> = speeds
        .iter()
        .map(|s| ((s / speed_sum) * total as f64).round() as usize)
        .map(|c| c.clamp(min_count, max_count))
        .collect();
    // fix the sum drift deterministically
    let mut diff = total as i64 - new.iter().sum::<usize>() as i64;
    let mut i = 0;
    while diff != 0 {
        let idx = i % new.len();
        if diff > 0 && new[idx] < max_count {
            new[idx] += 1;
            diff -= 1;
        } else if diff < 0 && new[idx] > min_count {
            new[idx] -= 1;
            diff += 1;
        }
        i += 1;
        if i > 10 * new.len() * (total + 1) {
            return counts.to_vec(); // infeasible clamp box; keep as-is
        }
    }
    new
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ics_are_reproducible_and_in_cube() {
        let (p1, v1, m1) = generate_ics(100, 9);
        let (p2, _, _) = generate_ics(100, 9);
        assert_eq!(p1, p2);
        assert!(p1.iter().all(|x| x.abs() <= 0.5));
        assert_eq!(v1.len(), 300);
        assert!((m1.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn slabs_are_ordered_by_x_and_cover_everything() {
        let (pos, vel, mass) = generate_ics(90, 3);
        let slabs = split_slabs(&pos, &vel, &mass, &[30, 30, 30], 128);
        assert_eq!(slabs.len(), 3);
        let mut total_mass = 0.0f32;
        for s in &slabs {
            assert_eq!(s.n_local, 30);
            total_mass += s.mass.iter().sum::<f32>();
        }
        assert!((total_mass - 1.0).abs() < 1e-4);
        // slab boundaries: max x of slab i <= min x of slab i+1
        for w in slabs.windows(2) {
            let max0 = (0..w[0].n_local).map(|i| w[0].pos[i * 3]).fold(f32::MIN, f32::max);
            let min1 = (0..w[1].n_local).map(|i| w[1].pos[i * 3]).fold(f32::MAX, f32::min);
            assert!(max0 <= min1);
        }
    }

    #[test]
    fn padding_has_zero_mass() {
        let (pos, vel, mass) = generate_ics(10, 4);
        let slabs = split_slabs(&pos, &vel, &mass, &[10], 32);
        assert!(slabs[0].mass[10..].iter().all(|&m| m == 0.0));
    }

    #[test]
    #[should_panic(expected = "counts must cover all particles")]
    fn split_rejects_bad_counts() {
        let (pos, vel, mass) = generate_ics(10, 4);
        split_slabs(&pos, &vel, &mass, &[4, 4], 32);
    }

    #[test]
    fn rebalance_moves_work_to_fast_sites() {
        // site 1 is twice as fast per particle → should gain particles
        let new = rebalance(&[100, 100], &[2.0, 1.0], 10, 1000);
        assert_eq!(new.iter().sum::<usize>(), 200);
        assert!(new[1] > new[0], "{new:?}");
    }

    #[test]
    fn rebalance_is_stable_when_balanced() {
        let new = rebalance(&[100, 100, 100], &[1.0, 1.0, 1.0], 10, 1000);
        assert_eq!(new, vec![100, 100, 100]);
    }

    #[test]
    fn rebalance_respects_bounds_and_sum() {
        let new = rebalance(&[100, 100], &[100.0, 1.0], 80, 120);
        assert_eq!(new.iter().sum::<usize>(), 200);
        assert!(new.iter().all(|&c| (80..=120).contains(&c)), "{new:?}");
    }

    #[test]
    fn momentum_diag() {
        let mut sp = SiteParticles::empty(4);
        sp.n_local = 1;
        sp.mass[0] = 2.0;
        sp.vel[0..3].copy_from_slice(&[1.0, 0.0, -1.0]);
        assert_eq!(sp.momentum(), [2.0, 0.0, -2.0]);
    }
}
