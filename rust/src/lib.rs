//! # MPWide — light-weight message passing over wide area networks
//!
//! Reproduction of *MPWide: a light-weight library for efficient message
//! passing over wide area networks* (Groen, Rieder, Portegies Zwart, JORS
//! 2013, DOI 10.5334/jors.ah) as a three-layer Rust + JAX + Pallas stack.
//!
//! * [`mpwide`] — the library itself: communication **paths** made of 1–256
//!   parallel TCP streams, chunked + paced sends, TCP window tuning, a
//!   creation-time autotuner plus an online adaptive tuner (live
//!   restriping as WAN conditions drift), dynamic-size messaging,
//!   non-blocking operations, relays, and a C-style facade mirroring the
//!   paper's Table 2 API.
//! * [`netsim`] — a flow-level discrete-event TCP simulator standing in for
//!   the paper's wide-area testbeds (see DESIGN.md §2), with link profiles
//!   named after the paper's endpoint pairs.
//! * [`baselines`] — models of the comparator tools from the paper's
//!   evaluation (scp, ZeroMQ, MUSCLE 1, Aspera).
//! * [`tools`] — the shipped utilities: Forwarder, mpw-cp, DataGather and
//!   the MPWTest two-endpoint benchmark.
//! * [`runtime`] — PJRT CPU client loading AOT-compiled JAX/Pallas payloads
//!   (`artifacts/*.hlo.txt`); python never runs on the request path.
//! * [`cosmogrid`] / [`bloodflow`] — the paper's two distributed
//!   applications (§1.2.1, §1.2.2), rebuilt at laptop scale on top of the
//!   runtime and coordinated over MPWide paths.
//! * [`benchlib`] — a minimal measurement harness used by `cargo bench`
//!   targets (one per paper table/figure).

pub mod baselines;
pub mod benchlib;
pub mod bloodflow;
pub mod cli;
pub mod cosmogrid;
pub mod mpwide;
pub mod netsim;
pub mod runtime;
pub mod tools;
pub mod util;
