//! Summary statistics for measurement series (benchlib, experiment reports).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation; 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.len() == 1 {
        return v[0];
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min of a series; 0.0 for empty input.
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Max of a series; 0.0 for empty input.
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138).abs() < 0.01, "{s}");
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn max_basic() {
        assert_eq!(max(&[1.0, 5.0, 2.0]), 5.0);
        assert_eq!(max(&[]), 0.0);
    }
}
