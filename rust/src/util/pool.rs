//! Global task pool with scoped execution (§Perf change 1).
//!
//! `Path::send`/`recv` originally spawned one OS thread per stream per
//! operation — measured at ~26 MB/s for 64 KB messages over 16 streams
//! (thread spawn ≈ 10–20 µs each, dwarfing the copy). This pool keeps
//! workers alive between operations and **grows on demand**: if a job is
//! submitted and no worker is idle, a new worker is spawned (up to a
//! generous cap). Growth-on-demand is load-bearing for correctness, not
//! just speed: jobs block on socket I/O that may depend on *other* jobs
//! (the peer's recv), so a fixed-size pool could deadlock.
//!
//! [`scope`] runs a batch of possibly-borrowing closures and blocks
//! until all complete, so borrows never outlive the call — the same
//! contract as `std::thread::scope`, minus the per-call spawns.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, OnceLock};

use crate::util::lockorder::{rank, OrderedCondvar, OrderedMutex};

/// Upper bound on pool size — a backstop against runaway growth, far
/// above what the test-suite/benches need concurrently.
const MAX_WORKERS: usize = 1024;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolInner {
    jobs: VecDeque<Job>,
    idle: usize,
    workers: usize,
}

struct Pool {
    pool_st: OrderedMutex<PoolInner>,
    cv: OrderedCondvar,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        pool_st: OrderedMutex::new(rank::POOL, PoolInner { jobs: VecDeque::new(), idle: 0, workers: 0 }),
        cv: OrderedCondvar::new(),
    })
}

fn worker_loop() {
    let p = pool();
    let mut g = p.pool_st.lock();
    loop {
        if let Some(job) = g.jobs.pop_front() {
            drop(g);
            job();
            g = p.pool_st.lock();
        } else {
            g.idle += 1;
            g = p.cv.wait(g);
            g.idle -= 1;
        }
    }
}

fn submit(job: Job) {
    let p = pool();
    let mut g = p.pool_st.lock();
    g.jobs.push_back(job);
    if g.idle == 0 && g.workers < MAX_WORKERS {
        g.workers += 1;
        std::thread::Builder::new()
            .name("mpwide-pool".into())
            .spawn(worker_loop)
            .expect("spawn pool worker");
    }
    p.cv.notify_one();
}

struct ScopeState {
    remaining: OrderedMutex<usize>,
    panicked: OrderedMutex<Option<String>>,
    done: OrderedCondvar,
}

impl ScopeState {
    fn new(n: usize) -> ScopeState {
        ScopeState {
            remaining: OrderedMutex::new(rank::POOL_SCOPE, n),
            panicked: OrderedMutex::new(rank::POOL_SCOPE, None),
            done: OrderedCondvar::new(),
        }
    }
}

/// Run `jobs` on the pool, blocking until every one has completed.
/// Closures may borrow from the caller's stack (the wait guarantees the
/// borrows end before `scope` returns). Panics inside a job are caught
/// and re-raised here.
pub fn scope<'env>(jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
    if jobs.is_empty() {
        return;
    }
    // Fast path: a single job runs inline — no handoff, no wakeup.
    let n = jobs.len();
    let state = Arc::new(ScopeState::new(n));
    for job in jobs {
        // SAFETY: the closure may borrow data with lifetime 'env, which
        // outlives this function call; we block below until every job
        // has run to completion, so the borrow never escapes 'env. This
        // is the same argument std::thread::scope makes, applied to a
        // pool. The transmute only erases the lifetime parameter of the
        // trait object; the layout is identical.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
        };
        let state = state.clone();
        submit(Box::new(move || {
            let r = catch_unwind(AssertUnwindSafe(job));
            if let Err(p) = r {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "job panicked".into());
                *state.panicked.lock() = Some(msg);
            }
            let mut rem = state.remaining.lock();
            *rem -= 1;
            if *rem == 0 {
                state.done.notify_all();
            }
        }));
    }
    let mut rem = state.remaining.lock();
    while *rem > 0 {
        rem = state.done.wait(rem);
    }
    drop(rem);
    let panicked = state.panicked.lock().take();
    if let Some(msg) = panicked {
        panic!("pool job panicked: {msg}");
    }
}

/// Like [`scope`] but additionally runs `inline` on the *calling* thread
/// concurrently with the pooled jobs (saves one handoff for the common
/// "one send job + inline receive" pattern), returning its value.
pub fn scope_with_inline<'env, R>(
    jobs: Vec<Box<dyn FnOnce() + Send + 'env>>,
    inline: impl FnOnce() -> R,
) -> R {
    if jobs.is_empty() {
        return inline();
    }
    let n = jobs.len();
    let state = Arc::new(ScopeState::new(n));
    for job in jobs {
        // SAFETY: identical argument to `scope` — we block below until
        // every job completed, so 'env borrows cannot escape.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
        };
        let state = state.clone();
        submit(Box::new(move || {
            let r = catch_unwind(AssertUnwindSafe(job));
            if let Err(p) = r {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "job panicked".into());
                *state.panicked.lock() = Some(msg);
            }
            let mut rem = state.remaining.lock();
            *rem -= 1;
            if *rem == 0 {
                state.done.notify_all();
            }
        }));
    }
    let out = inline();
    let mut rem = state.remaining.lock();
    while *rem > 0 {
        rem = state.done.wait(rem);
    }
    drop(rem);
    let panicked = state.panicked.lock().take();
    if let Some(msg) = panicked {
        panic!("pool job panicked: {msg}");
    }
    out
}

/// Current pool size (diagnostics/tests).
pub fn workers() -> usize {
    pool().pool_st.lock().workers
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Condvar, Mutex};

    #[test]
    fn scope_runs_all_jobs_with_borrows() {
        let mut results = vec![0usize; 16];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = results
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| Box::new(move || *slot = i * i) as Box<dyn FnOnce() + Send>)
            .collect();
        scope(jobs);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r, i * i);
        }
    }

    #[test]
    fn scope_reuses_workers() {
        // warm up
        scope(vec![Box::new(|| {})]);
        let before = workers();
        for _ in 0..50 {
            scope(vec![Box::new(|| {}), Box::new(|| {})]);
        }
        let after = workers();
        assert!(after <= before + 4, "pool kept growing: {before} -> {after}");
    }

    #[test]
    fn interdependent_blocking_jobs_complete() {
        // job A blocks until job B runs — requires growth on demand
        let flag = Arc::new((Mutex::new(false), Condvar::new()));
        let f1 = flag.clone();
        let f2 = flag.clone();
        scope(vec![
            Box::new(move || {
                let (m, cv) = &*f1;
                let mut g = m.lock();
                while !*g {
                    g = cv.wait(g).unwrap();
                }
            }),
            Box::new(move || {
                let (m, cv) = &*f2;
                *m.lock() = true;
                cv.notify_all();
            }),
        ]);
    }

    #[test]
    #[should_panic(expected = "pool job panicked")]
    fn job_panic_propagates() {
        scope(vec![Box::new(|| panic!("boom"))]);
    }

    #[test]
    fn heavy_concurrency() {
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..200)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        scope(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 200);
    }
}
