//! Mini property-testing harness (offline substitute for `proptest`).
//!
//! Runs a property over many seeded-random cases; on failure, reports the
//! seed and case index so the exact counterexample is reproducible, and
//! performs a simple size-shrinking pass when the generator supports it.

use super::rng::Rng;

/// Run `cases` random trials of `property`. The property receives a fresh
/// deterministic RNG per case; returning `Err(msg)` fails the test with the
/// seed printed so it can be replayed.
pub fn check<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_seeded(name, 0xC0FFEE, cases, &mut property);
}

/// Like [`check`] but with an explicit base seed (used to replay failures).
pub fn check_seeded<F>(name: &str, base_seed: u64, cases: usize, property: &mut F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay with seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Generate a random message size spanning the interesting regimes:
/// empty, tiny (< chunk), chunk-boundary ±1, and multi-megabyte.
pub fn message_size(rng: &mut Rng, chunk: usize) -> usize {
    match rng.urange(0, 6) {
        0 => 0,
        1 => rng.urange(1, 64),
        2 => chunk.saturating_sub(1) + rng.urange(0, 3), // straddle the chunk boundary
        3 => rng.urange(1, 4 * chunk + 2),
        4 => rng.urange(1, 1 << 20),
        _ => rng.urange(1, 8 << 20),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |rng| {
            let x = rng.urange(0, 100);
            if x < 100 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |_rng| Err("nope".into()));
    }

    #[test]
    fn message_size_hits_regimes() {
        let mut rng = Rng::new(1);
        let mut saw_zero = false;
        let mut saw_big = false;
        for _ in 0..500 {
            let s = message_size(&mut rng, 1024);
            if s == 0 {
                saw_zero = true;
            }
            if s > 1 << 20 {
                saw_big = true;
            }
        }
        assert!(saw_zero && saw_big);
    }
}
