//! Deterministic pseudo-random number generator (SplitMix64).
//!
//! Used everywhere randomness is needed — the network simulator's loss
//! draws, workload generation, property tests — so that every experiment in
//! EXPERIMENTS.md is exactly reproducible from its seed.

/// SplitMix64 PRNG. Small state, passes BigCrush, and is trivially seedable,
/// which is all we need (this is not used for anything security-relevant).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn urange(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and fine
    /// for jitter modelling).
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a byte slice with pseudo-random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let v = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&v[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.urange(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(5);
        assert!(!r.chance(0.0));
        assert!(r.chance(1.0));
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(9);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        // Overwhelmingly unlikely to still be all zeros.
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn gauss_mean_and_var() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
