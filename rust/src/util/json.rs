//! Minimal JSON parser (offline substitute for `serde_json`), used to read
//! the artifact manifest emitted by `python/compile/aot.py`.
//!
//! Supports the full JSON value grammar; numbers are parsed as `f64`
//! (sufficient for the manifest: shapes, tolerances and f32 validation
//! vectors).

use std::collections::HashMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element access.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    /// As f64.
    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integral number).
    pub fn usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As str.
    pub fn str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object map.
    pub fn obj(&self) -> Option<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: an array of numbers as `Vec<f32>`.
    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        self.arr()?.iter().map(|v| v.num().map(|n| n as f32)).collect()
    }

    /// Convenience: an array of numbers as `Vec<usize>`.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (fast path for big arrays)
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c\n"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().at(0).unwrap().num(), Some(1.0));
        assert_eq!(j.get("a").unwrap().at(2).unwrap().get("b").unwrap().str(), Some("c\n"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn f32_vec_helper() {
        let j = Json::parse("[1.5, 2, -3]").unwrap();
        assert_eq!(j.f32_vec().unwrap(), vec![1.5, 2.0, -3.0]);
        assert!(Json::parse("[1, \"x\"]").unwrap().f32_vec().is_none());
    }

    #[test]
    fn usize_vec_helper() {
        let j = Json::parse("[1024, 3]").unwrap();
        assert_eq!(j.usize_vec().unwrap(), vec![1024, 3]);
        assert!(Json::parse("[1.5]").unwrap().usize_vec().is_none());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap().str(), Some("A"));
    }

    #[test]
    fn big_float_array_roundtrip() {
        let src: Vec<f32> = (0..1000).map(|i| i as f32 * 0.25 - 100.0).collect();
        let text = format!(
            "[{}]",
            src.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
        );
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.f32_vec().unwrap(), src);
    }
}
