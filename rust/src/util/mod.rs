//! Small self-contained utilities: deterministic RNG, statistics, byte
//! formatting, a mini property-testing harness and the lock-order
//! discipline wrappers.
//!
//! The build environment is offline, so the usual crates (`rand`,
//! `proptest`, `criterion`) are unavailable; these modules provide the
//! minimal, well-tested subset the rest of the codebase needs.

pub mod bytes;
pub mod json;
pub mod lockorder;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

pub use bytes::{human_bytes, human_rate};
pub use lockorder::{OrderedCondvar, OrderedGuard, OrderedMutex};
pub use rng::Rng;
