//! Lock-order discipline: ranked mutex/condvar wrappers for the
//! concurrent core.
//!
//! Every lock in `mpwide::{path, resilience, mux, transport, api}` is an
//! [`OrderedMutex`] carrying a **rank** from the global hierarchy in
//! [`rank`]. The invariant: a thread may only acquire a lock whose rank
//! is **greater than or equal to** the highest rank it already holds
//! (equal ranks cover sibling instances such as per-stream slots, which
//! are never nested on one thread). Any two threads that both respect
//! the hierarchy cannot deadlock on these locks, because a deadlock
//! cycle needs at least one edge from a higher rank to a strictly lower
//! one.
//!
//! **Debug builds** keep a per-thread stack of held locks and panic on
//! the spot when an acquisition would invert the hierarchy (or re-enter
//! a lock the thread already holds — a guaranteed self-deadlock with
//! `std::sync::Mutex`). **Release builds** compile every check out and
//! delegate straight to `std::sync` — the rank metadata is two words per
//! mutex and the hot path is exactly a `Mutex::lock`.
//!
//! The hierarchy itself — which rank belongs to which lock and why the
//! order is what it is — is documented in `docs/CONCURRENCY.md`. Keep
//! the two in sync.
//!
//! # Poisoning policy
//!
//! `lock()` returns the guard directly, not a `LockResult`. A poisoned
//! lock (some thread panicked inside the critical section) panics with
//! the lock's rank name. This is deliberate: a panic mid-update may
//! have left shared state torn, and limping on would convert a loud
//! failure into silent corruption — the same policy as the
//! `.lock().unwrap()` idiom this wrapper replaced, minus ~400 unwrap
//! sites. Threads that must survive a sibling's panic (the pool
//! workers) catch it at the job boundary, before any shared lock is
//! reacquired.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// The global lock-rank hierarchy, outermost (lowest rank) first.
///
/// A thread holding a lock of rank `r` may only acquire locks of rank
/// `>= r`. The full rationale lives in `docs/CONCURRENCY.md`; the short
/// form: ranks follow the call graph from the API facade down through
/// path orchestration into per-stream state and finally the in-memory
/// transport queues.
pub mod rank {
    /// Test-harness serialization locks (outermost; test code only).
    pub const TEST_HARNESS: u16 = 0;
    /// The API facade's global context registry (`api::Context`).
    pub const API_CTX: u16 = 10;
    /// Mux endpoint state (`mux::MuxInner::st`). Held while failing the
    /// path (`shutdown_all_streams` → stream meta), hence above the
    /// context but below everything path-internal.
    pub const MUX_STATE: u16 = 20;
    /// Rejoin registry map (`resilience::RejoinRegistry`). Never held
    /// across a reinstall — lookups release before path surgery.
    pub const REJOIN_REGISTRY: u16 = 25;
    /// A path's send gate (one striped send at a time).
    pub const SEND_GATE: u16 = 30;
    /// A path's receive gate (one striped receive at a time).
    pub const RECV_GATE: u16 = 31;
    /// The windowed-send bookkeeping (`resilience::SendWindow::st`),
    /// held across post/reap while gated sends touch stream state.
    pub const SEND_WINDOW: u16 = 40;
    /// Peer-advertised send credit (`resilience::SendCredit::st`).
    /// Acquired from the windowed sender (while SEND_WINDOW is held) and
    /// from ACK/WINDOW_UPDATE absorption; never held across I/O.
    pub const SEND_CREDIT: u16 = 41;
    /// Stream-health synchronization (`path::HealthState::sync`): death
    /// marking, reinstall, zero-live waits.
    pub const HEALTH: u16 = 50;
    /// The path's mutable config snapshot (`Path::cfg`).
    pub const PATH_CFG: u16 = 60;
    /// The runtime reconnect policy (`Path::reconnect`).
    pub const RECONNECT_POLICY: u16 = 61;
    /// The remembered remote endpoint (`Path::remote`).
    pub const PATH_REMOTE: u16 = 62;
    /// The handshake-agreed path uuid (`Path::uuid`).
    pub const PATH_UUID: u16 = 63;
    /// The adaptive controller (`Path::controller`).
    pub const CONTROLLER: u16 = 70;
    /// A stream slot's write half (`StreamSlot::tx`).
    pub const STREAM_TX: u16 = 80;
    /// A stream slot's read half (`StreamSlot::rx`).
    pub const STREAM_RX: u16 = 81;
    /// A stream slot's metadata (fd, kill switch).
    pub const STREAM_META: u16 = 82;
    /// Parked-frame inboxes (`resilience::FrameBox`), taken while the
    /// owning stream's rx half is held.
    pub const FRAME_INBOX: u16 = 90;
    /// The windowed receiver's reorder buffer (`resilience::ReorderBuf`).
    pub const RECV_REORDER: u16 = 91;
    /// ACK watchdog state (`resilience::WdShared`), armed from send
    /// paths that hold the gate/window locks.
    pub const ACK_WATCHDOG: u16 = 95;
    /// In-memory transport queues (`transport::{Chan, DelayChan}`) —
    /// innermost library lock: taken from inside stream tx/rx writes,
    /// reads and kill-switch firing.
    pub const MEM_CHAN: u16 = 100;
    /// Worker-pool job queue (`util::pool`). Ranks above every library
    /// lock: `submit` is called while callers hold gate/window locks,
    /// and pooled jobs never lock it back (they drop the guard before
    /// running the job).
    pub const POOL: u16 = 110;
    /// Per-`scope` completion state (`util::pool::ScopeState`). Locked
    /// by pooled workers after a job's own guards are dropped, and by
    /// the scoping caller while it drains the batch.
    pub const POOL_SCOPE: u16 = 111;

    /// Human-readable name of a rank, for violation diagnostics.
    pub fn name(rank: u16) -> &'static str {
        match rank {
            TEST_HARNESS => "TEST_HARNESS",
            API_CTX => "API_CTX",
            MUX_STATE => "MUX_STATE",
            REJOIN_REGISTRY => "REJOIN_REGISTRY",
            SEND_GATE => "SEND_GATE",
            RECV_GATE => "RECV_GATE",
            SEND_WINDOW => "SEND_WINDOW",
            SEND_CREDIT => "SEND_CREDIT",
            HEALTH => "HEALTH",
            PATH_CFG => "PATH_CFG",
            RECONNECT_POLICY => "RECONNECT_POLICY",
            PATH_REMOTE => "PATH_REMOTE",
            PATH_UUID => "PATH_UUID",
            CONTROLLER => "CONTROLLER",
            STREAM_TX => "STREAM_TX",
            STREAM_RX => "STREAM_RX",
            STREAM_META => "STREAM_META",
            FRAME_INBOX => "FRAME_INBOX",
            RECV_REORDER => "RECV_REORDER",
            ACK_WATCHDOG => "ACK_WATCHDOG",
            MEM_CHAN => "MEM_CHAN",
            POOL => "POOL",
            POOL_SCOPE => "POOL_SCOPE",
            _ => "UNNAMED",
        }
    }
}

#[cfg(debug_assertions)]
mod held {
    use std::cell::RefCell;

    thread_local! {
        /// Locks this thread currently holds, in acquisition order. The
        /// hierarchy check keeps ranks nondecreasing, so the last entry
        /// is always the maximum.
        static HELD: RefCell<Vec<(u16, usize)>> = const { RefCell::new(Vec::new()) };
    }

    /// Panic if acquiring `(rank, addr)` now would invert the hierarchy
    /// or re-enter an already-held lock. Does **not** record the lock —
    /// call [`push`] once the acquisition actually succeeded.
    pub fn check(rank: u16, addr: usize) {
        HELD.with(|h| {
            let v = h.borrow();
            if v.iter().any(|&(_, a)| a == addr) {
                panic!(
                    "lock-order violation: thread re-entered {} lock it already \
                     holds (guaranteed self-deadlock); see docs/CONCURRENCY.md",
                    super::rank::name(rank)
                );
            }
            if let Some(&(top, _)) = v.last() {
                if rank < top {
                    panic!(
                        "lock-order violation: acquiring {} (rank {rank}) while \
                         holding {} (rank {top}); see docs/CONCURRENCY.md",
                        super::rank::name(rank),
                        super::rank::name(top)
                    );
                }
            }
        });
    }

    pub fn push(rank: u16, addr: usize) {
        HELD.with(|h| h.borrow_mut().push((rank, addr)));
    }

    pub fn pop(addr: usize) {
        HELD.with(|h| {
            let mut v = h.borrow_mut();
            if let Some(i) = v.iter().rposition(|&(_, a)| a == addr) {
                v.remove(i);
            }
        });
    }
}

/// A mutex with a declared rank in the global hierarchy. See the module
/// docs for the invariant and the poisoning policy.
pub struct OrderedMutex<T: ?Sized> {
    rank: u16,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// Wrap `value` in a mutex of rank `rank` (a [`rank`] constant).
    pub const fn new(rank: u16, value: T) -> OrderedMutex<T> {
        OrderedMutex { rank, inner: Mutex::new(value) }
    }

    /// Acquire the lock, enforcing the rank hierarchy in debug builds.
    /// Panics if the lock is poisoned (see the module docs).
    pub fn lock(&self) -> OrderedGuard<'_, T> {
        let addr = self as *const OrderedMutex<T> as *const () as usize;
        #[cfg(debug_assertions)]
        held::check(self.rank, addr);
        let inner = match self.inner.lock() {
            Ok(g) => g,
            Err(_) => panic!(
                "{} lock poisoned: a thread panicked while holding it",
                rank::name(self.rank)
            ),
        };
        #[cfg(debug_assertions)]
        held::push(self.rank, addr);
        OrderedGuard { inner: Some(inner), addr }
    }

    /// The lock's declared rank.
    pub fn rank(&self) -> u16 {
        self.rank
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("rank", &rank::name(self.rank))
            .field("inner", &self.inner)
            .finish()
    }
}

impl<T: Default> Default for OrderedMutex<T> {
    /// A defaulted lock lands on the innermost rank ([`rank::MEM_CHAN`])
    /// so it can never mask a violation on real hierarchy locks; the
    /// concurrent core always names its rank explicitly.
    fn default() -> OrderedMutex<T> {
        OrderedMutex::new(rank::MEM_CHAN, T::default())
    }
}

/// RAII guard of an [`OrderedMutex`]; releasing it pops the lock from
/// the thread's held stack.
///
/// The inner `Option` is only ever `None` transiently inside
/// [`OrderedCondvar::wait`]/[`wait_timeout`], which own the guard for
/// the duration — no external code can observe that state.
///
/// [`wait_timeout`]: OrderedCondvar::wait_timeout
pub struct OrderedGuard<'a, T: ?Sized> {
    inner: Option<MutexGuard<'a, T>>,
    #[allow(dead_code)] // release builds: kept so Drop stays uniform
    addr: usize,
}

impl<T: ?Sized> std::ops::Deref for OrderedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            Some(g) => g,
            None => unreachable!("guard emptied outside a condvar wait"),
        }
    }
}

impl<T: ?Sized> std::ops::DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            Some(g) => g,
            None => unreachable!("guard emptied outside a condvar wait"),
        }
    }
}

impl<T: ?Sized> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        held::pop(self.addr);
    }
}

/// Condition variable paired with [`OrderedMutex`]. The blocked thread
/// keeps its slot on the held-rank stack across the wait: it cannot
/// acquire anything while parked, and it owns the mutex again the
/// moment `wait` returns.
///
/// Like [`OrderedMutex::lock`], the wait methods panic on poisoning
/// instead of returning a `LockResult` (same policy, same rationale).
pub struct OrderedCondvar {
    inner: Condvar,
}

impl OrderedCondvar {
    /// A fresh condvar.
    pub const fn new() -> OrderedCondvar {
        OrderedCondvar { inner: Condvar::new() }
    }

    /// Park until notified; the guard is released for the duration and
    /// re-acquired before returning.
    pub fn wait<'a, T>(&self, mut guard: OrderedGuard<'a, T>) -> OrderedGuard<'a, T> {
        let Some(inner) = guard.inner.take() else {
            unreachable!("guard emptied outside a condvar wait")
        };
        match self.inner.wait(inner) {
            Ok(g) => guard.inner = Some(g),
            Err(_) => panic!("lock poisoned while parked in a condvar wait"),
        }
        guard
    }

    /// [`wait`](OrderedCondvar::wait) with a timeout.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: OrderedGuard<'a, T>,
        dur: Duration,
    ) -> (OrderedGuard<'a, T>, WaitTimeoutResult) {
        let Some(inner) = guard.inner.take() else {
            unreachable!("guard emptied outside a condvar wait")
        };
        match self.inner.wait_timeout(inner, dur) {
            Ok((g, timed_out)) => {
                guard.inner = Some(g);
                (guard, timed_out)
            }
            Err(_) => panic!("lock poisoned while parked in a condvar wait"),
        }
    }

    /// Wake one parked waiter.
    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    /// Wake every parked waiter.
    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

impl Default for OrderedCondvar {
    fn default() -> OrderedCondvar {
        OrderedCondvar::new()
    }
}

impl std::fmt::Debug for OrderedCondvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedCondvar").finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn in_order_acquisition_passes() {
        let outer = OrderedMutex::new(rank::SEND_GATE, 1u32);
        let inner = OrderedMutex::new(rank::STREAM_TX, 2u32);
        let a = outer.lock();
        let b = inner.lock();
        assert_eq!(*a + *b, 3);
    }

    #[test]
    fn equal_rank_siblings_pass() {
        // Same rank, different instances (e.g. two stream slots probed
        // sequentially) is allowed; only strict inversions are bugs.
        let s0 = OrderedMutex::new(rank::STREAM_TX, ());
        let s1 = OrderedMutex::new(rank::STREAM_TX, ());
        let _a = s0.lock();
        let _b = s1.lock();
    }

    #[test]
    fn reacquire_after_release_passes() {
        let m = OrderedMutex::new(rank::HEALTH, 0u8);
        drop(m.lock());
        drop(m.lock());
    }

    #[cfg(debug_assertions)]
    #[test]
    fn rank_inversion_panics_in_debug() {
        let outer = Arc::new(OrderedMutex::new(rank::STREAM_TX, ()));
        let inner = Arc::new(OrderedMutex::new(rank::SEND_GATE, ()));
        // a fresh thread: catch_unwind must not leave this test thread's
        // held stack carrying the panicking acquisition
        let t = std::thread::spawn(move || {
            let _g = outer.lock();
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _h = inner.lock(); // SEND_GATE while holding STREAM_TX
            }))
            .is_err()
        });
        assert!(t.join().expect("probe thread"), "inversion must panic in debug builds");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn reentry_panics_in_debug() {
        let m = Arc::new(OrderedMutex::new(rank::HEALTH, ()));
        let t = std::thread::spawn(move || {
            let _g = m.lock();
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _h = m.lock(); // self-deadlock, caught before blocking
            }))
            .is_err()
        });
        assert!(t.join().expect("probe thread"), "re-entry must panic in debug builds");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn violation_unwinds_cleanly() {
        // After a caught violation the thread's held stack must be
        // intact: the failed acquisition was never pushed, and further
        // in-order locking works.
        let outer = OrderedMutex::new(rank::HEALTH, ());
        let inner = OrderedMutex::new(rank::SEND_GATE, ());
        let deeper = OrderedMutex::new(rank::STREAM_RX, ());
        let g = outer.lock();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _h = inner.lock();
        }));
        assert!(r.is_err());
        let _d = deeper.lock(); // still fine: HEALTH -> STREAM_RX
        drop(g);
        let _again = inner.lock(); // and SEND_GATE alone is fine too
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn release_builds_pass_through() {
        // Checks compile out: an out-of-order acquisition is silent.
        let outer = OrderedMutex::new(rank::STREAM_TX, ());
        let inner = OrderedMutex::new(rank::SEND_GATE, ());
        let _g = outer.lock();
        let _h = inner.lock();
    }

    #[test]
    fn condvar_roundtrip_keeps_guard_usable() {
        let m = Arc::new(OrderedMutex::new(rank::HEALTH, false));
        let cv = Arc::new(OrderedCondvar::new());
        let (m2, cv2) = (m.clone(), cv.clone());
        let t = std::thread::spawn(move || {
            let mut g = m2.lock();
            *g = true;
            drop(g);
            cv2.notify_all();
        });
        let mut g = m.lock();
        while !*g {
            let (g2, _) = cv.wait_timeout(g, Duration::from_millis(50));
            g = g2;
        }
        assert!(*g);
        drop(g);
        t.join().expect("notifier");
        // the guard returned by the wait still pops its stack slot: a
        // subsequent lower-rank acquisition on this thread is legal
        let outer = OrderedMutex::new(rank::SEND_GATE, ());
        let _o = outer.lock();
    }

    #[test]
    fn guard_derefs_both_ways() {
        let m = OrderedMutex::new(rank::PATH_CFG, vec![1, 2, 3]);
        {
            let mut g = m.lock();
            g.push(4);
        }
        assert_eq!(m.lock().len(), 4);
    }
}
