//! Human-readable byte and rate formatting for reports and CLI output.

/// Format a byte count, e.g. `64.0 MB`. Uses SI-ish binary steps of 1024 but
/// MB/GB labels, matching how the paper reports sizes (64MB, 256MB, 160GB).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a rate in bytes/second as `MB/s` (the paper's unit in Table 1).
pub fn human_rate(bytes_per_sec: f64) -> String {
    format!("{:.1} MB/s", bytes_per_sec / (1024.0 * 1024.0))
}

/// Convenience: MB (binary) to bytes.
pub const fn mb(n: u64) -> u64 {
    n * 1024 * 1024
}

/// Convenience: KB (binary) to bytes.
pub const fn kb(n: u64) -> u64 {
    n * 1024
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_small() {
        assert_eq!(human_bytes(512), "512 B");
    }

    #[test]
    fn bytes_mb() {
        assert_eq!(human_bytes(mb(64)), "64.0 MB");
    }

    #[test]
    fn rate_mbs() {
        assert_eq!(human_rate(70.0 * 1024.0 * 1024.0), "70.0 MB/s");
    }

    #[test]
    fn consts() {
        assert_eq!(kb(1), 1024);
        assert_eq!(mb(1), 1024 * 1024);
    }
}
