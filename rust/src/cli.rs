//! Tiny CLI argument parser (offline substitute for `clap`): positional
//! arguments plus `--key value` / `--flag` options.

use std::collections::HashMap;

/// Parsed command line: subcommand, positionals, options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First token (subcommand).
    pub command: String,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// `--key value` and bare `--flag` (value `"true"`) options.
    pub options: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut it = tokens.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                options.insert(key.to_string(), value);
            } else {
                positional.push(tok);
            }
        }
        Args { command, positional, options }
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Positional argument by index.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(String::as_str)
    }

    /// Option value as string.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Option parsed as `T`, with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Bare flag presence.
    pub fn flag(&self, key: &str) -> bool {
        self.opt(key).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn parses_subcommand_and_positionals() {
        let a = parse("cp file.bin localhost");
        assert_eq!(a.command, "cp");
        assert_eq!(a.pos(0), Some("file.bin"));
        assert_eq!(a.pos(1), Some("localhost"));
        assert_eq!(a.pos(2), None);
    }

    #[test]
    fn parses_options_and_flags() {
        let a = parse("serve --port 6000 --streams 32 --verbose");
        assert_eq!(a.opt_parse("port", 0u16), 6000);
        assert_eq!(a.opt_parse("streams", 1usize), 32);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn option_default_applies() {
        let a = parse("serve");
        assert_eq!(a.opt_parse("port", 7777u16), 7777);
    }

    #[test]
    fn empty_input() {
        let a = parse("");
        assert_eq!(a.command, "");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b 3");
        assert_eq!(a.opt("a"), Some("true"));
        assert_eq!(a.opt_parse("b", 0), 3);
    }
}
