//! Model drivers for the two coupled codes, each executing its AOT
//! artifact on a thread-local PJRT runtime.

use std::path::Path as FsPath;

use anyhow::Result;

use crate::runtime::{Executable, Runtime};

/// The 1-D arterial network (pyNS analog): pressure/flow on a vessel,
/// inlet driven by a heart waveform, outlet coupled to the 3-D code.
pub struct Flow1d {
    /// Pressure along the vessel.
    pub p: Vec<f32>,
    /// Flow rate along the vessel.
    pub q: Vec<f32>,
    exe: Executable,
    /// Interface values (coupling payload): [pressure, flow] at the
    /// distal end after the last step.
    pub iface: [f32; 2],
    step_count: u64,
}

impl Flow1d {
    /// Load the artifact and start from rest.
    pub fn new(artifacts_dir: &FsPath) -> Result<Flow1d> {
        let rt = Runtime::open(artifacts_dir)?;
        let m = rt.manifest().config_usize("flow1d_m")?;
        Ok(Flow1d {
            p: vec![0.0; m],
            q: vec![0.0; m],
            exe: rt.load("flow1d_step")?,
            iface: [0.0; 2],
            step_count: 0,
        })
    }

    /// Heart inlet waveform (periodic pulse).
    pub fn inlet(&self) -> f32 {
        let t = self.step_count as f32 * 0.05;
        1.0 + 0.5 * (t).sin()
    }

    /// One solver step with the outlet pressure received from the 3-D
    /// code; updates the interface payload.
    pub fn step(&mut self, outlet_pressure: f32) -> Result<()> {
        let bc = [self.inlet(), outlet_pressure];
        let out = self.exe.run_f32(&[&self.p, &self.q, &bc])?;
        let mut it = out.into_iter();
        self.p = it.next().unwrap();
        self.q = it.next().unwrap();
        let iface = it.next().unwrap();
        self.iface = [iface[0], iface[1]];
        self.step_count += 1;
        Ok(())
    }
}

/// The 3-D flow solver (HemeLB analog): relaxation on a cube with the
/// inlet plane driven by the 1-D interface pressure.
pub struct Flow3d {
    /// The 3-D field, flat (d, d, d).
    pub u: Vec<f32>,
    /// Grid extent.
    pub d: usize,
    exe: Executable,
    /// Outlet value (coupling payload) after the last step.
    pub outlet: f32,
}

impl Flow3d {
    /// Load the artifact and start from rest.
    pub fn new(artifacts_dir: &FsPath) -> Result<Flow3d> {
        let rt = Runtime::open(artifacts_dir)?;
        let d = rt.manifest().config_usize("flow3d_d")?;
        Ok(Flow3d { u: vec![0.0; d * d * d], d, exe: rt.load("flow3d_step")?, outlet: 0.0 })
    }

    /// One relaxation sweep with the inlet plane set from the received
    /// 1-D interface pressure.
    pub fn step(&mut self, inlet_pressure: f32) -> Result<()> {
        let plane = vec![inlet_pressure; self.d * self.d];
        let out = self.exe.run_f32(&[&self.u, &plane])?;
        let mut it = out.into_iter();
        self.u = it.next().unwrap();
        self.outlet = it.next().unwrap()[0];
        Ok(())
    }
}

// PJRT-backed tests live in rust/tests/apps_end_to_end.rs.
