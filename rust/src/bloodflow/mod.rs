//! The distributed multiscale bloodflow application (paper §1.2.2,
//! Fig 3): a 1-D arterial-network model (pyNS analog, on "a local desktop
//! at UCL") coupled to a 3-D flow solver (HemeLB analog, on HECToR's
//! compute nodes) through an MPWide **Forwarder** on the front-end —
//! compute nodes cannot accept inbound connections, so both codes dial
//! the forwarder.
//!
//! The coupling exchanges boundary values at a fixed cadence; the paper
//! achieves 6 ms of overhead per exchange (1.2 % of runtime) over an
//! 11 ms round-trip by hiding latency with non-blocking exchanges
//! (`MPW_ISendRecv`), which [`coupling`] reproduces with real sockets and
//! a real delay-injecting forwarder.

pub mod coupling;
pub mod models;

pub use coupling::{run_coupled, CouplingConfig, CouplingReport};
pub use models::{Flow1d, Flow3d};
