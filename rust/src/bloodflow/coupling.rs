//! The coupled run (paper §1.2.2): 1-D and 3-D codes exchanging boundary
//! values through a Forwarder, with optional latency hiding via
//! `MPW_ISendRecv`.
//!
//! Topology (paper Fig 3): both codes **connect** to the forwarder (the
//! HECToR compute nodes cannot accept inbound connections); the
//! forwarder relays. The forwarder injects a configurable one-way delay
//! so the paper's 11 ms round-trip is reproduced over real sockets.
//!
//! Latency hiding: each side posts the boundary exchange, computes its
//! sub-steps with the previous boundary values, and only then waits —
//! the coupling overhead per exchange is the *residual* wait time, which
//! the paper measured at 6 ms (1.2 % of runtime).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::models::{Flow1d, Flow3d};
use crate::mpwide::nonblocking::{NbeHandle, NbeOp};
use crate::mpwide::{Path, PathConfig};
use crate::tools::forwarder;

/// Configuration of the coupled run.
#[derive(Debug, Clone)]
pub struct CouplingConfig {
    /// Number of coupling exchanges (the paper's run exchanged every
    /// 0.6 s of simulated time).
    pub exchanges: usize,
    /// 3-D solver sub-steps between exchanges (compute available for
    /// latency hiding on the measured side).
    pub substeps: usize,
    /// 1-D solver sub-steps between exchanges. The 1-D step is far
    /// cheaper; give it more sub-steps so the two codes are comparably
    /// paced per coupling interval (as the paper's were).
    pub substeps_1d: usize,
    /// Hide latency with non-blocking exchanges (`MPW_ISendRecv`) or
    /// block on every exchange (the ablation).
    pub latency_hiding: bool,
    /// One-way delay injected per forwarder hop. The paper's UCL–HECToR
    /// link has an 11 ms round trip; each exchange crosses the forwarder
    /// once per direction, so 5.5 ms per hop reproduces it.
    pub hop_delay: Option<Duration>,
    /// Artifacts directory.
    pub artifacts_dir: PathBuf,
}

impl Default for CouplingConfig {
    fn default() -> Self {
        CouplingConfig {
            exchanges: 50,
            substeps: 12,
            substeps_1d: 24,
            latency_hiding: true,
            hop_delay: Some(Duration::from_micros(5500)),
            artifacts_dir: crate::runtime::Runtime::default_dir(),
        }
    }
}

/// Measured outcome of a coupled run. The primary overhead numbers are
/// taken on the **3-D side** — the paper measured the coupling overhead
/// of the heavy code (HemeLB on 2048 cores), whose blocked time is the
/// quantity latency hiding is supposed to shrink. The 1-D side's wait is
/// also reported; being the cheaper code, it spends most of its time
/// waiting for the 3-D side regardless of hiding.
#[derive(Debug, Clone)]
pub struct CouplingReport {
    /// Exchanges performed.
    pub exchanges: usize,
    /// Total wallclock of the 3-D side, seconds.
    pub total_seconds: f64,
    /// Seconds the 3-D side spent blocked on communication.
    pub comm_wait_seconds: f64,
    /// Mean blocked time per exchange on the 3-D side (paper: ~6 ms).
    pub overhead_per_exchange: f64,
    /// Blocked share of the 3-D side's runtime (paper: 1.2 %).
    pub overhead_fraction: f64,
    /// Mean blocked time per exchange on the 1-D side.
    pub desktop_wait_per_exchange: f64,
    /// Final outlet pressure (physics sanity).
    pub final_outlet: f32,
    /// Final 1-D interface pressure.
    pub final_iface_p: f32,
}

/// Boundary payloads: f32 LE encodings.
fn encode_f32s(vals: &[f32]) -> Vec<u8> {
    let mut v = Vec::with_capacity(vals.len() * 4);
    for x in vals {
        v.extend_from_slice(&x.to_le_bytes());
    }
    v
}

fn decode_f32(buf: &[u8], idx: usize) -> f32 {
    f32::from_le_bytes(buf[idx * 4..idx * 4 + 4].try_into().unwrap())
}

/// Run the coupled simulation; returns the 1-D ("desktop") side's report.
pub fn run_coupled(cfg: &CouplingConfig) -> Result<CouplingReport> {
    // Fig 3: the forwarder lives on the reachable front-end
    let (port, fwd_handle) = forwarder::spawn(1, cfg.hop_delay)?;

    let mut pcfg = PathConfig::with_streams(1);
    pcfg.autotune = false;

    // 3-D side (HemeLB on the compute nodes) — the measured side
    let cfg3 = cfg.clone();
    let pcfg3 = pcfg.clone();
    let hpc = std::thread::spawn(move || -> Result<(f32, f64, f64)> {
        let path = Arc::new(Path::connect("127.0.0.1", port, pcfg3)?);
        let mut model = Flow3d::new(&cfg3.artifacts_dir)?;
        let mut inlet_pressure = 0.0f32;
        let t_total = Instant::now();
        let mut comm_wait = 0.0f64;
        for _ in 0..cfg3.exchanges {
            if cfg3.latency_hiding {
                // post the exchange, compute, then wait only for the residue
                let h = NbeHandle::start(
                    path.clone(),
                    NbeOp::DSendRecv(encode_f32s(&[model.outlet])),
                );
                for _ in 0..cfg3.substeps {
                    model.step(inlet_pressure)?;
                }
                let t_w = Instant::now();
                let got = h.wait()?.expect("dsendrecv returns payload");
                comm_wait += t_w.elapsed().as_secs_f64();
                inlet_pressure = decode_f32(&got, 0);
            } else {
                let t_w = Instant::now();
                let mut cache = Vec::new();
                path.dsend_recv(&encode_f32s(&[model.outlet]), &mut cache)?;
                comm_wait += t_w.elapsed().as_secs_f64();
                inlet_pressure = decode_f32(&cache, 0);
                for _ in 0..cfg3.substeps {
                    model.step(inlet_pressure)?;
                }
            }
        }
        Ok((model.outlet, comm_wait, t_total.elapsed().as_secs_f64()))
    });

    // 1-D side (pyNS on the desktop): cheap, always ready early
    let path = Arc::new(
        Path::connect("127.0.0.1", port, pcfg).context("1-D side connecting to forwarder")?,
    );
    let mut model = Flow1d::new(&cfg.artifacts_dir)?;
    let mut outlet_pressure = 0.0f32;
    let mut desktop_wait = 0.0f64;
    for _ in 0..cfg.exchanges {
        if cfg.latency_hiding {
            let h = NbeHandle::start(
                path.clone(),
                NbeOp::DSendRecv(encode_f32s(&[model.iface[0], model.iface[1]])),
            );
            for _ in 0..cfg.substeps_1d {
                model.step(outlet_pressure)?;
            }
            let t_w = Instant::now();
            let got = h.wait()?.expect("dsendrecv returns payload");
            desktop_wait += t_w.elapsed().as_secs_f64();
            outlet_pressure = decode_f32(&got, 0);
        } else {
            let t_w = Instant::now();
            let mut cache = Vec::new();
            path.dsend_recv(&encode_f32s(&[model.iface[0], model.iface[1]]), &mut cache)?;
            desktop_wait += t_w.elapsed().as_secs_f64();
            outlet_pressure = decode_f32(&cache, 0);
            for _ in 0..cfg.substeps_1d {
                model.step(outlet_pressure)?;
            }
        }
    }

    let (final_outlet, comm_wait, total) = hpc.join().expect("3-D thread")?;
    drop(path);
    let _ = fwd_handle.join();

    Ok(CouplingReport {
        exchanges: cfg.exchanges,
        total_seconds: total,
        comm_wait_seconds: comm_wait,
        overhead_per_exchange: comm_wait / cfg.exchanges as f64,
        overhead_fraction: if total > 0.0 { comm_wait / total } else { 0.0 },
        desktop_wait_per_exchange: desktop_wait / cfg.exchanges as f64,
        final_outlet,
        final_iface_p: model.iface[0],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_codec_roundtrip() {
        let buf = encode_f32s(&[1.5, -2.25]);
        assert_eq!(buf.len(), 8);
        assert_eq!(decode_f32(&buf, 0), 1.5);
        assert_eq!(decode_f32(&buf, 1), -2.25);
    }

    #[test]
    fn default_hop_delay_gives_11ms_rtt() {
        let cfg = CouplingConfig::default();
        assert_eq!(cfg.hop_delay.unwrap() * 2, Duration::from_millis(11));
    }

    // Full coupled runs (PJRT + sockets + forwarder) live in
    // rust/tests/apps_end_to_end.rs and the bloodflow_overhead bench.
}
