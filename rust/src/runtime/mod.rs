//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas payloads.
//!
//! The compile path (`python/compile/aot.py`, run once by `make
//! artifacts`) lowers each L2 model to HLO **text**; this module loads the
//! text (`HloModuleProto::from_text_file` — the text parser reassigns the
//! 64-bit instruction ids jax ≥ 0.5 emits, which xla_extension 0.5.1's
//! proto path rejects), compiles it on the PJRT CPU client, and exposes a
//! typed `run_f32` entry point for the coordinator's hot path. Python is
//! never invoked at runtime.

pub mod manifest;
// Offline stand-in for the real `xla` PJRT bindings: same API, every
// entry point errors. Delete this declaration and add the real crate
// dependency to re-enable PJRT execution; no call sites change.
mod xla;

use std::path::Path as FsPath;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ArtifactMeta, Manifest, TensorSpec};

/// A PJRT client plus the artifact manifest.
///
/// **Threading note:** the underlying `xla` crate wrappers are `Rc`-based
/// and not `Send`; create one `Runtime` per coordinator thread (each
/// CosmoGrid "site" owns its own client — which also mirrors the real
/// deployment, where every site is a separate process on a different
/// machine).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: std::path::PathBuf,
}

impl Runtime {
    /// Open the artifacts directory (produced by `make artifacts`) on the
    /// PJRT CPU client.
    pub fn open(dir: impl AsRef<FsPath>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, dir })
    }

    /// Default artifacts directory: `$MPWIDE_ARTIFACTS` or `./artifacts`
    /// (searched upward from the current directory so tests and examples
    /// work from any workspace subdirectory).
    pub fn default_dir() -> std::path::PathBuf {
        if let Ok(d) = std::env::var("MPWIDE_ARTIFACTS") {
            return d.into();
        }
        let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                return "artifacts".into();
            }
        }
    }

    /// The manifest describing every artifact.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile one artifact by name (e.g. `"nbody_accel"`).
    pub fn load(&self, name: &str) -> Result<Executable> {
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Executable { exe: Rc::new(exe), meta })
    }
}

/// A compiled artifact ready to execute. Cheap to clone within a thread
/// (shares the underlying PJRT executable); not `Send` — see [`Runtime`].
#[derive(Clone)]
pub struct Executable {
    exe: Rc<xla::PjRtLoadedExecutable>,
    meta: ArtifactMeta,
}

impl Executable {
    /// The artifact's manifest entry (shapes, validation data).
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Execute with f32 inputs laid out per the manifest. Checks element
    /// counts, feeds the PJRT executable, unwraps the output tuple and
    /// returns each output as a flat `Vec<f32>`.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                self.meta.file,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&self.meta.inputs) {
            if data.len() != spec.elems() {
                bail!(
                    "artifact {} input {:?} expects {} elements, got {}",
                    self.meta.file,
                    spec.shape,
                    spec.elems(),
                    data.len()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.meta.file))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("untuple result: {e:?}"))?;
        if tuple.len() != self.meta.outputs.len() {
            bail!(
                "artifact {} declared {} outputs, produced {}",
                self.meta.file,
                self.meta.outputs.len(),
                tuple.len()
            );
        }
        let mut out = Vec::with_capacity(tuple.len());
        for (lit, spec) in tuple.into_iter().zip(&self.meta.outputs) {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("read output: {e:?}"))?;
            if v.len() != spec.elems() {
                bail!("output expects {} elements, got {}", spec.elems(), v.len());
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Run the manifest's validation case and compare against the
    /// jax-computed expected outputs. Returns the max relative error seen.
    pub fn validate(&self) -> Result<f64> {
        let v = &self.meta.validation;
        let inputs: Vec<&[f32]> = v.inputs.iter().map(|x| x.as_slice()).collect();
        let outputs = self.run_f32(&inputs)?;
        let mut max_rel = 0.0f64;
        for (got, want) in outputs.iter().zip(&v.outputs) {
            if got.len() != want.len() {
                bail!("validation output length mismatch");
            }
            for (&g, &w) in got.iter().zip(want) {
                let (g, w) = (g as f64, w as f64);
                let tol = v.atol + v.rtol * w.abs();
                let err = (g - w).abs();
                if err > tol {
                    bail!(
                        "validation mismatch in {}: got {g}, want {w} (tol {tol})",
                        self.meta.file
                    );
                }
                let rel = err / (w.abs() + 1e-12);
                if rel > max_rel {
                    max_rel = rel;
                }
            }
        }
        Ok(max_rel)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/runtime_artifacts.rs (they need
    // `make artifacts` to have run). Here: pure path logic.
    use super::*;

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("MPWIDE_ARTIFACTS", "/tmp/somewhere");
        assert_eq!(Runtime::default_dir(), std::path::PathBuf::from("/tmp/somewhere"));
        std::env::remove_var("MPWIDE_ARTIFACTS");
    }
}
