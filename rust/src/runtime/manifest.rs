//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (shapes, file names, validation vectors).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape/dtype of one tensor crossing the AOT boundary (f32 only — the
/// paper's data-type stance applies: MPWide itself treats all payloads as
/// byte arrays; the numeric contract lives here, at the artifact level).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Seeded inputs + jax-computed outputs for numeric validation of the
/// PJRT round-trip.
#[derive(Debug, Clone)]
pub struct Validation {
    pub inputs: Vec<Vec<f32>>,
    pub outputs: Vec<Vec<f32>>,
    pub rtol: f64,
    pub atol: f64,
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub validation: Validation,
}

/// The whole manifest: artifact registry plus the export configuration
/// (particle counts, grid sizes) the applications need.
#[derive(Debug, Clone)]
pub struct Manifest {
    artifacts: HashMap<String, ArtifactMeta>,
    config: HashMap<String, f64>,
}

impl Manifest {
    /// Load and parse `manifest.json`.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let mut config = HashMap::new();
        for (k, v) in j.get("config").and_then(Json::obj).ok_or_else(|| anyhow!("no config"))? {
            config.insert(k.clone(), v.num().ok_or_else(|| anyhow!("config {k} not num"))?);
        }
        let mut artifacts = HashMap::new();
        for (name, a) in
            j.get("artifacts").and_then(Json::obj).ok_or_else(|| anyhow!("no artifacts"))?
        {
            artifacts.insert(name.clone(), Self::parse_artifact(name, a)?);
        }
        Ok(Manifest { artifacts, config })
    }

    fn parse_artifact(name: &str, a: &Json) -> Result<ArtifactMeta> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            a.get(key)
                .and_then(Json::arr)
                .ok_or_else(|| anyhow!("{name}: no {key}"))?
                .iter()
                .map(|s| {
                    Ok(TensorSpec {
                        shape: s
                            .get("shape")
                            .and_then(Json::usize_vec)
                            .ok_or_else(|| anyhow!("{name}: bad shape"))?,
                    })
                })
                .collect()
        };
        let v = a.get("validation").ok_or_else(|| anyhow!("{name}: no validation"))?;
        let vecs = |key: &str| -> Result<Vec<Vec<f32>>> {
            v.get(key)
                .and_then(Json::arr)
                .ok_or_else(|| anyhow!("{name}: no validation.{key}"))?
                .iter()
                .map(|x| x.f32_vec().ok_or_else(|| anyhow!("{name}: bad validation array")))
                .collect()
        };
        Ok(ArtifactMeta {
            file: a
                .get("file")
                .and_then(Json::str)
                .ok_or_else(|| anyhow!("{name}: no file"))?
                .to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            validation: Validation {
                inputs: vecs("inputs")?,
                outputs: vecs("outputs")?,
                rtol: v.get("rtol").and_then(Json::num).unwrap_or(1e-3),
                atol: v.get("atol").and_then(Json::num).unwrap_or(1e-5),
            },
        })
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.get(name)
    }

    /// All artifact names (sorted, for deterministic iteration).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Export-config value (e.g. `nbody_n`, `flow3d_d`).
    pub fn config(&self, key: &str) -> Option<f64> {
        self.config.get(key).copied()
    }

    /// Export-config value as usize, erroring with context if missing.
    pub fn config_usize(&self, key: &str) -> Result<usize> {
        self.config(key)
            .map(|v| v as usize)
            .ok_or_else(|| anyhow!("manifest config key '{key}' missing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "config": {"nbody_n": 8, "flow3d_d": 4},
        "artifacts": {
            "toy": {
                "file": "toy.hlo.txt",
                "inputs": [{"shape": [2, 3], "dtype": "f32"}],
                "outputs": [{"shape": [2], "dtype": "f32"}],
                "validation": {
                    "inputs": [[1, 2, 3, 4, 5, 6]],
                    "outputs": [[6, 15]],
                    "rtol": 0.001,
                    "atol": 0.0001
                }
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.names(), vec!["toy"]);
        assert_eq!(m.config_usize("nbody_n").unwrap(), 8);
        let a = m.artifact("toy").unwrap();
        assert_eq!(a.file, "toy.hlo.txt");
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].elems(), 6);
        assert_eq!(a.validation.outputs[0], vec![6.0, 15.0]);
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"config": {}, "artifacts": {"x": {}}}"#).is_err());
    }

    #[test]
    fn unknown_artifact_is_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_none());
        assert!(m.config("nope").is_none());
        assert!(m.config_usize("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Exercised fully in rust/tests/runtime_artifacts.rs; here only if
        // the artifacts have been built.
        let dir = crate::runtime::Runtime::default_dir();
        let path = dir.join("manifest.json");
        if path.exists() {
            let m = Manifest::load(path).unwrap();
            assert!(m.artifact("nbody_accel").is_some());
            assert_eq!(m.config_usize("nbody_n").unwrap(), 1024);
        }
    }
}
