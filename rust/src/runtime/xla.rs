//! Offline stub of the `xla` PJRT bindings.
//!
//! The real `xla` crate (PJRT CPU client + HLO text loader) is not
//! available in this build environment, so this module provides the
//! exact API surface [`super`] uses, with every entry point returning a
//! "PJRT unavailable" error. [`PjRtClient::cpu`] fails first, so
//! `Runtime::open` reports the situation up front and everything
//! downstream (CosmoGrid / bloodflow drivers, the artifact tests) skips
//! cleanly when no PJRT backend is present — the same behaviour those
//! tests already have when `make artifacts` has not run.
//!
//! Swapping the real bindings back in is a one-line change in
//! `runtime/mod.rs` (delete the `mod xla;` declaration and add the crate
//! dependency); no call sites change.

use std::path::Path;

/// Error type mirroring the binding layer's (`Debug`-formatted at every
/// call site in [`super`]).
#[derive(Debug)]
pub struct Error(pub String);

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT runtime unavailable: built with the offline xla stub (see rust/src/runtime/xla.rs)"
            .into(),
    ))
}

/// Stub of the PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub: there is no PJRT backend to open.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Unreachable in practice (no client can exist), present for API
    /// parity.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails in the stub.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    /// API-parity constructor.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always fails in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always fails in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    /// API-parity constructor (the data never reaches a device).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Always fails in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    /// Always fails in the stub.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    /// Always fails in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal.to_tuple().is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        let err = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(err.contains("PJRT runtime unavailable"), "{err}");
    }
}
