//! Models of the comparator tools in the paper's evaluation (Table 1 and
//! §1.2.3): scp, ZeroMQ, MUSCLE 1 and Aspera.
//!
//! Each model runs on the **same** flow-level TCP simulator and link
//! profiles as the MPWide [`crate::netsim::SimPath`]; what differs is
//! only the mechanism the paper credits/blames for each tool's
//! performance:
//!
//! * **scp** — one TCP flow, further throttled by OpenSSH's channel
//!   window (a protocol-level cap independent of the kernel's) and a
//!   crypto/cipher CPU ceiling.
//! * **ZeroMQ** — one TCP flow with default autotuned kernel windows
//!   (the paper used "default autotuned settings"); fast on a clean
//!   direction, collapses with loss (single congestion context).
//! * **MUSCLE 1** — one TCP flow behind a Java serialization pipeline:
//!   an application-level rate ceiling that binds before the network
//!   does (its 18/18 row is symmetric because the bottleneck is the CPU).
//! * **Aspera** — closed-source UDP transfer with delay/loss-insensitive
//!   rate control: modeled as a ramp to a target rate near the link's
//!   available capacity, degraded only by the loss fraction itself.

use crate::netsim::link::{Direction, LinkProfile};
use crate::netsim::network::{transfer_oneway, OneWayResult};
use crate::netsim::simpath::OS_AUTOSCALE_RWND;

/// OpenSSH channel window (protocol flow control; ~1 MB effective in the
/// era's releases once application-level draining is accounted for) —
/// scp's binding window even when kernels would autoscale.
pub const SSH_CHANNEL_WINDOW: f64 = 768.0 * 1024.0;

/// scp cipher/MAC/disk pipeline ceiling on era hardware, bytes/second
/// (scp reads from file and encrypts synchronously).
pub const SCP_CRYPTO_CAP: f64 = 34.0 * 1024.0 * 1024.0;

/// Rounds scp's application layer stays head-of-line blocked after each
/// TCP loss event (the ssh channel stalls on retransmission).
pub const SCP_LOSS_STALL: u32 = 4;

/// MUSCLE 1 serialization ceiling, bytes/second (the paper's 18/18 row).
pub const MUSCLE_SERIALIZE_CAP: f64 = 19.0 * 1024.0 * 1024.0;

/// Aspera's achievable fraction of available capacity (protocol
/// efficiency of its UDP rate control).
pub const ASPERA_EFFICIENCY: f64 = 0.90;

/// scp: single flow, SSH channel window + crypto cap + application-level
/// stall after loss events.
pub fn scp_transfer(link: &LinkProfile, dir: Direction, bytes: u64, seed: u64) -> OneWayResult {
    use crate::netsim::network::simulate_oneway;
    use crate::netsim::tcp_model::TcpFlow;
    use crate::util::Rng;
    let mut rng = Rng::new(seed);
    let mut flows = vec![TcpFlow::new(bytes as f64, SSH_CHANNEL_WINDOW, Some(SCP_CRYPTO_CAP))
        .with_loss_stall(SCP_LOSS_STALL)];
    simulate_oneway(&mut flows, link, dir, &mut rng, false)
}

/// ZeroMQ (default autotuned settings): single flow, kernel-autoscaled
/// window, no app cap.
pub fn zeromq_transfer(
    link: &LinkProfile,
    dir: Direction,
    bytes: u64,
    seed: u64,
) -> OneWayResult {
    transfer_oneway(link, dir, bytes as f64, 1, OS_AUTOSCALE_RWND, None, seed)
}

/// MUSCLE 1: single flow behind the serialization ceiling.
pub fn muscle_transfer(
    link: &LinkProfile,
    dir: Direction,
    bytes: u64,
    seed: u64,
) -> OneWayResult {
    transfer_oneway(
        link,
        dir,
        bytes as f64,
        1,
        OS_AUTOSCALE_RWND,
        Some(MUSCLE_SERIALIZE_CAP),
        seed,
    )
}

/// Aspera-style UDP transfer: rate-controlled, insensitive to RTT and to
/// TCP-style loss response; only the lost fraction is retransmitted. Its
/// UDP blast does not cede fair shares to background TCP the way a TCP
/// tool must, so the rate tracks raw capacity, not the fair share.
pub fn aspera_transfer(link: &LinkProfile, dir: Direction, bytes: u64) -> OneWayResult {
    let rate = link.capacity * ASPERA_EFFICIENCY * (1.0 - link.loss(dir));
    // short ramp (~1s) while the rate controller locks on
    let ramp = 1.0;
    let seconds = ramp * 0.5 + bytes as f64 / rate;
    OneWayResult {
        seconds,
        bytes: bytes as f64,
        throughput: bytes as f64 / seconds,
        losses: 0,
        rounds: 0,
        timeline: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::profiles;

    const MB: u64 = 1024 * 1024;
    const MBF: f64 = 1024.0 * 1024.0;

    #[test]
    fn scp_is_slowest_tcp_tool_on_wan() {
        let link = profiles::london_poznan();
        let scp = scp_transfer(&link, Direction::AtoB, 64 * MB, 1);
        let zmq = zeromq_transfer(&link, Direction::AtoB, 64 * MB, 1);
        assert!(
            scp.throughput <= zmq.throughput * 1.2,
            "scp {:.1} vs zmq {:.1} MB/s",
            scp.throughput / MBF,
            zmq.throughput / MBF
        );
    }

    #[test]
    fn scp_never_beats_crypto_cap() {
        for link in profiles::all() {
            let r = scp_transfer(&link, Direction::AtoB, 32 * MB, 2);
            assert!(r.throughput <= SCP_CRYPTO_CAP * 1.05, "{}", link.name);
        }
    }

    #[test]
    fn muscle_is_symmetric_cpu_bound() {
        let link = profiles::poznan_amsterdam();
        let ab = muscle_transfer(&link, Direction::AtoB, 64 * MB, 3);
        let ba = muscle_transfer(&link, Direction::BtoA, 64 * MB, 3);
        assert!(ab.throughput <= MUSCLE_SERIALIZE_CAP * 1.05);
        // A→B is clean enough that the CPU cap binds → near-symmetric
        let ratio = ab.throughput / ba.throughput.max(1.0);
        assert!((0.5..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn zeromq_asymmetry_follows_loss() {
        let link = profiles::london_poznan();
        let lossy = zeromq_transfer(&link, Direction::AtoB, 64 * MB, 4);
        let clean = zeromq_transfer(&link, Direction::BtoA, 64 * MB, 4);
        assert!(
            clean.throughput > 1.5 * lossy.throughput,
            "clean {:.1} vs lossy {:.1} MB/s",
            clean.throughput / MBF,
            lossy.throughput / MBF
        );
    }

    #[test]
    fn aspera_is_loss_and_rtt_insensitive() {
        let mut near = profiles::ucl_yale();
        near.rtt = 0.010;
        let far = profiles::ucl_yale();
        let a = aspera_transfer(&near, Direction::AtoB, 256 * MB);
        let b = aspera_transfer(&far, Direction::AtoB, 256 * MB);
        let ratio = a.throughput / b.throughput;
        assert!((0.95..1.05).contains(&ratio), "rtt changed aspera rate: {ratio}");
    }

    #[test]
    fn aspera_beats_tcp_tools_transatlantic() {
        // §1.2.3: scp 8 < MPWide 40 < Aspera 48 MB/s.
        let link = profiles::ucl_yale();
        let scp = scp_transfer(&link, Direction::AtoB, 256 * MB, 5);
        let asp = aspera_transfer(&link, Direction::AtoB, 256 * MB);
        assert!(asp.throughput > 3.0 * scp.throughput);
    }
}
