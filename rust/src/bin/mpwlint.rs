//! `mpwlint` — the in-tree project lint.
//!
//! Run with `cargo run --bin mpwlint` from anywhere in the workspace; it
//! exits non-zero on any violation and is wired into CI as a blocking
//! step. Plain line scanning, no external deps (same philosophy as the
//! vendored shims in `rust/vendor/`).
//!
//! Three checks:
//!
//! 1. **Panic ban** — no `.unwrap()` / `.expect(` in `rust/src/mpwide/**`
//!    outside `#[cfg(test)]` regions and comments. A checked-in
//!    allowlist (`rust/mpwlint.allow`) budgets the provably-infallible
//!    remainder per file, and is shrink-only: the lint fails both when a
//!    file exceeds its budget *and* when it drops below it, so the
//!    checked-in number can never silently lag behind reality.
//! 2. **Lock discipline** — no raw `std::sync` `Mutex`/`Condvar` tokens
//!    anywhere in `rust/src/**` except `util/lockorder.rs` (and test
//!    modules). Library code must go through `OrderedMutex` /
//!    `OrderedCondvar` so the debug-build lock-rank checker observes
//!    every acquisition (see `docs/CONCURRENCY.md`).
//! 3. **Protocol drift** — `docs/PROTOCOL.md` carries machine-checkable
//!    markers of the form
//!    `<!-- mpwlint-const: <src-file> <NAME> = <value> -->`;
//!    each is compared against the constant's definition in the source
//!    tree (numeric where both sides evaluate, textual otherwise), so
//!    the documented wire format cannot drift from the code.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

struct Violation {
    file: String,
    line: usize,
    msg: String,
}

fn violation(file: &str, line: usize, msg: String) -> Violation {
    Violation { file: file.to_string(), line, msg }
}

fn main() -> ExitCode {
    // CARGO_MANIFEST_DIR is `<repo>/rust` for this binary.
    let Some(root) = Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(Path::to_path_buf)
    else {
        eprintln!("mpwlint: cannot locate repo root");
        return ExitCode::FAILURE;
    };
    let mut v: Vec<Violation> = Vec::new();
    check_panics(&root, &mut v);
    check_raw_sync(&root, &mut v);
    check_protocol_consts(&root, &mut v);
    if v.is_empty() {
        println!("mpwlint: OK (panic ban, lock discipline, protocol constants)");
        ExitCode::SUCCESS
    } else {
        for x in &v {
            eprintln!("mpwlint: {}:{}: {}", x.file, x.line, x.msg);
        }
        eprintln!("mpwlint: {} violation(s)", v.len());
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------------
// shared scanning

/// Tag each line of a source file with its 1-based number and whether it
/// falls inside a `#[cfg(test)]` region. Regions start at the attribute
/// and end when the brace depth of the gated block returns to zero —
/// line-oriented and deliberately naive about braces inside string
/// literals, which is fine for the test modules this tree contains
/// (they run to end-of-file).
fn tag_lines(src: &str) -> Vec<(usize, bool, &str)> {
    let mut out = Vec::new();
    let mut in_test = false;
    let mut depth: i64 = 0;
    let mut armed = false; // saw the attribute, waiting for the opening brace
    for (i, line) in src.lines().enumerate() {
        if !in_test && line.trim_start().starts_with("#[cfg(test)]") {
            in_test = true;
            armed = true;
            depth = 0;
        }
        out.push((i + 1, in_test, line));
        if in_test {
            for c in line.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        armed = false;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if !armed && depth <= 0 {
                in_test = false;
            }
        }
    }
    out
}

fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// Recursively collect `.rs` files under `dir`, sorted for stable output.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().map_or(false, |x| x == "rs") {
            out.push(p);
        }
    }
}

fn rel_to(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/")
}

// ---------------------------------------------------------------------------
// check 1: panic ban in mpwide library code

/// Line numbers of `.unwrap()` / `.expect(` hits in non-test,
/// non-comment code.
fn panic_sites(src: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    for (n, in_test, line) in tag_lines(src) {
        if in_test || is_comment(line) {
            continue;
        }
        if line.contains(".unwrap()") || line.contains(".expect(") {
            hits.push(n);
        }
    }
    hits
}

/// Parse the allowlist: `<repo-relative path> <count>` per line, `#`
/// comments and blank lines ignored.
fn parse_allowlist(text: &str) -> Result<BTreeMap<String, usize>, (usize, String)> {
    let mut map = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(path), Some(count), None) = (it.next(), it.next(), it.next()) else {
            return Err((i + 1, format!("malformed allowlist line: {line:?}")));
        };
        let Ok(count) = count.parse::<usize>() else {
            return Err((i + 1, format!("bad count in allowlist line: {line:?}")));
        };
        map.insert(path.to_string(), count);
    }
    Ok(map)
}

const ALLOWLIST: &str = "rust/mpwlint.allow";

fn check_panics(root: &Path, v: &mut Vec<Violation>) {
    let allow_path = root.join(ALLOWLIST);
    let allow_text = fs::read_to_string(&allow_path).unwrap_or_default();
    let allow = match parse_allowlist(&allow_text) {
        Ok(a) => a,
        Err((line, msg)) => {
            v.push(violation(ALLOWLIST, line, msg));
            return;
        }
    };
    let mut files = Vec::new();
    rust_files(&root.join("rust/src/mpwide"), &mut files);
    let mut seen: BTreeMap<String, usize> = BTreeMap::new();
    for path in files {
        let rel = rel_to(root, &path);
        let Ok(src) = fs::read_to_string(&path) else {
            v.push(violation(&rel, 0, "unreadable file".into()));
            continue;
        };
        let hits = panic_sites(&src);
        let budget = allow.get(&rel).copied().unwrap_or(0);
        if hits.len() > budget {
            v.push(violation(
                &rel,
                hits[0],
                format!(
                    "{} `.unwrap()`/`.expect(` site(s) in library code (allowlist budget {}), at lines {:?}",
                    hits.len(),
                    budget,
                    hits
                ),
            ));
        }
        seen.insert(rel, hits.len());
    }
    // Shrink-only: a budget above reality is as much a failure as one
    // below it — the allowlist must track the tree downward.
    for (path, budget) in &allow {
        let actual = seen.get(path).copied().unwrap_or(0);
        if actual < *budget {
            v.push(violation(
                ALLOWLIST,
                0,
                format!("stale entry: {path} allows {budget} but only {actual} remain — shrink it"),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// check 2: raw Mutex/Condvar ban

/// Occurrences of `Mutex`/`Condvar` tokens not written as part of
/// `OrderedMutex`/`OrderedCondvar`, with line numbers.
fn raw_sync_sites(src: &str) -> Vec<(usize, String)> {
    let mut hits = Vec::new();
    for (n, in_test, line) in tag_lines(src) {
        if in_test || is_comment(line) {
            continue;
        }
        for tok in ["Mutex", "Condvar"] {
            let mut from = 0;
            while let Some(pos) = line[from..].find(tok) {
                let abs = from + pos;
                if !line[..abs].ends_with("Ordered") {
                    hits.push((n, tok.to_string()));
                }
                from = abs + tok.len();
            }
        }
    }
    hits
}

fn check_raw_sync(root: &Path, v: &mut Vec<Violation>) {
    let mut files = Vec::new();
    rust_files(&root.join("rust/src"), &mut files);
    for path in files {
        let rel = rel_to(root, &path);
        // lockorder.rs is the one home of the raw primitives; this
        // binary names the tokens in its own scan patterns.
        if rel.ends_with("util/lockorder.rs") || rel.ends_with("bin/mpwlint.rs") {
            continue;
        }
        let Ok(src) = fs::read_to_string(&path) else {
            v.push(violation(&rel, 0, "unreadable file".into()));
            continue;
        };
        for (n, tok) in raw_sync_sites(&src) {
            v.push(violation(
                &rel,
                n,
                format!("raw `{tok}` in library code — use the lock-ranked wrapper from util::lockorder"),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// check 3: protocol constants vs docs/PROTOCOL.md markers

struct Marker {
    doc_line: usize,
    file: String,
    name: String,
    expr: String,
}

/// Extract `<!-- mpwlint-const: <file> <NAME> = <expr> -->` markers.
fn parse_markers(doc: &str) -> (Vec<Marker>, Vec<(usize, String)>) {
    let mut markers = Vec::new();
    let mut errors = Vec::new();
    for (i, line) in doc.lines().enumerate() {
        let Some(start) = line.find("<!-- mpwlint-const:") else { continue };
        let rest = &line[start + "<!-- mpwlint-const:".len()..];
        let Some(end) = rest.find("-->") else {
            errors.push((i + 1, "unterminated mpwlint-const marker".into()));
            continue;
        };
        let body = rest[..end].trim();
        // `<file> <NAME> = <expr>` — expr may contain spaces.
        let Some((head, expr)) = body.split_once('=') else {
            errors.push((i + 1, format!("marker missing `=`: {body:?}")));
            continue;
        };
        let mut it = head.split_whitespace();
        let (Some(file), Some(name), None) = (it.next(), it.next(), it.next()) else {
            errors.push((i + 1, format!("marker head must be `<file> <NAME>`: {head:?}")));
            continue;
        };
        markers.push(Marker {
            doc_line: i + 1,
            file: file.to_string(),
            name: name.to_string(),
            expr: expr.trim().to_string(),
        });
    }
    (markers, errors)
}

/// Find `const NAME: ... = <expr>;` in a source file and return the
/// right-hand side text.
fn const_rhs(src: &str, name: &str) -> Option<String> {
    let needle = format!("const {name}:");
    for line in src.lines() {
        let Some(pos) = line.find(&needle) else { continue };
        let after = &line[pos + needle.len()..];
        let rhs = after.split_once('=')?.1;
        let rhs = rhs.split(';').next()?.trim();
        return Some(rhs.to_string());
    }
    None
}

/// Evaluate a small integer expression: decimal / `0x` hex literals
/// (optionally with `_` separators and a type suffix), combined with
/// `+`, `*` and `<<`. Returns `None` for anything else — the caller
/// falls back to normalized textual comparison.
fn eval_expr(s: &str) -> Option<u128> {
    let s = s.trim();
    if let Some(pos) = s.find("<<") {
        return Some(eval_sum(&s[..pos])?.checked_shl(eval_expr(&s[pos + 2..])? as u32)?);
    }
    eval_sum(s)
}

fn eval_sum(s: &str) -> Option<u128> {
    let mut total: u128 = 0;
    for part in s.split('+') {
        total = total.checked_add(eval_prod(part)?)?;
    }
    Some(total)
}

fn eval_prod(s: &str) -> Option<u128> {
    let mut total: u128 = 1;
    for part in s.split('*') {
        total = total.checked_mul(eval_atom(part)?)?;
    }
    Some(total)
}

fn eval_atom(s: &str) -> Option<u128> {
    let t = s.trim().replace('_', "");
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        let hex = hex.trim_end_matches(|c: char| !c.is_ascii_hexdigit());
        return u128::from_str_radix(hex, 16).ok();
    }
    let dec = t.trim_end_matches(|c: char| c.is_ascii_alphabetic());
    dec.parse::<u128>().ok()
}

fn normalized(s: &str) -> String {
    s.chars().filter(|c| !c.is_whitespace()).collect()
}

const PROTOCOL_DOC: &str = "docs/PROTOCOL.md";

fn check_protocol_consts(root: &Path, v: &mut Vec<Violation>) {
    let Ok(doc) = fs::read_to_string(root.join(PROTOCOL_DOC)) else {
        v.push(violation(PROTOCOL_DOC, 0, "missing protocol doc".into()));
        return;
    };
    let (markers, errors) = parse_markers(&doc);
    for (line, msg) in errors {
        v.push(violation(PROTOCOL_DOC, line, msg));
    }
    if markers.is_empty() {
        v.push(violation(
            PROTOCOL_DOC,
            0,
            "no mpwlint-const markers found — the drift check would silently pass".into(),
        ));
        return;
    }
    for m in &markers {
        let Ok(src) = fs::read_to_string(root.join(&m.file)) else {
            v.push(violation(PROTOCOL_DOC, m.doc_line, format!("marker points at unreadable file {}", m.file)));
            continue;
        };
        let Some(rhs) = const_rhs(&src, &m.name) else {
            v.push(violation(
                PROTOCOL_DOC,
                m.doc_line,
                format!("constant `{}` not found in {}", m.name, m.file),
            ));
            continue;
        };
        let matches = match (eval_expr(&m.expr), eval_expr(&rhs)) {
            (Some(a), Some(b)) => a == b,
            _ => normalized(&m.expr) == normalized(&rhs),
        };
        if !matches {
            v.push(violation(
                PROTOCOL_DOC,
                m.doc_line,
                format!("`{}` documented as `{}` but {} defines `{}`", m.name, m.expr, m.file, rhs),
            ));
        }
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    const PANIC_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/panics.rs.fixture"
    ));
    const RAW_SYNC_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/raw_sync.rs.fixture"
    ));
    const DOC_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/doc.md.fixture"
    ));
    const CONSTS_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/consts.rs.fixture"
    ));

    #[test]
    fn panic_sites_skip_tests_and_comments() {
        // Fixture layout: unwrap at lines 4 and 8, expect at line 9,
        // commented unwrap at line 6, test-mod unwrap near the end.
        assert_eq!(panic_sites(PANIC_FIXTURE), vec![4, 8, 9]);
    }

    #[test]
    fn raw_sync_flags_only_unwrapped_primitives() {
        let hits = raw_sync_sites(RAW_SYNC_FIXTURE);
        // One raw Mutex (line 5) and one raw Condvar (line 6); the
        // Ordered* uses and the test-module Mutex are clean.
        assert_eq!(
            hits,
            vec![(5, "Mutex".to_string()), (6, "Condvar".to_string())]
        );
    }

    #[test]
    fn test_region_tracking_ends_with_block() {
        let src = "fn a() {}\n#[cfg(test)]\nmod t {\n  fn x() {}\n}\nfn b() {}\n";
        let tags = tag_lines(src);
        let flags: Vec<bool> = tags.iter().map(|(_, t, _)| *t).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn expr_evaluator() {
        assert_eq!(eval_expr("18"), Some(18));
        assert_eq!(eval_expr("1 + 1 + 8 + 4 + 4"), Some(18));
        assert_eq!(eval_expr("64 << 20"), Some(64 << 20));
        assert_eq!(eval_expr("0xF5"), Some(0xF5));
        assert_eq!(eval_expr("2 * 3 + 4"), Some(10));
        assert_eq!(eval_expr("64usize"), Some(64));
        assert_eq!(eval_expr("*b\"MPW1\""), None);
    }

    #[test]
    fn markers_parse_and_compare() {
        let (markers, errors) = parse_markers(DOC_FIXTURE);
        assert!(errors.is_empty(), "{errors:?}");
        assert_eq!(markers.len(), 4);
        // The fixture doc and fixture source agree on the first three
        // markers and deliberately disagree on the fourth.
        let verdicts: Vec<bool> = markers
            .iter()
            .map(|m| {
                let rhs = const_rhs(CONSTS_FIXTURE, &m.name).expect("const present");
                match (eval_expr(&m.expr), eval_expr(&rhs)) {
                    (Some(a), Some(b)) => a == b,
                    _ => normalized(&m.expr) == normalized(&rhs),
                }
            })
            .collect();
        assert_eq!(verdicts, vec![true, true, true, false]);
    }

    #[test]
    fn const_rhs_extraction() {
        assert_eq!(const_rhs(CONSTS_FIXTURE, "MAGIC").as_deref(), Some("0xF5"));
        assert_eq!(const_rhs(CONSTS_FIXTURE, "HDR_LEN").as_deref(), Some("1 + 1 + 8 + 4 + 4"));
        assert_eq!(const_rhs(CONSTS_FIXTURE, "NOPE"), None);
    }

    #[test]
    fn allowlist_parses_and_rejects_garbage() {
        let ok = parse_allowlist("# comment\nrust/src/mpwide/a.rs 3\n\nrust/src/mpwide/b.rs 0\n");
        assert_eq!(ok.unwrap().get("rust/src/mpwide/a.rs"), Some(&3));
        assert!(parse_allowlist("too many words here 3").is_err());
        assert!(parse_allowlist("path notanumber").is_err());
    }
}
