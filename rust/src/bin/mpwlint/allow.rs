//! The sectioned, shrink-only allowlist (`rust/mpwlint.allow`).
//!
//! Format:
//!
//! ```text
//! # comments and blank lines are ignored
//! [panics]
//! rust/src/mpwide/foo.rs 3
//! [swallow]
//! rust/src/mpwide/bar.rs 1
//! [blocking]
//! ```
//!
//! Semantics — shrink-only **by entry**, not just by count:
//!
//! * a file over its budget fails (new debt is rejected);
//! * a file under its budget fails as *stale*, reporting the exact
//!   allowlist line to edit and the count to shrink it to;
//! * an entry burned down to zero is kept as a `<path> 0` tombstone —
//!   the line is never deleted, so a path that once carried debt can
//!   never silently reacquire it (a tombstoned path with fresh sites is
//!   an over-budget failure like any other).

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use crate::scan::{violation, Violation};

pub const ALLOWLIST: &str = "rust/mpwlint.allow";
pub const SECTIONS: [&str; 3] = ["panics", "swallow", "blocking"];

pub struct Entry {
    pub budget: usize,
    /// 1-based line in the allowlist file, for stale-entry reporting.
    pub line: usize,
}

#[derive(Default)]
pub struct Allowlist {
    pub sections: BTreeMap<String, BTreeMap<String, Entry>>,
}

impl Allowlist {
    pub fn budget(&self, section: &str, path: &str) -> usize {
        self.sections
            .get(section)
            .and_then(|s| s.get(path))
            .map_or(0, |e| e.budget)
    }
}

pub fn parse(text: &str) -> Result<Allowlist, (usize, String)> {
    let mut out = Allowlist::default();
    let mut cur: Option<String> = None;
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
            if !SECTIONS.contains(&name) {
                return Err((i + 1, format!("unknown allowlist section [{name}]")));
            }
            if out.sections.contains_key(name) {
                return Err((i + 1, format!("duplicate allowlist section [{name}]")));
            }
            out.sections.insert(name.to_string(), BTreeMap::new());
            cur = Some(name.to_string());
            continue;
        }
        let Some(section) = &cur else {
            return Err((i + 1, format!("entry before any [section] header: {line:?}")));
        };
        let mut it = line.split_whitespace();
        let (Some(path), Some(count), None) = (it.next(), it.next(), it.next()) else {
            return Err((i + 1, format!("malformed allowlist line: {line:?}")));
        };
        let Ok(count) = count.parse::<usize>() else {
            return Err((i + 1, format!("bad count in allowlist line: {line:?}")));
        };
        let entries = out.sections.get_mut(section).expect("current section exists");
        if entries
            .insert(path.to_string(), Entry { budget: count, line: i + 1 })
            .is_some()
        {
            return Err((i + 1, format!("duplicate entry for {path} in [{section}]")));
        }
    }
    Ok(out)
}

pub fn load(root: &Path, v: &mut Vec<Violation>) -> Allowlist {
    let text = fs::read_to_string(root.join(ALLOWLIST)).unwrap_or_default();
    match parse(&text) {
        Ok(a) => a,
        Err((line, msg)) => {
            v.push(violation(ALLOWLIST, line, msg));
            Allowlist::default()
        }
    }
}

/// Compare per-file site counts against one section's budgets, both
/// directions: over-budget fails at the offending file, under-budget
/// fails at the allowlist with the exact line to shrink.
pub fn check_section(
    allow: &Allowlist,
    section: &str,
    seen: &BTreeMap<String, (usize, usize)>, // path -> (count, first line)
    what: &str,
    v: &mut Vec<Violation>,
) {
    for (path, (count, first_line)) in seen {
        let budget = allow.budget(section, path);
        if *count > budget {
            v.push(violation(
                path,
                *first_line,
                format!(
                    "{count} {what} site(s) but [{section}] budget is {budget} — \
                     burn the new site(s) down (the allowlist is shrink-only)"
                ),
            ));
        }
    }
    check_stale(allow, section, seen, v);
}

/// The under-budget direction alone: every entry whose budget exceeds
/// reality is *stale* and names the exact allowlist line to shrink.
pub fn check_stale(
    allow: &Allowlist,
    section: &str,
    seen: &BTreeMap<String, (usize, usize)>,
    v: &mut Vec<Violation>,
) {
    if let Some(entries) = allow.sections.get(section) {
        for (path, e) in entries {
            let actual = seen.get(path).map_or(0, |(c, _)| *c);
            if actual < e.budget {
                v.push(violation(
                    ALLOWLIST,
                    e.line,
                    format!(
                        "stale [{section}] entry: {path} allows {} but only {actual} remain — \
                         shrink line {} to `{path} {actual}` (keep the line: entries are \
                         tombstoned at 0, never deleted)",
                        e.budget, e.line
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sectioned_allowlist_parses() {
        let a = parse(
            "# header\n[panics]\nrust/src/mpwide/a.rs 3\nrust/src/mpwide/b.rs 0\n\n[swallow]\nrust/src/mpwide/a.rs 1\n[blocking]\n",
        )
        .unwrap();
        assert_eq!(a.budget("panics", "rust/src/mpwide/a.rs"), 3);
        assert_eq!(a.budget("panics", "rust/src/mpwide/b.rs"), 0);
        assert_eq!(a.budget("swallow", "rust/src/mpwide/a.rs"), 1);
        assert_eq!(a.budget("blocking", "rust/src/mpwide/a.rs"), 0);
        // line numbers recorded for stale reporting
        assert_eq!(a.sections["panics"]["rust/src/mpwide/a.rs"].line, 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("rust/src/x.rs 3\n").is_err(), "entry before section");
        assert!(parse("[nonsense]\n").is_err(), "unknown section");
        assert!(parse("[panics]\npath notanumber\n").is_err());
        assert!(parse("[panics]\ntoo many words 3\n").is_err());
        assert!(parse("[panics]\na.rs 1\na.rs 2\n").is_err(), "duplicate entry");
        assert!(parse("[panics]\n[panics]\n").is_err(), "duplicate section");
    }

    #[test]
    fn check_reports_both_directions() {
        let a = parse("[panics]\na.rs 2\nb.rs 1\n").unwrap();
        let mut seen = BTreeMap::new();
        seen.insert("a.rs".to_string(), (3, 10)); // over budget
        // b.rs burned down to 0 -> stale entry at allowlist line 3
        let mut v = Vec::new();
        check_section(&a, "panics", &seen, "panic", &mut v);
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].file, "a.rs");
        assert_eq!(v[0].line, 10);
        assert_eq!(v[1].file, ALLOWLIST);
        assert_eq!(v[1].line, 3);
        assert!(v[1].msg.contains("`b.rs 0`"), "{}", v[1].msg);
    }
}
