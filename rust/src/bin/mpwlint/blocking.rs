//! Pass: blocking-call-under-lock — token lists and the per-line probe.
//!
//! The lock-graph analyzer (`lockgraph`) tracks which guards are live on
//! each line; this module decides whether the line *blocks*: socket
//! reads/writes, `Pacer::acquire`, `thread::sleep`, joins, accepts and
//! the library's own composite blocking helpers. Sleeping or doing I/O
//! while holding a *coordination* lock is how week-long WAN runs wedge,
//! so every hit must be either restructured (drop the guard first) or
//! budgeted in the `[blocking]` allowlist section.
//!
//! Ranks whose documented purpose IS serializing blocking I/O are
//! exempt: the send/recv gates exist to make whole-message I/O atomic,
//! and the per-stream halves / in-memory channels are the I/O itself.

pub const BLOCKING_TOKENS: [&str; 17] = [
    ".join()",
    "thread::sleep",
    "::sleep(",
    ".acquire(",
    ".read_exact(",
    ".read_some(",
    ".write_all(",
    ".write_vectored_all(",
    ".connect(",
    "TcpStream::connect",
    ".accept()",
    ".recv_msg(",
    ".flush()",
    ".wait()",
    "wait_for_any_live(",
    "measure_rtt(",
    "connect_retry(",
];

/// Substrings removed before the token scan — non-blocking lookalikes.
pub const NONBLOCKING_EXCEPTIONS: [&str; 1] = [".try_acquire("];

/// Rank names whose guards may legally be held across blocking calls.
pub const EXEMPT_RANKNAMES: [&str; 6] =
    ["SEND_GATE", "RECV_GATE", "STREAM_TX", "STREAM_RX", "STREAM_META", "MEM_CHAN"];

pub fn is_exempt(rankname: &str) -> bool {
    EXEMPT_RANKNAMES.contains(&rankname)
}

/// First blocking token on a (stripped) line, if any.
pub fn blocking_token(stripped: &str) -> Option<&'static str> {
    let mut s = stripped.to_string();
    for exc in NONBLOCKING_EXCEPTIONS {
        s = s.replace(exc, "");
    }
    BLOCKING_TOKENS.into_iter().find(|tok| s.contains(tok))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockgraph::{analyze_file, build_rank_map, parse_rank_consts, Analysis};

    #[test]
    fn tokens_and_exceptions() {
        assert_eq!(blocking_token("std::thread::sleep(d);"), Some("thread::sleep"));
        assert_eq!(blocking_token("let _ = h.join();"), Some(".join()"));
        assert_eq!(blocking_token("pacer.acquire(n);"), Some(".acquire("));
        assert_eq!(blocking_token("pacer.try_acquire(n);"), None);
        assert_eq!(blocking_token("w.write_all(&buf)?;"), Some(".write_all("));
        assert_eq!(blocking_token("st.chans.len()"), None);
        assert!(is_exempt("SEND_GATE"));
        assert!(!is_exempt("MUX_STATE"));
    }

    const BAD_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/blocking_bad.rs.fixture"
    ));
    const OK_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/blocking_ok.rs.fixture"
    ));

    fn run(src: &str) -> (Vec<crate::scan::Violation>, Analysis) {
        let ranks = parse_rank_consts(src);
        assert!(!ranks.is_empty(), "fixture must define rank consts");
        let sources = vec![("fixture.rs".to_string(), src.to_string())];
        let mut v = Vec::new();
        let rmap = build_rank_map(&sources, &ranks, &mut v);
        let mut analysis = Analysis::default();
        analyze_file("fixture.rs", src, &rmap, &mut analysis, &mut v);
        (v, analysis)
    }

    #[test]
    fn sleep_under_coordination_lock_is_flagged() {
        let (v, analysis) = run(BAD_FIXTURE);
        assert!(v.is_empty(), "lock-order itself is clean: {v:?}");
        assert_eq!(analysis.blocking.len(), 1, "{:?}", analysis.blocking);
        let (_, line, msg) = &analysis.blocking[0];
        assert_eq!(*line, 10);
        assert!(msg.contains("thread::sleep") && msg.contains("COORD"), "{msg}");
    }

    #[test]
    fn dropped_guards_and_exempt_ranks_pass() {
        let (v, analysis) = run(OK_FIXTURE);
        assert!(v.is_empty(), "{v:?}");
        assert!(analysis.blocking.is_empty(), "{:?}", analysis.blocking);
    }
}
