//! Shared scanning helpers: file discovery, test-region tagging and
//! literal/comment stripping. Everything is line-oriented — the same
//! deliberately naive philosophy as the original single-file lint.

use std::fs;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub msg: String,
}

pub fn violation(file: &str, line: usize, msg: String) -> Violation {
    Violation { file: file.to_string(), line, msg }
}

/// Tag each line of a source file with its 1-based number and whether it
/// falls inside a `#[cfg(test)]` region. Regions start at the attribute
/// and end when the brace depth of the gated block returns to zero —
/// line-oriented and deliberately naive about braces inside string
/// literals, which is fine for the test modules this tree contains
/// (they run to end-of-file).
pub fn tag_lines(src: &str) -> Vec<(usize, bool, &str)> {
    let mut out = Vec::new();
    let mut in_test = false;
    let mut depth: i64 = 0;
    let mut armed = false; // saw the attribute, waiting for the opening brace
    for (i, line) in src.lines().enumerate() {
        if !in_test && line.trim_start().starts_with("#[cfg(test)]") {
            in_test = true;
            armed = true;
            depth = 0;
        }
        out.push((i + 1, in_test, line));
        if in_test {
            for c in line.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        armed = false;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if !armed && depth <= 0 {
                in_test = false;
            }
        }
    }
    out
}

pub fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// Recursively collect `.rs` files under `dir`, sorted for stable output.
pub fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().map_or(false, |x| x == "rs") {
            out.push(p);
        }
    }
}

pub fn rel_to(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/")
}

/// Files the lock-discipline passes never scan: the home of the raw
/// primitives and this lint itself (which names the banned tokens in
/// its own patterns).
pub fn is_lint_exempt(rel: &str) -> bool {
    rel.ends_with("util/lockorder.rs") || rel.contains("bin/mpwlint")
}

/// Blank out string/char-literal contents and comments so token scans
/// cannot match inside them. Returns the stripped line and the updated
/// block-comment state. String delimiters are kept (as `"` / `' '`) so
/// column arithmetic stays roughly aligned with the raw line.
pub fn strip_line(line: &str, in_block_comment: &mut bool) -> String {
    let b = line.as_bytes();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    let n = b.len();
    while i < n {
        if *in_block_comment {
            if b[i..].starts_with(b"*/") {
                *in_block_comment = false;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        if b[i..].starts_with(b"/*") {
            *in_block_comment = true;
            i += 2;
            continue;
        }
        if b[i..].starts_with(b"//") {
            break; // rest of line is a comment
        }
        match b[i] {
            b'"' => {
                // string literal: skip to the closing quote, honoring escapes
                out.push('"');
                i += 1;
                while i < n {
                    match b[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
                out.push('"');
            }
            b'\'' => {
                // char literal like 'x', '\n', '{' — but also lifetimes 'a.
                // A char literal iff a closing quote appears right after
                // the (possibly escaped) payload.
                let mut j = i + 1;
                if j < n && b[j] == b'\\' {
                    j += 2;
                } else {
                    j += 1;
                }
                if j < n && b[j] == b'\'' {
                    out.push_str("' '");
                    i = j + 1;
                } else {
                    out.push('\'');
                    i += 1;
                }
            }
            c => {
                out.push(c as char);
                i += 1;
            }
        }
    }
    out
}

pub fn is_ident(s: &str) -> bool {
    !s.is_empty()
        && !s.as_bytes()[0].is_ascii_digit()
        && s.bytes().all(|c| c.is_ascii_alphanumeric() || c == b'_')
}

/// Leading identifier of `s` (after optional whitespace), if any.
pub fn leading_ident(s: &str) -> Option<&str> {
    let t = s.trim_start();
    let end = t
        .bytes()
        .position(|c| !(c.is_ascii_alphanumeric() || c == b'_'))
        .unwrap_or(t.len());
    let id = &t[..end];
    if is_ident(id) {
        Some(id)
    } else {
        None
    }
}

/// Trailing identifier of `s` (before optional whitespace), if any.
pub fn trailing_ident(s: &str) -> Option<&str> {
    let t = s.trim_end();
    let b = t.as_bytes();
    let mut i = t.len();
    while i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_') {
        i -= 1;
    }
    let id = &t[i..];
    if is_ident(id) {
        Some(id)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_region_tracking_ends_with_block() {
        let src = "fn a() {}\n#[cfg(test)]\nmod t {\n  fn x() {}\n}\nfn b() {}\n";
        let tags = tag_lines(src);
        let flags: Vec<bool> = tags.iter().map(|(_, t, _)| *t).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn strip_line_blanks_strings_and_comments() {
        let mut bc = false;
        assert_eq!(strip_line("a.lock(); // b.lock()", &mut bc), "a.lock(); ");
        assert_eq!(strip_line("let s = \"x.lock()\";", &mut bc), "let s = \"\";");
        assert_eq!(strip_line("before /* a.lock()", &mut bc), "before ");
        assert!(bc);
        assert_eq!(strip_line("still */ after", &mut bc), " after");
        assert!(!bc);
        // lifetimes survive, char literals are blanked
        assert_eq!(strip_line("fn f<'a>(c: char) { x('{') }", &mut bc), "fn f<'a>(c: char) { x(' ') }");
    }

    #[test]
    fn ident_helpers() {
        assert_eq!(leading_ident("  foo, bar"), Some("foo"));
        assert_eq!(leading_ident(" 9x"), None);
        assert_eq!(trailing_ident("let mut g "), Some("g"));
        assert_eq!(trailing_ident("a.b"), Some("b"));
        assert!(is_ident("wd_st"));
        assert!(!is_ident("a.b"));
    }
}
