//! Pass: raw `std::sync` `Mutex`/`Condvar` ban — everything in
//! `rust/src/**` except `util/lockorder.rs` (and test modules) must use
//! `OrderedMutex`/`OrderedCondvar` so the debug-build lock-rank checker
//! observes every acquisition (see `docs/CONCURRENCY.md`).

use std::fs;
use std::path::Path;

use crate::scan::{is_comment, is_lint_exempt, rel_to, rust_files, tag_lines, violation, Violation};

/// Occurrences of `Mutex`/`Condvar` tokens not written as part of
/// `OrderedMutex`/`OrderedCondvar`, with line numbers.
pub fn raw_sync_sites(src: &str) -> Vec<(usize, String)> {
    let mut hits = Vec::new();
    for (n, in_test, line) in tag_lines(src) {
        if in_test || is_comment(line) {
            continue;
        }
        for tok in ["Mutex", "Condvar"] {
            let mut from = 0;
            while let Some(pos) = line[from..].find(tok) {
                let abs = from + pos;
                if !line[..abs].ends_with("Ordered") {
                    hits.push((n, tok.to_string()));
                }
                from = abs + tok.len();
            }
        }
    }
    hits
}

pub fn check(root: &Path, v: &mut Vec<Violation>) {
    let mut files = Vec::new();
    rust_files(&root.join("rust/src"), &mut files);
    for path in files {
        let rel = rel_to(root, &path);
        if is_lint_exempt(&rel) {
            continue;
        }
        let Ok(src) = fs::read_to_string(&path) else {
            v.push(violation(&rel, 0, "unreadable file".into()));
            continue;
        };
        for (n, tok) in raw_sync_sites(&src) {
            v.push(violation(
                &rel,
                n,
                format!("raw `{tok}` in library code — use the lock-ranked wrapper from util::lockorder"),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RAW_SYNC_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/raw_sync.rs.fixture"
    ));

    #[test]
    fn raw_sync_flags_only_unwrapped_primitives() {
        let hits = raw_sync_sites(RAW_SYNC_FIXTURE);
        // One raw Mutex (line 5) and one raw Condvar (line 6); the
        // Ordered* uses and the test-module Mutex are clean.
        assert_eq!(
            hits,
            vec![(5, "Mutex".to_string()), (6, "Condvar".to_string())]
        );
    }
}
