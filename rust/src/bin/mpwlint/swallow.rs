//! Pass: swallowed-`Result` ban — `let _ =` in non-test library code
//! (`rust/src/mpwide/**` and `rust/src/util/**`) silently discards
//! whatever the right-hand side reports; over a week-long WAN run that
//! is how errors disappear. Every site must either propagate a typed
//! `MpwError`, or carry a `// swallow-ok: <reason>` justification
//! comment (same line or the comment block directly above) *and* fit
//! its file's `[swallow]` allowlist budget, which is shrink-only.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use crate::allow::{self, Allowlist};
use crate::scan::{is_comment, is_lint_exempt, rel_to, rust_files, tag_lines, violation, Violation};

const MARKER: &str = "swallow-ok:";

/// Is there a `let _ =` discard on this (raw) line?
fn discards(line: &str) -> bool {
    let mut from = 0;
    while let Some(p) = line[from..].find("let _") {
        let abs = from + p;
        let before_ok = abs == 0
            || !line.as_bytes()[abs - 1].is_ascii_alphanumeric() && line.as_bytes()[abs - 1] != b'_';
        let rest = line[abs + "let _".len()..].trim_start();
        if before_ok && rest.starts_with('=') && !rest.starts_with("==") {
            return true;
        }
        from = abs + "let _".len();
    }
    false
}

/// `(line, justified)` for every `let _ =` site in non-test code.
/// A site is justified by a `swallow-ok:` marker on the same line or in
/// the contiguous `//` comment block directly above it.
pub fn swallow_sites(src: &str) -> Vec<(usize, bool)> {
    let tagged = tag_lines(src);
    let mut out = Vec::new();
    for (idx, (n, in_test, raw)) in tagged.iter().enumerate() {
        if *in_test || is_comment(raw) {
            continue;
        }
        if !discards(raw) {
            continue;
        }
        let mut justified = raw.contains(MARKER);
        let mut j = idx;
        while j > 0 && is_comment(tagged[j - 1].2) {
            j -= 1;
            if tagged[j].2.contains(MARKER) {
                justified = true;
            }
        }
        out.push((*n, justified));
    }
    out
}

pub fn check(root: &Path, allow: &Allowlist, v: &mut Vec<Violation>) {
    let mut files = Vec::new();
    rust_files(&root.join("rust/src/mpwide"), &mut files);
    rust_files(&root.join("rust/src/util"), &mut files);
    let mut seen: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for path in files {
        let rel = rel_to(root, &path);
        if is_lint_exempt(&rel) {
            continue;
        }
        let Ok(src) = fs::read_to_string(&path) else {
            v.push(violation(&rel, 0, "unreadable file".into()));
            continue;
        };
        for (n, justified) in swallow_sites(&src) {
            if justified {
                let e = seen.entry(rel.clone()).or_insert((0, n));
                e.0 += 1;
            } else {
                v.push(violation(
                    &rel,
                    n,
                    "swallowed `Result`: `let _ =` in library code — propagate a typed \
                     `MpwError`, or justify with `// swallow-ok: <reason>` and a [swallow] \
                     allowlist budget"
                        .into(),
                ));
            }
        }
    }
    allow::check_section(allow, "swallow", &seen, "justified `let _ =`", v);
}

#[cfg(test)]
mod tests {
    use super::*;

    const BAD_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/swallow_bad.rs.fixture"
    ));
    const OK_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/swallow_ok.rs.fixture"
    ));

    #[test]
    fn unjustified_discard_is_flagged() {
        let sites = swallow_sites(BAD_FIXTURE);
        // one bare site (line 4) and one with an unrelated comment (line 7)
        assert_eq!(sites, vec![(4, false), (7, false)]);
    }

    #[test]
    fn justified_and_test_discards_pass() {
        let sites = swallow_sites(OK_FIXTURE);
        // inline marker (line 4) and comment-block marker (line 8);
        // the test-module discard is not a site at all
        assert_eq!(sites, vec![(4, true), (8, true)]);
    }

    #[test]
    fn discard_detection() {
        assert!(discards("    let _ = foo();"));
        assert!(discards("let _= foo();"));
        assert!(!discards("let _x = foo();"));
        assert!(!discards("outlet _ = 3;"), "word boundary before `let`");
        assert!(!discards("let x = foo();"));
    }
}
