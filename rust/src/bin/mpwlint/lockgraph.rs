//! Pass: static lock-acquisition graph.
//!
//! Three stages, all line-oriented over comment/literal-stripped source
//! (`scan::strip_line`):
//!
//! 1. **Rank map** — every `OrderedMutex::new(rank::X, ..)` construction
//!    is bound to the field/binding name on its left; the tree-wide
//!    invariant is that each *name* maps to exactly one rank
//!    (unique-name discipline — ambiguity is itself a lint failure, so
//!    `.lock()` receivers can be resolved by their final path segment).
//!    Constructions with no visible binding (e.g. inside `get_or_init`)
//!    are covered by a `// mpwlint-lock: <name> = <RANK>` annotation in
//!    the same file.
//! 2. **Guard tracking** — per file, a lexical walk tracks which guards
//!    are live (`let`-bound guards scoped by brace depth, temporaries
//!    for `match`/`if let` scrutinees, condvar waits consuming and
//!    rebinding their guard, `drop(g)` releasing early, and
//!    spawn-closure barriers resetting the held set inside a new
//!    thread's body). Each `.lock()` under a held guard records an
//!    acquisition edge `held-rank -> new-rank`; an edge to a *lower*
//!    rank is a rank inversion and fails immediately. Blocking probes
//!    (see `blocking`) run against the same held set.
//! 3. **Graph checks** — the name-level edge graph must be acyclic
//!    (catches equal-rank ABBA orders the runtime checker permits) and
//!    self-edge-free; the rank constants are cross-checked against the
//!    `mpwlint-rank` markers in `docs/CONCURRENCY.md` so code and docs
//!    cannot drift. `--emit-lockgraph` serializes the edge set as DOT.
//!
//! Limits (documented in CONCURRENCY.md §1): the walk is lexical, not
//! interprocedural — a helper that blocks while its *caller* holds a
//! guard is invisible here and remains the runtime checker's and
//! TSan's job. The pass proves ordering for every path it can see,
//! including ones no test executes.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::Path;

use crate::allow::{self, Allowlist};
use crate::blocking;
use crate::scan::{
    is_ident, is_lint_exempt, leading_ident, rel_to, rust_files, strip_line, tag_lines,
    trailing_ident, violation, Violation,
};

pub const LOCKORDER: &str = "rust/src/util/lockorder.rs";
pub const CONCURRENCY_DOC: &str = "docs/CONCURRENCY.md";

// ---------------------------------------------------------------------------
// rank constants and doc markers

/// Parse `pub const NAME: u16 = N;` lines (the `lockorder::rank` table).
pub fn parse_rank_consts(src: &str) -> BTreeMap<String, u16> {
    let mut out = BTreeMap::new();
    for line in src.lines() {
        let t = line.trim_start();
        let Some(rest) = t.strip_prefix("pub const ") else { continue };
        let Some((name, rest)) = rest.split_once(':') else { continue };
        let Some((ty, rhs)) = rest.split_once('=') else { continue };
        if ty.trim() != "u16" {
            continue;
        }
        let Some(valtxt) = rhs.split(';').next() else { continue };
        if let Ok(val) = valtxt.trim().parse::<u16>() {
            out.insert(name.trim().to_string(), val);
        }
    }
    out
}

/// Cross-check `<!-- mpwlint-rank: NAME = N -->` markers in
/// `docs/CONCURRENCY.md` against the rank constants, both directions:
/// every marker must match a constant, every constant must be marked.
pub fn check_rank_markers(doc: &str, ranks: &BTreeMap<String, u16>, v: &mut Vec<Violation>) {
    const TAG: &str = "<!-- mpwlint-rank:";
    let mut seen: BTreeSet<String> = BTreeSet::new();
    for (i, line) in doc.lines().enumerate() {
        let Some(start) = line.find(TAG) else { continue };
        let rest = &line[start + TAG.len()..];
        let Some(end) = rest.find("-->") else {
            v.push(violation(CONCURRENCY_DOC, i + 1, "unterminated mpwlint-rank marker".into()));
            continue;
        };
        let body = rest[..end].trim();
        let Some((name, val)) = body.split_once('=') else {
            v.push(violation(CONCURRENCY_DOC, i + 1, format!("marker missing `=`: {body:?}")));
            continue;
        };
        let (name, val) = (name.trim(), val.trim());
        let Ok(val) = val.parse::<u16>() else {
            v.push(violation(CONCURRENCY_DOC, i + 1, format!("bad rank value in marker: {body:?}")));
            continue;
        };
        match ranks.get(name) {
            None => v.push(violation(
                CONCURRENCY_DOC,
                i + 1,
                format!("marker documents unknown rank `{name}` — not in {LOCKORDER}"),
            )),
            Some(actual) if *actual != val => v.push(violation(
                CONCURRENCY_DOC,
                i + 1,
                format!("rank `{name}` documented as {val} but {LOCKORDER} defines {actual}"),
            )),
            _ => {}
        }
        if !seen.insert(name.to_string()) {
            v.push(violation(CONCURRENCY_DOC, i + 1, format!("duplicate mpwlint-rank marker for `{name}`")));
        }
    }
    for (name, val) in ranks {
        if !seen.contains(name) {
            v.push(violation(
                CONCURRENCY_DOC,
                0,
                format!(
                    "rank `{name}` ({val}) has no mpwlint-rank marker — add \
                     `<!-- mpwlint-rank: {name} = {val} -->` to the rank table"
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// rank map: lock name -> rank

pub struct RankMap {
    /// name -> (rank name, rank value)
    pub resolve: BTreeMap<String, (String, u16)>,
}

/// Binding name to the left of an `OrderedMutex::new(` construction:
/// a struct-literal field (`name: `), or a `let`/`static` binding
/// (`let [mut] name [: Ty] = `).
fn construction_binding(head: &str) -> Option<String> {
    let t = head.trim_end();
    if let Some(t2) = t.strip_suffix(':') {
        return trailing_ident(t2).map(str::to_string);
    }
    let t2 = t.strip_suffix('=')?;
    let toks: Vec<&str> = t2.split_whitespace().collect();
    let kw = toks.iter().position(|&w| w == "let" || w == "static")?;
    let mut j = kw + 1;
    if toks.get(j) == Some(&"mut") {
        j += 1;
    }
    let name = toks.get(j)?.split(':').next()?;
    if is_ident(name) {
        Some(name.to_string())
    } else {
        None
    }
}

/// Find `rank::NAME` on the (stripped) line starting at `from`, or on
/// one of the next few stripped lines (multi-line constructions).
fn rank_arg(stripped: &[(usize, bool, String)], idx: usize, from: usize) -> Option<String> {
    let mut look: &str = &stripped[idx].2[from..];
    for step in 0..5 {
        if let Some(p) = look.find("rank::") {
            return leading_ident(&look[p + "rank::".len()..]).map(str::to_string);
        }
        look = &stripped.get(idx + 1 + step)?.2;
    }
    None
}

/// Build the tree-wide lock-name → rank map from every
/// `OrderedMutex::new` construction plus `mpwlint-lock` annotations.
/// Ambiguous names (two ranks), unknown ranks and unannotated anonymous
/// constructions are violations.
pub fn build_rank_map(
    sources: &[(String, String)],
    ranks: &BTreeMap<String, u16>,
    v: &mut Vec<Violation>,
) -> RankMap {
    // name -> rankname -> sites
    let mut cand: BTreeMap<String, BTreeMap<String, Vec<(String, usize)>>> = BTreeMap::new();
    for (rel, src) in sources {
        let tagged = tag_lines(src);
        let mut stripped: Vec<(usize, bool, String)> = Vec::with_capacity(tagged.len());
        let mut bc = false;
        for (n, t, raw) in &tagged {
            stripped.push((*n, *t, strip_line(raw, &mut bc)));
        }
        // annotations: `// mpwlint-lock: <name> = <RANK>` (raw lines —
        // they live in comments)
        let mut file_annotated_ranks: BTreeSet<String> = BTreeSet::new();
        for (n, _, raw) in &tagged {
            let Some(p) = raw.find("mpwlint-lock:") else { continue };
            let rest = &raw[p + "mpwlint-lock:".len()..];
            let Some((name, rankpart)) = rest.split_once('=') else {
                v.push(violation(rel, *n, "malformed mpwlint-lock annotation (expected `name = RANK`)".into()));
                continue;
            };
            let name = name.trim();
            let Some(rank) = leading_ident(rankpart) else {
                v.push(violation(rel, *n, "malformed mpwlint-lock annotation (expected `name = RANK`)".into()));
                continue;
            };
            if !is_ident(name) {
                v.push(violation(rel, *n, format!("mpwlint-lock annotation name `{name}` is not an identifier")));
                continue;
            }
            cand.entry(name.to_string())
                .or_default()
                .entry(rank.to_string())
                .or_default()
                .push((rel.clone(), *n));
            file_annotated_ranks.insert(rank.to_string());
        }
        for idx in 0..stripped.len() {
            let (n, in_test, _) = (stripped[idx].0, stripped[idx].1, ());
            if in_test {
                continue;
            }
            let mut from = 0;
            loop {
                let s = &stripped[idx].2;
                let Some(p) = s[from..].find("OrderedMutex::new(") else { break };
                let abs = from + p;
                let end = abs + "OrderedMutex::new(".len();
                let rank = rank_arg(&stripped, idx, end);
                let binding = construction_binding(&stripped[idx].2[..abs]);
                match (rank, binding) {
                    (None, _) => v.push(violation(
                        rel,
                        n,
                        "OrderedMutex construction without a visible `rank::` argument".into(),
                    )),
                    (Some(rank), Some(name)) => {
                        cand.entry(name).or_default().entry(rank).or_default().push((rel.clone(), n));
                    }
                    (Some(rank), None) => {
                        // anonymous (e.g. inside get_or_init) — fine if a
                        // same-file annotation covers this rank
                        if !file_annotated_ranks.contains(&rank) {
                            v.push(violation(
                                rel,
                                n,
                                format!(
                                    "anonymous OrderedMutex::new(rank::{rank}) — bind it to a \
                                     name or add `// mpwlint-lock: <name> = {rank}`"
                                ),
                            ));
                        }
                    }
                }
                from = end;
            }
        }
    }
    let mut resolve = BTreeMap::new();
    for (name, by_rank) in cand {
        if by_rank.len() > 1 {
            let detail: Vec<String> = by_rank
                .iter()
                .map(|(rk, sites)| format!("{rk} at {}:{}", sites[0].0, sites[0].1))
                .collect();
            let first = by_rank.values().next().and_then(|s| s.first()).cloned();
            let (f, l) = first.unwrap_or_default();
            v.push(violation(
                &f,
                l,
                format!(
                    "ambiguous lock name `{name}` maps to multiple ranks ({}) — rename the \
                     fields so every lock name is tree-wide unique",
                    detail.join(", ")
                ),
            ));
            continue;
        }
        let (rankname, sites) = by_rank.into_iter().next().expect("non-empty");
        match ranks.get(&rankname) {
            Some(val) => {
                resolve.insert(name, (rankname, *val));
            }
            None => {
                let (f, l) = sites[0].clone();
                v.push(violation(&f, l, format!("unknown rank `{rankname}` for lock `{name}`")));
            }
        }
    }
    RankMap { resolve }
}

// ---------------------------------------------------------------------------
// guard tracking

#[derive(Clone)]
struct Guard {
    name: String,
    rankname: String,
    rankval: u16,
    /// Brace depth at which the binding lives; popped when the scope
    /// closes below it.
    depth: i64,
    /// `barriers.len()` at bind time — a guard bound outside a spawn
    /// closure is not "held" by the code inside it.
    barrier_idx: usize,
}

#[derive(Default)]
pub struct Analysis {
    /// (held rank name, acquired rank name) -> acquisition sites.
    pub edges: BTreeMap<(String, String), Vec<(String, usize)>>,
    /// Blocking calls under a non-exempt guard: (file, line, message).
    pub blocking: Vec<(String, usize, String)>,
}

/// `self.inner.st` -> `st`; `ctx()` -> `ctx`.
fn last_segment(expr: &str) -> &str {
    let seg = expr.rsplit('.').next().unwrap_or(expr);
    seg.strip_suffix("()").unwrap_or(seg)
}

/// The receiver expression ending at byte `end` (exclusive): the
/// longest suffix of identifier/`.`/`()` characters.
fn receiver_before(s: &str, end: usize) -> &str {
    let b = s.as_bytes();
    let mut i = end;
    while i > 0 {
        let c = b[i - 1];
        if c.is_ascii_alphanumeric() || c == b'_' || c == b'.' || c == b'(' || c == b')' {
            i -= 1;
        } else {
            break;
        }
    }
    &s[i..end]
}

/// Guard binding to the left of a `.lock()` receiver: `let [mut] g =`
/// binds a new guard, a bare `g =` re-locks into an existing one.
enum Bind {
    Let(String),
    Reassign(String),
}

fn bind_before(before: &str) -> Option<Bind> {
    let t = before.trim_end();
    let t = t.strip_suffix('=')?;
    if t.ends_with(|c: char| "=<>!+-*/&|^".contains(c)) {
        return None; // `==`, `+=`, `<=`, ... are not bindings
    }
    let toks: Vec<&str> = t.split_whitespace().collect();
    match toks.as_slice() {
        ["let", name] | ["let", "mut", name] => {
            let name = name.split(':').next()?;
            is_ident(name).then(|| Bind::Let(name.to_string()))
        }
        [name] => is_ident(name).then(|| Bind::Reassign(name.to_string())),
        _ => None,
    }
}

/// First condvar-wait argument on the line: the guard identifier in
/// `.wait(g)` / `.wait_timeout(g, ..)` / `.wait_while(g, ..)`. Waits
/// with no guard argument (`handle.wait()`) are not condvar waits.
fn wait_arg(s: &str) -> Option<String> {
    for pat in [".wait_timeout(", ".wait_while(", ".wait("] {
        if let Some(p) = s.find(pat) {
            if let Some(id) = leading_ident(&s[p + pat.len()..]) {
                return Some(id.to_string());
            }
        }
    }
    None
}

/// Walk one file, recording acquisition edges, rank inversions,
/// unresolvable lock names and blocking-under-lock hits.
pub fn analyze_file(
    rel: &str,
    src: &str,
    rmap: &RankMap,
    out: &mut Analysis,
    v: &mut Vec<Violation>,
) {
    let tagged = tag_lines(src);
    let mut bc = false;
    let mut depth: i64 = 0;
    let mut guards: Vec<Guard> = Vec::new();
    let mut barriers: Vec<i64> = Vec::new();
    for (n, in_test, raw) in tagged {
        let s = strip_line(raw, &mut bc);
        if in_test {
            // still track braces so depth stays consistent
            depth += brace_delta(&s);
            continue;
        }
        let depth_at_start = depth;
        let mut line_temps: Vec<Guard> = Vec::new();

        // `drop(g)` releases a guard early
        let mut from = 0;
        while let Some(p) = s[from..].find("drop(") {
            let abs = from + p;
            let inner = &s[abs + "drop(".len()..];
            if let Some(id) = leading_ident(inner) {
                if inner[id.len()..].starts_with(')') {
                    if let Some(i) = guards.iter().rposition(|g| g.name == id) {
                        guards.remove(i);
                    }
                }
            }
            from = abs + "drop(".len();
        }

        // guard rename / move: a plain `a = b;` or `let a = b;` where
        // `b` is a live guard
        if let Some((lhs, rhs)) = plain_move(&s) {
            if let Some(g) = guards.iter_mut().find(|g| g.name == rhs) {
                g.name = lhs;
            }
        }

        // condvar waits: the guard is consumed and (usually) rebound
        if let Some(warg) = wait_arg(&s) {
            let held_now: Vec<&Guard> = guards
                .iter()
                .filter(|g| g.barrier_idx == barriers.len() && g.name != warg)
                .filter(|g| !blocking::is_exempt(&g.rankname))
                .collect();
            if let Some(top) = held_now.last() {
                out.blocking.push((
                    rel.to_string(),
                    n,
                    format!("condvar wait while holding {}", top.rankname),
                ));
            }
            let t = s.trim_start();
            if t.starts_with("let _ =") || t.starts_with("let _=") || t.starts_with("drop(") {
                // `let _ = cv.wait_timeout(g, ..)` / `drop(cv.wait*(g))`
                // discard the returned guard — it is gone
                if let Some(i) = guards.iter().rposition(|g| g.name == warg) {
                    guards.remove(i);
                }
            } else if let Some(newname) = tuple_rebind(t) {
                // `let (g2, _) = cv.wait_timeout(g, ..)`
                if let Some(g) = guards.iter_mut().find(|g| g.name == warg) {
                    g.name = newname;
                }
            }
            // plain `g = cv.wait(g);` rebinds to the same name: no-op
        }

        // lock sites, left to right
        let mut from = 0;
        while let Some(p) = s[from..].find(".lock()") {
            let abs = from + p;
            from = abs + ".lock()".len();
            let recv = receiver_before(&s, abs);
            let seg = last_segment(recv);
            let Some((rankname, rankval)) = rmap.resolve.get(seg) else {
                v.push(violation(
                    rel,
                    n,
                    format!(
                        "cannot resolve the rank of `{recv}.lock()` (name `{seg}` has no \
                         OrderedMutex construction or mpwlint-lock annotation)"
                    ),
                ));
                continue;
            };
            let held: Vec<&Guard> = guards
                .iter()
                .filter(|g| g.barrier_idx == barriers.len())
                .chain(line_temps.iter())
                .collect();
            if let Some(top) = held.iter().max_by_key(|g| g.rankval) {
                out.edges
                    .entry((top.rankname.clone(), rankname.clone()))
                    .or_default()
                    .push((rel.to_string(), n));
                if *rankval < top.rankval {
                    v.push(violation(
                        rel,
                        n,
                        format!(
                            "rank inversion: acquiring {rankname}({rankval}) while holding \
                             {}({})",
                            top.rankname, top.rankval
                        ),
                    ));
                }
            }
            let after = &s[abs + ".lock()".len()..];
            let before = &s[..abs - recv.len()];
            match bind_before(before) {
                Some(Bind::Let(name)) if after.starts_with(';') => {
                    if name != "_" {
                        guards.retain(|g| g.name != name);
                        guards.push(Guard {
                            name,
                            rankname: rankname.clone(),
                            rankval: *rankval,
                            depth: depth_at_start,
                            barrier_idx: barriers.len(),
                        });
                    }
                }
                Some(Bind::Reassign(name)) if after.starts_with(';') => {
                    if let Some(g) = guards.iter_mut().find(|g| g.name == name) {
                        g.rankname = rankname.clone();
                        g.rankval = *rankval;
                    } else {
                        guards.push(Guard {
                            name,
                            rankname: rankname.clone(),
                            rankval: *rankval,
                            depth: depth_at_start,
                            barrier_idx: barriers.len(),
                        });
                    }
                }
                _ if s.trim_end().ends_with('{') => {
                    // `match x.lock() {` / `if let .. = x.lock() {`: the
                    // scrutinee temporary lives for the whole block
                    guards.push(Guard {
                        name: format!("<temp {seg}>"),
                        rankname: rankname.clone(),
                        rankval: *rankval,
                        depth: depth_at_start + 1,
                        barrier_idx: barriers.len(),
                    });
                }
                _ => {
                    // expression temporary: held to the end of this line
                    line_temps.push(Guard {
                        name: format!("<line {seg}>"),
                        rankname: rankname.clone(),
                        rankval: *rankval,
                        depth: depth_at_start + 1,
                        barrier_idx: barriers.len(),
                    });
                }
            }
        }

        // blocking probes against everything held on this line
        let held: Vec<&Guard> = guards
            .iter()
            .filter(|g| g.barrier_idx == barriers.len())
            .chain(line_temps.iter())
            .filter(|g| !blocking::is_exempt(&g.rankname))
            .collect();
        if let Some(top) = held.last() {
            if let Some(tok) = blocking::blocking_token(&s) {
                out.blocking.push((
                    rel.to_string(),
                    n,
                    format!("`{}` while holding {}", tok.trim_matches(|c| c == '.' || c == '('), top.rankname),
                ));
            }
        }

        // spawn-closure barrier: code inside a freshly spawned thread's
        // closure starts with an empty held set
        let spawned =
            s.contains("spawn(") || s.contains("submit(") || s.contains("Builder::new()");
        let opens_closure =
            s.contains("move |") || (s.contains('|') && s.trim_end().ends_with('{'));
        if spawned && opens_closure {
            barriers.push(depth_at_start + 1);
        }
        depth += brace_delta(&s);
        guards.retain(|g| g.depth <= depth);
        barriers.retain(|b| *b <= depth);
        let nb = barriers.len();
        for g in &mut guards {
            g.barrier_idx = g.barrier_idx.min(nb);
        }
    }
}

fn brace_delta(s: &str) -> i64 {
    let mut d = 0;
    for c in s.chars() {
        match c {
            '{' => d += 1,
            '}' => d -= 1,
            _ => {}
        }
    }
    d
}

/// `a = b;` / `let [mut] a = b;` with both sides plain identifiers.
fn plain_move(s: &str) -> Option<(String, String)> {
    let t = s.trim();
    let t = t.strip_suffix(';')?;
    let (lhs, rhs) = t.split_once('=')?;
    let rhs = rhs.trim();
    let mut lhs = lhs.trim();
    if let Some(r) = lhs.strip_prefix("let ") {
        lhs = r.trim_start().strip_prefix("mut ").unwrap_or(r).trim();
    }
    (is_ident(lhs) && is_ident(rhs)).then(|| (lhs.to_string(), rhs.to_string()))
}

/// `let (g2, _) = ...` — the first tuple element rebinds the guard.
fn tuple_rebind(trimmed: &str) -> Option<String> {
    let rest = trimmed.strip_prefix("let (").or_else(|| trimmed.strip_prefix("let("))?;
    leading_ident(rest).map(str::to_string)
}

// ---------------------------------------------------------------------------
// graph checks and DOT output

/// The name-level acquisition graph must have no self-edges (a lock
/// name acquired while an instance of the same name is held — the
/// cross-instance order is unprovable statically) and no cycles
/// (equal-rank ABBA orders that the pointwise rank check permits).
pub fn check_cycles(analysis: &Analysis, v: &mut Vec<Violation>) {
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for ((a, b), sites) in &analysis.edges {
        if a == b {
            let (f, l) = &sites[0];
            v.push(violation(
                f,
                *l,
                format!(
                    "self-edge: `{a}` acquired while an instance of `{a}` is already held — \
                     cross-instance ordering cannot be proven statically"
                ),
            ));
            continue;
        }
        adj.entry(a.as_str()).or_default().insert(b.as_str());
    }
    // DFS, white/gray/black
    let mut color: BTreeMap<&str, u8> = BTreeMap::new();
    fn dfs<'a>(
        node: &'a str,
        adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
        color: &mut BTreeMap<&'a str, u8>,
        stack: &mut Vec<&'a str>,
    ) -> Option<Vec<String>> {
        color.insert(node, 1);
        stack.push(node);
        if let Some(next) = adj.get(node) {
            for &m in next {
                match color.get(m).copied().unwrap_or(0) {
                    0 => {
                        if let Some(cycle) = dfs(m, adj, color, stack) {
                            return Some(cycle);
                        }
                    }
                    1 => {
                        let start = stack.iter().position(|&x| x == m).unwrap_or(0);
                        let mut cycle: Vec<String> =
                            stack[start..].iter().map(|s| s.to_string()).collect();
                        cycle.push(m.to_string());
                        return Some(cycle);
                    }
                    _ => {}
                }
            }
        }
        stack.pop();
        color.insert(node, 2);
        None
    }
    let nodes: Vec<&str> = adj.keys().copied().collect();
    for node in nodes {
        if color.get(node).copied().unwrap_or(0) == 0 {
            let mut stack = Vec::new();
            if let Some(cycle) = dfs(node, &adj, &mut color, &mut stack) {
                let first_edge = (cycle[0].clone(), cycle[1].clone());
                let (f, l) = analysis
                    .edges
                    .get(&first_edge)
                    .and_then(|s| s.first())
                    .cloned()
                    .unwrap_or_default();
                v.push(violation(
                    &f,
                    l,
                    format!(
                        "lock-acquisition cycle: {} — some thread orders these locks the \
                         other way around (deadlock)",
                        cycle.join(" -> ")
                    ),
                ));
                return; // one cycle report is enough to fail the build
            }
        }
    }
}

/// Serialize the acquisition graph as Graphviz DOT (CI artifact; the
/// CONCURRENCY.md thread-inventory diagram is drawn from this).
pub fn dot(ranks: &BTreeMap<String, u16>, rmap: &RankMap, analysis: &Analysis) -> String {
    let mut used: BTreeMap<&str, u16> = BTreeMap::new();
    for (rankname, val) in rmap.resolve.values() {
        used.insert(rankname.as_str(), *val);
    }
    for ((a, b), _) in &analysis.edges {
        for r in [a, b] {
            if let Some(val) = ranks.get(r.as_str()) {
                used.insert(r.as_str(), *val);
            }
        }
    }
    let mut nodes: Vec<(&str, u16)> = used.into_iter().collect();
    nodes.sort_by_key(|(name, val)| (*val, name.to_string()));
    let mut out = String::new();
    out.push_str("// Lock-acquisition graph extracted by `mpwlint --emit-lockgraph`.\n");
    out.push_str("// Nodes are lock ranks (util::lockorder::rank); an edge A -> B means\n");
    out.push_str("// some code path acquires B while holding A. Render with:\n");
    out.push_str("//   dot -Tsvg lockgraph.dot -o lockgraph.svg\n");
    out.push_str("digraph mpwide_locks {\n");
    out.push_str("  rankdir=LR;\n");
    out.push_str("  node [shape=box, fontname=\"monospace\"];\n");
    for (name, val) in &nodes {
        out.push_str(&format!("  \"{name} ({val})\";\n"));
    }
    for ((a, b), sites) in &analysis.edges {
        let av = ranks.get(a.as_str()).copied().unwrap_or(0);
        let bv = ranks.get(b.as_str()).copied().unwrap_or(0);
        out.push_str(&format!(
            "  \"{a} ({av})\" -> \"{b} ({bv})\" [label=\"{} site(s)\"];\n",
            sites.len()
        ));
    }
    out.push_str("}\n");
    out
}

// ---------------------------------------------------------------------------
// top-level pass

pub struct Graph {
    pub ranks: BTreeMap<String, u16>,
    pub rmap: RankMap,
    pub analysis: Analysis,
}

pub fn check(root: &Path, allow: &Allowlist, v: &mut Vec<Violation>) -> Graph {
    let mut empty = Graph {
        ranks: BTreeMap::new(),
        rmap: RankMap { resolve: BTreeMap::new() },
        analysis: Analysis::default(),
    };
    let Ok(lo) = fs::read_to_string(root.join(LOCKORDER)) else {
        v.push(violation(LOCKORDER, 0, "missing lockorder.rs — cannot build the rank table".into()));
        return empty;
    };
    let ranks = parse_rank_consts(&lo);
    if ranks.is_empty() {
        v.push(violation(LOCKORDER, 0, "no `pub const NAME: u16 = ..;` rank constants found".into()));
        return empty;
    }
    match fs::read_to_string(root.join(CONCURRENCY_DOC)) {
        Ok(doc) => check_rank_markers(&doc, &ranks, v),
        Err(_) => v.push(violation(CONCURRENCY_DOC, 0, "missing concurrency doc".into())),
    }
    let mut files = Vec::new();
    rust_files(&root.join("rust/src"), &mut files);
    let mut sources: Vec<(String, String)> = Vec::new();
    for path in files {
        let rel = rel_to(root, &path);
        if is_lint_exempt(&rel) {
            continue;
        }
        let Ok(src) = fs::read_to_string(&path) else {
            v.push(violation(&rel, 0, "unreadable file".into()));
            continue;
        };
        sources.push((rel, src));
    }
    let rmap = build_rank_map(&sources, &ranks, v);
    let mut analysis = Analysis::default();
    for (rel, src) in &sources {
        analyze_file(rel, src, &rmap, &mut analysis, v);
    }
    check_cycles(&analysis, v);
    // blocking hits against the [blocking] allowlist section
    let mut seen: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for (file, line, _) in &analysis.blocking {
        let e = seen.entry(file.clone()).or_insert((0, *line));
        e.0 += 1;
    }
    for (file, line, msg) in &analysis.blocking {
        let budget = allow.budget("blocking", file);
        if seen.get(file).map_or(0, |(c, _)| *c) > budget {
            v.push(violation(
                file,
                *line,
                format!("{msg} — blocking under a coordination lock ([blocking] budget {budget})"),
            ));
        }
    }
    allow::check_stale(allow, "blocking", &seen, v);
    empty.ranks = ranks;
    empty.rmap = rmap;
    empty.analysis = analysis;
    empty
}

#[cfg(test)]
mod tests {
    use super::*;

    const OK_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/lockgraph_ok.rs.fixture"
    ));
    const BAD_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/lockgraph_bad.rs.fixture"
    ));
    const CYCLE_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/lockgraph_cycle.rs.fixture"
    ));

    fn run(src: &str) -> (Vec<Violation>, Analysis) {
        // fixtures are self-contained: they carry their own rank consts
        let ranks = parse_rank_consts(src);
        assert!(!ranks.is_empty(), "fixture must define rank consts");
        let sources = vec![("fixture.rs".to_string(), src.to_string())];
        let mut v = Vec::new();
        let rmap = build_rank_map(&sources, &ranks, &mut v);
        let mut analysis = Analysis::default();
        analyze_file("fixture.rs", src, &rmap, &mut analysis, &mut v);
        check_cycles(&analysis, &mut v);
        (v, analysis)
    }

    #[test]
    fn clean_fixture_passes_with_downward_edges() {
        let (v, analysis) = run(OK_FIXTURE);
        let msgs: Vec<&str> = v.iter().map(|x| x.msg.as_str()).collect();
        assert!(v.is_empty(), "unexpected violations: {msgs:?}");
        assert!(
            analysis.edges.contains_key(&("OUTER".to_string(), "INNER".to_string())),
            "expected OUTER -> INNER edge, got {:?}",
            analysis.edges.keys().collect::<Vec<_>>()
        );
        // guard dropped before the re-lock: no INNER -> OUTER edge
        assert!(!analysis.edges.contains_key(&("INNER".to_string(), "OUTER".to_string())));
    }

    #[test]
    fn rank_inversion_is_detected() {
        let (v, _) = run(BAD_FIXTURE);
        assert!(
            v.iter().any(|x| x.msg.contains("rank inversion")
                && x.msg.contains("OUTER(10)")
                && x.msg.contains("INNER(20)")),
            "expected an inversion violation, got: {:?}",
            v.iter().map(|x| &x.msg).collect::<Vec<_>>()
        );
    }

    #[test]
    fn equal_rank_abba_cycle_is_detected() {
        let (v, _) = run(CYCLE_FIXTURE);
        assert!(
            v.iter().any(|x| x.msg.contains("lock-acquisition cycle")),
            "expected a cycle violation, got: {:?}",
            v.iter().map(|x| &x.msg).collect::<Vec<_>>()
        );
        // equal values: the pointwise rank check must NOT fire
        assert!(!v.iter().any(|x| x.msg.contains("rank inversion")));
    }

    #[test]
    fn rank_consts_parse() {
        let ranks = parse_rank_consts("pub const A: u16 = 10;\npub const B: u16 = 20;\nconst C: u32 = 9;\n");
        assert_eq!(ranks.len(), 2);
        assert_eq!(ranks["A"], 10);
        assert_eq!(ranks["B"], 20);
    }

    #[test]
    fn rank_markers_check_both_directions() {
        let mut ranks = BTreeMap::new();
        ranks.insert("A".to_string(), 10u16);
        ranks.insert("B".to_string(), 20u16);
        let mut v = Vec::new();
        check_rank_markers(
            "| 10 | `A` | <!-- mpwlint-rank: A = 10 -->\n| 99 | `B` | <!-- mpwlint-rank: B = 99 -->\n<!-- mpwlint-rank: C = 5 -->\n",
            &ranks,
            &mut v,
        );
        let msgs: Vec<&str> = v.iter().map(|x| x.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("documented as 99")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("unknown rank `C`")), "{msgs:?}");
        // A is fine; B has a (wrong) marker, so no "missing marker" for it
        assert!(!msgs.iter().any(|m| m.contains("no mpwlint-rank marker")), "{msgs:?}");
    }

    #[test]
    fn dot_output_is_deterministic() {
        let (_, analysis) = run(OK_FIXTURE);
        let ranks = parse_rank_consts(OK_FIXTURE);
        let sources = vec![("fixture.rs".to_string(), OK_FIXTURE.to_string())];
        let mut v = Vec::new();
        let rmap = build_rank_map(&sources, &ranks, &mut v);
        let d = dot(&ranks, &rmap, &analysis);
        assert!(d.starts_with("// Lock-acquisition graph"));
        assert!(d.contains("digraph mpwide_locks"));
        assert!(d.contains("\"OUTER (10)\" -> \"INNER (20)\""), "{d}");
    }
}
