//! Pass: panic ban — no `.unwrap()` / `.expect(` in `rust/src/mpwide/**`
//! outside `#[cfg(test)]` regions and comments, budgeted by the
//! `[panics]` allowlist section (provably-infallible codec `try_into`s).

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use crate::allow::{self, Allowlist};
use crate::scan::{is_comment, rel_to, rust_files, tag_lines, violation, Violation};

/// Line numbers of `.unwrap()` / `.expect(` hits in non-test,
/// non-comment code.
pub fn panic_sites(src: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    for (n, in_test, line) in tag_lines(src) {
        if in_test || is_comment(line) {
            continue;
        }
        if line.contains(".unwrap()") || line.contains(".expect(") {
            hits.push(n);
        }
    }
    hits
}

pub fn check(root: &Path, allow: &Allowlist, v: &mut Vec<Violation>) {
    let mut files = Vec::new();
    rust_files(&root.join("rust/src/mpwide"), &mut files);
    let mut seen: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for path in files {
        let rel = rel_to(root, &path);
        let Ok(src) = fs::read_to_string(&path) else {
            v.push(violation(&rel, 0, "unreadable file".into()));
            continue;
        };
        let hits = panic_sites(&src);
        if !hits.is_empty() {
            seen.insert(rel, (hits.len(), hits[0]));
        }
    }
    allow::check_section(allow, "panics", &seen, "`.unwrap()`/`.expect(`", v);
}

#[cfg(test)]
mod tests {
    use super::*;

    const PANIC_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/panics.rs.fixture"
    ));

    #[test]
    fn panic_sites_skip_tests_and_comments() {
        // Fixture layout: unwrap at lines 4 and 8, expect at line 9,
        // commented unwrap at line 6, test-mod unwrap near the end.
        assert_eq!(panic_sites(PANIC_FIXTURE), vec![4, 8, 9]);
    }
}
