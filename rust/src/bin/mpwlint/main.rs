//! `mpwlint` — the in-tree project lint.
//!
//! Run with `cargo run --bin mpwlint` from anywhere in the workspace; it
//! exits non-zero on any violation and is wired into CI as a blocking
//! step. Plain line scanning, no external deps (same philosophy as the
//! vendored shims in `rust/vendor/`).
//!
//! Six passes:
//!
//! 1. **Panic ban** (`panics`) — no `.unwrap()` / `.expect(` in
//!    `rust/src/mpwide/**` outside `#[cfg(test)]` regions and comments,
//!    budgeted by the `[panics]` allowlist section.
//! 2. **Lock discipline** (`rawsync`) — no raw `std::sync`
//!    `Mutex`/`Condvar` tokens anywhere in `rust/src/**` except
//!    `util/lockorder.rs` (and test modules).
//! 3. **Protocol drift** (`consts`) — `docs/PROTOCOL.md`
//!    `mpwlint-const` markers vs. the constants in the source tree.
//! 4. **Static lock graph** (`lockgraph`) — every `OrderedMutex`
//!    construction and `.lock()`/`.wait*` site is parsed, live guards
//!    are tracked lexically, and the cross-rank acquisition graph must
//!    be inversion-free and acyclic. Rank constants are cross-checked
//!    against the `mpwlint-rank` markers in `docs/CONCURRENCY.md`.
//!    `--emit-lockgraph <path>` additionally writes the graph as DOT.
//! 5. **Blocking under lock** (`blocking` + `lockgraph`) — socket I/O,
//!    sleeps, joins and `Pacer::acquire` while a non-exempt guard is
//!    live, budgeted by the `[blocking]` allowlist section.
//! 6. **Swallowed results** (`swallow`) — `let _ =` in non-test
//!    `mpwide`/`util` code needs a `// swallow-ok:` justification and a
//!    `[swallow]` budget.
//!
//! The allowlist (`rust/mpwlint.allow`) is sectioned and shrink-only
//! *by entry*: burned-down entries become `<path> 0` tombstones rather
//! than being deleted, so old debt cannot silently reappear.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

mod allow;
mod blocking;
mod consts;
mod lockgraph;
mod panics;
mod rawsync;
mod scan;
mod swallow;

use scan::Violation;

fn main() -> ExitCode {
    // CARGO_MANIFEST_DIR is `<repo>/rust` for this binary.
    let Some(root) = Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(Path::to_path_buf)
    else {
        eprintln!("mpwlint: cannot locate repo root");
        return ExitCode::FAILURE;
    };
    let mut emit_dot: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--emit-lockgraph" => {
                let Some(p) = args.next() else {
                    eprintln!("mpwlint: --emit-lockgraph needs a path argument");
                    return ExitCode::FAILURE;
                };
                emit_dot = Some(PathBuf::from(p));
            }
            other => {
                eprintln!("mpwlint: unknown argument {other:?} (supported: --emit-lockgraph <path>)");
                return ExitCode::FAILURE;
            }
        }
    }

    let mut v: Vec<Violation> = Vec::new();
    let allowlist = allow::load(&root, &mut v);
    panics::check(&root, &allowlist, &mut v);
    rawsync::check(&root, &mut v);
    consts::check(&root, &mut v);
    let graph = lockgraph::check(&root, &allowlist, &mut v);
    swallow::check(&root, &allowlist, &mut v);

    if let Some(path) = emit_dot {
        let dot = lockgraph::dot(&graph.ranks, &graph.rmap, &graph.analysis);
        if let Err(e) = fs::write(&path, dot) {
            eprintln!("mpwlint: cannot write {}: {e}", path.display());
            return ExitCode::FAILURE;
        }
        println!("mpwlint: wrote lock graph to {}", path.display());
    }

    if v.is_empty() {
        println!(
            "mpwlint: OK (panic ban, lock discipline, protocol constants, lock graph \
             [{} locks, {} edges], blocking-under-lock, swallowed results)",
            graph.rmap.resolve.len(),
            graph.analysis.edges.len()
        );
        ExitCode::SUCCESS
    } else {
        for x in &v {
            eprintln!("mpwlint: {}:{}: {}", x.file, x.line, x.msg);
        }
        eprintln!("mpwlint: {} violation(s)", v.len());
        ExitCode::FAILURE
    }
}
