//! Pass: protocol-constant drift — `docs/PROTOCOL.md` carries
//! machine-checkable markers of the form
//! `<!-- mpwlint-const: <src-file> <NAME> = <value> -->`; each is
//! compared against the constant's definition in the source tree
//! (numeric where both sides evaluate, textual otherwise), so the
//! documented wire format cannot drift from the code.

use std::fs;
use std::path::Path;

use crate::scan::{violation, Violation};

pub struct Marker {
    pub doc_line: usize,
    pub file: String,
    pub name: String,
    pub expr: String,
}

/// Extract `<!-- mpwlint-const: <file> <NAME> = <expr> -->` markers.
pub fn parse_markers(doc: &str) -> (Vec<Marker>, Vec<(usize, String)>) {
    let mut markers = Vec::new();
    let mut errors = Vec::new();
    for (i, line) in doc.lines().enumerate() {
        let Some(start) = line.find("<!-- mpwlint-const:") else { continue };
        let rest = &line[start + "<!-- mpwlint-const:".len()..];
        let Some(end) = rest.find("-->") else {
            errors.push((i + 1, "unterminated mpwlint-const marker".into()));
            continue;
        };
        let body = rest[..end].trim();
        // `<file> <NAME> = <expr>` — expr may contain spaces.
        let Some((head, expr)) = body.split_once('=') else {
            errors.push((i + 1, format!("marker missing `=`: {body:?}")));
            continue;
        };
        let mut it = head.split_whitespace();
        let (Some(file), Some(name), None) = (it.next(), it.next(), it.next()) else {
            errors.push((i + 1, format!("marker head must be `<file> <NAME>`: {head:?}")));
            continue;
        };
        markers.push(Marker {
            doc_line: i + 1,
            file: file.to_string(),
            name: name.to_string(),
            expr: expr.trim().to_string(),
        });
    }
    (markers, errors)
}

/// Find `const NAME: ... = <expr>;` in a source file and return the
/// right-hand side text.
pub fn const_rhs(src: &str, name: &str) -> Option<String> {
    let needle = format!("const {name}:");
    for line in src.lines() {
        let Some(pos) = line.find(&needle) else { continue };
        let after = &line[pos + needle.len()..];
        let rhs = after.split_once('=')?.1;
        let rhs = rhs.split(';').next()?.trim();
        return Some(rhs.to_string());
    }
    None
}

/// Evaluate a small integer expression: decimal / `0x` hex literals
/// (optionally with `_` separators and a type suffix), combined with
/// `+`, `*` and `<<`. Returns `None` for anything else — the caller
/// falls back to normalized textual comparison.
pub fn eval_expr(s: &str) -> Option<u128> {
    let s = s.trim();
    if let Some(pos) = s.find("<<") {
        return eval_sum(&s[..pos])?.checked_shl(eval_expr(&s[pos + 2..])? as u32);
    }
    eval_sum(s)
}

fn eval_sum(s: &str) -> Option<u128> {
    let mut total: u128 = 0;
    for part in s.split('+') {
        total = total.checked_add(eval_prod(part)?)?;
    }
    Some(total)
}

fn eval_prod(s: &str) -> Option<u128> {
    let mut total: u128 = 1;
    for part in s.split('*') {
        total = total.checked_mul(eval_atom(part)?)?;
    }
    Some(total)
}

fn eval_atom(s: &str) -> Option<u128> {
    let t = s.trim().replace('_', "");
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        let hex = hex.trim_end_matches(|c: char| !c.is_ascii_hexdigit());
        return u128::from_str_radix(hex, 16).ok();
    }
    let dec = t.trim_end_matches(|c: char| c.is_ascii_alphabetic());
    dec.parse::<u128>().ok()
}

pub fn normalized(s: &str) -> String {
    s.chars().filter(|c| !c.is_whitespace()).collect()
}

const PROTOCOL_DOC: &str = "docs/PROTOCOL.md";

pub fn check(root: &Path, v: &mut Vec<Violation>) {
    let Ok(doc) = fs::read_to_string(root.join(PROTOCOL_DOC)) else {
        v.push(violation(PROTOCOL_DOC, 0, "missing protocol doc".into()));
        return;
    };
    let (markers, errors) = parse_markers(&doc);
    for (line, msg) in errors {
        v.push(violation(PROTOCOL_DOC, line, msg));
    }
    if markers.is_empty() {
        v.push(violation(
            PROTOCOL_DOC,
            0,
            "no mpwlint-const markers found — the drift check would silently pass".into(),
        ));
        return;
    }
    for m in &markers {
        let Ok(src) = fs::read_to_string(root.join(&m.file)) else {
            v.push(violation(PROTOCOL_DOC, m.doc_line, format!("marker points at unreadable file {}", m.file)));
            continue;
        };
        let Some(rhs) = const_rhs(&src, &m.name) else {
            v.push(violation(
                PROTOCOL_DOC,
                m.doc_line,
                format!("constant `{}` not found in {}", m.name, m.file),
            ));
            continue;
        };
        let matches = match (eval_expr(&m.expr), eval_expr(&rhs)) {
            (Some(a), Some(b)) => a == b,
            _ => normalized(&m.expr) == normalized(&rhs),
        };
        if !matches {
            v.push(violation(
                PROTOCOL_DOC,
                m.doc_line,
                format!("`{}` documented as `{}` but {} defines `{}`", m.name, m.expr, m.file, rhs),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/doc.md.fixture"
    ));
    const CONSTS_FIXTURE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mpwlint/consts.rs.fixture"
    ));

    #[test]
    fn expr_evaluator() {
        assert_eq!(eval_expr("18"), Some(18));
        assert_eq!(eval_expr("1 + 1 + 8 + 4 + 4"), Some(18));
        assert_eq!(eval_expr("64 << 20"), Some(64 << 20));
        assert_eq!(eval_expr("0xF5"), Some(0xF5));
        assert_eq!(eval_expr("2 * 3 + 4"), Some(10));
        assert_eq!(eval_expr("64usize"), Some(64));
        assert_eq!(eval_expr("*b\"MPW1\""), None);
    }

    #[test]
    fn markers_parse_and_compare() {
        let (markers, errors) = parse_markers(DOC_FIXTURE);
        assert!(errors.is_empty(), "{errors:?}");
        assert_eq!(markers.len(), 4);
        // The fixture doc and fixture source agree on the first three
        // markers and deliberately disagree on the fourth.
        let verdicts: Vec<bool> = markers
            .iter()
            .map(|m| {
                let rhs = const_rhs(CONSTS_FIXTURE, &m.name).expect("const present");
                match (eval_expr(&m.expr), eval_expr(&rhs)) {
                    (Some(a), Some(b)) => a == b,
                    _ => normalized(&m.expr) == normalized(&rhs),
                }
            })
            .collect();
        assert_eq!(verdicts, vec![true, true, true, false]);
    }

    #[test]
    fn const_rhs_extraction() {
        assert_eq!(const_rhs(CONSTS_FIXTURE, "MAGIC").as_deref(), Some("0xF5"));
        assert_eq!(const_rhs(CONSTS_FIXTURE, "HDR_LEN").as_deref(), Some("1 + 1 + 8 + 4 + 4"));
        assert_eq!(const_rhs(CONSTS_FIXTURE, "NOPE"), None);
    }
}
