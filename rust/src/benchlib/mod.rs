//! Minimal measurement harness for the `cargo bench` targets (the
//! `criterion` crate is unavailable in the offline build).
//!
//! Provides warmup + repeated sampling with summary statistics, a
//! fixed-width table printer used to emit the paper-style rows every
//! bench target regenerates (DESIGN.md §4), and a small JSON emitter
//! ([`BenchJson`]) writing `BENCH_<name>.json` files that CI archives as
//! artifacts so the perf trajectory is recorded per PR. Bench binaries
//! are declared `harness = false` and call these helpers from `main`.

use std::time::Instant;

use crate::util::stats;

/// Summary of repeated measurements of one quantity.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Label of the measured case.
    pub name: String,
    /// Raw samples (seconds, MB/s, … — caller-defined unit).
    pub samples: Vec<f64>,
}

impl Summary {
    /// Mean of the samples.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    /// Median (p50).
    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }

    /// 95th percentile.
    pub fn p95(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.samples)
    }

    /// Pretty one-liner: `name  mean ± sd  (median, p95)`.
    pub fn line(&self, unit: &str) -> String {
        format!(
            "{:<38} {:>10.3} ± {:>8.3} {unit}  (p50 {:.3}, p95 {:.3}, n={})",
            self.name,
            self.mean(),
            self.stddev(),
            self.median(),
            self.p95(),
            self.samples.len()
        )
    }
}

/// Measure `f` (which returns its own metric, e.g. seconds or MB/s):
/// `warmup` throwaway calls, then `samples` recorded calls.
pub fn sample_metric<F: FnMut() -> f64>(
    name: &str,
    warmup: usize,
    samples: usize,
    mut f: F,
) -> Summary {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut v = Vec::with_capacity(samples);
    for _ in 0..samples {
        v.push(f());
    }
    Summary { name: name.to_string(), samples: v }
}

/// Measure wall-clock seconds of `f` per call.
pub fn sample_seconds<F: FnMut()>(
    name: &str,
    warmup: usize,
    samples: usize,
    mut f: F,
) -> Summary {
    sample_metric(name, warmup, samples, || {
        let t0 = Instant::now();
        f();
        t0.elapsed().as_secs_f64()
    })
}

/// Fixed-width table printer for paper-style outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, width) in cells.iter().zip(w) {
                line.push_str(&format!("{c:<width$} | "));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &w));
        out.push('\n');
        out.push_str(&format!(
            "|{}|",
            w.iter().map(|x| "-".repeat(x + 2)).collect::<Vec<_>>().join("|")
        ));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Section banner for bench output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// One field of a [`BenchJson`] report.
#[derive(Debug, Clone)]
enum JsonField {
    Num(f64),
    Text(String),
    Series(Vec<f64>),
}

/// Flat JSON report for one bench run, written as `BENCH_<name>.json`.
///
/// The output directory is `$BENCH_OUT_DIR` when set, else the current
/// directory. Non-finite numbers serialize as `null` (JSON has no NaN).
#[derive(Debug, Clone)]
pub struct BenchJson {
    name: String,
    fields: Vec<(String, JsonField)>,
}

impl BenchJson {
    /// New report for the bench called `name`.
    pub fn new(name: &str) -> BenchJson {
        BenchJson { name: name.to_string(), fields: Vec::new() }
    }

    /// Add a numeric field.
    pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
        self.fields.push((key.to_string(), JsonField::Num(v)));
        self
    }

    /// Add a string field.
    pub fn text(&mut self, key: &str, v: &str) -> &mut Self {
        self.fields.push((key.to_string(), JsonField::Text(v.to_string())));
        self
    }

    /// Add an array-of-numbers field.
    pub fn series(&mut self, key: &str, v: &[f64]) -> &mut Self {
        self.fields.push((key.to_string(), JsonField::Series(v.to_vec())));
        self
    }

    fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    fn fmt_num(v: f64) -> String {
        if v.is_finite() {
            format!("{v}")
        } else {
            "null".into()
        }
    }

    /// Serialize to a JSON object string.
    pub fn render(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"bench\": \"{}\"", Self::escape(&self.name)));
        for (k, v) in &self.fields {
            out.push_str(", ");
            out.push_str(&format!("\"{}\": ", Self::escape(k)));
            match v {
                JsonField::Num(n) => out.push_str(&Self::fmt_num(*n)),
                JsonField::Text(s) => out.push_str(&format!("\"{}\"", Self::escape(s))),
                JsonField::Series(xs) => {
                    out.push('[');
                    for (i, x) in xs.iter().enumerate() {
                        if i > 0 {
                            out.push_str(", ");
                        }
                        out.push_str(&Self::fmt_num(*x));
                    }
                    out.push(']');
                }
            }
        }
        out.push_str("}\n");
        out
    }

    /// Write `BENCH_<name>.json` into `$BENCH_OUT_DIR` (or the current
    /// directory) and return its path.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
        self.write_to(std::path::Path::new(&dir))
    }

    /// Write `BENCH_<name>.json` into `dir` and return its path.
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let s = Summary { name: "x".into(), samples: vec![1.0, 2.0, 3.0] };
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.median(), 2.0);
        assert!(s.line("s").contains('x'));
    }

    #[test]
    fn sample_runs_expected_count() {
        let mut calls = 0;
        let s = sample_metric("t", 2, 5, || {
            calls += 1;
            calls as f64
        });
        assert_eq!(s.samples.len(), 5);
        assert_eq!(calls, 7, "2 warmup + 5 samples");
        // warmup discarded: samples start at 3
        assert_eq!(s.samples[0], 3.0);
    }

    #[test]
    fn sample_seconds_positive() {
        let s = sample_seconds("sleepless", 0, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.samples.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "tool"]);
        t.row(&["1".into(), "mpwide".into()]);
        t.row(&["22".into(), "scp".into()]);
        let r = t.render();
        assert!(r.contains("| a  | tool   |"), "{r}");
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn bench_json_renders_parseable_json() {
        let mut j = BenchJson::new("adaptive_wan");
        j.num("ratio", 2.5)
            .text("scenario", "congestion \"ramp\"\n")
            .series("goodput", &[1.0, 2.5, f64::NAN]);
        let text = j.render();
        let parsed = crate::util::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(parsed.get("bench").unwrap().str(), Some("adaptive_wan"));
        assert_eq!(parsed.get("ratio").unwrap().num(), Some(2.5));
        assert_eq!(parsed.get("scenario").unwrap().str(), Some("congestion \"ramp\"\n"));
        let series = parsed.get("goodput").unwrap().arr().unwrap();
        assert_eq!(series.len(), 3);
        assert_eq!(series[2], crate::util::json::Json::Null);
    }

    #[test]
    fn bench_json_writes_to_dir() {
        // write_to, not the env-var path: mutating the process environment
        // in a parallel test run races other threads' getenv
        let dir = std::env::temp_dir().join(format!("benchjson-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = BenchJson::new("smoke").num("x", 1.0).write_to(&dir).unwrap();
        assert!(path.ends_with("BENCH_smoke.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::Json::parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
