//! Minimal measurement harness for the `cargo bench` targets (the
//! `criterion` crate is unavailable in the offline build).
//!
//! Provides warmup + repeated sampling with summary statistics, and a
//! fixed-width table printer used to emit the paper-style rows every
//! bench target regenerates (DESIGN.md §4). Bench binaries are declared
//! `harness = false` and call these helpers from `main`.

use std::time::Instant;

use crate::util::stats;

/// Summary of repeated measurements of one quantity.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Label of the measured case.
    pub name: String,
    /// Raw samples (seconds, MB/s, … — caller-defined unit).
    pub samples: Vec<f64>,
}

impl Summary {
    /// Mean of the samples.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    /// Median (p50).
    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }

    /// 95th percentile.
    pub fn p95(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.samples)
    }

    /// Pretty one-liner: `name  mean ± sd  (median, p95)`.
    pub fn line(&self, unit: &str) -> String {
        format!(
            "{:<38} {:>10.3} ± {:>8.3} {unit}  (p50 {:.3}, p95 {:.3}, n={})",
            self.name,
            self.mean(),
            self.stddev(),
            self.median(),
            self.p95(),
            self.samples.len()
        )
    }
}

/// Measure `f` (which returns its own metric, e.g. seconds or MB/s):
/// `warmup` throwaway calls, then `samples` recorded calls.
pub fn sample_metric<F: FnMut() -> f64>(
    name: &str,
    warmup: usize,
    samples: usize,
    mut f: F,
) -> Summary {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut v = Vec::with_capacity(samples);
    for _ in 0..samples {
        v.push(f());
    }
    Summary { name: name.to_string(), samples: v }
}

/// Measure wall-clock seconds of `f` per call.
pub fn sample_seconds<F: FnMut()>(
    name: &str,
    warmup: usize,
    samples: usize,
    mut f: F,
) -> Summary {
    sample_metric(name, warmup, samples, || {
        let t0 = Instant::now();
        f();
        t0.elapsed().as_secs_f64()
    })
}

/// Fixed-width table printer for paper-style outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, width) in cells.iter().zip(w) {
                line.push_str(&format!("{c:<width$} | "));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &w));
        out.push('\n');
        out.push_str(&format!(
            "|{}|",
            w.iter().map(|x| "-".repeat(x + 2)).collect::<Vec<_>>().join("|")
        ));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Section banner for bench output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let s = Summary { name: "x".into(), samples: vec![1.0, 2.0, 3.0] };
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.median(), 2.0);
        assert!(s.line("s").contains('x'));
    }

    #[test]
    fn sample_runs_expected_count() {
        let mut calls = 0;
        let s = sample_metric("t", 2, 5, || {
            calls += 1;
            calls as f64
        });
        assert_eq!(s.samples.len(), 5);
        assert_eq!(calls, 7, "2 warmup + 5 samples");
        // warmup discarded: samples start at 3
        assert_eq!(s.samples[0], 3.0);
    }

    #[test]
    fn sample_seconds_positive() {
        let s = sample_seconds("sleepless", 0, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.samples.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "tool"]);
        t.row(&["1".into(), "mpwide".into()]);
        t.row(&["22".into(), "scp".into()]);
        let r = t.render();
        assert!(r.contains("| a  | tool   |"), "{r}");
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
