//! `mpwide` — the command-line launcher for the MPWide reproduction.
//!
//! Subcommands map to the tools and applications the paper ships:
//!
//! ```text
//! mpwide mpwtest-serve --port P --streams N [--channels]   MPWTest slave endpoint
//! mpwide mpwtest HOST --port P --streams N [--weights 1,2,4]   MPWTest master
//! mpwide forward --port P --streams N [--delay-ms D]   Forwarder (Fig 3)
//! mpwide cp-serve --port P --dir DIR --streams N   mpw-cp receiving end
//! mpwide cp FILE HOST [NAME] --port P --streams N  mpw-cp sender
//! mpwide gather-serve --port P --dir DIR           DataGather destination
//! mpwide gather DIR HOST --port P [--watch SECS]   DataGather source
//! mpwide cosmogrid [--sites S --steps K --snapshot F]  distributed N-body
//! mpwide bloodflow [--exchanges E --no-hiding]     coupled multiscale run
//! mpwide dns HOST                                  MPW_DNSResolve
//! ```

use std::time::Duration;

use anyhow::{bail, Context, Result};

use mpwide::bloodflow::{run_coupled, CouplingConfig};
use mpwide::cli::Args;
use mpwide::cosmogrid::{self, SimConfig};
use mpwide::mpwide::{Path, PathConfig, PathListener};
use mpwide::tools::{datagather, forwarder, mpwcp, mpwtest};
use mpwide::util::{human_rate, Rng};

fn client_cfg(args: &Args) -> PathConfig {
    let mut cfg = PathConfig::with_streams(args.opt_parse("streams", 1usize));
    cfg.autotune = !args.flag("no-autotune");
    if let Some(c) = args.opt("chunk") {
        cfg.chunk_size = c.parse().unwrap_or(cfg.chunk_size);
    }
    if let Some(w) = args.opt("window") {
        cfg.tcp_window = w.parse().ok();
    }
    if let Some(p) = args.opt("pacing") {
        cfg.pacing_rate = p.parse().ok();
    }
    cfg
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_str() {
        "mpwtest-serve" => {
            let port = args.opt_parse("port", 6010u16);
            let mut listener = PathListener::bind(port, client_cfg(&args))?;
            eprintln!("MPWTest slave on port {}", listener.port());
            if args.flag("channels") {
                // multi-channel slave: echo one weighted suite per channel
                let path = listener.accept_path_arc()?;
                mpwtest::run_slave_channels(path)?;
            } else {
                let path = listener.accept_path()?;
                mpwtest::run_slave(&path)?;
            }
        }
        "mpwtest" => {
            let host = args.pos(0).context("usage: mpwide mpwtest HOST --port P")?;
            let port = args.opt_parse("port", 6010u16);
            let path = Path::connect(host, port, client_cfg(&args))?;
            if let Some(ws) = args.opt("weights") {
                // weighted multi-channel mode: one concurrent echo suite
                // per weight, over channels 1..=N of one muxed path (the
                // slave must run with --channels)
                let weights = ws
                    .split(',')
                    .map(|w| w.trim().parse::<u32>())
                    .collect::<std::result::Result<Vec<_>, _>>()
                    .context("--weights expects a comma-separated list of integers")?;
                let specs: Vec<mpwtest::ChannelSpec> = weights
                    .iter()
                    .enumerate()
                    .map(|(i, &w)| mpwtest::ChannelSpec {
                        channel: i as u32 + 1,
                        weight: w,
                        rate: None,
                    })
                    .collect();
                let rows = mpwtest::run_master_channels(
                    std::sync::Arc::new(path),
                    &specs,
                    &mpwtest::SIZES,
                    mpwtest::default_reps,
                )?;
                println!(
                    "{:>8} {:>7} {:>12} {:>8} {:>12} {:>14}",
                    "channel", "weight", "size", "reps", "secs/xchg", "rate/dir"
                );
                for r in rows {
                    println!(
                        "{:>8} {:>7} {:>12} {:>8} {:>12.5} {:>14}",
                        r.channel,
                        r.weight,
                        r.size,
                        r.reps,
                        r.seconds,
                        human_rate(r.rate)
                    );
                }
            } else {
                let rows = mpwtest::run_master(&path, &mpwtest::SIZES, mpwtest::default_reps)?;
                println!("{:>12} {:>8} {:>12} {:>14}", "size", "reps", "secs/xchg", "rate/dir");
                for r in rows {
                    println!(
                        "{:>12} {:>8} {:>12.5} {:>14}",
                        r.size,
                        r.reps,
                        r.seconds,
                        human_rate(r.rate)
                    );
                }
            }
        }
        "forward" => {
            let port = args.opt_parse("port", 6020u16);
            let streams = args.opt_parse("streams", 1usize);
            let delay = args
                .opt("delay-ms")
                .and_then(|d| d.parse::<f64>().ok())
                .map(|ms| Duration::from_secs_f64(ms / 1e3));
            let mut cfg = PathConfig::with_streams(streams);
            cfg.autotune = false;
            let mut listener = PathListener::bind(port, cfg)?;
            eprintln!("forwarder on port {} ({} streams)", listener.port(), streams);
            let fcfg = forwarder::ForwarderConfig { nstreams: streams, delay, max_bytes: None };
            let stats = forwarder::run(&mut listener, &fcfg)?;
            eprintln!("relayed {} + {} bytes", stats.a_to_b, stats.b_to_a);
        }
        "cp-serve" => {
            let port = args.opt_parse("port", 6030u16);
            let dir = args.opt("dir").unwrap_or(".").to_string();
            let mut listener = PathListener::bind(port, client_cfg(&args))?;
            eprintln!("mpw-cp server on port {} -> {dir}", listener.port());
            let path = listener.accept_path()?;
            let n = mpwcp::serve(&path, std::path::Path::new(&dir))?;
            eprintln!("received {n} files");
        }
        "cp" => {
            let file = args.pos(0).context("usage: mpwide cp FILE HOST [NAME]")?;
            let host = args.pos(1).context("usage: mpwide cp FILE HOST [NAME]")?;
            let name = args.pos(2).map(str::to_string).unwrap_or_else(|| {
                std::path::Path::new(file)
                    .file_name()
                    .map(|f| f.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "file".into())
            });
            let port = args.opt_parse("port", 6030u16);
            let path = Path::connect(host, port, client_cfg(&args))?;
            let stats = mpwcp::send_file(&path, std::path::Path::new(file), &name)?;
            println!(
                "{} bytes in {:.3}s = {}",
                stats.bytes,
                stats.seconds,
                human_rate(stats.bytes as f64 / stats.seconds.max(1e-9))
            );
        }
        "gather-serve" => {
            let port = args.opt_parse("port", 6040u16);
            let dir = args.opt("dir").unwrap_or("gathered").to_string();
            let mut cfg = PathConfig::with_streams(args.opt_parse("streams", 1usize));
            cfg.autotune = false;
            let mut listener = PathListener::bind(port, cfg)?;
            eprintln!("DataGather destination on port {} -> {dir}", listener.port());
            let path = listener.accept_path()?;
            while let Ok(n) = datagather::serve_once(&path, std::path::Path::new(&dir)) {
                eprintln!("sync round: {n} files");
            }
        }
        "gather" => {
            let dir = args.pos(0).context("usage: mpwide gather DIR HOST")?;
            let host = args.pos(1).context("usage: mpwide gather DIR HOST")?;
            let port = args.opt_parse("port", 6040u16);
            let watch = args.opt("watch").and_then(|w| w.parse::<f64>().ok());
            let mut cfg = PathConfig::with_streams(args.opt_parse("streams", 1usize));
            cfg.autotune = false;
            let path = Path::connect(host, port, cfg)?;
            loop {
                let stats = datagather::sync_once(&path, std::path::Path::new(dir))?;
                eprintln!(
                    "scanned {} shipped {} ({} bytes)",
                    stats.scanned, stats.shipped, stats.bytes
                );
                match watch {
                    Some(secs) => std::thread::sleep(Duration::from_secs_f64(secs)),
                    None => break,
                }
            }
        }
        "cosmogrid" => {
            let cfg = SimConfig {
                sites: args.opt_parse("sites", 3usize),
                steps: args.opt_parse("steps", 20usize),
                nstreams: args.opt_parse("streams", 4usize),
                ..Default::default()
            };
            eprintln!("distributed CosmoGrid: {} sites × {} steps", cfg.sites, cfg.steps);
            let report = cosmogrid::run_distributed(&cfg)?;
            let total = cosmogrid::sim::total_wallclock(&report.timings);
            let comm = cosmogrid::sim::comm_fraction(&report.timings);
            println!(
                "total {:.2}s, comm fraction {:.1}%, {} bytes exchanged",
                total,
                comm * 100.0,
                report.bytes_exchanged
            );
            if let Some(snap) = args.opt("snapshot") {
                cosmogrid::snapshot::snapshot(
                    &report.sites,
                    std::path::Path::new(snap),
                    512,
                    0.8,
                )?;
                println!("snapshot written to {snap}");
            }
        }
        "bloodflow" => {
            let cfg = CouplingConfig {
                exchanges: args.opt_parse("exchanges", 50usize),
                substeps: args.opt_parse("substeps", 12usize),
                latency_hiding: !args.flag("no-hiding"),
                ..Default::default()
            };
            let report = run_coupled(&cfg)?;
            println!(
                "{} exchanges, total {:.2}s, overhead {:.2} ms/exchange ({:.2}% of runtime)",
                report.exchanges,
                report.total_seconds,
                report.overhead_per_exchange * 1e3,
                report.overhead_fraction * 100.0
            );
        }
        "dns" => {
            let host = args.pos(0).context("usage: mpwide dns HOST")?;
            println!("{}", mpwide::mpwide::dns::dns_resolve(host)?);
        }
        "selftest" => {
            // MPWUnitTests analog: a quick in-process functional pass
            let mut cfg = PathConfig::with_streams(4);
            cfg.autotune = false;
            let mut listener = PathListener::bind(0, cfg.clone())?;
            let port = listener.port();
            let t = std::thread::spawn(move || -> Result<()> {
                let p = Path::connect("127.0.0.1", port, cfg)?;
                let mut msg = vec![0u8; 1 << 20];
                Rng::new(2).fill_bytes(&mut msg);
                p.send(&msg)?;
                p.barrier()?;
                Ok(())
            });
            let p = listener.accept_path()?;
            let mut buf = vec![0u8; 1 << 20];
            p.recv(&mut buf)?;
            p.barrier()?;
            t.join().expect("client thread")?;
            let mut want = vec![0u8; 1 << 20];
            Rng::new(2).fill_bytes(&mut want);
            anyhow::ensure!(buf == want, "selftest payload mismatch");
            println!("selftest OK");
        }
        "" | "help" | "--help" => {
            print!("{HELP}");
        }
        other => bail!("unknown subcommand '{other}' (try: mpwide help)"),
    }
    Ok(())
}

const HELP: &str = r#"mpwide — light-weight message passing over wide area networks
(reproduction of Groen, Rieder & Portegies Zwart, JORS 2013)

Usage: mpwide <command> [args] [--options]

Commands:
  mpwtest-serve --port P --streams N [--channels]   benchmark slave endpoint
  mpwtest HOST --port P --streams N [--weights 1,2,4]  benchmark master
                                        (--weights: concurrent weighted
                                         channel suites over one muxed path)
  forward --port P --streams N [--delay-ms D]   user-space forwarder
  cp-serve --port P --dir DIR           mpw-cp receiving end
  cp FILE HOST [NAME] --port P --streams N --chunk C   mpw-cp sender
  gather-serve --port P --dir DIR       DataGather destination
  gather DIR HOST --port P [--watch S]  DataGather source (one-way sync)
  cosmogrid [--sites S --steps K --snapshot F.ppm]   distributed N-body
  bloodflow [--exchanges E --substeps K --no-hiding] coupled multiscale
  dns HOST                              resolve a hostname locally
  selftest                              quick functional pass

Common options: --streams N  --chunk BYTES  --window BYTES  --pacing B/S
                --no-autotune
"#;
