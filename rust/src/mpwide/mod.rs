//! Core MPWide library (the paper's primary contribution).
//!
//! The central abstraction is the communication [`Path`](path::Path): a
//! logical connection between two endpoints made of 1–256 parallel TCP
//! streams. Messages sent over a path are striped evenly across the
//! streams ([`stripe`]), written in user-configurable chunks
//! ([`config::PathConfig::chunk_size`]), optionally rate-limited by a
//! software pacer ([`pacing`]) and with tuned TCP windows
//! ([`transport`]).
//!
//! Tuning happens at two distinct times:
//!
//! * **Creation time** — the [`autotune`]r (the paper's §1.3.1 tuner,
//!   enabled by default) probes chunk sizes over the freshly-built path,
//!   adopts the fastest on both ends, and sets a BDP-derived TCP window.
//!   After that the paper's MPWide never touches the knobs again.
//! * **Runtime** — the [`adapt`] subsystem (this reproduction's
//!   extension, opt-in via
//!   [`AdaptConfig::mode`](adapt::AdaptConfig::mode) or
//!   `MPW_setTuneMode`) keeps watching per-send goodput and **live
//!   restripes** the path: it changes how many of the established
//!   streams a message is striped over, re-chunks, and re-paces as WAN
//!   conditions drift — no reconnects, both ends converging through a
//!   tiny per-message active-stream header.
//!
//! On top of paths the library provides dynamic-size messaging with
//! receive-side caching ([`dynamic`]), non-blocking operations
//! ([`nonblocking`]), message cycling/relaying between paths ([`relay`]),
//! and a C-style facade mirroring the paper's Table 2 ([`api`]).
//!
//! ## Fault tolerance
//!
//! With [`config::ResilienceConfig::enabled`] set (both ends!), the
//! [`resilience`] layer frames every message so that a single stream's
//! TCP error no longer kills the path: the failed stream is isolated,
//! the in-flight message retries over the survivors, and striping runs
//! in degraded mode (the active-stream count follows the live count)
//! until the stream rejoins. Rejoin reuses the creation-time handshake —
//! the connecting end's [`resilience::ReconnectMonitor`] redials with
//! the original path uuid + stream index, and the accepting end's
//! [`resilience::RejoinDaemon`] (made from the [`PathListener`]) slots
//! the fresh socket back into its old position. Stream-death semantics,
//! the rejoin knobs ([`config::ReconnectPolicy`]) and the facade calls
//! (`mpw_path_status`, `mpw_set_reconnect_policy`) are documented in
//! [`resilience`]. Delivery is acknowledged per message; by default a
//! resilient send is a rendezvous (one RTT per message), and setting
//! [`config::ResilienceConfig::window`] `> 1` pipelines up to that
//! many posted-but-unacknowledged messages with out-of-order ACK
//! accounting and selective retry — [`Path::flush`](path::Path::flush)
//! or a barrier drains the window. The byte-exact wire formats live in
//! `docs/PROTOCOL.md`.
//!
//! ## Channel multiplexing
//!
//! One tuned, resilient path is expensive to set up and cheap to share:
//! the [`mux`] session layer multiplexes many logical **channels** over
//! a single path, so several concurrent couplings (a solver boundary
//! exchange, a DataGather sync, a bulk file transfer) reuse one WAN
//! fat-pipe instead of opening one path each. Channel frames carry a
//! channel id and per-channel message sequence on top of the path's
//! framing; a per-path dispatcher routes inbound frames to per-channel
//! queues, and the sender pump interleaves channels round-robin with a
//! chunk budget so bulk traffic cannot starve latency-sensitive
//! channels. Frame headers ride in front of payload chunks through the
//! scatter send path ([`stripe::SplitBuf`] + vectored writes) — never
//! copy-assembled. The facade surface is `mpw_open_channel`,
//! `mpw_channel_send`, `mpw_channel_recv`, `mpw_close_channel`; the
//! guarantees/limitations contract is documented in [`mux`].

pub mod adapt;
pub mod api;
pub mod autotune;
pub mod config;
pub mod dns;
pub mod dynamic;
pub mod errors;
pub mod mux;
pub mod nonblocking;
pub mod pacing;
pub mod path;
pub mod relay;
pub mod resilience;
pub mod stripe;
pub mod transport;

pub use adapt::{AdaptConfig, TuneMode, TuneSnapshot};
pub use config::{PathConfig, ReconnectPolicy, ResilienceConfig};
pub use errors::{MpwError, Result};
pub use mux::{Channel, ChannelStats, MsgLink, MuxConfig, MuxEndpoint};
pub use path::{Path, PathListener};
pub use resilience::{PathStatus, ReconnectMonitor, RejoinDaemon};
