//! Core MPWide library (the paper's primary contribution).
//!
//! The central abstraction is the communication [`Path`](path::Path): a
//! logical connection between two endpoints made of 1–256 parallel TCP
//! streams. Messages sent over a path are striped evenly across the
//! streams ([`stripe`]), written in user-configurable chunks
//! ([`config::PathConfig::chunk_size`]), optionally rate-limited by a
//! software pacer ([`pacing`]) and with tuned TCP windows
//! ([`transport`]). An [`autotune`]r probes these parameters at path
//! creation when enabled (the paper's default).
//!
//! On top of paths the library provides dynamic-size messaging with
//! receive-side caching ([`dynamic`]), non-blocking operations
//! ([`nonblocking`]), message cycling/relaying between paths ([`relay`]),
//! and a C-style facade mirroring the paper's Table 2 ([`api`]).

pub mod api;
pub mod autotune;
pub mod config;
pub mod dns;
pub mod dynamic;
pub mod errors;
pub mod nonblocking;
pub mod pacing;
pub mod path;
pub mod relay;
pub mod stripe;
pub mod transport;

pub use config::PathConfig;
pub use errors::{MpwError, Result};
pub use path::{Path, PathListener};
