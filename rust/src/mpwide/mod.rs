//! Core MPWide library (the paper's primary contribution).
//!
//! The central abstraction is the communication [`Path`](path::Path): a
//! logical connection between two endpoints made of 1–256 parallel TCP
//! streams. Messages sent over a path are striped evenly across the
//! streams ([`stripe`]), written in user-configurable chunks
//! ([`config::PathConfig::chunk_size`]), optionally rate-limited by a
//! software pacer ([`pacing`]) and with tuned TCP windows
//! ([`transport`]).
//!
//! Tuning happens at two distinct times:
//!
//! * **Creation time** — the [`autotune`]r (the paper's §1.3.1 tuner,
//!   enabled by default) probes chunk sizes over the freshly-built path,
//!   adopts the fastest on both ends, and sets a BDP-derived TCP window.
//!   After that the paper's MPWide never touches the knobs again.
//! * **Runtime** — the [`adapt`] subsystem (this reproduction's
//!   extension, opt-in via
//!   [`AdaptConfig::mode`](adapt::AdaptConfig::mode) or
//!   `MPW_setTuneMode`) keeps watching per-send goodput and **live
//!   restripes** the path: it changes how many of the established
//!   streams a message is striped over, re-chunks, and re-paces as WAN
//!   conditions drift — no reconnects, both ends converging through a
//!   tiny per-message active-stream header.
//!
//! On top of paths the library provides dynamic-size messaging with
//! receive-side caching ([`dynamic`]), non-blocking operations
//! ([`nonblocking`]), message cycling/relaying between paths ([`relay`]),
//! and a C-style facade mirroring the paper's Table 2 ([`api`]).

pub mod adapt;
pub mod api;
pub mod autotune;
pub mod config;
pub mod dns;
pub mod dynamic;
pub mod errors;
pub mod nonblocking;
pub mod pacing;
pub mod path;
pub mod relay;
pub mod stripe;
pub mod transport;

pub use adapt::{AdaptConfig, TuneMode, TuneSnapshot};
pub use config::PathConfig;
pub use errors::{MpwError, Result};
pub use path::{Path, PathListener};
