//! Dynamic-size messaging with receive-side caching (`MPW_DSendRecv`,
//! `MPW_DCycle`).
//!
//! Fixed-size `send`/`recv` requires both ends to agree on the message
//! length, like MPI. When the size is not known to the receiver, MPWide
//! prefixes an 8-byte length header on stream 0 and lets the receiver grow
//! a cached buffer — the cache avoids reallocating on every exchange of a
//! slowly-varying message (the bloodflow coupling's boundary arrays).

use super::errors::{MpwError, Result};
use super::path::Path;
use super::stripe::SplitBuf;

/// Upper bound accepted for a dynamic message (guards against a corrupted
/// or malicious header causing an absurd allocation).
pub const MAX_DYNAMIC: u64 = 1 << 40; // 1 TiB

impl Path {
    /// Send `buf` with a length prefix; pairs with [`Path::drecv_into`] /
    /// [`Path::drecv`]. Holds the path's send gate across header **and**
    /// body so concurrent senders (non-blocking handles) cannot
    /// interleave mid-message. In resilient mode no separate header is
    /// needed: the message length travels in the per-message CTRL frame.
    pub fn dsend(&self, buf: &[u8]) -> Result<()> {
        self.dsend_split(&[], buf)
    }

    /// [`Path::dsend`] of a two-part logical message (`head ++ tail`)
    /// without concatenating the parts — the striping layer resolves
    /// segments and chunks through [`SplitBuf`] and the transport writes
    /// header + payload with one vectored call. This is how the mux
    /// layer ships a channel-frame header in front of a payload chunk
    /// with zero copies.
    pub fn dsend_split(&self, head: &[u8], tail: &[u8]) -> Result<()> {
        let _gate = self.send_gate.lock();
        let buf = SplitBuf { head, tail };
        if self.resilient() {
            super::resilience::send(self, buf)?;
            return Ok(());
        }
        self.send_header(buf.len() as u64)?;
        self.send_split_ungated(buf)?;
        Ok(())
    }

    /// Receive a dynamic message into `cache`, resizing it as needed. The
    /// cache is only grown, never shrunk, so steady-state exchanges do not
    /// allocate. Returns the message length.
    pub fn drecv_into(&self, cache: &mut Vec<u8>) -> Result<usize> {
        let _gate = self.recv_gate.lock();
        if self.resilient() {
            return super::resilience::recv(self, super::resilience::RecvTarget::Dynamic(cache));
        }
        let len = self.recv_header()? as usize;
        if cache.len() < len {
            cache.resize(len, 0);
        }
        self.recv_ungated(&mut cache[..len])?;
        Ok(len)
    }

    /// Receive a dynamic message as a fresh vector.
    pub fn drecv(&self) -> Result<Vec<u8>> {
        let mut v = Vec::new();
        let n = self.drecv_into(&mut v)?;
        v.truncate(n);
        Ok(v)
    }

    /// `MPW_DSendRecv`: full-duplex dynamic exchange — send `sbuf` while
    /// receiving the peer's message into `cache`. Returns the received
    /// length.
    pub fn dsend_recv(&self, sbuf: &[u8], cache: &mut Vec<u8>) -> Result<usize> {
        std::thread::scope(|scope| -> Result<usize> {
            let tx = scope.spawn(|| self.dsend(sbuf));
            let n = self.drecv_into(cache)?;
            tx.join().map_err(|_| MpwError::WorkerPanic("dsend".into()))??;
            Ok(n)
        })
    }

    fn send_header(&self, len: u64) -> Result<()> {
        let slot = &self.streams[0];
        let mut tx = slot.tx.lock();
        tx.w.write_all(&len.to_be_bytes())?;
        tx.w.flush()?;
        Ok(())
    }

    fn recv_header(&self) -> Result<u64> {
        let slot = &self.streams[0];
        let mut hdr = [0u8; 8];
        slot.rx.lock().read_exact(&mut hdr)?;
        let len = u64::from_be_bytes(hdr);
        if len > MAX_DYNAMIC {
            return Err(MpwError::Protocol(format!("dynamic message length {len} too large")));
        }
        Ok(len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::config::PathConfig;
    use crate::mpwide::transport::mem_path_pairs;
    use crate::util::Rng;

    fn mem_paths(n: usize) -> (Path, Path) {
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        cfg.chunk_size = 1024;
        (Path::from_pairs(l, cfg.clone()).unwrap(), Path::from_pairs(r, cfg).unwrap())
    }

    #[test]
    fn dynamic_roundtrip_unknown_size() {
        let (a, b) = mem_paths(3);
        let mut msg = vec![0u8; 12_345];
        Rng::new(4).fill_bytes(&mut msg);
        let msg2 = msg.clone();
        let t = std::thread::spawn(move || b.drecv().unwrap());
        a.dsend(&msg).unwrap();
        assert_eq!(t.join().unwrap(), msg2);
    }

    #[test]
    fn dynamic_empty_message() {
        let (a, b) = mem_paths(2);
        let t = std::thread::spawn(move || b.drecv().unwrap());
        a.dsend(&[]).unwrap();
        assert_eq!(t.join().unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn cache_is_reused_and_grows() {
        let (a, b) = mem_paths(2);
        let t = std::thread::spawn(move || {
            let mut cache = Vec::new();
            let n1 = b.drecv_into(&mut cache).unwrap();
            let cap1 = cache.capacity();
            let n2 = b.drecv_into(&mut cache).unwrap();
            let n3 = b.drecv_into(&mut cache).unwrap();
            (n1, n2, n3, cap1, cache.capacity())
        });
        a.dsend(&[1u8; 1000]).unwrap();
        a.dsend(&[2u8; 500]).unwrap(); // smaller: reuses, no realloc
        a.dsend(&[3u8; 2000]).unwrap(); // larger: grows
        let (n1, n2, n3, cap1, cap3) = t.join().unwrap();
        assert_eq!((n1, n2, n3), (1000, 500, 2000));
        assert!(cap1 >= 1000);
        assert!(cap3 >= 2000);
    }

    #[test]
    fn dsend_recv_full_duplex() {
        let (a, b) = mem_paths(4);
        let ma = vec![5u8; 7777];
        let mb = vec![6u8; 333];
        let ma2 = ma.clone();
        let mb2 = mb.clone();
        let t = std::thread::spawn(move || {
            let mut cache = Vec::new();
            let n = b.dsend_recv(&mb2, &mut cache).unwrap();
            assert_eq!(&cache[..n], &ma2[..]);
        });
        let mut cache = Vec::new();
        let n = a.dsend_recv(&ma, &mut cache).unwrap();
        assert_eq!(&cache[..n], &mb[..]);
        t.join().unwrap();
    }

    #[test]
    fn oversized_header_rejected() {
        let (a, b) = mem_paths(1);
        // Forge a header directly on stream 0.
        {
            let slot = &a.streams[0];
            let mut tx = slot.tx.lock();
            tx.w.write_all(&(MAX_DYNAMIC + 1).to_be_bytes()).unwrap();
        }
        assert!(b.drecv().is_err());
    }
}
