//! Online adaptive path tuning (live restriping).
//!
//! The paper's autotuner probes chunk size and TCP windows **once, at
//! path creation** (§1.3.1). Real wide-area routes drift over the hours a
//! distributed run lasts — background load rises, loss bursts appear —
//! so a setting that was right at creation can be badly wrong an hour in.
//! This module adds the runtime half of the tuning story:
//!
//! * [`TuningState`] — an atomically-shared knob block (active stream
//!   count, chunk size, pacing rate) that `Path::send`/`recv` consult on
//!   every operation, with no lock on the hot path;
//! * [`AdaptiveController`] — an EWMA-fed hill-climbing state machine
//!   that watches per-send goodput and decides when to restripe over
//!   more (or fewer) of the already-established streams, re-chunk, and
//!   re-pace;
//! * [`TuneMode`] / [`TuneSnapshot`] — the facade-level surface
//!   (`MPW_setTuneMode` / `MPW_TuneState` in Table 2 style).
//!
//! Restriping never reconnects: a path keeps all `nstreams` TCP streams
//! open and simply stripes each message over the first `active` of them.
//! The sender advertises its active count in a 2-byte header on stream 0
//! of every message, so the receiver follows without negotiation and
//! both ends converge per direction.
//!
//! The controller is pure (no I/O) and deterministic: the same sample
//! sequence always yields the same decisions, which is what makes the
//! netsim-backed tests and the `adaptive_wan` bench reproducible.

use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};

use super::pacing;

/// Floor/ceiling for adaptively-chosen chunk sizes. The floor keeps the
/// per-call overhead bounded; the ceiling bounds memory per low-level
/// call (matches the autotuner's largest probe).
pub const MIN_ADAPT_CHUNK: usize = 64 * 1024;
/// See [`MIN_ADAPT_CHUNK`].
pub const MAX_ADAPT_CHUNK: usize = 8 << 20;

/// Whether a path's performance parameters are frozen after creation
/// (the paper's behaviour) or adjusted online by the
/// [`AdaptiveController`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneMode {
    /// Tune only at path creation (autotuner); then freeze.
    Static,
    /// Keep tuning at runtime: live restriping, re-chunking, re-pacing.
    Adaptive,
}

/// Configuration of the online controller. Defaults are deliberately
/// conservative: adaptation is off unless requested, and every knob is
/// clamped to a floor/ceiling so a misbehaving estimate cannot wedge a
/// path.
#[derive(Debug, Clone)]
pub struct AdaptConfig {
    /// Runtime tuning mode (default [`TuneMode::Static`] — the paper's
    /// behaviour — so existing callers see no change).
    pub mode: TuneMode,
    /// EWMA smoothing factor for goodput samples, in (0, 1].
    pub alpha: f64,
    /// Samples to wait between adjustments (oscillation damping).
    pub cooldown: u32,
    /// A smoothed rate below `drop_frac × best` is treated as a WAN
    /// regime change and restarts the upward search.
    pub drop_frac: f64,
    /// Relative rate change that counts as a real improvement/regression
    /// for the hill climber.
    pub improve_frac: f64,
    /// Fewest streams the controller may stripe over.
    pub min_streams: usize,
    /// Smallest/largest chunk the controller may pick.
    pub min_chunk: usize,
    /// See [`AdaptConfig::min_chunk`].
    pub max_chunk: usize,
    /// Chunk-size target: aim for about this many low-level calls per
    /// stream per message. 0 disables chunk adaptation.
    pub target_calls_per_stream: usize,
    /// Pacing is set to `ewma_rate × pace_headroom`, split across the
    /// active streams — capping overshoot (and the loss it causes on a
    /// congested bottleneck) while never binding in steady state.
    /// `<= 0` disables pacing adaptation.
    pub pace_headroom: f64,
    /// Sends smaller than this are ignored by the monitor (their timing
    /// is dominated by per-operation latency, not bandwidth).
    pub min_sample_bytes: usize,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            mode: TuneMode::Static,
            alpha: 0.3,
            cooldown: 2,
            drop_frac: 0.7,
            improve_frac: 0.05,
            min_streams: 1,
            min_chunk: MIN_ADAPT_CHUNK,
            max_chunk: MAX_ADAPT_CHUNK,
            target_calls_per_stream: 4,
            pace_headroom: 1.5,
            min_sample_bytes: 64 * 1024,
        }
    }
}

impl AdaptConfig {
    /// Validate controller parameters (called from
    /// [`PathConfig::validate`](super::config::PathConfig::validate)).
    pub fn validate(&self) -> crate::mpwide::Result<()> {
        let err = |m: String| Err(crate::mpwide::MpwError::Config(m));
        if !(self.alpha > 0.0 && self.alpha <= 1.0) {
            return err(format!("adapt.alpha must be in (0, 1], got {}", self.alpha));
        }
        if !(self.drop_frac > 0.0 && self.drop_frac < 1.0) {
            return err(format!("adapt.drop_frac must be in (0, 1), got {}", self.drop_frac));
        }
        if self.improve_frac < 0.0 {
            return err(format!("adapt.improve_frac must be >= 0, got {}", self.improve_frac));
        }
        if self.min_streams == 0 {
            return err("adapt.min_streams must be >= 1".into());
        }
        if self.min_chunk == 0 || self.min_chunk > self.max_chunk {
            return err(format!(
                "adapt chunk bounds invalid: {}..{}",
                self.min_chunk, self.max_chunk
            ));
        }
        Ok(())
    }
}

/// Point-in-time view of a path's live tuning state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TuneSnapshot {
    /// Streams the next send will stripe over.
    pub active_streams: usize,
    /// Bytes handed to each low-level call.
    pub chunk_size: usize,
    /// Per-stream software pacing rate, bytes/second (None = unpaced).
    pub pacing_rate: Option<f64>,
    /// Current tuning mode.
    pub mode: TuneMode,
    /// Controller's smoothed goodput estimate, bytes/second (None until
    /// the first qualifying sample; always None in static mode).
    pub ewma_rate: Option<f64>,
    /// In-flight resilient send window (1 = rendezvous sends).
    pub window: usize,
    /// Ceiling the controller may raise the window to
    /// ([`ResilienceConfig::window`](super::config::ResilienceConfig::window)).
    pub window_max: usize,
}

const MODE_STATIC: u8 = 0;
const MODE_ADAPTIVE: u8 = 1;
/// Sentinel bit pattern for "pacing disabled" (0.0 is not a legal rate).
const PACING_OFF: u64 = 0;

/// Live, atomically-shared tuning knobs for one path.
///
/// `Path::send`/`recv` (and the netsim
/// [`AdaptiveSimPath`](crate::netsim::simpath::AdaptiveSimPath)) read
/// these with relaxed-ordering atomic loads — no mutex on the hot path.
/// Writers are the [`AdaptiveController`] (via [`TuningState::apply`])
/// and the explicit `MPW_set*` setters.
#[derive(Debug)]
pub struct TuningState {
    active: AtomicUsize,
    /// The active count last chosen by the user or controller, before
    /// any degraded-mode clamp. When a dead stream rejoins, the live
    /// limit rises and `active` is restored toward this value — so a
    /// path that lost a stream "re-absorbs" it without renegotiation.
    preferred_active: AtomicUsize,
    chunk: AtomicUsize,
    pacing_bits: AtomicU64,
    mode: AtomicU8,
    /// In-flight resilient send window (1 = rendezvous sends). Written
    /// by the controller / facade, read by the resilience layer's
    /// windowed sender on every send.
    window: AtomicUsize,
    /// Hard ceiling for `window` — the configured
    /// [`ResilienceConfig::window`](super::config::ResilienceConfig::window).
    window_max: AtomicUsize,
    /// Message budget most recently advertised by the peer's receiver
    /// (credit flow control). `usize::MAX` until the first credit frame
    /// arrives; the effective window never exceeds it, so the tuner
    /// cannot widen past what the peer's reorder stash can absorb.
    credit_cap: AtomicUsize,
}

impl TuningState {
    /// Fresh state: stripe over `active` streams with the given chunk and
    /// pacing.
    pub fn new(active: usize, chunk: usize, pacing: Option<f64>, mode: TuneMode) -> TuningState {
        let s = TuningState {
            active: AtomicUsize::new(active.max(1)),
            preferred_active: AtomicUsize::new(active.max(1)),
            chunk: AtomicUsize::new(chunk.max(1)),
            pacing_bits: AtomicU64::new(PACING_OFF),
            mode: AtomicU8::new(MODE_STATIC),
            window: AtomicUsize::new(1),
            window_max: AtomicUsize::new(1),
            credit_cap: AtomicUsize::new(usize::MAX),
        };
        s.set_pacing(pacing);
        s.set_mode(mode);
        s
    }

    /// Initial state for a path configured with `cfg`.
    pub fn from_config(cfg: &super::config::PathConfig) -> TuningState {
        let s = TuningState::new(cfg.nstreams, cfg.chunk_size, cfg.pacing_rate, cfg.adapt.mode);
        s.init_window(cfg.resilience.window.max(1));
        s
    }

    /// Seed both the current window and its ceiling (path creation).
    pub fn init_window(&self, w: usize) {
        self.window_max.store(w.max(1), Ordering::Relaxed);
        self.window.store(w.max(1), Ordering::Relaxed);
    }

    /// Current in-flight send window (1 = rendezvous sends).
    pub fn window(&self) -> usize {
        self.window.load(Ordering::Relaxed)
    }

    /// The configured window ceiling.
    pub fn window_max(&self) -> usize {
        self.window_max.load(Ordering::Relaxed)
    }

    /// Set the in-flight window, clamped to `[1, min(window_max,
    /// peer credit)]` — the controller may narrow a configured window
    /// (congestion: in-flight messages just sit in a queue) and
    /// re-widen it, but never exceed what the path was configured to
    /// pipeline nor what the peer's receiver advertised room for.
    pub fn set_window(&self, w: usize) {
        let max = self
            .window_max
            .load(Ordering::Relaxed)
            .min(self.credit_cap.load(Ordering::Relaxed));
        self.window.store(w.clamp(1, max.max(1)), Ordering::Relaxed);
    }

    /// Record the peer's advertised message budget and re-clamp the
    /// current window under it. Called by the resilience layer whenever
    /// a credit frame (extended ACK or WINDOW_UPDATE) lands.
    pub fn apply_window_credit(&self, cap: usize) {
        self.credit_cap.store(cap.max(1), Ordering::Relaxed);
        let w = self.window.load(Ordering::Relaxed);
        self.set_window(w);
    }

    /// Streams the next operation stripes over.
    pub fn active_streams(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    /// Set the active stream count (clamped to >= 1 by callers). This is
    /// a *deliberate* choice (user or controller), so it also updates the
    /// preferred count that degraded-mode striping restores after rejoin.
    pub fn set_active(&self, n: usize) {
        self.active.store(n.max(1), Ordering::Relaxed);
        self.preferred_active.store(n.max(1), Ordering::Relaxed);
    }

    /// The active count the path would use if every stream were healthy.
    pub fn preferred_active(&self) -> usize {
        self.preferred_active.load(Ordering::Relaxed)
    }

    /// Degraded-mode clamp: cap the *effective* active count to the
    /// number of live streams without forgetting the preferred count.
    /// Called by the resilience layer on stream death and rejoin.
    pub fn apply_live_limit(&self, live: usize) {
        let preferred = self.preferred_active.load(Ordering::Relaxed);
        self.active.store(preferred.min(live).max(1), Ordering::Relaxed);
    }

    /// Current chunk size.
    pub fn chunk(&self) -> usize {
        self.chunk.load(Ordering::Relaxed)
    }

    /// Set the chunk size.
    pub fn set_chunk(&self, c: usize) {
        self.chunk.store(c.max(1), Ordering::Relaxed);
    }

    /// Current per-stream pacing rate.
    pub fn pacing(&self) -> Option<f64> {
        match self.pacing_bits.load(Ordering::Relaxed) {
            PACING_OFF => None,
            bits => Some(f64::from_bits(bits)),
        }
    }

    /// Set the per-stream pacing rate (`None` disables pacing).
    pub fn set_pacing(&self, rate: Option<f64>) {
        let bits = match rate {
            Some(r) if r > 0.0 => r.to_bits(),
            _ => PACING_OFF,
        };
        self.pacing_bits.store(bits, Ordering::Relaxed);
    }

    /// Current tuning mode.
    pub fn mode(&self) -> TuneMode {
        match self.mode.load(Ordering::Relaxed) {
            MODE_ADAPTIVE => TuneMode::Adaptive,
            _ => TuneMode::Static,
        }
    }

    /// Switch between static and adaptive tuning at runtime.
    pub fn set_mode(&self, m: TuneMode) {
        let v = match m {
            TuneMode::Static => MODE_STATIC,
            TuneMode::Adaptive => MODE_ADAPTIVE,
        };
        self.mode.store(v, Ordering::Relaxed);
    }

    /// Apply a controller decision.
    pub fn apply(&self, d: &Decision) {
        if let Some(n) = d.active {
            self.set_active(n);
        }
        if let Some(c) = d.chunk {
            self.set_chunk(c);
        }
        if let Some(p) = d.pacing {
            self.set_pacing(p);
        }
        if let Some(w) = d.window {
            self.set_window(w);
        }
    }

    /// Snapshot the knobs (controller rate is filled in by
    /// `Path::tune_snapshot`, which also owns the controller).
    pub fn snapshot(&self) -> TuneSnapshot {
        TuneSnapshot {
            active_streams: self.active_streams(),
            chunk_size: self.chunk(),
            pacing_rate: self.pacing(),
            mode: self.mode(),
            ewma_rate: None,
            window: self.window(),
            window_max: self.window_max(),
        }
    }
}

/// What the controller wants changed after a sample (`None` fields =
/// leave as is).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Decision {
    /// New active stream count.
    pub active: Option<usize>,
    /// New chunk size.
    pub chunk: Option<usize>,
    /// New per-stream pacing rate (`Some(None)` = disable pacing).
    pub pacing: Option<Option<f64>>,
    /// New in-flight send window (clamped to the configured ceiling by
    /// [`TuningState::set_window`]).
    pub window: Option<usize>,
}

impl Decision {
    /// True when nothing changes.
    pub fn is_hold(&self) -> bool {
        self.active.is_none()
            && self.chunk.is_none()
            && self.pacing.is_none()
            && self.window.is_none()
    }
}

/// EWMA-fed hill-climbing controller.
///
/// Per qualifying send it folds the observed goodput into an EWMA. At
/// every decision point (each `cooldown + 1` samples) it:
///
/// 1. detects **collapse** — smoothed rate below `drop_frac × best` —
///    and restarts an upward stream search (the restriping trigger);
/// 2. otherwise hill-climbs the active stream count: keep moving while
///    the last move improved the rate, flip direction and halve the
///    step when it regressed (oscillation damping), settle at step 1
///    when moves stop mattering;
/// 3. re-chunks toward `target_calls_per_stream` calls per stream and
///    re-paces to `ewma × pace_headroom` (both damped, both clamped).
#[derive(Debug)]
pub struct AdaptiveController {
    cfg: AdaptConfig,
    max_streams: usize,
    ewma: Option<f64>,
    best: f64,
    /// Instantaneous rate at the previous decision point (0 = none yet).
    last_rate: f64,
    dir: i64,
    step: usize,
    cool: u32,
    settled: bool,
}

impl AdaptiveController {
    /// Controller for a path with `max_streams` established streams.
    pub fn new(cfg: AdaptConfig, max_streams: usize) -> AdaptiveController {
        AdaptiveController {
            cfg,
            max_streams: max_streams.max(1),
            ewma: None,
            best: 0.0,
            last_rate: 0.0,
            dir: 1,
            step: 1,
            cool: 0,
            settled: false,
        }
    }

    /// Cap the hill climb at `live` streams (degraded-mode striping: dead
    /// streams cannot carry traffic, so proposals above the live count
    /// would stall every send). Raising the ceiling (rejoin) restarts the
    /// upward search: the controller may have settled while degraded and
    /// would otherwise never try the recovered streams.
    pub fn set_ceiling(&mut self, live: usize) {
        let live = live.max(1);
        if live > self.max_streams {
            self.settled = false;
            self.dir = 1;
            self.step = self.step.max(1);
            self.last_rate = 0.0;
        }
        self.max_streams = live;
    }

    /// Current hill-climb ceiling.
    pub fn ceiling(&self) -> usize {
        self.max_streams
    }

    /// Seed the rate estimate from the creation-time autotuner, so the
    /// collapse detector has a baseline before the first send.
    pub fn seed_rate(&mut self, rate: f64) {
        if rate > 0.0 {
            self.ewma = Some(rate);
            self.best = self.best.max(rate);
            self.last_rate = rate;
        }
    }

    /// Smoothed goodput estimate, bytes/second.
    pub fn ewma_rate(&self) -> Option<f64> {
        self.ewma
    }

    /// Feed one send observation; `current` is the live knob state the
    /// proposals are relative to. Returns what to change (possibly
    /// nothing).
    pub fn observe(&mut self, bytes: usize, seconds: f64, current: &TuneSnapshot) -> Decision {
        let mut d = Decision::default();
        if seconds <= 0.0 || bytes < self.cfg.min_sample_bytes {
            return d;
        }
        let rate = bytes as f64 / seconds;
        let ewma = match self.ewma {
            None => rate,
            Some(prev) => self.cfg.alpha * rate + (1.0 - self.cfg.alpha) * prev,
        };
        self.ewma = Some(ewma);
        if ewma > self.best {
            self.best = ewma;
        }
        if self.cool > 0 {
            self.cool -= 1;
            return d;
        }
        self.cool = self.cfg.cooldown;

        let collapsed = self.best > 0.0 && ewma < self.cfg.drop_frac * self.best;
        if collapsed {
            // Regime change: forget the stale optimum and search upward —
            // on a congested or lossy bottleneck more parallel streams
            // recover a larger aggregate share (the paper's §1.3.1
            // mechanism, now applied at runtime).
            self.best = ewma;
            self.dir = 1;
            self.step = self.step.max(2);
            self.settled = false;
        }

        // floor can never exceed the established stream count, whatever
        // the config says — clamp would panic on an inverted range
        let lo = self.cfg.min_streams.min(self.max_streams);
        let hi = self.max_streams;
        let active = current.active_streams.clamp(lo, hi);
        if !self.settled {
            // Gains are judged on the *instantaneous* rate of this sample
            // vs the one at the previous decision: right after a regime
            // change the EWMA is still draining the old level, so a
            // smoothed-vs-smoothed comparison would read every move —
            // even a good one — as a regression and stall the ramp.
            if self.last_rate > 0.0 && !collapsed {
                let gain = (rate - self.last_rate) / self.last_rate;
                if gain > self.cfg.improve_frac {
                    // last move helped: accelerate in the same direction
                    self.step = (self.step * 2).min(self.max_streams);
                } else if gain < -self.cfg.improve_frac {
                    // last move hurt: back off, damp the step
                    self.dir = -self.dir;
                    self.step = (self.step / 2).max(1);
                } else if self.step > 1 {
                    self.step /= 2;
                } else {
                    self.settled = true;
                }
            }
            if !self.settled {
                let proposed =
                    (active as i64 + self.dir * self.step as i64).clamp(lo as i64, hi as i64)
                        as usize;
                if proposed != active {
                    d.active = Some(proposed);
                }
            }
        }
        // In-flight send window (resilient paths only — the ceiling is 1
        // everywhere else): on a long-RTT path deeper pipelining is what
        // recovers the goodput a rendezvous-per-message protocol leaves
        // on the table, so keep doubling toward the configured ceiling
        // while samples improve; a collapse means the extra in-flight
        // bytes are queueing behind a congested bottleneck — halve back.
        if current.window_max > 1 {
            if collapsed {
                if current.window > 1 {
                    d.window = Some((current.window / 2).max(1));
                }
            } else if self.last_rate > 0.0
                && (rate - self.last_rate) / self.last_rate > self.cfg.improve_frac
                && current.window < current.window_max
            {
                d.window = Some((current.window * 2).min(current.window_max));
            }
        }
        self.last_rate = rate;

        let goal_active = d.active.unwrap_or(active).max(1);
        if self.cfg.target_calls_per_stream > 0 {
            let per_stream = bytes / goal_active;
            let ideal = (per_stream / self.cfg.target_calls_per_stream)
                .clamp(self.cfg.min_chunk, self.cfg.max_chunk);
            // only act on a >= 2x mismatch — chunk size is a coarse knob
            if ideal >= current.chunk_size.saturating_mul(2)
                || ideal.saturating_mul(2) <= current.chunk_size
            {
                d.chunk = Some(ideal);
            }
        }
        if self.cfg.pace_headroom > 0.0 {
            let per = pacing::per_stream_rate(ewma * self.cfg.pace_headroom, goal_active);
            let apply = match current.pacing_rate {
                None => true,
                Some(p) => (per - p).abs() > 0.25 * p,
            };
            if apply {
                d.pacing = Some(Some(per));
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: usize = 1024 * 1024;

    fn test_cfg() -> AdaptConfig {
        AdaptConfig {
            mode: TuneMode::Adaptive,
            cooldown: 0, // decide on every sample: keeps tests short
            ..Default::default()
        }
    }

    /// Drive the controller with a rate model and apply its decisions to
    /// a snapshot, like Path/AdaptiveSimPath do. Returns the active
    /// stream counts after each sample.
    fn drive(
        c: &mut AdaptiveController,
        snap: &mut TuneSnapshot,
        rate_of: impl Fn(usize) -> f64,
        samples: usize,
    ) -> Vec<usize> {
        let mut trace = Vec::with_capacity(samples);
        for _ in 0..samples {
            let rate = rate_of(snap.active_streams);
            let bytes = 64 * MB;
            let d = c.observe(bytes, bytes as f64 / rate, snap);
            if let Some(n) = d.active {
                snap.active_streams = n;
            }
            if let Some(ch) = d.chunk {
                snap.chunk_size = ch;
            }
            if let Some(p) = d.pacing {
                snap.pacing_rate = p;
            }
            trace.push(snap.active_streams);
        }
        trace
    }

    fn snap(active: usize) -> TuneSnapshot {
        TuneSnapshot {
            active_streams: active,
            chunk_size: MB,
            pacing_rate: None,
            mode: TuneMode::Adaptive,
            ewma_rate: None,
            window: 1,
            window_max: 1,
        }
    }

    #[test]
    fn tuning_state_roundtrips_knobs() {
        let t = TuningState::new(8, 1 << 20, Some(5e6), TuneMode::Adaptive);
        assert_eq!(t.active_streams(), 8);
        assert_eq!(t.chunk(), 1 << 20);
        assert_eq!(t.pacing(), Some(5e6));
        assert_eq!(t.mode(), TuneMode::Adaptive);
        t.set_pacing(None);
        assert_eq!(t.pacing(), None);
        t.set_mode(TuneMode::Static);
        assert_eq!(t.mode(), TuneMode::Static);
        t.apply(&Decision {
            active: Some(3),
            chunk: Some(4096),
            pacing: Some(Some(1e6)),
            window: None,
        });
        assert_eq!(t.active_streams(), 3);
        assert_eq!(t.chunk(), 4096);
        assert_eq!(t.pacing(), Some(1e6));
    }

    #[test]
    fn window_clamps_to_configured_ceiling() {
        let t = TuningState::new(4, 1 << 20, None, TuneMode::Adaptive);
        assert_eq!(t.window(), 1, "windowing defaults off");
        t.init_window(8);
        assert_eq!((t.window(), t.window_max()), (8, 8));
        t.set_window(3);
        assert_eq!(t.window(), 3);
        t.set_window(100);
        assert_eq!(t.window(), 8, "window must not exceed the ceiling");
        t.set_window(0);
        assert_eq!(t.window(), 1, "window floor is 1");
        t.apply(&Decision { window: Some(4), ..Default::default() });
        assert_eq!(t.window(), 4);
    }

    #[test]
    fn controller_widens_window_on_improvement_and_narrows_on_collapse() {
        let mut c = AdaptiveController::new(test_cfg(), 4);
        let mut s = TuneSnapshot { window: 2, window_max: 16, ..snap(4) };
        // sample 1 establishes last_rate; sample 2 improves on it
        let d = c.observe(64 * MB, 1.0, &s);
        assert_eq!(d.window, None, "no baseline yet");
        let d = c.observe(64 * MB, 0.5, &s);
        assert_eq!(d.window, Some(4), "improvement must double the window");
        s.window = 16;
        // collapse: rate falls far below best (the EWMA needs a few
        // samples to drain below the drop threshold)
        let mut narrowed = None;
        for _ in 0..10 {
            let d = c.observe(64 * MB, 100.0, &s);
            if d.window.is_some() {
                narrowed = d.window;
                break;
            }
        }
        assert_eq!(narrowed, Some(8), "collapse must halve the window");
        // a non-resilient path (ceiling 1) never gets window decisions
        let mut c = AdaptiveController::new(test_cfg(), 4);
        let s1 = snap(4);
        c.observe(64 * MB, 1.0, &s1);
        let d = c.observe(64 * MB, 0.5, &s1);
        assert_eq!(d.window, None);
    }

    #[test]
    fn small_samples_are_ignored() {
        let mut c = AdaptiveController::new(test_cfg(), 32);
        let s = snap(4);
        let d = c.observe(1024, 0.001, &s);
        assert!(d.is_hold());
        assert!(c.ewma_rate().is_none());
    }

    #[test]
    fn collapse_triggers_monotone_ramp_up() {
        // fair-share model: N active streams on a bottleneck with 12
        // background flows get N/(N+12) of 1 GB/s
        let congested = |n: usize| 1e9 * n as f64 / (n as f64 + 12.0);
        let clean = |_n: usize| 900e6;

        let mut c = AdaptiveController::new(test_cfg(), 32);
        let mut s = snap(4);
        drive(&mut c, &mut s, clean, 10); // settle on the clean link
        let before = s.active_streams;

        let trace = drive(&mut c, &mut s, congested, 40);
        // ramp is monotone non-decreasing once the collapse registers…
        let start = trace.iter().position(|&a| a > before).expect("controller never restriped");
        for w in trace[start..].windows(2) {
            assert!(w[1] >= w[0], "ramp not monotone: {trace:?}");
        }
        // …and reaches the ceiling, where the congested share is maximal
        assert_eq!(*trace.last().unwrap(), 32, "did not reach ceiling: {trace:?}");
    }

    #[test]
    fn oscillation_damps_to_settled() {
        // flat response: no stream count is better than another
        let mut c = AdaptiveController::new(test_cfg(), 32);
        let mut s = snap(16);
        let trace = drive(&mut c, &mut s, |_| 500e6, 30);
        // after settling, the active count stops changing
        let tail = &trace[trace.len() - 10..];
        assert!(tail.windows(2).all(|w| w[0] == w[1]), "still oscillating: {trace:?}");
        // and never left the clamp range
        assert!(trace.iter().all(|&a| (1..=32).contains(&a)));
    }

    #[test]
    fn clamps_hold_at_floor_and_ceiling() {
        let cfg = AdaptConfig { min_streams: 2, ..test_cfg() };
        let mut c = AdaptiveController::new(cfg, 8);
        let mut s = snap(8);
        // reward fewer streams: controller walks down, must stop at 2
        let trace = drive(&mut c, &mut s, |n| 1e9 / n as f64, 40);
        assert!(trace.iter().all(|&a| (2..=8).contains(&a)), "{trace:?}");
        // reward more streams from the floor: must stop at 8
        let mut c = AdaptiveController::new(AdaptConfig { min_streams: 2, ..test_cfg() }, 8);
        let mut s = snap(2);
        let trace = drive(&mut c, &mut s, |n| 1e6 * n as f64, 40);
        assert!(trace.iter().all(|&a| (2..=8).contains(&a)), "{trace:?}");
        assert_eq!(*trace.last().unwrap(), 8);
    }

    #[test]
    fn chunk_tracks_message_and_stream_count() {
        let mut c = AdaptiveController::new(test_cfg(), 4);
        let s = snap(4);
        // 64 MB over 4 streams, 4 calls per stream -> 4 MB chunks
        let d = c.observe(64 * MB, 1.0, &s);
        assert_eq!(d.chunk, Some(4 * MB));
        // bounds respected for tiny messages
        let mut c = AdaptiveController::new(test_cfg(), 4);
        let s = TuneSnapshot { chunk_size: 4 * MB, ..snap(4) };
        let d = c.observe(256 * 1024, 0.01, &s);
        assert_eq!(d.chunk, Some(MIN_ADAPT_CHUNK));
    }

    #[test]
    fn pacing_follows_ewma_with_headroom() {
        let mut c = AdaptiveController::new(test_cfg(), 4);
        let s = snap(4);
        let d = c.observe(64 * MB, 1.0, &s); // 64 MB/s observed
        let per = d.pacing.expect("pacing decision").expect("enabled");
        let expect = 64.0 * MB as f64 * 1.5 / 4.0;
        assert!((per - expect).abs() < 1.0, "{per} vs {expect}");
        // damping: a repeat observation within 25% does not re-pace
        let s2 = TuneSnapshot { pacing_rate: Some(per), ..s };
        let d2 = c.observe(64 * MB, 1.0, &s2);
        assert_eq!(d2.pacing, None);
    }

    #[test]
    fn pacing_adaptation_can_be_disabled() {
        let cfg = AdaptConfig { pace_headroom: 0.0, target_calls_per_stream: 0, ..test_cfg() };
        let mut c = AdaptiveController::new(cfg, 4);
        let s = snap(4);
        let d = c.observe(64 * MB, 1.0, &s);
        assert_eq!(d.pacing, None);
        assert_eq!(d.chunk, None);
    }

    #[test]
    fn seed_rate_arms_collapse_detector() {
        let mut c = AdaptiveController::new(test_cfg(), 32);
        c.seed_rate(1e9);
        assert_eq!(c.ewma_rate(), Some(1e9));
        let mut s = snap(4);
        // first real samples are far below the seeded baseline: with the
        // seed in place the very first decisions already ramp upward
        let trace = drive(&mut c, &mut s, |n| 1e7 * n as f64, 12);
        assert!(*trace.last().unwrap() > 4, "{trace:?}");
    }

    #[test]
    fn live_limit_clamps_and_restores_preferred() {
        let t = TuningState::new(8, 1 << 20, None, TuneMode::Static);
        assert_eq!(t.preferred_active(), 8);
        t.apply_live_limit(5); // 3 streams died
        assert_eq!(t.active_streams(), 5);
        assert_eq!(t.preferred_active(), 8, "clamp must not overwrite intent");
        t.apply_live_limit(8); // all rejoined
        assert_eq!(t.active_streams(), 8);
        // a deliberate set during degradation updates the preference
        t.apply_live_limit(2);
        t.set_active(2);
        t.apply_live_limit(8);
        assert_eq!(t.active_streams(), 2);
    }

    #[test]
    fn controller_ceiling_caps_proposals() {
        let mut c = AdaptiveController::new(test_cfg(), 16);
        c.set_ceiling(3);
        assert_eq!(c.ceiling(), 3);
        let mut s = snap(3);
        // reward more streams: without the ceiling this ramps to 16
        let trace = drive(&mut c, &mut s, |n| 1e6 * n as f64, 30);
        assert!(trace.iter().all(|&a| a <= 3), "climbed past the live count: {trace:?}");
        // rejoin: ceiling back up, the climb resumes
        c.set_ceiling(16);
        let trace = drive(&mut c, &mut s, |n| 1e6 * n as f64, 40);
        assert!(*trace.last().unwrap() > 3, "never re-absorbed rejoined streams: {trace:?}");
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let ok = AdaptConfig::default();
        assert!(ok.validate().is_ok());
        assert!(AdaptConfig { alpha: 0.0, ..ok.clone() }.validate().is_err());
        assert!(AdaptConfig { alpha: 1.5, ..ok.clone() }.validate().is_err());
        assert!(AdaptConfig { drop_frac: 1.0, ..ok.clone() }.validate().is_err());
        assert!(AdaptConfig { improve_frac: -0.1, ..ok.clone() }.validate().is_err());
        assert!(AdaptConfig { min_streams: 0, ..ok.clone() }.validate().is_err());
        assert!(AdaptConfig { min_chunk: 0, ..ok.clone() }.validate().is_err());
        assert!(
            AdaptConfig { min_chunk: 2 * MAX_ADAPT_CHUNK, ..ok }.validate().is_err()
        );
    }
}
