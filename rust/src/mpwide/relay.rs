//! Message cycling and relaying between paths (`MPW_Cycle`, `MPW_DCycle`,
//! `MPW_Relay`).
//!
//! `cycle` moves one message: send a buffer over one path while receiving
//! from another — the building block for daisy-chaining sites. `relay`
//! pumps **all** traffic between two paths until they close; a standalone
//! [`crate::tools::forwarder`] process wraps it to mimic firewall-style
//! data forwarding on machines where compute nodes cannot accept inbound
//! connections (paper Fig 3).

use std::time::Duration;

use super::errors::{MpwError, Result};
use super::path::Path;

/// Buffer size used by the relay pump loops.
pub const RELAY_BUF: usize = 256 * 1024;

/// `MPW_Cycle`: send `buf` over `send_to` while receiving `recv_len` bytes
/// from `recv_from`. Returns the received message.
pub fn cycle(recv_from: &Path, send_to: &Path, buf: &[u8], recv_len: usize) -> Result<Vec<u8>> {
    std::thread::scope(|scope| -> Result<Vec<u8>> {
        let tx = scope.spawn(|| send_to.send(buf).map(|_| ()));
        let mut out = vec![0u8; recv_len];
        recv_from.recv(&mut out)?;
        tx.join().map_err(|_| MpwError::WorkerPanic("cycle send".into()))??;
        Ok(out)
    })
}

/// `MPW_DCycle`: like [`cycle`] but with dynamic sizes and a reusable
/// receive cache. Returns the received length (data is in `cache`).
pub fn dcycle(
    recv_from: &Path,
    send_to: &Path,
    buf: &[u8],
    cache: &mut Vec<u8>,
) -> Result<usize> {
    std::thread::scope(|scope| -> Result<usize> {
        let tx = scope.spawn(|| send_to.dsend(buf));
        let n = recv_from.drecv_into(cache)?;
        tx.join().map_err(|_| MpwError::WorkerPanic("dcycle send".into()))??;
        Ok(n)
    })
}

/// Totals moved by a [`relay`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RelayStats {
    /// Bytes forwarded from path `a` to path `b`.
    pub a_to_b: u64,
    /// Bytes forwarded from path `b` to path `a`.
    pub b_to_a: u64,
}

/// `MPW_Relay`: sustained bidirectional forwarding of all traffic between
/// two paths, stream-for-stream, until both directions reach end-of-stream.
/// Requires equal stream counts (the forwarder creates both sides, so this
/// holds by construction).
///
/// When one leg dies mid-pump (a hard stream error rather than a clean
/// close), the relay tears **both** paths down so every pump unblocks,
/// and returns [`MpwError::RelayBroken`] carrying the partial totals —
/// a dead leg must surface promptly, not hang the forwarder forever on
/// the healthy leg's idle streams.
pub fn relay(a: &Path, b: &Path) -> Result<RelayStats> {
    relay_delayed(a, b, None)
}

/// [`relay`] with an artificial one-way delay per forwarded batch
/// (propagation emulation — what the user-space forwarder's `--delay-ms`
/// exposes). `None` forwards immediately.
pub fn relay_delayed(a: &Path, b: &Path, delay: Option<Duration>) -> Result<RelayStats> {
    if a.nstreams() != b.nstreams() {
        return Err(MpwError::Config(format!(
            "relay requires equal stream counts ({} vs {})",
            a.nstreams(),
            b.nstreams()
        )));
    }
    let n = a.nstreams();
    std::thread::scope(|scope| -> Result<RelayStats> {
        let mut fwd = Vec::with_capacity(n);
        let mut bwd = Vec::with_capacity(n);
        for i in 0..n {
            let (sa, sb) = (&a.streams[i], &b.streams[i]);
            fwd.push(scope.spawn(move || pump_guarded(sa, sb, a, b, delay)));
            bwd.push(scope.spawn(move || pump_guarded(sb, sa, a, b, delay)));
        }
        let mut stats = RelayStats { a_to_b: 0, b_to_a: 0 };
        let mut first_err: Option<MpwError> = None;
        for h in fwd {
            let (moved, err) =
                h.join().map_err(|_| MpwError::WorkerPanic("relay fwd".into()))?;
            stats.a_to_b += moved;
            if let Some(e) = err {
                first_err.get_or_insert(e);
            }
        }
        for h in bwd {
            let (moved, err) =
                h.join().map_err(|_| MpwError::WorkerPanic("relay bwd".into()))?;
            stats.b_to_a += moved;
            if let Some(e) = err {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(stats),
            Some(e) => Err(MpwError::RelayBroken {
                a_to_b: stats.a_to_b,
                b_to_a: stats.b_to_a,
                detail: e.to_string(),
            }),
        }
    })
}

/// Channel-aware relay: forward whole **messages** between two paths in
/// both directions until either side closes. Unlike the byte-level
/// [`relay`], which splices stream `i` of one path to stream `i` of the
/// other (and therefore requires equal stream counts), the message
/// relay re-sends each dynamic message through the far path's own
/// striping — so mux channel frames (ids, sequence numbers) survive the
/// hop intact **across legs with different stream counts, chunk sizes
/// or resilience settings**. This is what makes a forwarder a valid hop
/// for multiplexed traffic: N channels cross the relay as N interleaved
/// frame streams without the relay knowing or caring which is which.
///
/// A clean close of either leg (EOF-like errors) ends the relay with
/// `Ok`; a hard error tears both paths down and surfaces as
/// [`MpwError::RelayBroken`] with the partial totals, exactly like the
/// byte relay.
pub fn relay_messages(a: &Path, b: &Path) -> Result<RelayStats> {
    let mut ab: (u64, Option<MpwError>) = (0, None);
    let mut ba: (u64, Option<MpwError>) = (0, None);
    {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| ab = pump_messages_guarded(a, b)),
            Box::new(|| ba = pump_messages_guarded(b, a)),
        ];
        crate::util::pool::scope(jobs);
    }
    let stats = RelayStats { a_to_b: ab.0, b_to_a: ba.0 };
    match ab.1.or(ba.1) {
        None => Ok(stats),
        Some(e) => Err(MpwError::RelayBroken {
            a_to_b: stats.a_to_b,
            b_to_a: stats.b_to_a,
            detail: e.to_string(),
        }),
    }
}

/// One direction of the message relay plus teardown: any end (clean or
/// hard) force-closes both paths so the sibling pump unblocks — a
/// message relay session is one-shot by design.
fn pump_messages_guarded(src: &Path, dst: &Path) -> (u64, Option<MpwError>) {
    let mut cache = Vec::new();
    let mut total = 0u64;
    let err = loop {
        match src.drecv_into(&mut cache) {
            Ok(n) => {
                if let Err(e) = dst.dsend(&cache[..n]) {
                    break classify_relay_end(e);
                }
                // counted only once the far leg accepted it, so the
                // partial totals in RelayBroken mean the same thing as
                // the byte relay's
                total += n as u64;
            }
            Err(e) => break classify_relay_end(e),
        }
    };
    src.shutdown_all_streams();
    dst.shutdown_all_streams();
    (total, err)
}

/// Separate the normal ways a message-relay leg ends (peer closed its
/// path, or the sibling pump tore the session down) from genuine
/// failures.
fn classify_relay_end(e: MpwError) -> Option<MpwError> {
    let clean = match &e {
        MpwError::Io(io) => matches!(
            io.kind(),
            std::io::ErrorKind::UnexpectedEof
                | std::io::ErrorKind::ConnectionAborted
                | std::io::ErrorKind::ConnectionReset
                | std::io::ErrorKind::BrokenPipe
        ),
        MpwError::AllStreamsDead | MpwError::StreamDead { .. } => true,
        _ => false,
    };
    if clean {
        None
    } else {
        Some(e)
    }
}

/// [`pump`] plus teardown: a hard pump error force-closes every stream
/// of both paths so sibling pumps parked in reads unblock instead of
/// hanging the relay.
fn pump_guarded(
    src: &crate::mpwide::path::StreamSlot,
    dst: &crate::mpwide::path::StreamSlot,
    a: &Path,
    b: &Path,
    delay: Option<Duration>,
) -> (u64, Option<MpwError>) {
    let out = pump(src, dst, delay);
    if out.1.is_some() {
        a.shutdown_all_streams();
        b.shutdown_all_streams();
    }
    out
}

/// Copy bytes from `src`'s read half to `dst`'s write half until EOF.
/// Returns the bytes moved and the hard error that stopped the pump, if
/// any (clean close and shutdown races report no error).
///
/// Known limitation: `ConnectionReset`/`BrokenPipe` are treated as a
/// clean close because peers routinely reset right after finishing (the
/// normal shutdown race) — without message framing the pump cannot tell
/// that apart from a mid-transfer reset, so a reset-killed leg ends its
/// own pump quietly rather than tearing the relay down. Endpoint-level
/// recovery for that case lives in `mpwide::resilience`, not here.
fn pump(
    src: &crate::mpwide::path::StreamSlot,
    dst: &crate::mpwide::path::StreamSlot,
    delay: Option<Duration>,
) -> (u64, Option<MpwError>) {
    let mut buf = vec![0u8; RELAY_BUF];
    let mut total = 0u64;
    loop {
        let n = {
            let mut rx = src.rx.lock();
            match rx.read_some(&mut buf) {
                Ok(0) => break,
                Ok(n) => n,
                // Peer reset after finishing is a normal shutdown race.
                Err(e)
                    if e.kind() == std::io::ErrorKind::ConnectionReset
                        || e.kind() == std::io::ErrorKind::BrokenPipe =>
                {
                    break
                }
                Err(e) => return (total, Some(e.into())),
            }
        };
        if let Some(d) = delay {
            std::thread::sleep(d);
        }
        let mut tx = dst.tx.lock();
        tx.pacer.acquire(n);
        match tx.w.write_all(&buf[..n]) {
            Ok(()) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::ConnectionReset
                    || e.kind() == std::io::ErrorKind::BrokenPipe =>
            {
                break
            }
            Err(e) => return (total, Some(e.into())),
        }
        if let Err(e) = tx.w.flush() {
            return (total, Some(e.into()));
        }
        total += n as u64;
    }
    (total, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::config::PathConfig;
    use crate::mpwide::transport::mem_path_pairs;
    use crate::util::Rng;

    fn mem_paths(n: usize) -> (Path, Path) {
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        (Path::from_pairs(l, cfg.clone()).unwrap(), Path::from_pairs(r, cfg).unwrap())
    }

    #[test]
    fn cycle_moves_between_paths() {
        // topology: left <-> mid(a, b) <-> right
        let (left, mid_a) = mem_paths(2);
        let (mid_b, right) = mem_paths(2);
        let t_left = std::thread::spawn(move || {
            left.send(&[1u8; 100]).unwrap();
        });
        let t_right = std::thread::spawn(move || {
            let mut buf = vec![0u8; 100];
            right.recv(&mut buf).unwrap();
            buf
        });
        // mid receives from left, forwards to right (its own payload here
        // is what it received — classic cycle usage passes a buffer along).
        let got = cycle(&mid_a, &mid_b, &[0u8; 0], 0).unwrap();
        assert!(got.is_empty());
        let mut buf = vec![0u8; 100];
        mid_a.recv(&mut buf).unwrap();
        mid_b.send(&buf).unwrap();
        assert_eq!(t_right.join().unwrap(), vec![1u8; 100]);
        t_left.join().unwrap();
    }

    #[test]
    fn dcycle_roundtrip() {
        let (left, mid_a) = mem_paths(1);
        let (mid_b, right) = mem_paths(1);
        let payload = vec![9u8; 4096];
        let p2 = payload.clone();
        let t_left = std::thread::spawn(move || left.dsend(&p2).unwrap());
        let t_right = std::thread::spawn(move || right.drecv().unwrap());
        let mut cache = Vec::new();
        // receive from left, forward the same bytes to right
        let n = dcycle(&mid_a, &mid_b, &[], &mut cache).unwrap();
        assert_eq!(n, 4096);
        // the dcycle above sent an empty message first; consume it…
        let first = t_right.join().unwrap();
        assert!(first.is_empty());
        // …then forward the real payload
        let t_right2 = {
            let (mid_b2, right2) = mem_paths(1);
            let h = std::thread::spawn(move || right2.drecv().unwrap());
            mid_b2.dsend(&cache[..n]).unwrap();
            h
        };
        assert_eq!(t_right2.join().unwrap(), payload);
        t_left.join().unwrap();
    }

    #[test]
    fn relay_rejects_mismatched_streams() {
        let (a, _a2) = mem_paths(2);
        let (b, _b2) = mem_paths(3);
        assert!(relay(&a, &b).is_err());
    }

    #[test]
    fn relay_leg_death_returns_partial_stats_not_hang() {
        use crate::mpwide::transport::mem_path_pairs_killable;
        // left <-> (fwd_l | fwd_r) <-> right, with a kill switch on one
        // stream of the left leg.
        let (l, fl, kills) = mem_path_pairs_killable(2);
        let (fr, right) = mem_path_pairs(2);
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        let left = Path::from_pairs(l, cfg.clone()).unwrap();
        let fwd_l = Path::from_pairs(fl, cfg.clone()).unwrap();
        let fwd_r = Path::from_pairs(fr, cfg.clone()).unwrap();
        let right = Path::from_pairs(right, cfg).unwrap();

        let t_relay = std::thread::spawn(move || relay(&fwd_l, &fwd_r));
        let t_right = std::thread::spawn(move || {
            let mut buf = vec![0u8; 10_000];
            right.recv(&mut buf).unwrap();
            buf
        });
        left.send(&[3u8; 10_000]).unwrap();
        assert_eq!(t_right.join().unwrap(), vec![3u8; 10_000]);
        // now sever one stream of the left leg while the relay idles on it
        kills[1].fire();
        let r = t_relay.join().unwrap();
        match r {
            Err(MpwError::RelayBroken { a_to_b, b_to_a, detail }) => {
                let hdr = crate::mpwide::path::ACTIVE_HEADER_LEN as u64;
                assert_eq!(a_to_b, 10_000 + hdr, "partial totals must survive");
                assert_eq!(b_to_a, 0);
                assert!(!detail.is_empty());
            }
            other => panic!("expected RelayBroken, got {other:?}"),
        }
        // the left endpoint sees the teardown as stream errors, not a hang
        assert!(left.send(&[1u8; 64]).is_err());
    }

    #[test]
    fn message_relay_bridges_unequal_stream_counts() {
        // left(2 streams) <-> [fwd_l(2) | fwd_r(3)] <-> right(3 streams):
        // the byte relay would reject this; the message relay re-stripes
        // each hop, so channel frames survive unequal legs.
        let (left, fwd_l) = mem_paths(2);
        let (fwd_r, right) = {
            let (l, r) = mem_path_pairs(3);
            let mut cfg = PathConfig::with_streams(3);
            cfg.autotune = false;
            (Path::from_pairs(l, cfg.clone()).unwrap(), Path::from_pairs(r, cfg).unwrap())
        };
        let t_relay = std::thread::spawn(move || relay_messages(&fwd_l, &fwd_r));
        let t_right = std::thread::spawn(move || {
            let m = right.drecv().unwrap();
            right.dsend(&m).unwrap(); // echo
            m
        });
        left.dsend(&[5u8; 10_000]).unwrap();
        let back = left.drecv().unwrap();
        assert_eq!(back, vec![5u8; 10_000]);
        assert_eq!(t_right.join().unwrap(), vec![5u8; 10_000]);
        drop(left); // clean close ends the relay session
        let stats = t_relay.join().unwrap().unwrap();
        assert_eq!(stats.a_to_b, 10_000);
        assert_eq!(stats.b_to_a, 10_000);
    }

    #[test]
    fn relay_forwards_both_directions() {
        // ends: left <-> (fwd_l | fwd_r) <-> right
        let (left, fwd_l) = mem_paths(2);
        let (fwd_r, right) = mem_paths(2);
        let mut msg_lr = vec![0u8; 50_000];
        let mut msg_rl = vec![0u8; 20_000];
        Rng::new(5).fill_bytes(&mut msg_lr);
        Rng::new(6).fill_bytes(&mut msg_rl);
        let (m1, m2) = (msg_lr.clone(), msg_rl.clone());

        let t_relay = std::thread::spawn(move || relay(&fwd_l, &fwd_r).unwrap());
        let t_right = std::thread::spawn(move || {
            let mut buf = vec![0u8; 50_000];
            right.recv(&mut buf).unwrap();
            right.send(&msg_rl).unwrap();
            drop(right); // close so the relay sees EOF
            buf
        });
        left.send(&msg_lr).unwrap();
        let mut buf = vec![0u8; 20_000];
        left.recv(&mut buf).unwrap();
        assert_eq!(buf, m2);
        assert_eq!(t_right.join().unwrap(), m1);
        drop(left);
        let stats = t_relay.join().unwrap();
        // payload + the per-message active-stream header on stream 0
        let hdr = crate::mpwide::path::ACTIVE_HEADER_LEN as u64;
        assert_eq!(stats.a_to_b, 50_000 + hdr);
        assert_eq!(stats.b_to_a, 20_000 + hdr);
    }
}
