//! Message cycling and relaying between paths (`MPW_Cycle`, `MPW_DCycle`,
//! `MPW_Relay`).
//!
//! `cycle` moves one message: send a buffer over one path while receiving
//! from another — the building block for daisy-chaining sites. `relay`
//! pumps **all** traffic between two paths until they close; a standalone
//! [`crate::tools::forwarder`] process wraps it to mimic firewall-style
//! data forwarding on machines where compute nodes cannot accept inbound
//! connections (paper Fig 3).

use super::errors::{MpwError, Result};
use super::path::Path;

/// Buffer size used by the relay pump loops.
pub const RELAY_BUF: usize = 256 * 1024;

/// `MPW_Cycle`: send `buf` over `send_to` while receiving `recv_len` bytes
/// from `recv_from`. Returns the received message.
pub fn cycle(recv_from: &Path, send_to: &Path, buf: &[u8], recv_len: usize) -> Result<Vec<u8>> {
    std::thread::scope(|scope| -> Result<Vec<u8>> {
        let tx = scope.spawn(|| send_to.send(buf).map(|_| ()));
        let mut out = vec![0u8; recv_len];
        recv_from.recv(&mut out)?;
        tx.join().map_err(|_| MpwError::WorkerPanic("cycle send".into()))??;
        Ok(out)
    })
}

/// `MPW_DCycle`: like [`cycle`] but with dynamic sizes and a reusable
/// receive cache. Returns the received length (data is in `cache`).
pub fn dcycle(
    recv_from: &Path,
    send_to: &Path,
    buf: &[u8],
    cache: &mut Vec<u8>,
) -> Result<usize> {
    std::thread::scope(|scope| -> Result<usize> {
        let tx = scope.spawn(|| send_to.dsend(buf));
        let n = recv_from.drecv_into(cache)?;
        tx.join().map_err(|_| MpwError::WorkerPanic("dcycle send".into()))??;
        Ok(n)
    })
}

/// Totals moved by a [`relay`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RelayStats {
    /// Bytes forwarded from path `a` to path `b`.
    pub a_to_b: u64,
    /// Bytes forwarded from path `b` to path `a`.
    pub b_to_a: u64,
}

/// `MPW_Relay`: sustained bidirectional forwarding of all traffic between
/// two paths, stream-for-stream, until both directions reach end-of-stream.
/// Requires equal stream counts (the forwarder creates both sides, so this
/// holds by construction).
pub fn relay(a: &Path, b: &Path) -> Result<RelayStats> {
    if a.nstreams() != b.nstreams() {
        return Err(MpwError::Config(format!(
            "relay requires equal stream counts ({} vs {})",
            a.nstreams(),
            b.nstreams()
        )));
    }
    let n = a.nstreams();
    std::thread::scope(|scope| -> Result<RelayStats> {
        let mut fwd = Vec::with_capacity(n);
        let mut bwd = Vec::with_capacity(n);
        for i in 0..n {
            let (sa, sb) = (&a.streams[i], &b.streams[i]);
            fwd.push(scope.spawn(move || pump(sa, sb)));
            bwd.push(scope.spawn(move || pump(sb, sa)));
        }
        let mut stats = RelayStats { a_to_b: 0, b_to_a: 0 };
        for h in fwd {
            stats.a_to_b += h.join().map_err(|_| MpwError::WorkerPanic("relay fwd".into()))??;
        }
        for h in bwd {
            stats.b_to_a += h.join().map_err(|_| MpwError::WorkerPanic("relay bwd".into()))??;
        }
        Ok(stats)
    })
}

/// Copy bytes from `src`'s read half to `dst`'s write half until EOF.
fn pump(
    src: &crate::mpwide::path::StreamSlot,
    dst: &crate::mpwide::path::StreamSlot,
) -> Result<u64> {
    let mut buf = vec![0u8; RELAY_BUF];
    let mut total = 0u64;
    loop {
        let n = {
            let mut rx = src.rx.lock().unwrap();
            match rx.read_some(&mut buf) {
                Ok(0) => break,
                Ok(n) => n,
                // Peer reset after finishing is a normal shutdown race.
                Err(e)
                    if e.kind() == std::io::ErrorKind::ConnectionReset
                        || e.kind() == std::io::ErrorKind::BrokenPipe =>
                {
                    break
                }
                Err(e) => return Err(e.into()),
            }
        };
        let mut tx = dst.tx.lock().unwrap();
        tx.pacer.acquire(n);
        match tx.w.write_all(&buf[..n]) {
            Ok(()) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::ConnectionReset
                    || e.kind() == std::io::ErrorKind::BrokenPipe =>
            {
                break
            }
            Err(e) => return Err(e.into()),
        }
        tx.w.flush()?;
        total += n as u64;
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::config::PathConfig;
    use crate::mpwide::transport::mem_path_pairs;
    use crate::util::Rng;

    fn mem_paths(n: usize) -> (Path, Path) {
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        (Path::from_pairs(l, cfg.clone()).unwrap(), Path::from_pairs(r, cfg).unwrap())
    }

    #[test]
    fn cycle_moves_between_paths() {
        // topology: left <-> mid(a, b) <-> right
        let (left, mid_a) = mem_paths(2);
        let (mid_b, right) = mem_paths(2);
        let t_left = std::thread::spawn(move || {
            left.send(&vec![1u8; 100]).unwrap();
        });
        let t_right = std::thread::spawn(move || {
            let mut buf = vec![0u8; 100];
            right.recv(&mut buf).unwrap();
            buf
        });
        // mid receives from left, forwards to right (its own payload here
        // is what it received — classic cycle usage passes a buffer along).
        let got = cycle(&mid_a, &mid_b, &vec![0u8; 0], 0).unwrap();
        assert!(got.is_empty());
        let mut buf = vec![0u8; 100];
        mid_a.recv(&mut buf).unwrap();
        mid_b.send(&buf).unwrap();
        assert_eq!(t_right.join().unwrap(), vec![1u8; 100]);
        t_left.join().unwrap();
    }

    #[test]
    fn dcycle_roundtrip() {
        let (left, mid_a) = mem_paths(1);
        let (mid_b, right) = mem_paths(1);
        let payload = vec![9u8; 4096];
        let p2 = payload.clone();
        let t_left = std::thread::spawn(move || left.dsend(&p2).unwrap());
        let t_right = std::thread::spawn(move || right.drecv().unwrap());
        let mut cache = Vec::new();
        // receive from left, forward the same bytes to right
        let n = dcycle(&mid_a, &mid_b, &[], &mut cache).unwrap();
        assert_eq!(n, 4096);
        // the dcycle above sent an empty message first; consume it…
        let first = t_right.join().unwrap();
        assert!(first.is_empty());
        // …then forward the real payload
        let t_right2 = {
            let (mid_b2, right2) = mem_paths(1);
            let h = std::thread::spawn(move || right2.drecv().unwrap());
            mid_b2.dsend(&cache[..n]).unwrap();
            h
        };
        assert_eq!(t_right2.join().unwrap(), payload);
        t_left.join().unwrap();
    }

    #[test]
    fn relay_rejects_mismatched_streams() {
        let (a, _a2) = mem_paths(2);
        let (b, _b2) = mem_paths(3);
        assert!(relay(&a, &b).is_err());
    }

    #[test]
    fn relay_forwards_both_directions() {
        // ends: left <-> (fwd_l | fwd_r) <-> right
        let (left, fwd_l) = mem_paths(2);
        let (fwd_r, right) = mem_paths(2);
        let mut msg_lr = vec![0u8; 50_000];
        let mut msg_rl = vec![0u8; 20_000];
        Rng::new(5).fill_bytes(&mut msg_lr);
        Rng::new(6).fill_bytes(&mut msg_rl);
        let (m1, m2) = (msg_lr.clone(), msg_rl.clone());

        let t_relay = std::thread::spawn(move || relay(&fwd_l, &fwd_r).unwrap());
        let t_right = std::thread::spawn(move || {
            let mut buf = vec![0u8; 50_000];
            right.recv(&mut buf).unwrap();
            right.send(&msg_rl).unwrap();
            drop(right); // close so the relay sees EOF
            buf
        });
        left.send(&msg_lr).unwrap();
        let mut buf = vec![0u8; 20_000];
        left.recv(&mut buf).unwrap();
        assert_eq!(buf, m2);
        assert_eq!(t_right.join().unwrap(), m1);
        drop(left);
        let stats = t_relay.join().unwrap();
        // payload + the per-message active-stream header on stream 0
        let hdr = crate::mpwide::path::ACTIVE_HEADER_LEN as u64;
        assert_eq!(stats.a_to_b, 50_000 + hdr);
        assert_eq!(stats.b_to_a, 20_000 + hdr);
    }
}
