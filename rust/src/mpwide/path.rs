//! The communication **path**: MPWide's central abstraction (§1.3.1).
//!
//! A path is a logical connection made of 1–256 parallel TCP streams.
//! `send` stripes the message evenly over the **active** streams
//! ([`super::stripe`]) and drives each stream from its own thread,
//! writing in chunk-size units through the per-stream
//! [`Pacer`](super::pacing::Pacer) — the same pthread-per-stream design as
//! the C++ original. `send`/`recv` sizes must match on both ends (like
//! MPI); use [`super::dynamic`] for unknown-size messages.
//!
//! The per-operation knobs (active stream count, chunk size, pacing) are
//! read from the path's lock-free [`TuningState`] so the
//! [`adapt`](super::adapt)ive controller can adjust them mid-run. Every
//! message carries a 2-byte header on stream 0 advertising the sender's
//! active stream count, so the receiver restripes in lockstep without any
//! negotiation round-trip.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::lockorder::{rank, OrderedMutex};

use super::adapt::{AdaptiveController, TuneMode, TuneSnapshot, TuningState};
use super::config::{PathConfig, ReconnectPolicy};
use super::errors::{MpwError, Result};
use super::pacing::Pacer;
use super::resilience::{self, FrameBox, HealthState, PathStatus, RejoinDaemon, RejoinRegistry};
use super::stripe::{self, SplitBuf};
use super::transport::{
    connect_streams, HalfDuplex, KillSwitch, RawPathListener, StreamPair, HELLO_VERSION,
};

/// Wire size of the per-message active-stream header (u16, big endian,
/// on stream 0 ahead of the striped payload).
pub const ACTIVE_HEADER_LEN: usize = 2;

/// Write half of one stream plus its pacer (locked together: pacing is
/// per-stream and applies to writes).
pub(crate) struct TxHalf {
    pub w: Box<dyn HalfDuplex>,
    pub pacer: Pacer,
}

/// Transport metadata of one stream, replaced wholesale on rejoin.
pub(crate) struct SlotMeta {
    /// Raw socket fd when TCP-backed, for later `MPW_setWin` calls.
    pub fd: Option<i32>,
    /// Force-close handle (failure isolation / relay teardown).
    pub kill: KillSwitch,
}

/// One stream of a path: independently lockable halves so a send and a
/// receive can run concurrently (`MPW_SendRecv`).
pub(crate) struct StreamSlot {
    pub tx: OrderedMutex<TxHalf>,
    pub rx: OrderedMutex<Box<dyn HalfDuplex>>,
    pub meta: OrderedMutex<SlotMeta>,
    /// Failure flag (resilience layer); dead streams carry no traffic
    /// until a rejoin replaces their transport.
    pub dead: AtomicBool,
    /// Frames read off this stream for another consumer (resilient mode).
    pub inbox: FrameBox,
}

/// A communication path between two endpoints.
///
/// The central MPWide abstraction: 1–256 parallel TCP streams driven as
/// one logical connection, with striping, chunking, pacing and (opt-in)
/// resilience and windowed pipelining layered on top. Construct with
/// [`Path::connect`] / [`PathListener::accept_path`] for sockets, or
/// [`Path::from_pairs`] over any transport.
///
/// # Examples
///
/// ```
/// use mpwide::mpwide::{Path, PathConfig};
/// # use mpwide::mpwide::transport::mem_path_pairs;
/// let mut cfg = PathConfig::with_streams(4);
/// cfg.autotune = false; // autotuning needs the two-sided probe protocol
/// let (l, r) = mem_path_pairs(4);
/// let a = Path::from_pairs(l, cfg.clone()).unwrap();
/// let b = Path::from_pairs(r, cfg).unwrap();
/// let msg = vec![42u8; 100_000];
/// let t = std::thread::spawn(move || {
///     let mut buf = vec![0u8; 100_000]; // sizes must match, like MPI
///     b.recv(&mut buf).unwrap();
///     buf
/// });
/// a.send(&msg).unwrap();
/// assert_eq!(t.join().unwrap(), msg);
/// ```
pub struct Path {
    pub(crate) streams: Vec<StreamSlot>,
    cfg: OrderedMutex<PathConfig>,
    /// Live performance knobs, consulted per operation (lock-free reads).
    tuning: Arc<TuningState>,
    /// Online tuner fed by the send path when the mode is adaptive.
    controller: OrderedMutex<AdaptiveController>,
    peer: String,
    /// Serializes whole send operations so concurrent sends (e.g. several
    /// non-blocking handles on one path) cannot interleave the byte
    /// streams mid-message.
    pub(crate) send_gate: OrderedMutex<()>,
    /// Serializes whole receive operations (same rationale).
    pub(crate) recv_gate: OrderedMutex<()>,
    /// Stream health (rejoin generation, rejoin tally, waiter condvar).
    pub(crate) health: HealthState,
    /// Sticky control stream index for resilient framing.
    pub(crate) cur_ctrl: AtomicUsize,
    /// Next outgoing / expected incoming message sequence numbers of the
    /// resilient protocol (guarded by the send/recv gates respectively).
    pub(crate) res_send_seq: AtomicU64,
    pub(crate) res_recv_seq: AtomicU64,
    /// Resilient framing enabled (cached from the config at creation;
    /// both ends must agree, like every other MPWide knob).
    resilient: bool,
    /// Progress budget for the resilient sender's ACK wait (cached from
    /// the config; `None` disables the watchdog).
    ack_timeout: Option<Duration>,
    /// Timer thread firing the control stream's kill switch when an ACK
    /// wait exceeds its budget (lazily spawned on first armed wait).
    pub(crate) ack_watchdog: resilience::AckWatchdog,
    /// Windowed sender state: messages posted but not yet acknowledged
    /// (empty and inert while `resilience.window == 1`).
    pub(crate) send_window: resilience::SendWindow,
    /// Receiver-side stash for messages a pipelining peer completed out
    /// of turn (see [`resilience::MAX_WINDOW`]).
    pub(crate) recv_reorder: resilience::ReorderBuf,
    /// Latest credit the peer's receiver advertised (credit flow
    /// control); the windowed sender posts only against it.
    pub(crate) send_credit: resilience::SendCredit,
    /// Whether the peer understands credit frames (hello version >= 1).
    /// False until proven: sending an extended ACK or a WINDOW_UPDATE
    /// kind to a legacy peer would be a fatal protocol error over there.
    /// The connecting side cannot learn the acceptor's version at the
    /// initial handshake (there is no hello reply), so it starts false
    /// and flips on the first credit-bearing frame the peer sends us.
    peer_credit_aware: AtomicBool,
    /// Monotone id for our outgoing credit adverts (starts at 1; the
    /// peer's `SendCredit` treats id 0 as "nothing applied yet").
    credit_advert: AtomicU64,
    /// Byte budget for the reorder stash (cached from the config).
    recv_stash_high_water: Option<usize>,
    /// `SO_SNDTIMEO`-style write deadline (cached from the config;
    /// reapplied to every rejoined stream).
    write_timeout: Option<Duration>,
    /// Sticky closed flag: set by [`Path::close`], never cleared. Gates
    /// rejoin so a closed path cannot be resurrected by its monitor.
    closed: AtomicBool,
    /// Reconnect policy consulted by zero-live waits and the monitor.
    reconnect: OrderedMutex<ReconnectPolicy>,
    /// `host:port` + path uuid of the remote end (connecting side only);
    /// what the reconnect monitor redials.
    remote: OrderedMutex<Option<(String, u64)>>,
    /// Path uuid from the stream handshake (both sides, where known).
    uuid: OrderedMutex<Option<u64>>,
}

impl std::fmt::Debug for Path {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Path")
            .field("peer", &self.peer)
            .field("nstreams", &self.streams.len())
            .field("active", &self.tuning.active_streams())
            .field("live", &self.live_stream_indices().len())
            .finish()
    }
}

impl Path {
    /// Build a path from already-established stream pairs. Applies the
    /// configured TCP window to every stream. (Autotuning is a two-sided
    /// protocol and is run by [`Path::connect`] / [`PathListener::accept_path`].)
    pub fn from_pairs(pairs: Vec<StreamPair>, cfg: PathConfig) -> Result<Path> {
        if pairs.is_empty() {
            return Err(MpwError::Config("a path needs at least one stream".into()));
        }
        let mut cfg = cfg;
        cfg.nstreams = pairs.len();
        cfg.validate()?;
        if let Some(win) = cfg.tcp_window {
            for p in &pairs {
                p.set_window(win)?;
            }
        }
        if let Some(t) = cfg.resilience.write_timeout {
            for p in &pairs {
                p.set_send_timeout(Some(t))?;
            }
        }
        let peer = pairs[0].peer.clone();
        let streams: Vec<StreamSlot> = pairs
            .into_iter()
            .map(|p| {
                let (tx, rx, fd, kill) = p.into_parts();
                StreamSlot {
                    tx: OrderedMutex::new(
                        rank::STREAM_TX,
                        TxHalf { w: tx, pacer: Pacer::new(cfg.pacing_rate) },
                    ),
                    rx: OrderedMutex::new(rank::STREAM_RX, rx),
                    meta: OrderedMutex::new(rank::STREAM_META, SlotMeta { fd, kill }),
                    dead: AtomicBool::new(false),
                    inbox: FrameBox::default(),
                }
            })
            .collect();
        let tuning = Arc::new(TuningState::from_config(&cfg));
        let controller = OrderedMutex::new(
            rank::CONTROLLER,
            AdaptiveController::new(cfg.adapt.clone(), streams.len()),
        );
        let resilient = cfg.resilience.enabled;
        let ack_timeout = cfg.resilience.ack_timeout;
        let write_timeout = cfg.resilience.write_timeout;
        let recv_stash_high_water = cfg.resilience.recv_stash_high_water;
        let reconnect = cfg.resilience.reconnect.clone();
        Ok(Path {
            streams,
            cfg: OrderedMutex::new(rank::PATH_CFG, cfg),
            tuning,
            controller,
            peer,
            send_gate: OrderedMutex::new(rank::SEND_GATE, ()),
            recv_gate: OrderedMutex::new(rank::RECV_GATE, ()),
            health: HealthState::new(),
            cur_ctrl: AtomicUsize::new(0),
            res_send_seq: AtomicU64::new(0),
            res_recv_seq: AtomicU64::new(0),
            resilient,
            ack_timeout,
            ack_watchdog: resilience::AckWatchdog::new(),
            send_window: resilience::SendWindow::default(),
            recv_reorder: resilience::ReorderBuf::default(),
            send_credit: resilience::SendCredit::default(),
            // from_pairs is the same-build constructor (tests, in-memory
            // transports, forwarders): both ends speak the current
            // revision. The socket constructors override this from the
            // handshake below.
            peer_credit_aware: AtomicBool::new(true),
            credit_advert: AtomicU64::new(1),
            recv_stash_high_water,
            write_timeout,
            closed: AtomicBool::new(false),
            reconnect: OrderedMutex::new(rank::RECONNECT_POLICY, reconnect),
            remote: OrderedMutex::new(rank::PATH_REMOTE, None),
            uuid: OrderedMutex::new(rank::PATH_UUID, None),
        })
    }

    /// Client side of `MPW_CreatePath`: connect `cfg.nstreams` streams to
    /// `host:port` (retrying until `cfg.connect_timeout`), then run the
    /// autotuner as master if `cfg.autotune` is set.
    pub fn connect(host: &str, port: u16, cfg: PathConfig) -> Result<Path> {
        cfg.validate()?;
        let (pairs, uuid) = connect_streams(host, port, cfg.nstreams, cfg.connect_timeout)?;
        let autotune = cfg.autotune;
        let path = Path::from_pairs(pairs, cfg)?;
        // The initial connect handshake has no reply, so the acceptor's
        // protocol version is unknown here; stay conservative until the
        // peer proves credit-awareness by sending a credit frame.
        path.set_peer_credit_aware(false);
        *path.remote.lock() = Some((format!("{host}:{port}"), uuid));
        *path.uuid.lock() = Some(uuid);
        if autotune {
            // Suspend runtime adaptation while the probe protocol runs:
            // the probes must measure each chunk candidate under identical
            // striping/pacing, and the controller must not learn from its
            // own probe traffic (it is seeded with the clean result).
            let mode = path.tune_mode();
            path.set_tune_mode(TuneMode::Static);
            super::autotune::tune_master(&path)?;
            path.set_tune_mode(mode);
        }
        Ok(path)
    }

    /// Number of parallel TCP streams in this path.
    pub fn nstreams(&self) -> usize {
        self.streams.len()
    }

    /// Peer description (diagnostics).
    pub fn peer(&self) -> &str {
        &self.peer
    }

    /// Snapshot of the current configuration, with the live tuning values
    /// (chunk size, pacing) overlaid so it reflects what the path is
    /// actually doing right now.
    pub fn config(&self) -> PathConfig {
        let mut cfg = self.cfg.lock().clone();
        cfg.chunk_size = self.tuning.chunk();
        cfg.pacing_rate = self.tuning.pacing();
        cfg
    }

    /// The path's live tuning knobs (shared with the adaptive controller).
    pub fn tuning(&self) -> &TuningState {
        &self.tuning
    }

    /// `MPW_setTuneMode`: switch between creation-time-only tuning and
    /// online adaptation at runtime.
    pub fn set_tune_mode(&self, mode: TuneMode) {
        self.tuning.set_mode(mode);
    }

    /// `MPW_TuneMode`: the current tuning mode.
    pub fn tune_mode(&self) -> TuneMode {
        self.tuning.mode()
    }

    /// `MPW_TuneState`: snapshot of the live tuning state, including the
    /// controller's smoothed goodput estimate.
    pub fn tune_snapshot(&self) -> TuneSnapshot {
        let mut s = self.tuning.snapshot();
        s.ewma_rate = self.controller.lock().ewma_rate();
        s
    }

    /// Seed the runtime controller's rate baseline (called by the
    /// creation-time autotuner so the collapse detector is armed from the
    /// first send).
    pub(crate) fn note_tuned_rate(&self, rate: f64) {
        self.controller.lock().seed_rate(rate);
    }

    /// `MPW_setChunkSize`: bytes handed to each low-level tcp call.
    pub fn set_chunk_size(&self, chunk: usize) -> Result<()> {
        if chunk == 0 {
            return Err(MpwError::Config("chunk_size must be >= 1".into()));
        }
        self.cfg.lock().chunk_size = chunk;
        self.tuning.set_chunk(chunk);
        Ok(())
    }

    /// `MPW_setPacingRate`: per-stream software pacing in bytes/second
    /// (`None` disables pacing).
    pub fn set_pacing_rate(&self, rate: Option<f64>) -> Result<()> {
        if let Some(r) = rate {
            if !(r > 0.0) {
                return Err(MpwError::Config(format!("pacing rate must be positive, got {r}")));
            }
        }
        self.cfg.lock().pacing_rate = rate;
        self.tuning.set_pacing(rate);
        for s in &self.streams {
            s.tx.lock().pacer.set_rate(rate);
        }
        Ok(())
    }

    /// `MPW_setWin`: request a TCP window on every stream; the kernel may
    /// clamp it to site limits. Returns the granted value of the last
    /// stream (None for non-socket transports).
    pub fn set_window(&self, bytes: usize) -> Result<Option<usize>> {
        self.cfg.lock().tcp_window = Some(bytes);
        let mut granted = None;
        for s in &self.streams {
            let fd = s.meta.lock().fd;
            if let Some(fd) = fd {
                granted = super::transport::set_socket_window(fd, bytes)?;
            }
        }
        Ok(granted)
    }

    /// `MPW_setAutoTuning`.
    pub fn set_autotuning(&self, on: bool) {
        self.cfg.lock().autotune = on;
    }

    /// `MPW_Send`: send `buf`, split evenly over the streams. The receiver
    /// must post a `recv` of exactly the same size. Returns bytes sent.
    pub fn send(&self, buf: &[u8]) -> Result<usize> {
        let _gate = self.send_gate.lock();
        self.send_ungated(buf)
    }

    /// Send without taking the send gate (callers that already hold it:
    /// the dynamic-message layer).
    pub(crate) fn send_ungated(&self, buf: &[u8]) -> Result<usize> {
        self.send_split_ungated(SplitBuf::plain(buf))
    }

    /// `MPW_Send` of a two-part logical message (`head ++ tail`) without
    /// concatenating the parts: segments and chunks are resolved through
    /// [`SplitBuf::slice`] and written with one vectored call each. This
    /// is the mux layer's hot path (channel-frame header + payload).
    pub fn send_split(&self, head: &[u8], tail: &[u8]) -> Result<usize> {
        let _gate = self.send_gate.lock();
        self.send_split_ungated(SplitBuf { head, tail })
    }

    /// [`Path::send_split`] without taking the send gate.
    pub(crate) fn send_split_ungated(&self, buf: SplitBuf<'_>) -> Result<usize> {
        if self.resilient {
            return resilience::send(self, buf);
        }
        let t0 = Instant::now();
        let chunk = self.tuning.chunk();
        let active = self.tuning.active_streams().clamp(1, self.streams.len());
        // flush only when no payload follows on stream 0 (empty message);
        // otherwise stream 0's worker flushes and carries the header along
        self.write_active_header(active, buf.is_empty())?;
        if active == 1 {
            Self::send_worker(&self.streams[0], buf, chunk)?;
        } else {
            // §Perf: stream workers run on the persistent task pool — one
            // OS thread spawn per stream per send was the dominant cost
            // for small multi-stream messages (EXPERIMENTS.md §Perf 1).
            let segs = stripe::segments(buf.len(), active);
            let mut results: Vec<Result<()>> = Vec::new();
            results.resize_with(active, || Ok(()));
            {
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(active);
                for ((slot, seg), out) in
                    self.streams[..active].iter().zip(segs).zip(results.iter_mut())
                {
                    if seg.is_empty() {
                        continue;
                    }
                    let (h, t) = buf.slice(seg);
                    let data = SplitBuf { head: h, tail: t };
                    jobs.push(Box::new(move || *out = Self::send_worker(slot, data, chunk)));
                }
                crate::util::pool::scope(jobs);
            }
            results.into_iter().collect::<Result<Vec<_>>>()?;
        }
        self.observe_send(buf.len(), t0.elapsed());
        Ok(buf.len())
    }

    /// Feed the adaptive controller with this send's goodput and apply
    /// whatever it decides (no-op in static mode).
    pub(crate) fn observe_send(&self, bytes: usize, elapsed: Duration) {
        if self.tuning.mode() != TuneMode::Adaptive {
            return;
        }
        let decision = {
            let snapshot = self.tuning.snapshot();
            let mut c = self.controller.lock();
            c.observe(bytes, elapsed.as_secs_f64(), &snapshot)
        };
        if decision.is_hold() {
            return;
        }
        self.tuning.apply(&decision);
        if let Some(rate) = decision.pacing {
            // pacers are per-stream state behind the tx locks; the send
            // workers are done by now, so these are uncontended
            for s in &self.streams {
                s.tx.lock().pacer.set_rate(rate);
            }
        }
    }

    /// Write the 2-byte active-stream header on stream 0 (always the
    /// first bytes of a message, ahead of any striped payload).
    fn write_active_header(&self, active: usize, flush: bool) -> Result<()> {
        let mut tx = self.streams[0].tx.lock();
        tx.w.write_all(&(active as u16).to_be_bytes())?;
        if flush {
            tx.w.flush()?;
        }
        Ok(())
    }

    /// Read the peer's active-stream header from stream 0.
    fn read_active_header(&self) -> Result<usize> {
        let mut hdr = [0u8; ACTIVE_HEADER_LEN];
        self.streams[0].rx.lock().read_exact(&mut hdr)?;
        let n = u16::from_be_bytes(hdr) as usize;
        if n == 0 || n > self.streams.len() {
            return Err(MpwError::Protocol(format!(
                "peer advertised {n} active streams on a {}-stream path",
                self.streams.len()
            )));
        }
        Ok(n)
    }

    /// `MPW_Recv`: receive exactly `buf.len()` bytes, merging the incoming
    /// per-stream segments. Returns bytes received.
    pub fn recv(&self, buf: &mut [u8]) -> Result<usize> {
        let _gate = self.recv_gate.lock();
        self.recv_ungated(buf)
    }

    /// Receive without taking the recv gate (dynamic-message layer).
    pub(crate) fn recv_ungated(&self, buf: &mut [u8]) -> Result<usize> {
        if self.resilient {
            return resilience::recv(self, resilience::RecvTarget::Fixed(buf));
        }
        let chunk = self.tuning.chunk();
        // The sender's header tells us how many streams this message was
        // striped over — restriping needs no negotiation round-trip.
        let active = self.read_active_header()?;
        let len = buf.len();
        if active == 1 {
            Self::recv_worker(&self.streams[0], buf, chunk)?;
            return Ok(len);
        }
        // Split the buffer into disjoint &mut segments for the workers.
        let parts: Vec<(usize, &mut [u8])> = stripe::split_mut(buf, active)
            .into_iter()
            .enumerate()
            .filter(|(_, head)| !head.is_empty())
            .collect();
        let mut results: Vec<Result<()>> = Vec::new();
        results.resize_with(parts.len(), || Ok(()));
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(parts.len());
            for ((i, part), out) in parts.into_iter().zip(results.iter_mut()) {
                let slot = &self.streams[i];
                jobs.push(Box::new(move || *out = Self::recv_worker(slot, part, chunk)));
            }
            crate::util::pool::scope(jobs);
        }
        results.into_iter().collect::<Result<Vec<_>>>()?;
        Ok(len)
    }

    /// `MPW_SendRecv`: full-duplex exchange — send `sbuf` while receiving
    /// `rbuf.len()` bytes, concurrently over all streams.
    pub fn send_recv(&self, sbuf: &[u8], rbuf: &mut [u8]) -> Result<()> {
        let mut tx_res: Result<()> = Ok(());
        let mut rx_res: Result<()> = Ok(());
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| tx_res = self.send(sbuf).map(|_| ())),
                Box::new(|| rx_res = self.recv(rbuf).map(|_| ())),
            ];
            crate::util::pool::scope(jobs);
        }
        tx_res?;
        rx_res
    }

    /// Drain the resilient send window: block until every message the
    /// windowed sender has posted is acknowledged by the peer (see
    /// [`ResilienceConfig::window`](super::config::ResilienceConfig::window)),
    /// surfacing any deferred pipeline failure. A no-op on
    /// non-resilient paths and with the default `window == 1`.
    ///
    /// # Examples
    ///
    /// ```
    /// use mpwide::mpwide::{Path, PathConfig};
    /// # use mpwide::mpwide::transport::mem_path_pairs;
    /// let mut cfg = PathConfig::with_streams(2);
    /// cfg.autotune = false;
    /// cfg.resilience.enabled = true;
    /// cfg.resilience.window = 4; // pipeline up to 4 in-flight messages
    /// let (l, r) = mem_path_pairs(2);
    /// let a = Path::from_pairs(l, cfg.clone()).unwrap();
    /// let b = Path::from_pairs(r, cfg).unwrap();
    /// let t = std::thread::spawn(move || {
    ///     let mut buf = vec![0u8; 1000];
    ///     for _ in 0..3 {
    ///         b.recv(&mut buf).unwrap();
    ///     }
    /// });
    /// for _ in 0..3 {
    ///     a.send(&vec![7u8; 1000]).unwrap(); // posts without waiting
    /// }
    /// a.flush().unwrap(); // all three confirmed delivered
    /// t.join().unwrap();
    /// ```
    pub fn flush(&self) -> Result<()> {
        if !self.resilient {
            return Ok(());
        }
        let _gate = self.send_gate.lock();
        resilience::drain_window(self)
    }

    /// The sender's in-flight window limit (≥ 1; reads the live tunable
    /// so the adaptive controller can widen or narrow it mid-run).
    pub(crate) fn send_window_limit(&self) -> usize {
        self.tuning.window().max(1)
    }

    /// `MPW_Barrier`: synchronize the two ends — each side sends a token
    /// byte on stream 0 and waits for the peer's. In resilient mode the
    /// token exchange is a pair of resilient empty messages — so a
    /// barrier survives stream death like any other operation — followed
    /// by a window drain: when the barrier returns, everything this end
    /// sent before it is confirmed delivered, even with `window > 1`.
    pub fn barrier(&self) -> Result<()> {
        if self.resilient {
            let mut empty: [u8; 0] = [];
            self.send_recv(&[], &mut empty)?;
            let _gate = self.send_gate.lock();
            return resilience::drain_window(self);
        }
        const TOKEN: u8 = 0xB7;
        let slot = &self.streams[0];
        let mut tx_res: Result<()> = Ok(());
        let mut b = [0u8; 1];
        {
            let tx_job = || -> Result<()> {
                let _gate = self.send_gate.lock();
                let mut tx = slot.tx.lock();
                tx.w.write_all(&[TOKEN])?;
                tx.w.flush()?;
                Ok(())
            };
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| tx_res = tx_job())];
            // token receive runs inline; the pool handles the send half
            crate::util::pool::scope_with_inline(jobs, || -> Result<()> {
                let _gate = self.recv_gate.lock();
                slot.rx.lock().read_exact(&mut b)?;
                Ok(())
            })?;
        }
        tx_res?;
        if b[0] != TOKEN {
            return Err(MpwError::Protocol(format!("bad barrier token {:#x}", b[0])));
        }
        Ok(())
    }

    /// Round-trip time measured with a barrier exchange (used by the
    /// autotuner's window estimate and by diagnostics).
    pub fn measure_rtt(&self) -> Result<Duration> {
        let t0 = std::time::Instant::now();
        self.barrier()?;
        Ok(t0.elapsed())
    }

    /// Seed the in-flight send window from the measured
    /// bandwidth-delay product instead of the configured constant: the
    /// pipeline needs `BDP / message-size` messages in flight to keep a
    /// long fat link full, and the adaptive tuner's halve/double
    /// hill-climb takes many round trips to discover that from a coarse
    /// starting point. Measures RTT with a barrier exchange, takes the
    /// best goodput estimate available (controller EWMA when the
    /// adaptive mode has samples, otherwise the aggregate pacing rate),
    /// and widens/narrows both the live window and its tunable ceiling
    /// to `ceil(BDP / chunk)`, clamped to `[1,`
    /// [`resilience::MAX_WINDOW`]`]` and re-clamped under any credit
    /// the peer has advertised. With no goodput estimate (static mode,
    /// unpaced) the window is left untouched. Returns the effective
    /// window. Resilient paths only; call between exchanges — it runs a
    /// barrier.
    pub fn seed_window_from_bdp(&self) -> Result<usize> {
        if !self.resilient {
            return Err(MpwError::Config(
                "seed_window_from_bdp needs resilience.enabled (windowing lives there)".into(),
            ));
        }
        let rtt = self.measure_rtt()?;
        let snap = self.tune_snapshot();
        let rate = snap
            .ewma_rate
            .or_else(|| snap.pacing_rate.map(|r| r * snap.active_streams.max(1) as f64));
        let Some(rate) = rate else {
            return Ok(self.send_window_limit());
        };
        let bdp = rate.max(0.0) * rtt.as_secs_f64();
        let msgs = (bdp / snap.chunk_size.max(1) as f64).ceil() as usize;
        let w = msgs.clamp(1, resilience::MAX_WINDOW);
        self.tuning.init_window(w);
        self.tuning.set_window(w); // re-applies the peer-credit clamp
        Ok(self.tuning.window())
    }

    // -- stream health (resilience layer) -----------------------------------

    /// Whether resilient framing is active on this path.
    pub fn resilient(&self) -> bool {
        self.resilient
    }

    /// The configured ACK progress budget, if any (resilient mode).
    pub(crate) fn ack_timeout(&self) -> Option<Duration> {
        self.ack_timeout
    }

    /// Byte budget for the receive-side reorder stash, if configured.
    pub(crate) fn recv_stash_high_water(&self) -> Option<usize> {
        self.recv_stash_high_water
    }

    /// Whether the peer understands credit frames (extended ACKs and the
    /// WINDOW_UPDATE kind). Gates every credit emission: a legacy peer
    /// treats both as fatal protocol errors.
    pub(crate) fn peer_credit_aware(&self) -> bool {
        self.peer_credit_aware.load(Ordering::Relaxed)
    }

    /// Record that the peer just sent us a credit-bearing frame — only a
    /// version >= 1 build does that, so it is safe to reciprocate.
    pub(crate) fn note_peer_credit_aware(&self) {
        self.peer_credit_aware.store(true, Ordering::Relaxed);
    }

    /// Set credit-awareness from the handshake (socket constructors).
    pub(crate) fn set_peer_credit_aware(&self, aware: bool) {
        self.peer_credit_aware.store(aware, Ordering::Relaxed);
    }

    /// Fresh id for an outgoing credit advert. Strictly increasing, so
    /// the peer can keep the newest advert regardless of arrival order
    /// (an advert can travel in an ACK and in a WINDOW_UPDATE frame).
    pub(crate) fn next_credit_advert_id(&self) -> u64 {
        self.credit_advert.fetch_add(1, Ordering::Relaxed)
    }

    /// Whether stream `i` can currently carry traffic.
    pub fn stream_alive(&self, i: usize) -> bool {
        i < self.streams.len() && !self.streams[i].dead.load(Ordering::SeqCst)
    }

    /// Indices of all live streams, ascending.
    pub fn live_stream_indices(&self) -> Vec<usize> {
        (0..self.streams.len()).filter(|&i| self.stream_alive(i)).collect()
    }

    /// The next live stream after `c`, cyclically — THE control-stream
    /// rotation rule. Both ends apply it independently on observing the
    /// same death, so it must stay the single definition (the resilient
    /// framing's `ctrl_stream` and the eager rotation in
    /// `mark_stream_dead` both call it).
    pub(crate) fn next_live_after(&self, c: usize) -> Option<usize> {
        let n = self.streams.len();
        (1..=n).map(|d| (c + d) % n).find(|&j| self.stream_alive(j))
    }

    /// Current health generation (bumped only on rejoin; failure reports
    /// carry the generation they observed so a report about a
    /// since-replaced transport is dropped — but two simultaneous death
    /// reports both land).
    pub(crate) fn health_generation(&self) -> u64 {
        self.health.generation.load(Ordering::SeqCst)
    }

    /// Isolate stream `i`: mark it dead, force-close its transport (which
    /// propagates the failure to the peer), clamp the striping to the
    /// live count and cap the adaptive controller. `gen_seen` is the
    /// health generation the caller observed before the failing
    /// operation; a mismatch means a rejoin replaced transports
    /// underneath it and the (possibly stale) report is dropped.
    pub(crate) fn mark_stream_dead(&self, i: usize, gen_seen: u64) {
        if i >= self.streams.len() {
            return;
        }
        let _g = self.health.sync.lock();
        if self.health.generation.load(Ordering::SeqCst) != gen_seen {
            return;
        }
        let slot = &self.streams[i];
        if slot.dead.swap(true, Ordering::SeqCst) {
            return;
        }
        slot.meta.lock().kill.fire();
        // Eagerly rotate the control stream off the dead slot. Rotation
        // must happen at *death observation* (which both ends make,
        // because the kill propagates), not lazily at the next use: a
        // background rejoin could revive the slot in between, and a side
        // that never observed the death would stay on the old control
        // stream while the peer moved on.
        let c = self.cur_ctrl.load(Ordering::SeqCst);
        if c == i {
            if let Some(next) = self.next_live_after(c) {
                self.cur_ctrl.store(next, Ordering::SeqCst);
            }
        }
        let live = self.live_stream_indices().len().max(1);
        self.tuning.apply_live_limit(live);
        self.controller.lock().set_ceiling(live);
        self.health.cv.notify_all();
    }

    /// Chaos/testing hook (also used by the rejoin daemon to retire a
    /// stale socket): force stream `i` into the dead state as if its I/O
    /// had failed.
    pub fn inject_stream_failure(&self, i: usize) -> Result<()> {
        if i >= self.streams.len() {
            return Err(MpwError::Config(format!("stream index {i} out of range")));
        }
        let gen = self.health_generation();
        self.mark_stream_dead(i, gen);
        Ok(())
    }

    /// Install a fresh transport into dead stream `i` (the rejoin
    /// protocol's final step). Restores the stream to the live set,
    /// raises the controller ceiling and wakes any zero-live waiters.
    pub fn reinstall_stream(&self, i: usize, pair: StreamPair) -> Result<()> {
        if i >= self.streams.len() {
            return Err(MpwError::Config(format!("stream index {i} out of range")));
        }
        let _g = self.health.sync.lock();
        // checked under the health lock: a close() racing this install
        // must not be followed by a resurrecting reinstall
        if self.is_closed() {
            return Err(MpwError::Protocol("path is closed; refusing reinstall".into()));
        }
        let slot = &self.streams[i];
        if !slot.dead.load(Ordering::SeqCst) {
            return Err(MpwError::Protocol(format!("stream {i} is alive; refusing reinstall")));
        }
        // Socket options are applied at connect time (`connect_stream`);
        // a fresh fd needs the same treatment, and a failure is just as
        // fatal to the rejoin as it would have been to the connect.
        if let Some(win) = self.cfg.lock().tcp_window {
            pair.set_window(win)?;
        }
        // the write deadline is per-socket state: reapply to the fresh fd
        if let Some(t) = self.write_timeout {
            pair.set_send_timeout(Some(t))?;
        }
        let (tx, rx, fd, kill) = pair.into_parts();
        {
            // meta first: once the old tx/rx halves are dropped their fd
            // is closed (and may be reused by the OS), so the old
            // KillSwitch must already be unreachable by then — a
            // concurrent shutdown_all_streams may fire the *new* switch
            // (correct: it wants everything closed) but never a stale fd
            let mut m = slot.meta.lock();
            m.fd = fd;
            m.kill = kill;
        }
        {
            let mut txg = slot.tx.lock();
            txg.w = tx;
            txg.pacer.set_rate(self.tuning.pacing());
        }
        *slot.rx.lock() = rx;
        // frames parked off the dead transport must not replay on the new
        slot.inbox.clear();
        slot.dead.store(false, Ordering::SeqCst);
        let live = self.live_stream_indices().len();
        self.tuning.apply_live_limit(live);
        self.controller.lock().set_ceiling(live);
        self.health.rejoined.fetch_add(1, Ordering::SeqCst);
        self.health.generation.fetch_add(1, Ordering::SeqCst);
        self.health.cv.notify_all();
        Ok(())
    }

    /// Block until at least one stream is live. Errors immediately with
    /// `AllStreamsDead` when reconnection is disabled, or after the
    /// policy's `rejoin_wait` deadline otherwise.
    pub(crate) fn wait_for_any_live(&self) -> Result<()> {
        let policy = self.reconnect.lock().clone();
        if self.is_closed() || !policy.enabled {
            return Err(MpwError::AllStreamsDead);
        }
        let deadline = Instant::now() + policy.rejoin_wait;
        let mut g = self.health.sync.lock();
        loop {
            if self.is_closed() {
                return Err(MpwError::AllStreamsDead);
            }
            if self.streams.iter().any(|s| !s.dead.load(Ordering::SeqCst)) {
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(MpwError::AllStreamsDead);
            }
            let (g2, _) = self.health.cv.wait_timeout(g, deadline - now);
            g = g2;
        }
    }

    /// The path's reconnect policy (a snapshot).
    pub fn reconnect_policy(&self) -> ReconnectPolicy {
        self.reconnect.lock().clone()
    }

    /// Replace the reconnect policy at runtime (`MPW_setReconnectPolicy`
    /// facade). Validated with the same rules as at creation (a zero
    /// backoff floor or reconnect-without-framing must not sneak in
    /// through the runtime door). Takes effect on the monitor's next
    /// cycle and the next zero-live-stream wait.
    pub fn set_reconnect_policy(&self, policy: ReconnectPolicy) -> Result<()> {
        let probe = super::config::ResilienceConfig {
            enabled: self.resilient,
            reconnect: policy.clone(),
            ..Default::default()
        };
        probe.validate()?;
        *self.reconnect.lock() = policy;
        // wake the monitor so a newly-enabled policy acts promptly
        let _g = self.health.sync.lock();
        self.health.cv.notify_all();
        Ok(())
    }

    /// Remote endpoint (`host:port`, path uuid) — connecting side only.
    pub fn remote_endpoint(&self) -> Option<(String, u64)> {
        self.remote.lock().clone()
    }

    /// The path uuid agreed in the stream handshake, where known.
    pub fn path_uuid(&self) -> Option<u64> {
        *self.uuid.lock()
    }

    pub(crate) fn set_path_uuid(&self, uuid: u64) {
        *self.uuid.lock() = Some(uuid);
    }

    /// `MPW_PathStatus`: point-in-time health report.
    pub fn status(&self) -> PathStatus {
        let dead: Vec<usize> =
            (0..self.streams.len()).filter(|&i| !self.stream_alive(i)).collect();
        PathStatus {
            nstreams: self.streams.len(),
            live: self.streams.len() - dead.len(),
            dead,
            active_streams: self.tuning.active_streams(),
            preferred_active: self.tuning.preferred_active(),
            rejoined: self.health.rejoined.load(Ordering::SeqCst),
            ack_timeouts: self.ack_watchdog.fired(),
            window_in_flight: self.send_window.in_flight(),
            reorder_stash_bytes: self.recv_reorder.usage().1,
            resilient: self.resilient,
            reconnect_enabled: self.reconnect.lock().enabled,
        }
    }

    /// Permanently close the path: force-close every stream and set a
    /// sticky closed flag. Any worker parked in a blocking read or
    /// write — including the detached worker of a dropped non-blocking
    /// handle — fails promptly and exits. The flag gates
    /// [`Path::reinstall_stream`] and the zero-live wait, so neither the
    /// reconnect monitor nor a rejoin daemon can resurrect a closed
    /// path; drop it.
    pub fn close(&self) {
        {
            // flag set under the health lock: a racing reinstall either
            // completed before this (and its fresh transport is killed by
            // the shutdown below) or observes the flag and refuses
            let _g = self.health.sync.lock();
            self.closed.store(true, Ordering::SeqCst);
            self.health.cv.notify_all();
        }
        self.ack_watchdog.stop();
        self.shutdown_all_streams();
    }

    /// Whether [`Path::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Force-close every stream (relay teardown: unblocks pumps parked
    /// in reads on healthy streams when a sibling stream fails hard).
    pub(crate) fn shutdown_all_streams(&self) {
        for s in &self.streams {
            s.meta.lock().kill.fire();
        }
    }

    fn send_worker(slot: &StreamSlot, data: SplitBuf<'_>, chunk: usize) -> Result<()> {
        let mut tx = slot.tx.lock();
        for c in stripe::chunks(0..data.len(), chunk) {
            tx.pacer.acquire(c.len());
            let (h, t) = data.slice(c);
            tx.w.write_vectored_all(&[h, t])?;
        }
        tx.w.flush()?;
        Ok(())
    }

    fn recv_worker(slot: &StreamSlot, data: &mut [u8], chunk: usize) -> Result<()> {
        let mut rx = slot.rx.lock();
        for c in stripe::chunks(0..data.len(), chunk) {
            rx.read_exact(&mut data[c])?;
        }
        Ok(())
    }
}

impl Drop for Path {
    fn drop(&mut self) {
        // The ACK watchdog's timer thread holds no reference to the
        // path; tell it to exit (close() already did for closed paths).
        self.ack_watchdog.stop();
    }
}

/// Server side of `MPW_CreatePath`: listens for incoming stream bundles and
/// assembles them into [`Path`]s (multiple concurrent clients supported —
/// a forwarder accepts two paths from one listener).
pub struct PathListener {
    raw: RawPathListener,
    cfg: PathConfig,
    registry: Arc<RejoinRegistry>,
}

impl PathListener {
    /// Bind a listener on `port` (0 picks a free port) with the config
    /// applied to every accepted path.
    pub fn bind(port: u16, cfg: PathConfig) -> Result<PathListener> {
        Ok(PathListener {
            raw: RawPathListener::bind(&format!("0.0.0.0:{port}"))?,
            cfg,
            registry: Arc::new(RejoinRegistry::default()),
        })
    }

    /// The bound port.
    pub fn port(&self) -> u16 {
        self.raw.port()
    }

    /// Accept the next complete path; runs the autotuner as slave if
    /// configured (must match the connecting side's setting).
    pub fn accept_path(&mut self) -> Result<Path> {
        let (pairs, uuid, version) = self.raw.accept_streams()?;
        let autotune = self.cfg.autotune;
        let path = Path::from_pairs(pairs, self.cfg.clone())?;
        path.set_path_uuid(uuid);
        path.set_peer_credit_aware(version >= HELLO_VERSION);
        if autotune {
            // see Path::connect: no runtime adaptation during the probes
            let mode = path.tune_mode();
            path.set_tune_mode(TuneMode::Static);
            super::autotune::tune_slave(&path)?;
            path.set_tune_mode(mode);
        }
        Ok(path)
    }

    /// Like [`PathListener::accept_path`] but returns the path shared and
    /// registered for stream rejoin: once the listener is turned into a
    /// [`RejoinDaemon`], reconnecting streams bearing this path's uuid
    /// are routed back into it.
    pub fn accept_path_arc(&mut self) -> Result<Arc<Path>> {
        let (pairs, uuid, version) = self.raw.accept_streams()?;
        let autotune = self.cfg.autotune;
        let path = Path::from_pairs(pairs, self.cfg.clone())?;
        path.set_path_uuid(uuid);
        path.set_peer_credit_aware(version >= HELLO_VERSION);
        let path = Arc::new(path);
        if autotune {
            let mode = path.tune_mode();
            path.set_tune_mode(TuneMode::Static);
            super::autotune::tune_slave(&path)?;
            path.set_tune_mode(mode);
        }
        self.registry.register(uuid, &path);
        Ok(path)
    }

    /// The rejoin registry shared with daemons created from this listener.
    pub fn registry(&self) -> Arc<RejoinRegistry> {
        self.registry.clone()
    }

    /// Convert the listener into a background [`RejoinDaemon`] serving
    /// stream rejoins for every path accepted via
    /// [`PathListener::accept_path_arc`]. Call once all expected paths
    /// have been accepted. Fails only when the OS refuses to spawn the
    /// daemon thread.
    pub fn into_rejoin_daemon(self) -> Result<RejoinDaemon> {
        RejoinDaemon::spawn(self.raw, self.registry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::transport::mem_path_pairs;
    use crate::util::Rng;

    fn mem_paths(n: usize) -> (Path, Path) {
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        cfg.chunk_size = 4096;
        let a = Path::from_pairs(l, cfg.clone()).unwrap();
        let b = Path::from_pairs(r, cfg).unwrap();
        (a, b)
    }

    #[test]
    fn send_recv_roundtrip_multi_stream() {
        let (a, b) = mem_paths(4);
        let mut msg = vec![0u8; 100_000];
        Rng::new(1).fill_bytes(&mut msg);
        let msg2 = msg.clone();
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 100_000];
            b.recv(&mut buf).unwrap();
            assert_eq!(buf, msg2);
        });
        assert_eq!(a.send(&msg).unwrap(), 100_000);
        t.join().unwrap();
    }

    #[test]
    fn send_recv_empty_message() {
        let (a, b) = mem_paths(3);
        a.send(&[]).unwrap();
        let mut buf = [];
        b.recv(&mut buf).unwrap();
    }

    #[test]
    fn message_smaller_than_stream_count() {
        let (a, b) = mem_paths(8);
        let msg = [1u8, 2, 3];
        let t = std::thread::spawn(move || {
            let mut buf = [0u8; 3];
            b.recv(&mut buf).unwrap();
            buf
        });
        a.send(&msg).unwrap();
        assert_eq!(t.join().unwrap(), msg);
    }

    #[test]
    fn full_duplex_send_recv() {
        let (a, b) = mem_paths(2);
        let ma = vec![7u8; 50_000];
        let mb = vec![9u8; 30_000];
        let ma2 = ma.clone();
        let mb2 = mb.clone();
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 50_000];
            b.send_recv(&mb2, &mut buf).unwrap();
            assert_eq!(buf, ma2);
        });
        let mut buf = vec![0u8; 30_000];
        a.send_recv(&ma, &mut buf).unwrap();
        assert_eq!(buf, mb);
        t.join().unwrap();
    }

    #[test]
    fn barrier_synchronizes() {
        let (a, b) = mem_paths(2);
        let t = std::thread::spawn(move || b.barrier().unwrap());
        a.barrier().unwrap();
        t.join().unwrap();
    }

    #[test]
    fn chunk_size_smaller_than_message() {
        let (a, b) = mem_paths(2);
        a.set_chunk_size(7).unwrap();
        b.set_chunk_size(7).unwrap();
        let mut msg = vec![0u8; 1001];
        Rng::new(2).fill_bytes(&mut msg);
        let msg2 = msg.clone();
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 1001];
            b.recv(&mut buf).unwrap();
            buf
        });
        a.send(&msg).unwrap();
        assert_eq!(t.join().unwrap(), msg2);
    }

    #[test]
    fn set_chunk_zero_rejected() {
        let (a, _b) = mem_paths(1);
        assert!(a.set_chunk_size(0).is_err());
    }

    #[test]
    fn set_pacing_negative_rejected() {
        let (a, _b) = mem_paths(1);
        assert!(a.set_pacing_rate(Some(-5.0)).is_err());
        assert!(a.set_pacing_rate(Some(1e6)).is_ok());
        assert!(a.set_pacing_rate(None).is_ok());
    }

    #[test]
    fn from_pairs_rejects_empty() {
        assert!(Path::from_pairs(vec![], PathConfig::default()).is_err());
    }

    #[test]
    fn tcp_path_end_to_end() {
        let mut cfg = PathConfig::with_streams(4);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = Path::connect("127.0.0.1", port, cfg).unwrap();
            let mut msg = vec![0u8; 256 * 1024];
            Rng::new(3).fill_bytes(&mut msg);
            p.send(&msg).unwrap();
            p.barrier().unwrap();
            msg
        });
        let server = listener.accept_path().unwrap();
        let mut buf = vec![0u8; 256 * 1024];
        server.recv(&mut buf).unwrap();
        server.barrier().unwrap();
        let sent = t.join().unwrap();
        assert_eq!(buf, sent);
    }

    #[test]
    fn restriped_send_follows_header() {
        // Sender stripes over 3 of 8 established streams; the receiver
        // learns the count from the per-message header — no negotiation.
        let (a, b) = mem_paths(8);
        a.tuning().set_active(3);
        let mut msg = vec![0u8; 50_000];
        Rng::new(7).fill_bytes(&mut msg);
        let m2 = msg.clone();
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 50_000];
            b.recv(&mut buf).unwrap();
            buf
        });
        a.send(&msg).unwrap();
        assert_eq!(t.join().unwrap(), m2);
    }

    #[test]
    fn restripe_can_change_between_messages() {
        let (a, b) = mem_paths(4);
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 30_000];
            for _ in 0..3 {
                b.recv(&mut buf).unwrap();
            }
            buf
        });
        let msg = vec![9u8; 30_000];
        for active in [4usize, 1, 2] {
            a.tuning().set_active(active);
            a.send(&msg).unwrap();
        }
        assert_eq!(t.join().unwrap(), msg);
    }

    #[test]
    fn adaptive_mode_roundtrips_and_reports_state() {
        let (l, r) = mem_path_pairs(4);
        let mut cfg = PathConfig::with_streams(4);
        cfg.autotune = false;
        cfg.adapt.mode = TuneMode::Adaptive;
        let a = Path::from_pairs(l, cfg.clone()).unwrap();
        let b = Path::from_pairs(r, cfg).unwrap();
        assert_eq!(a.tune_mode(), TuneMode::Adaptive);
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 1 << 20];
            for _ in 0..8 {
                b.recv(&mut buf).unwrap();
            }
        });
        let msg = vec![5u8; 1 << 20];
        for _ in 0..8 {
            a.send(&msg).unwrap();
        }
        let snap = a.tune_snapshot();
        assert!((1..=4).contains(&snap.active_streams), "{snap:?}");
        assert!(snap.ewma_rate.is_some(), "controller saw no samples");
        t.join().unwrap();
    }

    #[test]
    fn tune_mode_switches_at_runtime() {
        let (a, _b) = mem_paths(2);
        assert_eq!(a.tune_mode(), TuneMode::Static);
        a.set_tune_mode(TuneMode::Adaptive);
        assert_eq!(a.tune_mode(), TuneMode::Adaptive);
        a.set_tune_mode(TuneMode::Static);
        assert_eq!(a.tune_mode(), TuneMode::Static);
    }

    #[test]
    fn bogus_active_header_rejected() {
        let (a, b) = mem_paths(2);
        // forge a header advertising more streams than the path has
        {
            let mut tx = a.streams[0].tx.lock();
            tx.w.write_all(&9u16.to_be_bytes()).unwrap();
        }
        let mut buf = [0u8; 4];
        assert!(b.recv(&mut buf).is_err());
    }

    #[test]
    fn measure_rtt_loopback_is_small() {
        let mut cfg = PathConfig::with_streams(1);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = Path::connect("127.0.0.1", port, cfg).unwrap();
            for _ in 0..3 {
                p.barrier().unwrap();
            }
        });
        let server = listener.accept_path().unwrap();
        for _ in 0..3 {
            let rtt = server.measure_rtt().unwrap();
            assert!(rtt < Duration::from_secs(1));
        }
        t.join().unwrap();
    }
}
