//! Channel multiplexing over a shared path (`mpwide::mux`).
//!
//! The paper positions MPWide for client-server coupling and for running
//! several concurrent applications (DataGather next to a live solver
//! coupling) between the same two sites. Before this module, each of
//! those logical conversations needed its **own path** — its own TCP
//! stream bundle, its own autotune round, its own firewall holes — which
//! is exactly what the WAN setting penalizes. `mux` multiplexes many
//! logical **channels** over one shared striped path, so N couplings
//! reuse a single tuned, resilient WAN fat-pipe instead of opening N
//! paths.
//!
//! A [`MuxEndpoint`] wraps an established [`Path`] (both ends must wrap
//! theirs) and runs two background workers:
//!
//! * the **sender pump** drains per-channel outbound queues onto the
//!   path with a **deficit-round-robin scheduler**: each rotation turn a
//!   channel accrues a byte allowance of
//!   `weight × chunk_budget` ([`ChannelOptions::weight`] ×
//!   [`MuxConfig::chunk_budget`]) and sends budget-sized frames until
//!   the allowance runs out, so a weight-4 bulk channel gets ~4× the
//!   bytes per rotation of a weight-1 channel while neither can starve
//!   a latency-sensitive coupling; an optional per-channel token-bucket
//!   [`ChannelOptions::rate`] cap pins one channel below the path rate
//!   without slowing its siblings;
//! * the **dispatcher** reads frames off the path and routes them into
//!   per-channel inbound queues by channel id.
//!
//! Each frame is one path message whose payload is
//! `[channel header][payload chunk]`; the header travels in front of
//! the chunk via the path's scatter send
//! ([`Path::dsend_split`]) — striped, chunked and written with vectored
//! I/O, never copy-assembled. Under a resilient path the channel frames
//! ride *on top of* the resilience framing, so stream death, degraded
//! striping and rejoin remain invisible to channels.
//!
//! ### Guarantees
//!
//! * **Delivery**: a message accepted by [`Channel::send`] is delivered
//!   exactly once to the peer channel's [`Channel::recv`], or the
//!   endpoint reports a fatal path error to every channel.
//! * **Per-channel ordering**: messages on one channel arrive in send
//!   order (verified by per-message sequence numbers; a violation is a
//!   protocol error, not silent reordering). No ordering is promised
//!   *across* channels — that independence is the point.
//! * **Weighted fairness**: per rotation, every channel with queued
//!   data and a live turn sends up to `weight × chunk_budget` bytes
//!   (deficit round-robin: unspent allowance smaller than the next
//!   frame carries over to the channel's next turn, so long-run byte
//!   shares converge to the weight ratios even when frame sizes do not
//!   divide the quantum). A channel's wait for the wire is bounded by
//!   one rotation — `Σ other weights × chunk_budget` bytes and at most
//!   `Σ other weights × FRAME_COST_DIVISOR` frames — regardless of how
//!   much bulk data the other channels have queued. A channel gated by
//!   credit or by its own rate cap forfeits its turn without burning
//!   (or accruing) deficit; the rotation moves on.
//! * **Backpressure**: [`Channel::send`] blocks once the channel's
//!   queued-but-unsent bytes exceed [`MuxConfig::high_water`], so one
//!   producer cannot balloon the process.
//!
//! ### Limitations
//!
//! * A muxed path belongs to the mux: once wrapped, all traffic must go
//!   through channels (the dispatcher owns the path's receive side).
//! * By default inbound messages queue unboundedly on a channel nobody
//!   `recv`s — the dispatcher must never block on a slow consumer, or
//!   it would head-of-line-block every other channel. Set
//!   [`MuxConfig::recv_high_water`] to bound them instead: the
//!   dispatcher withholds credit ([`CH_WINDOW_UPDATE`] frames) past the
//!   mark, the *peer's* pump parks that one channel (others keep
//!   flowing) and the peer's producers feel its outbound high-water —
//!   backpressure end to end, no unbounded buffer anywhere.
//! * Both ends must agree on channel ids (like ports); opening is not
//!   negotiated. A frame for a never-opened id creates the channel
//!   state, so open order across the two ends is free. The flip side:
//!   state for an id the peer used but this side never opens is kept
//!   (drained, a few hundred bytes) after the peer's CLOSE, so that a
//!   late local `open` still observes the close instead of hanging;
//!   bound that retention for unbounded ephemeral-id workloads with
//!   the [`MuxConfig::tombstone_ttl`] lease. An id may be *reused*
//!   after a close, but only once
//!   **both** ends have closed and drained it — reopening while the
//!   peer's old state lingers looks like traffic on a closed channel
//!   (a protocol error); synchronize reuse at the application level,
//!   e.g. over a control channel.
//! * Fairness is byte-based, not deadline-based: a channel's latency is
//!   bounded by one full rotation of weighted quanta, which on a slow
//!   link can still be long — size `chunk_budget` (and the weights of
//!   bulk channels) for the link. Weights and rate caps are
//!   endpoint-local scheduler state: nothing about them travels on the
//!   wire, the two ends need not agree, and each end shapes only its
//!   own send direction.
//! * Over a **resilient** path every frame is a delivery-ACKed path
//!   message. With the default
//!   [`ResilienceConfig::window`](super::config::ResilienceConfig::window)
//!   of 1 the single pump runs stop-and-wait at `chunk_budget`
//!   granularity, bounding long-fat-pipe goodput near
//!   `chunk_budget / RTT`. Raise the window to pipeline: the pump then
//!   keeps up to `window` budget-sized frames in flight on the path's
//!   send window and drains the window whenever it goes idle, so
//!   goodput scales toward `window × chunk_budget / RTT`. Size
//!   `window × chunk_budget` toward the path's bandwidth-delay product
//!   for resilient WAN deployments (both knobs are per endpoint and do
//!   not need to match the peer).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::errors::{MpwError, Result};
use super::pacing::Pacer;
use super::path::Path;
use crate::util::lockorder::{rank, OrderedCondvar, OrderedMutex};

/// Sanity byte opening every channel frame.
pub const MUX_MAGIC: u8 = 0xC4;
/// Frame kinds: a non-final chunk of a channel message.
pub const CH_DATA: u8 = 1;
/// The final chunk of a channel message (a small message is a single
/// `CH_FIN` frame).
pub const CH_FIN: u8 = 2;
/// Channel opened by the peer (informational; state is auto-created on
/// first frame either way).
pub const CH_OPEN: u8 = 3;
/// Peer closed the channel; no further frames for this id will follow.
pub const CH_CLOSE: u8 = 4;
/// Receiver-driven credit for one channel: the `msg_seq` field carries a
/// cumulative byte grant — the total payload bytes the sender may have
/// handed to the wire on this channel. A sender whose peer advertises
/// credit starts a new message only while its cumulative sent bytes are
/// below the newest grant; the receiver raises the grant as its
/// application drains the inbound queue. Zero payload.
pub const CH_WINDOW_UPDATE: u8 = 5;
/// Channel frame header size: magic + kind + channel + msg_seq + len.
pub const MUX_HDR_LEN: usize = 1 + 1 + 4 + 8 + 4;
/// Upper bound on a single channel frame payload (a corrupted header
/// must not trigger an absurd allocation).
pub const MAX_MUX_PAYLOAD: usize = 64 << 20;
/// Upper bound on [`ChannelOptions::weight`]. Weights are endpoint-local
/// scheduler state — nothing about them travels on the wire — so this
/// bound exists only to keep `weight × chunk_budget` quanta sane.
pub const MAX_WEIGHT: u32 = 1024;
/// Minimum deficit one frame burns, expressed as a divisor of
/// [`MuxConfig::chunk_budget`]: every frame costs at least
/// `chunk_budget / FRAME_COST_DIVISOR` allowance even when its payload
/// is smaller. Without this floor a torrent of tiny messages would turn
/// a byte quantum into an unbounded number of wire frames per turn
/// (each frame has real per-frame wire cost); with it one turn is at
/// most `weight × FRAME_COST_DIVISOR` frames.
pub const FRAME_COST_DIVISOR: usize = 16;

/// Per-channel scheduling options for [`MuxEndpoint::open_opts`].
///
/// Both knobs shape only this endpoint's **send** direction and are
/// invisible on the wire; the peer sets its own.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelOptions {
    /// Deficit-round-robin weight, `1..=MAX_WEIGHT`: the channel's byte
    /// allowance per rotation turn is `weight × chunk_budget`, so a
    /// weight-4 channel gets ~4× the bytes per rotation of a weight-1
    /// channel. Changeable live via [`Channel::set_weight`].
    pub weight: u32,
    /// Optional token-bucket rate cap in bytes/second (burst allowance
    /// `max(1% of rate, 64 KiB)`, as for path pacing): the pump skips
    /// the channel's turn — without burning its deficit — while the
    /// bucket is empty, pinning the channel below the path rate while
    /// siblings use the headroom. `None` (the default) means unlimited.
    /// Changeable live via [`Channel::set_rate`]. Control frames
    /// (OPEN/CLOSE/credit) are never rate-gated.
    pub rate: Option<f64>,
}

impl Default for ChannelOptions {
    fn default() -> Self {
        ChannelOptions { weight: 1, rate: None }
    }
}

impl ChannelOptions {
    /// Validate the knobs.
    pub fn validate(&self) -> Result<()> {
        if self.weight == 0 {
            return Err(MpwError::Config("channel weight must be >= 1".into()));
        }
        if self.weight > MAX_WEIGHT {
            return Err(MpwError::Config(format!(
                "channel weight {} exceeds MAX_WEIGHT {MAX_WEIGHT}",
                self.weight
            )));
        }
        if let Some(r) = self.rate {
            if !r.is_finite() || r <= 0.0 {
                return Err(MpwError::Config(format!(
                    "channel rate cap must be finite and positive (got {r})"
                )));
            }
        }
        Ok(())
    }
}

/// Decoded channel frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MuxHdr {
    /// Frame kind (`CH_*`).
    pub kind: u8,
    /// Channel id the frame belongs to.
    pub channel: u32,
    /// Per-channel message sequence number (same for every chunk of one
    /// message; the ordering check on delivery).
    pub msg_seq: u64,
    /// Payload length in bytes.
    pub len: u32,
}

/// Encode a channel frame header.
pub fn encode_mux_hdr(kind: u8, channel: u32, msg_seq: u64, len: u32) -> [u8; MUX_HDR_LEN] {
    let mut h = [0u8; MUX_HDR_LEN];
    h[0] = MUX_MAGIC;
    h[1] = kind;
    h[2..6].copy_from_slice(&channel.to_be_bytes());
    h[6..14].copy_from_slice(&msg_seq.to_be_bytes());
    h[14..18].copy_from_slice(&len.to_be_bytes());
    h
}

/// Decode and validate a channel frame header.
pub fn decode_mux_hdr(h: &[u8; MUX_HDR_LEN]) -> Result<MuxHdr> {
    if h[0] != MUX_MAGIC {
        return Err(MpwError::Protocol(format!("bad channel frame magic {:#04x}", h[0])));
    }
    let kind = h[1];
    if !(CH_DATA..=CH_WINDOW_UPDATE).contains(&kind) {
        return Err(MpwError::Protocol(format!("bad channel frame kind {kind}")));
    }
    let channel = u32::from_be_bytes(h[2..6].try_into().unwrap());
    let msg_seq = u64::from_be_bytes(h[6..14].try_into().unwrap());
    let len = u32::from_be_bytes(h[14..18].try_into().unwrap());
    if len as usize > MAX_MUX_PAYLOAD {
        return Err(MpwError::Protocol(format!("channel frame payload {len} exceeds bound")));
    }
    if (kind == CH_OPEN || kind == CH_CLOSE || kind == CH_WINDOW_UPDATE) && len != 0 {
        return Err(MpwError::Protocol(format!(
            "control channel frame (kind {kind}) carries {len} payload bytes"
        )));
    }
    Ok(MuxHdr { kind, channel, msg_seq, len })
}

/// Mux tuning knobs.
#[derive(Debug, Clone)]
pub struct MuxConfig {
    /// Largest payload of one channel frame, and the unit of the DRR
    /// fairness quantum: a channel's byte allowance per rotation turn is
    /// its [`ChannelOptions::weight`] × `chunk_budget`. Bigger values
    /// amortize per-frame overhead; smaller values tighten the latency
    /// bound for small messages sharing the path with bulk transfers.
    pub chunk_budget: usize,
    /// Per-channel cap on queued-but-unsent bytes; [`Channel::send`]
    /// blocks above it (a single oversized message is always accepted
    /// once the queue is empty).
    pub high_water: usize,
    /// Lease on *tombstone* state: per-id state the peer created and
    /// closed but this side never opened, retained so that a late local
    /// [`MuxEndpoint::open`] still observes the close (see the module
    /// docs). `None` (the default) retains such state for the
    /// endpoint's lifetime; `Some(ttl)` drops it once it has sat closed
    /// **and drained** for `ttl`, after which a late `open` treats the
    /// id as never used (its `recv` would block like any fresh
    /// channel's). Size the lease well above the application's
    /// worst-case open skew.
    pub tombstone_ttl: Option<Duration>,
    /// Per-channel bound on *inbound* queued-but-not-`recv`ed bytes.
    /// `None` (the default) keeps the historical behaviour: a channel
    /// nobody `recv`s grows without bound. `Some(hw)` turns on
    /// receiver-driven credit: the dispatcher advertises a cumulative
    /// byte grant per channel ([`CH_WINDOW_UPDATE`] frames), `recv`
    /// replenishes it, and the *peer's* pump stops starting new
    /// messages on a channel whose grant is exhausted — the peer's
    /// producers then park on its own [`MuxConfig::high_water`], so the
    /// backpressure reaches the remote application instead of this
    /// process's memory. A stalled reader holds at most `hw` plus one
    /// message; other channels keep flowing. Both knobs are per
    /// endpoint and need not match the peer; a legacy peer simply never
    /// advertises, and this end then applies no send-side gating.
    pub recv_high_water: Option<usize>,
}

impl Default for MuxConfig {
    fn default() -> Self {
        MuxConfig {
            chunk_budget: 256 * 1024,
            high_water: 16 << 20,
            tombstone_ttl: None,
            recv_high_water: None,
        }
    }
}

impl MuxConfig {
    /// Validate the knobs.
    pub fn validate(&self) -> Result<()> {
        if self.chunk_budget == 0 {
            return Err(MpwError::Config("mux chunk_budget must be >= 1".into()));
        }
        if self.chunk_budget > MAX_MUX_PAYLOAD {
            return Err(MpwError::Config(format!(
                "mux chunk_budget {} exceeds the {MAX_MUX_PAYLOAD}-byte frame bound",
                self.chunk_budget
            )));
        }
        if self.high_water == 0 {
            return Err(MpwError::Config("mux high_water must be >= 1".into()));
        }
        if self.tombstone_ttl.is_some_and(|ttl| ttl.is_zero()) {
            return Err(MpwError::Config("mux tombstone_ttl must be positive".into()));
        }
        if self.recv_high_water == Some(0) {
            // a zero grant would park every sending peer forever;
            // "unbounded" is spelled None, not 0
            return Err(MpwError::Config(
                "mux recv_high_water must be positive (use None to disable)".into(),
            ));
        }
        Ok(())
    }
}

/// One queued outbound message (owned while queued; chunks are sliced
/// out of it zero-copy by the pump).
struct OutMsg {
    data: Vec<u8>,
    off: usize,
    seq: u64,
}

/// Per-channel state, both directions.
#[derive(Default)]
struct ChanState {
    /// Incarnation counter (endpoint-local): a reused channel id gets a
    /// fresh generation, so stale [`Channel`] handles from the previous
    /// incarnation report `ChannelClosed` instead of silently aliasing
    /// the new conversation.
    gen: u64,
    /// The local application opened this channel (vs. auto-created from
    /// an inbound frame).
    locally_opened: bool,
    open_sent: bool,
    local_closed: bool,
    close_sent: bool,
    remote_closed: bool,
    /// A chunk of this channel's head message is being written to the
    /// path right now (outside the state lock); gates CLOSE and gc.
    in_flight: bool,
    /// When this state became a tombstone — closed by the peer while
    /// never locally opened. Starts the [`MuxConfig::tombstone_ttl`]
    /// lease; cleared if a local `open` adopts the state after all.
    tombstone_since: Option<Instant>,
    // inbound
    partial: Vec<u8>,
    ready: VecDeque<Vec<u8>>,
    next_recv_seq: u64,
    /// Payload bytes sitting in `ready` (complete messages only —
    /// `partial` is excluded so a message larger than the receive
    /// high-water cannot wedge the credit accounting mid-reassembly).
    ready_bytes: usize,
    /// Cumulative payload bytes of completed inbound messages (the
    /// basis of the byte grants this end advertises).
    recvd_bytes: u64,
    /// Newest cumulative grant advertised to the peer (monotone; only
    /// raised — a retransmitted or reordered grant must never shrink
    /// the peer's budget).
    last_grant: u64,
    // outbound
    outq: VecDeque<OutMsg>,
    out_bytes: usize,
    next_send_seq: u64,
    // deficit-round-robin scheduling (see pick_job)
    /// DRR weight ([`ChannelOptions::weight`]); quantum per rotation
    /// turn is `weight × chunk_budget`. `ensure_chan` initializes it to
    /// 1 (the struct-Default 0 is never observed by the scheduler,
    /// which clamps with `max(1)` anyway).
    weight: u32,
    /// Unspent byte allowance carried between rotation turns, bounded
    /// by two quanta.
    deficit: u64,
    /// The channel is mid-turn: it holds the pump's attention until its
    /// deficit runs out, its queue drains, or a gate ends the turn.
    turn_active: bool,
    /// Optional token-bucket rate cap ([`ChannelOptions::rate`]). Only
    /// ever probed with the non-blocking [`Pacer::try_acquire`] — the
    /// pump must never sleep while holding the state lock.
    pacer: Option<Pacer>,
    /// Newest cumulative byte grant the peer advertised for this
    /// channel; compared against `sent_bytes` when credit gating is on.
    peer_grant: u64,
    /// FIFO tickets for senders parked on the high-water mark: a parked
    /// sender enqueues only when its ticket reaches `park_head`, and the
    /// fast paths stand down while anyone is parked — otherwise a later
    /// send could overtake a blocked one and break per-channel ordering.
    park_head: u64,
    park_tail: u64,
    // stats
    delivered_bytes: u64,
    sent_bytes: u64,
    last_delivery_ticket: u64,
}

/// Point-in-time statistics of one channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChannelStats {
    /// Channel id.
    pub id: u32,
    /// Payload bytes of fully delivered inbound messages.
    pub delivered_bytes: u64,
    /// Payload bytes handed to the wire so far.
    pub sent_bytes: u64,
    /// Outbound bytes queued but not yet sent.
    pub queued_bytes: usize,
    /// Inbound messages delivered but not yet `recv`ed.
    pub ready_msgs: usize,
    /// Global delivery ticket of this channel's most recent completed
    /// inbound message (endpoint-wide monotonic counter; lets tests and
    /// diagnostics compare delivery *order* across channels).
    pub last_delivery_ticket: u64,
    /// Inbound bytes queued for `recv` (complete messages plus any
    /// partially reassembled one) — the quantity
    /// [`MuxConfig::recv_high_water`] bounds.
    pub inbound_queued_bytes: usize,
    /// Newest cumulative byte grant the peer advertised for this
    /// channel (0 until a credit-aware peer's first WINDOW_UPDATE).
    pub peer_grant: u64,
    /// The channel's deficit-round-robin weight
    /// ([`ChannelOptions::weight`]).
    pub weight: u32,
    /// Unspent DRR byte allowance carried into the channel's next
    /// rotation turn.
    pub deficit: u64,
}

struct MuxState {
    chans: HashMap<u32, ChanState>,
    /// Channel ids in open order — the round-robin rotation order.
    order: Vec<u32>,
    /// Next rotation position.
    cursor: usize,
    /// Endpoint-wide counter of completed inbound messages.
    delivery_ticket: u64,
    /// Generation source for [`ChanState::gen`].
    next_gen: u64,
    /// Fatal path/protocol error, reported to every channel operation.
    dead: Option<String>,
    shutdown: bool,
    /// The peer has sent at least one WINDOW_UPDATE, proving it runs a
    /// credit-aware build with a receive high-water configured. Only
    /// then does the pump gate sends on per-channel grants — gating
    /// against a peer that never advertises would park every channel
    /// forever.
    peer_credit: bool,
}

struct MuxInner {
    path: Arc<Path>,
    cfg: MuxConfig,
    st: OrderedMutex<MuxState>,
    /// Wakes the sender pump (new outbound work, close, shutdown).
    send_cv: OrderedCondvar,
    /// Wakes producers blocked on the high-water mark.
    space_cv: OrderedCondvar,
    /// Wakes consumers blocked in `recv`.
    recv_cv: OrderedCondvar,
}

/// What the pump sends next (selected under the state lock, sent
/// outside it).
enum PumpJob {
    Open(u32),
    Close(u32),
    Chunk { id: u32, msg: OutMsg, end: usize, fin: bool },
    /// Advertise a cumulative inbound byte grant for a channel.
    Credit { id: u32, grant: u64 },
}

/// One end of a multiplexed path. See the module docs for the model.
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use mpwide::mpwide::{MuxEndpoint, Path, PathConfig};
/// # use mpwide::mpwide::transport::mem_path_pairs;
/// let mut cfg = PathConfig::with_streams(2);
/// cfg.autotune = false;
/// let (l, r) = mem_path_pairs(2);
/// let a = MuxEndpoint::start(Arc::new(Path::from_pairs(l, cfg.clone()).unwrap())).unwrap();
/// let b = MuxEndpoint::start(Arc::new(Path::from_pairs(r, cfg).unwrap())).unwrap();
/// // both ends agree on channel ids, like ports
/// let (tx, rx) = (a.open(1).unwrap(), b.open(1).unwrap());
/// tx.send(b"solver boundary data").unwrap();
/// assert_eq!(rx.recv().unwrap(), b"solver boundary data");
/// ```
pub struct MuxEndpoint {
    inner: Arc<MuxInner>,
    pump: Option<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
}

impl MuxEndpoint {
    /// Wrap `path` with the default [`MuxConfig`]. The endpoint takes
    /// over the path: all further traffic must go through channels, and
    /// shutting the endpoint down closes the path. Fails only when the
    /// OS refuses to spawn the worker threads.
    pub fn start(path: Arc<Path>) -> Result<MuxEndpoint> {
        MuxEndpoint::start_cfg(path, MuxConfig::default())
    }

    /// Wrap `path` with explicit knobs.
    pub fn start_cfg(path: Arc<Path>, cfg: MuxConfig) -> Result<MuxEndpoint> {
        cfg.validate()?;
        let inner = Arc::new(MuxInner {
            path,
            cfg,
            st: OrderedMutex::new(
                rank::MUX_STATE,
                MuxState {
                    chans: HashMap::new(),
                    order: Vec::new(),
                    cursor: 0,
                    delivery_ticket: 0,
                    next_gen: 0,
                    dead: None,
                    shutdown: false,
                    peer_credit: false,
                },
            ),
            send_cv: OrderedCondvar::new(),
            space_cv: OrderedCondvar::new(),
            recv_cv: OrderedCondvar::new(),
        });
        let pump = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("mpwide-mux-pump".into())
                .spawn(move || pump_loop(&inner))?
        };
        let dispatcher = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("mpwide-mux-dispatch".into())
                .spawn(move || dispatch_loop(&inner))
        };
        let dispatcher = match dispatcher {
            Ok(d) => d,
            Err(e) => {
                // Unwind the half-started endpoint: stop the pump (and
                // release the path) before surfacing the spawn failure.
                inner.st.lock().shutdown = true;
                inner.send_cv.notify_all();
                inner.path.close();
                // swallow-ok: already unwinding a spawn failure; a pump
                // panic here cannot be acted on beyond the Err below.
                let _ = pump.join();
                return Err(e.into());
            }
        };
        Ok(MuxEndpoint { inner, pump: Some(pump), dispatcher: Some(dispatcher) })
    }

    /// The multiplexed path.
    pub fn path(&self) -> &Arc<Path> {
        &self.inner.path
    }

    /// Open (or adopt) channel `id`. Both ends must open the same id,
    /// like agreeing on a port; opening twice is an error.
    pub fn open(&self, id: u32) -> Result<Channel> {
        self.open_opts(id, ChannelOptions::default())
    }

    /// [`MuxEndpoint::open`] with explicit scheduling options: a DRR
    /// weight and an optional token-bucket rate cap for this end's send
    /// direction (see [`ChannelOptions`]).
    pub fn open_opts(&self, id: u32, opts: ChannelOptions) -> Result<Channel> {
        opts.validate()?;
        let mut st = self.inner.st.lock();
        check_alive(&st)?;
        let known = st.chans.contains_key(&id);
        let ch = ensure_chan(&mut st, id);
        if ch.locally_opened {
            return Err(MpwError::Config(format!("channel {id} is already open")));
        }
        ch.locally_opened = true;
        ch.tombstone_since = None; // adopted: the lease no longer applies
        ch.weight = opts.weight;
        ch.pacer = opts.rate.map(|r| Pacer::new(Some(r)));
        if known {
            // the peer evidently knows the channel already (its frames
            // created the state) — no OPEN needed
            ch.open_sent = true;
        }
        let gen = ch.gen;
        drop(st);
        self.inner.send_cv.notify_all();
        Ok(Channel { id, gen, inner: self.inner.clone() })
    }

    /// Statistics of every live channel, ascending by id.
    pub fn channel_stats(&self) -> Vec<ChannelStats> {
        let st = self.inner.st.lock();
        let mut out: Vec<ChannelStats> = st
            .chans
            .iter()
            .map(|(&id, c)| ChannelStats {
                id,
                delivered_bytes: c.delivered_bytes,
                sent_bytes: c.sent_bytes,
                queued_bytes: c.out_bytes,
                ready_msgs: c.ready.len(),
                last_delivery_ticket: c.last_delivery_ticket,
                inbound_queued_bytes: c.ready_bytes + c.partial.len(),
                peer_grant: c.peer_grant,
                weight: c.weight.max(1),
                deficit: c.deficit,
            })
            .collect();
        out.sort_by_key(|c| c.id);
        out
    }

    /// The fatal error that killed the endpoint, if any.
    pub fn dead_reason(&self) -> Option<String> {
        self.inner.st.lock().dead.clone()
    }

    /// Whether `ch` is a handle of this endpoint (registry cleanup:
    /// destroying a path must release its channel handles too).
    pub fn owns(&self, ch: &Channel) -> bool {
        Arc::ptr_eq(&self.inner, &ch.inner)
    }

    /// Shut the endpoint down: wake every blocked operation, close the
    /// underlying path (which unblocks the workers) and join the
    /// workers. Idempotent.
    pub fn shutdown(&mut self) {
        {
            let mut st = self.inner.st.lock();
            st.shutdown = true;
            self.inner.send_cv.notify_all();
            self.inner.space_cv.notify_all();
            self.inner.recv_cv.notify_all();
        }
        self.inner.path.close();
        // A worker panic is endpoint death with a cause worth keeping:
        // record it (first cause wins) so `dead_reason` can surface it.
        if let Some(h) = self.pump.take() {
            if h.join().is_err() {
                let mut st = self.inner.st.lock();
                if st.dead.is_none() {
                    st.dead = Some("mux pump panicked".into());
                }
            }
        }
        if let Some(h) = self.dispatcher.take() {
            if h.join().is_err() {
                let mut st = self.inner.st.lock();
                if st.dead.is_none() {
                    st.dead = Some("mux dispatcher panicked".into());
                }
            }
        }
    }
}

impl Drop for MuxEndpoint {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for MuxEndpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let st = self.inner.st.lock();
        f.debug_struct("MuxEndpoint")
            .field("channels", &st.chans.len())
            .field("dead", &st.dead)
            .finish()
    }
}

/// A logical channel of a [`MuxEndpoint`]. Cheap to clone (handles share
/// the channel); message-oriented like the dynamic path API.
#[derive(Clone)]
pub struct Channel {
    id: u32,
    /// The incarnation this handle refers to; a reused id's fresh state
    /// carries a newer generation and stale handles observe
    /// `ChannelClosed`.
    gen: u64,
    inner: Arc<MuxInner>,
}

impl Channel {
    /// The channel id.
    pub fn id(&self) -> u32 {
        self.id
    }

    /// This handle's incarnation of the channel state, if it still
    /// exists — a reused id's newer generation is invisible to stale
    /// handles (they observe `ChannelClosed` instead of aliasing it).
    fn chan<'a>(&self, st: &'a MuxState) -> Option<&'a ChanState> {
        st.chans.get(&self.id).filter(|c| c.gen == self.gen)
    }

    /// Mutable variant of [`Channel::chan`].
    fn chan_mut<'a>(&self, st: &'a mut MuxState) -> Option<&'a mut ChanState> {
        st.chans.get_mut(&self.id).filter(|c| c.gen == self.gen)
    }

    /// Queue `data` for transmission as one message. Blocks only on the
    /// channel's [`MuxConfig::high_water`] backpressure, never on the
    /// wire. Returns once the message is queued.
    pub fn send(&self, data: &[u8]) -> Result<()> {
        self.send_owned(data.to_vec())
    }

    /// [`Channel::send`] of an already-owned buffer — queued as-is, no
    /// copy (the `isend` path and producers that build their message in
    /// a `Vec` anyway).
    pub fn send_owned(&self, data: Vec<u8>) -> Result<()> {
        match self.queue_or_park(data)? {
            None => Ok(()),
            Some((data, ticket)) => self.wait_and_enqueue(data, ticket),
        }
    }

    /// One atomic admission step shared by the blocking and non-blocking
    /// send paths: queue immediately when nobody is parked and there is
    /// room (`Ok(None)`), otherwise hand back the buffer together with a
    /// freshly assigned FIFO park ticket (`Ok(Some(..))`). The ticket is
    /// taken **here, in program order**, so a later send can never
    /// overtake an earlier one that fell back to parking — regardless of
    /// how the parked waiters' threads are scheduled.
    fn queue_or_park(&self, data: Vec<u8>) -> Result<Option<(Vec<u8>, u64)>> {
        let mut st = self.inner.st.lock();
        check_alive(&st)?;
        let ch = self
            .chan_mut(&mut st)
            .ok_or(MpwError::ChannelClosed { channel: self.id })?;
        if ch.local_closed || ch.remote_closed {
            return Err(MpwError::ChannelClosed { channel: self.id });
        }
        if ch.park_head == ch.park_tail && admit(ch, data.len(), self.inner.cfg.high_water) {
            enqueue(ch, data);
            drop(st);
            self.inner.send_cv.notify_all();
            return Ok(None);
        }
        let ticket = ch.park_tail;
        ch.park_tail += 1;
        Ok(Some((data, ticket)))
    }

    /// Park until `ticket` reaches the head of the channel's FIFO *and*
    /// the high-water mark admits the message, then enqueue. Error exits
    /// (endpoint dead, channel closed) leave the ticket unreleased on
    /// purpose: those conditions are permanent and every other parked
    /// sender observes them too.
    fn wait_and_enqueue(&self, data: Vec<u8>, ticket: u64) -> Result<()> {
        let mut st = self.inner.st.lock();
        loop {
            check_alive(&st)?;
            let Some(ch) = self.chan(&st) else {
                return Err(MpwError::ChannelClosed { channel: self.id });
            };
            if ch.local_closed || ch.remote_closed {
                return Err(MpwError::ChannelClosed { channel: self.id });
            }
            if ch.park_head == ticket && admit(ch, data.len(), self.inner.cfg.high_water) {
                break;
            }
            st = self.inner.space_cv.wait(st);
        }
        let Some(ch) = self.chan_mut(&mut st) else {
            return Err(MpwError::ChannelClosed { channel: self.id });
        };
        ch.park_head += 1;
        enqueue(ch, data);
        drop(st);
        self.inner.send_cv.notify_all();
        // the next parked ticket (if any) watches park_head via space_cv
        self.inner.space_cv.notify_all();
        Ok(())
    }

    /// Receive the next message, blocking until one is available.
    /// Returns [`MpwError::ChannelClosed`] once the channel is closed
    /// (either end) **and** every delivered message has been drained.
    pub fn recv(&self) -> Result<Vec<u8>> {
        let mut st = self.inner.st.lock();
        loop {
            if let Some(ch) = self.chan_mut(&mut st) {
                if let Some(msg) = ch.ready.pop_front() {
                    ch.ready_bytes = ch.ready_bytes.saturating_sub(msg.len());
                    gc_chan(&mut st, self.id);
                    drop(st);
                    self.inner.space_cv.notify_all();
                    if self.inner.cfg.recv_high_water.is_some() {
                        // freed inbound budget: let the pump consider a
                        // fresh credit advert for the peer
                        self.inner.send_cv.notify_all();
                    }
                    return Ok(msg);
                }
                if ch.remote_closed || ch.local_closed {
                    return Err(MpwError::ChannelClosed { channel: self.id });
                }
            } else {
                return Err(MpwError::ChannelClosed { channel: self.id });
            }
            check_alive(&st)?;
            st = self.inner.recv_cv.wait(st);
        }
    }

    /// Like [`Channel::recv`] but non-blocking: `Ok(None)` when no
    /// message is currently available.
    pub fn try_recv(&self) -> Result<Option<Vec<u8>>> {
        let mut st = self.inner.st.lock();
        if let Some(ch) = self.chan_mut(&mut st) {
            if let Some(msg) = ch.ready.pop_front() {
                ch.ready_bytes = ch.ready_bytes.saturating_sub(msg.len());
                gc_chan(&mut st, self.id);
                drop(st);
                self.inner.space_cv.notify_all();
                if self.inner.cfg.recv_high_water.is_some() {
                    self.inner.send_cv.notify_all();
                }
                return Ok(Some(msg));
            }
            if ch.remote_closed || ch.local_closed {
                return Err(MpwError::ChannelClosed { channel: self.id });
            }
        } else {
            return Err(MpwError::ChannelClosed { channel: self.id });
        }
        check_alive(&st)?;
        Ok(None)
    }

    /// Block until every queued outbound byte of this channel has been
    /// handed to the path — and, in resilient mode, acknowledged by the
    /// peer: rendezvous sends (window 1) acknowledge inline, and for a
    /// pipelined path ([`ResilienceConfig::window`] > 1) this drains
    /// the path's in-flight send window before returning. Call before
    /// dropping the endpoint: [`MuxEndpoint::shutdown`] is abrupt and
    /// discards still-queued messages.
    ///
    /// [`ResilienceConfig::window`]: super::config::ResilienceConfig::window
    pub fn flush(&self) -> Result<()> {
        let mut st = self.inner.st.lock();
        loop {
            check_alive(&st)?;
            match self.chan(&st) {
                None => break, // fully closed and drained
                Some(ch) => {
                    if ch.outq.is_empty() && !ch.in_flight {
                        break;
                    }
                }
            }
            st = self.inner.space_cv.wait(st);
        }
        drop(st);
        // handed to the path may still mean "posted into the send
        // window, unacknowledged" — drain it before reporting done
        self.inner.path.flush()
    }

    /// Close the channel: already-queued messages are still sent, then a
    /// CLOSE frame tells the peer no more will follow. Idempotent.
    pub fn close(&self) -> Result<()> {
        let mut st = self.inner.st.lock();
        if let Some(ch) = self.chan_mut(&mut st) {
            ch.local_closed = true;
        }
        drop(st);
        self.inner.send_cv.notify_all();
        self.inner.recv_cv.notify_all();
        // producers blocked on the high-water mark must observe the close
        self.inner.space_cv.notify_all();
        Ok(())
    }

    /// Change this channel's DRR scheduling weight live (see
    /// [`ChannelOptions::weight`]). Takes effect from the channel's next
    /// rotation turn; already-accrued deficit is kept.
    pub fn set_weight(&self, weight: u32) -> Result<()> {
        ChannelOptions { weight, rate: None }.validate()?;
        let mut st = self.inner.st.lock();
        check_alive(&st)?;
        let ch = self
            .chan_mut(&mut st)
            .ok_or(MpwError::ChannelClosed { channel: self.id })?;
        ch.weight = weight;
        drop(st);
        self.inner.send_cv.notify_all();
        Ok(())
    }

    /// Replace this channel's token-bucket rate cap live (see
    /// [`ChannelOptions::rate`]); `None` removes the cap. The bucket
    /// restarts with a fresh burst allowance.
    pub fn set_rate(&self, rate: Option<f64>) -> Result<()> {
        ChannelOptions { weight: 1, rate }.validate()?;
        let mut st = self.inner.st.lock();
        check_alive(&st)?;
        let ch = self
            .chan_mut(&mut st)
            .ok_or(MpwError::ChannelClosed { channel: self.id })?;
        ch.pacer = rate.map(|r| Pacer::new(Some(r)));
        drop(st);
        self.inner.send_cv.notify_all();
        Ok(())
    }

    /// Start a non-blocking send (`MPW_ISendRecv` pattern): the message
    /// is queued and flushed by the pump while the caller computes.
    /// When there is room below the high-water mark — the common case —
    /// the queue push happens inline and the returned handle is already
    /// finished (no worker thread); only a send that would block on
    /// backpressure falls back to a worker, which carries a park ticket
    /// assigned *here*, so per-channel send order holds even across the
    /// worker handoff.
    pub fn isend(&self, data: Vec<u8>) -> super::nonblocking::NbeHandle {
        match self.queue_or_park(data) {
            Ok(None) => super::nonblocking::NbeHandle::ready(Ok(None)),
            Ok(Some((data, ticket))) => {
                let ch = self.clone();
                super::nonblocking::NbeHandle::spawn(move || {
                    ch.wait_and_enqueue(data, ticket).map(|()| None)
                })
            }
            Err(e) => super::nonblocking::NbeHandle::ready(Err(e)),
        }
    }

    /// Start a non-blocking receive; `wait()` returns the message. A
    /// message already delivered to the channel completes inline (no
    /// worker thread) — mirrors the `isend` fast path.
    ///
    /// With **several** `irecv`s outstanding on one channel, which
    /// handle receives which message is unspecified (their workers race
    /// for the queue); the channel itself stays FIFO. Issue one at a
    /// time — the latency-hiding pattern — when assignment order
    /// matters.
    pub fn irecv(&self) -> super::nonblocking::NbeHandle {
        match self.try_recv() {
            Ok(Some(msg)) => super::nonblocking::NbeHandle::ready(Ok(Some(msg))),
            Ok(None) => {
                let ch = self.clone();
                super::nonblocking::NbeHandle::spawn(move || ch.recv().map(Some))
            }
            Err(e) => super::nonblocking::NbeHandle::ready(Err(e)),
        }
    }
}

impl std::fmt::Debug for Channel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Channel").field("id", &self.id).finish()
    }
}

/// THE high-water admission rule, shared by the blocking and
/// non-blocking send paths so backpressure policy cannot drift between
/// them: a message is admitted when the queue is empty (a single
/// oversized message must always be sendable) or when it fits under the
/// mark.
fn admit(ch: &ChanState, len: usize, high_water: usize) -> bool {
    ch.out_bytes == 0 || ch.out_bytes + len <= high_water
}

/// Enqueue bookkeeping shared by the blocking and non-blocking send
/// paths (sequence number, byte accounting, queue push).
fn enqueue(ch: &mut ChanState, data: Vec<u8>) {
    let seq = ch.next_send_seq;
    ch.next_send_seq += 1;
    ch.out_bytes += data.len();
    ch.outq.push_back(OutMsg { data, off: 0, seq });
}

fn check_alive(st: &MuxState) -> Result<()> {
    if let Some(msg) = &st.dead {
        return Err(MpwError::Protocol(format!("mux endpoint failed: {msg}")));
    }
    if st.shutdown {
        return Err(MpwError::Protocol("mux endpoint is shut down".into()));
    }
    Ok(())
}

/// Get-or-create channel state (inbound frames may precede the local
/// `open`), registering the id in the rotation order.
fn ensure_chan(st: &mut MuxState, id: u32) -> &mut ChanState {
    let gen = st.next_gen;
    let order = &mut st.order;
    let mut created = false;
    let ch = st.chans.entry(id).or_insert_with(|| {
        order.push(id);
        created = true;
        ChanState { gen, weight: 1, ..ChanState::default() }
    });
    if created {
        st.next_gen += 1;
    }
    ch
}

/// Drop a channel's state once both ends closed it and everything is
/// drained (frees the id's slot in the rotation).
///
/// State the peer created but this side never opened is deliberately
/// *retained* after the peer's CLOSE: erasing it would forget
/// `remote_closed` (a later local `open` would block in `recv` forever
/// instead of reporting `ChannelClosed`) and would discard messages a
/// fire-and-close producer sent for a late opener to drain — the "open
/// order across the two ends is free" guarantee depends on both. The
/// cost is one `ChanState` per never-opened id **including any
/// undrained `ready` payloads**; [`MuxConfig::tombstone_ttl`] leases
/// that retention for ephemeral-id workloads (see
/// [`sweep_tombstones`]).
fn gc_chan(st: &mut MuxState, id: u32) {
    let done = match st.chans.get(&id) {
        Some(c) => {
            c.local_closed
                && c.close_sent
                && c.remote_closed
                && !c.in_flight
                && c.ready.is_empty()
                && c.outq.is_empty()
        }
        None => false,
    };
    if done {
        st.chans.remove(&id);
        if let Some(pos) = st.order.iter().position(|&x| x == id) {
            st.order.remove(pos);
            if st.cursor > pos {
                st.cursor -= 1;
            }
        }
        if !st.order.is_empty() {
            st.cursor %= st.order.len();
        } else {
            st.cursor = 0;
        }
    }
}

/// Expire leased tombstones: state for ids the peer closed but this
/// side never opened, retained so a late `open` observes the close
/// (see [`gc_chan`]). Under a [`MuxConfig::tombstone_ttl`] lease such
/// state is dropped once it has sat closed and drained for the ttl —
/// an `open` later than that behaves like a never-used id. Runs in the
/// pump, which wakes at least once per ttl while the endpoint idles.
fn sweep_tombstones(st: &mut MuxState, ttl: Option<Duration>) {
    let Some(ttl) = ttl else { return };
    let expired: Vec<u32> = st
        .chans
        .iter()
        .filter(|(_, c)| {
            !c.locally_opened
                && c.remote_closed
                && c.ready.is_empty()
                && c.tombstone_since.is_some_and(|t0| t0.elapsed() >= ttl)
        })
        .map(|(&id, _)| id)
        .collect();
    if expired.is_empty() {
        return;
    }
    for id in expired {
        st.chans.remove(&id);
        if let Some(pos) = st.order.iter().position(|&x| x == id) {
            st.order.remove(pos);
            if st.cursor > pos {
                st.cursor -= 1;
            }
        }
    }
    if st.order.is_empty() {
        st.cursor = 0;
    } else {
        st.cursor %= st.order.len();
    }
}

/// Select the pump's next frame with deficit round-robin: scan the
/// rotation from the cursor; the first channel with eligible work opens
/// (or continues) a **turn**. Opening a turn accrues one quantum of
/// byte allowance — `weight × chunk_budget`, carried deficit included,
/// capped at two quanta — and the channel then keeps the cursor until
/// its allowance cannot cover the next frame, its queue drains (deficit
/// resets: an idle channel must not hoard allowance), or a gate ends
/// the turn. Unspent allowance smaller than the next frame carries over
/// to the channel's next turn, so long-run byte shares converge to the
/// weight ratios even when frame sizes do not divide the quantum.
/// Every frame burns at least `chunk_budget / FRAME_COST_DIVISOR`
/// allowance (see [`FRAME_COST_DIVISOR`]), bounding a turn in frames as
/// well as bytes.
///
/// Gates compose without burning deficit:
///
/// * **Credit** (with a credit-advertising peer): a channel *starts* a
///   new message only while its cumulative sent bytes are below the
///   peer's newest grant; a started message is always finished
///   (`off > 0`), so a single message larger than the grant window
///   cannot wedge the peer's reassembly. A creditless channel forfeits
///   its turn — deficit kept, nothing accrued — and is skipped, not
///   waited on: the rotation keeps every other channel flowing.
/// * **Rate cap**: a channel whose token bucket cannot cover the next
///   frame forfeits its turn the same way; the earliest refill time
///   among such channels is returned so the pump can bound its idle
///   wait instead of relying on an external wakeup. The bucket is only
///   probed with the non-blocking [`Pacer::try_acquire`] — the pump
///   never sleeps under the state lock.
///
/// Control frames are unchanged from the flat scheduler: a pending OPEN
/// precedes data, and with `recv_high_water` set a due credit advert
/// preempts the channel's own data (a starved peer needs the grant more
/// than we need the next chunk). Neither touches the deficit.
fn pick_job(
    st: &mut MuxState,
    budget: usize,
    recv_high_water: Option<usize>,
) -> (Option<PumpJob>, Option<Duration>) {
    let n = st.order.len();
    let peer_credit = st.peer_credit;
    let frame_floor = (budget / FRAME_COST_DIVISOR).max(1) as u64;
    let mut next_ready: Option<Duration> = None;
    for k in 0..n {
        let pos = (st.cursor + k) % n;
        let id = st.order[pos];
        let Some(ch) = st.chans.get_mut(&id) else { continue };
        if ch.locally_opened && !ch.open_sent {
            ch.open_sent = true;
            st.cursor = (pos + 1) % n;
            return (Some(PumpJob::Open(id)), next_ready);
        }
        if let Some(hw) = recv_high_water {
            if !ch.remote_closed {
                let desired = ch
                    .recvd_bytes
                    .saturating_add((hw as u64).saturating_sub(ch.ready_bytes as u64))
                    .max(ch.last_grant);
                // Re-advertise only on meaningful growth (a quarter of
                // the budget) — a WINDOW_UPDATE per tiny recv would
                // spend the wire on bookkeeping. The first advert
                // (last_grant 0, desired >= hw) always qualifies.
                if desired - ch.last_grant >= ((hw / 4).max(1)) as u64 {
                    ch.last_grant = desired;
                    st.cursor = (pos + 1) % n;
                    return (Some(PumpJob::Credit { id, grant: desired }), next_ready);
                }
            }
        }
        let credit_gated = peer_credit
            && ch.outq.front().is_some_and(|m| m.off == 0)
            && ch.sent_bytes >= ch.peer_grant;
        if credit_gated {
            // forfeit the turn: deficit kept, nothing accrued
            ch.turn_active = false;
        }
        let head = if credit_gated { None } else { ch.outq.front() };
        if let Some((end, take, fin)) = head.map(|m| {
            let end = (m.off + budget).min(m.data.len());
            (end, end - m.off, end == m.data.len())
        }) {
            let quantum = u64::from(ch.weight.max(1)) * budget as u64;
            let cost = (take as u64).max(frame_floor);
            // Speculative turn accounting: the quantum is committed only
            // if the frame actually goes out, so a rate-gated channel
            // neither accrues nor burns allowance while it waits.
            let allowance = if ch.turn_active {
                ch.deficit
            } else {
                ch.deficit.saturating_add(quantum).min(quantum.saturating_mul(2))
            };
            if cost <= allowance {
                match ch.pacer.as_mut().and_then(|p| p.try_acquire(take)) {
                    Some(ready) => {
                        // rate-gated: forfeit the turn, remember when the
                        // bucket refills so the pump's wait is bounded
                        ch.turn_active = false;
                        next_ready = Some(match next_ready {
                            Some(cur) => cur.min(ready),
                            None => ready,
                        });
                    }
                    None => {
                        if let Some(msg) = ch.outq.pop_front() {
                            ch.out_bytes -= take;
                            ch.sent_bytes += take as u64;
                            ch.in_flight = true;
                            let left = allowance - cost;
                            if (fin && ch.outq.is_empty()) || left == 0 {
                                // queue drained or allowance spent: the
                                // turn ends, the rotation moves on
                                ch.deficit = if fin && ch.outq.is_empty() { 0 } else { left };
                                ch.turn_active = false;
                                st.cursor = (pos + 1) % n;
                            } else {
                                ch.deficit = left;
                                ch.turn_active = true;
                                st.cursor = pos;
                            }
                            return (Some(PumpJob::Chunk { id, msg, end, fin }), next_ready);
                        }
                    }
                }
            } else {
                // mid-turn exhaustion: carry the remainder to the next turn
                ch.turn_active = false;
            }
        }
        if ch.local_closed && !ch.close_sent && !ch.in_flight && ch.outq.is_empty() {
            ch.close_sent = true;
            st.cursor = (pos + 1) % n;
            return (Some(PumpJob::Close(id)), next_ready);
        }
    }
    (None, next_ready)
}

fn pump_loop(inner: &Arc<MuxInner>) {
    let budget = inner.cfg.chunk_budget;
    // Frames were handed to the path since the last window drain: on
    // going idle the pump flushes the path once (outside the state
    // lock) before parking, so a windowed resilient path never sits on
    // unacknowledged frames while the queues look drained.
    let mut dirty = false;
    loop {
        let job = {
            let mut st = inner.st.lock();
            loop {
                if st.shutdown || st.dead.is_some() {
                    return;
                }
                sweep_tombstones(&mut st, inner.cfg.tombstone_ttl);
                let (job, rate_hint) = pick_job(&mut st, budget, inner.cfg.recv_high_water);
                if let Some(job) = job {
                    break Some(job);
                }
                if dirty {
                    break None; // drain the path window outside the lock
                }
                // Idle: wake on new work, the periodic tombstone sweep,
                // or the earliest rate-gated channel's bucket refill —
                // whichever comes first. The refill bound matters: no
                // external event announces "tokens have accrued", so
                // without it a rate-capped channel would stall until the
                // next unrelated send.
                let wait = match (inner.cfg.tombstone_ttl, rate_hint) {
                    (Some(ttl), Some(ready)) => Some(ttl.min(ready)),
                    (Some(ttl), None) => Some(ttl),
                    (None, ready) => ready,
                };
                st = match wait {
                    Some(d) => inner.send_cv.wait_timeout(st, d).0,
                    None => inner.send_cv.wait(st),
                };
            }
        };
        let Some(job) = job else {
            // idle with frames outstanding: push the path's in-flight
            // send window through to the peer's ACKs before sleeping
            let drained = inner.path.flush();
            dirty = false;
            if let Err(e) = drained {
                let mut st = inner.st.lock();
                if !st.shutdown && st.dead.is_none() {
                    st.dead = Some(format!("mux window drain failed: {e}"));
                }
                inner.recv_cv.notify_all();
                inner.space_cv.notify_all();
                inner.send_cv.notify_all();
                return;
            }
            // Channel::flush waiters recheck queue + window through this
            inner.space_cv.notify_all();
            continue;
        };
        // producers may be blocked on the bytes we just claimed
        inner.space_cv.notify_all();
        let sent = match &job {
            PumpJob::Open(id) => {
                let hdr = encode_mux_hdr(CH_OPEN, *id, 0, 0);
                inner.path.dsend_split(&hdr, &[])
            }
            PumpJob::Close(id) => {
                let hdr = encode_mux_hdr(CH_CLOSE, *id, 0, 0);
                inner.path.dsend_split(&hdr, &[])
            }
            PumpJob::Chunk { id, msg, end, fin } => {
                let kind = if *fin { CH_FIN } else { CH_DATA };
                let chunk = &msg.data[msg.off..*end];
                let hdr = encode_mux_hdr(kind, *id, msg.seq, chunk.len() as u32);
                inner.path.dsend_split(&hdr, chunk)
            }
            PumpJob::Credit { id, grant } => {
                let hdr = encode_mux_hdr(CH_WINDOW_UPDATE, *id, *grant, 0);
                inner.path.dsend_split(&hdr, &[])
            }
        };
        let mut st = inner.st.lock();
        match job {
            PumpJob::Chunk { id, msg, end, fin } => {
                if let Some(ch) = st.chans.get_mut(&id) {
                    ch.in_flight = false;
                    if !fin && sent.is_ok() {
                        let mut msg = msg;
                        msg.off = end;
                        ch.outq.push_front(msg);
                    }
                }
            }
            PumpJob::Close(id) => {
                // the CLOSE just sent may have been the channel's last
                // pending duty — without this, the side that closes
                // *second* (its gc triggers in recv/route already ran)
                // would keep the state forever and the id could never
                // be reused here
                gc_chan(&mut st, id);
            }
            PumpJob::Open(_) | PumpJob::Credit { .. } => {}
        }
        // flush() waiters watch in_flight/outq through this condvar
        inner.space_cv.notify_all();
        match sent {
            Ok(()) => dirty = true,
            Err(e) => {
                if !st.shutdown {
                    st.dead = Some(format!("mux send failed: {e}"));
                }
                inner.recv_cv.notify_all();
                inner.space_cv.notify_all();
                inner.send_cv.notify_all();
                return;
            }
        }
    }
}

fn dispatch_loop(inner: &Arc<MuxInner>) {
    let mut cache: Vec<u8> = Vec::new();
    loop {
        {
            let st = inner.st.lock();
            if st.shutdown || st.dead.is_some() {
                return;
            }
        }
        let n = match inner.path.drecv_into(&mut cache) {
            Ok(n) => n,
            Err(e) => {
                let mut st = inner.st.lock();
                if !st.shutdown && st.dead.is_none() {
                    st.dead = Some(format!("mux receive failed: {e}"));
                }
                inner.recv_cv.notify_all();
                inner.space_cv.notify_all();
                inner.send_cv.notify_all();
                return;
            }
        };
        if let Err(e) = route_frame(inner, &cache[..n]) {
            let mut st = inner.st.lock();
            if st.dead.is_none() {
                st.dead = Some(e.to_string());
            }
            inner.recv_cv.notify_all();
            inner.space_cv.notify_all();
            inner.send_cv.notify_all();
            // a protocol violation is unrecoverable: fail the path too so
            // the peer does not hang on a dispatcher that stopped reading
            inner.path.shutdown_all_streams();
            return;
        }
    }
}

/// Validate one inbound frame and fold it into the channel state.
fn route_frame(inner: &Arc<MuxInner>, frame: &[u8]) -> Result<()> {
    if frame.len() < MUX_HDR_LEN {
        return Err(MpwError::Protocol(format!("short channel frame ({} bytes)", frame.len())));
    }
    let (hdr_bytes, payload) = frame.split_at(MUX_HDR_LEN);
    let hdr = match <&[u8; MUX_HDR_LEN]>::try_from(hdr_bytes) {
        Ok(h) => decode_mux_hdr(h)?,
        Err(_) => {
            return Err(MpwError::Protocol(format!(
                "short channel frame ({} bytes)",
                frame.len()
            )))
        }
    };
    if payload.len() != hdr.len as usize {
        return Err(MpwError::Protocol(format!(
            "channel frame length mismatch: header says {}, message carries {}",
            hdr.len,
            payload.len()
        )));
    }
    let mut st = inner.st.lock();
    match hdr.kind {
        CH_OPEN => {
            ensure_chan(&mut st, hdr.channel);
        }
        CH_CLOSE => {
            let ch = ensure_chan(&mut st, hdr.channel);
            ch.remote_closed = true;
            if !ch.locally_opened && ch.tombstone_since.is_none() {
                ch.tombstone_since = Some(Instant::now());
            }
            gc_chan(&mut st, hdr.channel);
            drop(st);
            inner.recv_cv.notify_all();
        }
        CH_DATA | CH_FIN => {
            let ticket = st.delivery_ticket + 1;
            let ch = ensure_chan(&mut st, hdr.channel);
            if ch.remote_closed {
                return Err(MpwError::Protocol(format!(
                    "data frame on channel {} after its CLOSE",
                    hdr.channel
                )));
            }
            if hdr.msg_seq != ch.next_recv_seq {
                return Err(MpwError::Protocol(format!(
                    "channel {} ordering violated: frame for message {} while expecting {}",
                    hdr.channel, hdr.msg_seq, ch.next_recv_seq
                )));
            }
            // MAX_MUX_PAYLOAD bounds one frame; the reassembled message
            // must be bounded too, or a peer that never sends FIN could
            // grow the buffer without limit (same guard as the dynamic
            // and resilience layers)
            let total = ch.partial.len() as u64 + payload.len() as u64;
            if total > super::dynamic::MAX_DYNAMIC {
                return Err(MpwError::Protocol(format!(
                    "channel {} message exceeds the {}-byte bound",
                    hdr.channel,
                    super::dynamic::MAX_DYNAMIC
                )));
            }
            ch.partial.extend_from_slice(payload);
            if hdr.kind == CH_FIN {
                let msg = std::mem::take(&mut ch.partial);
                ch.delivered_bytes += msg.len() as u64;
                ch.recvd_bytes += msg.len() as u64;
                ch.ready_bytes += msg.len();
                ch.ready.push_back(msg);
                ch.next_recv_seq += 1;
                ch.last_delivery_ticket = ticket;
                st.delivery_ticket = ticket;
                drop(st);
                inner.recv_cv.notify_all();
            }
        }
        CH_WINDOW_UPDATE => {
            // proof of a credit-aware peer: from here on the pump gates
            // each channel's sends on that channel's grant
            st.peer_credit = true;
            // advisory: a grant for state we already dropped (both ends
            // closed and drained) must not resurrect the channel
            if let Some(ch) = st.chans.get_mut(&hdr.channel) {
                ch.peer_grant = ch.peer_grant.max(hdr.msg_seq);
            }
            drop(st);
            // the pump may be parked on exhausted credit
            inner.send_cv.notify_all();
        }
        _ => unreachable!("decode_mux_hdr validated the kind"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Message links: the abstraction that makes tools channel-aware.
// ---------------------------------------------------------------------------

/// Anything that can move whole dynamic-size messages: a [`Path`]
/// (`dsend`/`drecv`) or a mux [`Channel`]. Tools written against this
/// trait (DataGather, mpw-cp) run unchanged over a dedicated path *or*
/// over one channel of a shared path.
pub trait MsgLink {
    /// Send one whole message.
    fn send_msg(&self, buf: &[u8]) -> Result<()>;
    /// Receive one whole message.
    fn recv_msg(&self) -> Result<Vec<u8>>;
    /// Receive one whole message into a reusable cache; returns its
    /// length. The default allocates via [`MsgLink::recv_msg`].
    fn recv_msg_into(&self, cache: &mut Vec<u8>) -> Result<usize> {
        let msg = self.recv_msg()?;
        let n = msg.len();
        if cache.len() < n {
            cache.resize(n, 0);
        }
        cache[..n].copy_from_slice(&msg);
        Ok(n)
    }
}

impl MsgLink for Path {
    fn send_msg(&self, buf: &[u8]) -> Result<()> {
        self.dsend(buf)
    }
    fn recv_msg(&self) -> Result<Vec<u8>> {
        self.drecv()
    }
    fn recv_msg_into(&self, cache: &mut Vec<u8>) -> Result<usize> {
        self.drecv_into(cache)
    }
}

impl MsgLink for Channel {
    fn send_msg(&self, buf: &[u8]) -> Result<()> {
        self.send(buf)
    }
    fn recv_msg(&self) -> Result<Vec<u8>> {
        self.recv()
    }
    fn recv_msg_into(&self, cache: &mut Vec<u8>) -> Result<usize> {
        // recv already yields an owned buffer; swap it in instead of
        // copying (the transfer loops call this per 8 MB chunk)
        let mut msg = self.recv()?;
        let n = msg.len();
        std::mem::swap(cache, &mut msg);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::config::PathConfig;
    use crate::mpwide::transport::mem_path_pairs;
    use crate::util::Rng;

    fn mem_endpoints(n: usize, cfg: MuxConfig) -> (MuxEndpoint, MuxEndpoint) {
        let (l, r) = mem_path_pairs(n);
        let mut pc = PathConfig::with_streams(n);
        pc.autotune = false;
        pc.chunk_size = 64 * 1024;
        let a = Arc::new(Path::from_pairs(l, pc.clone()).unwrap());
        let b = Arc::new(Path::from_pairs(r, pc).unwrap());
        (
            MuxEndpoint::start_cfg(a, cfg.clone()).unwrap(),
            MuxEndpoint::start_cfg(b, cfg).unwrap(),
        )
    }

    #[test]
    fn mux_hdr_roundtrip() {
        let h = encode_mux_hdr(CH_DATA, 7, 42, 1000);
        let d = decode_mux_hdr(&h).unwrap();
        assert_eq!(d, MuxHdr { kind: CH_DATA, channel: 7, msg_seq: 42, len: 1000 });
    }

    #[test]
    fn mux_hdr_rejects_garbage() {
        let mut h = encode_mux_hdr(CH_FIN, 1, 0, 4);
        h[0] = 0;
        assert!(decode_mux_hdr(&h).is_err(), "bad magic");
        let mut h = encode_mux_hdr(CH_FIN, 1, 0, 4);
        h[1] = 99;
        assert!(decode_mux_hdr(&h).is_err(), "bad kind");
        let h = encode_mux_hdr(CH_DATA, 1, 0, (MAX_MUX_PAYLOAD + 1) as u32);
        assert!(decode_mux_hdr(&h).is_err(), "oversized payload");
        let h = encode_mux_hdr(CH_OPEN, 1, 0, 4);
        assert!(decode_mux_hdr(&h).is_err(), "OPEN with payload");
    }

    #[test]
    fn two_channels_roundtrip() {
        let (a, b) = mem_endpoints(2, MuxConfig::default());
        let a1 = a.open(1).unwrap();
        let a2 = a.open(2).unwrap();
        let b1 = b.open(1).unwrap();
        let b2 = b.open(2).unwrap();
        let mut m1 = vec![0u8; 100_000];
        let mut m2 = vec![0u8; 5_000];
        Rng::new(31).fill_bytes(&mut m1);
        Rng::new(32).fill_bytes(&mut m2);
        a1.send(&m1).unwrap();
        a2.send(&m2).unwrap();
        assert_eq!(b1.recv().unwrap(), m1);
        assert_eq!(b2.recv().unwrap(), m2);
        // reverse direction over the same shared path
        b2.send(&m1).unwrap();
        assert_eq!(a2.recv().unwrap(), m1);
    }

    #[test]
    fn per_channel_ordering_holds() {
        let (a, b) =
            mem_endpoints(1, MuxConfig { chunk_budget: 1024, high_water: 1 << 20, ..MuxConfig::default() });
        let tx = a.open(9).unwrap();
        let rx = b.open(9).unwrap();
        for i in 0..20u32 {
            let mut m = i.to_be_bytes().to_vec();
            m.resize(3_000, i as u8);
            tx.send(&m).unwrap();
        }
        for i in 0..20u32 {
            let m = rx.recv().unwrap();
            assert_eq!(u32::from_be_bytes(m[..4].try_into().unwrap()), i, "reordered");
        }
    }

    #[test]
    fn bulk_does_not_starve_small_channels() {
        // The bulk channel queues a big message FIRST; small messages on
        // other channels queued afterwards must still be delivered before
        // the bulk completes (global delivery tickets make the order
        // deterministic — a strict-FIFO mux would fail this).
        let cfg =
            MuxConfig { chunk_budget: 16 * 1024, high_water: 64 << 20, ..MuxConfig::default() };
        // paced path: the pump needs tens of milliseconds for the bulk
        // message while enqueueing the small one takes microseconds, so
        // the ticket comparison below cannot be raced by scheduling
        let (l, r) = mem_path_pairs(2);
        let mut pc = PathConfig::with_streams(2);
        pc.autotune = false;
        pc.chunk_size = 64 * 1024;
        pc.pacing_rate = Some(32.0 * 1024.0 * 1024.0);
        let pa = Arc::new(Path::from_pairs(l, pc.clone()).unwrap());
        let pb = Arc::new(Path::from_pairs(r, pc).unwrap());
        let a = MuxEndpoint::start_cfg(pa, cfg.clone()).unwrap();
        let b = MuxEndpoint::start_cfg(pb, cfg).unwrap();
        let bulk_tx = a.open(1).unwrap();
        let small_tx = a.open(2).unwrap();
        let bulk_rx = b.open(1).unwrap();
        let small_rx = b.open(2).unwrap();
        let big = vec![7u8; 4 << 20];
        bulk_tx.send(&big).unwrap();
        small_tx.send(&[1, 2, 3]).unwrap();
        assert_eq!(small_rx.recv().unwrap(), vec![1, 2, 3]);
        assert_eq!(bulk_rx.recv().unwrap(), big);
        let stats = b.channel_stats();
        let t_bulk = stats.iter().find(|c| c.id == 1).unwrap().last_delivery_ticket;
        let t_small = stats.iter().find(|c| c.id == 2).unwrap().last_delivery_ticket;
        assert!(
            t_small < t_bulk,
            "small message (ticket {t_small}) must beat the bulk transfer (ticket {t_bulk})"
        );
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let (a, b) = mem_endpoints(1, MuxConfig::default());
        let tx = a.open(4).unwrap();
        let rx = b.open(4).unwrap();
        tx.send(b"last words").unwrap();
        tx.close().unwrap();
        assert_eq!(rx.recv().unwrap(), b"last words");
        match rx.recv() {
            Err(MpwError::ChannelClosed { channel: 4 }) => {}
            other => panic!("expected ChannelClosed, got {other:?}"),
        }
        match tx.send(b"x") {
            Err(MpwError::ChannelClosed { channel: 4 }) => {}
            other => panic!("expected ChannelClosed on closed send, got {other:?}"),
        }
    }

    #[test]
    fn channel_id_reusable_after_both_ends_close() {
        let (a, b) = mem_endpoints(1, MuxConfig::default());
        let tx = a.open(6).unwrap();
        let rx = b.open(6).unwrap();
        tx.send(b"gen1").unwrap();
        assert_eq!(rx.recv().unwrap(), b"gen1");
        tx.close().unwrap();
        assert!(matches!(rx.recv(), Err(MpwError::ChannelClosed { .. })));
        rx.close().unwrap();
        // both ends quiesce the id (CLOSE frames exchanged + gc) …
        let t0 = std::time::Instant::now();
        loop {
            let a_gone = a.channel_stats().iter().all(|c| c.id != 6);
            let b_gone = b.channel_stats().iter().all(|c| c.id != 6);
            if a_gone && b_gone {
                break;
            }
            assert!(t0.elapsed().as_secs() < 5, "closed channel state never gc'd");
            std::thread::yield_now();
        }
        // … after which the id is reusable with fresh sequence state
        let tx2 = a.open(6).unwrap();
        let rx2 = b.open(6).unwrap();
        tx2.send(b"gen2").unwrap();
        assert_eq!(rx2.recv().unwrap(), b"gen2");
    }

    #[test]
    fn tombstone_lease_expires_never_opened_state() {
        let ttl = std::time::Duration::from_millis(50);
        let cfg = MuxConfig { tombstone_ttl: Some(ttl), ..MuxConfig::default() };
        let (a, b) = mem_endpoints(1, cfg);
        // `a` opens and closes id 8; `b` never opens it. The OPEN and
        // CLOSE frames leave drained tombstone state on `b` …
        let tx = a.open(8).unwrap();
        tx.close().unwrap();
        let t0 = std::time::Instant::now();
        while b.channel_stats().iter().all(|c| c.id != 8) {
            assert!(t0.elapsed().as_secs() < 5, "tombstone state never appeared");
            std::thread::yield_now();
        }
        // … which the lease expires instead of retaining forever
        let t0 = std::time::Instant::now();
        while b.channel_stats().iter().any(|c| c.id == 8) {
            assert!(t0.elapsed().as_secs() < 5, "tombstone never expired");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // an open later than the lease sees a fresh, never-used id
        let late = b.open(8).unwrap();
        assert!(late.try_recv().unwrap().is_none());
    }

    #[test]
    fn zero_tombstone_ttl_rejected() {
        let cfg = MuxConfig {
            tombstone_ttl: Some(std::time::Duration::ZERO),
            ..MuxConfig::default()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn zero_recv_high_water_rejected() {
        let cfg = MuxConfig { recv_high_water: Some(0), ..MuxConfig::default() };
        assert!(cfg.validate().is_err(), "a zero grant parks every peer forever");
        let cfg = MuxConfig { recv_high_water: Some(1 << 20), ..MuxConfig::default() };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn window_update_hdr_roundtrip() {
        let h = encode_mux_hdr(CH_WINDOW_UPDATE, 9, 123_456_789, 0);
        let d = decode_mux_hdr(&h).unwrap();
        assert_eq!(
            d,
            MuxHdr { kind: CH_WINDOW_UPDATE, channel: 9, msg_seq: 123_456_789, len: 0 }
        );
        // a credit frame must not carry payload
        let h = encode_mux_hdr(CH_WINDOW_UPDATE, 9, 1, 4);
        assert!(decode_mux_hdr(&h).is_err());
    }

    #[test]
    fn credited_channels_roundtrip_and_report_grants() {
        // both ends bound their inbound queues; traffic must still flow
        // and each end must learn the other's grant
        let cfg = MuxConfig { recv_high_water: Some(1 << 20), ..MuxConfig::default() };
        let (a, b) = mem_endpoints(2, cfg);
        let tx = a.open(3).unwrap();
        let rx = b.open(3).unwrap();
        let mut msg = vec![0u8; 200_000];
        Rng::new(77).fill_bytes(&mut msg);
        for _ in 0..8 {
            tx.send(&msg).unwrap();
            assert_eq!(rx.recv().unwrap(), msg);
        }
        tx.flush().unwrap();
        // reverse ping: b's pump sent its first credit advert before this
        // message (FIFO wire), so once it arrives the grant has landed
        rx.send(b"done").unwrap();
        assert_eq!(tx.recv().unwrap(), b"done");
        let stats = a.channel_stats();
        let c = stats.iter().find(|c| c.id == 3).expect("channel 3 stats");
        assert!(c.peer_grant > 0, "peer never advertised credit");
    }

    #[test]
    fn empty_message_roundtrips() {
        let (a, b) = mem_endpoints(1, MuxConfig::default());
        let tx = a.open(0).unwrap();
        let rx = b.open(0).unwrap();
        tx.send(&[]).unwrap();
        tx.send(b"after").unwrap();
        assert_eq!(rx.recv().unwrap(), Vec::<u8>::new());
        assert_eq!(rx.recv().unwrap(), b"after");
    }

    #[test]
    fn open_twice_rejected_and_unopened_frames_adopted() {
        let (a, b) = mem_endpoints(1, MuxConfig::default());
        let tx = a.open(5).unwrap();
        assert!(a.open(5).is_err(), "double open");
        // peer sends before this end opens: state is auto-created and
        // adopted by the later open
        tx.send(b"early").unwrap();
        let t0 = std::time::Instant::now();
        while b.channel_stats().iter().all(|c| c.id != 5) {
            assert!(t0.elapsed().as_secs() < 5, "frame never arrived");
            std::thread::yield_now();
        }
        let rx = b.open(5).unwrap();
        assert_eq!(rx.recv().unwrap(), b"early");
    }

    #[test]
    fn nonblocking_channel_ops() {
        let (a, b) = mem_endpoints(2, MuxConfig::default());
        let tx = a.open(3).unwrap();
        let rx = b.open(3).unwrap();
        let h = rx.irecv();
        let _ = h.is_finished(); // polling is allowed at any time
        let _ = tx.isend(vec![9u8; 10_000]).wait().unwrap();
        assert_eq!(h.wait().unwrap().unwrap(), vec![9u8; 10_000]);
    }

    #[test]
    fn resilient_path_carries_channels_through_stream_death() {
        use crate::mpwide::transport::mem_path_pairs_killable;
        let (l, r, kills) = mem_path_pairs_killable(4);
        let mut pc = PathConfig::with_streams(4);
        pc.autotune = false;
        pc.chunk_size = 32 * 1024;
        pc.resilience.enabled = true;
        let pa = Arc::new(Path::from_pairs(l, pc.clone()).unwrap());
        let pb = Arc::new(Path::from_pairs(r, pc).unwrap());
        let a = MuxEndpoint::start(pa).unwrap();
        let b = MuxEndpoint::start(pb).unwrap();
        let tx = a.open(1).unwrap();
        let rx = b.open(1).unwrap();
        let mut msg = vec![0u8; 1 << 20];
        Rng::new(77).fill_bytes(&mut msg);
        tx.send(&msg).unwrap();
        assert_eq!(rx.recv().unwrap(), msg);
        // kill a (non-control) stream; the resilience layer routes around
        // it and the channels never notice
        kills[2].fire();
        tx.send(&msg).unwrap();
        assert_eq!(rx.recv().unwrap(), msg);
        assert!(a.path().status().live >= 3);
    }

    #[test]
    fn path_death_surfaces_to_channels() {
        use crate::mpwide::transport::mem_path_pairs_killable;
        let (l, r, kills) = mem_path_pairs_killable(1);
        let mut pc = PathConfig::with_streams(1);
        pc.autotune = false;
        let pa = Arc::new(Path::from_pairs(l, pc.clone()).unwrap());
        let pb = Arc::new(Path::from_pairs(r, pc).unwrap());
        let a = MuxEndpoint::start(pa).unwrap();
        let b = MuxEndpoint::start(pb).unwrap();
        let tx = a.open(1).unwrap();
        let rx = b.open(1).unwrap();
        tx.send(b"ok").unwrap();
        assert_eq!(rx.recv().unwrap(), b"ok");
        for k in &kills {
            k.fire();
        }
        // the dispatcher dies on the failed path; blocked and future recvs
        // must error, not hang
        let t0 = std::time::Instant::now();
        loop {
            match rx.recv() {
                Ok(_) => {}
                Err(_) => break,
            }
            assert!(t0.elapsed().as_secs() < 10, "recv hung on a dead path");
        }
        assert!(b.dead_reason().is_some());
    }

    /// Bare scheduler state for driving `pick_job` directly — no path,
    /// no workers, every "send" completes instantly in the test loop.
    fn synth_state() -> MuxState {
        MuxState {
            chans: HashMap::new(),
            order: Vec::new(),
            cursor: 0,
            delivery_ticket: 0,
            next_gen: 0,
            dead: None,
            shutdown: false,
            peer_credit: false,
        }
    }

    /// Mirror the pump's post-send bookkeeping for a synthetic pick:
    /// clear `in_flight`, reinsert an unfinished message.
    fn complete_chunk(st: &mut MuxState, id: u32, msg: OutMsg, end: usize, fin: bool) {
        let ch = st.chans.get_mut(&id).unwrap();
        ch.in_flight = false;
        if !fin {
            let mut msg = msg;
            msg.off = end;
            ch.outq.push_front(msg);
        }
    }

    #[test]
    fn channel_options_validate() {
        assert!(ChannelOptions::default().validate().is_ok());
        assert_eq!(ChannelOptions::default().weight, 1);
        assert!(ChannelOptions { weight: 0, rate: None }.validate().is_err());
        assert!(ChannelOptions { weight: MAX_WEIGHT + 1, rate: None }.validate().is_err());
        assert!(ChannelOptions { weight: MAX_WEIGHT, rate: Some(1e6) }.validate().is_ok());
        assert!(ChannelOptions { weight: 1, rate: Some(0.0) }.validate().is_err());
        assert!(ChannelOptions { weight: 1, rate: Some(-1.0) }.validate().is_err());
        assert!(ChannelOptions { weight: 1, rate: Some(f64::NAN) }.validate().is_err());
        assert!(ChannelOptions { weight: 1, rate: Some(f64::INFINITY) }.validate().is_err());
    }

    #[test]
    fn open_opts_sets_weight_and_live_changes_are_validated() {
        let (a, b) = mem_endpoints(1, MuxConfig::default());
        assert!(a.open_opts(1, ChannelOptions { weight: 0, rate: None }).is_err());
        // a rejected open must not burn the id
        let tx = a.open_opts(1, ChannelOptions { weight: 4, rate: None }).unwrap();
        let rx = b.open(1).unwrap();
        tx.send(b"hi").unwrap();
        assert_eq!(rx.recv().unwrap(), b"hi");
        let stats = a.channel_stats();
        assert_eq!(stats.iter().find(|c| c.id == 1).unwrap().weight, 4);
        tx.set_weight(7).unwrap();
        assert_eq!(a.channel_stats()[0].weight, 7);
        assert!(tx.set_weight(0).is_err());
        assert!(tx.set_weight(MAX_WEIGHT + 1).is_err());
        assert!(tx.set_rate(Some(-5.0)).is_err());
        tx.set_rate(Some(1e9)).unwrap();
        tx.set_rate(None).unwrap();
        // the default-weight peer reports weight 1
        assert_eq!(b.channel_stats()[0].weight, 1);
    }

    #[test]
    fn rate_capped_channel_is_paced_and_siblings_are_not() {
        // fast unpaced mem path; channel 1 pinned to 2 MB/s, channel 2
        // free — the cap must bite without dragging the sibling down
        let (a, b) = mem_endpoints(2, MuxConfig::default());
        let rate = 2.0 * 1024.0 * 1024.0;
        let capped = a.open_opts(1, ChannelOptions { weight: 1, rate: Some(rate) }).unwrap();
        let free = a.open(2).unwrap();
        let rx_capped = b.open(1).unwrap();
        let rx_free = b.open(2).unwrap();
        let capped_msg = vec![9u8; 1 << 20]; // 1 MB at 2 MB/s ≈ 0.47 s after burst
        let big = vec![3u8; 4 << 20];
        capped.send(&capped_msg).unwrap();
        free.send(&big).unwrap();
        let t0 = Instant::now();
        assert_eq!(rx_free.recv().unwrap(), big);
        let t_free = t0.elapsed().as_secs_f64();
        assert_eq!(rx_capped.recv().unwrap(), capped_msg);
        let t_capped = t0.elapsed().as_secs_f64();
        assert!(t_capped > 0.25, "rate cap never bit: capped channel done in {t_capped}s");
        assert!(
            t_free < t_capped,
            "free channel ({t_free}s) was dragged behind the capped one ({t_capped}s)"
        );
    }

    #[test]
    fn tiny_message_turn_is_frame_bounded() {
        // A weight-1 channel fed thousands of tiny messages must not turn
        // its byte quantum into an unbounded run of wire frames: the
        // per-frame cost floor bounds one turn at FRAME_COST_DIVISOR
        // frames.
        let budget = 16 * 1024;
        let mut st = synth_state();
        for id in 0..2u32 {
            let ch = ensure_chan(&mut st, id);
            ch.locally_opened = true;
            ch.open_sent = true;
        }
        {
            let ch = st.chans.get_mut(&0).unwrap();
            for _ in 0..2000 {
                enqueue(ch, vec![1u8; 8]);
            }
        }
        {
            let ch = st.chans.get_mut(&1).unwrap();
            enqueue(ch, vec![2u8; 1 << 20]);
        }
        let mut run = 0usize;
        let mut worst = 0usize;
        for _ in 0..4000 {
            let (job, _) = pick_job(&mut st, budget, None);
            match job {
                Some(PumpJob::Chunk { id, msg, end, fin }) => {
                    complete_chunk(&mut st, id, msg, end, fin);
                    if id == 0 {
                        run += 1;
                        worst = worst.max(run);
                    } else {
                        run = 0;
                    }
                }
                Some(_) => {}
                None => break,
            }
        }
        assert!(worst > 1, "cost floor too aggressive: no tiny-message batching at all");
        assert!(
            worst <= FRAME_COST_DIVISOR,
            "tiny-message turn ran {worst} consecutive frames (bound {FRAME_COST_DIVISOR})"
        );
    }

    #[test]
    fn drr_picker_shares_follow_weights() {
        use crate::util::prop;
        // Mixed weights × message sizes × credit-gated channels: at the
        // moment the first ungated channel runs dry, every ungated
        // channel's charged cost (bytes, floored per frame) divided by
        // its weight must agree within tolerance; gated channels send
        // nothing; queue accounting and deficit bounds hold throughout.
        prop::check("drr-picker-shares", 20, |rng| {
            let budget = 8 * 1024usize;
            let frame_floor = (budget / FRAME_COST_DIVISOR).max(1) as u64;
            let backlog = 2usize << 20;
            let nch = rng.urange(2, 7);
            let mut st = synth_state();
            let weights: Vec<u32> = (0..nch).map(|_| [1u32, 2, 4, 8][rng.urange(0, 4)]).collect();
            // channel 0 is always ungated so the run terminates
            let gated: Vec<bool> = (0..nch).map(|i| i != 0 && rng.chance(0.25)).collect();
            st.peer_credit = true;
            for i in 0..nch {
                let ch = ensure_chan(&mut st, i as u32);
                ch.locally_opened = true;
                ch.open_sent = true;
                ch.weight = weights[i];
                ch.peer_grant = if gated[i] { 0 } else { u64::MAX };
                let mut left = backlog;
                let mut msgs = 0;
                while left > 0 {
                    // bounded message count: the last slot takes the rest
                    let sz = if msgs == 63 {
                        left
                    } else {
                        prop::message_size(rng, budget).clamp(1, left)
                    };
                    enqueue(ch, vec![0u8; sz]);
                    left -= sz;
                    msgs += 1;
                }
            }
            let mut cost = vec![0u64; nch];
            let mut dry = false;
            for _ in 0..200_000 {
                let (job, _) = pick_job(&mut st, budget, None);
                let Some(job) = job else { break };
                match job {
                    PumpJob::Chunk { id, msg, end, fin } => {
                        let take = (end - msg.off) as u64;
                        cost[id as usize] += take.max(frame_floor);
                        complete_chunk(&mut st, id, msg, end, fin);
                        if !gated[id as usize]
                            && st.chans.get(&id).is_some_and(|c| c.outq.is_empty())
                        {
                            dry = true;
                        }
                    }
                    PumpJob::Open(_) | PumpJob::Close(_) | PumpJob::Credit { .. } => {}
                }
                if dry {
                    break;
                }
            }
            if !dry {
                return Err("picker wedged: no ungated channel ever drained".into());
            }
            // structural invariants after the run
            for (i, w) in weights.iter().enumerate() {
                let ch = &st.chans[&(i as u32)];
                let queued: usize = ch.outq.iter().map(|m| m.data.len() - m.off).sum();
                if ch.out_bytes != queued {
                    return Err(format!("chan {i}: out_bytes {} != queued {queued}", ch.out_bytes));
                }
                let quantum = u64::from(*w) * budget as u64;
                if ch.deficit > quantum * 2 {
                    return Err(format!("chan {i}: deficit {} exceeds 2 quanta", ch.deficit));
                }
            }
            for i in 0..nch {
                if gated[i] && cost[i] != 0 {
                    return Err(format!("credit-gated chan {i} sent {} cost units", cost[i]));
                }
                if !gated[i] && cost[i] == 0 {
                    return Err(format!("ungated chan {i} starved (weights {weights:?})"));
                }
            }
            // weight-normalized shares agree across ungated channels
            let shares: Vec<f64> = (0..nch)
                .filter(|&i| !gated[i])
                .map(|i| cost[i] as f64 / f64::from(weights[i]))
                .collect();
            let lo = shares.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = shares.iter().cloned().fold(0.0, f64::max);
            if hi / lo > 1.35 {
                return Err(format!(
                    "normalized shares diverge: {shares:?} (weights {weights:?}, gated {gated:?})"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn msg_link_is_object_safe_and_uniform() {
        let (a, b) = mem_endpoints(1, MuxConfig::default());
        let tx = a.open(2).unwrap();
        let rx = b.open(2).unwrap();
        let dl: &dyn MsgLink = &tx;
        dl.send_msg(b"via trait").unwrap();
        let dr: &dyn MsgLink = &rx;
        assert_eq!(dr.recv_msg().unwrap(), b"via trait");
        let mut cache = Vec::new();
        dl.send_msg(b"cached").unwrap();
        let n = dr.recv_msg_into(&mut cache).unwrap();
        assert_eq!(&cache[..n], b"cached");
    }
}
