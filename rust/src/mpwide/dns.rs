//! `MPW_DNSResolve`: obtain an IP address locally, given a hostname.
//!
//! MPWide ships this because compute nodes of some supercomputers have no
//! working resolver, so the front-end resolves names and passes literal
//! addresses to the nodes.

use std::net::ToSocketAddrs;

use super::errors::{MpwError, Result};

/// Resolve `host` to an IPv4/IPv6 address string (first result wins, IPv4
/// preferred, matching the original's behaviour).
pub fn dns_resolve(host: &str) -> Result<String> {
    let addrs: Vec<_> = (host, 0u16)
        .to_socket_addrs()
        .map_err(|e| MpwError::Protocol(format!("cannot resolve {host}: {e}")))?
        .collect();
    addrs
        .iter()
        .find(|a| a.is_ipv4())
        .or_else(|| addrs.first())
        .map(|a| a.ip().to_string())
        .ok_or_else(|| MpwError::Protocol(format!("no address for {host}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_localhost() {
        let ip = dns_resolve("localhost").unwrap();
        assert!(ip == "127.0.0.1" || ip == "::1", "{ip}");
    }

    #[test]
    fn literal_ip_passes_through() {
        assert_eq!(dns_resolve("127.0.0.1").unwrap(), "127.0.0.1");
    }

    #[test]
    fn garbage_host_errors() {
        assert!(dns_resolve("no-such-host.invalid.").is_err());
    }
}
