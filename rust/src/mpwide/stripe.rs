//! Message striping: how a logical message is split across the parallel
//! TCP streams of a path, and into per-call chunks within each stream.
//!
//! This module is **pure** (no I/O) and is shared verbatim by the real
//! socket path ([`super::path`]) and the WAN simulator's
//! [`crate::netsim::simpath`], so the simulated experiments exercise the
//! same splitting logic as the production code.

use std::ops::Range;

/// Byte range of a message assigned to stream `i` of `nstreams`
/// (`MPW_Send` "splitted evenly over the channels").
///
/// Uses balanced contiguous slabs: the first `len % nstreams` streams get
/// one extra byte, so segment sizes differ by at most 1.
pub fn segment(len: usize, nstreams: usize, i: usize) -> Range<usize> {
    assert!(nstreams > 0, "nstreams must be >= 1");
    assert!(i < nstreams, "stream index {i} out of range {nstreams}");
    let base = len / nstreams;
    let extra = len % nstreams;
    let start = i * base + i.min(extra);
    let size = base + usize::from(i < extra);
    start..start + size
}

/// All stream segments for a message of `len` bytes.
pub fn segments(len: usize, nstreams: usize) -> Vec<Range<usize>> {
    (0..nstreams).map(|i| segment(len, nstreams, i)).collect()
}

/// Split `buf` into the `nseg` disjoint mutable per-stream segments of
/// [`segments`], in order (empty segments included, so indices line up
/// with stream positions). Shared by the socket receive path and the
/// resilient receive path so the split arithmetic cannot diverge.
pub fn split_mut(buf: &mut [u8], nseg: usize) -> Vec<&mut [u8]> {
    let segs = segments(buf.len(), nseg);
    let mut out = Vec::with_capacity(nseg);
    let mut rest = buf;
    let mut consumed = 0usize;
    for seg in segs {
        let (head, tail) = rest.split_at_mut(seg.end - consumed);
        consumed = seg.end;
        rest = tail;
        out.push(head);
    }
    out
}

/// Iterator over the chunk ranges of a single stream segment: each chunk is
/// at most `chunk_size` bytes (the unit handed to one low-level tcp call).
pub fn chunks(seg: Range<usize>, chunk_size: usize) -> impl Iterator<Item = Range<usize>> {
    assert!(chunk_size > 0, "chunk_size must be >= 1");
    let mut pos = seg.start;
    let end = seg.end;
    std::iter::from_fn(move || {
        if pos >= end {
            return None;
        }
        let next = (pos + chunk_size).min(end);
        let r = pos..next;
        pos = next;
        Some(r)
    })
}

/// Number of low-level calls needed to move `len` bytes over `nstreams`
/// streams with the given chunk size (used by the simulator and by the
/// autotuner's cost model).
pub fn call_count(len: usize, nstreams: usize, chunk_size: usize) -> usize {
    segments(len, nstreams)
        .into_iter()
        .map(|s| s.len().div_ceil(chunk_size))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_cover_exactly() {
        for len in [0usize, 1, 7, 100, 1023, 1024, 1025] {
            for n in [1usize, 2, 3, 7, 32] {
                let segs = segments(len, n);
                assert_eq!(segs.len(), n);
                // contiguous, ordered, covering 0..len
                assert_eq!(segs[0].start, 0);
                assert_eq!(segs[n - 1].end, len);
                for w in segs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn split_mut_matches_segments() {
        let mut buf: Vec<u8> = (0..=99).collect();
        let parts = split_mut(&mut buf, 3);
        assert_eq!(parts.len(), 3);
        let segs = segments(100, 3);
        for (part, seg) in parts.iter().zip(&segs) {
            assert_eq!(part.len(), seg.len());
            assert_eq!(part[0], seg.start as u8, "segment starts misaligned");
        }
        // empty segments are preserved so indices line up
        let mut tiny = [1u8, 2];
        let parts = split_mut(&mut tiny, 4);
        assert_eq!(parts.iter().map(|p| p.len()).collect::<Vec<_>>(), vec![1, 1, 0, 0]);
    }

    #[test]
    fn segments_balanced() {
        let segs = segments(10, 3);
        let sizes: Vec<usize> = segs.iter().map(|r| r.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn empty_message_gives_empty_segments() {
        for s in segments(0, 5) {
            assert!(s.is_empty());
        }
    }

    #[test]
    fn chunks_partition_segment() {
        let seg = 5..27;
        let cs: Vec<_> = chunks(seg.clone(), 8).collect();
        assert_eq!(cs, vec![5..13, 13..21, 21..27]);
    }

    #[test]
    fn chunks_empty_segment() {
        assert_eq!(chunks(3..3, 8).count(), 0);
    }

    #[test]
    fn chunk_exact_multiple() {
        let cs: Vec<_> = chunks(0..16, 8).collect();
        assert_eq!(cs, vec![0..8, 8..16]);
    }

    #[test]
    fn call_count_matches_manual() {
        // 100 bytes over 3 streams: 34+33+33; chunk 10 -> 4+4+4 = 12 calls
        assert_eq!(call_count(100, 3, 10), 12);
        assert_eq!(call_count(0, 3, 10), 0);
        assert_eq!(call_count(1, 1, 1 << 20), 1);
    }

    #[test]
    #[should_panic]
    fn segment_index_out_of_range_panics() {
        segment(10, 2, 2);
    }
}
