//! Message striping: how a logical message is split across the parallel
//! TCP streams of a path, and into per-call chunks within each stream.
//!
//! This module is **pure** (no I/O) and is shared verbatim by the real
//! socket path ([`super::path`]) and the WAN simulator's
//! [`crate::netsim::simpath`], so the simulated experiments exercise the
//! same splitting logic as the production code.

use std::ops::Range;

/// Byte range of a message assigned to stream `i` of `nstreams`
/// (`MPW_Send` "splitted evenly over the channels").
///
/// Uses balanced contiguous slabs: the first `len % nstreams` streams get
/// one extra byte, so segment sizes differ by at most 1.
pub fn segment(len: usize, nstreams: usize, i: usize) -> Range<usize> {
    assert!(nstreams > 0, "nstreams must be >= 1");
    assert!(i < nstreams, "stream index {i} out of range {nstreams}");
    let base = len / nstreams;
    let extra = len % nstreams;
    let start = i * base + i.min(extra);
    let size = base + usize::from(i < extra);
    start..start + size
}

/// All stream segments for a message of `len` bytes.
pub fn segments(len: usize, nstreams: usize) -> Vec<Range<usize>> {
    (0..nstreams).map(|i| segment(len, nstreams, i)).collect()
}

/// Split `buf` into the `nseg` disjoint mutable per-stream segments of
/// [`segments`], in order (empty segments included, so indices line up
/// with stream positions). Shared by the socket receive path and the
/// resilient receive path so the split arithmetic cannot diverge.
pub fn split_mut(buf: &mut [u8], nseg: usize) -> Vec<&mut [u8]> {
    let segs = segments(buf.len(), nseg);
    let mut out = Vec::with_capacity(nseg);
    let mut rest = buf;
    let mut consumed = 0usize;
    for seg in segs {
        let (head, tail) = rest.split_at_mut(seg.end - consumed);
        consumed = seg.end;
        rest = tail;
        out.push(head);
    }
    out
}

/// A logical message made of two borrowed parts (`head ++ tail`) that is
/// striped and chunked **without ever being concatenated**: each
/// byte-range of the logical message resolves to at most one slice of
/// each part, and the transport writes them with one vectored call.
///
/// This is the zero-copy building block of the mux hot path (an 18-byte
/// channel-frame header in front of a payload chunk) and of any other
/// header-plus-body send; a plain message is simply `head = &[]`.
#[derive(Clone, Copy)]
pub struct SplitBuf<'a> {
    /// First part of the logical message (usually a small header).
    pub head: &'a [u8],
    /// Second part (usually the payload).
    pub tail: &'a [u8],
}

impl<'a> SplitBuf<'a> {
    /// A split buffer with an empty head (plain message).
    pub fn plain(tail: &'a [u8]) -> SplitBuf<'a> {
        SplitBuf { head: &[], tail }
    }

    /// Total logical length, bytes.
    pub fn len(&self) -> usize {
        self.head.len() + self.tail.len()
    }

    /// True when both parts are empty.
    pub fn is_empty(&self) -> bool {
        self.head.is_empty() && self.tail.is_empty()
    }

    /// Resolve a byte range of the logical message to (head part, tail
    /// part) — either may be empty. Panics if the range exceeds the
    /// logical length, like slicing would.
    pub fn slice(&self, r: Range<usize>) -> (&'a [u8], &'a [u8]) {
        let h = self.head.len();
        let hs = r.start.min(h);
        let he = r.end.min(h);
        let ts = r.start.max(h) - h;
        let te = r.end.max(h) - h;
        (&self.head[hs..he], &self.tail[ts..te])
    }
}

/// Iterator over the chunk ranges of a single stream segment: each chunk is
/// at most `chunk_size` bytes (the unit handed to one low-level tcp call).
pub fn chunks(seg: Range<usize>, chunk_size: usize) -> impl Iterator<Item = Range<usize>> {
    assert!(chunk_size > 0, "chunk_size must be >= 1");
    let mut pos = seg.start;
    let end = seg.end;
    std::iter::from_fn(move || {
        if pos >= end {
            return None;
        }
        let next = (pos + chunk_size).min(end);
        let r = pos..next;
        pos = next;
        Some(r)
    })
}

/// Number of low-level calls needed to move `len` bytes over `nstreams`
/// streams with the given chunk size (used by the simulator and by the
/// autotuner's cost model).
pub fn call_count(len: usize, nstreams: usize, chunk_size: usize) -> usize {
    segments(len, nstreams)
        .into_iter()
        .map(|s| s.len().div_ceil(chunk_size))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_cover_exactly() {
        for len in [0usize, 1, 7, 100, 1023, 1024, 1025] {
            for n in [1usize, 2, 3, 7, 32] {
                let segs = segments(len, n);
                assert_eq!(segs.len(), n);
                // contiguous, ordered, covering 0..len
                assert_eq!(segs[0].start, 0);
                assert_eq!(segs[n - 1].end, len);
                for w in segs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn split_mut_matches_segments() {
        let mut buf: Vec<u8> = (0..=99).collect();
        let parts = split_mut(&mut buf, 3);
        assert_eq!(parts.len(), 3);
        let segs = segments(100, 3);
        for (part, seg) in parts.iter().zip(&segs) {
            assert_eq!(part.len(), seg.len());
            assert_eq!(part[0], seg.start as u8, "segment starts misaligned");
        }
        // empty segments are preserved so indices line up
        let mut tiny = [1u8, 2];
        let parts = split_mut(&mut tiny, 4);
        assert_eq!(parts.iter().map(|p| p.len()).collect::<Vec<_>>(), vec![1, 1, 0, 0]);
    }

    #[test]
    fn segments_balanced() {
        let segs = segments(10, 3);
        let sizes: Vec<usize> = segs.iter().map(|r| r.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn empty_message_gives_empty_segments() {
        for s in segments(0, 5) {
            assert!(s.is_empty());
        }
    }

    #[test]
    fn chunks_partition_segment() {
        let seg = 5..27;
        let cs: Vec<_> = chunks(seg.clone(), 8).collect();
        assert_eq!(cs, vec![5..13, 13..21, 21..27]);
    }

    #[test]
    fn chunks_empty_segment() {
        assert_eq!(chunks(3..3, 8).count(), 0);
    }

    #[test]
    fn chunk_exact_multiple() {
        let cs: Vec<_> = chunks(0..16, 8).collect();
        assert_eq!(cs, vec![0..8, 8..16]);
    }

    #[test]
    fn call_count_matches_manual() {
        // 100 bytes over 3 streams: 34+33+33; chunk 10 -> 4+4+4 = 12 calls
        assert_eq!(call_count(100, 3, 10), 12);
        assert_eq!(call_count(0, 3, 10), 0);
        assert_eq!(call_count(1, 1, 1 << 20), 1);
    }

    #[test]
    #[should_panic]
    fn segment_index_out_of_range_panics() {
        segment(10, 2, 2);
    }

    #[test]
    fn split_buf_slices_across_the_seam() {
        let head = [1u8, 2, 3];
        let tail = [4u8, 5, 6, 7];
        let sb = SplitBuf { head: &head, tail: &tail };
        assert_eq!(sb.len(), 7);
        assert!(!sb.is_empty());
        // entirely inside the head
        assert_eq!(sb.slice(0..2), (&head[0..2], &tail[0..0]));
        // straddling the seam
        assert_eq!(sb.slice(1..5), (&head[1..3], &tail[0..2]));
        // entirely inside the tail
        assert_eq!(sb.slice(4..7), (&head[3..3], &tail[1..4]));
        // empty range at the seam
        assert_eq!(sb.slice(3..3), (&head[3..3], &tail[0..0]));
        assert!(SplitBuf::plain(&[]).is_empty());
    }

    #[test]
    fn split_buf_reassembles_under_any_chunking() {
        let head: Vec<u8> = (0..10).collect();
        let tail: Vec<u8> = (10..64).collect();
        let sb = SplitBuf { head: &head, tail: &tail };
        for chunk in [1usize, 3, 7, 10, 11, 64, 100] {
            let mut out = Vec::new();
            for c in chunks(0..sb.len(), chunk) {
                let (h, t) = sb.slice(c);
                out.extend_from_slice(h);
                out.extend_from_slice(t);
            }
            let want: Vec<u8> = (0..64).collect();
            assert_eq!(out, want, "chunk={chunk}");
        }
    }
}
