//! Error type shared across the MPWide library.
//!
//! Display/From impls are hand-written (the `thiserror` derive crate is
//! unavailable in the offline build).

use std::fmt;

/// Errors surfaced by MPWide operations.
#[derive(Debug)]
pub enum MpwError {
    /// Underlying socket / file I/O failure.
    Io(std::io::Error),

    /// Connection could not be established within the configured timeout.
    ConnectTimeout {
        /// The `host:port` that could not be reached.
        endpoint: String,
        /// The configured timeout, seconds.
        seconds: f64,
    },

    /// A path id (or non-blocking handle id) that is not registered.
    UnknownId(i32),

    /// Handshake or wire-protocol violation.
    Protocol(String),

    /// Invalid configuration (e.g. 0 streams, oversized stream count).
    Config(String),

    /// A worker thread servicing one of the path's streams panicked.
    WorkerPanic(String),

    /// One stream of a path failed and was isolated (resilience layer).
    StreamDead {
        /// Index of the failed stream within its path.
        stream: usize,
    },

    /// Every stream of a path is dead and no rejoin arrived in time.
    AllStreamsDead,

    /// A mux channel is closed (either end) and fully drained.
    ChannelClosed {
        /// The channel id.
        channel: u32,
    },

    /// A relay/forwarder pump hit a hard stream error mid-flight; the
    /// relay was torn down. Carries the bytes moved before the failure so
    /// callers still get partial accounting.
    RelayBroken {
        /// Bytes forwarded a→b before the failure.
        a_to_b: u64,
        /// Bytes forwarded b→a before the failure.
        b_to_a: u64,
        /// Description of the underlying stream error.
        detail: String,
    },
}

impl fmt::Display for MpwError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MpwError::Io(e) => write!(f, "i/o error: {e}"),
            MpwError::ConnectTimeout { endpoint, seconds } => {
                write!(f, "connect to {endpoint} timed out after {seconds:.1}s")
            }
            MpwError::UnknownId(id) => write!(f, "unknown id {id}"),
            MpwError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            MpwError::Config(msg) => write!(f, "invalid configuration: {msg}"),
            MpwError::WorkerPanic(msg) => write!(f, "stream worker panicked: {msg}"),
            MpwError::StreamDead { stream } => {
                write!(f, "stream {stream} is dead (isolated by the resilience layer)")
            }
            MpwError::AllStreamsDead => {
                write!(f, "all streams of the path are dead and no rejoin arrived")
            }
            MpwError::ChannelClosed { channel } => {
                write!(f, "channel {channel} is closed")
            }
            MpwError::RelayBroken { a_to_b, b_to_a, detail } => write!(
                f,
                "relay broken after forwarding {a_to_b} bytes a->b / {b_to_a} bytes b->a: {detail}"
            ),
        }
    }
}

impl std::error::Error for MpwError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MpwError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for MpwError {
    fn from(e: std::io::Error) -> MpwError {
        MpwError::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, MpwError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = MpwError::UnknownId(7);
        assert_eq!(e.to_string(), "unknown id 7");
        let e = MpwError::ConnectTimeout { endpoint: "x:1".into(), seconds: 2.0 };
        assert!(e.to_string().contains("x:1"));
    }

    #[test]
    fn channel_closed_display() {
        let e = MpwError::ChannelClosed { channel: 12 };
        assert!(e.to_string().contains("channel 12"));
    }

    #[test]
    fn resilience_display_messages() {
        let e = MpwError::StreamDead { stream: 3 };
        assert!(e.to_string().contains("stream 3"));
        assert!(MpwError::AllStreamsDead.to_string().contains("all streams"));
        let e = MpwError::RelayBroken { a_to_b: 10, b_to_a: 20, detail: "boom".into() };
        let s = e.to_string();
        assert!(s.contains("10") && s.contains("20") && s.contains("boom"), "{s}");
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone");
        let e: MpwError = io.into();
        assert!(matches!(e, MpwError::Io(_)));
    }
}
