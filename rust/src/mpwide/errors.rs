//! Error type shared across the MPWide library.

use thiserror::Error;

/// Errors surfaced by MPWide operations.
#[derive(Debug, Error)]
pub enum MpwError {
    /// Underlying socket / file I/O failure.
    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),

    /// Connection could not be established within the configured timeout.
    #[error("connect to {endpoint} timed out after {seconds:.1}s")]
    ConnectTimeout { endpoint: String, seconds: f64 },

    /// A path id (or non-blocking handle id) that is not registered.
    #[error("unknown id {0}")]
    UnknownId(i32),

    /// Handshake or wire-protocol violation.
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Invalid configuration (e.g. 0 streams, oversized stream count).
    #[error("invalid configuration: {0}")]
    Config(String),

    /// A worker thread servicing one of the path's streams panicked.
    #[error("stream worker panicked: {0}")]
    WorkerPanic(String),
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, MpwError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = MpwError::UnknownId(7);
        assert_eq!(e.to_string(), "unknown id 7");
        let e = MpwError::ConnectTimeout { endpoint: "x:1".into(), seconds: 2.0 };
        assert!(e.to_string().contains("x:1"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone");
        let e: MpwError = io.into();
        assert!(matches!(e, MpwError::Io(_)));
    }
}
