//! Stream transports: real TCP sockets (the production transport, analogous
//! to the paper's `Socket` class) and an in-memory duplex used by unit
//! tests.
//!
//! A path's stream is a pair of independently lockable halves so that a
//! send and a receive can proceed concurrently on the same stream
//! (`MPW_SendRecv`), exactly as MPWide uses full-duplex TCP with one
//! pthread per direction.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::os::fd::AsRawFd;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::errors::{MpwError, Result};
use crate::util::lockorder::{rank, OrderedCondvar, OrderedMutex};

/// Magic bytes opening the per-stream handshake.
pub const HELLO_MAGIC: [u8; 4] = *b"MPW1";
/// Handshake size: magic + path uuid + stream idx + nstreams + version
/// byte + reserved.
pub const HELLO_LEN: usize = 4 + 8 + 2 + 2 + 8;
/// Protocol revision this build advertises at hello offset 16.
/// Pre-credit builds wrote the byte as reserved-zero, so version 0 means
/// a legacy peer; version 1 peers understand credit
/// (`WINDOW_UPDATE` frames and extended, credit-bearing ACKs). The
/// decoder ignores unknown *higher* versions' extra semantics — the
/// revision only ever unlocks additive behavior.
pub const HELLO_VERSION: u8 = 1;

/// One direction of a stream. Implemented by `TcpStream` (via the blanket
/// impl) and the in-memory test transport.
pub trait HalfDuplex: Send {
    /// Write the whole buffer.
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()>;
    /// Write several buffers as one logical contiguous write (frame
    /// header + payload on the mux/resilience hot path). The default
    /// falls back to sequential `write_all`s; socket transports override
    /// it with a real `writev` so the header needs no copy-assemble step
    /// and no extra syscall.
    fn write_vectored_all(&mut self, bufs: &[&[u8]]) -> std::io::Result<()> {
        for b in bufs {
            if !b.is_empty() {
                self.write_all(b)?;
            }
        }
        Ok(())
    }
    /// Read exactly `buf.len()` bytes.
    fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<()>;
    /// Read up to `buf.len()` bytes; `Ok(0)` signals end-of-stream. Used by
    /// the relay/forwarder, which must forward whatever arrives.
    fn read_some(&mut self, buf: &mut [u8]) -> std::io::Result<usize>;
    /// Flush buffered data (no-op for unbuffered transports).
    fn flush(&mut self) -> std::io::Result<()>;
}

/// Largest gather list [`write_vectored_loop`] accepts — callers pass at
/// most a frame header plus the two halves of a
/// [`SplitBuf`](super::stripe::SplitBuf), and the fixed bound keeps the
/// per-chunk hot path free of heap allocation.
pub(crate) const MAX_GATHER: usize = 8;

/// Drive `Write::write_vectored` to completion over `bufs`, restarting
/// after partial writes without copying (manual cursor instead of
/// `IoSlice::advance_slices`, which is newer than our MSRV). The slice
/// table lives on the stack — no allocation per syscall.
pub(crate) fn write_vectored_loop<W: Write>(w: &mut W, bufs: &[&[u8]]) -> std::io::Result<()> {
    assert!(bufs.len() <= MAX_GATHER, "gather list exceeds MAX_GATHER");
    let mut i = 0usize; // first unfinished buffer
    let mut off = 0usize; // bytes of bufs[i] already written
    while i < bufs.len() {
        if off >= bufs[i].len() {
            i += 1;
            off = 0;
            continue;
        }
        let mut slices: [std::io::IoSlice<'_>; MAX_GATHER] =
            std::array::from_fn(|_| std::io::IoSlice::new(&[]));
        let mut cnt = 0usize;
        slices[cnt] = std::io::IoSlice::new(&bufs[i][off..]);
        cnt += 1;
        for b in &bufs[i + 1..] {
            if !b.is_empty() {
                slices[cnt] = std::io::IoSlice::new(b);
                cnt += 1;
            }
        }
        let mut n = match w.write_vectored(&slices[..cnt]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "vectored write returned 0",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        while n > 0 && i < bufs.len() {
            let avail = bufs[i].len() - off;
            if n >= avail {
                n -= avail;
                i += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(())
}

impl HalfDuplex for TcpStream {
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        Write::write_all(self, buf)
    }
    fn write_vectored_all(&mut self, bufs: &[&[u8]]) -> std::io::Result<()> {
        write_vectored_loop(self, bufs)
    }
    fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        Read::read_exact(self, buf)
    }
    fn read_some(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        Read::read(self, buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Write::flush(self)
    }
}

/// Adapter giving any `Read + Write` object the [`HalfDuplex`] surface
/// (used by tools that wrap buffered readers/writers).
pub struct IoHalf<T: Read + Write + Send>(pub T);

impl<T: Read + Write + Send> HalfDuplex for IoHalf<T> {
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        Write::write_all(&mut self.0, buf)
    }
    fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        Read::read_exact(&mut self.0, buf)
    }
    fn read_some(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        Read::read(&mut self.0, buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Write::flush(&mut self.0)
    }
}

/// Lock-free "close this transport now" handle for one stream.
///
/// The resilience layer fires it when it isolates a failed stream: for a
/// TCP stream this is `shutdown(fd, SHUT_RDWR)` (which unblocks any
/// reader parked in `recv` on either end and makes the peer's next
/// operation fail fast), for the in-memory transport it poisons both
/// direction channels. Firing must never take the stream's tx/rx locks —
/// those may be held by the very reader the shutdown is meant to unblock.
#[derive(Clone, Default)]
pub struct KillSwitch(Option<Arc<dyn Fn() + Send + Sync>>);

impl KillSwitch {
    /// A switch that does nothing (transports with no kill support).
    pub fn none() -> KillSwitch {
        KillSwitch(None)
    }

    /// Wrap a closing action.
    pub fn new(f: impl Fn() + Send + Sync + 'static) -> KillSwitch {
        KillSwitch(Some(Arc::new(f)))
    }

    /// Force-close the underlying transport (idempotent, lock-free).
    pub fn fire(&self) {
        if let Some(f) = &self.0 {
            f();
        }
    }
}

impl std::fmt::Debug for KillSwitch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KillSwitch").field("armed", &self.0.is_some()).finish()
    }
}

/// A full-duplex stream: independently owned tx/rx halves plus transport
/// metadata. Building block handed to [`super::path::Path`].
pub struct StreamPair {
    /// Write half.
    pub tx: Box<dyn HalfDuplex>,
    /// Read half.
    pub rx: Box<dyn HalfDuplex>,
    /// Human-readable peer description (for diagnostics).
    pub peer: String,
    /// Raw fd when backed by a real socket — lets `set_window` adjust
    /// SO_SNDBUF/SO_RCVBUF after creation.
    fd: Option<i32>,
    /// Force-close handle (resilience layer failure isolation).
    kill: KillSwitch,
}

impl std::fmt::Debug for StreamPair {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamPair").field("peer", &self.peer).field("fd", &self.fd).finish()
    }
}

impl StreamPair {
    /// Wrap an established, handshaken TCP stream.
    pub fn from_tcp(stream: TcpStream) -> Result<StreamPair> {
        stream.set_nodelay(true)?;
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
        let fd = stream.as_raw_fd();
        let rx = stream.try_clone()?;
        let kill = KillSwitch::new(move || {
            shutdown_fd(fd);
        });
        Ok(StreamPair { tx: Box::new(stream), rx: Box::new(rx), peer, fd: Some(fd), kill })
    }

    /// Raw socket fd when TCP-backed (None for in-memory transports).
    pub fn raw_fd(&self) -> Option<i32> {
        self.fd
    }

    /// The stream's force-close handle.
    pub fn kill_switch(&self) -> KillSwitch {
        self.kill.clone()
    }

    /// Decompose into `(tx, rx, fd, kill)` — used when installing the
    /// pair into a path's stream slot (the metadata fields are private).
    #[allow(clippy::type_complexity)]
    pub fn into_parts(self) -> (Box<dyn HalfDuplex>, Box<dyn HalfDuplex>, Option<i32>, KillSwitch) {
        (self.tx, self.rx, self.fd, self.kill)
    }

    /// Set the TCP window (both SO_SNDBUF and SO_RCVBUF) on the underlying
    /// socket. The kernel is free to clamp the value to the site limits —
    /// the same constraint the paper notes for `MPW_setWin`. Returns the
    /// value actually granted by the kernel (doubled bookkeeping included),
    /// or `None` for non-socket transports.
    pub fn set_window(&self, bytes: usize) -> Result<Option<usize>> {
        match self.fd {
            None => Ok(None),
            Some(fd) => set_socket_window(fd, bytes),
        }
    }

    /// Apply (or clear) an `SO_SNDTIMEO`-style write deadline on the
    /// underlying socket — see
    /// [`ResilienceConfig::write_timeout`](super::config::ResilienceConfig::write_timeout).
    /// No-op on non-socket transports (the in-memory transport's writes
    /// never block on a remote peer).
    pub fn set_send_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        match self.fd {
            None => Ok(()),
            Some(fd) => set_socket_send_timeout(fd, timeout),
        }
    }
}

/// Raw `setsockopt`/`getsockopt` bindings (the `libc` crate is
/// unavailable in the offline build; these are the two calls MPWide
/// needs for `MPW_setWin`).
#[cfg(unix)]
mod sockopt {
    use std::ffi::{c_int, c_void};

    /// `socklen_t` is `u32` on every supported unix target.
    pub type SockLen = u32;

    /// Mainstream Linux ABIs use the asm-generic socket constants; the
    /// mips/sparc Linux ports kept the historical BSD-style values, as
    /// do macOS and the BSDs.
    #[cfg(all(
        target_os = "linux",
        not(any(
            target_arch = "mips",
            target_arch = "mips64",
            target_arch = "sparc",
            target_arch = "sparc64"
        ))
    ))]
    mod values {
        use std::ffi::c_int;
        pub const SOL_SOCKET: c_int = 1;
        pub const SO_SNDBUF: c_int = 7;
        pub const SO_RCVBUF: c_int = 8;
        pub const SO_SNDTIMEO: c_int = 21;
    }

    #[cfg(not(all(
        target_os = "linux",
        not(any(
            target_arch = "mips",
            target_arch = "mips64",
            target_arch = "sparc",
            target_arch = "sparc64"
        ))
    )))]
    mod values {
        use std::ffi::c_int;
        pub const SOL_SOCKET: c_int = 0xffff;
        pub const SO_SNDBUF: c_int = 0x1001;
        pub const SO_RCVBUF: c_int = 0x1002;
        pub const SO_SNDTIMEO: c_int = 0x1005;
    }

    pub use values::{SOL_SOCKET, SO_RCVBUF, SO_SNDBUF, SO_SNDTIMEO};

    /// `struct timeval` as `setsockopt(SO_SNDTIMEO)` expects it.
    /// `tv_usec` is `suseconds_t`: `int` on macOS, `long` elsewhere.
    #[cfg(target_os = "macos")]
    pub type Usec = std::ffi::c_int;
    /// See above.
    #[cfg(not(target_os = "macos"))]
    pub type Usec = std::ffi::c_long;

    /// See [`Usec`].
    #[repr(C)]
    pub struct Timeval {
        pub tv_sec: std::ffi::c_long,
        pub tv_usec: Usec,
    }

    /// `SHUT_RDWR` has value 2 on every supported platform.
    pub const SHUT_RDWR: c_int = 2;

    extern "C" {
        pub fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            value: *const c_void,
            len: SockLen,
        ) -> c_int;
        pub fn getsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            value: *mut c_void,
            len: *mut SockLen,
        ) -> c_int;
        pub fn shutdown(fd: c_int, how: c_int) -> c_int;
    }
}

/// Set SO_SNDBUF/SO_RCVBUF on a raw socket fd; returns the granted value
/// (the kernel clamps to site limits, exactly the `MPW_setWin` caveat).
#[cfg(unix)]
pub fn set_socket_window(fd: i32, bytes: usize) -> Result<Option<usize>> {
    use std::ffi::{c_int, c_void};
    let val = bytes as c_int;
    // SAFETY: fd is a valid open socket owned by the calling StreamPair /
    // Path; we pass a correctly-sized c_int for both options.
    unsafe {
        for opt in [sockopt::SO_SNDBUF, sockopt::SO_RCVBUF] {
            let rc = sockopt::setsockopt(
                fd,
                sockopt::SOL_SOCKET,
                opt,
                &val as *const c_int as *const c_void,
                std::mem::size_of::<c_int>() as sockopt::SockLen,
            );
            if rc != 0 {
                return Err(MpwError::Io(std::io::Error::last_os_error()));
            }
        }
        let mut got: c_int = 0;
        let mut len = std::mem::size_of::<c_int>() as sockopt::SockLen;
        let rc = sockopt::getsockopt(
            fd,
            sockopt::SOL_SOCKET,
            sockopt::SO_SNDBUF,
            &mut got as *mut c_int as *mut c_void,
            &mut len,
        );
        if rc != 0 {
            return Err(MpwError::Io(std::io::Error::last_os_error()));
        }
        Ok(Some(got as usize))
    }
}

/// Non-unix fallback: window tuning is unavailable; report `None` exactly
/// like the in-memory transports do.
#[cfg(not(unix))]
pub fn set_socket_window(_fd: i32, _bytes: usize) -> Result<Option<usize>> {
    Ok(None)
}

/// Set (or clear, with `None`) `SO_SNDTIMEO` on a raw socket fd: a write
/// that cannot make progress within the deadline fails with
/// `WouldBlock`/`TimedOut` instead of riding TCP's own multi-minute
/// timeout. This is the resilience layer's write-side progress watchdog
/// (the read side is covered by the ACK watchdog).
#[cfg(unix)]
pub fn set_socket_send_timeout(fd: i32, timeout: Option<Duration>) -> Result<()> {
    use std::ffi::c_void;
    // A zeroed timeval means "no timeout" to the kernel, which is
    // exactly the `None` semantics; config validation rejects an
    // explicit zero Duration for the same reason.
    let tv = match timeout {
        None => sockopt::Timeval { tv_sec: 0, tv_usec: 0 },
        Some(t) => sockopt::Timeval {
            tv_sec: t.as_secs() as std::ffi::c_long,
            tv_usec: t.subsec_micros() as sockopt::Usec,
        },
    };
    // SAFETY: fd is a valid open socket owned by the calling StreamPair /
    // Path; we pass a correctly-sized struct timeval.
    unsafe {
        let rc = sockopt::setsockopt(
            fd,
            sockopt::SOL_SOCKET,
            sockopt::SO_SNDTIMEO,
            &tv as *const sockopt::Timeval as *const c_void,
            std::mem::size_of::<sockopt::Timeval>() as sockopt::SockLen,
        );
        if rc != 0 {
            return Err(MpwError::Io(std::io::Error::last_os_error()));
        }
    }
    Ok(())
}

/// Non-unix fallback: write deadlines are unavailable; silently keep the
/// OS behaviour, exactly like the in-memory transports do.
#[cfg(not(unix))]
pub fn set_socket_send_timeout(_fd: i32, _timeout: Option<Duration>) -> Result<()> {
    Ok(())
}

/// Force both directions of a raw socket closed (`shutdown(2)`), waking
/// any reader blocked on it — on this end *and* on the peer. This is how
/// stream death propagates: whichever side detects the failure first
/// shuts the socket down, and the other side's next read/write fails
/// promptly instead of hanging. Errors are ignored (the fd may already
/// be closed).
#[cfg(unix)]
pub fn shutdown_fd(fd: i32) {
    // SAFETY: shutdown on an invalid/closed fd returns EBADF/ENOTCONN,
    // which we deliberately ignore; no memory is touched.
    unsafe {
        // swallow-ok: EBADF/ENOTCONN on an already-closed fd is the
        // expected race (see doc comment).
        let _ = sockopt::shutdown(fd, sockopt::SHUT_RDWR);
    }
}

/// Non-unix fallback: nothing to do.
#[cfg(not(unix))]
pub fn shutdown_fd(_fd: i32) {}

/// Encode the per-stream hello: which path this stream belongs to and its
/// index, so a listener can group concurrently arriving streams (possibly
/// from several clients) into complete paths.
pub fn encode_hello(path_uuid: u64, stream_idx: u16, nstreams: u16) -> [u8; HELLO_LEN] {
    let mut h = [0u8; HELLO_LEN];
    h[0..4].copy_from_slice(&HELLO_MAGIC);
    h[4..12].copy_from_slice(&path_uuid.to_be_bytes());
    h[12..14].copy_from_slice(&stream_idx.to_be_bytes());
    h[14..16].copy_from_slice(&nstreams.to_be_bytes());
    h[16] = HELLO_VERSION;
    h
}

/// Decode and validate a hello header. The fourth element is the peer's
/// protocol version (offset 16; legacy peers wrote the byte as
/// reserved-zero, so they decode as version 0).
pub fn decode_hello(h: &[u8; HELLO_LEN]) -> Result<(u64, u16, u16, u8)> {
    if h[0..4] != HELLO_MAGIC {
        return Err(MpwError::Protocol(format!("bad magic {:?}", &h[0..4])));
    }
    let uuid = u64::from_be_bytes(h[4..12].try_into().unwrap());
    let idx = u16::from_be_bytes(h[12..14].try_into().unwrap());
    let n = u16::from_be_bytes(h[14..16].try_into().unwrap());
    let version = h[16];
    if n == 0 || idx >= n {
        return Err(MpwError::Protocol(format!("bad stream index {idx}/{n}")));
    }
    Ok((uuid, idx, n, version))
}

/// Connect one TCP stream with retry until `timeout` (endpoints of a
/// distributed run start in arbitrary order, so the connecting side polls).
pub fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    connect_retry_counted(addr, timeout).0
}

/// [`connect_retry`] that also reports how many connect attempts were
/// made (diagnostics and the no-busy-spin regression tests: attempts are
/// bounded by the exponential backoff, so a short timeout cannot burn a
/// core no matter how fast each attempt fails).
pub fn connect_retry_counted(addr: &str, timeout: Duration) -> (Result<TcpStream>, u32) {
    let deadline = Instant::now() + timeout;
    let mut delay = Duration::from_millis(10);
    let mut attempts: u32 = 0;
    let timed_out = || MpwError::ConnectTimeout {
        endpoint: addr.to_string(),
        seconds: timeout.as_secs_f64(),
    };
    loop {
        attempts += 1;
        // Per-attempt connect budget: never poll past the caller's
        // deadline (a 200 ms timeout must not block 5 s in one attempt).
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return (Err(timed_out()), attempts);
        }
        let per_attempt = remaining.min(Duration::from_secs(5));
        // Re-resolve each attempt: DNS may converge while we wait.
        let attempt = addr
            .to_socket_addrs()
            .ok()
            .and_then(|mut it| it.next())
            .ok_or_else(|| MpwError::Protocol(format!("cannot resolve {addr}")));
        match attempt {
            Ok(sa) => match TcpStream::connect_timeout(&sa, per_attempt) {
                Ok(s) => return (Ok(s), attempts),
                Err(_) if Instant::now() < deadline => {}
                Err(e) => {
                    let err = if Instant::now() >= deadline {
                        timed_out()
                    } else {
                        MpwError::Io(e)
                    };
                    return (Err(err), attempts);
                }
            },
            Err(e) => {
                if Instant::now() >= deadline {
                    return (Err(e), attempts);
                }
            }
        }
        if Instant::now() >= deadline {
            return (Err(timed_out()), attempts);
        }
        // Exponential backoff between attempts: instantly-failing
        // connects (dead port, unresolvable name) must sleep, not spin.
        std::thread::sleep(delay.min(deadline.saturating_duration_since(Instant::now())));
        delay = (delay * 2).min(Duration::from_millis(500));
    }
}

// ---------------------------------------------------------------------------
// In-memory duplex transport (unit tests; no sockets, no ports).
// ---------------------------------------------------------------------------

#[derive(Default)]
struct ChanInner {
    buf: std::collections::VecDeque<u8>,
    closed: bool,
    /// Hard failure injected via [`KillSwitch`]: unlike a graceful close
    /// (reader sees EOF), a killed channel fails loudly on both ends —
    /// the in-memory analogue of a reset TCP connection.
    killed: bool,
}

// Default puts the mutex at MEM_CHAN — the leaf rank; the in-memory
// transports lock it below every library lock, including inside tx/rx
// stream guards.
#[derive(Default)]
struct Chan {
    inner: OrderedMutex<ChanInner>,
    cv: OrderedCondvar,
}

impl Chan {
    /// Poison the channel: pending and future reads/writes fail.
    fn kill(&self) {
        let mut g = self.inner.lock();
        g.killed = true;
        g.closed = true;
        self.cv.notify_all();
    }
}

/// Writer half of an in-memory channel; marks the channel closed on drop.
pub struct MemWriter(Arc<Chan>);
/// Reader half of an in-memory channel.
pub struct MemReader(Arc<Chan>);

impl Write for MemWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let mut g = self.0.inner.lock();
        if g.killed {
            return Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "channel killed"));
        }
        g.buf.extend(buf.iter());
        self.0.cv.notify_all();
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Drop for MemWriter {
    fn drop(&mut self) {
        self.0.inner.lock().closed = true;
        self.0.cv.notify_all();
    }
}

impl Read for MemReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let mut g = self.0.inner.lock();
        loop {
            if g.killed && g.buf.is_empty() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::ConnectionAborted,
                    "channel killed",
                ));
            }
            if !g.buf.is_empty() {
                let n = buf.len().min(g.buf.len());
                for (b, v) in buf.iter_mut().zip(g.buf.drain(..n)) {
                    *b = v;
                }
                return Ok(n);
            }
            if g.closed {
                return Ok(0);
            }
            g = self.0.cv.wait(g);
        }
    }
}

// Read-only / write-only halves still need the full HalfDuplex surface; the
// unused direction errors loudly rather than hanging.
struct MemTx(MemWriter);
struct MemRx(MemReader);

impl HalfDuplex for MemTx {
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        Write::write_all(&mut self.0, buf)
    }
    fn write_vectored_all(&mut self, bufs: &[&[u8]]) -> std::io::Result<()> {
        // one lock + one wakeup for the whole gather, mirroring the
        // single-syscall TCP override
        let mut g = self.0 .0.inner.lock();
        if g.killed {
            return Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "channel killed"));
        }
        for b in bufs {
            g.buf.extend(b.iter());
        }
        self.0 .0.cv.notify_all();
        Ok(())
    }
    fn read_exact(&mut self, _buf: &mut [u8]) -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "write-only half"))
    }
    fn read_some(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
        Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "write-only half"))
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl HalfDuplex for MemRx {
    fn write_all(&mut self, _buf: &[u8]) -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "read-only half"))
    }
    fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        Read::read_exact(&mut self.0, buf)
    }
    fn read_some(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        Read::read(&mut self.0, buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Create a connected pair of in-memory full-duplex streams. Used by unit
/// tests so path logic can be exercised without sockets.
pub fn mem_pair() -> (StreamPair, StreamPair) {
    let ab = Arc::new(Chan::default()); // a -> b
    let ba = Arc::new(Chan::default()); // b -> a
    let kill = {
        let (ab, ba) = (ab.clone(), ba.clone());
        KillSwitch::new(move || {
            ab.kill();
            ba.kill();
        })
    };
    let a = StreamPair {
        tx: Box::new(MemTx(MemWriter(ab.clone()))),
        rx: Box::new(MemRx(MemReader(ba.clone()))),
        peer: "mem:b".into(),
        fd: None,
        kill: kill.clone(),
    };
    let b = StreamPair {
        tx: Box::new(MemTx(MemWriter(ba))),
        rx: Box::new(MemRx(MemReader(ab))),
        peer: "mem:a".into(),
        fd: None,
        kill,
    };
    (a, b)
}

/// Create `n` connected in-memory stream pairs (one path's worth).
pub fn mem_path_pairs(n: usize) -> (Vec<StreamPair>, Vec<StreamPair>) {
    let mut left = Vec::with_capacity(n);
    let mut right = Vec::with_capacity(n);
    for _ in 0..n {
        let (a, b) = mem_pair();
        left.push(a);
        right.push(b);
    }
    (left, right)
}

/// Like [`mem_path_pairs`] but also returns each stream's [`KillSwitch`]
/// so fault-injection tests can sever individual streams mid-transfer
/// (both directions of both ends fail, like a reset TCP connection).
pub fn mem_path_pairs_killable(
    n: usize,
) -> (Vec<StreamPair>, Vec<StreamPair>, Vec<KillSwitch>) {
    let (left, right) = mem_path_pairs(n);
    let kills = left.iter().map(|p| p.kill_switch()).collect();
    (left, right, kills)
}

// ---------------------------------------------------------------------------
// Latency-injecting in-memory transport (benchmarks: high-BDP links).
// ---------------------------------------------------------------------------

#[derive(Default)]
struct DelayChanInner {
    /// Written chunks, each visible to the reader from its `ready_at`
    /// instant — a one-way propagation delay with unconstrained
    /// bandwidth (writes never block), i.e. an idealized long fat pipe.
    q: std::collections::VecDeque<(Instant, std::collections::VecDeque<u8>)>,
    closed: bool,
    killed: bool,
}

struct DelayChan {
    inner: OrderedMutex<DelayChanInner>,
    cv: OrderedCondvar,
    delay: Duration,
}

impl DelayChan {
    fn new(delay: Duration) -> DelayChan {
        DelayChan {
            inner: OrderedMutex::new(rank::MEM_CHAN, DelayChanInner::default()),
            cv: OrderedCondvar::new(),
            delay,
        }
    }

    /// Poison the channel: pending and future reads/writes fail.
    fn kill(&self) {
        let mut g = self.inner.lock();
        g.killed = true;
        g.closed = true;
        self.cv.notify_all();
    }

    fn push(&self, bufs: &[&[u8]]) -> std::io::Result<()> {
        let mut g = self.inner.lock();
        if g.killed {
            return Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "channel killed"));
        }
        let ready = Instant::now() + self.delay;
        let mut chunk = std::collections::VecDeque::new();
        for b in bufs {
            chunk.extend(b.iter());
        }
        g.q.push_back((ready, chunk));
        self.cv.notify_all();
        Ok(())
    }
}

/// Writer half of a latency-injecting channel; closes on drop.
struct DelayWriter(Arc<DelayChan>);
/// Reader half of a latency-injecting channel.
struct DelayReader(Arc<DelayChan>);

impl Drop for DelayWriter {
    fn drop(&mut self) {
        self.0.inner.lock().closed = true;
        self.0.cv.notify_all();
    }
}

impl Read for DelayReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let ch = &self.0;
        let mut g = ch.inner.lock();
        loop {
            if g.killed && g.q.is_empty() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::ConnectionAborted,
                    "channel killed",
                ));
            }
            if let Some(&(ready, _)) = g.q.front() {
                let now = Instant::now();
                if ready <= now {
                    if let Some((_, front)) = g.q.front_mut() {
                        let n = buf.len().min(front.len());
                        for (b, v) in buf.iter_mut().zip(front.drain(..n)) {
                            *b = v;
                        }
                        if front.is_empty() {
                            g.q.pop_front();
                        }
                        return Ok(n);
                    }
                    continue;
                }
                // the head chunk is still "in flight": sleep out the
                // remaining propagation delay (or an earlier wakeup)
                let (g2, _) = ch.cv.wait_timeout(g, ready - now);
                g = g2;
                continue;
            }
            if g.closed {
                return Ok(0);
            }
            g = ch.cv.wait(g);
        }
    }
}

struct DelayTx(DelayWriter);
struct DelayRx(DelayReader);

impl HalfDuplex for DelayTx {
    fn write_all(&mut self, buf: &[u8]) -> std::io::Result<()> {
        self.0 .0.push(&[buf])
    }
    fn write_vectored_all(&mut self, bufs: &[&[u8]]) -> std::io::Result<()> {
        // one lock + one delayed chunk for the whole gather
        self.0 .0.push(bufs)
    }
    fn read_exact(&mut self, _buf: &mut [u8]) -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "write-only half"))
    }
    fn read_some(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
        Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "write-only half"))
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl HalfDuplex for DelayRx {
    fn write_all(&mut self, _buf: &[u8]) -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "read-only half"))
    }
    fn read_exact(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        let mut got = 0usize;
        while got < buf.len() {
            let n = Read::read(&mut self.0, &mut buf[got..])?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "channel closed",
                ));
            }
            got += n;
        }
        Ok(())
    }
    fn read_some(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        Read::read(&mut self.0, buf)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Like [`mem_pair`] but every write becomes visible to its reader only
/// `delay` after it happened — a one-way propagation delay, so one
/// request/response rendezvous costs `2 * delay` (one RTT). Benchmarks
/// use it to model high-bandwidth-delay-product links without sockets
/// (bandwidth is unconstrained; only latency is simulated).
pub fn mem_pair_latency(delay: Duration) -> (StreamPair, StreamPair) {
    let ab = Arc::new(DelayChan::new(delay)); // a -> b
    let ba = Arc::new(DelayChan::new(delay)); // b -> a
    let kill = {
        let (ab, ba) = (ab.clone(), ba.clone());
        KillSwitch::new(move || {
            ab.kill();
            ba.kill();
        })
    };
    let a = StreamPair {
        tx: Box::new(DelayTx(DelayWriter(ab.clone()))),
        rx: Box::new(DelayRx(DelayReader(ba.clone()))),
        peer: "mem+delay:b".into(),
        fd: None,
        kill: kill.clone(),
    };
    let b = StreamPair {
        tx: Box::new(DelayTx(DelayWriter(ba))),
        rx: Box::new(DelayRx(DelayReader(ab))),
        peer: "mem+delay:a".into(),
        fd: None,
        kill,
    };
    (a, b)
}

/// Create `n` connected latency-injecting in-memory stream pairs (one
/// path's worth), each with one-way delay `delay`.
pub fn mem_path_pairs_latency(n: usize, delay: Duration) -> (Vec<StreamPair>, Vec<StreamPair>) {
    let mut left = Vec::with_capacity(n);
    let mut right = Vec::with_capacity(n);
    for _ in 0..n {
        let (a, b) = mem_pair_latency(delay);
        left.push(a);
        right.push(b);
    }
    (left, right)
}

// ---------------------------------------------------------------------------
// Path listener: groups incoming handshaken streams into complete paths.
// ---------------------------------------------------------------------------

/// Accepts TCP connections and assembles them into complete stream sets,
/// keyed by the client-generated path uuid in each stream's hello. Several
/// clients may connect concurrently (e.g. both sides of a forwarder).
pub struct RawPathListener {
    listener: TcpListener,
    /// Partially assembled paths plus the minimum protocol version seen
    /// across their hellos (every stream of a path comes from one build,
    /// but min() is the conservative merge if they ever disagree).
    pending: HashMap<u64, (Vec<Option<TcpStream>>, u8)>,
}

impl RawPathListener {
    /// Bind to `addr` (e.g. `"0.0.0.0:6000"`).
    pub fn bind(addr: &str) -> Result<RawPathListener> {
        Ok(RawPathListener { listener: TcpListener::bind(addr)?, pending: HashMap::new() })
    }

    /// The local port actually bound (useful with port 0 in tests).
    pub fn port(&self) -> u16 {
        self.listener.local_addr().map(|a| a.port()).unwrap_or(0)
    }

    /// Accept one TCP connection and read its hello header. Building
    /// block shared by [`RawPathListener::accept_streams`] (grouping
    /// fresh streams into complete paths) and the resilience layer's
    /// rejoin daemon (routing a reconnected stream back into its old
    /// slot by uuid + index).
    ///
    /// The hello read is bounded by a 10 s timeout so a client that
    /// connects and then goes silent cannot wedge the acceptor (and the
    /// rejoin daemon's stop path) forever; the socket is restored to
    /// blocking mode before being returned.
    pub fn accept_hello(&mut self) -> Result<(TcpStream, u64, u16, u16, u8)> {
        let (mut s, _) = self.listener.accept()?;
        s.set_read_timeout(Some(Duration::from_secs(10)))?;
        let mut hello = [0u8; HELLO_LEN];
        Read::read_exact(&mut s, &mut hello)?;
        s.set_read_timeout(None)?;
        let (uuid, idx, n, version) = decode_hello(&hello)?;
        Ok((s, uuid, idx, n, version))
    }

    /// Block until one complete path (all `nstreams` streams, ordered by
    /// stream index) has arrived; returns its streams, uuid, and the
    /// peer's protocol version (minimum across the path's hellos).
    pub fn accept_streams(&mut self) -> Result<(Vec<StreamPair>, u64, u8)> {
        loop {
            let (s, uuid, idx, n, version) = self.accept_hello()?;
            let entry = self.pending.entry(uuid).or_insert_with(|| {
                let mut v = Vec::with_capacity(n as usize);
                v.resize_with(n as usize, || None);
                (v, version)
            });
            entry.1 = entry.1.min(version);
            let slot = &mut entry.0;
            if slot.len() != n as usize {
                return Err(MpwError::Protocol(format!(
                    "stream count mismatch for path {uuid:#x}: {} vs {n}",
                    slot.len()
                )));
            }
            if slot[idx as usize].is_some() {
                return Err(MpwError::Protocol(format!("duplicate stream {idx} for {uuid:#x}")));
            }
            slot[idx as usize] = Some(s);
            if slot.iter().all(Option::is_some) {
                let Some((streams, peer_version)) = self.pending.remove(&uuid) else {
                    return Err(MpwError::Protocol(format!(
                        "pending stream set vanished for path {uuid:#x}"
                    )));
                };
                let pairs = streams
                    .into_iter()
                    .map(|s| match s {
                        Some(s) => StreamPair::from_tcp(s),
                        None => Err(MpwError::Protocol(format!(
                            "incomplete stream set for path {uuid:#x}"
                        ))),
                    })
                    .collect::<Result<Vec<_>>>()?;
                return Ok((pairs, uuid, peer_version));
            }
        }
    }
}

/// Connect `nstreams` handshaken TCP streams to `host:port`, all tagged
/// with a fresh path uuid. Returns the streams and the uuid (the
/// resilience layer reuses the uuid to rejoin individual streams later).
pub fn connect_streams(
    host: &str,
    port: u16,
    nstreams: usize,
    timeout: Duration,
) -> Result<(Vec<StreamPair>, u64)> {
    let addr = format!("{host}:{port}");
    let uuid = fresh_uuid();
    let mut pairs = Vec::with_capacity(nstreams);
    for i in 0..nstreams {
        // NOTE: deliberately *not* reconnect_stream — initial creation
        // has no confirmation byte (accept_streams slots the stream
        // silently); only the rejoin protocol acknowledges.
        let mut s = connect_retry(&addr, timeout)?;
        Write::write_all(&mut s, &encode_hello(uuid, i as u16, nstreams as u16))?;
        pairs.push(StreamPair::from_tcp(s)?);
    }
    Ok((pairs, uuid))
}

/// Byte the rejoin acceptor sends once it has slotted a reconnected
/// stream back into its path (before any other traffic on the socket).
pub const REJOIN_ACK: u8 = 0xA6;

/// Connect a *single* stream to `addr` and handshake it as stream `idx`
/// of the existing path `uuid` — the client half of the rejoin protocol.
/// The listener side recognises the known uuid, slots the fresh socket
/// back into the dead stream's position and confirms with a
/// [`REJOIN_ACK`] byte; only then does this side report success. Without
/// the confirmation, a connect into a listener with *no* rejoin daemon
/// (or a rejected hello) would look like a completed rejoin and flap the
/// stream between live and dead forever.
pub fn reconnect_stream(
    addr: &str,
    uuid: u64,
    idx: u16,
    nstreams: u16,
    timeout: Duration,
) -> Result<StreamPair> {
    let mut s = connect_retry(addr, timeout)?;
    Write::write_all(&mut s, &encode_hello(uuid, idx, nstreams))?;
    s.set_read_timeout(Some(timeout.max(Duration::from_millis(100))))?;
    let mut ack = [0u8; 1];
    Read::read_exact(&mut s, &mut ack)?;
    s.set_read_timeout(None)?;
    if ack[0] != REJOIN_ACK {
        return Err(MpwError::Protocol(format!("bad rejoin ack {:#04x}", ack[0])));
    }
    StreamPair::from_tcp(s)
}

/// Generate a path uuid: time + pid + counter. Uniqueness only needs to
/// hold per listener, briefly.
fn fresh_uuid() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static CTR: AtomicU64 = AtomicU64::new(0);
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let pid = std::process::id() as u64;
    t ^ (pid << 32) ^ CTR.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9E37_79B9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrip() {
        let h = encode_hello(0xDEAD_BEEF, 3, 8);
        let (uuid, idx, n, version) = decode_hello(&h).unwrap();
        assert_eq!((uuid, idx, n), (0xDEAD_BEEF, 3, 8));
        assert_eq!(version, HELLO_VERSION);
        // a legacy hello (reserved-zero byte 16) decodes as version 0
        let mut legacy = h;
        legacy[16] = 0;
        let (_, _, _, version) = decode_hello(&legacy).unwrap();
        assert_eq!(version, 0);
    }

    #[test]
    fn hello_rejects_bad_magic() {
        let mut h = encode_hello(1, 0, 1);
        h[0] = b'X';
        assert!(decode_hello(&h).is_err());
    }

    #[test]
    fn hello_rejects_bad_index() {
        let h = encode_hello(1, 5, 4);
        assert!(decode_hello(&h).is_err());
        let h = encode_hello(1, 0, 0);
        assert!(decode_hello(&h).is_err());
    }

    #[test]
    fn vectored_write_preserves_order_across_parts() {
        let (mut a, mut b) = mem_pair();
        a.tx.write_vectored_all(&[&b"head"[..], &[], &b"tail"[..]]).unwrap();
        let mut buf = [0u8; 8];
        b.rx.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"headtail");
    }

    #[test]
    fn write_vectored_loop_handles_partial_writes() {
        // A writer that accepts at most 3 bytes per call forces the loop
        // to re-anchor its cursor across buffer boundaries.
        struct Trickle(Vec<u8>);
        impl Write for Trickle {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                let n = buf.len().min(3);
                self.0.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut t = Trickle(Vec::new());
        write_vectored_loop(&mut t, &[&b"abcd"[..], &[], &b"efghij"[..]]).unwrap();
        assert_eq!(t.0, b"abcdefghij");
        // all-empty gathers are a no-op
        let mut t = Trickle(Vec::new());
        write_vectored_loop(&mut t, &[&[], &[]]).unwrap();
        assert!(t.0.is_empty());
    }

    #[test]
    fn mem_pair_roundtrip() {
        let (mut a, mut b) = mem_pair();
        a.tx.write_all(b"hello").unwrap();
        let mut buf = [0u8; 5];
        b.rx.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hello");
        // and the reverse direction
        b.tx.write_all(b"world").unwrap();
        a.rx.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"world");
    }

    #[test]
    fn mem_reader_sees_eof_on_writer_drop() {
        let (a, mut b) = mem_pair();
        drop(a);
        let mut buf = [0u8; 4];
        let n = b.rx.read_some(&mut buf).unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn mem_rx_refuses_write() {
        let (mut a, _b) = mem_pair();
        assert!(a.rx.write_all(b"x").is_err());
        assert!(a.tx.read_exact(&mut [0u8; 1]).is_err());
    }

    #[test]
    fn tcp_streams_assemble_into_path() {
        let mut listener = RawPathListener::bind("127.0.0.1:0").unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            connect_streams("127.0.0.1", port, 3, Duration::from_secs(5)).unwrap()
        });
        let (server_side, uuid, version) = listener.accept_streams().unwrap();
        let (client_side, client_uuid) = t.join().unwrap();
        assert_eq!(server_side.len(), 3);
        assert_eq!(client_side.len(), 3);
        assert_eq!(uuid, client_uuid, "both ends must agree on the path uuid");
        assert_eq!(version, HELLO_VERSION, "same-build peer advertises the current revision");
    }

    #[test]
    fn tcp_set_window_returns_granted() {
        let mut listener = RawPathListener::bind("127.0.0.1:0").unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            connect_streams("127.0.0.1", port, 1, Duration::from_secs(5)).unwrap()
        });
        let (server_side, _, _) = listener.accept_streams().unwrap();
        let (client_side, _) = t.join().unwrap();
        let granted = client_side[0].set_window(1 << 20).unwrap();
        assert!(granted.is_some());
        assert!(granted.unwrap() > 0);
        drop(server_side);
    }

    #[test]
    fn connect_retry_times_out_quickly_on_dead_port() {
        // Port 1 on localhost is almost certainly closed; refused, not hang.
        let r = connect_retry("127.0.0.1:1", Duration::from_millis(200));
        assert!(r.is_err());
    }

    #[test]
    fn connect_retry_backs_off_instead_of_spinning() {
        // Connects to a dead port fail in microseconds; without backoff a
        // 250 ms window would burn tens of thousands of attempts on one
        // core. The exponential backoff (10 ms doubling, capped) bounds
        // it to a handful.
        let t0 = Instant::now();
        let (r, attempts) = connect_retry_counted("127.0.0.1:1", Duration::from_millis(250));
        assert!(r.is_err());
        assert!(attempts <= 16, "busy-spun: {attempts} attempts in 250 ms");
        assert!(t0.elapsed() < Duration::from_secs(3), "overshot the deadline");
    }

    #[test]
    fn mem_kill_fails_both_ends() {
        let (mut a, mut b) = mem_pair();
        let kill = a.kill_switch();
        a.tx.write_all(b"pre").unwrap();
        kill.fire();
        // buffered bytes still drain, then the reader sees a hard error
        let mut pre = [0u8; 3];
        b.rx.read_exact(&mut pre).unwrap();
        assert_eq!(&pre, b"pre");
        assert!(b.rx.read_exact(&mut [0u8; 1]).is_err(), "killed reader must fail");
        assert!(a.tx.write_all(b"x").is_err(), "killed writer must fail");
        assert!(b.tx.write_all(b"x").is_err(), "kill severs both directions");
    }

    #[test]
    fn mem_kill_wakes_blocked_reader() {
        let (a, mut b) = mem_pair();
        let kill = a.kill_switch();
        let t = std::thread::spawn(move || b.rx.read_exact(&mut [0u8; 8]));
        std::thread::sleep(Duration::from_millis(20));
        kill.fire();
        let r = t.join().unwrap();
        assert!(r.is_err(), "blocked reader must be woken with an error");
        drop(a);
    }

    #[test]
    fn reconnect_stream_requires_acceptor_confirmation() {
        let mut listener = RawPathListener::bind("127.0.0.1:0").unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            reconnect_stream(
                &format!("127.0.0.1:{port}"),
                0xABCD,
                1,
                4,
                Duration::from_secs(5),
            )
            .unwrap()
        });
        let (mut s, uuid, idx, n, _version) = listener.accept_hello().unwrap();
        assert_eq!((uuid, idx, n), (0xABCD, 1, 4));
        Write::write_all(&mut s, &[REJOIN_ACK]).unwrap();
        let _ = t.join().unwrap();
        drop(s);

        // an unconfirmed reconnect (acceptor closes without the ack byte)
        // must report failure, not a phantom rejoin
        let t = std::thread::spawn(move || {
            reconnect_stream(&format!("127.0.0.1:{port}"), 0xABCD, 1, 4, Duration::from_secs(5))
        });
        let (s2, _, _, _, _) = listener.accept_hello().unwrap();
        drop(s2);
        assert!(t.join().unwrap().is_err());
    }

    #[test]
    fn interleaved_clients_get_separate_paths() {
        let mut listener = RawPathListener::bind("127.0.0.1:0").unwrap();
        let port = listener.port();
        let t1 = std::thread::spawn(move || {
            connect_streams("127.0.0.1", port, 2, Duration::from_secs(5)).unwrap()
        });
        let t2 = std::thread::spawn(move || {
            connect_streams("127.0.0.1", port, 2, Duration::from_secs(5)).unwrap()
        });
        let (p1, u1, _) = listener.accept_streams().unwrap();
        let (p2, u2, _) = listener.accept_streams().unwrap();
        assert_ne!(u1, u2);
        assert_eq!(p1.len(), 2);
        assert_eq!(p2.len(), 2);
        t1.join().unwrap();
        t2.join().unwrap();
    }
}
