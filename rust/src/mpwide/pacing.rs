//! Software communication pacing (`MPW_setPacingRate`).
//!
//! MPWide lets the user cap the throughput of each individual stream from
//! user space — useful on shared links where a distributed run must not
//! starve other traffic, and to keep many parallel streams from tripping
//! over each other's congestion response. Implemented as a token bucket:
//! each stream accumulates budget at `rate` bytes/second (up to one
//! `burst` of headroom) and sleeps when a chunk would exceed it.

use std::time::{Duration, Instant};

/// Token-bucket pacer for a single stream.
#[derive(Debug)]
pub struct Pacer {
    rate: Option<f64>, // bytes per second; None = unlimited
    tokens: f64,
    burst: f64,
    last: Instant,
}

impl Pacer {
    /// Create a pacer. `rate` is bytes/second per stream; `None` disables
    /// pacing entirely (zero overhead on the hot path).
    pub fn new(rate: Option<f64>) -> Self {
        let burst = rate.map_or(f64::INFINITY, |r| (r * 0.01).max(64.0 * 1024.0));
        Pacer { rate, tokens: burst, burst, last: Instant::now() }
    }

    /// Change the pacing rate at runtime.
    pub fn set_rate(&mut self, rate: Option<f64>) {
        self.rate = rate;
        self.burst = rate.map_or(f64::INFINITY, |r| (r * 0.01).max(64.0 * 1024.0));
        self.tokens = self.tokens.min(self.burst);
        self.last = Instant::now();
    }

    /// Current rate (bytes/second), if pacing is enabled.
    pub fn rate(&self) -> Option<f64> {
        self.rate
    }

    /// Block until `bytes` may be sent without exceeding the pacing rate.
    pub fn acquire(&mut self, bytes: usize) {
        let Some(rate) = self.rate else { return };
        let now = Instant::now();
        self.tokens = (self.tokens + now.duration_since(self.last).as_secs_f64() * rate)
            .min(self.burst);
        self.last = now;
        if self.tokens >= bytes as f64 {
            self.tokens -= bytes as f64;
            return;
        }
        let deficit = bytes as f64 - self.tokens;
        let wait = deficit / rate;
        std::thread::sleep(Duration::from_secs_f64(wait));
        self.tokens = 0.0;
        self.last = Instant::now();
    }

    /// Pure helper for the simulator: time (seconds) a paced stream needs
    /// to emit `bytes`, ignoring the initial burst allowance.
    pub fn ideal_duration(rate: Option<f64>, bytes: usize) -> f64 {
        match rate {
            None => 0.0,
            Some(r) => bytes as f64 / r,
        }
    }
}

/// Floor for adaptively-chosen per-stream pacing rates: the online
/// controller never paces a stream below this, so a transiently bad
/// goodput estimate cannot wedge a path at a crawl.
pub const MIN_ADAPTIVE_RATE: f64 = 1024.0 * 1024.0; // 1 MB/s

/// Split a path-level pacing budget (bytes/second) across `active`
/// streams, clamped to [`MIN_ADAPTIVE_RATE`]. Used by the
/// [`adapt`](super::adapt) controller when it re-paces a live path.
pub fn per_stream_rate(total: f64, active: usize) -> f64 {
    (total / active.max(1) as f64).max(MIN_ADAPTIVE_RATE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_pacer_never_blocks() {
        let mut p = Pacer::new(None);
        let t0 = Instant::now();
        for _ in 0..1000 {
            p.acquire(1 << 20);
        }
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn paced_stream_respects_rate() {
        // 10 MB/s, send 2 MB beyond the burst: should take ~>=0.15s.
        let rate = 10.0 * 1024.0 * 1024.0;
        let mut p = Pacer::new(Some(rate));
        let total = 2 * 1024 * 1024;
        let t0 = Instant::now();
        let mut sent = 0;
        while sent < total {
            p.acquire(64 * 1024);
            sent += 64 * 1024;
        }
        let dt = t0.elapsed().as_secs_f64();
        // burst allowance is max(1% of rate, 64KB) ≈ 105KB, so ~1.9MB paced
        let min_expected = (total as f64 - 0.02 * rate - 128.0 * 1024.0) / rate;
        assert!(dt >= min_expected, "dt={dt} expected >= {min_expected}");
        // and not absurdly slow (allow 3x for scheduler noise)
        assert!(dt < 3.0 * total as f64 / rate + 0.2, "dt={dt}");
    }

    #[test]
    fn set_rate_updates() {
        let mut p = Pacer::new(Some(1e6));
        assert_eq!(p.rate(), Some(1e6));
        p.set_rate(None);
        assert_eq!(p.rate(), None);
        let t0 = Instant::now();
        p.acquire(100 << 20);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn ideal_duration_math() {
        assert_eq!(Pacer::ideal_duration(None, 1000), 0.0);
        assert!((Pacer::ideal_duration(Some(1000.0), 500) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn per_stream_rate_splits_and_floors() {
        assert_eq!(per_stream_rate(32.0 * MIN_ADAPTIVE_RATE, 4), 8.0 * MIN_ADAPTIVE_RATE);
        // floor binds for tiny budgets and is safe for active = 0
        assert_eq!(per_stream_rate(1.0, 16), MIN_ADAPTIVE_RATE);
        assert_eq!(per_stream_rate(5.0 * MIN_ADAPTIVE_RATE, 0), 5.0 * MIN_ADAPTIVE_RATE);
    }
}
