//! Software communication pacing (`MPW_setPacingRate`).
//!
//! MPWide lets the user cap the throughput of each individual stream from
//! user space — useful on shared links where a distributed run must not
//! starve other traffic, and to keep many parallel streams from tripping
//! over each other's congestion response. Implemented as a token bucket:
//! each stream accumulates budget at `rate` bytes/second (up to one
//! `burst` of headroom) and sleeps when a chunk would exceed it.

use std::time::{Duration, Instant};

/// Token-bucket pacer for a single stream.
#[derive(Debug)]
pub struct Pacer {
    rate: Option<f64>, // bytes per second; None = unlimited
    tokens: f64,
    burst: f64,
    last: Instant,
}

impl Pacer {
    /// Create a pacer. `rate` is bytes/second per stream; `None` disables
    /// pacing entirely (zero overhead on the hot path).
    pub fn new(rate: Option<f64>) -> Self {
        let burst = rate.map_or(f64::INFINITY, |r| (r * 0.01).max(64.0 * 1024.0));
        Pacer { rate, tokens: burst, burst, last: Instant::now() }
    }

    /// Change the pacing rate at runtime.
    pub fn set_rate(&mut self, rate: Option<f64>) {
        self.rate = rate;
        self.burst = rate.map_or(f64::INFINITY, |r| (r * 0.01).max(64.0 * 1024.0));
        self.tokens = self.tokens.min(self.burst);
        self.last = Instant::now();
    }

    /// Current rate (bytes/second), if pacing is enabled.
    pub fn rate(&self) -> Option<f64> {
        self.rate
    }

    /// Refill the bucket from wall-clock time elapsed since the last
    /// refill, clamped to the burst allowance.
    fn refill(&mut self, rate: f64) {
        let now = Instant::now();
        self.tokens = (self.tokens + now.duration_since(self.last).as_secs_f64() * rate)
            .min(self.burst);
        self.last = now;
    }

    /// Block until `bytes` may be sent without exceeding the pacing rate.
    pub fn acquire(&mut self, bytes: usize) {
        let Some(rate) = self.rate else { return };
        self.refill(rate);
        if self.tokens >= bytes as f64 {
            self.tokens -= bytes as f64;
            return;
        }
        let deficit = bytes as f64 - self.tokens;
        let wait = deficit / rate;
        let parked = Instant::now();
        std::thread::sleep(Duration::from_secs_f64(wait));
        let end = Instant::now();
        let slept = end.duration_since(parked).as_secs_f64();
        self.tokens = Self::settle_after_sleep(self.tokens, bytes as f64, slept, rate, self.burst);
        self.last = end;
    }

    /// Post-sleep token accounting, factored out so the oversleep case is
    /// unit-testable: budget accrues from the sleep that *actually
    /// happened* (`slept` seconds), not from the deficit we asked for.
    /// On coarse-timer hosts the OS routinely oversleeps, and discarding
    /// that accrual makes the long-run achieved rate systematically
    /// undershoot the configured rate.
    fn settle_after_sleep(tokens: f64, bytes: f64, slept: f64, rate: f64, burst: f64) -> f64 {
        (tokens + slept * rate - bytes).min(burst)
    }

    /// Non-blocking variant of [`acquire`](Self::acquire) for callers
    /// that must not sleep (the mux pump holds shared scheduler state):
    /// debit the bucket and return `None` when `bytes` are admitted now,
    /// otherwise leave the bucket untouched and return how long until
    /// enough tokens will have accrued. Callers park on their own
    /// condvar with that duration as the timeout and retry.
    pub fn try_acquire(&mut self, bytes: usize) -> Option<Duration> {
        let Some(rate) = self.rate else { return None };
        self.refill(rate);
        if self.tokens >= bytes as f64 {
            self.tokens -= bytes as f64;
            return None;
        }
        let deficit = bytes as f64 - self.tokens;
        Some(Duration::from_secs_f64(deficit / rate))
    }

    /// Pure helper for the simulator: time (seconds) a paced stream needs
    /// to emit `bytes`, ignoring the initial burst allowance.
    pub fn ideal_duration(rate: Option<f64>, bytes: usize) -> f64 {
        match rate {
            None => 0.0,
            Some(r) => bytes as f64 / r,
        }
    }
}

/// Floor for adaptively-chosen per-stream pacing rates: the online
/// controller never paces a stream below this, so a transiently bad
/// goodput estimate cannot wedge a path at a crawl.
pub const MIN_ADAPTIVE_RATE: f64 = 1024.0 * 1024.0; // 1 MB/s

/// Split a path-level pacing budget (bytes/second) across `active`
/// streams. Used by the [`adapt`](super::adapt) controller when it
/// re-paces a live path.
///
/// The aggregate never exceeds `max(total, MIN_ADAPTIVE_RATE)`: when the
/// fair share `total / active` would fall below [`MIN_ADAPTIVE_RATE`],
/// the floor is applied to the *path* budget and then split — not to
/// each stream individually, which would let `active` streams exceed the
/// user's cap by up to `active ×` in aggregate. The never-wedge intent
/// is preserved: a transiently bad goodput estimate can pace the path
/// down to a 1 MB/s aggregate, never to a crawl.
pub fn per_stream_rate(total: f64, active: usize) -> f64 {
    total.max(MIN_ADAPTIVE_RATE) / active.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_pacer_never_blocks() {
        let mut p = Pacer::new(None);
        let t0 = Instant::now();
        for _ in 0..1000 {
            p.acquire(1 << 20);
        }
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn paced_stream_respects_rate() {
        // 10 MB/s, send 2 MB beyond the burst: should take ~>=0.15s.
        let rate = 10.0 * 1024.0 * 1024.0;
        let mut p = Pacer::new(Some(rate));
        let total = 2 * 1024 * 1024;
        let t0 = Instant::now();
        let mut sent = 0;
        while sent < total {
            p.acquire(64 * 1024);
            sent += 64 * 1024;
        }
        let dt = t0.elapsed().as_secs_f64();
        // burst allowance is max(1% of rate, 64KB) ≈ 105KB, so ~1.9MB paced
        let min_expected = (total as f64 - 0.02 * rate - 128.0 * 1024.0) / rate;
        assert!(dt >= min_expected, "dt={dt} expected >= {min_expected}");
        // and not absurdly slow (allow 3x for scheduler noise)
        assert!(dt < 3.0 * total as f64 / rate + 0.2, "dt={dt}");
    }

    #[test]
    fn set_rate_updates() {
        let mut p = Pacer::new(Some(1e6));
        assert_eq!(p.rate(), Some(1e6));
        p.set_rate(None);
        assert_eq!(p.rate(), None);
        let t0 = Instant::now();
        p.acquire(100 << 20);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn ideal_duration_math() {
        assert_eq!(Pacer::ideal_duration(None, 1000), 0.0);
        assert!((Pacer::ideal_duration(Some(1000.0), 500) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn per_stream_rate_splits_and_floors() {
        assert_eq!(per_stream_rate(32.0 * MIN_ADAPTIVE_RATE, 4), 8.0 * MIN_ADAPTIVE_RATE);
        // when the budget binds, the floor applies to the path and is
        // split — each of 16 streams gets 1/16 of MIN_ADAPTIVE_RATE, not
        // a full MIN_ADAPTIVE_RATE each
        assert_eq!(per_stream_rate(1.0, 16), MIN_ADAPTIVE_RATE / 16.0);
        // safe for active = 0
        assert_eq!(per_stream_rate(5.0 * MIN_ADAPTIVE_RATE, 0), 5.0 * MIN_ADAPTIVE_RATE);
    }

    #[test]
    fn per_stream_rate_never_exceeds_aggregate_cap() {
        // regression: the old floor was per stream, so a 2 MB/s budget
        // over 8 streams yielded 8 MB/s aggregate — 4x the user's cap
        for &active in &[1usize, 2, 8, 64] {
            for &total in &[0.5, 1.0, 2.0, 7.5] {
                let budget = total * MIN_ADAPTIVE_RATE;
                let aggregate = per_stream_rate(budget, active) * active as f64;
                let cap = budget.max(MIN_ADAPTIVE_RATE);
                assert!(
                    aggregate <= cap * (1.0 + 1e-9),
                    "active={active} budget={budget}: aggregate {aggregate} > cap {cap}"
                );
            }
        }
    }

    #[test]
    fn oversleep_budget_is_retained() {
        // regression for the acquire() tail: tokens after the sleep must
        // reflect the sleep that actually happened, not be zeroed. At
        // 1 MB/s with an empty bucket, acquiring 100_000 bytes asks for a
        // 0.1 s sleep; if the OS delivers 0.12 s, the extra 0.02 s is
        // 20_000 bytes of budget the next acquire must see.
        let rate = 1_000_000.0;
        let burst = 64.0 * 1024.0;
        let t = Pacer::settle_after_sleep(0.0, 100_000.0, 0.12, rate, burst);
        assert!((t - 20_000.0).abs() < 1e-6, "retained {t}, want 20000");
        // a wild oversleep is clamped to the burst allowance
        let t = Pacer::settle_after_sleep(0.0, 100_000.0, 0.25, rate, burst);
        assert!((t - burst).abs() < 1e-6, "retained {t}, want burst {burst}");
        // an exact sleep leaves nothing over (the only case the old
        // zero-the-bucket code got right)
        let t = Pacer::settle_after_sleep(25_000.0, 100_000.0, 0.075, rate, burst);
        assert!(t.abs() < 1e-6, "retained {t}, want 0");
        // an early wakeup leaves the bucket in debt rather than minting
        // budget that was never accrued
        let t = Pacer::settle_after_sleep(0.0, 100_000.0, 0.05, rate, burst);
        assert!((t + 50_000.0).abs() < 1e-6, "retained {t}, want -50000");
    }

    #[test]
    fn try_acquire_admits_and_gates_without_sleeping() {
        let rate = 1_000_000.0;
        let mut p = Pacer::new(Some(rate));
        // the initial burst (max(1% rate, 64 KiB) = 64 KiB) admits freely
        assert_eq!(p.try_acquire(32 * 1024), None);
        assert_eq!(p.try_acquire(32 * 1024), None);
        // the bucket is now ~empty: a large ask is gated, never slept,
        // and the hint approximates deficit / rate
        let t0 = Instant::now();
        let wait = p.try_acquire(500_000);
        assert!(t0.elapsed() < Duration::from_millis(50), "try_acquire slept");
        let wait = match wait {
            Some(w) => w.as_secs_f64(),
            None => panic!("empty bucket admitted 500 KB"),
        };
        assert!(wait > 0.3 && wait < 0.6, "wait hint {wait}");
        // a gated ask must not debit the bucket: a small ask after real
        // accrual still succeeds
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(p.try_acquire(8 * 1024), None);
        // unlimited pacers never gate
        let mut free = Pacer::new(None);
        assert_eq!(free.try_acquire(usize::MAX / 2), None);
    }
}
