//! Path configuration: the user-tunable performance parameters the paper
//! exposes (§1.3.1) — stream count, chunk size, pacing rate, TCP window
//! size, and the autotuning switch (enabled by default) — plus the
//! runtime-adaptation settings ([`AdaptConfig`]) layered on top by this
//! reproduction.

use std::time::Duration;

use super::adapt::AdaptConfig;

/// How (and whether) a path's client end re-establishes dead streams.
///
/// The accepting end is passive: its listener's rejoin daemon recognises
/// the original path uuid + stream index in the reconnect handshake and
/// slots the fresh socket back into the dead stream's position. This
/// policy drives the *connecting* end's background reconnect monitor.
#[derive(Debug, Clone, PartialEq)]
pub struct ReconnectPolicy {
    /// Reconnect dead streams in the background (off by default: the
    /// paper's MPWide treats stream errors as fatal, and rejoin needs a
    /// rejoin daemon on the accepting end).
    pub enabled: bool,
    /// Give up on a stream after this many consecutive failed reconnect
    /// attempts (0 = never give up).
    pub max_attempts: u32,
    /// Backoff floor between reconnect attempts.
    pub base_delay: Duration,
    /// Backoff ceiling (delay doubles from `base_delay` up to this).
    pub max_delay: Duration,
    /// Per-attempt connect timeout.
    pub connect_timeout: Duration,
    /// How long a send/recv with *zero* live streams waits for a rejoin
    /// before failing with `AllStreamsDead`. `ZERO` is allowed and means
    /// "fail immediately" — background rejoin of *partially* degraded
    /// paths still works.
    pub rejoin_wait: Duration,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy {
            enabled: false,
            max_attempts: 0,
            base_delay: Duration::from_millis(50),
            max_delay: Duration::from_secs(5),
            connect_timeout: Duration::from_secs(5),
            rejoin_wait: Duration::from_secs(30),
        }
    }
}

/// Fault-tolerance settings for a path (the `mpwide::resilience` layer).
///
/// # Examples
///
/// A windowed resilient path over the in-memory transport — the sends
/// post into the in-flight window instead of waiting one RTT each, and
/// the flush confirms delivery of all of them:
///
/// ```
/// use mpwide::mpwide::{Path, PathConfig};
/// # use mpwide::mpwide::transport::mem_path_pairs;
/// let mut cfg = PathConfig::with_streams(2);
/// cfg.autotune = false;
/// cfg.resilience.enabled = true;
/// cfg.resilience.window = 4; // pipeline up to 4 unacknowledged sends
/// let (l, r) = mem_path_pairs(2);
/// let a = Path::from_pairs(l, cfg.clone()).unwrap();
/// let b = Path::from_pairs(r, cfg).unwrap();
/// let t = std::thread::spawn(move || {
///     let mut buf = vec![0u8; 1000];
///     for _ in 0..3 {
///         b.recv(&mut buf).unwrap();
///     }
/// });
/// for _ in 0..3 {
///     a.send(&[5u8; 1000]).unwrap(); // posted, not yet acknowledged
/// }
/// a.flush().unwrap(); // every posted message is now confirmed delivered
/// t.join().unwrap();
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceConfig {
    /// Frame every message so single-stream failures are detected and
    /// isolated, with the in-flight message retried over the surviving
    /// streams. Off by default: the framed protocol changes the wire
    /// format (both ends must agree) and adds a per-message delivery
    /// acknowledgement.
    pub enabled: bool,
    /// Progress timeout on the resilient **sender's ACK wait**: if the
    /// receiver's delivery acknowledgement has not arrived within this
    /// budget, the current control stream is force-closed and the send
    /// retries over the survivors. This closes the documented
    /// control-stream divergence window (a rejoin half-completing
    /// exactly as the control stream dies could leave the two ends
    /// waiting on different streams until TCP gave up). `None` disables
    /// the watchdog (the pre-timeout behaviour). When set, it must
    /// comfortably exceed the worst-case time for one whole message to
    /// be *consumed* by the peer — with `window == 1`, resilient sends
    /// are rendezvous sends, so the budget covers the peer's
    /// compute/scheduling delay before its matching `recv`, not just
    /// wire time; with `window > 1` the watchdog guards progress on the
    /// *oldest unacknowledged* message and is re-armed every time that
    /// message advances. Couplings with unbounded gaps between
    /// exchanges should leave this `None`.
    pub ack_timeout: Option<Duration>,
    /// Maximum number of resilient messages in flight (posted but not
    /// yet acknowledged) before a send blocks reaping ACKs. `1` (the
    /// default) preserves the classic rendezvous semantics: every send
    /// returns only after the peer has consumed the message, exactly
    /// like MPWide's paired send/recv. Values `> 1` pipeline sends —
    /// `Path::send` may return as soon as the message is written and
    /// *posted*, with delivery confirmed asynchronously as later sends
    /// reap ACKs (a delivery failure then surfaces on a later send,
    /// [`Path::flush`](super::path::Path::flush),
    /// [`Path::barrier`](super::path::Path::barrier), or close). On a
    /// high-bandwidth-delay-product link this removes the
    /// one-round-trip-per-message goodput cap. The wire format is
    /// unchanged — the window is a sender-side discipline, so the two
    /// ends may use different window sizes.
    pub window: usize,
    /// Deadline on individual **segment writes** (`SO_SNDTIMEO`-style):
    /// a resilient sender stalled by TCP backpressure — e.g. the peer
    /// died without resetting the connection, or the path diverged
    /// mid-rejoin — fails the write after this budget instead of riding
    /// the kernel's own (minutes-long) timeout, letting the resilience
    /// layer mark the stream dead and retry over the survivors. `None`
    /// (default) keeps the OS behaviour. Only effective on socket-backed
    /// streams; the in-memory test transport ignores it.
    pub write_timeout: Option<Duration>,
    /// Byte high-water for the windowed receiver's reorder stash. With a
    /// window `> 1` an out-of-order message is stashed until the gap
    /// fills; the stash is already capped at `MAX_WINDOW` *messages*, but
    /// 64 stashed multi-MB messages can still exhaust memory. When set,
    /// the receiver (a) refuses to stash past this many bytes — the
    /// sender sees a retryable NACK and backs off without marking the
    /// stream dead — and (b) advertises the remaining byte budget to a
    /// credit-aware peer (hello version >= 1) in every ACK and in
    /// dedicated WINDOW_UPDATE frames, so a well-behaved sender never
    /// hits the hard limit at all. A single message larger than the
    /// budget is still accepted when the stash is empty (it can always
    /// be delivered), so this cannot deadlock. `None` (default) keeps
    /// the message-count bound only.
    pub recv_stash_high_water: Option<usize>,
    /// Background reconnection of dead streams (connecting end only).
    pub reconnect: ReconnectPolicy,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            enabled: false,
            ack_timeout: None,
            window: 1,
            write_timeout: None,
            recv_stash_high_water: None,
            reconnect: ReconnectPolicy::default(),
        }
    }
}

impl ResilienceConfig {
    /// Resilient framing on, background rejoin on, ACK progress watchdog
    /// armed at 10 minutes (WAN production preset). The generous budget
    /// tolerates real coupling imbalance — a peer minutes late to its
    /// exchange point is normal, a sender parked for tens of minutes is
    /// the divergence hang the watchdog exists to break. Tighten it for
    /// latency-bound deployments; set `ack_timeout: None` for couplings
    /// whose inter-exchange gaps are genuinely unbounded.
    pub fn wan() -> Self {
        ResilienceConfig {
            enabled: true,
            ack_timeout: Some(Duration::from_secs(600)),
            window: 8,
            write_timeout: None,
            // 256 MiB: generous for WAN BDPs, small next to a cluster
            // node's memory; bounds a slow consumer's stash growth.
            recv_stash_high_water: Some(256 << 20),
            reconnect: ReconnectPolicy { enabled: true, ..Default::default() },
        }
    }

    /// Validate the resilience parameters.
    pub fn validate(&self) -> crate::mpwide::Result<()> {
        if let Some(t) = self.ack_timeout {
            if t.is_zero() {
                // a zero budget would kill the control stream before the
                // receiver could possibly consume anything
                return Err(crate::mpwide::MpwError::Config(
                    "resilience ack_timeout must be positive".into(),
                ));
            }
        }
        if self.window == 0 {
            // a zero window can never post anything: every send would
            // deadlock waiting for space that cannot open up
            return Err(crate::mpwide::MpwError::Config(
                "resilience window must be >= 1".into(),
            ));
        }
        if self.window > super::resilience::MAX_WINDOW {
            // the receiver bounds its reorder stash (and rejects CTRL
            // sequences) by MAX_WINDOW — a wider sender would be
            // treated as a protocol violation by its peer
            return Err(crate::mpwide::MpwError::Config(format!(
                "resilience window {} exceeds MAX_WINDOW ({})",
                self.window,
                super::resilience::MAX_WINDOW
            )));
        }
        if let Some(t) = self.write_timeout {
            if t.is_zero() {
                // SO_SNDTIMEO of zero means "block forever" to the
                // kernel — the opposite of what the caller asked for
                return Err(crate::mpwide::MpwError::Config(
                    "resilience write_timeout must be positive".into(),
                ));
            }
        }
        if self.recv_stash_high_water == Some(0) {
            // a zero byte budget would advertise zero credit forever;
            // "no byte bound" is spelled None, not 0
            return Err(crate::mpwide::MpwError::Config(
                "resilience recv_stash_high_water must be positive (use None to disable)".into(),
            ));
        }
        let r = &self.reconnect;
        if r.base_delay > r.max_delay {
            return Err(crate::mpwide::MpwError::Config(format!(
                "reconnect base_delay {:?} exceeds max_delay {:?}",
                r.base_delay, r.max_delay
            )));
        }
        if r.base_delay.is_zero() {
            // a zero base never grows (0 * 2 = 0): the monitor would open
            // connects as fast as the wakeup floor allows, forever
            return Err(crate::mpwide::MpwError::Config(
                "reconnect base_delay must be positive".into(),
            ));
        }
        if r.enabled && r.connect_timeout.is_zero() {
            // connect_retry with a zero deadline fails on entry: every
            // redial would fail instantly and no stream could ever rejoin
            return Err(crate::mpwide::MpwError::Config(
                "reconnect connect_timeout must be positive".into(),
            ));
        }
        if r.enabled && !self.enabled {
            // stream death is only ever *detected* by the resilient
            // framing layer; a reconnect monitor without it would idle
            // forever while stream errors stay fatal — silently inert
            // fault tolerance is worse than an upfront error
            return Err(crate::mpwide::MpwError::Config(
                "reconnect requires resilience.enabled (failure detection lives there)".into(),
            ));
        }
        Ok(())
    }
}

/// Maximum number of TCP streams per path. The paper reports efficient
/// operation with up to 256 streams in a single path.
pub const MAX_STREAMS: usize = 256;

/// Default chunk size: the amount of data handed to each low-level tcp
/// send/recv call (`MPW_setChunkSize`).
pub const DEFAULT_CHUNK: usize = 1 << 20; // 1 MiB

/// Configuration for a single communication path.
#[derive(Debug, Clone)]
pub struct PathConfig {
    /// Number of parallel TCP streams (always user-provided per the paper;
    /// recommended: 1 locally, ≥32 over long-distance networks).
    pub nstreams: usize,
    /// Bytes sent/received per low-level call (`MPW_setChunkSize`).
    pub chunk_size: usize,
    /// Software pacing rate per stream, bytes/second
    /// (`MPW_setPacingRate`). `None` disables pacing.
    pub pacing_rate: Option<f64>,
    /// Requested TCP window (SO_SNDBUF/SO_RCVBUF), bytes (`MPW_setWin`).
    /// `None` keeps the OS default; the effective value is constrained by
    /// the site configuration, exactly as the paper notes.
    pub tcp_window: Option<usize>,
    /// Autotune chunk size / window at path creation (`MPW_setAutoTuning`;
    /// default enabled per the paper).
    pub autotune: bool,
    /// How long `Path::connect` keeps retrying before giving up (endpoints
    /// of a distributed run start in arbitrary order).
    pub connect_timeout: Duration,
    /// Runtime adaptation (live restriping / re-chunking / re-pacing).
    /// Defaults to [`TuneMode::Static`](super::adapt::TuneMode::Static),
    /// i.e. the paper's creation-time-only behaviour.
    pub adapt: AdaptConfig,
    /// Fault tolerance: per-stream failure isolation, degraded-mode
    /// striping and background stream rejoin. Defaults to disabled (the
    /// paper's stream-error-is-fatal behaviour).
    pub resilience: ResilienceConfig,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            nstreams: 1,
            chunk_size: DEFAULT_CHUNK,
            pacing_rate: None,
            tcp_window: None,
            autotune: true,
            connect_timeout: Duration::from_secs(30),
            adapt: AdaptConfig::default(),
            resilience: ResilienceConfig::default(),
        }
    }
}

impl PathConfig {
    /// Config with a given stream count and library defaults otherwise.
    pub fn with_streams(nstreams: usize) -> Self {
        PathConfig { nstreams, ..Default::default() }
    }

    /// Validate the configuration, mirroring MPWide's constraints.
    pub fn validate(&self) -> crate::mpwide::Result<()> {
        if self.nstreams == 0 {
            return Err(crate::mpwide::MpwError::Config("nstreams must be >= 1".into()));
        }
        if self.nstreams > MAX_STREAMS {
            return Err(crate::mpwide::MpwError::Config(format!(
                "nstreams {} exceeds maximum {MAX_STREAMS}",
                self.nstreams
            )));
        }
        if self.chunk_size == 0 {
            return Err(crate::mpwide::MpwError::Config("chunk_size must be >= 1".into()));
        }
        if let Some(r) = self.pacing_rate {
            if !(r > 0.0) {
                return Err(crate::mpwide::MpwError::Config(format!(
                    "pacing rate must be positive, got {r}"
                )));
            }
        }
        self.adapt.validate()?;
        self.resilience.validate()?;
        Ok(())
    }

    /// The paper's recommendation for a WAN path: ≥32 streams, autotuning on.
    pub fn wan_recommended() -> Self {
        PathConfig { nstreams: 32, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper() {
        let c = PathConfig::default();
        assert_eq!(c.nstreams, 1);
        assert!(c.autotune, "autotuner is enabled by default per the paper");
        assert!(c.pacing_rate.is_none());
    }

    #[test]
    fn validate_rejects_zero_streams() {
        let c = PathConfig { nstreams: 0, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_too_many_streams() {
        let c = PathConfig { nstreams: MAX_STREAMS + 1, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_chunk() {
        let c = PathConfig { chunk_size: 0, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_nonpositive_pacing() {
        let c = PathConfig { pacing_rate: Some(0.0), ..Default::default() };
        assert!(c.validate().is_err());
        let c = PathConfig { pacing_rate: Some(-1.0), ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn resilience_defaults_off_and_wan_preset_on() {
        let c = PathConfig::default();
        assert!(!c.resilience.enabled, "resilient framing must be opt-in");
        assert!(!c.resilience.reconnect.enabled);
        let w = ResilienceConfig::wan();
        assert!(w.enabled && w.reconnect.enabled);
        assert!(w.ack_timeout.is_some(), "wan preset arms the ACK watchdog");
        assert!(w.validate().is_ok());
    }

    #[test]
    fn resilience_validation_rejects_zero_ack_timeout() {
        let mut c = PathConfig::default();
        c.resilience.ack_timeout = Some(Duration::ZERO);
        assert!(c.validate().is_err(), "a zero ACK budget kills every send");
        c.resilience.ack_timeout = Some(Duration::from_millis(100));
        assert!(c.validate().is_ok());
    }

    #[test]
    fn resilience_defaults_to_rendezvous_window() {
        let c = ResilienceConfig::default();
        assert_eq!(c.window, 1, "default must preserve rendezvous send semantics");
        assert!(c.write_timeout.is_none());
        let w = ResilienceConfig::wan();
        assert!(w.window > 1, "wan preset should pipeline sends");
        assert!(w.validate().is_ok());
    }

    #[test]
    fn resilience_validation_rejects_zero_window() {
        let mut c = PathConfig::default();
        c.resilience.window = 0;
        assert!(c.validate().is_err(), "a zero window can never post a message");
        c.resilience.window = 1;
        assert!(c.validate().is_ok());
        c.resilience.window = crate::mpwide::resilience::MAX_WINDOW;
        assert!(c.validate().is_ok());
        c.resilience.window = crate::mpwide::resilience::MAX_WINDOW + 1;
        assert!(c.validate().is_err(), "window beyond the receiver's reorder bound");
    }

    #[test]
    fn resilience_validation_rejects_zero_stash_high_water() {
        let mut c = PathConfig::default();
        c.resilience.recv_stash_high_water = Some(0);
        assert!(c.validate().is_err(), "zero byte credit means no progress, ever");
        c.resilience.recv_stash_high_water = Some(1 << 20);
        assert!(c.validate().is_ok());
        c.resilience.recv_stash_high_water = None;
        assert!(c.validate().is_ok(), "None disables the byte bound");
        let w = ResilienceConfig::wan();
        assert!(w.recv_stash_high_water.is_some(), "wan preset bounds the stash");
    }

    #[test]
    fn resilience_validation_rejects_zero_write_timeout() {
        let mut c = PathConfig::default();
        c.resilience.write_timeout = Some(Duration::ZERO);
        assert!(c.validate().is_err(), "SO_SNDTIMEO(0) means block forever");
        c.resilience.write_timeout = Some(Duration::from_secs(1));
        assert!(c.validate().is_ok());
    }

    #[test]
    fn resilience_validation_rejects_inverted_backoff() {
        let mut c = PathConfig::default();
        c.resilience.reconnect.base_delay = Duration::from_secs(10);
        c.resilience.reconnect.max_delay = Duration::from_secs(1);
        assert!(c.validate().is_err());
    }

    #[test]
    fn resilience_validation_rejects_zero_backoff() {
        let mut c = PathConfig::default();
        c.resilience.reconnect.base_delay = Duration::ZERO;
        assert!(c.validate().is_err(), "a zero backoff floor never grows");
    }

    #[test]
    fn resilience_validation_rejects_zero_connect_timeout() {
        let mut c = PathConfig::default();
        c.resilience.enabled = true;
        c.resilience.reconnect.enabled = true;
        c.resilience.reconnect.connect_timeout = Duration::ZERO;
        assert!(c.validate().is_err(), "a zero connect deadline can never rejoin");
    }

    #[test]
    fn resilience_validation_rejects_reconnect_without_framing() {
        let mut c = PathConfig::default();
        c.resilience.reconnect.enabled = true; // framing left off
        assert!(c.validate().is_err(), "reconnect without failure detection is inert");
        c.resilience.enabled = true;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn accepts_256_streams() {
        let c = PathConfig::with_streams(256);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn wan_recommended_has_32_streams() {
        assert_eq!(PathConfig::wan_recommended().nstreams, 32);
    }
}
