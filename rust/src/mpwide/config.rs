//! Path configuration: the user-tunable performance parameters the paper
//! exposes (§1.3.1) — stream count, chunk size, pacing rate, TCP window
//! size, and the autotuning switch (enabled by default) — plus the
//! runtime-adaptation settings ([`AdaptConfig`]) layered on top by this
//! reproduction.

use std::time::Duration;

use super::adapt::AdaptConfig;

/// Maximum number of TCP streams per path. The paper reports efficient
/// operation with up to 256 streams in a single path.
pub const MAX_STREAMS: usize = 256;

/// Default chunk size: the amount of data handed to each low-level tcp
/// send/recv call (`MPW_setChunkSize`).
pub const DEFAULT_CHUNK: usize = 1 << 20; // 1 MiB

/// Configuration for a single communication path.
#[derive(Debug, Clone)]
pub struct PathConfig {
    /// Number of parallel TCP streams (always user-provided per the paper;
    /// recommended: 1 locally, ≥32 over long-distance networks).
    pub nstreams: usize,
    /// Bytes sent/received per low-level call (`MPW_setChunkSize`).
    pub chunk_size: usize,
    /// Software pacing rate per stream, bytes/second
    /// (`MPW_setPacingRate`). `None` disables pacing.
    pub pacing_rate: Option<f64>,
    /// Requested TCP window (SO_SNDBUF/SO_RCVBUF), bytes (`MPW_setWin`).
    /// `None` keeps the OS default; the effective value is constrained by
    /// the site configuration, exactly as the paper notes.
    pub tcp_window: Option<usize>,
    /// Autotune chunk size / window at path creation (`MPW_setAutoTuning`;
    /// default enabled per the paper).
    pub autotune: bool,
    /// How long `Path::connect` keeps retrying before giving up (endpoints
    /// of a distributed run start in arbitrary order).
    pub connect_timeout: Duration,
    /// Runtime adaptation (live restriping / re-chunking / re-pacing).
    /// Defaults to [`TuneMode::Static`](super::adapt::TuneMode::Static),
    /// i.e. the paper's creation-time-only behaviour.
    pub adapt: AdaptConfig,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            nstreams: 1,
            chunk_size: DEFAULT_CHUNK,
            pacing_rate: None,
            tcp_window: None,
            autotune: true,
            connect_timeout: Duration::from_secs(30),
            adapt: AdaptConfig::default(),
        }
    }
}

impl PathConfig {
    /// Config with a given stream count and library defaults otherwise.
    pub fn with_streams(nstreams: usize) -> Self {
        PathConfig { nstreams, ..Default::default() }
    }

    /// Validate the configuration, mirroring MPWide's constraints.
    pub fn validate(&self) -> crate::mpwide::Result<()> {
        if self.nstreams == 0 {
            return Err(crate::mpwide::MpwError::Config("nstreams must be >= 1".into()));
        }
        if self.nstreams > MAX_STREAMS {
            return Err(crate::mpwide::MpwError::Config(format!(
                "nstreams {} exceeds maximum {MAX_STREAMS}",
                self.nstreams
            )));
        }
        if self.chunk_size == 0 {
            return Err(crate::mpwide::MpwError::Config("chunk_size must be >= 1".into()));
        }
        if let Some(r) = self.pacing_rate {
            if !(r > 0.0) {
                return Err(crate::mpwide::MpwError::Config(format!(
                    "pacing rate must be positive, got {r}"
                )));
            }
        }
        self.adapt.validate()?;
        Ok(())
    }

    /// The paper's recommendation for a WAN path: ≥32 streams, autotuning on.
    pub fn wan_recommended() -> Self {
        PathConfig { nstreams: 32, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper() {
        let c = PathConfig::default();
        assert_eq!(c.nstreams, 1);
        assert!(c.autotune, "autotuner is enabled by default per the paper");
        assert!(c.pacing_rate.is_none());
    }

    #[test]
    fn validate_rejects_zero_streams() {
        let c = PathConfig { nstreams: 0, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_too_many_streams() {
        let c = PathConfig { nstreams: MAX_STREAMS + 1, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_chunk() {
        let c = PathConfig { chunk_size: 0, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_nonpositive_pacing() {
        let c = PathConfig { pacing_rate: Some(0.0), ..Default::default() };
        assert!(c.validate().is_err());
        let c = PathConfig { pacing_rate: Some(-1.0), ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn accepts_256_streams() {
        let c = PathConfig::with_streams(256);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn wan_recommended_has_32_streams() {
        assert_eq!(PathConfig::wan_recommended().nstreams, 32);
    }
}
