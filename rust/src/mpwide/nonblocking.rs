//! Non-blocking operations: `MPW_ISendRecv`, `MPW_Has_NBE_Finished`,
//! `MPW_Wait`.
//!
//! These are the latency-hiding primitive the distributed bloodflow run
//! uses (§1.2.2): the solver posts the boundary exchange, computes the
//! next sub-steps, and only waits when the data is actually needed —
//! reducing the effective coupling overhead to ~6 ms per exchange.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::errors::{MpwError, Result};
use super::path::Path;

/// The operation a non-blocking handle performs.
pub enum NbeOp {
    /// Send a buffer.
    Send(Vec<u8>),
    /// Receive exactly `n` bytes.
    Recv(usize),
    /// Full-duplex: send the buffer, receive exactly `n` bytes.
    SendRecv(Vec<u8>, usize),
    /// Full-duplex with dynamic sizes (`MPW_DSendRecv` semantics).
    DSendRecv(Vec<u8>),
}

/// Handle to an in-flight non-blocking exchange.
pub struct NbeHandle {
    done: Arc<AtomicBool>,
    join: Option<JoinHandle<Result<Option<Vec<u8>>>>>,
    /// Result of an operation that completed inline (no worker thread;
    /// the mux `isend` fast path).
    ready: Option<Result<Option<Vec<u8>>>>,
}

impl NbeHandle {
    /// `MPW_ISendRecv`: start the operation on a worker thread.
    pub fn start(path: Arc<Path>, op: NbeOp) -> NbeHandle {
        NbeHandle::spawn(move || match op {
            NbeOp::Send(buf) => path.send(&buf).map(|_| None),
            NbeOp::Recv(n) => {
                let mut buf = vec![0u8; n];
                path.recv(&mut buf).map(|_| Some(buf))
            }
            NbeOp::SendRecv(sbuf, n) => {
                let mut buf = vec![0u8; n];
                path.send_recv(&sbuf, &mut buf).map(|_| Some(buf))
            }
            NbeOp::DSendRecv(sbuf) => {
                let mut cache = Vec::new();
                path.dsend_recv(&sbuf, &mut cache).map(|n| {
                    cache.truncate(n);
                    Some(cache)
                })
            }
        })
    }

    /// Run an arbitrary blocking operation under the non-blocking handle
    /// discipline (poll with [`NbeHandle::is_finished`], harvest with
    /// [`NbeHandle::wait`], detach on drop). The mux layer uses this for
    /// channel-level `isend`/`irecv`, so channels compose with the same
    /// latency-hiding pattern paths do.
    pub fn spawn(
        f: impl FnOnce() -> Result<Option<Vec<u8>>> + Send + 'static,
    ) -> NbeHandle {
        let done = Arc::new(AtomicBool::new(false));
        let done2 = done.clone();
        let join = std::thread::spawn(move || {
            let result = f();
            done2.store(true, Ordering::Release);
            result
        });
        NbeHandle { done, join: Some(join), ready: None }
    }

    /// A handle whose operation already completed inline — no worker
    /// thread at all. `is_finished` is immediately true and `wait`
    /// returns `result` directly. Used by queue-only operations (mux
    /// `isend` with room below the high-water mark) so the non-blocking
    /// API costs nothing when nothing would block.
    pub fn ready(result: Result<Option<Vec<u8>>>) -> NbeHandle {
        NbeHandle { done: Arc::new(AtomicBool::new(true)), join: None, ready: Some(result) }
    }

    /// `MPW_Has_NBE_Finished`: poll without blocking.
    pub fn is_finished(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }

    /// `MPW_Wait`: block until completion; returns the received buffer for
    /// receiving operations, `None` for pure sends.
    pub fn wait(mut self) -> Result<Option<Vec<u8>>> {
        if let Some(r) = self.ready.take() {
            return r;
        }
        let Some(join) = self.join.take() else {
            // `wait` consumes the handle, so the worker handle can only be
            // absent if construction was bypassed; report it as a dead worker
            // rather than panicking in library code.
            return Err(MpwError::WorkerPanic("non-blocking worker handle missing".into()));
        };
        join.join().map_err(|_| MpwError::WorkerPanic("non-blocking worker".into()))?
    }
}

impl Drop for NbeHandle {
    fn drop(&mut self) {
        // Detach, never join: joining here wedged the dropping thread
        // forever when an unfinished Recv/SendRecv handle was abandoned
        // and the peer never sent (the worker is parked in a blocking
        // read). The worker owns its own Arc<Path> and exits when the
        // operation resolves or the path's streams are closed —
        // `Path::close` (or `mpw_finalize`, which calls it) unwedges an
        // abandoned worker deliberately.
        self.join = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::config::PathConfig;
    use crate::mpwide::transport::mem_path_pairs;

    fn mem_paths(n: usize) -> (Arc<Path>, Arc<Path>) {
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        (
            Arc::new(Path::from_pairs(l, cfg.clone()).unwrap()),
            Arc::new(Path::from_pairs(r, cfg).unwrap()),
        )
    }

    #[test]
    fn isend_irecv_complete() {
        let (a, b) = mem_paths(2);
        let msg = vec![42u8; 10_000];
        let h_send = NbeHandle::start(a, NbeOp::Send(msg.clone()));
        let h_recv = NbeHandle::start(b, NbeOp::Recv(10_000));
        let got = h_recv.wait().unwrap().unwrap();
        assert_eq!(got, msg);
        assert!(h_send.wait().unwrap().is_none());
    }

    #[test]
    fn has_finished_eventually_true() {
        let (a, b) = mem_paths(1);
        let h = NbeHandle::start(a, NbeOp::Send(vec![1u8; 100]));
        let r = NbeHandle::start(b, NbeOp::Recv(100));
        r.wait().unwrap();
        // send must complete shortly after the receive drained it
        let t0 = std::time::Instant::now();
        while !h.is_finished() {
            assert!(t0.elapsed().as_secs() < 5, "send never finished");
            std::thread::yield_now();
        }
        assert!(h.is_finished());
    }

    #[test]
    fn nonblocking_sendrecv_both_sides() {
        let (a, b) = mem_paths(3);
        let ma = vec![1u8; 5000];
        let mb = vec![2u8; 6000];
        let ha = NbeHandle::start(a, NbeOp::SendRecv(ma.clone(), 6000));
        let hb = NbeHandle::start(b, NbeOp::SendRecv(mb.clone(), 5000));
        assert_eq!(ha.wait().unwrap().unwrap(), mb);
        assert_eq!(hb.wait().unwrap().unwrap(), ma);
    }

    #[test]
    fn nonblocking_dynamic_exchange() {
        let (a, b) = mem_paths(2);
        let ha = NbeHandle::start(a, NbeOp::DSendRecv(vec![7u8; 123]));
        let hb = NbeHandle::start(b, NbeOp::DSendRecv(vec![8u8; 4567]));
        assert_eq!(ha.wait().unwrap().unwrap(), vec![8u8; 4567]);
        assert_eq!(hb.wait().unwrap().unwrap(), vec![7u8; 123]);
    }

    #[test]
    fn dropping_unfinished_handle_does_not_block() {
        // Regression: Drop used to join the worker thread, wedging the
        // dropping thread forever when the peer never sends.
        let (a, b) = mem_paths(2);
        let h = NbeHandle::start(a, NbeOp::Recv(1024));
        assert!(!h.is_finished());
        let t0 = std::time::Instant::now();
        drop(h);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "drop of an in-flight handle must not block on the worker"
        );
        // keep the peer alive until here so the receive genuinely blocks
        drop(b);
    }

    #[test]
    fn overlap_hides_latency() {
        // The latency-hiding pattern from §1.2.2: post exchange, compute,
        // then wait. With an in-memory transport the exchange is fast; this
        // test asserts the *pattern* works (compute proceeds while the
        // exchange is in flight and the result is still correct).
        let (a, b) = mem_paths(2);
        let echo = std::thread::spawn(move || {
            let mut cache = Vec::new();
            let n = b.drecv_into(&mut cache).unwrap();
            b.dsend(&cache[..n]).unwrap();
        });
        let h = NbeHandle::start(a.clone(), NbeOp::DSendRecv(vec![3u8; 2048]));
        // "compute" while the exchange is in flight
        let mut acc = 0u64;
        for i in 0..100_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        assert!(acc > 0);
        let got = h.wait().unwrap().unwrap();
        assert_eq!(got, vec![3u8; 2048]);
        echo.join().unwrap();
    }
}
