//! Fault-tolerant paths: per-stream failure detection and isolation,
//! automatic stream rejoin, and degraded-mode striping.
//!
//! The paper's MPWide (and this reproduction, before this module)
//! treated any single-stream TCP error as fatal to the whole path — a
//! poor fit for the library's headline deployments, week-scale WAN runs
//! striped across continents where *some* socket dying is a matter of
//! when, not if. This module layers three mechanisms on top of the
//! existing path machinery, all opt-in via
//! [`ResilienceConfig`](super::config::ResilienceConfig):
//!
//! 1. **Failure detection & isolation** — in resilient mode every
//!    message is sent as typed frames (`CTRL` / `DATA` / `ACK`, each
//!    tagged with a message sequence number and attempt counter). A
//!    stream whose I/O fails is marked dead and force-closed
//!    ([`KillSwitch`](super::transport::KillSwitch)), which propagates
//!    the failure to the peer, and the in-flight message is *retried
//!    over the surviving streams* instead of erroring the path. Delivery
//!    is confirmed by a per-message `ACK`; a receiver that lost a stream
//!    mid-message `NACK`s with the dead stream's index so the sender
//!    routes around it even when the sender's own writes "succeeded"
//!    into a dying socket.
//! 2. **Degraded-mode striping** — stream health feeds the live tuning
//!    state: the effective active-stream count is clamped to the live
//!    count ([`TuningState::apply_live_limit`](super::adapt::TuningState::apply_live_limit))
//!    and the adaptive controller's hill-climb ceiling follows the live
//!    count, so the per-message active-stream header automatically
//!    routes around dead streams and re-absorbs rejoined ones.
//! 3. **Background rejoin** — the connecting end runs a
//!    [`ReconnectMonitor`] that redials dead streams with the *original
//!    path uuid and stream index* (the same hello handshake used at
//!    creation); the accepting end runs a [`RejoinDaemon`] on the path
//!    listener that recognises the uuid and slots the fresh socket back
//!    into its old position via [`Path::reinstall_stream`].
//!
//! ### Wire format (resilient mode only)
//!
//! Every frame is `[magic u8][kind u8][msg_seq u64][attempt u32][len
//! u32]` followed by `len` payload bytes. `CTRL` (on the current control
//! stream) carries the message length, the explicit list of stream
//! indices the payload is striped over, and the sender's dead set
//! (in-band death gossip — a failure only the sender can observe still
//! reaches the receiver, whose slot must die before a rejoin can be
//! accepted); `DATA` carries one chunk of one stream's segment; `ACK`
//! carries delivered/retry plus the index of a stream the receiver
//! found dead. Frames from aborted attempts are
//! skipped by sequence/attempt comparison, so retries need no draining
//! protocol. Frame headers cost 18 bytes per chunk (≥ 64 KiB in
//! adaptive mode) — well under 0.1% overhead.
//!
//! The control stream is *sticky*: both ends start at stream 0 and
//! rotate — to the next live index, cyclically — only when the current
//! control stream dies. Rotation is driven by death (which propagates
//! through the socket shutdown) and never by rejoin (which does not),
//! so both ends converge on the same control stream without
//! negotiation.
//!
//! ### Semantics: rendezvous by default, pipelined by request
//!
//! With the default window of 1, delivery being ACK-confirmed means a
//! resilient `send` completes only once the receiver's matching `recv`
//! has consumed the message — MPI's `Ssend` semantics, not the buffered
//! semantics of non-resilient mode. Two ends that both do `send(..)`
//! then `recv(..)` therefore deadlock (each waits for the other's ack).
//! Symmetric exchanges must use `send_recv` / `dsend_recv` (which run
//! both directions concurrently), `barrier`, or non-blocking handles —
//! the patterns MPWide applications already use.
//!
//! ### In-flight windowing
//!
//! With [`ResilienceConfig::window`](super::config::ResilienceConfig::window)
//! `> 1` the sender *pipelines*: a send **posts** its message (writes
//! CTRL + DATA, keeping an owned retransmit copy) and returns, and
//! delivery acknowledgements are **reaped** out of order as later sends
//! fill the window, or by an explicit drain (`Path::flush`, `barrier`,
//! a window-full send). On a high-bandwidth-delay-product link this
//! lifts the `message/RTT` goodput cap of the rendezvous protocol —
//! the exact regime the paper targets. The wire format is unchanged
//! (the window is a sender-side discipline; per-message seq/attempt
//! counters already order everything), so the two ends may use
//! different windows. Selective retry resends only the NACKed or
//! timed-out message; a control-stream death reposts everything still
//! in flight, and the receiver re-acknowledges duplicates by sequence
//! number. The receiver keeps a bounded reorder stash (at most
//! [`MAX_WINDOW`] messages) for messages a retry delivered ahead of
//! their turn. A delivery failure in the pipeline *poisons* it: the
//! error surfaces on a later send, `flush`, or `barrier` — callers that
//! need per-message confirmation keep `window = 1`.
//!
//! ### Limitations
//!
//! Failure detection is I/O-driven: a half-open connection that
//! swallows writes without erroring (cable pull, NAT timeout) is only
//! detected when TCP gives up — enable OS keepalive for long-idle
//! paths. A lost final `ACK` can leave the sender retrying a message
//! the receiver already delivered; the duplicate is detected by
//! sequence number and re-acknowledged on the receiver's next `recv`
//! (or, if this end is itself blocked in a send, by the ACK wait
//! itself). The formerly documented divergence window — a control-stream
//! death in the sub-RTT interval while *another* stream's rejoin is
//! half-installed (one end confirmed, the other still awaiting its
//! [`REJOIN_ACK`]) could rotate the two ends to different control
//! streams and stall both until one side's I/O failed — is now closed by
//! the ACK progress watchdog: with
//! [`ResilienceConfig::ack_timeout`](super::config::ResilienceConfig::ack_timeout)
//! set, a sender whose delivery acknowledgement does not arrive within
//! the budget force-closes its control stream and retries over the
//! survivors, re-converging both ends through the ordinary rotation
//! rule. The watchdog is off by default (with `window = 1` resilient
//! sends are rendezvous sends, so the budget must exceed the worst-case
//! time for the peer to *consume* a whole message); the
//! [`ResilienceConfig::wan`](super::config::ResilienceConfig::wan)
//! preset arms it at 10 minutes. With `window > 1` the watchdog tracks
//! *oldest-unacked progress*: the deadline re-arms whenever the oldest
//! in-flight message changes (is acknowledged or reposted on a new
//! control stream), so a pipelined sender only trips it when the head
//! of the window stalls. Segment **writes** stalled by TCP
//! backpressure (possible in the same divergence scenario when the
//! message exceeds the socket buffers) are covered separately by
//! [`ResilienceConfig::write_timeout`](super::config::ResilienceConfig::write_timeout),
//! an `SO_SNDTIMEO`-style deadline on socket transports; without it a
//! stalled writer still rides TCP's own timeout.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::errors::{MpwError, Result};
use super::path::Path;
use super::stripe::{self, SplitBuf};
use super::transport::{reconnect_stream, KillSwitch, RawPathListener, StreamPair, REJOIN_ACK};
use crate::util::lockorder::{rank, OrderedCondvar, OrderedMutex};

/// Sanity byte opening every resilient frame.
pub const FRAME_MAGIC: u8 = 0xF5;
/// Frame kinds.
pub const KIND_CTRL: u8 = 1;
/// See [`KIND_CTRL`].
pub const KIND_DATA: u8 = 2;
/// See [`KIND_CTRL`].
pub const KIND_ACK: u8 = 3;
/// Receiver-credit advertisement (`WINDOW_UPDATE`): the frame's
/// `msg_seq` carries the advert id and its payload is one
/// [`WINDOW_UPDATE_LEN`]-byte credit block. Advisory — a lost one is
/// healed by the credit copy every extended ACK carries.
pub const KIND_WINDOW_UPDATE: u8 = 4;
/// Fixed frame header size: magic + kind + msg_seq + attempt + len.
pub const FRAME_HDR_LEN: usize = 1 + 1 + 8 + 4 + 4;
/// Upper bound on a single DATA frame payload (a corrupted header must
/// not trigger an absurd allocation).
pub const MAX_FRAME_PAYLOAD: usize = 64 << 20;

const ACK_OK: u8 = 0;
const ACK_RETRY: u8 = 1;
/// "No dead stream to report" in an ACK's detail field.
const NO_DETAIL: u16 = u16::MAX;
/// `ACK_RETRY` detail: the receiver's reorder stash is byte-full
/// ([`ResilienceConfig::recv_stash_high_water`](super::config::ResilienceConfig::recv_stash_high_water)),
/// not a stream failure — the sender must repost later without marking
/// any stream dead.
pub const DETAIL_STASH_FULL: u16 = 0xFFFE;
/// Size of one credit block: advert id + seq limit + byte credit +
/// message budget. The payload of a `WINDOW_UPDATE` frame, and the tail
/// of an extended (credit-bearing) ACK.
pub const WINDOW_UPDATE_LEN: usize = 8 + 8 + 8 + 4;

/// Hard ceiling on [`ResilienceConfig::window`](super::config::ResilienceConfig::window).
///
/// Bounds the receiver's reorder stash (out-of-turn messages a
/// pipelining sender completed early) and lets the receiver reject a
/// CTRL whose sequence lies beyond any window the peer could legally
/// have open — the windowed analogue of the old "ctrl for future
/// message" check.
pub const MAX_WINDOW: usize = 64;

/// Decoded frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHdr {
    /// Frame kind (`KIND_*`).
    pub kind: u8,
    /// Per-direction message sequence number.
    pub msg_seq: u64,
    /// Retry attempt within the message.
    pub attempt: u32,
    /// Payload length in bytes.
    pub len: u32,
}

/// Encode a frame header.
pub fn encode_frame_hdr(kind: u8, msg_seq: u64, attempt: u32, len: u32) -> [u8; FRAME_HDR_LEN] {
    let mut h = [0u8; FRAME_HDR_LEN];
    h[0] = FRAME_MAGIC;
    h[1] = kind;
    h[2..10].copy_from_slice(&msg_seq.to_be_bytes());
    h[10..14].copy_from_slice(&attempt.to_be_bytes());
    h[14..18].copy_from_slice(&len.to_be_bytes());
    h
}

/// Decode and validate a frame header.
pub fn decode_frame_hdr(h: &[u8; FRAME_HDR_LEN]) -> Result<FrameHdr> {
    if h[0] != FRAME_MAGIC {
        return Err(MpwError::Protocol(format!("bad frame magic {:#04x}", h[0])));
    }
    let kind = h[1];
    if !(KIND_CTRL..=KIND_WINDOW_UPDATE).contains(&kind) {
        return Err(MpwError::Protocol(format!("bad frame kind {kind}")));
    }
    let msg_seq = u64::from_be_bytes(h[2..10].try_into().unwrap());
    let attempt = u32::from_be_bytes(h[10..14].try_into().unwrap());
    let len = u32::from_be_bytes(h[14..18].try_into().unwrap());
    if len as usize > MAX_FRAME_PAYLOAD {
        return Err(MpwError::Protocol(format!("frame payload {len} exceeds bound")));
    }
    Ok(FrameHdr { kind, msg_seq, attempt, len })
}

/// Decoded CTRL payload: message length, the explicit stream list the
/// payload is striped over (in segment order), and the sender's dead
/// set — in-band death gossip, so a failure only one side can observe
/// (e.g. a write error whose stream the sender then stops using) still
/// reaches the peer and unlocks rejoin there.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CtrlMsg {
    /// Total message length, bytes.
    pub total: u64,
    /// Stream indices carrying segments 0..k. `streams[0]` is also the
    /// control stream of the attempt.
    pub streams: Vec<u16>,
    /// Stream indices the sender considers dead.
    pub dead: Vec<u16>,
}

/// Encode a CTRL payload.
pub fn encode_ctrl(total: u64, streams: &[u16], dead: &[u16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + 2 * (streams.len() + dead.len()));
    out.extend_from_slice(&total.to_be_bytes());
    out.extend_from_slice(&(streams.len() as u16).to_be_bytes());
    for s in streams {
        out.extend_from_slice(&s.to_be_bytes());
    }
    out.extend_from_slice(&(dead.len() as u16).to_be_bytes());
    for s in dead {
        out.extend_from_slice(&s.to_be_bytes());
    }
    out
}

/// Decode a CTRL payload.
pub fn parse_ctrl(p: &[u8]) -> Result<CtrlMsg> {
    if p.len() < 12 {
        return Err(MpwError::Protocol("short ctrl frame".into()));
    }
    let total = u64::from_be_bytes(p[0..8].try_into().unwrap());
    let k = u16::from_be_bytes(p[8..10].try_into().unwrap()) as usize;
    if k == 0 || p.len() < 12 + 2 * k {
        return Err(MpwError::Protocol(format!("ctrl frame stream list malformed (k={k})")));
    }
    let streams: Vec<u16> =
        (0..k).map(|i| u16::from_be_bytes(p[10 + 2 * i..12 + 2 * i].try_into().unwrap())).collect();
    let off = 10 + 2 * k;
    let d = u16::from_be_bytes(p[off..off + 2].try_into().unwrap()) as usize;
    if p.len() != off + 2 + 2 * d {
        return Err(MpwError::Protocol(format!("ctrl frame dead list malformed (d={d})")));
    }
    let base = off + 2;
    let dead = (0..d)
        .map(|i| u16::from_be_bytes(p[base + 2 * i..base + 2 + 2 * i].try_into().unwrap()))
        .collect();
    Ok(CtrlMsg { total, streams, dead })
}

/// Decoded credit advertisement (`WINDOW_UPDATE` payload, or the tail
/// of an extended ACK). All values are **absolute** — a credit block
/// replaces, never increments, the sender's view — so a lost or
/// reordered advert is harmless: the newest `advert_id` wins.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Credit {
    /// Monotonic per-direction advert counter; receivers of a credit
    /// block apply it only if this is newer than the last applied one.
    pub advert_id: u64,
    /// Highest `msg_seq` the receiver grants: the sender must not post
    /// a message with a larger sequence number. `u64::MAX` = no limit.
    pub seq_limit: u64,
    /// Free bytes in the receiver's reorder stash. Messages beyond the
    /// oldest in flight must fit in it; `u64::MAX` = unbounded (no
    /// byte high-water configured).
    pub byte_credit: u64,
    /// The receiver's message budget — a cap on how many messages the
    /// sender should keep in flight (narrows the adaptive window
    /// tunable, never widens past [`MAX_WINDOW`]).
    pub budget_msgs: u32,
}

/// Encode a credit block.
pub fn encode_credit(c: &Credit) -> [u8; WINDOW_UPDATE_LEN] {
    let mut b = [0u8; WINDOW_UPDATE_LEN];
    b[0..8].copy_from_slice(&c.advert_id.to_be_bytes());
    b[8..16].copy_from_slice(&c.seq_limit.to_be_bytes());
    b[16..24].copy_from_slice(&c.byte_credit.to_be_bytes());
    b[24..28].copy_from_slice(&c.budget_msgs.to_be_bytes());
    b
}

/// Decode a credit block.
pub fn parse_credit(p: &[u8]) -> Result<Credit> {
    if p.len() != WINDOW_UPDATE_LEN {
        return Err(MpwError::Protocol(format!("credit block of {} bytes", p.len())));
    }
    let mut w = [0u8; 8];
    w.copy_from_slice(&p[0..8]);
    let advert_id = u64::from_be_bytes(w);
    w.copy_from_slice(&p[8..16]);
    let seq_limit = u64::from_be_bytes(w);
    w.copy_from_slice(&p[16..24]);
    let byte_credit = u64::from_be_bytes(w);
    let mut n = [0u8; 4];
    n.copy_from_slice(&p[24..28]);
    let budget_msgs = u32::from_be_bytes(n);
    Ok(Credit { advert_id, seq_limit, byte_credit, budget_msgs })
}

// ---------------------------------------------------------------------------
// Per-stream frame inbox: routing between concurrent frame consumers.
// ---------------------------------------------------------------------------

/// Frames read off a stream by one consumer but destined for another.
///
/// A stream's read half has a single byte-level owner at a time (the rx
/// mutex), but up to three logical consumers: the receiver's CTRL
/// reader, the receiver's DATA segment workers, and the sender's ACK
/// waiter (full-duplex traffic interleaves all three on the control
/// stream). Whoever holds the rx lock reads whole frames and parks the
/// ones that are not theirs here; every consumer checks the inbox
/// before (and immediately after) taking the lock.
pub(crate) struct FrameBox {
    frames: OrderedMutex<VecDeque<(FrameHdr, Vec<u8>)>>,
}

impl Default for FrameBox {
    fn default() -> Self {
        FrameBox { frames: OrderedMutex::new(rank::FRAME_INBOX, VecDeque::new()) }
    }
}

impl FrameBox {
    /// Park a frame for another consumer. Credit adverts are absolute
    /// (newest wins) and their consumer may never come, so at most one
    /// `WINDOW_UPDATE` is kept per inbox — the parked one is replaced.
    fn push(&self, hdr: FrameHdr, payload: Vec<u8>) {
        let mut q = self.frames.lock();
        if hdr.kind == KIND_WINDOW_UPDATE {
            q.retain(|(h, _)| h.kind != KIND_WINDOW_UPDATE);
        }
        q.push_back((hdr, payload));
    }

    /// Take the oldest parked frame of `kind`, if any.
    fn take(&self, kind: u8) -> Option<(FrameHdr, Vec<u8>)> {
        self.take_where(kind, |_| true)
    }

    /// Take the oldest parked frame of `kind` matching `pred`, leaving
    /// non-matching frames in place (they belong to another consumer —
    /// e.g. a pipelined later message — and must keep their order).
    fn take_where(&self, kind: u8, pred: impl Fn(&FrameHdr) -> bool) -> Option<(FrameHdr, Vec<u8>)> {
        let mut q = self.frames.lock();
        let pos = q.iter().position(|(h, _)| h.kind == kind && pred(h))?;
        q.remove(pos)
    }

    /// Drop parked DATA frames with `msg_seq <= seq`: once a message is
    /// delivered, stale duplicates of its segments (reposts that raced
    /// the delivery) can never be consumed and would otherwise leak.
    fn purge_data_through(&self, seq: u64) {
        self.frames.lock().retain(|(h, _)| h.kind != KIND_DATA || h.msg_seq > seq);
    }

    /// Discard every parked frame (stream rejoin: frames parked off the
    /// old transport must not be replayed against the new one).
    pub(crate) fn clear(&self) {
        self.frames.lock().clear();
    }
}

// ---------------------------------------------------------------------------
// ACK progress watchdog.
// ---------------------------------------------------------------------------

/// Progress watchdog for the resilient sender's ACK wait.
///
/// The sender's ACK wait is a blocking read on the control stream; if
/// the two ends ever diverge on which stream that is (the half-completed
/// rejoin racing a control-stream death — the divergence window formerly
/// documented as a limitation), the read would block until TCP gave up.
/// The watchdog closes that window: `arm` registers a deadline and the
/// control stream's [`KillSwitch`]; if `disarm` does not happen first, a
/// lazily spawned timer thread fires the switch, the blocked read fails
/// fast, the stream is isolated, and the send retries over survivors —
/// the exact path any other stream death takes.
///
/// One watchdog (and at most one timer thread) exists per path; arming
/// and disarming are two uncontended mutex operations on the send path.
pub(crate) struct AckWatchdog {
    shared: Arc<WdShared>,
}

struct WdShared {
    wd_st: OrderedMutex<WdState>,
    cv: OrderedCondvar,
}

struct WdState {
    /// Monotonic arm token: a stale disarm (or a stale expiry) of a
    /// previous wait must not touch the current one.
    token: u64,
    deadline: Option<Instant>,
    kill: Option<KillSwitch>,
    fired: u64,
    spawned: bool,
    stop: bool,
}

impl AckWatchdog {
    pub(crate) fn new() -> AckWatchdog {
        AckWatchdog {
            shared: Arc::new(WdShared {
                wd_st: OrderedMutex::new(
                    rank::ACK_WATCHDOG,
                    WdState {
                        token: 0,
                        deadline: None,
                        kill: None,
                        fired: 0,
                        spawned: false,
                        stop: false,
                    },
                ),
                cv: OrderedCondvar::new(),
            }),
        }
    }

    /// Register a deadline; returns the token to pass to `disarm`.
    /// Spawns the timer thread on first use (a failed spawn surfaces as
    /// `Io` and leaves the watchdog unarmed, so a later arm retries).
    pub(crate) fn arm(&self, kill: KillSwitch, timeout: Duration) -> Result<u64> {
        let mut g = self.shared.wd_st.lock();
        if !g.spawned {
            let shared = self.shared.clone();
            let handle = std::thread::Builder::new()
                .name("mpwide-ack-watchdog".into())
                .spawn(move || watchdog_loop(shared))?;
            // detached deliberately: the thread exits via the stop flag
            drop(handle);
            g.spawned = true;
        }
        g.token += 1;
        g.deadline = Some(Instant::now() + timeout);
        g.kill = Some(kill);
        self.shared.cv.notify_all();
        Ok(g.token)
    }

    /// Cancel the deadline registered under `token` (no-op if the
    /// watchdog already fired or a newer wait re-armed).
    pub(crate) fn disarm(&self, token: u64) {
        let mut g = self.shared.wd_st.lock();
        if g.token == token {
            g.deadline = None;
            g.kill = None;
        }
    }

    /// How many times the watchdog fired over the path's lifetime.
    pub(crate) fn fired(&self) -> u64 {
        self.shared.wd_st.lock().fired
    }

    /// Stop the timer thread (called when the path closes / drops).
    pub(crate) fn stop(&self) {
        let mut g = self.shared.wd_st.lock();
        g.stop = true;
        g.deadline = None;
        g.kill = None;
        self.shared.cv.notify_all();
    }
}

impl Default for AckWatchdog {
    fn default() -> Self {
        AckWatchdog::new()
    }
}

fn watchdog_loop(shared: Arc<WdShared>) {
    let mut g = shared.wd_st.lock();
    loop {
        if g.stop {
            return;
        }
        match g.deadline {
            None => {
                g = shared.cv.wait(g);
            }
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    let kill = g.kill.take();
                    g.deadline = None;
                    g.fired += 1;
                    drop(g);
                    if let Some(k) = kill {
                        k.fire();
                    }
                    g = shared.wd_st.lock();
                } else {
                    let (g2, _) = shared.cv.wait_timeout(g, d - now);
                    g = g2;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Path health.
// ---------------------------------------------------------------------------

/// Shared health state of one path: a *generation* counter bumped only
/// on rejoin (failure reports carry the generation they observed, so a
/// report about a since-replaced transport is discarded — while two
/// simultaneous death reports both land), a rejoin tally, and a condvar
/// for waiters (zero-live-stream sends, the reconnect monitor).
pub(crate) struct HealthState {
    pub(crate) generation: AtomicU64,
    pub(crate) rejoined: AtomicU64,
    pub(crate) sync: OrderedMutex<()>,
    pub(crate) cv: OrderedCondvar,
}

impl HealthState {
    pub(crate) fn new() -> HealthState {
        HealthState {
            generation: AtomicU64::new(0),
            rejoined: AtomicU64::new(0),
            sync: OrderedMutex::new(rank::HEALTH, ()),
            cv: OrderedCondvar::new(),
        }
    }
}

impl Default for HealthState {
    fn default() -> Self {
        HealthState::new()
    }
}

/// Point-in-time health report of a path (`mpw_path_status`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PathStatus {
    /// Established streams (live + dead).
    pub nstreams: usize,
    /// Streams currently able to carry traffic.
    pub live: usize,
    /// Indices of dead streams.
    pub dead: Vec<usize>,
    /// Streams the next send stripes over (after any degraded clamp).
    pub active_streams: usize,
    /// The active count the path would use at full health.
    pub preferred_active: usize,
    /// Total streams re-absorbed by rejoin over the path's lifetime.
    pub rejoined: u64,
    /// Times the ACK progress watchdog fired (each one force-closed the
    /// then-current control stream and re-routed the in-flight send).
    pub ack_timeouts: u64,
    /// Messages posted by the windowed sender and not yet acknowledged
    /// (always 0 with `window == 1`).
    pub window_in_flight: usize,
    /// Bytes currently held in the receiver's reorder stash (messages a
    /// pipelining peer completed out of turn).
    pub reorder_stash_bytes: usize,
    /// Whether resilient framing is enabled.
    pub resilient: bool,
    /// Whether background reconnection is enabled.
    pub reconnect_enabled: bool,
}

// ---------------------------------------------------------------------------
// Frame I/O over a path's streams.
// ---------------------------------------------------------------------------

/// The current control stream: sticky — rotates (to the next live
/// index, cyclically) only when the current one is dead. Returns
/// `AllStreamsDead` when nothing is live.
fn ctrl_stream(path: &Path) -> Result<usize> {
    loop {
        let c = path.cur_ctrl.load(Ordering::SeqCst);
        if path.stream_alive(c) {
            return Ok(c);
        }
        match path.next_live_after(c) {
            None => return Err(MpwError::AllStreamsDead),
            Some(i) => {
                // CAS so concurrent rotations settle on one choice.
                // swallow-ok: losing the CAS race is benign — the loop
                // re-reads whichever value won.
                let _ = path.cur_ctrl.compare_exchange(c, i, Ordering::SeqCst, Ordering::SeqCst);
            }
        }
    }
}

/// Write one frame (header + payload) under a single tx lock; pacing is
/// applied to DATA frames only. The payload is a [`SplitBuf`] so both
/// contiguous payloads (CTRL/ACK — `SplitBuf::plain`) and the data hot
/// path's (head, tail) scatter pairs share one frame-write discipline;
/// header and payload parts go out in a single vectored write — no
/// copy-assemble, one syscall on socket transports.
fn write_frame(
    path: &Path,
    s: usize,
    kind: u8,
    msg_seq: u64,
    attempt: u32,
    payload: SplitBuf<'_>,
    flush: bool,
) -> Result<()> {
    let hdr = encode_frame_hdr(kind, msg_seq, attempt, payload.len() as u32);
    let slot = &path.streams[s];
    let mut tx = slot.tx.lock();
    if kind == KIND_DATA {
        tx.pacer.acquire(payload.len());
    }
    tx.w.write_vectored_all(&[&hdr[..], payload.head, payload.tail])?;
    if flush {
        tx.w.flush()?;
    }
    Ok(())
}

/// One blocking read of a full frame off stream `s`, honouring the
/// inbox discipline shared by every frame consumer: check the inbox for
/// a parked frame of `want` before blocking, fail fast on dead streams,
/// and re-check the inbox once the rx lock is held (the previous lock
/// holder may have parked our frame while we waited). The returned
/// frame is *any* kind — the caller routes or parks foreign frames.
fn read_raw_frame(path: &Path, s: usize, want: u8) -> Result<(FrameHdr, Vec<u8>)> {
    read_raw_frame_where(path, s, want, |_| true)
}

/// [`read_raw_frame`] with a header predicate on the inbox takes: a
/// consumer interested only in *some* frames of `want` (e.g. a segment
/// worker that must not steal a pipelined later message's DATA) leaves
/// non-matching parked frames for their rightful consumer. Frames read
/// off the wire are returned regardless — the caller routes or parks
/// them.
fn read_raw_frame_where(
    path: &Path,
    s: usize,
    want: u8,
    pred: impl Fn(&FrameHdr) -> bool,
) -> Result<(FrameHdr, Vec<u8>)> {
    if let Some(f) = path.streams[s].inbox.take_where(want, &pred) {
        return Ok(f);
    }
    if !path.stream_alive(s) {
        return Err(MpwError::StreamDead { stream: s });
    }
    let mut rx = path.streams[s].rx.lock();
    if let Some(f) = path.streams[s].inbox.take_where(want, &pred) {
        return Ok(f);
    }
    let mut hb = [0u8; FRAME_HDR_LEN];
    rx.read_exact(&mut hb)?;
    let hdr = decode_frame_hdr(&hb)?;
    let mut payload = vec![0u8; hdr.len as usize];
    rx.read_exact(&mut payload)?;
    Ok((hdr, payload))
}

/// Read frames from stream `s` until one of kind `want` arrives; frames
/// for other consumers are parked in the stream's inbox (releasing the
/// lock between frames so a consumer blocked on the rx mutex can
/// collect them).
fn read_frame(path: &Path, s: usize, want: u8) -> Result<(FrameHdr, Vec<u8>)> {
    loop {
        let (hdr, payload) = read_raw_frame(path, s, want)?;
        if hdr.kind == want {
            return Ok((hdr, payload));
        }
        path.streams[s].inbox.push(hdr, payload);
    }
}

/// Snapshot this end's *receive-side* credit: how far ahead of the
/// expected sequence the peer may post, and how many stash bytes are
/// free. Takes (and releases) the reorder-stash lock only — callers
/// write the resulting block with no credit lock held.
fn current_credit(path: &Path) -> Credit {
    let expected = path.res_recv_seq.load(Ordering::Relaxed);
    let (stash_msgs, stash_bytes) = path.recv_reorder.usage();
    let free_msgs = MAX_WINDOW.saturating_sub(stash_msgs).max(1);
    let byte_credit = match path.recv_stash_high_water() {
        Some(hw) => hw.saturating_sub(stash_bytes) as u64,
        None => u64::MAX,
    };
    Credit {
        advert_id: path.next_credit_advert_id(),
        seq_limit: expected.saturating_add(free_msgs as u64),
        byte_credit,
        budget_msgs: free_msgs as u32,
    }
}

/// Write an ACK frame on stream `s` (flushes immediately). Against a
/// credit-aware peer the ACK is *extended*: the 3 status bytes are
/// followed by a fresh credit block, so every acknowledgement also
/// refreshes the peer's view of this end's receive window.
fn write_ack(
    path: &Path,
    s: usize,
    msg_seq: u64,
    attempt: u32,
    status: u8,
    detail: u16,
) -> Result<()> {
    let d = detail.to_be_bytes();
    if path.peer_credit_aware() {
        let credit = encode_credit(&current_credit(path));
        let mut p = [0u8; 3 + WINDOW_UPDATE_LEN];
        p[0] = status;
        p[1] = d[0];
        p[2] = d[1];
        p[3..].copy_from_slice(&credit);
        write_frame(path, s, KIND_ACK, msg_seq, attempt, SplitBuf::plain(&p), true)
    } else {
        write_frame(
            path,
            s,
            KIND_ACK,
            msg_seq,
            attempt,
            SplitBuf::plain(&[status, d[0], d[1]]),
            true,
        )
    }
}

/// Send a dedicated `WINDOW_UPDATE` frame on the control stream,
/// advertising fresh receive-side credit outside the ACK flow (the
/// stash just shrank and the peer may be blocked on credit). Advisory:
/// write errors are swallowed — every extended ACK carries the same
/// information and a dead control stream is handled by its consumers.
fn advertise_credit(path: &Path) {
    if !path.peer_credit_aware() {
        return;
    }
    let c = current_credit(path);
    let Ok(s) = ctrl_stream(path) else { return };
    // swallow-ok: advisory frame (see doc comment) — every extended ACK
    // carries the same credit information.
    let _ = write_frame(
        path,
        s,
        KIND_WINDOW_UPDATE,
        c.advert_id,
        0,
        SplitBuf::plain(&encode_credit(&c)),
        true,
    );
}

/// Apply a credit block received from the peer: update the send-side
/// credit view (newest advert wins) and narrow the adaptive window
/// tunable to the peer's message budget. Receiving *any* credit also
/// proves the peer speaks the credit revision.
fn apply_peer_credit(path: &Path, c: &Credit) {
    path.note_peer_credit_aware();
    if path.send_credit.apply(c) {
        path.tuning().apply_window_credit((c.budget_msgs as usize).clamp(1, MAX_WINDOW));
    }
}

/// Drain any `WINDOW_UPDATE` frames other consumers parked in the
/// stream inboxes (the receive loop reads frames wanting CTRL and parks
/// foreign kinds there). At most one per stream thanks to the inbox's
/// newest-wins dedup.
fn absorb_window_updates(path: &Path) {
    for s in &path.streams {
        while let Some((_, p)) = s.inbox.take(KIND_WINDOW_UPDATE) {
            if let Ok(c) = parse_credit(&p) {
                apply_peer_credit(path, &c);
            }
        }
    }
}

/// Send one stream's segment as chunked DATA frames.
fn send_segment(
    path: &Path,
    s: usize,
    msg_seq: u64,
    attempt: u32,
    data: SplitBuf<'_>,
    chunk: usize,
) -> Result<()> {
    for c in stripe::chunks(0..data.len(), chunk) {
        let (h, t) = data.slice(c);
        write_frame(path, s, KIND_DATA, msg_seq, attempt, SplitBuf { head: h, tail: t }, false)?;
    }
    path.streams[s].tx.lock().w.flush()?;
    Ok(())
}

/// Fold one already-buffered DATA frame into the segment buffer:
/// returns the new fill level, skipping stale frames from aborted
/// attempts / re-sent messages, erroring on frames from the future.
fn consume_data(
    hdr: FrameHdr,
    payload: &[u8],
    msg_seq: u64,
    attempt: u32,
    out: &mut [u8],
    got: usize,
    s: usize,
) -> Result<usize> {
    if hdr.msg_seq == msg_seq && hdr.attempt == attempt {
        let end = got + payload.len();
        if end > out.len() {
            return Err(MpwError::Protocol(format!(
                "data overrun on stream {s}: segment {} got {end}",
                out.len()
            )));
        }
        out[got..end].copy_from_slice(payload);
        Ok(end)
    } else if hdr.msg_seq < msg_seq || (hdr.msg_seq == msg_seq && hdr.attempt < attempt) {
        // stale frame from an aborted attempt or duplicated message
        Ok(got)
    } else {
        Err(MpwError::Protocol(format!(
            "data frame from the future on stream {s}: msg {} attempt {} while receiving \
             msg {msg_seq} attempt {attempt}",
            hdr.msg_seq, hdr.attempt
        )))
    }
}

/// Receive one stream's segment. Follows the same inbox routing
/// discipline as [`read_frame`], but current-attempt DATA payloads are
/// read **directly into the caller's buffer** — no per-chunk allocation
/// or extra copy on the bulk-transfer hot path; only stale/foreign
/// frames are buffered.
fn recv_segment(path: &Path, s: usize, msg_seq: u64, attempt: u32, out: &mut [u8]) -> Result<()> {
    // Only claim parked DATA that is ours or stale: a pipelining sender
    // can put a *later* message's (or a reposted later attempt's) DATA
    // on this stream, and that frame belongs to whichever worker ends
    // up receiving it — stealing it here would lose the bytes.
    let ours = |h: &FrameHdr| h.msg_seq < msg_seq || (h.msg_seq == msg_seq && h.attempt <= attempt);
    let mut got = 0usize;
    while got < out.len() {
        if let Some((hdr, payload)) = path.streams[s].inbox.take_where(KIND_DATA, ours) {
            got = consume_data(hdr, &payload, msg_seq, attempt, out, got, s)?;
            continue;
        }
        if !path.stream_alive(s) {
            return Err(MpwError::StreamDead { stream: s });
        }
        let mut rx = path.streams[s].rx.lock();
        // Re-check after acquiring: the previous lock holder may have
        // parked a frame for us while we waited.
        if let Some((hdr, payload)) = path.streams[s].inbox.take_where(KIND_DATA, ours) {
            drop(rx);
            got = consume_data(hdr, &payload, msg_seq, attempt, out, got, s)?;
            continue;
        }
        let mut hb = [0u8; FRAME_HDR_LEN];
        rx.read_exact(&mut hb)?;
        let hdr = decode_frame_hdr(&hb)?;
        let len = hdr.len as usize;
        // Fast path only when the payload fits the remaining buffer —
        // an overrun falls through to the buffered path so the stream
        // stays frame-aligned while consume_data reports the error.
        if hdr.kind == KIND_DATA
            && hdr.msg_seq == msg_seq
            && hdr.attempt == attempt
            && got + len <= out.len()
        {
            let end = got + len;
            rx.read_exact(&mut out[got..end])?;
            got = end;
            continue;
        }
        // slow path: stale or foreign frame — buffer, then route or skip
        let mut payload = vec![0u8; len];
        rx.read_exact(&mut payload)?;
        drop(rx);
        if hdr.kind == KIND_DATA && ours(&hdr) {
            got = consume_data(hdr, &payload, msg_seq, attempt, out, got, s)?;
        } else {
            // Foreign kind, or DATA from a pipelined later message /
            // later attempt that overtook us on the wire: park it for
            // its consumer.
            path.streams[s].inbox.push(hdr, payload);
        }
    }
    Ok(())
}

/// Consume and discard an aborted (or duplicated) attempt's DATA
/// frames from the streams this end still considers alive. Without the
/// drain, a sender whose segment workers are parked on TCP backpressure
/// could never finish the attempt's barrier — and therefore never read
/// the NACK/re-ack that tells it to move on. Errors are ignored (dead
/// streams fail fast; the retry protocol owns recovery).
fn drain_attempt(path: &Path, ctrl: &CtrlMsg, msg_seq: u64, attempt: u32) {
    let total = ctrl.total.min(usize::MAX as u64) as usize;
    let segs = stripe::segments(total, ctrl.streams.len());
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ctrl.streams.len());
    for (i, seg) in segs.iter().enumerate() {
        let si = ctrl.streams[i] as usize;
        if seg.is_empty() || !path.stream_alive(si) {
            continue;
        }
        let len = seg.len();
        jobs.push(Box::new(move || {
            // Frame-aligned discard loop: memory stays bounded by one
            // frame (whatever length the CTRL advertised), stale older
            // frames are swallowed, and anything newer — or any other
            // kind — is parked untouched so no live traffic is lost.
            let mut remaining = len;
            // Inbox takes are predicate-filtered so a pipelined later
            // message's parked DATA is never cycled through (a take +
            // push-back would reorder it behind frames parked later).
            let ours = |h: &FrameHdr| {
                h.msg_seq < msg_seq || (h.msg_seq == msg_seq && h.attempt <= attempt)
            };
            while remaining > 0 {
                match read_raw_frame_where(path, si, KIND_DATA, ours) {
                    Ok((h, p)) => {
                        if h.kind == KIND_DATA && h.msg_seq == msg_seq && h.attempt == attempt {
                            remaining = remaining.saturating_sub(p.len().max(1));
                        } else if h.kind == KIND_DATA && ours(&h) {
                            // even older stale frame: discard, keep going
                        } else {
                            // newer traffic or a foreign kind: not ours —
                            // read fresh off the wire, so this park does
                            // not reorder anything already queued
                            path.streams[si].inbox.push(h, p);
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        }));
    }
    crate::util::pool::scope(jobs);
}

/// Validate an ACK payload's length and apply the credit block an
/// extended (31-byte) ACK carries; legacy 3-byte ACKs pass through
/// untouched. Any other length is a protocol violation.
fn absorb_ack_credit(path: &Path, payload: &[u8]) -> Result<()> {
    match payload.len() {
        3 => Ok(()),
        n if n == 3 + WINDOW_UPDATE_LEN => {
            if let Ok(c) = parse_credit(&payload[3..]) {
                apply_peer_credit(path, &c);
            }
            Ok(())
        }
        _ => Err(MpwError::Protocol("malformed ack frame".into())),
    }
}

/// Outcome of the sender's ACK wait.
enum AckOutcome {
    /// Receiver confirmed full delivery.
    Delivered,
    /// Receiver aborted the attempt; `Some(i)` names a stream it found
    /// dead (so the sender can exclude it without waiting for its own
    /// I/O to fail).
    Retry(Option<usize>),
}

fn wait_ack(path: &Path, s: usize, msg_seq: u64, attempt: u32) -> Result<AckOutcome> {
    loop {
        let (hdr, payload) = read_ack_frame(path, s)?;
        if hdr.msg_seq < msg_seq {
            continue; // duplicate ack for an earlier message
        }
        if hdr.msg_seq > msg_seq {
            return Err(MpwError::Protocol(format!(
                "ack for future message {} while waiting on {msg_seq}",
                hdr.msg_seq
            )));
        }
        absorb_ack_credit(path, &payload)?;
        if payload[0] == ACK_OK {
            // any attempt counts: delivery is per message, not per attempt
            return Ok(AckOutcome::Delivered);
        }
        if hdr.attempt < attempt {
            continue; // NACK for an attempt we already abandoned
        }
        let detail = u16::from_be_bytes([payload[1], payload[2]]);
        let dead = if detail == NO_DETAIL || detail as usize >= path.nstreams() {
            None
        } else {
            Some(detail as usize)
        };
        return Ok(AckOutcome::Retry(dead));
    }
}

/// [`read_frame`] specialised for the sender's ACK wait: a duplicate
/// CTRL for an incoming message this end already delivered is
/// re-acknowledged *here* instead of parked — otherwise a peer
/// retransmitting after a lost final ack (while this end is itself
/// blocked in a send, so no `recv` is running to absorb the duplicate)
/// would deadlock both sides.
fn read_ack_frame(path: &Path, s: usize) -> Result<(FrameHdr, Vec<u8>)> {
    loop {
        let (hdr, payload) = read_raw_frame(path, s, KIND_ACK)?;
        if hdr.kind == KIND_ACK {
            return Ok((hdr, payload));
        }
        if hdr.kind == KIND_WINDOW_UPDATE {
            // the receiver refreshed our credit outside the ACK flow:
            // apply in place, keep waiting for the ACK proper
            if let Ok(c) = parse_credit(&payload) {
                apply_peer_credit(path, &c);
            }
            continue;
        }
        if hdr.kind == KIND_CTRL
            && (hdr.msg_seq < path.res_recv_seq.load(Ordering::Relaxed)
                || path.recv_reorder.contains(hdr.msg_seq))
        {
            // retransmission of a message we already delivered — or one
            // already complete in the reorder stash (the peer lost our
            // ack): re-acknowledge in place, then drain the resent data
            // — the peer's segment workers may be parked on TCP
            // backpressure and cannot reach their own ack wait until
            // those bytes are consumed
            // swallow-ok: a lost re-ack is recovered by the sender's
            // retry loop resending the attempt.
            let _ = write_ack(path, s, hdr.msg_seq, hdr.attempt, ACK_OK, NO_DETAIL);
            if let Ok(ctrl) = parse_ctrl(&payload) {
                drain_attempt(path, &ctrl, hdr.msg_seq, hdr.attempt);
            }
            continue;
        }
        path.streams[s].inbox.push(hdr, payload);
    }
}

// ---------------------------------------------------------------------------
// Resilient send / recv.
// ---------------------------------------------------------------------------

fn max_attempts(path: &Path) -> u32 {
    path.nstreams() as u32 * 2 + 8
}

/// Hard (non-retryable) protocol failure: force-close the path before
/// surfacing the error so the peer's blocking reads/ack-waits fail fast
/// instead of hanging in a protocol state this end can no longer
/// advance — the same failure-propagation rule streams follow, applied
/// to the whole path.
fn fatal(path: &Path, e: MpwError) -> MpwError {
    path.shutdown_all_streams();
    e
}

/// Outcome of posting one attempt of a message onto the wire.
enum PostOutcome {
    /// CTRL + every segment fully written; `ctrl` is the control stream
    /// used, `gen` the health generation the post observed.
    Written { ctrl: usize, gen: u64 },
    /// A stream died mid-post (already marked dead); the caller should
    /// re-evaluate liveness and retry with the next attempt number.
    Again,
}

/// Write one attempt of a message: pick the control stream, build the
/// stripe list from the live set, write CTRL (with in-band death
/// gossip), then fan the segments out over the worker pool. Shared by
/// the rendezvous sender and the windowed pipeline — retryable stream
/// deaths come back as [`PostOutcome::Again`], only protocol failures
/// no retry can heal are `Err` (callers wrap those in [`fatal`]).
fn write_attempt(path: &Path, msg_seq: u64, attempt: u32, buf: SplitBuf<'_>) -> Result<PostOutcome> {
    let gen = path.health_generation();
    let live = path.live_stream_indices();
    if live.is_empty() {
        path.wait_for_any_live()?;
        return Ok(PostOutcome::Again);
    }
    let c = match ctrl_stream(path) {
        Ok(c) => c,
        Err(_) => return Ok(PostOutcome::Again), // raced a death; re-evaluate liveness
    };
    let want = path.tuning().active_streams().clamp(1, path.nstreams());
    let k = want.min(live.len());
    let mut used: Vec<u16> = Vec::with_capacity(k);
    used.push(c as u16);
    for &i in &live {
        if i != c && used.len() < k {
            used.push(i as u16);
        }
    }
    let dead: Vec<u16> =
        (0..path.nstreams()).filter(|&i| !path.stream_alive(i)).map(|i| i as u16).collect();
    let ctrl = encode_ctrl(buf.len() as u64, &used, &dead);
    if write_frame(path, c, KIND_CTRL, msg_seq, attempt, SplitBuf::plain(&ctrl), true).is_err() {
        path.mark_stream_dead(c, gen);
        return Ok(PostOutcome::Again);
    }
    // Frames carry a u32 length validated against MAX_FRAME_PAYLOAD on
    // the receiving side; cap the per-frame chunk accordingly.
    let chunk = path.tuning().chunk().min(MAX_FRAME_PAYLOAD);
    let segs = stripe::segments(buf.len(), used.len());
    let mut results: Vec<Result<()>> = Vec::new();
    results.resize_with(used.len(), || Ok(()));
    {
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(used.len());
        for ((&si, seg), out) in used.iter().zip(segs).zip(results.iter_mut()) {
            if seg.is_empty() {
                continue;
            }
            let (h, t) = buf.slice(seg);
            let data = SplitBuf { head: h, tail: t };
            jobs.push(Box::new(move || {
                *out = send_segment(path, si as usize, msg_seq, attempt, data, chunk);
            }));
        }
        crate::util::pool::scope(jobs);
    }
    let mut failed = false;
    for (&si, r) in used.iter().zip(&results) {
        if let Err(e) = r {
            match e {
                MpwError::Io(_) | MpwError::StreamDead { .. } => {
                    path.mark_stream_dead(si as usize, gen);
                    failed = true;
                }
                // a protocol error cannot be healed by retrying
                _ => return Err(MpwError::Protocol(format!("send worker failed: {e}"))),
            }
        }
    }
    if failed {
        Ok(PostOutcome::Again)
    } else {
        Ok(PostOutcome::Written { ctrl: c, gen })
    }
}

/// Resilient `MPW_Send`: stripe over the live streams, isolate failures,
/// retry over survivors until the receiver confirms delivery. Caller
/// holds the path's send gate. The message is a [`SplitBuf`] so a
/// framing layer's header + payload need no concatenation (plain sends
/// pass `SplitBuf::plain`).
///
/// With [`ResilienceConfig::window`](super::config::ResilienceConfig::window)
/// `== 1` this is a rendezvous send (returns only after the ACK). With
/// a wider window it *posts* the message and returns, reaping
/// acknowledgements as the window fills — see the module docs.
pub(crate) fn send(path: &Path, buf: SplitBuf<'_>) -> Result<usize> {
    if path.send_window_limit() <= 1 {
        // The window may have been narrowed at runtime (autotuner or
        // reconfiguration) while messages were still in flight: drain
        // them first so rendezvous ordering is restored before this
        // message posts.
        drain_window(path)?;
        send_rendezvous(path, buf)
    } else {
        send_windowed(path, buf)
    }
}

/// One-message-at-a-time resilient send: post, wait for the ACK, retry
/// on NACK / stream death. The original MPWide pairing discipline.
fn send_rendezvous(path: &Path, buf: SplitBuf<'_>) -> Result<usize> {
    let t0 = Instant::now();
    let msg_seq = path.res_send_seq.load(Ordering::Relaxed);
    for attempt in 0..max_attempts(path) {
        let (c, gen) = match write_attempt(path, msg_seq, attempt, buf) {
            Ok(PostOutcome::Written { ctrl, gen }) => (ctrl, gen),
            Ok(PostOutcome::Again) => continue,
            Err(e) => return Err(fatal(path, e)),
        };
        // The ACK wait is the one place the sender can block on a stream
        // the peer may no longer be watching (the divergence window); a
        // configured progress timeout force-closes the control stream so
        // the wait fails over to the normal retry path.
        let ack = if let Some(t) = path.ack_timeout() {
            let kill = path.streams[c].meta.lock().kill.clone();
            let token = match path.ack_watchdog.arm(kill, t) {
                Ok(tok) => tok,
                Err(e) => return Err(fatal(path, e)),
            };
            let r = wait_ack(path, c, msg_seq, attempt);
            path.ack_watchdog.disarm(token);
            r
        } else {
            wait_ack(path, c, msg_seq, attempt)
        };
        match ack {
            Ok(AckOutcome::Delivered) => {
                path.res_send_seq.fetch_add(1, Ordering::Relaxed);
                path.observe_send(buf.len(), t0.elapsed());
                return Ok(buf.len());
            }
            Ok(AckOutcome::Retry(dead)) => {
                if let Some(d) = dead {
                    path.mark_stream_dead(d, gen);
                }
                continue;
            }
            Err(MpwError::Io(_)) | Err(MpwError::StreamDead { .. }) => {
                path.mark_stream_dead(c, gen);
                continue;
            }
            Err(e) => return Err(fatal(path, e)),
        }
    }
    Err(fatal(
        path,
        MpwError::Protocol(format!("resilient send of message {msg_seq} did not converge")),
    ))
}

// ---------------------------------------------------------------------------
// Windowed (pipelined) sender.
// ---------------------------------------------------------------------------

/// One posted-but-unacknowledged message in the send window.
struct Posted {
    /// Its sequence number.
    seq: u64,
    /// Attempt number of the last full post (retries bump it).
    attempt: u32,
    /// Owned retransmit copy — selective retry needs the bytes after
    /// the caller's `send` has long returned.
    data: Vec<u8>,
    /// When the message was first posted (goodput accounting).
    t0: Instant,
}

/// Mutable state of the windowed sender, guarded by [`SendWindow`]'s
/// mutex (which is uncontended in practice — the path's send gate
/// already serializes senders; the mutex exists for interior
/// mutability and the occasional `flush` from another thread).
#[derive(Default)]
struct SendState {
    /// In-flight messages, oldest first.
    outstanding: VecDeque<Posted>,
    /// A terminal pipeline failure, replayed (as a Protocol error) on
    /// every later send/flush: the failed message was reported complete
    /// to its caller, so the path cannot silently resume.
    poisoned: Option<String>,
}

/// Sliding-window state of a path's resilient sender (a Path field;
/// empty and inert while `window == 1`).
pub(crate) struct SendWindow {
    win_st: OrderedMutex<SendState>,
}

impl Default for SendWindow {
    fn default() -> Self {
        SendWindow { win_st: OrderedMutex::new(rank::SEND_WINDOW, SendState::default()) }
    }
}

impl SendWindow {
    /// Number of posted-but-unacknowledged messages.
    pub(crate) fn in_flight(&self) -> usize {
        self.win_st.lock().outstanding.len()
    }
}

/// The sender's view of the peer's advertised receive credit (a Path
/// field). Starts unlimited — against a legacy (pre-credit) peer no
/// advert ever arrives and the hard [`MAX_WINDOW`] bound remains the
/// only constraint, which is exactly the pre-credit protocol.
pub(crate) struct SendCredit {
    credit_st: OrderedMutex<Credit>,
}

impl Default for SendCredit {
    fn default() -> Self {
        SendCredit {
            credit_st: OrderedMutex::new(
                rank::SEND_CREDIT,
                Credit {
                    advert_id: 0,
                    seq_limit: u64::MAX,
                    byte_credit: u64::MAX,
                    budget_msgs: MAX_WINDOW as u32,
                },
            ),
        }
    }
}

impl SendCredit {
    /// Apply an advert if it is newer than the last applied one
    /// (adverts are absolute; out-of-order stale ones are dropped).
    /// Returns whether it was applied.
    fn apply(&self, c: &Credit) -> bool {
        let mut g = self.credit_st.lock();
        if c.advert_id > g.advert_id {
            *g = *c;
            true
        } else {
            false
        }
    }

    /// Current `(seq_limit, byte_credit)` pair.
    fn limits(&self) -> (u64, u64) {
        let g = self.credit_st.lock();
        (g.seq_limit, g.byte_credit)
    }
}

fn poisoned_err(msg: &str) -> MpwError {
    MpwError::Protocol(format!("windowed send pipeline failed: {msg}"))
}

/// Record a terminal pipeline failure: drop the in-flight set (their
/// delivery can no longer be confirmed) and remember the error for
/// every later operation on this path.
fn poison(st: &mut SendState, e: &MpwError) {
    st.outstanding.clear();
    if st.poisoned.is_none() {
        st.poisoned = Some(e.to_string());
    }
}

/// Post `msg_seq` until one attempt gets CTRL + all segments onto the
/// wire, starting from attempt `start`; returns the attempt number that
/// succeeded. Shares the rendezvous sender's per-message attempt
/// budget.
fn post_attempt(path: &Path, msg_seq: u64, start: u32, data: &[u8]) -> Result<u32> {
    let mut attempt = start;
    while attempt < max_attempts(path) {
        match write_attempt(path, msg_seq, attempt, SplitBuf::plain(data))? {
            PostOutcome::Written { .. } => return Ok(attempt),
            PostOutcome::Again => attempt += 1,
        }
    }
    Err(MpwError::Protocol(format!("resilient send of message {msg_seq} did not converge")))
}

/// Repost every in-flight message, oldest first, after losing the ACK
/// channel: we cannot know which of them the receiver delivered, and
/// duplicates are re-acknowledged by sequence number on the other end.
fn repost_all(path: &Path, st: &mut SendState) -> Result<()> {
    for slot in st.outstanding.iter_mut() {
        let a = post_attempt(path, slot.seq, slot.attempt + 1, &slot.data)?;
        slot.attempt = a;
    }
    Ok(())
}

/// Block until the in-flight set shrinks below its entry size (at least
/// one message reaped) or the pipeline fails. Selective retry: a NACK
/// reposts only the named message over the survivors; losing the ACK
/// channel itself reposts everything. A configured
/// [`ack_timeout`](super::config::ResilienceConfig::ack_timeout) is
/// applied as an *oldest-unacked progress* deadline — re-armed only
/// when the head of the window (or the control stream under it)
/// changes, so acks for younger messages never extend it.
fn reap_some(path: &Path, st: &mut SendState) -> Result<()> {
    let want_below = st.outstanding.len();
    // Convergence budget: every round either reaps, reposts after a
    // marked death, or absorbs a stale/duplicate ack — and there are at
    // most MAX_WINDOW in-flight messages and max_attempts stream
    // failures to burn through.
    let budget = max_attempts(path) + 2 * MAX_WINDOW as u32;
    let mut armed: Option<(u64, u64, usize)> = None; // (token, oldest seq, ctrl)
    let mut round = 0u32;
    let result = loop {
        if st.outstanding.len() < want_below {
            break Ok(());
        }
        if round >= budget {
            break Err(MpwError::Protocol("windowed resilient send did not converge".into()));
        }
        round += 1;
        let gen = path.health_generation();
        if path.live_stream_indices().is_empty() {
            if let Some((t, _, _)) = armed.take() {
                path.ack_watchdog.disarm(t);
            }
            match path.wait_for_any_live().and_then(|()| repost_all(path, st)) {
                Ok(()) => continue,
                Err(e) => break Err(e),
            }
        }
        let c = match ctrl_stream(path) {
            Ok(c) => c,
            Err(_) => continue, // raced a death; re-evaluate liveness
        };
        if let Some(t) = path.ack_timeout() {
            let oldest = st.outstanding.front().map(|p| p.seq).unwrap_or(0);
            let rearm = armed.map(|(_, s, cc)| s != oldest || cc != c).unwrap_or(true);
            if rearm {
                if let Some((tok, _, _)) = armed.take() {
                    path.ack_watchdog.disarm(tok);
                }
                let kill = path.streams[c].meta.lock().kill.clone();
                match path.ack_watchdog.arm(kill, t) {
                    Ok(tok) => armed = Some((tok, oldest, c)),
                    Err(e) => break Err(e),
                }
            }
        }
        let (hdr, payload) = match read_ack_frame(path, c) {
            Ok(f) => f,
            Err(MpwError::Io(_)) | Err(MpwError::StreamDead { .. }) => {
                if let Some((t, _, _)) = armed.take() {
                    path.ack_watchdog.disarm(t);
                }
                path.mark_stream_dead(c, gen);
                match repost_all(path, st) {
                    Ok(()) => continue,
                    Err(e) => break Err(e),
                }
            }
            Err(e) => break Err(e),
        };
        if let Err(e) = absorb_ack_credit(path, &payload) {
            break Err(e);
        }
        let pos = match st.outstanding.iter().position(|p| p.seq == hdr.msg_seq) {
            Some(p) => p,
            None => continue, // duplicate ack for an already-reaped message
        };
        if payload[0] == ACK_OK {
            // any attempt counts: delivery is per message, not per attempt
            if let Some(p) = st.outstanding.remove(pos) {
                path.observe_send(p.data.len(), p.t0.elapsed());
            }
            continue;
        }
        if hdr.attempt < st.outstanding[pos].attempt {
            continue; // NACK for an attempt we already abandoned
        }
        let detail = u16::from_be_bytes([payload[1], payload[2]]);
        if detail == DETAIL_STASH_FULL {
            // The receiver's reorder stash is byte-full — no stream
            // failed. Back off briefly so the repost below does not turn
            // into a NACK storm while the peer's consumer catches up
            // (fresh credit arrives with every ACK it sends).
            std::thread::sleep(Duration::from_millis(1));
        } else if detail != NO_DETAIL && (detail as usize) < path.nstreams() {
            path.mark_stream_dead(detail as usize, gen);
        }
        // Selective retry: only the NACKed message goes out again.
        let next = st.outstanding[pos].attempt + 1;
        match post_attempt(path, st.outstanding[pos].seq, next, &st.outstanding[pos].data) {
            Ok(a) => st.outstanding[pos].attempt = a,
            Err(e) => break Err(e),
        }
    };
    if let Some((t, _, _)) = armed {
        path.ack_watchdog.disarm(t);
    }
    result
}

/// Whether the peer's advertised credit admits posting one more message
/// of `len` bytes right now. The oldest in-flight message is excluded
/// from the byte accounting: it is delivered in order, straight into
/// the peer caller's buffer, and never enters the reorder stash.
/// Liveness: an empty pipeline always admits — posting is the only way
/// to provoke the ACKs that carry fresh credit.
fn credit_allows(path: &Path, st: &SendState, len: usize) -> bool {
    if st.outstanding.is_empty() {
        return true;
    }
    let (seq_limit, byte_credit) = path.send_credit.limits();
    if path.res_send_seq.load(Ordering::Relaxed) > seq_limit {
        return false;
    }
    if byte_credit < u64::MAX {
        let stashable =
            st.outstanding.iter().skip(1).map(|p| p.data.len() as u64).sum::<u64>() + len as u64;
        if stashable > byte_credit {
            return false;
        }
    }
    true
}

/// Pipelined resilient send: reap until the window has a free slot and
/// the peer's credit admits the message, post it (keeping an owned copy
/// for retransmission), and return without waiting for its ACK. The
/// window limit is re-read per round — a credit advert can narrow the
/// tunable while we block.
fn send_windowed(path: &Path, buf: SplitBuf<'_>) -> Result<usize> {
    let t0 = Instant::now();
    let mut st = path.send_window.win_st.lock();
    if let Some(msg) = &st.poisoned {
        return Err(poisoned_err(msg));
    }
    absorb_window_updates(path);
    while st.outstanding.len() >= path.send_window_limit() || !credit_allows(path, &st, buf.len())
    {
        if let Err(e) = reap_some(path, &mut st) {
            poison(&mut st, &e);
            return Err(fatal(path, e));
        }
    }
    let msg_seq = path.res_send_seq.load(Ordering::Relaxed);
    let mut data = Vec::with_capacity(buf.len());
    data.extend_from_slice(buf.head);
    data.extend_from_slice(buf.tail);
    match post_attempt(path, msg_seq, 0, &data) {
        Ok(a) => {
            path.res_send_seq.fetch_add(1, Ordering::Relaxed);
            st.outstanding.push_back(Posted { seq: msg_seq, attempt: a, data, t0 });
            Ok(buf.len())
        }
        Err(e) => {
            poison(&mut st, &e);
            Err(fatal(path, e))
        }
    }
}

/// Drain the send window: block until every posted message is
/// acknowledged or the pipeline fails. No-op when nothing is in flight
/// (including every `window == 1` path). Called from `Path::flush`,
/// `Path::barrier`, the mux pump's idle drain, and the rendezvous
/// fallback after a runtime window narrowing.
pub(crate) fn drain_window(path: &Path) -> Result<()> {
    let mut st = path.send_window.win_st.lock();
    if st.outstanding.is_empty() && st.poisoned.is_none() {
        return Ok(());
    }
    if let Some(msg) = &st.poisoned {
        return Err(poisoned_err(msg));
    }
    while !st.outstanding.is_empty() {
        if let Err(e) = reap_some(path, &mut st) {
            poison(&mut st, &e);
            return Err(fatal(path, e));
        }
    }
    Ok(())
}

/// Destination of a resilient receive.
pub(crate) enum RecvTarget<'a> {
    /// Fixed-size receive: the message length must match exactly.
    Fixed(&'a mut [u8]),
    /// Dynamic receive into a growable cache (`MPW_DRecv` semantics —
    /// the length travels in the CTRL frame, no separate header needed).
    Dynamic(&'a mut Vec<u8>),
}

/// Receiver-side stash for messages a pipelining sender completed out
/// of turn: a selective retry can finish `seq + 1` before `seq`
/// arrives intact. Keyed by sequence number; bounded by [`MAX_WINDOW`]
/// entries because the receiver rejects CTRLs beyond `expected +
/// MAX_WINDOW` (no sender can legally have more in flight). A Path
/// field; empty and inert against rendezvous peers.
pub(crate) struct ReorderBuf {
    stash: OrderedMutex<StashState>,
}

/// Stash map plus its running byte total (the byte high-water check and
/// the credit adverts both need the total without a walk).
#[derive(Default)]
struct StashState {
    map: HashMap<u64, Vec<u8>>,
    bytes: usize,
}

impl Default for ReorderBuf {
    fn default() -> Self {
        ReorderBuf { stash: OrderedMutex::new(rank::RECV_REORDER, StashState::default()) }
    }
}

impl ReorderBuf {
    /// Whether `seq` is already complete in the stash (its sender must
    /// be re-acknowledged, not re-served).
    pub(crate) fn contains(&self, seq: u64) -> bool {
        self.stash.lock().map.contains_key(&seq)
    }

    /// Whether `additional` more bytes fit under `budget`. An empty
    /// stash always fits: a single message larger than the budget must
    /// still be acceptable or it could never be delivered at all.
    fn fits(&self, additional: usize, budget: Option<usize>) -> bool {
        match budget {
            None => true,
            Some(b) => {
                let g = self.stash.lock();
                g.map.is_empty() || g.bytes.saturating_add(additional) <= b
            }
        }
    }

    fn insert(&self, seq: u64, data: Vec<u8>) {
        let mut g = self.stash.lock();
        g.bytes += data.len();
        if let Some(old) = g.map.insert(seq, data) {
            g.bytes -= old.len();
        }
    }

    fn remove(&self, seq: u64) -> Option<Vec<u8>> {
        let mut g = self.stash.lock();
        let v = g.map.remove(&seq);
        if let Some(v) = &v {
            g.bytes -= v.len();
        }
        v
    }

    /// `(messages, bytes)` currently stashed.
    pub(crate) fn usage(&self) -> (usize, usize) {
        let g = self.stash.lock();
        (g.map.len(), g.bytes)
    }
}

/// Copy a stashed (already fully received) message into the caller's
/// target, enforcing the same length contract as a wire delivery.
fn deliver_stashed(target: &mut RecvTarget<'_>, data: Vec<u8>) -> Result<usize> {
    match target {
        RecvTarget::Fixed(b) => {
            if data.len() != b.len() {
                return Err(MpwError::Protocol(format!(
                    "message length {} does not match posted recv of {} bytes",
                    data.len(),
                    b.len()
                )));
            }
            b.copy_from_slice(&data);
            Ok(data.len())
        }
        RecvTarget::Dynamic(v) => {
            let t = data.len();
            if v.len() < t {
                v.resize(t, 0);
            }
            v[..t].copy_from_slice(&data);
            Ok(t)
        }
    }
}

/// Post-delivery bookkeeping shared by wire and stash deliveries:
/// advance the expected sequence, then purge parked DATA duplicates of
/// the delivered prefix (reposts that raced the delivery would
/// otherwise sit in the inboxes forever).
fn finish_delivery(path: &Path, delivered: u64) {
    path.res_recv_seq.fetch_add(1, Ordering::Relaxed);
    for s in &path.streams {
        s.inbox.purge_data_through(delivered);
    }
}

/// Fan one attempt's striped segment receive out over the worker pool.
/// Returns `Ok(None)` when the message is complete in `buf`,
/// `Ok(Some(s))` when stream `s` died mid-receive (the caller NACKs
/// naming it), and `Err` only for protocol failures no retry can heal
/// (the caller wraps those in [`fatal`]).
fn recv_attempt_body(
    path: &Path,
    ctrl: &CtrlMsg,
    msg_seq: u64,
    attempt: u32,
    gen: u64,
    buf: &mut [u8],
) -> Result<Option<usize>> {
    // Split the buffer into disjoint per-stream segments (same
    // arithmetic as the sender's stripe::segments call), mapped to
    // the ctrl frame's explicit stream indices.
    let parts: Vec<(usize, &mut [u8])> = stripe::split_mut(buf, ctrl.streams.len())
        .into_iter()
        .enumerate()
        .filter(|(_, head)| !head.is_empty())
        .map(|(i, head)| (ctrl.streams[i] as usize, head))
        .collect();
    let part_streams: Vec<usize> = parts.iter().map(|(s, _)| *s).collect();
    let mut results: Vec<Result<()>> = Vec::new();
    results.resize_with(parts.len(), || Ok(()));
    {
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(parts.len());
        for ((si, part), out) in parts.into_iter().zip(results.iter_mut()) {
            jobs.push(Box::new(move || {
                *out = recv_segment(path, si, msg_seq, attempt, part);
            }));
        }
        crate::util::pool::scope(jobs);
    }
    let mut first_dead: Option<usize> = None;
    for (&si, r) in part_streams.iter().zip(&results) {
        if let Err(e) = r {
            match e {
                MpwError::Io(_) | MpwError::StreamDead { .. } => {
                    path.mark_stream_dead(si, gen);
                    first_dead.get_or_insert(si);
                }
                _ => return Err(MpwError::Protocol(format!("recv worker failed: {e}"))),
            }
        }
    }
    Ok(first_dead)
}

/// Resilient `MPW_Recv`: follow the sender's CTRL stream list, isolate
/// failed streams, NACK aborted attempts and deliver exactly once.
/// Caller holds the path's recv gate.
///
/// Sequence discipline against a pipelining sender: the expected
/// message is received straight into the caller's buffer; a message up
/// to [`MAX_WINDOW`] ahead of it (the peer's window, or a selective
/// retry that overtook the head) is received into a side buffer,
/// acknowledged, and stashed until its turn; anything beyond that
/// bound is a protocol violation.
pub(crate) fn recv(path: &Path, mut target: RecvTarget<'_>) -> Result<usize> {
    let msg_seq = path.res_recv_seq.load(Ordering::Relaxed);
    // An earlier recv may have completed this message out of turn:
    // deliver from the stash without touching the wire.
    if let Some(data) = path.recv_reorder.remove(msg_seq) {
        let total = deliver_stashed(&mut target, data).map_err(|e| fatal(path, e))?;
        finish_delivery(path, msg_seq);
        // The stash just shrank and no ACK is due (the message was
        // acknowledged when stashed): push the freed credit to a peer
        // that may be blocked on it.
        advertise_credit(path);
        return Ok(total);
    }
    // Beyond the rendezvous budget, each round may also complete one of
    // the peer's up-to-MAX_WINDOW pipelined future messages (stashed,
    // not delivered) or absorb its duplicate.
    for _round in 0..max_attempts(path) + 2 * MAX_WINDOW as u32 {
        let gen = path.health_generation();
        if path.live_stream_indices().is_empty() {
            path.wait_for_any_live()?;
            continue;
        }
        let c = match ctrl_stream(path) {
            Ok(c) => c,
            Err(_) => continue,
        };
        let (hdr, payload) = match read_frame(path, c, KIND_CTRL) {
            Ok(f) => f,
            Err(MpwError::Io(_)) | Err(MpwError::StreamDead { .. }) => {
                path.mark_stream_dead(c, gen);
                continue;
            }
            Err(e) => return Err(fatal(path, e)),
        };
        let ctrl = parse_ctrl(&payload).map_err(|e| fatal(path, e))?;
        if hdr.msg_seq < msg_seq || path.recv_reorder.contains(hdr.msg_seq) {
            // duplicate of an already-delivered (or already-stashed)
            // message — our ack was lost: re-acknowledge, then drain the
            // retransmission so the sender is not left parked on
            // backpressure mid-resend
            // swallow-ok: a lost re-ack is recovered by the sender's
            // retry loop resending the attempt.
            let _ = write_ack(path, c, hdr.msg_seq, hdr.attempt, ACK_OK, NO_DETAIL);
            drain_attempt(path, &ctrl, hdr.msg_seq, hdr.attempt);
            continue;
        }
        if hdr.msg_seq > msg_seq + MAX_WINDOW as u64 {
            let e = MpwError::Protocol(format!(
                "ctrl for message {} beyond any valid send window while expecting {msg_seq}",
                hdr.msg_seq
            ));
            return Err(fatal(path, e));
        }
        if ctrl.streams.is_empty()
            || ctrl.streams.len() > path.nstreams()
            || ctrl.streams.iter().any(|&i| (i as usize) >= path.nstreams())
        {
            let e = MpwError::Protocol(format!(
                "ctrl stream list invalid on a {}-stream path",
                path.nstreams()
            ));
            return Err(fatal(path, e));
        }
        // Duplicates would put two segment readers on one stream's rx,
        // interleaving their frames arbitrarily — reject like any other
        // malformed list.
        let mut listed = vec![false; path.nstreams()];
        for &i in &ctrl.streams {
            if std::mem::replace(&mut listed[i as usize], true) {
                let e = MpwError::Protocol(format!("ctrl stream list names stream {i} twice"));
                return Err(fatal(path, e));
            }
        }
        // Apply the sender's death gossip: failures only the sender could
        // observe (its writes failed, and degraded striping means it will
        // never touch the stream again) would otherwise leave our slot
        // alive forever — blocking the rejoin daemon from ever accepting
        // the reconnect.
        for &d in &ctrl.dead {
            if (d as usize) < path.nstreams() && path.stream_alive(d as usize) {
                // swallow-ok: only fails on an out-of-range index, which
                // the guard above already excludes.
                let _ = path.inject_stream_failure(d as usize);
            }
        }
        // If the sender picked a stream we already know is dead, short-cut
        // with a NACK naming it — this is how a receiver-side-only failure
        // (sender's writes still "succeed" into the dying socket) routes
        // the sender around the stream without waiting for its I/O error.
        // The aborted attempt is then *drained*: the sender's segment
        // workers may be parked on TCP backpressure writing the healthy
        // streams, and its retry barrier cannot complete (nor the NACK be
        // read) until someone consumes those bytes.
        if let Some(&d) = ctrl.streams.iter().find(|&&i| !path.stream_alive(i as usize)) {
            // swallow-ok: a lost NACK leaves the sender to hit its own
            // I/O error or ack timeout; the retry converges either way.
            let _ = write_ack(path, c, hdr.msg_seq, hdr.attempt, ACK_RETRY, d);
            drain_attempt(path, &ctrl, hdr.msg_seq, hdr.attempt);
            continue;
        }
        if hdr.msg_seq == msg_seq {
            // The expected message: receive straight into the caller's
            // buffer — no extra copy on the hot path.
            let buf: &mut [u8] = match &mut target {
                RecvTarget::Fixed(b) => {
                    if ctrl.total != b.len() as u64 {
                        let e = MpwError::Protocol(format!(
                            "message length {} does not match posted recv of {} bytes",
                            ctrl.total,
                            b.len()
                        ));
                        return Err(fatal(path, e));
                    }
                    &mut b[..]
                }
                RecvTarget::Dynamic(v) => {
                    if ctrl.total > super::dynamic::MAX_DYNAMIC {
                        let e = MpwError::Protocol(format!(
                            "dynamic message length {} too large",
                            ctrl.total
                        ));
                        return Err(fatal(path, e));
                    }
                    let t = ctrl.total as usize;
                    if v.len() < t {
                        v.resize(t, 0);
                    }
                    &mut v[..t]
                }
            };
            let total = buf.len();
            match recv_attempt_body(path, &ctrl, msg_seq, hdr.attempt, gen, buf) {
                Err(e) => return Err(fatal(path, e)),
                Ok(Some(d)) => {
                    // swallow-ok: a lost NACK leaves the sender to hit
                    // its own I/O error or ack timeout; retry converges.
                    let _ = write_ack(path, c, msg_seq, hdr.attempt, ACK_RETRY, d as u16);
                    continue;
                }
                Ok(None) => {
                    if write_ack(path, c, msg_seq, hdr.attempt, ACK_OK, NO_DETAIL).is_err() {
                        // The message is delivered; a failed ack only means
                        // the sender will retransmit, and the duplicate is
                        // absorbed by the stale-ctrl branch of the next recv.
                        path.mark_stream_dead(c, gen);
                    }
                    finish_delivery(path, msg_seq);
                    return Ok(total);
                }
            }
        }
        // A future message within the window: the sender pipelined ahead,
        // or a selective retry overtook the expected head. Receive it
        // into a side buffer (its length contract is its own, not the
        // posted target's), acknowledge, stash for its turn.
        if ctrl.total > super::dynamic::MAX_DYNAMIC {
            let e = MpwError::Protocol(format!(
                "pipelined message length {} too large",
                ctrl.total
            ));
            return Err(fatal(path, e));
        }
        // Byte high-water on the stash: reject the out-of-turn message
        // *before* buffering it — NACK with the stash-full detail (no
        // stream died; the sender reposts once credit frees up) and
        // drain the attempt so the sender's parked segment writers can
        // reach their ACK wait. Checked at CTRL time so memory stays
        // bounded by the budget plus one in-order message.
        if !path.recv_reorder.fits(ctrl.total as usize, path.recv_stash_high_water()) {
            // swallow-ok: a lost stash-full NACK degrades to the
            // sender's ack timeout; the repost converges either way.
            let _ = write_ack(path, c, hdr.msg_seq, hdr.attempt, ACK_RETRY, DETAIL_STASH_FULL);
            drain_attempt(path, &ctrl, hdr.msg_seq, hdr.attempt);
            continue;
        }
        let mut side = vec![0u8; ctrl.total as usize];
        match recv_attempt_body(path, &ctrl, hdr.msg_seq, hdr.attempt, gen, &mut side) {
            Err(e) => return Err(fatal(path, e)),
            Ok(Some(d)) => {
                // swallow-ok: a lost NACK leaves the sender to hit its
                // own I/O error or ack timeout; retry converges.
                let _ = write_ack(path, c, hdr.msg_seq, hdr.attempt, ACK_RETRY, d as u16);
                continue;
            }
            Ok(None) => {
                if write_ack(path, c, hdr.msg_seq, hdr.attempt, ACK_OK, NO_DETAIL).is_err() {
                    path.mark_stream_dead(c, gen);
                }
                path.recv_reorder.insert(hdr.msg_seq, side);
                continue;
            }
        }
    }
    Err(fatal(
        path,
        MpwError::Protocol(format!("resilient recv of message {msg_seq} did not converge")),
    ))
}

// ---------------------------------------------------------------------------
// Background rejoin: client-side reconnect monitor.
// ---------------------------------------------------------------------------

/// Background thread that redials dead streams of a *connecting-end*
/// path according to its
/// [`ReconnectPolicy`](super::config::ReconnectPolicy). Dropping the
/// monitor stops the thread (without blocking on in-flight attempts).
pub struct ReconnectMonitor {
    stop: Arc<AtomicBool>,
    weak: Weak<Path>,
    handle: Option<JoinHandle<()>>,
}

/// Spawn a reconnect monitor for `path`. The monitor holds only a weak
/// reference: it exits on its own when the path is dropped. Fails only
/// when the OS refuses to spawn the monitor thread.
pub fn spawn_reconnect_monitor(path: &Arc<Path>) -> Result<ReconnectMonitor> {
    let weak = Arc::downgrade(path);
    let stop = Arc::new(AtomicBool::new(false));
    let (w2, s2) = (weak.clone(), stop.clone());
    let handle = std::thread::Builder::new()
        .name("mpwide-rejoin".into())
        .spawn(move || monitor_loop(w2, s2))?;
    Ok(ReconnectMonitor { stop, weak, handle: Some(handle) })
}

/// Per-stream reconnect bookkeeping of the monitor.
struct StreamBackoff {
    attempts: u32,
    delay: Duration,
    /// Earliest time the next attempt may run (what actually enforces
    /// the exponential backoff — condvar wakeups arrive much faster).
    next_at: Instant,
}

fn monitor_loop(weak: Weak<Path>, stop: Arc<AtomicBool>) {
    let mut backoff: HashMap<usize, StreamBackoff> = HashMap::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let path = match weak.upgrade() {
            Some(p) => p,
            None => return,
        };
        if path.is_closed() {
            return;
        }
        let policy = path.reconnect_policy();
        let remote = path.remote_endpoint();
        let has_remote = remote.is_some();
        if !policy.enabled {
            // stale entries must not drive the wakeup schedule below
            backoff.clear();
        }
        if policy.enabled {
            if let Some((addr, uuid)) = remote {
                let n = path.nstreams();
                for i in 0..n {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    if path.stream_alive(i) {
                        backoff.remove(&i);
                        continue;
                    }
                    let now = Instant::now();
                    let st = backoff.entry(i).or_insert(StreamBackoff {
                        attempts: 0,
                        delay: policy.base_delay,
                        next_at: now,
                    });
                    if policy.max_attempts > 0 && st.attempts >= policy.max_attempts {
                        continue;
                    }
                    if now < st.next_at {
                        continue; // backoff window still open
                    }
                    st.attempts += 1;
                    match reconnect_stream(&addr, uuid, i as u16, n as u16, policy.connect_timeout)
                        .and_then(|pair| path.reinstall_stream(i, pair))
                    {
                        Ok(()) => {
                            backoff.remove(&i);
                        }
                        Err(_) => {
                            st.next_at = Instant::now() + st.delay;
                            st.delay = (st.delay * 2).min(policy.max_delay);
                        }
                    }
                }
            }
        }
        // Sleep until the next backoff expiry or a health change (a death
        // notification wakes the monitor immediately — attempts stay
        // gated by next_at either way). Streams whose attempt budget is
        // exhausted no longer schedule wakeups, and a monitor that can
        // never act (policy disabled, or an accepted-side path with no
        // remote to redial) idles at a slow heartbeat. The wait stays
        // bounded — not indefinite — because the periodic weak-upgrade
        // check is what lets the thread die with its path.
        let idle = !policy.enabled || !has_remote;
        let now = Instant::now();
        let pending = backoff
            .values()
            .filter(|s| policy.max_attempts == 0 || s.attempts < policy.max_attempts)
            .map(|s| s.next_at.saturating_duration_since(now))
            .min();
        let wait = match pending {
            Some(d) if !idle => d.clamp(Duration::from_millis(5), Duration::from_millis(500)),
            // healthy path, disabled policy, no remote, or exhausted
            // budgets: slow heartbeat (deaths notify the condvar anyway;
            // the periodic wake only services the weak/stop liveness
            // checks)
            _ => Duration::from_secs(2),
        };
        let g = path.health.sync.lock();
        drop(path.health.cv.wait_timeout(g, wait));
        drop(path);
    }
}

impl Drop for ReconnectMonitor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(p) = self.weak.upgrade() {
            let _g = p.health.sync.lock();
            p.health.cv.notify_all();
        }
        // Detach rather than join: an in-flight reconnect attempt may be
        // mid connect_timeout; the thread exits at its next stop check.
        self.handle = None;
    }
}

/// Convenience for the common client setup: connect a path, wrap it in
/// an `Arc` and start its reconnect monitor.
pub fn connect_with_rejoin(
    host: &str,
    port: u16,
    cfg: super::config::PathConfig,
) -> Result<(Arc<Path>, ReconnectMonitor)> {
    let path = Arc::new(Path::connect(host, port, cfg)?);
    let monitor = spawn_reconnect_monitor(&path)?;
    Ok((path, monitor))
}

// ---------------------------------------------------------------------------
// Background rejoin: server-side daemon.
// ---------------------------------------------------------------------------

/// Accepted paths a listener is willing to rejoin streams into, keyed by
/// path uuid.
pub struct RejoinRegistry {
    map: OrderedMutex<HashMap<u64, Weak<Path>>>,
}

impl Default for RejoinRegistry {
    fn default() -> Self {
        RejoinRegistry { map: OrderedMutex::new(rank::REJOIN_REGISTRY, HashMap::new()) }
    }
}

impl RejoinRegistry {
    /// Register a path under its uuid (called by
    /// [`PathListener::accept_path_arc`](super::path::PathListener::accept_path_arc)).
    pub fn register(&self, uuid: u64, path: &Arc<Path>) {
        let mut m = self.map.lock();
        m.retain(|_, w| w.strong_count() > 0);
        m.insert(uuid, Arc::downgrade(path));
    }

    /// Look up a registered, still-alive path.
    pub fn lookup(&self, uuid: u64) -> Option<Arc<Path>> {
        self.map.lock().get(&uuid).and_then(Weak::upgrade)
    }
}

/// Background acceptor that routes reconnecting streams back into their
/// paths: a hello whose uuid matches a registered path replaces that
/// path's dead stream at the hello's index. Unknown uuids are dropped.
///
/// Created with
/// [`PathListener::into_rejoin_daemon`](super::path::PathListener::into_rejoin_daemon)
/// once all expected paths have been accepted.
pub struct RejoinDaemon {
    stop: Arc<AtomicBool>,
    port: u16,
    handle: Option<JoinHandle<()>>,
}

impl RejoinDaemon {
    pub(crate) fn spawn(
        mut raw: RawPathListener,
        registry: Arc<RejoinRegistry>,
    ) -> Result<RejoinDaemon> {
        let stop = Arc::new(AtomicBool::new(false));
        let port = raw.port();
        let s2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("mpwide-rejoin-daemon".into())
            .spawn(move || loop {
                if s2.load(Ordering::Relaxed) {
                    return;
                }
                match raw.accept_hello() {
                    Ok((stream, uuid, idx, n, _version)) => {
                        if s2.load(Ordering::Relaxed) {
                            return;
                        }
                        if let Some(path) = registry.lookup(uuid) {
                            let idx = idx as usize;
                            // Only dead slots are eligible: a reconnect for
                            // an alive stream is dropped rather than trusted
                            // to retire the old socket — the uuid is a weak
                            // shared secret, and honoring such a hello would
                            // hand an on-path guesser a kill-and-splice
                            // primitive on healthy streams. A death only the
                            // peer observed reaches us via the CTRL frames'
                            // dead-set gossip (or our own failing I/O), after
                            // which the reconnect attempt lands.
                            if n as usize == path.nstreams()
                                && idx < path.nstreams()
                                && !path.stream_alive(idx)
                            {
                                // Confirm before installing: the ack byte
                                // must precede any framed traffic the path
                                // could emit on the fresh socket.
                                let mut stream = stream;
                                if std::io::Write::write_all(&mut stream, &[REJOIN_ACK]).is_ok() {
                                    if let Ok(pair) = StreamPair::from_tcp(stream) {
                                        // swallow-ok: a failed install leaves
                                        // the slot dead; the peer's monitor
                                        // retries on its own schedule.
                                        let _ = path.reinstall_stream(idx, pair);
                                    }
                                }
                            }
                        }
                    }
                    Err(_) => {
                        // transient accept/handshake failure (or the stop
                        // nudge): avoid a tight error loop
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            })?;
        Ok(RejoinDaemon { stop, port, handle: Some(handle) })
    }

    /// The port the daemon keeps listening on.
    pub fn port(&self) -> u16 {
        self.port
    }

    /// Stop the daemon and wait for its thread to exit.
    pub fn stop(&mut self) {
        if let Some(h) = self.handle.take() {
            self.stop.store(true, Ordering::Relaxed);
            // Nudge the blocking accept with a throwaway connection.
            // swallow-ok: a refused nudge means the listener is already
            // past accept; the join below still completes.
            let _ = std::net::TcpStream::connect(("127.0.0.1", self.port));
            // swallow-ok: daemon thread panics have nowhere to surface
            // from a destructor-driven stop.
            let _ = h.join();
        }
    }
}

impl Drop for RejoinDaemon {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::config::PathConfig;
    use crate::mpwide::transport::mem_path_pairs_killable;
    use crate::util::Rng;

    fn resilient_cfg(n: usize) -> PathConfig {
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        cfg.chunk_size = 16 * 1024;
        cfg.resilience.enabled = true;
        cfg
    }

    fn mem_resilient_paths(
        n: usize,
    ) -> (Path, Path, Vec<crate::mpwide::transport::KillSwitch>) {
        let (l, r, kills) = mem_path_pairs_killable(n);
        let cfg = resilient_cfg(n);
        let a = Path::from_pairs(l, cfg.clone()).unwrap();
        let b = Path::from_pairs(r, cfg).unwrap();
        (a, b, kills)
    }

    #[test]
    fn frame_hdr_roundtrip() {
        let h = encode_frame_hdr(KIND_DATA, 42, 3, 1000);
        let d = decode_frame_hdr(&h).unwrap();
        assert_eq!(d, FrameHdr { kind: KIND_DATA, msg_seq: 42, attempt: 3, len: 1000 });
    }

    #[test]
    fn frame_hdr_rejects_garbage() {
        let mut h = encode_frame_hdr(KIND_CTRL, 1, 0, 4);
        h[0] = 0x00;
        assert!(decode_frame_hdr(&h).is_err(), "bad magic");
        let mut h = encode_frame_hdr(KIND_CTRL, 1, 0, 4);
        h[1] = 9;
        assert!(decode_frame_hdr(&h).is_err(), "bad kind");
        let h = encode_frame_hdr(KIND_DATA, 1, 0, (MAX_FRAME_PAYLOAD + 1) as u32);
        assert!(decode_frame_hdr(&h).is_err(), "oversized payload");
    }

    #[test]
    fn ctrl_payload_roundtrip() {
        let p = encode_ctrl(1u64 << 33, &[0, 2, 5], &[1]);
        let c = parse_ctrl(&p).unwrap();
        assert_eq!(c, CtrlMsg { total: 1u64 << 33, streams: vec![0, 2, 5], dead: vec![1] });
        let p = encode_ctrl(7, &[0], &[]);
        assert_eq!(parse_ctrl(&p).unwrap().dead, Vec::<u16>::new());
        assert!(parse_ctrl(&p[..5]).is_err(), "truncated");
        assert!(parse_ctrl(&p[..p.len() - 1]).is_err(), "truncated dead list");
        assert!(parse_ctrl(&encode_ctrl(1, &[], &[])).is_err(), "empty stream list");
    }

    #[test]
    fn framebox_routes_by_kind_in_order() {
        let b = FrameBox::default();
        b.push(FrameHdr { kind: KIND_ACK, msg_seq: 1, attempt: 0, len: 0 }, vec![]);
        b.push(FrameHdr { kind: KIND_DATA, msg_seq: 2, attempt: 0, len: 1 }, vec![7]);
        b.push(FrameHdr { kind: KIND_DATA, msg_seq: 3, attempt: 0, len: 1 }, vec![8]);
        assert_eq!(b.take(KIND_CTRL), None);
        assert_eq!(b.take(KIND_DATA).unwrap().0.msg_seq, 2, "fifo per kind");
        assert_eq!(b.take(KIND_ACK).unwrap().0.msg_seq, 1);
        assert_eq!(b.take(KIND_DATA).unwrap().1, vec![8]);
        assert_eq!(b.take(KIND_DATA), None);
    }

    #[test]
    fn framebox_take_where_skips_foreign_frames() {
        let b = FrameBox::default();
        b.push(FrameHdr { kind: KIND_DATA, msg_seq: 9, attempt: 0, len: 1 }, vec![9]);
        b.push(FrameHdr { kind: KIND_DATA, msg_seq: 4, attempt: 0, len: 1 }, vec![4]);
        // A consumer for message 4 must leave message 9's frame queued
        // (and in place) rather than cycling it.
        let (h, p) = b.take_where(KIND_DATA, |h| h.msg_seq <= 4).unwrap();
        assert_eq!((h.msg_seq, p), (4, vec![4]));
        assert_eq!(b.take_where(KIND_DATA, |h| h.msg_seq <= 4), None);
        assert_eq!(b.take(KIND_DATA).unwrap().0.msg_seq, 9);
    }

    #[test]
    fn framebox_purges_delivered_data_only() {
        let b = FrameBox::default();
        b.push(FrameHdr { kind: KIND_DATA, msg_seq: 1, attempt: 2, len: 0 }, vec![]);
        b.push(FrameHdr { kind: KIND_ACK, msg_seq: 1, attempt: 0, len: 0 }, vec![]);
        b.push(FrameHdr { kind: KIND_DATA, msg_seq: 3, attempt: 0, len: 0 }, vec![]);
        b.purge_data_through(2);
        assert_eq!(b.take(KIND_DATA).unwrap().0.msg_seq, 3, "newer data survives");
        assert!(b.take(KIND_ACK).is_some(), "non-data kinds survive");
    }

    #[test]
    fn resilient_roundtrip_multi_stream() {
        let (a, b, _kills) = mem_resilient_paths(4);
        let mut msg = vec![0u8; 300_000];
        Rng::new(21).fill_bytes(&mut msg);
        let m2 = msg.clone();
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 300_000];
            b.recv(&mut buf).unwrap();
            b.recv(&mut buf).unwrap();
            buf
        });
        a.send(&msg).unwrap();
        a.send(&msg).unwrap(); // sequence numbers advance per message
        assert_eq!(t.join().unwrap(), m2);
    }

    #[test]
    fn resilient_empty_message_and_barrier() {
        let (a, b, _kills) = mem_resilient_paths(3);
        let t = std::thread::spawn(move || {
            let mut empty: [u8; 0] = [];
            b.recv(&mut empty).unwrap();
            b.barrier().unwrap();
        });
        a.send(&[]).unwrap();
        a.barrier().unwrap();
        t.join().unwrap();
    }

    #[test]
    fn kill_one_stream_mid_transfer_completes_over_survivors() {
        let (a, b, kills) = mem_resilient_paths(4);
        let mut msg = vec![0u8; 2 << 20];
        Rng::new(22).fill_bytes(&mut msg);
        let m2 = msg.clone();
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 2 << 20];
            for _ in 0..4 {
                b.recv(&mut buf).unwrap();
            }
            (buf, b.status())
        });
        // sever stream 2 while messages are in flight
        let killer = {
            let k = kills[2].clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(3));
                k.fire();
            })
        };
        for _ in 0..4 {
            a.send(&msg).unwrap();
        }
        killer.join().unwrap();
        let (buf, status) = t.join().unwrap();
        assert_eq!(buf, m2, "last message corrupted");
        let st = a.status();
        assert_eq!(st.nstreams, 4);
        assert!(st.live >= 3, "only the killed stream may be dead: {st:?}");
        assert!(status.live >= 3, "{status:?}");
    }

    #[test]
    fn killed_control_stream_rotates() {
        let (a, b, kills) = mem_resilient_paths(3);
        let msg = vec![9u8; 100_000];
        let m2 = msg.clone();
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 100_000];
            b.recv(&mut buf).unwrap();
            b.recv(&mut buf).unwrap();
            buf
        });
        a.send(&msg).unwrap();
        kills[0].fire(); // stream 0 is the initial control stream
        a.send(&msg).unwrap();
        assert_eq!(t.join().unwrap(), m2);
        assert_eq!(a.status().dead, vec![0]);
    }

    #[test]
    fn degraded_striping_clamps_active() {
        let (a, b, kills) = mem_resilient_paths(4);
        kills[1].fire();
        kills[3].fire();
        let msg = vec![5u8; 50_000];
        let m2 = msg.clone();
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 50_000];
            b.recv(&mut buf).unwrap();
            buf
        });
        a.send(&msg).unwrap();
        assert_eq!(t.join().unwrap(), m2);
        let st = a.status();
        assert_eq!(st.live, 2, "{st:?}");
        assert!(st.active_streams <= 2, "striping past the live count: {st:?}");
        assert_eq!(st.preferred_active, 4, "intent must survive degradation");
    }

    #[test]
    fn all_streams_dead_errors_without_reconnect() {
        let (a, _b, kills) = mem_resilient_paths(2);
        for k in &kills {
            k.fire();
        }
        match a.send(&[1, 2, 3]) {
            Err(MpwError::AllStreamsDead) => {}
            other => panic!("expected AllStreamsDead, got {other:?}"),
        }
    }

    #[test]
    fn resilient_dynamic_messages() {
        let (a, b, _kills) = mem_resilient_paths(2);
        let t = std::thread::spawn(move || b.drecv().unwrap());
        a.dsend(&[3u8; 12_345]).unwrap();
        assert_eq!(t.join().unwrap(), vec![3u8; 12_345]);
    }

    #[test]
    fn resilient_send_recv_full_duplex() {
        let (a, b, _kills) = mem_resilient_paths(3);
        let ma = vec![1u8; 70_000];
        let mb = vec![2u8; 40_000];
        let (ma2, mb2) = (ma.clone(), mb.clone());
        let t = std::thread::spawn(move || {
            let mut buf = vec![0u8; 70_000];
            b.send_recv(&mb2, &mut buf).unwrap();
            assert_eq!(buf, ma2);
        });
        let mut buf = vec![0u8; 40_000];
        a.send_recv(&ma, &mut buf).unwrap();
        assert_eq!(buf, mb);
        t.join().unwrap();
    }
}
