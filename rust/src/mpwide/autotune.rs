//! The MPWide autotuner (§1.3.1) — the **creation-time** half of tuning.
//!
//! Enabled by default, the autotuner probes a small set of chunk sizes at
//! path-creation time, measures round-trip throughput for each, adopts the
//! fastest on both ends, and sets the TCP window to a bandwidth-delay
//! product estimate divided across the streams. The paper's framing —
//! "useful for obtaining fairly good performance with minimal effort, but
//! the best performance is obtained by testing different parameters by
//! hand" — applies verbatim: the A1 bench (`streams_sweep`) compares
//! autotuned vs hand-tuned vs default configurations.
//!
//! The **runtime** half lives in [`super::adapt`]: the master side seeds
//! the adaptive controller with the rate achieved here, so the online
//! tuner starts from the creation-time optimum instead of cold.
//!
//! Protocol (on stream 0, both sides must have autotuning enabled):
//! 16-byte control frames `[cmd: u64 BE][value: u64 BE]`. The connecting
//! side is *master*, the accepting side *slave*.

use std::time::Instant;

use super::errors::{MpwError, Result};
use super::path::Path;

const CMD_PROBE: u64 = 1; // value = chunk size; exchange PROBE_BYTES each way
const CMD_ADOPT: u64 = 2; // value = final chunk size
const CMD_WINDOW: u64 = 3; // value = per-stream window in bytes (0 = skip)
const CMD_DONE: u64 = 4;

/// Bytes exchanged per probe (each direction). Small enough to keep path
/// creation cheap, large enough to exercise several chunks.
pub const PROBE_BYTES: usize = 1 << 20;

/// Candidate chunk sizes probed by the master.
pub const CANDIDATE_CHUNKS: [usize; 4] = [64 * 1024, 256 * 1024, 1 << 20, 4 << 20];

/// Outcome of an autotuning run.
#[derive(Debug, Clone, Copy)]
pub struct TuneResult {
    /// Chunk size adopted by both ends.
    pub chunk_size: usize,
    /// Per-stream TCP window requested (None if left at OS default).
    pub window: Option<usize>,
    /// Measured RTT during tuning.
    pub rtt_seconds: f64,
    /// Throughput of the best probe, bytes/second.
    pub best_rate: f64,
}

fn send_ctrl(path: &Path, cmd: u64, value: u64) -> Result<()> {
    let slot = &path.streams[0];
    let mut tx = slot.tx.lock();
    let mut frame = [0u8; 16];
    frame[..8].copy_from_slice(&cmd.to_be_bytes());
    frame[8..].copy_from_slice(&value.to_be_bytes());
    tx.w.write_all(&frame)?;
    tx.w.flush()?;
    Ok(())
}

fn recv_ctrl(path: &Path) -> Result<(u64, u64)> {
    let slot = &path.streams[0];
    let mut frame = [0u8; 16];
    slot.rx.lock().read_exact(&mut frame)?;
    Ok((
        u64::from_be_bytes(frame[..8].try_into().unwrap()),
        u64::from_be_bytes(frame[8..].try_into().unwrap()),
    ))
}

/// Run the master side (connecting end). Probes candidate chunk sizes,
/// adopts the best on both ends, and sets a BDP-derived window.
pub fn tune_master(path: &Path) -> Result<TuneResult> {
    let rtt = path.measure_rtt()?.as_secs_f64();
    let mut best = (CANDIDATE_CHUNKS[0], 0.0f64);
    let probe = vec![0xA5u8; PROBE_BYTES];
    let mut cache = vec![0u8; PROBE_BYTES];
    for &chunk in &CANDIDATE_CHUNKS {
        send_ctrl(path, CMD_PROBE, chunk as u64)?;
        path.set_chunk_size(chunk)?;
        let t0 = Instant::now();
        path.send_recv(&probe, &mut cache)?;
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        let rate = (2 * PROBE_BYTES) as f64 / dt;
        if rate > best.1 {
            best = (chunk, rate);
        }
    }
    send_ctrl(path, CMD_ADOPT, best.0 as u64)?;
    path.set_chunk_size(best.0)?;

    // Window: bandwidth-delay product split across streams, clamped to a
    // sane range; kernels clamp further (the `MPW_setWin` caveat).
    let window = if rtt > 1e-4 {
        let bdp = best.1 * rtt;
        let per_stream =
            ((bdp / path.nstreams() as f64) as usize).clamp(64 * 1024, 16 << 20);
        send_ctrl(path, CMD_WINDOW, per_stream as u64)?;
        path.set_window(per_stream)?;
        Some(per_stream)
    } else {
        send_ctrl(path, CMD_WINDOW, 0)?;
        None
    };
    send_ctrl(path, CMD_DONE, 0)?;
    path.barrier()?;
    // Arm the runtime controller's collapse detector with the rate the
    // path achieved at creation: if conditions later drift far below
    // this, the adaptive tuner (when enabled) restripes immediately
    // instead of first having to relearn a baseline.
    path.note_tuned_rate(best.1);
    Ok(TuneResult { chunk_size: best.0, window, rtt_seconds: rtt, best_rate: best.1 })
}

/// Run the slave side (accepting end): obey the master's probe/adopt
/// commands until DONE.
pub fn tune_slave(path: &Path) -> Result<()> {
    path.barrier()?; // pairs with the master's measure_rtt
    let mut probe = vec![0u8; PROBE_BYTES];
    loop {
        let (cmd, value) = recv_ctrl(path)?;
        match cmd {
            CMD_PROBE => {
                path.set_chunk_size(value as usize)?;
                // echo: receive the master's probe while sending ours
                let echo = vec![0x5Au8; PROBE_BYTES];
                path.send_recv(&echo, &mut probe)?;
            }
            CMD_ADOPT => path.set_chunk_size(value as usize)?,
            CMD_WINDOW => {
                if value > 0 {
                    path.set_window(value as usize)?;
                }
            }
            CMD_DONE => {
                path.barrier()?;
                return Ok(());
            }
            other => {
                return Err(MpwError::Protocol(format!("unexpected autotune cmd {other}")))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::config::PathConfig;
    use crate::mpwide::transport::mem_path_pairs;

    #[test]
    fn master_slave_converge_on_chunk() {
        let (l, r) = mem_path_pairs(2);
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false; // we drive the tuner manually here
        let a = Path::from_pairs(l, cfg.clone()).unwrap();
        let b = Path::from_pairs(r, cfg).unwrap();
        let t = std::thread::spawn(move || {
            tune_slave(&b).unwrap();
            b.config().chunk_size
        });
        let res = tune_master(&a).unwrap();
        let slave_chunk = t.join().unwrap();
        assert_eq!(res.chunk_size, slave_chunk);
        assert!(CANDIDATE_CHUNKS.contains(&res.chunk_size));
        assert!(res.best_rate > 0.0);
    }

    #[test]
    fn ctrl_frame_roundtrip() {
        let (l, r) = mem_path_pairs(1);
        let mut cfg = PathConfig::with_streams(1);
        cfg.autotune = false;
        let a = Path::from_pairs(l, cfg.clone()).unwrap();
        let b = Path::from_pairs(r, cfg).unwrap();
        send_ctrl(&a, CMD_ADOPT, 12345).unwrap();
        assert_eq!(recv_ctrl(&b).unwrap(), (CMD_ADOPT, 12345));
    }

    #[test]
    fn slave_rejects_garbage_cmd() {
        let (l, r) = mem_path_pairs(1);
        let mut cfg = PathConfig::with_streams(1);
        cfg.autotune = false;
        let a = Path::from_pairs(l, cfg.clone()).unwrap();
        let b = Path::from_pairs(r, cfg).unwrap();
        let t = std::thread::spawn(move || tune_slave(&b));
        a.barrier().unwrap(); // satisfy the slave's initial barrier
        send_ctrl(&a, 999, 0).unwrap();
        assert!(t.join().unwrap().is_err());
    }
}
