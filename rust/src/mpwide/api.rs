//! C-style facade mirroring the paper's Table 2 API.
//!
//! The original MPWide exposes free functions over a global connection
//! table (`MPW_Init`, `MPW_CreatePath`, `MPW_Send`, …). This module
//! provides the same surface — snake-cased — over a process-global
//! registry of [`Path`]s and non-blocking handles, so application code can
//! be ported one-to-one. New Rust code is encouraged to use [`Path`]
//! directly; this facade exists for API fidelity and for the CLI tools.
//!
//! | Paper (Table 2)          | Here                        |
//! |--------------------------|-----------------------------|
//! | `MPW_Init`               | [`mpw_init`]                |
//! | `MPW_Finalize`           | [`mpw_finalize`]            |
//! | `MPW_CreatePath`         | [`mpw_create_path`] / [`mpw_serve_path`] |
//! | `MPW_DestroyPath`        | [`mpw_destroy_path`]        |
//! | `MPW_Send` / `MPW_Recv`  | [`mpw_send`] / [`mpw_recv`] |
//! | `MPW_SendRecv`           | [`mpw_send_recv`]           |
//! | `MPW_DSendRecv`          | [`mpw_dsend_recv`]          |
//! | `MPW_Barrier`            | [`mpw_barrier`]             |
//! | `MPW_Cycle` / `MPW_DCycle` | [`mpw_cycle`] / [`mpw_dcycle`] |
//! | `MPW_Relay`              | [`mpw_relay`]               |
//! | `MPW_ISendRecv`          | [`mpw_isend_recv`]          |
//! | `MPW_Has_NBE_Finished`   | [`mpw_has_nbe_finished`]    |
//! | `MPW_Wait`               | [`mpw_wait`]                |
//! | `MPW_setChunkSize`       | [`mpw_set_chunk_size`]      |
//! | `MPW_setPacingRate`      | [`mpw_set_pacing_rate`]     |
//! | `MPW_setWin`             | [`mpw_set_win`]             |
//! | `MPW_setAutoTuning`      | [`mpw_set_autotuning`]      |
//! | `MPW_DNSResolve`         | [`mpw_dns_resolve`]         |
//!
//! Runtime-adaptation extensions (not in the paper's Table 2 — the
//! online tuner added on top of the creation-time autotuner):
//!
//! | Extension                | Here                        |
//! |--------------------------|-----------------------------|
//! | `MPW_setTuneMode`        | [`mpw_set_tune_mode`]       |
//! | `MPW_TuneMode`           | [`mpw_tune_mode`]           |
//! | `MPW_TuneState`          | [`mpw_tune_state`]          |
//! | `MPW_PathStatus`         | [`mpw_path_status`]         |
//! | `MPW_setReconnectPolicy` | [`mpw_set_reconnect_policy`] |
//! | `MPW_ServeRejoins`       | [`mpw_serve_rejoins`]       |
//!
//! Channel multiplexing extensions (`mpwide::mux` — many logical
//! channels over one shared path):
//!
//! | Extension                | Here                        |
//! |--------------------------|-----------------------------|
//! | `MPW_OpenChannel`        | [`mpw_open_channel`] / [`mpw_open_channel_opts`] |
//! | `MPW_ChannelSend`        | [`mpw_channel_send`]        |
//! | `MPW_ChannelRecv`        | [`mpw_channel_recv`]        |
//! | `MPW_CloseChannel`       | [`mpw_close_channel`]       |
//! | `MPW_setChannelWeight`   | [`mpw_channel_set_weight`]  |
//! | `MPW_setChannelRate`     | [`mpw_channel_set_rate`]    |
//! | `MPW_ChannelStats`       | [`mpw_channel_stats`]       |

use std::collections::HashMap;
use std::sync::{Arc, OnceLock};

use crate::util::lockorder::{rank, OrderedMutex};

use super::adapt::{TuneMode, TuneSnapshot};
use super::config::{PathConfig, ReconnectPolicy};
use super::errors::{MpwError, Result};
use super::mux::{Channel, ChannelOptions, ChannelStats, MuxEndpoint};
use super::nonblocking::{NbeHandle, NbeOp};
use super::path::{Path, PathListener};
use super::relay;
use super::resilience::{self, PathStatus, ReconnectMonitor, RejoinDaemon};

struct Context {
    paths: HashMap<i32, Arc<Path>>,
    /// In-flight non-blocking handles, tagged with the path id they
    /// operate on (the mux interlock needs the association).
    handles: HashMap<i32, (i32, NbeHandle)>,
    listeners: HashMap<u16, PathListener>,
    /// Background reconnect monitors, keyed by path id.
    monitors: HashMap<i32, ReconnectMonitor>,
    /// Background rejoin daemons, keyed by listen port.
    daemons: HashMap<u16, RejoinDaemon>,
    /// Mux endpoints, keyed by the path id they multiplex (created
    /// lazily by the first `mpw_open_channel` on a path).
    muxes: HashMap<i32, MuxEndpoint>,
    /// Open channel handles, keyed by channel handle id.
    channels: HashMap<i32, Channel>,
    /// Count of blocking facade operations in flight outside the
    /// registry lock (plain data-plane calls, `mpw_wait` joins), keyed
    /// by **path instance** ([`busy_key`]) rather than path id — ids are
    /// reused after finalize/destroy, instances never are (each guard
    /// pins its instance alive). The mux interlock must see these paths
    /// as busy.
    busy: HashMap<usize, usize>,
    next_path: i32,
    next_handle: i32,
    next_channel: i32,
}

static CTX: OnceLock<OrderedMutex<Context>> = OnceLock::new();

// mpwlint-lock: ctx = API_CTX — the construction below is anonymous
// (inside `get_or_init`), so the lock-graph pass learns the rank of
// `ctx().lock()` sites from this annotation instead.
fn ctx() -> &'static OrderedMutex<Context> {
    CTX.get_or_init(|| {
        OrderedMutex::new(
            rank::API_CTX,
            Context {
                paths: HashMap::new(),
                handles: HashMap::new(),
                listeners: HashMap::new(),
                monitors: HashMap::new(),
                daemons: HashMap::new(),
                muxes: HashMap::new(),
                channels: HashMap::new(),
                busy: HashMap::new(),
                next_path: 0,
                next_handle: 0,
                next_channel: 0,
            },
        )
    })
}

/// `MPW_Init`: reset the global context (idempotent).
pub fn mpw_init() {
    mpw_finalize();
}

/// `MPW_Finalize`: close all paths and listeners and **drain every
/// non-blocking handle** — finished handles are harvested (their worker
/// joined), unfinished ones are detached so finalize never wedges on a
/// peer that will not speak again. Abandoned handles used to leak in
/// the global table until `mpw_wait`; finalize now owns their cleanup.
pub fn mpw_finalize() {
    let (paths, handles, listeners, monitors, daemons, muxes, channels) = {
        let mut c = ctx().lock();
        c.next_path = 0;
        c.next_handle = 0;
        c.next_channel = 0;
        (
            std::mem::take(&mut c.paths),
            std::mem::take(&mut c.handles),
            std::mem::take(&mut c.listeners),
            std::mem::take(&mut c.monitors),
            std::mem::take(&mut c.daemons),
            std::mem::take(&mut c.muxes),
            std::mem::take(&mut c.channels),
        )
    };
    // Drop outside the context lock: monitor drops notify their paths,
    // and handle drops must not serialize behind the registry.
    drop(monitors);
    drop(daemons);
    // Mux endpoints first: their shutdown closes the multiplexed paths
    // and joins the pump/dispatcher workers; channel handles are inert
    // once their endpoint is gone.
    drop(channels);
    drop(muxes);
    // Close every path first (sticky flag + force-closed streams):
    // detached workers of unfinished handles are parked in blocking
    // reads holding their own Arc<Path>, and without this they (and
    // their sockets) would outlive finalize for the whole process
    // lifetime — or, with reconnection enabled, stall in the zero-live
    // rejoin wait.
    for p in paths.values() {
        p.close();
    }
    for (_, (_path_id, h)) in handles {
        if h.is_finished() {
            // swallow-ok: finalize tears the world down; the completed
            // result has no caller left to report to (C API contract).
            let _ = h.wait(); // join + discard the completed result
        }
        // unfinished handles detach on drop and exit promptly now that
        // their streams are closed
    }
    drop(paths);
    drop(listeners);
}

fn with_path<T>(id: i32, f: impl FnOnce(&Arc<Path>) -> Result<T>) -> Result<T> {
    let p = {
        let c = ctx().lock();
        c.paths.get(&id).cloned().ok_or(MpwError::UnknownId(id))?
    };
    f(&p)
}

/// Look up a path for a *data-plane* operation: once a path is
/// multiplexed its dispatcher owns the receive side and its pump owns
/// message framing, so plain sends/recvs would wedge behind (or
/// corrupt) the channel traffic — reject them instead. Tuning knobs
/// (`mpw_set_chunk_size`, …) stay allowed through [`with_path`].
fn data_path(c: &Context, id: i32) -> Result<Arc<Path>> {
    if c.muxes.contains_key(&id) {
        return Err(MpwError::Config(format!(
            "path {id} is multiplexed; use mpw_channel_send/mpw_channel_recv on its channels"
        )));
    }
    c.paths.get(&id).cloned().ok_or(MpwError::UnknownId(id))
}

fn with_data_path<T>(id: i32, f: impl FnOnce(&Arc<Path>) -> Result<T>) -> Result<T> {
    let (p, _guard) = {
        let mut c = ctx().lock();
        let p = data_path(&c, id)?;
        // mark the path busy while the (possibly blocking) operation
        // runs outside the lock, so mpw_open_channel cannot start a mux
        // dispatcher beside it
        let guard = mark_busy(&mut c, &[&p]);
        (p, guard)
    };
    f(&p)
}

/// Identity of a path *instance* for the busy map: the `Arc` allocation
/// address. Guards keep their instances alive, so a key can never be
/// reused while a guard referencing it exists.
fn busy_key(p: &Arc<Path>) -> usize {
    Arc::as_ptr(p) as usize
}

/// RAII marker for paths with a blocking facade call in flight. Created
/// under the registry lock; the drop re-locks, so it must never be
/// dropped while the registry lock is held.
struct BusyGuard {
    held: Vec<Arc<Path>>,
}

fn mark_busy(c: &mut Context, paths: &[&Arc<Path>]) -> BusyGuard {
    let mut held = Vec::with_capacity(paths.len());
    for p in paths {
        *c.busy.entry(busy_key(p)).or_insert(0) += 1;
        held.push(Arc::clone(p));
    }
    BusyGuard { held }
}

impl Drop for BusyGuard {
    fn drop(&mut self) {
        let mut c = ctx().lock();
        for p in &self.held {
            let k = busy_key(p);
            if let Some(b) = c.busy.get_mut(&k) {
                *b -= 1;
                if *b == 0 {
                    c.busy.remove(&k);
                }
            }
        }
    }
}

/// `MPW_CreatePath` (connecting side): open a path of `nstreams` tcp
/// streams to `host:port`. Returns the path id.
pub fn mpw_create_path(host: &str, port: u16, nstreams: usize) -> Result<i32> {
    mpw_create_path_cfg(host, port, PathConfig::with_streams(nstreams))
}

/// `MPW_CreatePath` with a full configuration. When the configuration
/// enables background reconnection, a per-path monitor is started and
/// owned by the global context (stopped by destroy/finalize).
pub fn mpw_create_path_cfg(host: &str, port: u16, cfg: PathConfig) -> Result<i32> {
    let spawn_monitor = cfg.resilience.reconnect.enabled;
    let path = Arc::new(Path::connect(host, port, cfg)?);
    let monitor =
        if spawn_monitor { Some(resilience::spawn_reconnect_monitor(&path)?) } else { None };
    let mut c = ctx().lock();
    let id = c.next_path;
    c.next_path += 1;
    c.paths.insert(id, path);
    if let Some(m) = monitor {
        c.monitors.insert(id, m);
    }
    Ok(id)
}

/// `MPW_CreatePath` (accepting side): listen on `port` and accept one
/// complete path. The listener stays registered so several paths can be
/// accepted from the same port (forwarder usage).
pub fn mpw_serve_path(port: u16, nstreams: usize) -> Result<i32> {
    mpw_serve_path_cfg(port, PathConfig::with_streams(nstreams))
}

/// Accepting side with a full configuration. Accepted paths are
/// registered for stream rejoin; call [`mpw_serve_rejoins`] once all
/// expected paths on a port have been accepted to start serving
/// reconnects.
pub fn mpw_serve_path_cfg(port: u16, cfg: PathConfig) -> Result<i32> {
    // Hold the context lock only around registry mutation, not accept().
    let mut listener = {
        let mut c = ctx().lock();
        match c.listeners.remove(&port) {
            Some(l) => l,
            None => PathListener::bind(port, cfg.clone())?,
        }
    };
    let real_port = listener.port();
    let path = listener.accept_path_arc()?;
    let mut c = ctx().lock();
    c.listeners.insert(real_port, listener);
    let id = c.next_path;
    c.next_path += 1;
    c.paths.insert(id, path);
    Ok(id)
}

/// `MPW_ServeRejoins` (resilience extension): convert the listener on
/// `port` into a background [`RejoinDaemon`] serving stream reconnects
/// for every path previously accepted from it. The port can no longer
/// accept *new* paths afterwards (the daemon owns the socket); the
/// daemon is stopped by finalize.
pub fn mpw_serve_rejoins(port: u16) -> Result<()> {
    // One critical section: releasing the lock between removing the
    // listener and inserting the daemon would race finalize/init and
    // leak a live daemon into the reset context.
    let mut c = ctx().lock();
    let listener = c.listeners.remove(&port).ok_or(MpwError::UnknownId(port as i32))?;
    let daemon = listener.into_rejoin_daemon()?;
    c.daemons.insert(port, daemon);
    Ok(())
}

/// `MPW_DestroyPath`: close and unregister a path (and stop its
/// reconnect monitor, if any). The streams are force-closed so any
/// detached non-blocking worker still parked on the path exits instead
/// of leaking with its sockets — once destroyed, the path is gone from
/// the table and finalize could no longer reach it.
pub fn mpw_destroy_path(id: i32) -> Result<()> {
    let (path, monitor, mux) = {
        let mut c = ctx().lock();
        let p = c.paths.remove(&id).ok_or(MpwError::UnknownId(id))?;
        let monitor = c.monitors.remove(&id);
        let mux = c.muxes.remove(&id);
        if let Some(m) = &mux {
            // stale channel handles would pin the destroyed path's
            // memory (and queued messages) in the registry until finalize
            c.channels.retain(|_, ch| !m.owns(ch));
        }
        (p, monitor, mux)
    };
    drop(monitor);
    // a multiplexed path is torn down through its endpoint (joins the
    // pump/dispatcher); stale channel handles report the shutdown
    drop(mux);
    path.close();
    drop(path);
    Ok(())
}

/// `MPW_Send`.
pub fn mpw_send(id: i32, buf: &[u8]) -> Result<usize> {
    with_data_path(id, |p| p.send(buf))
}

/// `MPW_Recv`.
pub fn mpw_recv(id: i32, buf: &mut [u8]) -> Result<usize> {
    with_data_path(id, |p| p.recv(buf))
}

/// `MPW_SendRecv`.
pub fn mpw_send_recv(id: i32, sbuf: &[u8], rbuf: &mut [u8]) -> Result<()> {
    with_data_path(id, |p| p.send_recv(sbuf, rbuf))
}

/// `MPW_DSendRecv` (dynamic sizes; returns the received message).
pub fn mpw_dsend_recv(id: i32, sbuf: &[u8]) -> Result<Vec<u8>> {
    with_data_path(id, |p| {
        let mut cache = Vec::new();
        let n = p.dsend_recv(sbuf, &mut cache)?;
        cache.truncate(n);
        Ok(cache)
    })
}

/// `MPW_Barrier`.
pub fn mpw_barrier(id: i32) -> Result<()> {
    with_data_path(id, |p| p.barrier())
}

/// `MPW_Cycle`: receive `recv_len` bytes from path `recv_id` while sending
/// `buf` over path `send_id`.
pub fn mpw_cycle(recv_id: i32, send_id: i32, buf: &[u8], recv_len: usize) -> Result<Vec<u8>> {
    let (pr, ps, _guard) = {
        let mut c = ctx().lock();
        let pr = data_path(&c, recv_id)?;
        let ps = data_path(&c, send_id)?;
        let guard = mark_busy(&mut c, &[&pr, &ps]);
        (pr, ps, guard)
    };
    relay::cycle(&pr, &ps, buf, recv_len)
}

/// `MPW_DCycle` (dynamic sizes).
pub fn mpw_dcycle(recv_id: i32, send_id: i32, buf: &[u8]) -> Result<Vec<u8>> {
    let (pr, ps, _guard) = {
        let mut c = ctx().lock();
        let pr = data_path(&c, recv_id)?;
        let ps = data_path(&c, send_id)?;
        let guard = mark_busy(&mut c, &[&pr, &ps]);
        (pr, ps, guard)
    };
    let mut cache = Vec::new();
    let n = relay::dcycle(&pr, &ps, buf, &mut cache)?;
    cache.truncate(n);
    Ok(cache)
}

/// `MPW_Relay`: forward all traffic between two paths until both close.
pub fn mpw_relay(a: i32, b: i32) -> Result<relay::RelayStats> {
    let (pa, pb, _guard) = {
        let mut c = ctx().lock();
        let pa = data_path(&c, a)?;
        let pb = data_path(&c, b)?;
        let guard = mark_busy(&mut c, &[&pa, &pb]);
        (pa, pb, guard)
    };
    relay::relay(&pa, &pb)
}

/// `MPW_ISendRecv`: start a non-blocking exchange; returns a handle id.
pub fn mpw_isend_recv(id: i32, op: NbeOp) -> Result<i32> {
    // One critical section for lookup + start + registration: the
    // worker must already be visible in the handle table when the lock
    // is released, or `mpw_open_channel`'s in-flight interlock could
    // miss it and start a mux dispatcher beside a live plain recv.
    // (`NbeHandle::start` only spawns the worker thread; it does no I/O
    // on the caller's side, so holding the registry lock is cheap.)
    let mut c = ctx().lock();
    let p = data_path(&c, id)?;
    let h = NbeHandle::start(p, op);
    let hid = c.next_handle;
    c.next_handle += 1;
    c.handles.insert(hid, (id, h));
    Ok(hid)
}

/// `MPW_Has_NBE_Finished`.
pub fn mpw_has_nbe_finished(hid: i32) -> Result<bool> {
    let c = ctx().lock();
    c.handles.get(&hid).map(|(_, h)| h.is_finished()).ok_or(MpwError::UnknownId(hid))
}

/// `MPW_Wait`: block on a non-blocking exchange; returns the received
/// bytes for receiving operations.
pub fn mpw_wait(hid: i32) -> Result<Option<Vec<u8>>> {
    let (h, _guard) = {
        let mut c = ctx().lock();
        let (path_id, h) = c.handles.remove(&hid).ok_or(MpwError::UnknownId(hid))?;
        // the join below blocks outside the lock while the worker may
        // still be on the path; keep the path marked busy so the mux
        // interlock cannot slip a dispatcher in beside it (if the path
        // was already destroyed, there is nothing left to protect)
        let path = c.paths.get(&path_id).cloned();
        let guard = path.as_ref().map(|p| mark_busy(&mut c, &[p]));
        (h, guard)
    };
    h.wait()
}

/// `MPW_setChunkSize`.
pub fn mpw_set_chunk_size(id: i32, chunk: usize) -> Result<()> {
    with_path(id, |p| p.set_chunk_size(chunk))
}

/// `MPW_setPacingRate` (bytes/second per stream; `None` disables).
pub fn mpw_set_pacing_rate(id: i32, rate: Option<f64>) -> Result<()> {
    with_path(id, |p| p.set_pacing_rate(rate))
}

/// `MPW_setWin`.
pub fn mpw_set_win(id: i32, bytes: usize) -> Result<Option<usize>> {
    with_path(id, |p| p.set_window(bytes))
}

/// `MPW_setAutoTuning`.
pub fn mpw_set_autotuning(id: i32, on: bool) -> Result<()> {
    with_path(id, |p| {
        p.set_autotuning(on);
        Ok(())
    })
}

/// `MPW_setTuneMode` (runtime extension): switch a live path between
/// creation-time-only tuning ([`TuneMode::Static`]) and online
/// adaptation ([`TuneMode::Adaptive`]).
pub fn mpw_set_tune_mode(id: i32, mode: TuneMode) -> Result<()> {
    with_path(id, |p| {
        p.set_tune_mode(mode);
        Ok(())
    })
}

/// `MPW_TuneMode` (runtime extension): current tuning mode of a path.
pub fn mpw_tune_mode(id: i32) -> Result<TuneMode> {
    with_path(id, |p| Ok(p.tune_mode()))
}

/// `MPW_TuneState` (runtime extension): snapshot of the live tuning
/// state — active streams, chunk size, pacing rate and the controller's
/// smoothed goodput estimate.
pub fn mpw_tune_state(id: i32) -> Result<TuneSnapshot> {
    with_path(id, |p| Ok(p.tune_snapshot()))
}

/// `MPW_PathStatus` (resilience extension): per-stream health of a
/// path — live/dead streams, effective vs preferred striping width and
/// the rejoin tally.
pub fn mpw_path_status(id: i32) -> Result<PathStatus> {
    with_path(id, |p| Ok(p.status()))
}

/// `MPW_setReconnectPolicy` (resilience extension): replace a path's
/// reconnect policy at runtime. Enabling reconnection starts a
/// background monitor for the path if none is running; disabling stops
/// it.
pub fn mpw_set_reconnect_policy(id: i32, policy: ReconnectPolicy) -> Result<()> {
    let enable = policy.enabled;
    // One critical section for lookup + policy + monitor bookkeeping:
    // releasing the lock in between would race destroy/finalize and could
    // leave a stale monitor entry under a reused id.
    let mut c = ctx().lock();
    let path = c.paths.get(&id).cloned().ok_or(MpwError::UnknownId(id))?;
    // validation (zero backoff, reconnect-without-framing) lives in
    // Path::set_reconnect_policy
    path.set_reconnect_policy(policy)?;
    if enable {
        if !c.monitors.contains_key(&id) {
            c.monitors.insert(id, resilience::spawn_reconnect_monitor(&path)?);
        }
    } else {
        c.monitors.remove(&id);
    }
    Ok(())
}

/// `MPW_DNSResolve`.
pub fn mpw_dns_resolve(host: &str) -> Result<String> {
    super::dns::dns_resolve(host)
}

// ---------------------------------------------------------------------------
// Channel multiplexing (mux extension).
// ---------------------------------------------------------------------------

/// `MPW_OpenChannel` (mux extension): open logical channel `channel` on
/// path `path_id`, multiplexing it over the shared striped path. The
/// first open on a path wraps it in a [`MuxEndpoint`] — from then on
/// all traffic on that path must go through channels. Both ends must
/// open the same channel number (like agreeing on a port). Returns a
/// channel handle id for `mpw_channel_send` / `mpw_channel_recv`.
pub fn mpw_open_channel(path_id: i32, channel: u32) -> Result<i32> {
    mpw_open_channel_opts(path_id, channel, ChannelOptions::default())
}

/// `MPW_OpenChannel` with scheduling options (mux extension): like
/// [`mpw_open_channel`] but sets the channel's DRR `weight` and optional
/// token-bucket `rate` cap at open time. Weights shape how the sender
/// pump splits the shared path between channels (a weight-4 channel gets
/// ~4× the bytes per rotation of a weight-1 sibling); both are local to
/// this endpoint's send side and invisible on the wire.
pub fn mpw_open_channel_opts(path_id: i32, channel: u32, opts: ChannelOptions) -> Result<i32> {
    // validate before touching the registry: a bad option must not
    // spawn (or roll back) a mux endpoint
    opts.validate()?;
    let mut c = ctx().lock();
    let path = c.paths.get(&path_id).cloned().ok_or(MpwError::UnknownId(path_id))?;
    // An unfinished non-blocking handle owns reads/writes on the path;
    // starting the mux dispatcher beside it would interleave plain and
    // framed traffic. Refuse until the caller waits the handles out.
    let fresh = !c.muxes.contains_key(&path_id);
    let busy = c.handles.values().any(|(pid, h)| *pid == path_id && !h.is_finished())
        || c.busy.get(&busy_key(&path)).copied().unwrap_or(0) > 0;
    if fresh && busy {
        return Err(MpwError::Config(format!(
            "path {path_id} has in-flight operations (non-blocking handles or blocking \
             calls); finish them before multiplexing"
        )));
    }
    if fresh {
        // a spawn failure here leaves the registry untouched: the path
        // is still usable for plain (non-multiplexed) traffic
        let endpoint = MuxEndpoint::start(path)?;
        c.muxes.insert(path_id, endpoint);
    }
    let opened = match c.muxes.get(&path_id) {
        Some(m) => m.open_opts(channel, opts),
        None => return Err(MpwError::UnknownId(path_id)),
    };
    let ch = match opened {
        Ok(ch) => ch,
        Err(e) => {
            // a failed FIRST open must not leave the path marked as
            // multiplexed (plain calls would be rejected forever with a
            // misleading error); restore the pre-call state. The removed
            // endpoint is dropped AFTER the registry lock is released —
            // its teardown joins worker threads, and every other facade
            // call would stall behind that otherwise.
            let rollback = if fresh { c.muxes.remove(&path_id) } else { None };
            drop(c);
            drop(rollback);
            return Err(e);
        }
    };
    let id = c.next_channel;
    c.next_channel += 1;
    c.channels.insert(id, ch);
    Ok(id)
}

fn with_channel(id: i32) -> Result<Channel> {
    // clone the handle out so blocking channel ops never hold the
    // global registry lock
    let c = ctx().lock();
    c.channels.get(&id).cloned().ok_or(MpwError::UnknownId(id))
}

/// `MPW_ChannelSend` (mux extension): queue one message on a channel.
/// Blocks only on the channel's high-water backpressure; the sender
/// pump interleaves it fairly with every other channel on the path.
pub fn mpw_channel_send(id: i32, buf: &[u8]) -> Result<()> {
    with_channel(id)?.send(buf)
}

/// `MPW_ChannelRecv` (mux extension): receive the next message on a
/// channel (blocking; message-oriented like `MPW_DRecv`).
pub fn mpw_channel_recv(id: i32) -> Result<Vec<u8>> {
    with_channel(id)?.recv()
}

/// `MPW_setChannelWeight` (mux extension): change a live channel's DRR
/// scheduling weight (1..=[`MAX_WEIGHT`](super::mux::MAX_WEIGHT)). Takes
/// effect at the channel's next pump turn.
pub fn mpw_channel_set_weight(id: i32, weight: u32) -> Result<()> {
    with_channel(id)?.set_weight(weight)
}

/// `MPW_setChannelRate` (mux extension): cap (or uncap, with `None`) a
/// live channel's send rate in bytes/second. The token bucket restarts
/// with a fresh burst allowance.
pub fn mpw_channel_set_rate(id: i32, rate: Option<f64>) -> Result<()> {
    with_channel(id)?.set_rate(rate)
}

/// `MPW_ChannelStats` (mux extension): per-channel observability
/// snapshot for a multiplexed path — queued/sent bytes, scheduling
/// weight and the current DRR deficit, one row per live channel.
pub fn mpw_channel_stats(path_id: i32) -> Result<Vec<ChannelStats>> {
    // snapshotting under the registry lock is fine: channel_stats only
    // takes the mux state lock, which ranks above API_CTX, and copies
    let c = ctx().lock();
    if !c.paths.contains_key(&path_id) {
        return Err(MpwError::UnknownId(path_id));
    }
    match c.muxes.get(&path_id) {
        Some(m) => Ok(m.channel_stats()),
        None => Err(MpwError::Config(format!(
            "path {path_id} is not multiplexed; open a channel first"
        ))),
    }
}

/// `MPW_CloseChannel` (mux extension): flush the channel's queued
/// messages, send the CLOSE frame and release the handle id.
pub fn mpw_close_channel(id: i32) -> Result<()> {
    let ch = {
        let mut c = ctx().lock();
        c.channels.remove(&id).ok_or(MpwError::UnknownId(id))?
    };
    ch.flush()?;
    ch.close()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The facade is a process-global; serialize the tests that use it.
    // TEST_HARNESS ranks below every library lock, so holding it across
    // whole facade calls never trips the lock-order checker.
    static API_LOCK: OrderedMutex<()> = OrderedMutex::new(rank::TEST_HARNESS, ());

    #[test]
    fn unknown_ids_error() {
        let _g = API_LOCK.lock();
        mpw_init();
        assert!(matches!(mpw_send(99, b"x"), Err(MpwError::UnknownId(99))));
        assert!(matches!(mpw_barrier(1), Err(MpwError::UnknownId(1))));
        assert!(matches!(mpw_wait(0), Err(MpwError::UnknownId(0))));
        assert!(mpw_destroy_path(3).is_err());
    }

    #[test]
    fn end_to_end_over_facade() {
        let _g = API_LOCK.lock();
        mpw_init();
        // server thread uses the Path API directly to avoid sharing CTX
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = listener.accept_path().unwrap();
            let mut buf = vec![0u8; 1000];
            p.recv(&mut buf).unwrap();
            p.send(&buf).unwrap();
        });
        let id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        mpw_set_chunk_size(id, 128).unwrap();
        let msg = vec![7u8; 1000];
        mpw_send(id, &msg).unwrap();
        let mut back = vec![0u8; 1000];
        mpw_recv(id, &mut back).unwrap();
        assert_eq!(back, msg);
        mpw_destroy_path(id).unwrap();
        t.join().unwrap();
        mpw_finalize();
    }

    #[test]
    fn tune_mode_over_facade() {
        let _g = API_LOCK.lock();
        mpw_init();
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = listener.accept_path().unwrap();
            let mut buf = vec![0u8; 256 * 1024];
            for _ in 0..3 {
                p.recv(&mut buf).unwrap();
            }
        });
        let id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        assert_eq!(mpw_tune_mode(id).unwrap(), TuneMode::Static);
        mpw_set_tune_mode(id, TuneMode::Adaptive).unwrap();
        assert_eq!(mpw_tune_mode(id).unwrap(), TuneMode::Adaptive);
        let msg = vec![1u8; 256 * 1024];
        for _ in 0..3 {
            mpw_send(id, &msg).unwrap();
        }
        let state = mpw_tune_state(id).unwrap();
        assert!((1..=2).contains(&state.active_streams));
        assert!(state.chunk_size >= 1);
        assert!(matches!(mpw_tune_mode(99), Err(MpwError::UnknownId(99))));
        mpw_destroy_path(id).unwrap();
        t.join().unwrap();
        mpw_finalize();
    }

    #[test]
    fn finalize_drains_inflight_handles_without_wedging() {
        let _g = API_LOCK.lock();
        mpw_init();
        let mut cfg = PathConfig::with_streams(1);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = listener.accept_path().unwrap();
            // answer only the first exchange; the second recv handle
            // stays in flight forever
            let mut buf = vec![0u8; 32];
            p.recv(&mut buf).unwrap();
            p.send(&buf).unwrap();
            p // keep the path open so the abandoned recv genuinely blocks
        });
        let id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        // one handle that finishes...
        let done = mpw_isend_recv(id, NbeOp::SendRecv(vec![9u8; 32], 32)).unwrap();
        let t0 = std::time::Instant::now();
        while !mpw_has_nbe_finished(done).unwrap() {
            assert!(t0.elapsed().as_secs() < 5, "exchange never completed");
            std::thread::yield_now();
        }
        // ...and one that never will (peer sends nothing further)
        let stuck = mpw_isend_recv(id, NbeOp::Recv(64)).unwrap();
        assert!(!mpw_has_nbe_finished(stuck).unwrap());
        let t1 = std::time::Instant::now();
        mpw_finalize();
        assert!(
            t1.elapsed() < std::time::Duration::from_secs(2),
            "finalize must detach in-flight handles, not join them"
        );
        // the table was drained: both ids are gone
        assert!(matches!(mpw_has_nbe_finished(done), Err(MpwError::UnknownId(_))));
        assert!(matches!(mpw_has_nbe_finished(stuck), Err(MpwError::UnknownId(_))));
        let server = t.join().unwrap();
        drop(server);
    }

    #[test]
    fn path_status_and_reconnect_policy_over_facade() {
        let _g = API_LOCK.lock();
        mpw_init();
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        cfg.resilience.enabled = true; // reconnect requires resilient framing
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || listener.accept_path().unwrap());
        let id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        let server = t.join().unwrap();
        let st = mpw_path_status(id).unwrap();
        assert_eq!((st.nstreams, st.live), (2, 2));
        assert!(st.dead.is_empty());
        assert!(!st.reconnect_enabled);
        let policy = crate::mpwide::config::ReconnectPolicy {
            enabled: true,
            ..Default::default()
        };
        mpw_set_reconnect_policy(id, policy).unwrap();
        assert!(mpw_path_status(id).unwrap().reconnect_enabled);
        assert!(matches!(mpw_path_status(99), Err(MpwError::UnknownId(99))));
        mpw_destroy_path(id).unwrap();
        drop(server);
        mpw_finalize();
    }

    #[test]
    fn serve_rejoins_takes_over_the_listener() {
        let _g = API_LOCK.lock();
        mpw_init();
        assert!(mpw_serve_rejoins(59_871).is_err(), "no listener bound on that port");
        let mut cfg = PathConfig::with_streams(1);
        cfg.autotune = false;
        // reserve an ephemeral port for the facade listener (hardcoded
        // ports collide with whatever else runs on the CI host)
        let port = {
            let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            probe.local_addr().unwrap().port()
        };
        let ccfg = cfg.clone();
        let t = std::thread::spawn(move || {
            // Path::connect retries until the facade's listener is up
            let p = Path::connect("127.0.0.1", port, ccfg).unwrap();
            p.barrier().unwrap();
            p
        });
        let id = mpw_serve_path_cfg(port, cfg).unwrap();
        mpw_barrier(id).unwrap();
        // converting the listener into a rejoin daemon consumes it
        mpw_serve_rejoins(port).unwrap();
        assert!(mpw_serve_rejoins(port).is_err(), "listener already consumed");
        let client = t.join().unwrap();
        drop(client);
        mpw_finalize();
    }

    #[test]
    fn channels_over_facade() {
        let _g = API_LOCK.lock();
        mpw_init();
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            // server side uses the library API directly (shared CTX is
            // the client's)
            let p = Arc::new(listener.accept_path().unwrap());
            let mux = super::super::mux::MuxEndpoint::start(p).unwrap();
            let bulk = mux.open(1).unwrap();
            let ctl = mux.open(2).unwrap();
            let got = bulk.recv().unwrap();
            ctl.send(b"ack").unwrap();
            ctl.flush().unwrap();
            // hold the endpoint open until the client's CLOSE lands, so
            // the client-side flush/close never races a dying path
            assert!(matches!(bulk.recv(), Err(MpwError::ChannelClosed { .. })));
            got
        });
        let path_id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        let bulk = mpw_open_channel(path_id, 1).unwrap();
        let ctl = mpw_open_channel(path_id, 2).unwrap();
        assert!(mpw_open_channel(99, 1).is_err(), "unknown path id");
        assert!(
            mpw_send(path_id, b"raw").is_err(),
            "plain data-plane calls on a multiplexed path must be rejected"
        );
        mpw_channel_send(bulk, &[3u8; 50_000]).unwrap();
        assert_eq!(mpw_channel_recv(ctl).unwrap(), b"ack");
        mpw_close_channel(bulk).unwrap();
        assert!(mpw_channel_send(bulk, b"x").is_err(), "handle released");
        assert_eq!(t.join().unwrap(), vec![3u8; 50_000]);
        mpw_finalize();
    }

    #[test]
    fn weighted_channels_over_facade() {
        let _g = API_LOCK.lock();
        mpw_init();
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = Arc::new(listener.accept_path().unwrap());
            let mux = super::super::mux::MuxEndpoint::start(p).unwrap();
            let bulk = mux.open(1).unwrap();
            let got = bulk.recv().unwrap();
            bulk.send(b"ok").unwrap();
            bulk.flush().unwrap();
            assert!(matches!(bulk.recv(), Err(MpwError::ChannelClosed { .. })));
            got
        });
        let path_id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        // stats on a not-yet-multiplexed path is a config error, not a panic
        assert!(matches!(mpw_channel_stats(path_id), Err(MpwError::Config(_))));
        // a bad option never multiplexes the path
        let bad = ChannelOptions { weight: 0, rate: None };
        assert!(mpw_open_channel_opts(path_id, 1, bad).is_err());
        assert!(
            matches!(mpw_channel_stats(path_id), Err(MpwError::Config(_))),
            "rejected options must not mark the path as multiplexed"
        );
        let opts = ChannelOptions { weight: 4, rate: None };
        let bulk = mpw_open_channel_opts(path_id, 1, opts).unwrap();
        let stats = mpw_channel_stats(path_id).unwrap();
        assert_eq!(stats.iter().find(|s| s.id == 1).unwrap().weight, 4);
        mpw_channel_set_weight(bulk, 7).unwrap();
        assert!(mpw_channel_set_weight(bulk, 0).is_err());
        mpw_channel_set_rate(bulk, Some(64.0 * 1024.0 * 1024.0)).unwrap();
        mpw_channel_set_rate(bulk, None).unwrap();
        assert!(mpw_channel_set_rate(bulk, Some(-1.0)).is_err());
        let stats = mpw_channel_stats(path_id).unwrap();
        assert_eq!(stats.iter().find(|s| s.id == 1).unwrap().weight, 7);
        mpw_channel_send(bulk, &[9u8; 10_000]).unwrap();
        assert_eq!(mpw_channel_recv(bulk).unwrap(), b"ok");
        mpw_close_channel(bulk).unwrap();
        assert!(mpw_channel_set_weight(bulk, 2).is_err(), "handle released");
        assert!(matches!(mpw_channel_stats(99), Err(MpwError::UnknownId(99))));
        assert_eq!(t.join().unwrap(), vec![9u8; 10_000]);
        mpw_finalize();
    }

    #[test]
    fn nonblocking_over_facade() {
        let _g = API_LOCK.lock();
        mpw_init();
        let mut cfg = PathConfig::with_streams(1);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = listener.accept_path().unwrap();
            let mut buf = vec![0u8; 64];
            p.recv(&mut buf).unwrap();
            p.send(&buf).unwrap();
        });
        let id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        let hid = mpw_isend_recv(id, NbeOp::SendRecv(vec![1u8; 64], 64)).unwrap();
        let got = mpw_wait(hid).unwrap().unwrap();
        assert_eq!(got, vec![1u8; 64]);
        assert!(mpw_has_nbe_finished(hid).is_err(), "handle consumed by wait");
        t.join().unwrap();
        mpw_finalize();
    }
}
