//! C-style facade mirroring the paper's Table 2 API.
//!
//! The original MPWide exposes free functions over a global connection
//! table (`MPW_Init`, `MPW_CreatePath`, `MPW_Send`, …). This module
//! provides the same surface — snake-cased — over a process-global
//! registry of [`Path`]s and non-blocking handles, so application code can
//! be ported one-to-one. New Rust code is encouraged to use [`Path`]
//! directly; this facade exists for API fidelity and for the CLI tools.
//!
//! | Paper (Table 2)          | Here                        |
//! |--------------------------|-----------------------------|
//! | `MPW_Init`               | [`mpw_init`]                |
//! | `MPW_Finalize`           | [`mpw_finalize`]            |
//! | `MPW_CreatePath`         | [`mpw_create_path`] / [`mpw_serve_path`] |
//! | `MPW_DestroyPath`        | [`mpw_destroy_path`]        |
//! | `MPW_Send` / `MPW_Recv`  | [`mpw_send`] / [`mpw_recv`] |
//! | `MPW_SendRecv`           | [`mpw_send_recv`]           |
//! | `MPW_DSendRecv`          | [`mpw_dsend_recv`]          |
//! | `MPW_Barrier`            | [`mpw_barrier`]             |
//! | `MPW_Cycle` / `MPW_DCycle` | [`mpw_cycle`] / [`mpw_dcycle`] |
//! | `MPW_Relay`              | [`mpw_relay`]               |
//! | `MPW_ISendRecv`          | [`mpw_isend_recv`]          |
//! | `MPW_Has_NBE_Finished`   | [`mpw_has_nbe_finished`]    |
//! | `MPW_Wait`               | [`mpw_wait`]                |
//! | `MPW_setChunkSize`       | [`mpw_set_chunk_size`]      |
//! | `MPW_setPacingRate`      | [`mpw_set_pacing_rate`]     |
//! | `MPW_setWin`             | [`mpw_set_win`]             |
//! | `MPW_setAutoTuning`      | [`mpw_set_autotuning`]      |
//! | `MPW_DNSResolve`         | [`mpw_dns_resolve`]         |
//!
//! Runtime-adaptation extensions (not in the paper's Table 2 — the
//! online tuner added on top of the creation-time autotuner):
//!
//! | Extension                | Here                        |
//! |--------------------------|-----------------------------|
//! | `MPW_setTuneMode`        | [`mpw_set_tune_mode`]       |
//! | `MPW_TuneMode`           | [`mpw_tune_mode`]           |
//! | `MPW_TuneState`          | [`mpw_tune_state`]          |

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::adapt::{TuneMode, TuneSnapshot};
use super::config::PathConfig;
use super::errors::{MpwError, Result};
use super::nonblocking::{NbeHandle, NbeOp};
use super::path::{Path, PathListener};
use super::relay;

struct Context {
    paths: HashMap<i32, Arc<Path>>,
    handles: HashMap<i32, NbeHandle>,
    listeners: HashMap<u16, PathListener>,
    next_path: i32,
    next_handle: i32,
}

static CTX: OnceLock<Mutex<Context>> = OnceLock::new();

fn ctx() -> &'static Mutex<Context> {
    CTX.get_or_init(|| {
        Mutex::new(Context {
            paths: HashMap::new(),
            handles: HashMap::new(),
            listeners: HashMap::new(),
            next_path: 0,
            next_handle: 0,
        })
    })
}

/// `MPW_Init`: reset the global context (idempotent).
pub fn mpw_init() {
    let mut c = ctx().lock().unwrap();
    c.paths.clear();
    c.handles.clear();
    c.listeners.clear();
    c.next_path = 0;
    c.next_handle = 0;
}

/// `MPW_Finalize`: close all paths, listeners and in-flight handles.
pub fn mpw_finalize() {
    mpw_init();
}

fn with_path<T>(id: i32, f: impl FnOnce(&Arc<Path>) -> Result<T>) -> Result<T> {
    let p = {
        let c = ctx().lock().unwrap();
        c.paths.get(&id).cloned().ok_or(MpwError::UnknownId(id))?
    };
    f(&p)
}

/// `MPW_CreatePath` (connecting side): open a path of `nstreams` tcp
/// streams to `host:port`. Returns the path id.
pub fn mpw_create_path(host: &str, port: u16, nstreams: usize) -> Result<i32> {
    mpw_create_path_cfg(host, port, PathConfig::with_streams(nstreams))
}

/// `MPW_CreatePath` with a full configuration.
pub fn mpw_create_path_cfg(host: &str, port: u16, cfg: PathConfig) -> Result<i32> {
    let path = Path::connect(host, port, cfg)?;
    let mut c = ctx().lock().unwrap();
    let id = c.next_path;
    c.next_path += 1;
    c.paths.insert(id, Arc::new(path));
    Ok(id)
}

/// `MPW_CreatePath` (accepting side): listen on `port` and accept one
/// complete path. The listener stays registered so several paths can be
/// accepted from the same port (forwarder usage).
pub fn mpw_serve_path(port: u16, nstreams: usize) -> Result<i32> {
    mpw_serve_path_cfg(port, PathConfig::with_streams(nstreams))
}

/// Accepting side with a full configuration.
pub fn mpw_serve_path_cfg(port: u16, cfg: PathConfig) -> Result<i32> {
    // Hold the context lock only around registry mutation, not accept().
    let mut listener = {
        let mut c = ctx().lock().unwrap();
        match c.listeners.remove(&port) {
            Some(l) => l,
            None => PathListener::bind(port, cfg.clone())?,
        }
    };
    let real_port = listener.port();
    let path = listener.accept_path()?;
    let mut c = ctx().lock().unwrap();
    c.listeners.insert(real_port, listener);
    let id = c.next_path;
    c.next_path += 1;
    c.paths.insert(id, Arc::new(path));
    Ok(id)
}

/// `MPW_DestroyPath`: close and unregister a path.
pub fn mpw_destroy_path(id: i32) -> Result<()> {
    let mut c = ctx().lock().unwrap();
    c.paths.remove(&id).map(|_| ()).ok_or(MpwError::UnknownId(id))
}

/// `MPW_Send`.
pub fn mpw_send(id: i32, buf: &[u8]) -> Result<usize> {
    with_path(id, |p| p.send(buf))
}

/// `MPW_Recv`.
pub fn mpw_recv(id: i32, buf: &mut [u8]) -> Result<usize> {
    with_path(id, |p| p.recv(buf))
}

/// `MPW_SendRecv`.
pub fn mpw_send_recv(id: i32, sbuf: &[u8], rbuf: &mut [u8]) -> Result<()> {
    with_path(id, |p| p.send_recv(sbuf, rbuf))
}

/// `MPW_DSendRecv` (dynamic sizes; returns the received message).
pub fn mpw_dsend_recv(id: i32, sbuf: &[u8]) -> Result<Vec<u8>> {
    with_path(id, |p| {
        let mut cache = Vec::new();
        let n = p.dsend_recv(sbuf, &mut cache)?;
        cache.truncate(n);
        Ok(cache)
    })
}

/// `MPW_Barrier`.
pub fn mpw_barrier(id: i32) -> Result<()> {
    with_path(id, |p| p.barrier())
}

/// `MPW_Cycle`: receive `recv_len` bytes from path `recv_id` while sending
/// `buf` over path `send_id`.
pub fn mpw_cycle(recv_id: i32, send_id: i32, buf: &[u8], recv_len: usize) -> Result<Vec<u8>> {
    let (pr, ps) = {
        let c = ctx().lock().unwrap();
        (
            c.paths.get(&recv_id).cloned().ok_or(MpwError::UnknownId(recv_id))?,
            c.paths.get(&send_id).cloned().ok_or(MpwError::UnknownId(send_id))?,
        )
    };
    relay::cycle(&pr, &ps, buf, recv_len)
}

/// `MPW_DCycle` (dynamic sizes).
pub fn mpw_dcycle(recv_id: i32, send_id: i32, buf: &[u8]) -> Result<Vec<u8>> {
    let (pr, ps) = {
        let c = ctx().lock().unwrap();
        (
            c.paths.get(&recv_id).cloned().ok_or(MpwError::UnknownId(recv_id))?,
            c.paths.get(&send_id).cloned().ok_or(MpwError::UnknownId(send_id))?,
        )
    };
    let mut cache = Vec::new();
    let n = relay::dcycle(&pr, &ps, buf, &mut cache)?;
    cache.truncate(n);
    Ok(cache)
}

/// `MPW_Relay`: forward all traffic between two paths until both close.
pub fn mpw_relay(a: i32, b: i32) -> Result<relay::RelayStats> {
    let (pa, pb) = {
        let c = ctx().lock().unwrap();
        (
            c.paths.get(&a).cloned().ok_or(MpwError::UnknownId(a))?,
            c.paths.get(&b).cloned().ok_or(MpwError::UnknownId(b))?,
        )
    };
    relay::relay(&pa, &pb)
}

/// `MPW_ISendRecv`: start a non-blocking exchange; returns a handle id.
pub fn mpw_isend_recv(id: i32, op: NbeOp) -> Result<i32> {
    let p = {
        let c = ctx().lock().unwrap();
        c.paths.get(&id).cloned().ok_or(MpwError::UnknownId(id))?
    };
    let h = NbeHandle::start(p, op);
    let mut c = ctx().lock().unwrap();
    let hid = c.next_handle;
    c.next_handle += 1;
    c.handles.insert(hid, h);
    Ok(hid)
}

/// `MPW_Has_NBE_Finished`.
pub fn mpw_has_nbe_finished(hid: i32) -> Result<bool> {
    let c = ctx().lock().unwrap();
    c.handles.get(&hid).map(|h| h.is_finished()).ok_or(MpwError::UnknownId(hid))
}

/// `MPW_Wait`: block on a non-blocking exchange; returns the received
/// bytes for receiving operations.
pub fn mpw_wait(hid: i32) -> Result<Option<Vec<u8>>> {
    let h = {
        let mut c = ctx().lock().unwrap();
        c.handles.remove(&hid).ok_or(MpwError::UnknownId(hid))?
    };
    h.wait()
}

/// `MPW_setChunkSize`.
pub fn mpw_set_chunk_size(id: i32, chunk: usize) -> Result<()> {
    with_path(id, |p| p.set_chunk_size(chunk))
}

/// `MPW_setPacingRate` (bytes/second per stream; `None` disables).
pub fn mpw_set_pacing_rate(id: i32, rate: Option<f64>) -> Result<()> {
    with_path(id, |p| p.set_pacing_rate(rate))
}

/// `MPW_setWin`.
pub fn mpw_set_win(id: i32, bytes: usize) -> Result<Option<usize>> {
    with_path(id, |p| p.set_window(bytes))
}

/// `MPW_setAutoTuning`.
pub fn mpw_set_autotuning(id: i32, on: bool) -> Result<()> {
    with_path(id, |p| {
        p.set_autotuning(on);
        Ok(())
    })
}

/// `MPW_setTuneMode` (runtime extension): switch a live path between
/// creation-time-only tuning ([`TuneMode::Static`]) and online
/// adaptation ([`TuneMode::Adaptive`]).
pub fn mpw_set_tune_mode(id: i32, mode: TuneMode) -> Result<()> {
    with_path(id, |p| {
        p.set_tune_mode(mode);
        Ok(())
    })
}

/// `MPW_TuneMode` (runtime extension): current tuning mode of a path.
pub fn mpw_tune_mode(id: i32) -> Result<TuneMode> {
    with_path(id, |p| Ok(p.tune_mode()))
}

/// `MPW_TuneState` (runtime extension): snapshot of the live tuning
/// state — active streams, chunk size, pacing rate and the controller's
/// smoothed goodput estimate.
pub fn mpw_tune_state(id: i32) -> Result<TuneSnapshot> {
    with_path(id, |p| Ok(p.tune_snapshot()))
}

/// `MPW_DNSResolve`.
pub fn mpw_dns_resolve(host: &str) -> Result<String> {
    super::dns::dns_resolve(host)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    // The facade is a process-global; serialize the tests that use it.
    static API_LOCK: StdMutex<()> = StdMutex::new(());

    #[test]
    fn unknown_ids_error() {
        let _g = API_LOCK.lock().unwrap();
        mpw_init();
        assert!(matches!(mpw_send(99, b"x"), Err(MpwError::UnknownId(99))));
        assert!(matches!(mpw_barrier(1), Err(MpwError::UnknownId(1))));
        assert!(matches!(mpw_wait(0), Err(MpwError::UnknownId(0))));
        assert!(mpw_destroy_path(3).is_err());
    }

    #[test]
    fn end_to_end_over_facade() {
        let _g = API_LOCK.lock().unwrap();
        mpw_init();
        // server thread uses the Path API directly to avoid sharing CTX
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = listener.accept_path().unwrap();
            let mut buf = vec![0u8; 1000];
            p.recv(&mut buf).unwrap();
            p.send(&buf).unwrap();
        });
        let id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        mpw_set_chunk_size(id, 128).unwrap();
        let msg = vec![7u8; 1000];
        mpw_send(id, &msg).unwrap();
        let mut back = vec![0u8; 1000];
        mpw_recv(id, &mut back).unwrap();
        assert_eq!(back, msg);
        mpw_destroy_path(id).unwrap();
        t.join().unwrap();
        mpw_finalize();
    }

    #[test]
    fn tune_mode_over_facade() {
        let _g = API_LOCK.lock().unwrap();
        mpw_init();
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = listener.accept_path().unwrap();
            let mut buf = vec![0u8; 256 * 1024];
            for _ in 0..3 {
                p.recv(&mut buf).unwrap();
            }
        });
        let id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        assert_eq!(mpw_tune_mode(id).unwrap(), TuneMode::Static);
        mpw_set_tune_mode(id, TuneMode::Adaptive).unwrap();
        assert_eq!(mpw_tune_mode(id).unwrap(), TuneMode::Adaptive);
        let msg = vec![1u8; 256 * 1024];
        for _ in 0..3 {
            mpw_send(id, &msg).unwrap();
        }
        let state = mpw_tune_state(id).unwrap();
        assert!((1..=2).contains(&state.active_streams));
        assert!(state.chunk_size >= 1);
        assert!(matches!(mpw_tune_mode(99), Err(MpwError::UnknownId(99))));
        mpw_destroy_path(id).unwrap();
        t.join().unwrap();
        mpw_finalize();
    }

    #[test]
    fn nonblocking_over_facade() {
        let _g = API_LOCK.lock().unwrap();
        mpw_init();
        let mut cfg = PathConfig::with_streams(1);
        cfg.autotune = false;
        let mut listener = PathListener::bind(0, cfg.clone()).unwrap();
        let port = listener.port();
        let t = std::thread::spawn(move || {
            let p = listener.accept_path().unwrap();
            let mut buf = vec![0u8; 64];
            p.recv(&mut buf).unwrap();
            p.send(&buf).unwrap();
        });
        let id = mpw_create_path_cfg("127.0.0.1", port, cfg).unwrap();
        let hid = mpw_isend_recv(id, NbeOp::SendRecv(vec![1u8; 64], 64)).unwrap();
        let got = mpw_wait(hid).unwrap().unwrap();
        assert_eq!(got, vec![1u8; 64]);
        assert!(mpw_has_nbe_finished(hid).is_err(), "handle consumed by wait");
        t.join().unwrap();
        mpw_finalize();
    }
}
