//! The DataGather (paper §1.3.5): one-way, real-time synchronization of a
//! directory to a remote machine, designed to run *concurrently* with a
//! distributed simulation so its output collects on a single resource.
//!
//! Protocol per sync round (source side drives):
//! 1. source scans its directory and sends a manifest of
//!    (relative path, size, crc32);
//! 2. destination replies with the indices it is missing or whose
//!    size/crc differ;
//! 3. source ships exactly those files via the [`super::mpwcp`] framing.
//!
//! Sync is one-way by design (the paper's constraint); deletions are not
//! propagated.

use std::collections::HashMap;
use std::path::Path as FsPath;

use crate::mpwide::errors::{MpwError, Result};
use crate::mpwide::mux::MsgLink;

/// One file entry in the sync manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    /// Path relative to the synced root (always `/`-separated).
    pub rel: String,
    /// File size in bytes.
    pub size: u64,
    /// CRC32 of the contents.
    pub crc: u32,
}

/// Statistics of one sync round.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SyncStats {
    /// Files in the source manifest.
    pub scanned: usize,
    /// Files actually shipped this round.
    pub shipped: usize,
    /// Payload bytes shipped.
    pub bytes: u64,
}

/// Scan a directory recursively into manifest entries (sorted by path
/// for determinism).
pub fn scan(root: &FsPath) -> Result<Vec<Entry>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.is_file() {
                let data = std::fs::read(&p)?;
                let rel = p
                    .strip_prefix(root)
                    .map_err(|_| MpwError::Protocol("path outside root".into()))?
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push(Entry { rel, size: data.len() as u64, crc: crc32fast::hash(&data) });
            }
        }
    }
    out.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(out)
}

fn encode_manifest(entries: &[Entry]) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&(entries.len() as u32).to_be_bytes());
    for e in entries {
        let name = e.rel.as_bytes();
        buf.extend_from_slice(&(name.len() as u16).to_be_bytes());
        buf.extend_from_slice(name);
        buf.extend_from_slice(&e.size.to_be_bytes());
        buf.extend_from_slice(&e.crc.to_be_bytes());
    }
    buf
}

fn decode_manifest(buf: &[u8]) -> Result<Vec<Entry>> {
    let err = || MpwError::Protocol("malformed datagather manifest".into());
    if buf.len() < 4 {
        return Err(err());
    }
    let n = u32::from_be_bytes(buf[0..4].try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(n);
    let mut i = 4;
    for _ in 0..n {
        if buf.len() < i + 2 {
            return Err(err());
        }
        let nl = u16::from_be_bytes(buf[i..i + 2].try_into().unwrap()) as usize;
        i += 2;
        if buf.len() < i + nl + 12 {
            return Err(err());
        }
        let rel = String::from_utf8(buf[i..i + nl].to_vec()).map_err(|_| err())?;
        i += nl;
        let size = u64::from_be_bytes(buf[i..i + 8].try_into().unwrap());
        i += 8;
        let crc = u32::from_be_bytes(buf[i..i + 4].try_into().unwrap());
        i += 4;
        out.push(Entry { rel, size, crc });
    }
    if i != buf.len() {
        return Err(err());
    }
    Ok(out)
}

/// Which manifest entries does the destination need, given its local
/// state? (pure: unit-tested directly)
pub fn diff_needed(remote: &[Entry], local: &HashMap<String, Entry>) -> Vec<u32> {
    remote
        .iter()
        .enumerate()
        .filter(|(_, e)| match local.get(&e.rel) {
            None => true,
            Some(l) => l.size != e.size || l.crc != e.crc,
        })
        .map(|(i, _)| i as u32)
        .collect()
}

/// Source side: run one sync round of `root` over `path` — a whole
/// [`Path`](crate::mpwide::path::Path) or one mux
/// [`Channel`](crate::mpwide::mux::Channel), so the gather runs
/// *concurrently with the simulation it collects from* over the same
/// shared WAN path (the paper's intended deployment, without a second
/// path).
pub fn sync_once<L: MsgLink + ?Sized>(path: &L, root: &FsPath) -> Result<SyncStats> {
    let entries = scan(root)?;
    path.send_msg(&encode_manifest(&entries))?;
    let wanted_raw = path.recv_msg()?;
    if wanted_raw.len() % 4 != 0 {
        return Err(MpwError::Protocol("malformed want-list".into()));
    }
    let wanted: Vec<u32> = wanted_raw
        .chunks_exact(4)
        .map(|c| u32::from_be_bytes(c.try_into().unwrap()))
        .collect();
    let mut stats =
        SyncStats { scanned: entries.len(), shipped: wanted.len(), bytes: 0 };
    for idx in wanted {
        let e = entries
            .get(idx as usize)
            .ok_or_else(|| MpwError::Protocol(format!("bad want index {idx}")))?;
        let full = root.join(e.rel.replace('/', std::path::MAIN_SEPARATOR_STR));
        super::mpwcp::send_file(path, &full, &e.rel.replace('/', "__"))?;
        stats.bytes += e.size;
    }
    Ok(stats)
}

/// Destination side: serve one sync round into `dest`. Returns the
/// number of files received.
pub fn serve_once<L: MsgLink + ?Sized>(path: &L, dest: &FsPath) -> Result<usize> {
    std::fs::create_dir_all(dest)?;
    let manifest = decode_manifest(&path.recv_msg()?)?;
    let local: HashMap<String, Entry> = scan(dest)?
        .into_iter()
        .map(|e| (e.rel.replace("__", "/"), e))
        .collect();
    let needed = diff_needed(&manifest, &local);
    let mut reply = Vec::with_capacity(needed.len() * 4);
    for idx in &needed {
        reply.extend_from_slice(&idx.to_be_bytes());
    }
    path.send_msg(&reply)?;
    for _ in 0..needed.len() {
        super::mpwcp::recv_file(path, dest)?;
    }
    Ok(needed.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::path::Path;
    use crate::mpwide::transport::mem_path_pairs;
    use crate::mpwide::PathConfig;
    use std::path::PathBuf;

    fn mem_paths(n: usize) -> (Path, Path) {
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        (Path::from_pairs(l, cfg.clone()).unwrap(), Path::from_pairs(r, cfg).unwrap())
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("datagather-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn manifest_roundtrip() {
        let entries = vec![
            Entry { rel: "a/b.txt".into(), size: 10, crc: 0xDEAD },
            Entry { rel: "c.bin".into(), size: 0, crc: 0 },
        ];
        assert_eq!(decode_manifest(&encode_manifest(&entries)).unwrap(), entries);
        assert!(decode_manifest(&[1, 2, 3]).is_err());
    }

    #[test]
    fn diff_detects_new_changed_and_same() {
        let remote = vec![
            Entry { rel: "same".into(), size: 5, crc: 1 },
            Entry { rel: "changed".into(), size: 5, crc: 2 },
            Entry { rel: "new".into(), size: 5, crc: 3 },
        ];
        let mut local = HashMap::new();
        local.insert("same".to_string(), Entry { rel: "same".into(), size: 5, crc: 1 });
        local.insert("changed".to_string(), Entry { rel: "changed".into(), size: 5, crc: 99 });
        assert_eq!(diff_needed(&remote, &local), vec![1, 2]);
    }

    #[test]
    fn scan_is_recursive_and_sorted() {
        let dir = tmpdir("scan");
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        std::fs::write(dir.join("z.txt"), b"zz").unwrap();
        std::fs::write(dir.join("sub/a.txt"), b"aa").unwrap();
        let entries = scan(&dir).unwrap();
        let rels: Vec<&str> = entries.iter().map(|e| e.rel.as_str()).collect();
        assert_eq!(rels, vec!["sub/a.txt", "z.txt"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sync_over_a_mux_channel_beside_live_traffic() {
        // The gather runs over ONE channel of a shared path while a
        // "solver coupling" exchanges messages on another — the
        // channel-aware deployment the paper's DataGather wants.
        use crate::mpwide::mux::MuxEndpoint;
        use std::sync::Arc;
        let dir = tmpdir("muxsync");
        let src = dir.join("src");
        let dst = dir.join("dst");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(src.join("snap.dat"), vec![9u8; 20_000]).unwrap();

        let (l, r) = mem_path_pairs(2);
        let mut cfg = PathConfig::with_streams(2);
        cfg.autotune = false;
        let pa = Arc::new(Path::from_pairs(l, cfg.clone()).unwrap());
        let pb = Arc::new(Path::from_pairs(r, cfg).unwrap());
        let a = MuxEndpoint::start(pa).unwrap();
        let b = MuxEndpoint::start(pb).unwrap();
        let gather_tx = a.open(1).unwrap();
        let gather_rx = b.open(1).unwrap();
        let solver_a = a.open(2).unwrap();
        let solver_b = b.open(2).unwrap();

        let t = std::thread::spawn(move || serve_once(&gather_rx, &dst).unwrap());
        // concurrent coupling traffic on the sibling channel
        solver_a.send(&[1u8; 4096]).unwrap();
        let stats = sync_once(&gather_tx, &src).unwrap();
        assert_eq!(solver_b.recv().unwrap(), vec![1u8; 4096]);
        assert_eq!(t.join().unwrap(), 1);
        assert_eq!(stats.shipped, 1);
        assert_eq!(
            std::fs::read(dir.join("dst/snap.dat")).unwrap(),
            vec![9u8; 20_000],
            "file corrupted crossing the shared path"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_sync_then_incremental() {
        let dir = tmpdir("sync");
        let src = dir.join("src");
        let dst = dir.join("dst");
        std::fs::create_dir_all(src.join("run")).unwrap();
        std::fs::write(src.join("run/snap0.dat"), vec![1u8; 5000]).unwrap();
        std::fs::write(src.join("log.txt"), b"hello").unwrap();

        // round 1: everything ships
        let (a, b) = mem_paths(2);
        let dst2 = dst.clone();
        let t = std::thread::spawn(move || serve_once(&b, &dst2).unwrap());
        let src2 = src.clone();
        let stats = sync_once(&a, &src2).unwrap();
        assert_eq!(t.join().unwrap(), 2);
        assert_eq!(stats.shipped, 2);
        assert_eq!(std::fs::read(dst.join("run__snap0.dat")).unwrap(), vec![1u8; 5000]);

        // round 2: nothing changed → nothing ships
        let (a, b) = mem_paths(2);
        let dst2 = dst.clone();
        let t = std::thread::spawn(move || serve_once(&b, &dst2).unwrap());
        let stats = sync_once(&a, &src).unwrap();
        assert_eq!(t.join().unwrap(), 0);
        assert_eq!(stats.shipped, 0);

        // round 3: simulation wrote a new snapshot → only it ships
        std::fs::write(src.join("run/snap1.dat"), vec![2u8; 800]).unwrap();
        let (a, b) = mem_paths(2);
        let dst2 = dst.clone();
        let t = std::thread::spawn(move || serve_once(&b, &dst2).unwrap());
        let stats = sync_once(&a, &src).unwrap();
        assert_eq!(t.join().unwrap(), 1);
        assert_eq!(stats.shipped, 1);
        assert_eq!(stats.bytes, 800);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
