//! The tools shipped with MPWide (paper §1.3.3–§1.3.5 and §1.4):
//!
//! * [`forwarder`] — user-space data forwarding for sites whose compute
//!   nodes cannot accept inbound connections (Fig 3).
//! * [`mpwcp`] — `mpw-cp`, the scp-class file transfer tool with
//!   stream-count/chunk-size knobs and CRC32 integrity checking.
//! * [`datagather`] — one-way real-time directory synchronization.
//! * [`mpwtest`] — the two-endpoint benchmark suite (paper's `MPWTest`).

pub mod datagather;
pub mod forwarder;
pub mod mpwcp;
pub mod mpwtest;
