//! `mpw-cp` (paper §1.3.4): scp-class file transfer over an MPWide path.
//!
//! The original bootstraps its remote end via SSH; here the remote end is
//! a small server loop (`mpwide cp-serve`) — the measured quantity,
//! transfer performance, is unaffected (DESIGN.md §2). Unlike scp, the
//! user can tune streams/chunk size from the command line, which is the
//! tool's whole point. Every file carries a CRC32 that the receiver
//! verifies and acknowledges.

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path as FsPath, PathBuf};

use crate::mpwide::errors::{MpwError, Result};
use crate::mpwide::mux::MsgLink;

/// Transfer buffer size (bytes read from disk per dynamic message).
pub const IO_CHUNK: usize = 8 << 20;

/// Receiver acknowledgement codes.
const ACK_OK: u64 = 0xC0DE_600D;
const ACK_BAD: u64 = 0xC0DE_0BAD;

/// Outcome of one file transfer (sender side).
#[derive(Debug, Clone)]
pub struct CpStats {
    /// Payload bytes moved.
    pub bytes: u64,
    /// Wall seconds for the data phase.
    pub seconds: f64,
    /// CRC32 of the file contents.
    pub crc: u32,
}

/// Send one file over an established message link — a whole
/// [`Path`](crate::mpwide::path::Path) or one mux
/// [`Channel`](crate::mpwide::mux::Channel) of a shared path, so a file
/// transfer can ride alongside a live coupling.
/// `remote_name` is the name the receiver stores it under (sanitized
/// server-side).
pub fn send_file<L: MsgLink + ?Sized>(
    path: &L,
    file: &FsPath,
    remote_name: &str,
) -> Result<CpStats> {
    let mut f = File::open(file)?;
    let size = f.metadata()?.len();

    // header: name + size (CRC follows the data — computed while streaming)
    let name_bytes = remote_name.as_bytes();
    let mut header = Vec::with_capacity(2 + name_bytes.len() + 8);
    header.extend_from_slice(&(name_bytes.len() as u16).to_be_bytes());
    header.extend_from_slice(name_bytes);
    header.extend_from_slice(&size.to_be_bytes());
    path.send_msg(&header)?;

    let t0 = std::time::Instant::now();
    let mut hasher = crc32fast::Hasher::new();
    let mut buf = vec![0u8; IO_CHUNK];
    let mut sent = 0u64;
    while sent < size {
        let want = ((size - sent) as usize).min(IO_CHUNK);
        f.read_exact(&mut buf[..want])?;
        hasher.update(&buf[..want]);
        path.send_msg(&buf[..want])?;
        sent += want as u64;
    }
    let crc = hasher.finalize();
    path.send_msg(&crc.to_be_bytes())?;
    let seconds = t0.elapsed().as_secs_f64();

    // wait for the receiver's verdict
    let ack = path.recv_msg()?;
    if ack.len() != 8 {
        return Err(MpwError::Protocol("short mpw-cp ack".into()));
    }
    match u64::from_be_bytes(ack.try_into().unwrap()) {
        ACK_OK => Ok(CpStats { bytes: size, seconds, crc }),
        ACK_BAD => Err(MpwError::Protocol("receiver reported CRC mismatch".into())),
        other => Err(MpwError::Protocol(format!("bad ack {other:#x}"))),
    }
}

/// Receive one file into `dest_dir`. Returns (stored path, bytes, crc).
pub fn recv_file<L: MsgLink + ?Sized>(
    path: &L,
    dest_dir: &FsPath,
) -> Result<(PathBuf, u64, u32)> {
    let header = path.recv_msg()?;
    if header.len() < 10 {
        return Err(MpwError::Protocol("short mpw-cp header".into()));
    }
    let name_len = u16::from_be_bytes(header[0..2].try_into().unwrap()) as usize;
    if header.len() != 2 + name_len + 8 {
        return Err(MpwError::Protocol("malformed mpw-cp header".into()));
    }
    let name = String::from_utf8(header[2..2 + name_len].to_vec())
        .map_err(|_| MpwError::Protocol("non-utf8 file name".into()))?;
    let size = u64::from_be_bytes(header[2 + name_len..].try_into().unwrap());

    // sanitize: basename only — a hostile sender must not escape dest_dir
    let base = std::path::Path::new(&name)
        .file_name()
        .ok_or_else(|| MpwError::Protocol(format!("bad file name {name:?}")))?;
    let dest = dest_dir.join(base);

    let mut out = File::create(&dest)?;
    let mut hasher = crc32fast::Hasher::new();
    let mut cache = Vec::new();
    let mut got = 0u64;
    while got < size {
        let n = path.recv_msg_into(&mut cache)?;
        hasher.update(&cache[..n]);
        out.write_all(&cache[..n])?;
        got += n as u64;
    }
    out.flush()?;
    let crc_msg = path.recv_msg()?;
    if crc_msg.len() != 4 {
        return Err(MpwError::Protocol("short crc trailer".into()));
    }
    let want_crc = u32::from_be_bytes(crc_msg.try_into().unwrap());
    let crc = hasher.finalize();
    let verdict = if crc == want_crc { ACK_OK } else { ACK_BAD };
    path.send_msg(&verdict.to_be_bytes())?;
    if crc != want_crc {
        return Err(MpwError::Protocol(format!("crc mismatch: {crc:#x} != {want_crc:#x}")));
    }
    Ok((dest, size, crc))
}

/// Server loop: accept files on `path` until the peer closes. Returns
/// the number of files received.
pub fn serve<L: MsgLink + ?Sized>(path: &L, dest_dir: &FsPath) -> Result<usize> {
    std::fs::create_dir_all(dest_dir)?;
    let mut count = 0;
    loop {
        match recv_file(path, dest_dir) {
            Ok(_) => count += 1,
            Err(MpwError::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::UnexpectedEof
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::BrokenPipe
                ) =>
            {
                return Ok(count)
            }
            // a mux channel signals the peer's close explicitly
            Err(MpwError::ChannelClosed { .. }) => return Ok(count),
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::path::Path;
    use crate::mpwide::transport::mem_path_pairs;
    use crate::mpwide::PathConfig;
    use crate::util::Rng;

    fn mem_paths(n: usize) -> (Path, Path) {
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        (Path::from_pairs(l, cfg.clone()).unwrap(), Path::from_pairs(r, cfg).unwrap())
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mpwcp-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn file_roundtrip_with_integrity() {
        let dir = tmpdir("rt");
        let src = dir.join("input.bin");
        let mut data = vec![0u8; 3 * 1024 * 1024 + 17];
        Rng::new(7).fill_bytes(&mut data);
        std::fs::write(&src, &data).unwrap();

        let (a, b) = mem_paths(4);
        let dest = dir.join("out");
        std::fs::create_dir_all(&dest).unwrap();
        let dest2 = dest.clone();
        let t = std::thread::spawn(move || recv_file(&b, &dest2).unwrap());
        let stats = send_file(&a, &src, "copy.bin").unwrap();
        let (stored, size, crc) = t.join().unwrap();
        assert_eq!(size, data.len() as u64);
        assert_eq!(stats.crc, crc);
        assert_eq!(std::fs::read(stored).unwrap(), data);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_file_roundtrip() {
        let dir = tmpdir("empty");
        let src = dir.join("empty.bin");
        std::fs::write(&src, b"").unwrap();
        let (a, b) = mem_paths(1);
        let dest = dir.clone();
        let t = std::thread::spawn(move || recv_file(&b, &dest).unwrap());
        let stats = send_file(&a, &src, "empty.out").unwrap();
        let (_, size, _) = t.join().unwrap();
        assert_eq!(size, 0);
        assert_eq!(stats.bytes, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hostile_path_is_sanitized() {
        let dir = tmpdir("evil");
        let src = dir.join("x.bin");
        std::fs::write(&src, b"attack").unwrap();
        let (a, b) = mem_paths(1);
        let dest = dir.join("dest");
        std::fs::create_dir_all(&dest).unwrap();
        let dest2 = dest.clone();
        let t = std::thread::spawn(move || recv_file(&b, &dest2).unwrap());
        send_file(&a, &src, "../../escape.bin").unwrap();
        let (stored, _, _) = t.join().unwrap();
        assert!(stored.starts_with(&dest), "stored at {stored:?}");
        assert_eq!(stored.file_name().unwrap(), "escape.bin");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_counts_files_until_close() {
        let dir = tmpdir("serve");
        let src1 = dir.join("a.bin");
        let src2 = dir.join("b.bin");
        std::fs::write(&src1, vec![1u8; 1000]).unwrap();
        std::fs::write(&src2, vec![2u8; 2000]).unwrap();
        let (a, b) = mem_paths(2);
        let dest = dir.join("dest");
        let dest2 = dest.clone();
        let t = std::thread::spawn(move || serve(&b, &dest2).unwrap());
        send_file(&a, &src1, "a.bin").unwrap();
        send_file(&a, &src2, "b.bin").unwrap();
        drop(a); // close → server loop ends
        assert_eq!(t.join().unwrap(), 2);
        assert_eq!(std::fs::read(dest.join("a.bin")).unwrap(), vec![1u8; 1000]);
        assert_eq!(std::fs::read(dest.join("b.bin")).unwrap(), vec![2u8; 2000]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
