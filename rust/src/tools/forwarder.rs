//! The Forwarder (paper §1.3.3, Fig 3).
//!
//! Supercomputing sites commonly deny inbound connections to compute
//! nodes. Administrators would normally punch firewall holes; the
//! Forwarder mimics that *in user space*: a small process on a reachable
//! front-end node that accepts two paths — one from each endpoint — and
//! relays all traffic between them. "Because the Forwarder operates on a
//! higher level in the network architecture, it is generally slightly
//! less efficient than conventional firewall-based forwarding" — the
//! `local_overhead` bench quantifies that overhead here.
//!
//! An optional artificial one-way delay per hop lets integration tests
//! and the bloodflow experiment (§1.2.2) reproduce the paper's 11 ms
//! round-trip over real sockets.

use std::time::Duration;

use crate::mpwide::errors::Result;
use crate::mpwide::path::{Path, PathListener};
use crate::mpwide::relay::RelayStats;
use crate::mpwide::PathConfig;

/// Forwarder configuration.
#[derive(Debug, Clone)]
pub struct ForwarderConfig {
    /// Streams per accepted path (both sides must match).
    pub nstreams: usize,
    /// Artificial one-way delay added per forwarded batch (propagation
    /// emulation; `None` = forward immediately).
    pub delay: Option<Duration>,
    /// Stop after relaying this many total bytes (tests); `None` = until
    /// both sides close.
    pub max_bytes: Option<u64>,
}

impl Default for ForwarderConfig {
    fn default() -> Self {
        ForwarderConfig { nstreams: 1, delay: None, max_bytes: None }
    }
}

/// Accept two paths from `listener` and relay between them until both
/// close. Returns the relay statistics.
///
/// Both endpoints *connect* to the forwarder (exactly the Fig 3 layout:
/// pyNS and HemeLB both dial the front-end process), so path creation
/// order on the listener is first-come-first-served.
pub fn run(listener: &mut PathListener, cfg: &ForwarderConfig) -> Result<RelayStats> {
    let a = listener.accept_path()?;
    let b = listener.accept_path()?;
    relay_with_delay(&a, &b, cfg.delay)
}

/// Channel-aware forwarder: accept two paths and relay whole
/// **messages** between them ([`crate::mpwide::relay::relay_messages`]),
/// so multiplexed channel frames (`mpwide::mux`) cross the hop intact —
/// including between legs with *different* stream counts, which the
/// byte-level [`run`] must reject. Use this variant when the endpoints
/// run mux endpoints over their paths to the forwarder.
pub fn run_channels(listener: &mut PathListener) -> Result<RelayStats> {
    let a = listener.accept_path()?;
    let b = listener.accept_path()?;
    crate::mpwide::relay::relay_messages(&a, &b)
}

/// Spawn a channel-aware forwarder on a fresh port; returns the port
/// and the join handle producing its relay stats. Legs may use any
/// stream counts (each hello declares its own).
pub fn spawn_channels(
    nstreams: usize,
) -> Result<(u16, std::thread::JoinHandle<Result<RelayStats>>)> {
    let mut cfg = PathConfig::with_streams(nstreams);
    cfg.autotune = false;
    let mut listener = PathListener::bind(0, cfg)?;
    let port = listener.port();
    let handle = std::thread::spawn(move || run_channels(&mut listener));
    Ok((port, handle))
}

/// Like [`crate::mpwide::relay::relay`] but optionally delaying each
/// forwarded batch by `delay` (one-way propagation emulation). Thin
/// wrapper over [`crate::mpwide::relay::relay_delayed`], so it shares
/// the relay's dead-leg semantics: a hard stream error tears both paths
/// down (unblocking the sibling pumps) and surfaces as
/// [`crate::mpwide::MpwError::RelayBroken`] with the partial totals,
/// instead of the forwarder hanging forever on the healthy leg's idle
/// streams.
pub fn relay_with_delay(a: &Path, b: &Path, delay: Option<Duration>) -> Result<RelayStats> {
    crate::mpwide::relay::relay_delayed(a, b, delay)
}

/// Spawn a forwarder on a fresh port; returns the port and the join
/// handle producing its relay stats. Autotuning must be disabled on the
/// connecting endpoints too (the forwarder cannot play autotune slave on
/// two sides at once before relaying).
pub fn spawn(
    nstreams: usize,
    delay: Option<Duration>,
) -> Result<(u16, std::thread::JoinHandle<Result<RelayStats>>)> {
    let mut cfg = PathConfig::with_streams(nstreams);
    cfg.autotune = false;
    let mut listener = PathListener::bind(0, cfg)?;
    let port = listener.port();
    let fcfg = ForwarderConfig { nstreams, delay, max_bytes: None };
    let handle = std::thread::spawn(move || run(&mut listener, &fcfg));
    Ok((port, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn client_cfg(n: usize) -> PathConfig {
        let mut c = PathConfig::with_streams(n);
        c.autotune = false;
        c
    }

    #[test]
    fn endpoints_communicate_through_forwarder() {
        let (port, fwd) = spawn(2, None).unwrap();
        let t_a = std::thread::spawn(move || {
            let p = Path::connect("127.0.0.1", port, client_cfg(2)).unwrap();
            p.send(&[7u8; 10_000]).unwrap();
            let mut buf = vec![0u8; 8];
            p.recv(&mut buf).unwrap();
            buf
        });
        let t_b = std::thread::spawn(move || {
            let p = Path::connect("127.0.0.1", port, client_cfg(2)).unwrap();
            let mut buf = vec![0u8; 10_000];
            p.recv(&mut buf).unwrap();
            p.send(&[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
            buf
        });
        let got_b = t_b.join().unwrap();
        assert_eq!(got_b, vec![7u8; 10_000]);
        assert_eq!(t_a.join().unwrap(), [1, 2, 3, 4, 5, 6, 7, 8]);
        let stats = fwd.join().unwrap().unwrap();
        // two messages crossed, each carrying the 2-byte active-stream header
        let hdr = 2 * crate::mpwide::path::ACTIVE_HEADER_LEN as u64;
        assert_eq!(stats.a_to_b + stats.b_to_a, 10_008 + hdr);
    }

    #[test]
    fn delay_inflates_round_trip() {
        let delay = Duration::from_millis(8);
        let (port, _fwd) = spawn(1, Some(delay)).unwrap();
        let t_b = std::thread::spawn(move || {
            let p = Path::connect("127.0.0.1", port, client_cfg(1)).unwrap();
            for _ in 0..3 {
                p.barrier().unwrap();
            }
        });
        let p = Path::connect("127.0.0.1", port, client_cfg(1)).unwrap();
        let t0 = Instant::now();
        for _ in 0..3 {
            p.barrier().unwrap();
        }
        let per_barrier = t0.elapsed() / 3;
        // barrier tokens travel concurrently in both directions, so each
        // barrier costs one forwarder hop (~8 ms), not two
        assert!(per_barrier >= Duration::from_millis(7), "{per_barrier:?}");
        assert!(per_barrier < Duration::from_millis(40), "{per_barrier:?}");
        t_b.join().unwrap();
    }

    #[test]
    fn mux_channels_cross_the_forwarder() {
        use crate::mpwide::mux::MuxEndpoint;
        use std::sync::Arc;
        let (port, fwd) = spawn_channels(1).unwrap();
        let t_a = std::thread::spawn(move || {
            let p = Arc::new(Path::connect("127.0.0.1", port, client_cfg(2)).unwrap());
            let mux = MuxEndpoint::start(p).unwrap();
            let c1 = mux.open(1).unwrap();
            let c2 = mux.open(2).unwrap();
            c1.send(&[7u8; 20_000]).unwrap();
            c2.send(b"telemetry").unwrap();
            let echo = c1.recv().unwrap();
            drop(mux); // closes the path → ends the relay session
            echo
        });
        let t_b = std::thread::spawn(move || {
            // the far leg deliberately uses a different stream count
            let p = Arc::new(Path::connect("127.0.0.1", port, client_cfg(3)).unwrap());
            let mux = MuxEndpoint::start(p).unwrap();
            let c1 = mux.open(1).unwrap();
            let c2 = mux.open(2).unwrap();
            let bulk = c1.recv().unwrap();
            let small = c2.recv().unwrap();
            c1.send(&bulk).unwrap();
            c1.flush().unwrap(); // the endpoint drop below is abrupt
            (bulk, small)
        });
        let (bulk, small) = t_b.join().unwrap();
        assert_eq!(bulk, vec![7u8; 20_000]);
        assert_eq!(small, b"telemetry");
        assert_eq!(t_a.join().unwrap(), vec![7u8; 20_000]);
        let _ = fwd.join().unwrap(); // session ends when a leg closes
    }

    #[test]
    fn mismatched_stream_counts_rejected() {
        use crate::mpwide::transport::mem_path_pairs;
        let (a, _x) = mem_path_pairs(2);
        let (b, _y) = mem_path_pairs(3);
        let mut cfg = PathConfig::default();
        cfg.autotune = false;
        let pa = Path::from_pairs(a, cfg.clone()).unwrap();
        let pb = Path::from_pairs(b, cfg).unwrap();
        assert!(relay_with_delay(&pa, &pb, None).is_err());
    }
}
