//! `MPWTest` (paper §1.4): the two-endpoint benchmark suite, "requires to
//! be started manually on both end points". The master side drives
//! full-duplex `MPW_SendRecv` exchanges over a range of message sizes and
//! reports throughput per size; the slave echoes. This is the harness
//! behind the MPWide rows of Table 1.

use std::time::Instant;

use crate::mpwide::errors::{MpwError, Result};
use crate::mpwide::path::Path;

/// Message sizes exercised by the suite (1 KB … 64 MB).
pub const SIZES: [usize; 7] =
    [1 << 10, 16 << 10, 256 << 10, 1 << 20, 4 << 20, 16 << 20, 64 << 20];

/// One row of the benchmark report.
#[derive(Debug, Clone)]
pub struct TestRow {
    /// Message size per direction, bytes.
    pub size: usize,
    /// Repetitions measured.
    pub reps: usize,
    /// Mean seconds per full-duplex exchange.
    pub seconds: f64,
    /// Duplex throughput, bytes/second (size / seconds, per direction).
    pub rate: f64,
}

/// Master side: run the suite over an established path. `reps_for` maps
/// a size to a repetition count (fewer reps for huge messages).
pub fn run_master(
    path: &Path,
    sizes: &[usize],
    reps_for: impl Fn(usize) -> usize,
) -> Result<Vec<TestRow>> {
    let mut rows = Vec::with_capacity(sizes.len());
    // announce the plan: count, then (size, reps) pairs
    let mut plan = Vec::new();
    plan.extend_from_slice(&(sizes.len() as u32).to_be_bytes());
    for &s in sizes {
        plan.extend_from_slice(&(s as u64).to_be_bytes());
        plan.extend_from_slice(&(reps_for(s) as u32).to_be_bytes());
    }
    path.dsend(&plan)?;

    for &size in sizes {
        let reps = reps_for(size);
        let msg = vec![0x5Au8; size];
        let mut buf = vec![0u8; size];
        path.barrier()?;
        let t0 = Instant::now();
        for _ in 0..reps {
            path.send_recv(&msg, &mut buf)?;
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        rows.push(TestRow { size, reps, seconds: dt, rate: size as f64 / dt });
    }
    Ok(rows)
}

/// Slave side: obey the master's plan, echoing exchanges.
pub fn run_slave(path: &Path) -> Result<()> {
    let plan = path.drecv()?;
    if plan.len() < 4 {
        return Err(MpwError::Protocol("short MPWTest plan".into()));
    }
    let n = u32::from_be_bytes(plan[0..4].try_into().unwrap()) as usize;
    if plan.len() != 4 + n * 12 {
        return Err(MpwError::Protocol("malformed MPWTest plan".into()));
    }
    for k in 0..n {
        let off = 4 + k * 12;
        let size = u64::from_be_bytes(plan[off..off + 8].try_into().unwrap()) as usize;
        let reps = u32::from_be_bytes(plan[off + 8..off + 12].try_into().unwrap()) as usize;
        let msg = vec![0xA5u8; size];
        let mut buf = vec![0u8; size];
        path.barrier()?;
        for _ in 0..reps {
            path.send_recv(&msg, &mut buf)?;
        }
    }
    Ok(())
}

/// Default repetition policy: more reps for small messages.
pub fn default_reps(size: usize) -> usize {
    match size {
        s if s <= 16 << 10 => 50,
        s if s <= 1 << 20 => 20,
        s if s <= 16 << 20 => 5,
        _ => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpwide::transport::mem_path_pairs;
    use crate::mpwide::PathConfig;

    fn mem_paths(n: usize) -> (Path, Path) {
        let (l, r) = mem_path_pairs(n);
        let mut cfg = PathConfig::with_streams(n);
        cfg.autotune = false;
        (Path::from_pairs(l, cfg.clone()).unwrap(), Path::from_pairs(r, cfg).unwrap())
    }

    #[test]
    fn master_slave_suite_completes() {
        let (a, b) = mem_paths(2);
        let t = std::thread::spawn(move || run_slave(&b).unwrap());
        let rows = run_master(&a, &[1024, 65536], |_| 3).unwrap();
        t.join().unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.reps, 3);
            assert!(r.seconds > 0.0);
            assert!(r.rate > 0.0);
        }
        assert_eq!(rows[0].size, 1024);
    }

    #[test]
    fn default_reps_monotonic() {
        assert!(default_reps(1024) >= default_reps(1 << 20));
        assert!(default_reps(1 << 20) >= default_reps(64 << 20));
    }

    #[test]
    fn slave_rejects_garbage_plan() {
        let (a, b) = mem_paths(1);
        let t = std::thread::spawn(move || run_slave(&b));
        a.dsend(&[1, 2, 3]).unwrap();
        assert!(t.join().unwrap().is_err());
    }
}
